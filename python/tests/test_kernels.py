"""L1 kernel correctness: Pallas vs pure-jnp oracle (the CORE signal).

hypothesis sweeps shapes and codebook sizes; fixed-seed cases pin exact
agreement. All kernels run under interpret=True (CPU PJRT constraint).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import assign, dequant, ref

RNG = np.random.default_rng(0)


def unit_rows(n, k, seed):
    rng = np.random.default_rng(seed)
    m = rng.standard_normal((n, k)).astype(np.float32)
    m /= np.linalg.norm(m, axis=1, keepdims=True)
    return m


# ---------------------------------------------------------------- assign ---

def test_assign_matches_ref_fixed():
    v = RNG.standard_normal((512, 8)).astype(np.float32)
    cb = unit_rows(1024, 8, 1)
    got = assign.assign_cosine_pallas(jnp.asarray(v), jnp.asarray(cb))
    want = ref.assign_cosine(jnp.asarray(v), jnp.asarray(cb))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=12, deadline=None)
@given(
    n_tiles=st.integers(1, 3),
    cb_tiles=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_assign_matches_ref_hypothesis(n_tiles, cb_tiles, seed):
    rng = np.random.default_rng(seed)
    n = assign.TV * n_tiles
    m = assign.TC * cb_tiles
    v = rng.standard_normal((n, 8)).astype(np.float32)
    cb = unit_rows(m, 8, seed + 1)
    got = assign.assign_cosine_pallas(jnp.asarray(v), jnp.asarray(cb))
    want = ref.assign_cosine(jnp.asarray(v), jnp.asarray(cb))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_assign_identity_on_codebook_rows():
    cb = unit_rows(512, 8, 2)
    got = assign.assign_cosine_pallas(jnp.asarray(cb[:256] * 2.5), jnp.asarray(cb))
    np.testing.assert_array_equal(np.asarray(got), np.arange(256))


def test_assign_rejects_unpadded():
    v = np.zeros((100, 8), np.float32)
    cb = unit_rows(512, 8, 3)
    with pytest.raises(AssertionError):
        assign.assign_cosine_pallas(jnp.asarray(v), jnp.asarray(cb))


def test_pad_to_multiple():
    x = jnp.ones((100, 8))
    padded, orig = assign.pad_to_multiple(x, 0, 256)
    assert padded.shape == (256, 8) and orig == 100
    same, n = assign.pad_to_multiple(padded, 0, 256)
    assert same.shape == (256, 8) and n == 256


# --------------------------------------------------------------- dequant ---

def _dequant_case(rows, cols, a, b, seed):
    rng = np.random.default_rng(seed)
    k = 8
    n = rows * cols // k
    di = rng.integers(0, 1 << a, n).astype(np.int32)
    mi = rng.integers(0, 1 << b, n).astype(np.int32)
    dcb = unit_rows(1 << a, k, seed + 1)
    mag = np.sort(rng.random(1 << b).astype(np.float32)) * 3 + 0.1
    sc = rng.random(cols).astype(np.float32) + 0.5
    sg = np.sign(rng.standard_normal(rows)).astype(np.float32)
    sg[sg == 0] = 1.0
    return di, mi, dcb, mag, sc, sg


@pytest.mark.parametrize("rows,cols", [(128, 64), (64, 128), (128, 512), (256, 64)])
def test_dequant_weight_matches_ref(rows, cols):
    di, mi, dcb, mag, sc, sg = _dequant_case(rows, cols, 9, 2, 7)
    args = tuple(map(jnp.asarray, (di, mi, dcb, mag, sc, sg)))
    got = dequant.dequant_weight_pallas(*args, rows=rows, cols=cols)
    want = ref.dequant_weight(*args, rows, cols)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


@settings(max_examples=8, deadline=None)
@given(
    a=st.integers(4, 12),
    b=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_dequant_weight_hypothesis(a, b, seed):
    rows, cols = 128, 128
    di, mi, dcb, mag, sc, sg = _dequant_case(rows, cols, a, b, seed)
    args = tuple(map(jnp.asarray, (di, mi, dcb, mag, sc, sg)))
    got = dequant.dequant_weight_pallas(*args, rows=rows, cols=cols)
    want = ref.dequant_weight(*args, rows, cols)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


# -------------------------------------------------------------- hadamard ---

def test_fwht_involution():
    x = RNG.standard_normal((4, 64)).astype(np.float32)
    y = ref.fwht(ref.fwht(jnp.asarray(x)))
    np.testing.assert_allclose(np.asarray(y), x, atol=1e-5)


def test_rht_forward_inverse_round_trip():
    x = RNG.standard_normal((8, 128)).astype(np.float32)
    signs = np.sign(RNG.standard_normal(128)).astype(np.float32)
    signs[signs == 0] = 1.0
    y = ref.rht_inverse(ref.rht_forward(jnp.asarray(x), signs), signs)
    np.testing.assert_allclose(np.asarray(y), x, atol=1e-5)


def test_hadamard_matrix_orthogonal():
    h = ref.hadamard_matrix(32)
    np.testing.assert_allclose(h @ h.T, 32 * np.eye(32), atol=1e-4)
