"""AOT export invariants — guards the HLO-text interchange contract.

The two classes of silent corruption we hit during bring-up (DESIGN.md §6):
  1. serialized protos from jax>=0.5 are rejected by xla_extension 0.5.1
     (we use text — nothing to test beyond producing it);
  2. the HLO text PRINTER elides large constants as `constant({...})`,
     which the parser then reads as garbage — every lowered graph must be
     constant-free above the elision threshold.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M
from compile.kernels import ref

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def lowered_text(fn, *specs):
    return aot.to_hlo_text(jax.jit(fn).lower(*specs))


def test_hadamard_jnp_lowers_without_large_constants():
    def f(x):
        return (ref.fwht(x),)

    text = lowered_text(f, jax.ShapeDtypeStruct((4, 128), jnp.float32))
    assert "constant({...})" not in text


def test_fwd_q_lowers_without_large_constants():
    cfg = M.CONFIGS["gpt-mini"]
    # build tiny specs mirroring export_fwd_q's geometry
    qnames = M.quantizable_names(cfg)
    fp_names = sorted(k for k in M.init_params(cfg, 0) if k not in qnames)
    shapes = {k: v.shape for k, v in M.init_params(cfg, 0).items()}

    def fwd(*args):
        fp_params = dict(zip(fp_names, args[: len(fp_names)]))
        qweights = {}
        pos = len(fp_names)
        for name in qnames:
            qweights[name] = {
                "dir_idx": args[pos],
                "mag_idx": args[pos + 1],
                "scales": args[pos + 2],
                "signs": args[pos + 3],
            }
            pos += 4
        return (
            M.forward_q(cfg, fp_params, qweights, args[pos], args[pos + 1], args[pos + 2]),
        )

    specs = [jax.ShapeDtypeStruct(shapes[k], jnp.float32) for k in fp_names]
    for name in qnames:
        rows, cols = M.weight_shape(cfg, name)
        n = rows * cols // 8
        specs += [
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((cols,), jnp.float32),
            jax.ShapeDtypeStruct((rows,), jnp.float32),
        ]
    specs += [
        jax.ShapeDtypeStruct((16, 8), jnp.float32),
        jax.ShapeDtypeStruct((4,), jnp.float32),
        jax.ShapeDtypeStruct((2, cfg.ctx), jnp.int32),
    ]
    text = lowered_text(fwd, *specs)
    assert "constant({...})" not in text


@pytest.mark.skipif(not os.path.isdir(ART), reason="artifacts not built")
def test_existing_artifacts_have_no_elided_constants():
    found = []
    for fn in os.listdir(ART):
        if fn.endswith(".hlo.txt"):
            with open(os.path.join(ART, fn)) as f:
                if "constant({...})" in f.read():
                    found.append(fn)
    assert not found, f"elided constants in: {found}"


@pytest.mark.skipif(not os.path.isdir(ART), reason="artifacts not built")
def test_manifests_match_hlo_parameter_counts():
    import re

    for fn in sorted(os.listdir(ART)):
        if not fn.endswith(".manifest"):
            continue
        base = fn[: -len(".manifest")]
        hlo_path = os.path.join(ART, base + ".hlo.txt")
        if not os.path.exists(hlo_path):
            continue
        n_manifest = sum(1 for line in open(os.path.join(ART, fn)) if line.strip())
        with open(hlo_path) as f:
            text = f.read()
        # count parameters of the entry computation from the header line
        header = text.splitlines()[0]
        m = re.search(r"entry_computation_layout=\{\((.*?)\)->", header)
        assert m, f"{base}: no entry layout header"
        # bracket-depth-aware split (layouts contain commas inside {} / [])
        depth = 0
        n_params = 0
        body = m.group(1).strip()
        if body:
            n_params = 1
            for ch in body:
                if ch in "{[(":
                    depth += 1
                elif ch in "}])":
                    depth -= 1
                elif ch == "," and depth == 0:
                    n_params += 1
        assert (
            n_params == n_manifest
        ), f"{base}: manifest {n_manifest} vs HLO {n_params} params"


@pytest.mark.skipif(not os.path.isdir(ART), reason="artifacts not built")
def test_trained_models_are_actually_trained():
    """A trained checkpoint must beat the random-init loss on held-out text
    (guards against the trainer silently diverging)."""
    from compile import pct

    eval_tokens = pct.load(os.path.join(ART, "corpus_eval.pct"))["tokens"]
    cfg = M.CONFIGS["gpt-mini"]
    weights = pct.load(os.path.join(ART, "gpt-mini.pct"))
    params = {
        k: jnp.asarray(v) for k, v in weights.items() if not k.startswith("meta.")
    }
    x = eval_tokens[: 4 * cfg.ctx].reshape(4, cfg.ctx).astype(np.int32)
    y = eval_tokens[1 : 4 * cfg.ctx + 1].reshape(4, cfg.ctx).astype(np.int32)
    loss = float(M.loss_fn(cfg, params, jnp.asarray(x), jnp.asarray(y)))
    assert loss < 4.5, f"eval loss {loss} — model looks untrained (ln256 = 5.55)"
