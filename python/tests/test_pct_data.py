"""PCT1 container + corpus pipeline tests (python side of the IO boundary)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import data as D
from compile import pct


def test_pct_round_trip(tmp_path):
    path = str(tmp_path / "t.pct")
    entries = {
        "w": np.arange(12, dtype=np.float32).reshape(3, 4),
        "idx": np.array([1, 2, 3], np.uint32),
        "seed": np.array([2**63], np.uint64),
        "neg": np.array([-4, 9], np.int32),
    }
    pct.save(path, entries)
    out = pct.load(path)
    assert set(out) == set(entries)
    for k in entries:
        np.testing.assert_array_equal(out[k], entries[k])
        assert out[k].dtype == entries[k].dtype


@settings(max_examples=20, deadline=None)
@given(
    shape=st.lists(st.integers(1, 8), min_size=0, max_size=3),
    seed=st.integers(0, 2**31 - 1),
)
def test_pct_round_trip_hypothesis(tmp_path_factory, shape, seed):
    path = str(tmp_path_factory.mktemp("pct") / "h.pct")
    rng = np.random.default_rng(seed)
    arr = rng.standard_normal(shape).astype(np.float32)
    pct.save(path, {"x": arr})
    out = pct.load(path)["x"]
    np.testing.assert_array_equal(out, arr)


def test_pct_rejects_unsupported_dtype(tmp_path):
    with pytest.raises(TypeError):
        pct.save(str(tmp_path / "bad.pct"), {"x": np.zeros(3, np.float64)})


def test_pct_rejects_garbage(tmp_path):
    p = tmp_path / "garbage.pct"
    p.write_bytes(b"NOTAPCT1234567")
    with pytest.raises(ValueError):
        pct.load(str(p))


def test_corpus_collection_and_split():
    corpus = D.collect_corpus(max_bytes=300_000)
    assert len(corpus) >= 100_000
    tokens = D.tokenize(corpus)
    assert tokens.dtype == np.uint32
    assert tokens.max() < 256
    tr, ev = D.train_eval_split(tokens)
    assert len(tr) + len(ev) == len(tokens)
    assert len(ev) >= 10_000


def test_batch_iterator_shapes_and_determinism():
    tokens = np.arange(10_000, dtype=np.uint32) % 256
    a = list(D.batch_iterator(tokens, 4, 32, 3, seed=9))
    b = list(D.batch_iterator(tokens, 4, 32, 3, seed=9))
    assert len(a) == 3
    for (xa, ya), (xb, yb) in zip(a, b):
        assert xa.shape == (4, 32) and ya.shape == (4, 32)
        np.testing.assert_array_equal(xa, xb)
        # targets are inputs shifted by one
        np.testing.assert_array_equal(xa[:, 1:], ya[:, :-1])
