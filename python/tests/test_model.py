"""L2 model tests: shapes, loss sanity, fp-vs-quantized agreement."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels import ref


@pytest.fixture(scope="module")
def tiny():
    cfg = M.CONFIGS["gpt-mini"]
    params = {k: jnp.asarray(v) for k, v in M.init_params(cfg, 0).items()}
    return cfg, params


def test_forward_shape(tiny):
    cfg, params = tiny
    toks = jnp.zeros((3, cfg.ctx), jnp.int32)
    out = M.forward_fp(cfg, params, toks)
    assert out.shape == (3, cfg.ctx, cfg.vocab)
    assert bool(jnp.isfinite(out).all())


def test_causality(tiny):
    """Changing a future token must not change past logits."""
    cfg, params = tiny
    rng = np.random.default_rng(0)
    t1 = rng.integers(0, 256, (1, cfg.ctx)).astype(np.int32)
    t2 = t1.copy()
    t2[0, -1] = (t2[0, -1] + 7) % 256
    l1 = M.forward_fp(cfg, params, jnp.asarray(t1))
    l2 = M.forward_fp(cfg, params, jnp.asarray(t2))
    np.testing.assert_allclose(
        np.asarray(l1[0, : cfg.ctx - 1]), np.asarray(l2[0, : cfg.ctx - 1]), atol=1e-5
    )


def test_loss_decreases_on_repeated_batch(tiny):
    """A couple of SGD steps on one batch must reduce its loss."""
    cfg, params = tiny
    rng = np.random.default_rng(1)
    x = rng.integers(0, 256, (4, cfg.ctx)).astype(np.int32)
    y = np.roll(x, -1, axis=1).astype(np.int32)
    x, y = jnp.asarray(x), jnp.asarray(y)
    grad_fn = jax.jit(jax.value_and_grad(lambda p: M.loss_fn(cfg, p, x, y)))
    l0, g = grad_fn(params)
    p2 = {k: v - 0.5 * g[k] for k, v in params.items()}
    l1, _ = grad_fn(p2)
    assert float(l1) < float(l0)


def test_init_loss_near_uniform(tiny):
    cfg, params = tiny
    rng = np.random.default_rng(2)
    x = rng.integers(0, 256, (4, cfg.ctx)).astype(np.int32)
    y = rng.integers(0, 256, (4, cfg.ctx)).astype(np.int32)
    loss = float(M.loss_fn(cfg, params, jnp.asarray(x), jnp.asarray(y)))
    assert abs(loss - np.log(256)) < 0.5, loss


def test_quantizable_shapes_power_of_two_rows():
    for name, cfg in M.CONFIGS.items():
        for q in M.quantizable_names(cfg):
            rows, cols = M.weight_shape(cfg, q)
            assert rows & (rows - 1) == 0, (name, q, rows)
            assert (rows * cols) % 8 == 0


def test_forward_q_matches_fp_at_high_fidelity(tiny):
    """With a huge direction codebook containing each weight's own directions
    we can't be exact, but identity-quantization (reconstructing from exact
    per-vector codes) must match: build codes by assigning against a codebook
    that *contains* the true normalized vectors."""
    cfg, params = tiny
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, 256, (2, cfg.ctx)).astype(np.int32))

    qweights = {}
    k = 8
    all_dirs = []
    all_mags = []
    per_w = {}
    # regularize each weight the same way rust does, collect exact dirs/mags
    for name in M.quantizable_names(cfg):
        rows, cols = M.weight_shape(cfg, name)
        w = np.asarray(params[name])
        signs = np.sign(rng.standard_normal(rows)).astype(np.float32)
        signs[signs == 0] = 1.0
        h = np.asarray(ref.rht_forward(jnp.asarray(w.T), signs)).T
        scales = np.linalg.norm(w, axis=0) / np.sqrt(rows)
        scales[scales == 0] = 1.0
        h = h / scales[None, :]
        vecs = h.reshape(-1, k)
        mags = np.linalg.norm(vecs, axis=1)
        dirs = vecs / np.maximum(mags[:, None], 1e-12)
        per_w[name] = (dirs, mags, scales, signs)
        all_dirs.append(dirs)
        all_mags.append(mags)

    # codebooks = the exact values themselves (perfect reconstruction)
    dir_cb = np.concatenate(all_dirs).astype(np.float32)
    mag_lv = np.concatenate(all_mags).astype(np.float32)
    dir_off = 0
    mag_off = 0
    for name in M.quantizable_names(cfg):
        dirs, mags, scales, signs = per_w[name]
        n = len(mags)
        qweights[name] = {
            "dir_idx": jnp.arange(dir_off, dir_off + n, dtype=jnp.int32),
            "mag_idx": jnp.arange(mag_off, mag_off + n, dtype=jnp.int32),
            "scales": jnp.asarray(scales.astype(np.float32)),
            "signs": jnp.asarray(signs),
        }
        dir_off += n
        mag_off += n

    lq = M.forward_q(cfg, params, qweights, jnp.asarray(dir_cb), jnp.asarray(mag_lv), toks)
    lf = M.forward_fp(cfg, params, toks)
    np.testing.assert_allclose(np.asarray(lq), np.asarray(lf), atol=2e-2)
