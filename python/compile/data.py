"""Corpus assembly + byte-level tokenization.

The paper evaluates on WikiText2/C4, which are not available offline. The
substitute (DESIGN.md §2) is a real text corpus assembled from documentation
and source text present in the image — README files, rust sources, python
sources — which gives a few MB of natural-ish English + code. Byte-level
tokenization (vocab = 256) avoids shipping a tokenizer across the language
boundary.
"""

from __future__ import annotations

import os
from pathlib import Path
from typing import List

import numpy as np

# Directories scanned for corpus text, in priority order.
CORPUS_ROOTS = [
    "/opt/xla-example/vendor",
    "/opt/xla-example",
    "/root/repo",
]
TEXT_SUFFIXES = {".md", ".rs", ".py", ".txt", ".toml"}
MAX_BYTES = 6_000_000
MAX_FILE_BYTES = 200_000

VOCAB = 256


def collect_corpus(max_bytes: int = MAX_BYTES) -> bytes:
    """Deterministically walk the corpus roots and concatenate text files."""
    chunks: List[bytes] = []
    total = 0
    for root in CORPUS_ROOTS:
        if total >= max_bytes:
            break
        if not os.path.isdir(root):
            continue
        for path in sorted(Path(root).rglob("*")):
            if total >= max_bytes:
                break
            if not path.is_file() or path.suffix not in TEXT_SUFFIXES:
                continue
            if "target" in path.parts or "artifacts" in path.parts:
                continue
            try:
                data = path.read_bytes()[:MAX_FILE_BYTES]
            except OSError:
                continue
            # keep it printable-ish: skip binary-looking files
            if data and data.count(0) == 0:
                chunks.append(data)
                chunks.append(b"\n\n")
                total += len(data) + 2
    corpus = b"".join(chunks)[:max_bytes]
    if len(corpus) < 100_000:
        raise RuntimeError(f"corpus too small: {len(corpus)} bytes")
    return corpus


def tokenize(data: bytes) -> np.ndarray:
    """Byte-level tokens as u32."""
    return np.frombuffer(data, dtype=np.uint8).astype(np.uint32)


def train_eval_split(tokens: np.ndarray, eval_frac: float = 0.05):
    """Contiguous head/tail split (no leakage across the boundary)."""
    n_eval = max(int(len(tokens) * eval_frac), 10_000)
    return tokens[:-n_eval], tokens[-n_eval:]


def batch_iterator(tokens: np.ndarray, batch: int, seq: int, steps: int, seed: int):
    """Random-crop batches of (inputs, targets), deterministic in `seed`."""
    rng = np.random.default_rng(seed)
    n = len(tokens) - seq - 1
    for _ in range(steps):
        starts = rng.integers(0, n, size=batch)
        x = np.stack([tokens[s : s + seq] for s in starts]).astype(np.int32)
        y = np.stack([tokens[s + 1 : s + seq + 1] for s in starts]).astype(np.int32)
        yield x, y
