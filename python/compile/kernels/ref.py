"""Pure-jnp oracles for the Pallas kernels — the L1 correctness ground truth.

Every Pallas kernel in this package has a reference here written in plain
`jax.numpy`; pytest (and hypothesis sweeps) assert allclose between kernel
and oracle across shapes. The rust unit tests independently pin the same
semantics, closing the three-way loop rust ⇄ pallas ⇄ jnp.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def hadamard_matrix(n: int) -> np.ndarray:
    """Sylvester construction of H_n (n a power of two), unnormalized."""
    assert n & (n - 1) == 0 and n > 0, f"n must be a power of two, got {n}"
    h = np.array([[1.0]], dtype=np.float32)
    while h.shape[0] < n:
        h = np.block([[h, h], [h, -h]]).astype(np.float32)
    return h


def hadamard_jnp(n: int) -> jnp.ndarray:
    """H_n built *in-graph* from iota + popcount parity:
    `H[i, j] = (-1)^{popcount(i & j)}`.

    Deliberately constant-free: the HLO text printer elides literals above a
    size threshold (`constant({...})`), which silently corrupts the AOT
    round-trip through xla_extension 0.5.1 (see DESIGN.md §6). An iota-based
    construction survives text serialization exactly.
    """
    i = jax.lax.broadcasted_iota(jnp.uint32, (n, n), 0)
    j = jax.lax.broadcasted_iota(jnp.uint32, (n, n), 1)
    bits = jax.lax.population_count(i & j)
    return jnp.where(bits % 2 == 0, 1.0, -1.0).astype(jnp.float32)


def fwht(x: jnp.ndarray) -> jnp.ndarray:
    """Orthonormal Walsh-Hadamard transform along the last axis."""
    n = x.shape[-1]
    h = hadamard_jnp(n) / jnp.sqrt(jnp.float32(n))
    return x @ h


def rht_forward(x: jnp.ndarray, signs: jnp.ndarray) -> jnp.ndarray:
    """Randomized Hadamard transform: (H/sqrt(n)) @ (signs * x) along the
    last axis (matching rust `RandomizedHadamard::forward_col`)."""
    return fwht(x * signs)


def rht_inverse(x: jnp.ndarray, signs: jnp.ndarray) -> jnp.ndarray:
    """Inverse RHT: signs * ((H/sqrt(n)) @ x)."""
    return fwht(x) * signs


def assign_cosine(vectors: jnp.ndarray, codebook: jnp.ndarray) -> jnp.ndarray:
    """argmax_j vectors @ codebook.T — direction assignment (Eq. 7 VQ_phi).

    vectors: (n, k); codebook: (m, k) unit rows. Returns int32 (n,).
    """
    scores = vectors @ codebook.T
    return jnp.argmax(scores, axis=-1).astype(jnp.int32)


def dequant_reconstruct(
    dir_idx: jnp.ndarray,
    mag_idx: jnp.ndarray,
    dir_codebook: jnp.ndarray,
    mag_levels: jnp.ndarray,
) -> jnp.ndarray:
    """Reconstruct k-vectors from PCDVQ indices (Eq. 8 inverse):
    v_hat[i] = mag_levels[mag_idx[i]] * dir_codebook[dir_idx[i]]."""
    dirs = dir_codebook[dir_idx]          # (n, k)
    mags = mag_levels[mag_idx][:, None]   # (n, 1)
    return dirs * mags


def dequant_weight(
    dir_idx: jnp.ndarray,
    mag_idx: jnp.ndarray,
    dir_codebook: jnp.ndarray,
    mag_levels: jnp.ndarray,
    scales: jnp.ndarray,
    signs: jnp.ndarray,
    rows: int,
    cols: int,
) -> jnp.ndarray:
    """Full PCDVQ weight reconstruction, replaying rust
    `Pcdvq::dequantize_full`: codes -> k-vectors -> (rows, cols) matrix in the
    regularized domain -> per-column scales -> inverse RHT over the row dim.
    """
    vhat = dequant_reconstruct(dir_idx, mag_idx, dir_codebook, mag_levels)
    h = vhat.reshape(rows, cols)
    h = h * scales[None, :]
    # inverse RHT acts per column, i.e. along axis 0: transpose, apply, undo
    w = rht_inverse(h.T, signs).T
    return w


def dequant_matmul(
    x: jnp.ndarray,
    dir_idx: jnp.ndarray,
    mag_idx: jnp.ndarray,
    dir_codebook: jnp.ndarray,
    mag_levels: jnp.ndarray,
    scales: jnp.ndarray,
    signs: jnp.ndarray,
    rows: int,
    cols: int,
) -> jnp.ndarray:
    """Fused dequant + matmul oracle: y = x @ W_hat."""
    w = dequant_weight(
        dir_idx, mag_idx, dir_codebook, mag_levels, scales, signs, rows, cols
    )
    return x @ w
