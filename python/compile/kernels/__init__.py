"""L1 Pallas kernels (assign, dequant) and their pure-jnp oracles (ref)."""
