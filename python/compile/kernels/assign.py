"""Pallas kernel: tiled cosine-argmax direction assignment (L1).

The paper's nearest-codeword search is a CUDA per-thread scan in prior VQ
systems; the TPU rethink (DESIGN.md §7) formulates it as an MXU GEMM
(vector-tile x codebook-tile^T) followed by an on-chip running argmax across
codebook tiles:

  grid = (n_vec_tiles, n_cb_tiles); each step computes a (TV, TC) score tile
  in VMEM and folds it into per-vector running (best_score, best_index)
  accumulators that live in the output refs across the codebook-tile axis.

VMEM budget at the paper config (a = 14, k = 8): codebook tile 512x8 f32 =
16 KiB, vector tile 1024x8 f32 = 32 KiB, score tile 1024x512 f32 = 2 MiB —
comfortably inside the ~16 MiB VMEM of a TPUv4 core; the MXU sees
(1024x8)@(8x512) bf16-able GEMMs. On this image the kernel runs under
``interpret=True`` (Mosaic custom-calls cannot execute on CPU PJRT), so
correctness is validated here and performance is *estimated* in
EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile sizes (see module docstring for the VMEM budget).
TV = 256   # vectors per tile
TC = 512   # codebook rows per tile


def _assign_kernel(v_ref, cb_ref, best_ref, idx_ref):
    """One (vector-tile, codebook-tile) grid step."""
    j = pl.program_id(1)

    # (TV, k) @ (k, TC) -> (TV, TC) score tile: the MXU GEMM.
    scores = v_ref[...] @ cb_ref[...].T

    tile_best = jnp.max(scores, axis=1)
    tile_arg = jnp.argmax(scores, axis=1).astype(jnp.int32) + j * TC

    @pl.when(j == 0)
    def _init():
        best_ref[...] = tile_best
        idx_ref[...] = tile_arg

    @pl.when(j > 0)
    def _fold():
        prev_best = best_ref[...]
        prev_idx = idx_ref[...]
        take = tile_best > prev_best
        best_ref[...] = jnp.where(take, tile_best, prev_best)
        idx_ref[...] = jnp.where(take, tile_arg, prev_idx)


@functools.partial(jax.jit, static_argnames=("interpret",))
def assign_cosine_pallas(
    vectors: jnp.ndarray, codebook: jnp.ndarray, *, interpret: bool = True
) -> jnp.ndarray:
    """Direction assignment via the tiled Pallas kernel.

    vectors: (n, k) with n % TV == 0; codebook: (m, k) with m % TC == 0.
    Returns int32 (n,) argmax-cosine indices (codebook rows unit-norm).
    """
    n, k = vectors.shape
    m, k2 = codebook.shape
    assert k == k2, f"dim mismatch {k} vs {k2}"
    assert n % TV == 0, f"n={n} must be a multiple of {TV} (pad upstream)"
    assert m % TC == 0, f"m={m} must be a multiple of {TC} (pad upstream)"

    grid = (n // TV, m // TC)
    best, idx = pl.pallas_call(
        _assign_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TV, k), lambda i, j: (i, 0)),
            pl.BlockSpec((TC, k), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((TV,), lambda i, j: (i,)),
            pl.BlockSpec((TV,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
        ],
        interpret=interpret,
    )(vectors, codebook)
    del best
    return idx


def pad_to_multiple(x: jnp.ndarray, axis: int, multiple: int, value: float = 0.0):
    """Pad `x` along `axis` up to the next multiple; returns (padded, orig)."""
    n = x.shape[axis]
    target = ((n + multiple - 1) // multiple) * multiple
    if target == n:
        return x, n
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, target - n)
    return jnp.pad(x, pad, constant_values=value), n
