"""Pallas kernel: fused PCDVQ dequant + matmul tile (L1).

The serving hot loop computes `y = x @ W_hat` where `W_hat` never exists in
HBM at full precision — only the 2-bit code stream, the two DACC codebooks
and the per-column scales do. The CUDA implementations of prior VQ systems
gather codewords through shared memory per threadblock; the TPU rethink
(DESIGN.md §7):

  * both codebooks are VMEM-resident for the whole kernel (dir codebook at
    a = 14 is 16384x8 f32 = 512 KiB; mag levels are tiny),
  * the grid walks (row-tile, col-tile) over the *regularized* weight H; the
    code tile for a (TR, TCOL) block is gathered in VMEM and scaled, then
  * the MXU consumes the reconstructed tile for the GEMM against the
    activation strip; the inverse RHT is folded into the activations once per
    call (it commutes with the column-blocked GEMM).

Under ``interpret=True`` the gather lowers to plain HLO; numerics are
validated against `ref.dequant_matmul` in pytest.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

TR = 64    # rows of H per tile (vector groups: TR*TCOL/k codes per tile)
TCOL = 64  # columns of H per tile


def _dequant_tile_kernel(dir_idx_ref, mag_idx_ref, dir_cb_ref, mag_ref, scale_ref, h_ref):
    """Reconstruct one (TR, TCOL) tile of the regularized weight H."""
    # Codes for this tile: (TR, TCOL//k) each. Flatten to 1-D before the
    # gather: row-gathers with rank-1 indices lower to the same HLO pattern
    # as embedding lookups, which the pinned xla_extension 0.5.1 executes
    # correctly — the rank-2 scalar-gather form mis-executes after the HLO
    # text round-trip (returns zeros), see DESIGN.md §6.
    di = dir_idx_ref[...].reshape(-1)             # (TR*TCOL//k,)
    mi = mag_idx_ref[...].reshape(-1)
    dirs = dir_cb_ref[di]                         # (TR*TCOL//k, k)
    mags = mag_ref[mi][:, None]                   # (TR*TCOL//k, 1)
    tile = (dirs * mags).reshape(TR, TCOL)
    h_ref[...] = tile * scale_ref[...][None, :]


@functools.partial(jax.jit, static_argnames=("rows", "cols", "interpret"))
def dequant_weight_pallas(
    dir_idx: jnp.ndarray,
    mag_idx: jnp.ndarray,
    dir_codebook: jnp.ndarray,
    mag_levels: jnp.ndarray,
    scales: jnp.ndarray,
    signs: jnp.ndarray,
    *,
    rows: int,
    cols: int,
    interpret: bool = True,
) -> jnp.ndarray:
    """Reconstruct the full weight `W_hat` from PCDVQ codes via the tiled
    Pallas gather kernel + inverse RHT.

    The code stream is ordered row-major over H (k consecutive elements of a
    row form one vector), matching rust `Pcdvq::quantize_full`.
    """
    k = dir_codebook.shape[1]
    assert rows % TR == 0 and cols % TCOL == 0, (rows, cols)
    assert TCOL % k == 0
    codes_per_tile = TR * TCOL // k
    codes_per_rowstrip = cols // k  # codes per row of H

    # Reshape the flat code stream into (row_tiles, col_tiles, codes_per_tile)
    # gatherable blocks: code (r, c) lives at r*codes_per_rowstrip + c.
    n_codes = rows * cols // k
    assert dir_idx.shape == (n_codes,)
    di = dir_idx.reshape(rows, codes_per_rowstrip)
    mi = mag_idx.reshape(rows, codes_per_rowstrip)

    grid = (rows // TR, cols // TCOL)
    h = pl.pallas_call(
        _dequant_tile_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TR, TCOL // k), lambda i, j: (i, j)),
            pl.BlockSpec((TR, TCOL // k), lambda i, j: (i, j)),
            pl.BlockSpec(dir_codebook.shape, lambda i, j: (0, 0)),
            pl.BlockSpec(mag_levels.shape, lambda i, j: (0,)),
            pl.BlockSpec((TCOL,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((TR, TCOL), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        interpret=interpret,
    )(di, mi, dir_codebook, mag_levels, scales)

    # inverse RHT over the row dimension (per column)
    return ref.rht_inverse(h.T, signs).T


def _reshape_codes_for_tile(idx: jnp.ndarray, rows: int, cols: int, k: int):
    """(kept for documentation) the BlockSpec above indexes codes as a
    (rows, cols//k) grid so each (TR, TCOL//k) block holds exactly the codes
    of one weight tile."""
    return idx.reshape(rows, cols // k)
