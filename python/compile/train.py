"""Build-time trainer for the tinygpt model zoo.

Hand-rolled AdamW (optax is not installed) over the byte corpus from
`data.py`. Produces `artifacts/<name>.pct` weight containers the Rust
coordinator loads, plus the train/eval token streams. Runs once under
`make artifacts`; never on the request path.
"""

from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from . import model as model_mod
from . import pct

# Training budget per model (single CPU core: keep modest; the goal is a
# model whose quantization degradation is measurable, not SOTA bytes/char).
TRAIN_STEPS = {
    "gpt-s": 250,
    "gpt-m": 300,
    "gpt-l": 200,
    "gpt-alt": 250,
    "gpt-mini": 200,
}
BATCH = 8
LR = 3e-3
WARMUP = 20
WEIGHT_DECAY = 0.01
SEEDS = {"gpt-s": 1, "gpt-m": 2, "gpt-l": 3, "gpt-alt": 40, "gpt-mini": 50}


def adamw_init(params):
    zeros = {k: np.zeros_like(v) for k, v in params.items()}
    return {"m": zeros, "v": {k: np.zeros_like(v) for k, v in params.items()}, "t": 0}


def make_update_fn(cfg):
    """jitted (params, m, v, t, x, y, lr) -> (loss, params, m, v)."""

    def update(params, m, v, t, x, y, lr):
        loss, grads = jax.value_and_grad(
            lambda p: model_mod.loss_fn(cfg, p, x, y)
        )(params)
        b1, b2, eps = 0.9, 0.95, 1e-8
        new_params, new_m, new_v = {}, {}, {}
        for k in params:
            g = grads[k]
            m_k = b1 * m[k] + (1 - b1) * g
            v_k = b2 * v[k] + (1 - b2) * g * g
            mhat = m_k / (1 - b1 ** t)
            vhat = v_k / (1 - b2 ** t)
            p = params[k] * (1 - lr * WEIGHT_DECAY)
            new_params[k] = p - lr * mhat / (jnp.sqrt(vhat) + eps)
            new_m[k] = m_k
            new_v[k] = v_k
        return loss, new_params, new_m, new_v

    return jax.jit(update)


def lr_schedule(step: int, total: int) -> float:
    if step < WARMUP:
        return LR * (step + 1) / WARMUP
    frac = (step - WARMUP) / max(total - WARMUP, 1)
    return LR * 0.5 * (1 + np.cos(np.pi * frac))


def train_model(name: str, tokens_train: np.ndarray, log=print) -> Dict[str, np.ndarray]:
    cfg = model_mod.CONFIGS[name]
    steps = TRAIN_STEPS[name]
    seed = SEEDS[name]
    params_np = model_mod.init_params(cfg, seed)
    log(
        f"[train] {name}: {model_mod.count_params(params_np)/1e6:.2f}M params, "
        f"{steps} steps, batch {BATCH}x{cfg.ctx}"
    )
    params = {k: jnp.asarray(v) for k, v in params_np.items()}
    m = {k: jnp.zeros_like(v) for k, v in params.items()}
    v = {k: jnp.zeros_like(v_) for k, v_ in params.items()}
    update = make_update_fn(cfg)

    t0 = time.time()
    it = data_mod.batch_iterator(tokens_train, BATCH, cfg.ctx, steps, seed + 1000)
    loss = float("nan")
    for step, (x, y) in enumerate(it):
        lr = lr_schedule(step, steps)
        loss, params, m, v = update(
            params, m, v, jnp.float32(step + 1), jnp.asarray(x), jnp.asarray(y), jnp.float32(lr)
        )
        if step % 50 == 0 or step == steps - 1:
            log(f"[train] {name} step {step:4d} loss {float(loss):.4f} ({time.time()-t0:.0f}s)")
    log(f"[train] {name} done: final loss {float(loss):.4f} in {time.time()-t0:.0f}s")
    return {k: np.asarray(val) for k, val in params.items()}


def save_model(path: str, name: str, params: Dict[str, np.ndarray]) -> None:
    cfg = model_mod.CONFIGS[name]
    entries = dict(params)
    # model metadata as scalar entries
    entries["meta.vocab"] = np.array([cfg.vocab], np.uint64)
    entries["meta.d_model"] = np.array([cfg.d_model], np.uint64)
    entries["meta.n_layer"] = np.array([cfg.n_layer], np.uint64)
    entries["meta.n_head"] = np.array([cfg.n_head], np.uint64)
    entries["meta.d_ff"] = np.array([cfg.d_ff], np.uint64)
    entries["meta.ctx"] = np.array([cfg.ctx], np.uint64)
    pct.save(path, entries)
