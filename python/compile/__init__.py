"""Build-time python package: L2 JAX model + L1 Pallas kernels + AOT export.

Never imported at runtime — the Rust coordinator consumes only the artifacts
this package writes (HLO text, PCT1 weight containers, manifests).
"""
