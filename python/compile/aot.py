"""AOT export: corpus -> trained models -> HLO-text artifacts + manifests.

This is the single build-time python entry point (`make artifacts`):

    cd python && python -m compile.aot --out ../artifacts

Outputs (all consumed by the Rust coordinator, never by python at runtime):

    corpus_train.pct / corpus_eval.pct   byte-token streams (u32)
    <model>.pct                          trained tinygpt weights + meta
    fwd_fp_<model>_b{B}.hlo.txt/.manifest   dense forward (logits)
    fwd_q_<model>.hlo.txt/.manifest      PCDVQ in-graph-dequant forward
    assign_chunk.hlo.txt/.manifest       Pallas cosine-argmax kernel chunk
    dequant_weight.hlo.txt/.manifest     Pallas fused dequant kernel

Interchange is HLO **text** (xla_extension 0.5.1 rejects jax>=0.5 protos with
64-bit ids — see /opt/xla-example/README.md); Pallas kernels are lowered with
``interpret=True`` so CPU PJRT can execute them (Mosaic custom-calls cannot).
"""

from __future__ import annotations

import argparse
import os
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data as data_mod
from . import model as model_mod
from . import pct
from . import train as train_mod
from .kernels import assign as assign_kernel
from .kernels import dequant as dequant_kernel

# Serving/eval batch geometry compiled into the artifacts.
BATCH = 8
# PCDVQ serving config baked into fwd_q: the paper's 2.0-bpw setting.
DIR_BITS = 14
MAG_BITS = 2
K = 8
# Pallas assign-chunk geometry.
ASSIGN_CHUNK = 8192
ASSIGN_CB = 1 << DIR_BITS


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def write_manifest(path: str, args: Sequence[Tuple[str, np.dtype, Tuple[int, ...]]]):
    """Text manifest: `<index> <name> <dtype> <d0,d1,...>` per input, in HLO
    parameter order. Rust's runtime::manifest parses this."""
    with open(path, "w") as f:
        for i, (name, dtype, shape) in enumerate(args):
            dims = ",".join(str(d) for d in shape) if shape else "scalar"
            f.write(f"{i} {name} {np.dtype(dtype).name} {dims}\n")


def export_fwd_fp(cfg, out_dir: str, batch: int) -> None:
    """Dense forward as a flat-tuple function (explicit parameter order)."""
    names = sorted(model_mod.init_params(cfg, 0).keys())
    shapes = {k: v.shape for k, v in model_mod.init_params(cfg, 0).items()}

    def fwd(*args):
        params = dict(zip(names, args[:-1]))
        tokens = args[-1]
        return (model_mod.forward_fp(cfg, params, tokens),)

    specs = [jax.ShapeDtypeStruct(shapes[k], jnp.float32) for k in names]
    specs.append(jax.ShapeDtypeStruct((batch, cfg.ctx), jnp.int32))
    lowered = jax.jit(fwd).lower(*specs)
    base = os.path.join(out_dir, f"fwd_fp_{cfg.name}_b{batch}")
    with open(base + ".hlo.txt", "w") as f:
        f.write(to_hlo_text(lowered))
    manifest = [(k, np.float32, shapes[k]) for k in names]
    manifest.append(("tokens", np.int32, (batch, cfg.ctx)))
    write_manifest(base + ".manifest", manifest)
    print(f"[aot] wrote {base}.hlo.txt ({len(names)+1} inputs)")


def export_fwd_q(cfg, out_dir: str, batch: int) -> None:
    """PCDVQ serving forward: codes + codebooks in, logits out."""
    qnames = model_mod.quantizable_names(cfg)
    fp_names = sorted(
        k for k in model_mod.init_params(cfg, 0).keys() if k not in qnames
    )
    shapes = {k: v.shape for k, v in model_mod.init_params(cfg, 0).items()}

    manifest: List[Tuple[str, np.dtype, Tuple[int, ...]]] = []
    specs: List[jax.ShapeDtypeStruct] = []
    for k in fp_names:
        manifest.append((k, np.float32, shapes[k]))
        specs.append(jax.ShapeDtypeStruct(shapes[k], jnp.float32))
    for name in qnames:
        rows, cols = model_mod.weight_shape(cfg, name)
        n_vec = rows * cols // K
        for field, dt, shp in (
            ("dir_idx", np.int32, (n_vec,)),
            ("mag_idx", np.int32, (n_vec,)),
            ("scales", np.float32, (cols,)),
            ("signs", np.float32, (rows,)),
        ):
            manifest.append((f"{name}.{field}", dt, shp))
            specs.append(jax.ShapeDtypeStruct(shp, jnp.dtype(dt)))
    manifest.append(("codebook.dir", np.float32, (1 << DIR_BITS, K)))
    specs.append(jax.ShapeDtypeStruct((1 << DIR_BITS, K), jnp.float32))
    manifest.append(("codebook.mag", np.float32, (1 << MAG_BITS,)))
    specs.append(jax.ShapeDtypeStruct((1 << MAG_BITS,), jnp.float32))
    manifest.append(("tokens", np.int32, (batch, cfg.ctx)))
    specs.append(jax.ShapeDtypeStruct((batch, cfg.ctx), jnp.int32))

    n_fp = len(fp_names)

    def fwd(*args):
        fp_params = dict(zip(fp_names, args[:n_fp]))
        qweights = {}
        pos = n_fp
        for name in qnames:
            qweights[name] = {
                "dir_idx": args[pos],
                "mag_idx": args[pos + 1],
                "scales": args[pos + 2],
                "signs": args[pos + 3],
            }
            pos += 4
        dir_cb, mag_levels, tokens = args[pos], args[pos + 1], args[pos + 2]
        return (
            model_mod.forward_q(cfg, fp_params, qweights, dir_cb, mag_levels, tokens),
        )

    lowered = jax.jit(fwd).lower(*specs)
    base = os.path.join(out_dir, f"fwd_q_{cfg.name}")
    with open(base + ".hlo.txt", "w") as f:
        f.write(to_hlo_text(lowered))
    write_manifest(base + ".manifest", manifest)
    print(f"[aot] wrote {base}.hlo.txt ({len(specs)} inputs)")


def export_assign_kernel(out_dir: str) -> None:
    """The L1 Pallas cosine-argmax kernel as a standalone chunk executable."""

    def fn(vectors, codebook):
        return (assign_kernel.assign_cosine_pallas(vectors, codebook, interpret=True),)

    specs = (
        jax.ShapeDtypeStruct((ASSIGN_CHUNK, K), jnp.float32),
        jax.ShapeDtypeStruct((ASSIGN_CB, K), jnp.float32),
    )
    lowered = jax.jit(fn).lower(*specs)
    base = os.path.join(out_dir, "assign_chunk")
    with open(base + ".hlo.txt", "w") as f:
        f.write(to_hlo_text(lowered))
    write_manifest(
        base + ".manifest",
        [
            ("vectors", np.float32, (ASSIGN_CHUNK, K)),
            ("codebook", np.float32, (ASSIGN_CB, K)),
        ],
    )
    print(f"[aot] wrote {base}.hlo.txt")


def export_dequant_kernel(out_dir: str) -> None:
    """The L1 Pallas fused-dequant kernel for a 128x512 weight tile-grid."""
    rows, cols = 128, 512
    n_vec = rows * cols // K

    def fn(dir_idx, mag_idx, dir_cb, mag_levels, scales, signs):
        return (
            dequant_kernel.dequant_weight_pallas(
                dir_idx, mag_idx, dir_cb, mag_levels, scales, signs,
                rows=rows, cols=cols, interpret=True,
            ),
        )

    specs = (
        jax.ShapeDtypeStruct((n_vec,), jnp.int32),
        jax.ShapeDtypeStruct((n_vec,), jnp.int32),
        jax.ShapeDtypeStruct((1 << DIR_BITS, K), jnp.float32),
        jax.ShapeDtypeStruct((1 << MAG_BITS,), jnp.float32),
        jax.ShapeDtypeStruct((cols,), jnp.float32),
        jax.ShapeDtypeStruct((rows,), jnp.float32),
    )
    lowered = jax.jit(fn).lower(*specs)
    base = os.path.join(out_dir, "dequant_weight")
    with open(base + ".hlo.txt", "w") as f:
        f.write(to_hlo_text(lowered))
    write_manifest(
        base + ".manifest",
        [
            ("dir_idx", np.int32, (n_vec,)),
            ("mag_idx", np.int32, (n_vec,)),
            ("codebook.dir", np.float32, (1 << DIR_BITS, K)),
            ("codebook.mag", np.float32, (1 << MAG_BITS,)),
            ("scales", np.float32, (cols,)),
            ("signs", np.float32, (rows,)),
        ],
    )
    print(f"[aot] wrote {base}.hlo.txt")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument(
        "--models",
        default="gpt-s,gpt-m,gpt-l,gpt-alt,gpt-mini",
        help="comma-separated model names to train/export",
    )
    ap.add_argument("--steps-scale", type=float, default=1.0,
                    help="scale training steps (CI smoke: 0.05)")
    ap.add_argument("--skip-train", action="store_true",
                    help="only (re)export HLO for existing weights")
    args = ap.parse_args()
    out = args.out
    os.makedirs(out, exist_ok=True)
    models = [m for m in args.models.split(",") if m]

    # 1. corpus
    train_tok_path = os.path.join(out, "corpus_train.pct")
    eval_tok_path = os.path.join(out, "corpus_eval.pct")
    if not (os.path.exists(train_tok_path) and os.path.exists(eval_tok_path)):
        corpus = data_mod.collect_corpus()
        tokens = data_mod.tokenize(corpus)
        tr, ev = data_mod.train_eval_split(tokens)
        pct.save(train_tok_path, {"tokens": tr.astype(np.uint32)})
        pct.save(eval_tok_path, {"tokens": ev.astype(np.uint32)})
        print(f"[aot] corpus: {len(tr)} train / {len(ev)} eval tokens")
    else:
        tr = pct.load(train_tok_path)["tokens"]
        print(f"[aot] corpus cached: {len(tr)} train tokens")

    # 2. train models (skipped per-model when weights already exist)
    for name in models:
        wpath = os.path.join(out, f"{name}.pct")
        if os.path.exists(wpath) or args.skip_train:
            print(f"[aot] weights cached: {wpath}")
            continue
        steps = max(int(train_mod.TRAIN_STEPS[name] * args.steps_scale), 5)
        saved = train_mod.TRAIN_STEPS[name]
        train_mod.TRAIN_STEPS[name] = steps
        params = train_mod.train_model(name, tr)
        train_mod.TRAIN_STEPS[name] = saved
        train_mod.save_model(wpath, name, params)
        print(f"[aot] saved {wpath}")

    # 3. HLO artifacts
    for name in models:
        cfg = model_mod.CONFIGS[name]
        export_fwd_fp(cfg, out, BATCH)
        export_fwd_fp(cfg, out, 1)  # latency-path artifact
        export_fwd_q(cfg, out, BATCH)
    export_assign_kernel(out)
    export_dequant_kernel(out)
    print("[aot] done")


if __name__ == "__main__":
    main()
