"""L2 — the tinygpt model in JAX: fp forward, quantized forward, loss.

The substitution model for the LLaMA family (DESIGN.md §2): a byte-level
pre-norm GPT. Everything here is build-time only; the forward passes are
AOT-lowered to HLO text by `aot.py` and executed from the Rust coordinator
via PJRT.

Two forward variants share all code except the linear weights:

* `forward_fp(params, tokens)` — dense f32 weights (also used for training
  and as the baseline-eval artifact: the coordinator feeds *fake-quant*
  weights from any baseline into the same executable).
* `forward_q(qparams, tokens)` — the PCDVQ serving path: every quantizable
  matrix arrives as (dir_idx, mag_idx, scales, signs) plus the two shared
  DACC codebooks; dequantization happens **in-graph** (gather + scale +
  inverse RHT), so the weight never exists densely outside the executable.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ref


@dataclass(frozen=True)
class GptConfig:
    """tinygpt hyper-parameters. Dimensions are powers of two so every
    quantizable matrix has power-of-two rows (RHT requirement)."""

    name: str
    vocab: int = 256
    d_model: int = 128
    n_layer: int = 4
    n_head: int = 4
    d_ff: int = 512
    ctx: int = 128

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_head


# The model zoo: LLaMA-2 7B/13B/70B analogs (Table 1) + LLaMA-3/Mistral
# analogs (Table 2). See DESIGN.md §2 for the substitution argument.
CONFIGS: Dict[str, GptConfig] = {
    "gpt-s": GptConfig(name="gpt-s", d_model=128, n_layer=2, d_ff=512),
    "gpt-m": GptConfig(name="gpt-m", d_model=128, n_layer=4, d_ff=512),
    "gpt-l": GptConfig(name="gpt-l", d_model=256, n_layer=4, d_ff=1024),
    "gpt-alt": GptConfig(name="gpt-alt", d_model=128, n_layer=4, d_ff=512),
    "gpt-mini": GptConfig(name="gpt-mini", d_model=128, n_layer=2, d_ff=512),
}

# Names of the quantizable matrices per layer + top level, in a fixed order.
def quantizable_names(cfg: GptConfig) -> List[str]:
    names = []
    for i in range(cfg.n_layer):
        names += [
            f"layer{i}.attn.wq",
            f"layer{i}.attn.wk",
            f"layer{i}.attn.wv",
            f"layer{i}.attn.wo",
            f"layer{i}.mlp.w1",
            f"layer{i}.mlp.w2",
        ]
    names.append("head.w")
    return names


def weight_shape(cfg: GptConfig, name: str) -> Tuple[int, int]:
    """(rows, cols) of a quantizable matrix; rows = input dim (RHT axis)."""
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab
    if name.endswith("mlp.w1"):
        return (d, f)
    if name.endswith("mlp.w2"):
        return (f, d)
    if name == "head.w":
        return (d, v)
    return (d, d)  # attention projections


def init_params(cfg: GptConfig, seed: int) -> Dict[str, np.ndarray]:
    """Initialize all parameters (numpy, f32) with GPT-2-style scaling."""
    rng = np.random.default_rng(seed)
    p: Dict[str, np.ndarray] = {}
    d = cfg.d_model

    def w(shape, scale):
        return (rng.standard_normal(shape) * scale).astype(np.float32)

    p["embed.tok"] = w((cfg.vocab, d), 0.02)
    p["embed.pos"] = w((cfg.ctx, d), 0.01)
    for i in range(cfg.n_layer):
        for nm in ("wq", "wk", "wv"):
            p[f"layer{i}.attn.{nm}"] = w((d, d), d ** -0.5)
        p[f"layer{i}.attn.wo"] = w((d, d), (d * 2 * cfg.n_layer) ** -0.5)
        p[f"layer{i}.mlp.w1"] = w((d, cfg.d_ff), d ** -0.5)
        p[f"layer{i}.mlp.w2"] = w((cfg.d_ff, d), (cfg.d_ff * 2 * cfg.n_layer) ** -0.5)
        p[f"layer{i}.ln1.g"] = np.ones(d, np.float32)
        p[f"layer{i}.ln1.b"] = np.zeros(d, np.float32)
        p[f"layer{i}.ln2.g"] = np.ones(d, np.float32)
        p[f"layer{i}.ln2.b"] = np.zeros(d, np.float32)
    p["final_ln.g"] = np.ones(d, np.float32)
    p["final_ln.b"] = np.zeros(d, np.float32)
    p["head.w"] = w((d, cfg.vocab), d ** -0.5)
    return p


def _layer_norm(x, g, b):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-5) * g + b


def _attention(cfg: GptConfig, x, wq, wk, wv, wo):
    b, t, d = x.shape
    h, hd = cfg.n_head, cfg.head_dim
    q = (x @ wq).reshape(b, t, h, hd).transpose(0, 2, 1, 3)
    k = (x @ wk).reshape(b, t, h, hd).transpose(0, 2, 1, 3)
    v = (x @ wv).reshape(b, t, h, hd).transpose(0, 2, 1, 3)
    att = (q @ k.transpose(0, 1, 3, 2)) / jnp.sqrt(jnp.float32(hd))
    mask = jnp.tril(jnp.ones((t, t), bool))
    att = jnp.where(mask[None, None], att, -1e9)
    att = jax.nn.softmax(att, axis=-1)
    y = (att @ v).transpose(0, 2, 1, 3).reshape(b, t, d)
    return y @ wo


def forward_fp(cfg: GptConfig, params: Dict[str, jnp.ndarray], tokens: jnp.ndarray):
    """Dense forward: tokens (B, T) int32 -> logits (B, T, vocab) f32."""
    b, t = tokens.shape
    x = params["embed.tok"][tokens] + params["embed.pos"][:t][None]
    for i in range(cfg.n_layer):
        ln1 = _layer_norm(x, params[f"layer{i}.ln1.g"], params[f"layer{i}.ln1.b"])
        x = x + _attention(
            cfg,
            ln1,
            params[f"layer{i}.attn.wq"],
            params[f"layer{i}.attn.wk"],
            params[f"layer{i}.attn.wv"],
            params[f"layer{i}.attn.wo"],
        )
        ln2 = _layer_norm(x, params[f"layer{i}.ln2.g"], params[f"layer{i}.ln2.b"])
        h = jax.nn.gelu(ln2 @ params[f"layer{i}.mlp.w1"])
        x = x + h @ params[f"layer{i}.mlp.w2"]
    x = _layer_norm(x, params["final_ln.g"], params["final_ln.b"])
    return x @ params["head.w"]


def forward_q(
    cfg: GptConfig,
    fp_params: Dict[str, jnp.ndarray],
    qweights: Dict[str, Dict[str, jnp.ndarray]],
    dir_codebook: jnp.ndarray,
    mag_levels: jnp.ndarray,
    tokens: jnp.ndarray,
):
    """Quantized forward: quantizable matrices arrive as PCDVQ codes and are
    dequantized in-graph; embeddings/norms stay fp (as in the paper).

    qweights[name] = {"dir_idx": (n,), "mag_idx": (n,), "scales": (cols,),
                      "signs": (rows,)} — all jnp arrays.
    """

    def deq(name: str) -> jnp.ndarray:
        rows, cols = weight_shape(cfg, name)
        q = qweights[name]
        return ref.dequant_weight(
            q["dir_idx"],
            q["mag_idx"],
            dir_codebook,
            mag_levels,
            q["scales"],
            q["signs"],
            rows,
            cols,
        )

    params = dict(fp_params)
    for name in quantizable_names(cfg):
        params[name] = deq(name)
    return forward_fp(cfg, params, tokens)


def loss_fn(cfg: GptConfig, params, tokens, targets):
    """Mean token cross-entropy."""
    logits = forward_fp(cfg, params, tokens)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def count_params(params: Dict[str, np.ndarray]) -> int:
    return int(sum(int(np.prod(v.shape)) for v in params.values()))
