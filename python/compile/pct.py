"""PCT1 named-tensor container — python side of the rust `io::pct` format.

serde is unavailable in the offline rust crate set, so artifacts crossing the
python↔rust boundary (trained weights, token streams, codebooks) use this
deliberately boring little-endian format. Layout (see rust/src/io/mod.rs):

    magic "PCT1" | u32 entry count
    per entry: u16 name len | name | u8 dtype | u8 ndim | u64 dims[] | raw data

dtype tags: 0 = f32, 1 = u32, 2 = u64, 3 = i32.
"""

from __future__ import annotations

import struct
from typing import Dict

import numpy as np

MAGIC = b"PCT1"

_DTYPES = {
    0: np.dtype("<f4"),
    1: np.dtype("<u4"),
    2: np.dtype("<u8"),
    3: np.dtype("<i4"),
}
_TAGS = {v: k for k, v in _DTYPES.items()}


def _tag_for(arr: np.ndarray) -> int:
    dt = np.dtype(arr.dtype).newbyteorder("<")
    if dt not in _TAGS:
        raise TypeError(f"unsupported dtype {arr.dtype}; use f32/u32/u64/i32")
    return _TAGS[dt]


def save(path: str, entries: Dict[str, np.ndarray]) -> None:
    """Write a dict of arrays as a PCT1 file (keys sorted, matching rust's
    BTreeMap order)."""
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(entries)))
        for name in sorted(entries):
            arr = np.ascontiguousarray(entries[name])
            tag = _tag_for(arr)
            nb = name.encode("utf-8")
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", tag, arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<Q", d))
            f.write(arr.astype(_DTYPES[tag]).tobytes())


def load(path: str) -> Dict[str, np.ndarray]:
    """Read a PCT1 file into a dict of numpy arrays."""
    with open(path, "rb") as f:
        buf = f.read()
    if buf[:4] != MAGIC:
        raise ValueError(f"{path}: not a PCT1 file")
    pos = 4
    (count,) = struct.unpack_from("<I", buf, pos)
    pos += 4
    out: Dict[str, np.ndarray] = {}
    for _ in range(count):
        (nlen,) = struct.unpack_from("<H", buf, pos)
        pos += 2
        name = buf[pos : pos + nlen].decode("utf-8")
        pos += nlen
        tag, ndim = struct.unpack_from("<BB", buf, pos)
        pos += 2
        dims = struct.unpack_from(f"<{ndim}Q", buf, pos)
        pos += 8 * ndim
        dt = _DTYPES[tag]
        n = int(np.prod(dims)) if ndim else 1
        arr = np.frombuffer(buf, dtype=dt, count=n, offset=pos).reshape(dims)
        pos += n * dt.itemsize
        out[name] = arr.copy()
    return out
