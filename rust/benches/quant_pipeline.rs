//! Bench: whole-quantizer throughput per method — the Table-1 cost column.
//!
//! Melem/s counts weights quantized per second (a 13B-analog layer is
//! 128x512). Includes the compressed-artifact round trip: quantize → codes,
//! explicit dequantize, and the fused `matmul_from_codes` serving kernel —
//! plus the `matmul_kernels/*` scenario pitting the scalar reference kernel
//! against the blocked and blocked+LUT variants on the serving shapes
//! (DESIGN.md §11; blocked+lut is what `matmul_from_codes` runs).
//! Measurements land in `BENCH_quant.json` for the perf trajectory (set
//! `PCDVQ_BENCH_OUT_DIR` to redirect).

use std::sync::Arc;

use pcdvq::bench::{black_box, Bench};
use pcdvq::codebook::{DirectionCodebook, DirectionMethod, MagnitudeCodebook, MagnitudeMethod};
use pcdvq::quant::pcdvq::{Pcdvq, PcdvqConfig};
use pcdvq::quant::quip::QuipLike;
use pcdvq::quant::sq::Rtn;
use pcdvq::quant::vq_kmeans::KMeansVq;
use pcdvq::quant::Quantizer;
use pcdvq::rng::Rng;
use pcdvq::tensor::Matrix;

fn main() {
    let mut bench = Bench::new();
    println!("== quantizer pipeline throughput (128x512 layer) ==");
    let mut rng = Rng::new(1);
    let w = Matrix::from_vec(rng.normal_vec(128 * 512), 128, 512);
    let elems = (128 * 512) as u64;

    let dir = Arc::new(DirectionCodebook::build(DirectionMethod::GreedyE8, 14, 8, 0));
    let mag = Arc::new(MagnitudeCodebook::build(MagnitudeMethod::LloydMax, 2, 8, 1.0 - 1e-4, 0));
    let pcdvq = Pcdvq::new(
        PcdvqConfig { dir_bits: 14, mag_bits: 2, k: 8, seed: 7 },
        dir,
        mag,
    );
    bench.run_elems("pcdvq a=14 quantize_full", elems, || {
        black_box(pcdvq.quantize_full(black_box(&w)));
    });
    let qw = pcdvq.quantize_full(&w);
    let mut scratch = Matrix::zeros(128, 512);
    bench.run_elems("pcdvq a=14 dequantize_into", elems, || {
        black_box(&qw).dequantize_into(black_box(&mut scratch));
    });
    let x = Matrix::from_vec(rng.normal_vec(8 * 128), 8, 128);
    bench.run_elems("pcdvq a=14 matmul_from_codes (8x128 batch)", elems, || {
        black_box(qw.matmul_from_codes(black_box(&x)));
    });

    // matmul_kernels scenario: scalar reference vs blocked vs blocked+LUT on
    // the serving shapes (b1 = single-token decode matvec, b8 = batch/chunk
    // matmul), for both the PCDVQ two-stream artifact and a scalar-grid
    // artifact. New keys ride BENCH_quant.json into the bench_gate
    // regression job (records-only until baselined; baselines/README.md).
    println!("\n== matmul_kernels: scalar vs blocked vs blocked+LUT ==");
    let x1 = Matrix::from_vec(rng.normal_vec(128), 1, 128);
    let block = qw.default_block_vecs();
    for (batch, xb) in [("b1", &x1), ("b8", &x)] {
        bench.run_elems(&format!("matmul_kernels/pcdvq14 scalar 128x512 {batch}"), elems, || {
            black_box(qw.matmul_from_codes_scalar(black_box(xb)));
        });
        bench.run_elems(&format!("matmul_kernels/pcdvq14 blocked 128x512 {batch}"), elems, || {
            black_box(qw.matmul_from_codes_blocked(black_box(xb), block, false));
        });
        bench.run_elems(
            &format!("matmul_kernels/pcdvq14 blocked+lut 128x512 {batch}"),
            elems,
            || {
                black_box(qw.matmul_from_codes_blocked(black_box(xb), block, true));
            },
        );
    }

    // thread-scaling keys: the parallel column-strip fan-out of the same
    // kernel at explicit worker counts (t1 = the single-thread kernel).
    // Output is bit-identical at every count; median_ns should fall as
    // threads rise on a multi-core runner (records-only until baselined —
    // see baselines/README.md for the capture sanity checks).
    println!("\n== matmul_kernels: thread scaling (blocked+LUT, b8) ==");
    for threads in [1usize, 2, 4] {
        bench.run_elems(
            &format!("matmul_kernels/pcdvq14 blocked+lut 128x512 b8 t{threads}"),
            elems,
            || {
                black_box(qw.matmul_from_codes_threaded(black_box(&x), block, true, threads));
            },
        );
    }

    let rtn = Rtn::with_clip_search(2);
    bench.run_elems("rtn2+clip quantize", elems, || {
        black_box(rtn.quantize(black_box(&w)));
    });
    let qw_rtn = rtn.quantize(&w);
    let rtn_block = qw_rtn.default_block_vecs();
    bench.run_elems("matmul_kernels/rtn2 scalar 128x512 b8", elems, || {
        black_box(qw_rtn.matmul_from_codes_scalar(black_box(&x)));
    });
    bench.run_elems("matmul_kernels/rtn2 blocked+lut 128x512 b8", elems, || {
        black_box(qw_rtn.matmul_from_codes_blocked(black_box(&x), rtn_block, true));
    });

    let quip = QuipLike::build(14, 1);
    bench.run_elems("quip-like 14b quantize (algebraic decode)", elems, || {
        black_box(quip.quantize(black_box(&w)));
    });

    let mut km = KMeansVq::new(8, 10);
    km.fit_on_weight(&w);
    bench.run_elems("kmeans-vq 10b quantize to codes", elems, || {
        black_box(km.quantize(black_box(&w)));
    });

    let dir = std::env::var("PCDVQ_BENCH_OUT_DIR").unwrap_or_else(|_| ".".into());
    let path = std::path::Path::new(&dir).join("BENCH_quant.json");
    bench.write_json(&path).expect("writing BENCH_quant.json");
    println!("\nwrote {}", path.display());
}
