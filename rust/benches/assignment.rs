//! Bench: direction assignment (the L3 quantization hot path).
//!
//! `cargo bench --bench assignment` — measures the blocked GEMM+argmax at
//! the paper's operating points; Gelem/s counts vector·codeword dot
//! products (n_vec × n_cb). Each configuration is measured twice — the
//! serial scan ("serial", the pre-parallelization baseline) and the
//! scoped-thread strip split ("parallel") — and the before/after Gdot/s
//! land in `BENCH_assign.json` (set `PCDVQ_BENCH_OUT_DIR` to redirect).

use pcdvq::bench::{black_box, Bench};
use pcdvq::quant::assign::{assign_batch, assign_euclidean, assign_into_with_threads, euclidean_bias};
use pcdvq::rng::Rng;
use pcdvq::tensor::Matrix;

fn unit_rows(n: usize, k: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    let mut m = Matrix::from_vec(rng.normal_vec(n * k), n, k);
    for i in 0..n {
        let r = m.row_mut(i);
        let nrm: f32 = r.iter().map(|x| x * x).sum::<f32>().sqrt();
        r.iter_mut().for_each(|x| *x /= nrm);
    }
    m
}

fn main() {
    let mut bench = Bench::new();
    // PALLAS_THREADS (exec::default_threads) rather than raw hw threads, so
    // CI's pinned thread count makes the "parallel" keys comparable across
    // runner generations with different core counts
    let threads = pcdvq::exec::default_threads();
    println!("== assignment (cosine argmax over the direction codebook) ==");
    println!("== serial vs parallel ({threads} pool threads) ==");

    for &(n_vec, cb_bits) in &[(16384usize, 10u32), (16384, 14), (4096, 15)] {
        let n_cb = 1usize << cb_bits;
        let vectors = unit_rows(n_vec, 8, 1);
        let cb = unit_rows(n_cb, 8, 2);
        let mut out = vec![0u32; n_vec];
        bench.run_elems(
            &format!("cosine k=8 {n_vec}vec x 2^{cb_bits}cb serial"),
            (n_vec * n_cb) as u64,
            || {
                assign_into_with_threads(
                    black_box(&vectors),
                    black_box(&cb),
                    &[],
                    &mut out,
                    1,
                );
            },
        );
        bench.run_elems(
            &format!("cosine k=8 {n_vec}vec x 2^{cb_bits}cb parallel"),
            (n_vec * n_cb) as u64,
            || {
                assign_into_with_threads(
                    black_box(&vectors),
                    black_box(&cb),
                    &[],
                    &mut out,
                    threads,
                );
            },
        );
    }

    // Euclidean variant (coupled-VQ baselines)
    let vectors = unit_rows(4096, 8, 3);
    let cb = unit_rows(4096, 8, 4);
    let bias = euclidean_bias(&cb);
    bench.run_elems("euclidean k=8 4096vec x 4096cb", 4096u64 * 4096, || {
        black_box(assign_batch(black_box(&vectors), black_box(&cb), &bias));
    });

    // non-specialized dims (generic path)
    for k in [4usize, 16] {
        let v = unit_rows(2048, k, 5);
        let c = unit_rows(2048, k, 6);
        bench.run_elems(&format!("cosine generic k={k} 2048x2048"), 2048 * 2048, || {
            black_box(assign_euclidean(black_box(&v), black_box(&c)));
        });
    }

    let dir = std::env::var("PCDVQ_BENCH_OUT_DIR").unwrap_or_else(|_| ".".into());
    let path = std::path::Path::new(&dir).join("BENCH_assign.json");
    bench.write_json(&path).expect("writing BENCH_assign.json");
    println!("\nwrote {}", path.display());
}
