//! Bench: direction assignment (the L3 quantization hot path).
//!
//! `cargo bench --bench assignment` — measures the blocked GEMM+argmax at
//! the paper's operating points; Gelem/s counts vector·codeword dot
//! products (n_vec × n_cb). §Perf target: ≥1 Gdot/s (8 flops each) on the
//! single-core testbed.

use pcdvq::bench::{black_box, Bench};
use pcdvq::quant::assign::{assign_batch, assign_euclidean, euclidean_bias};
use pcdvq::rng::Rng;
use pcdvq::tensor::Matrix;

fn unit_rows(n: usize, k: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    let mut m = Matrix::from_vec(rng.normal_vec(n * k), n, k);
    for i in 0..n {
        let r = m.row_mut(i);
        let nrm: f32 = r.iter().map(|x| x * x).sum::<f32>().sqrt();
        r.iter_mut().for_each(|x| *x /= nrm);
    }
    m
}

fn main() {
    let mut bench = Bench::new();
    println!("== assignment (cosine argmax over the direction codebook) ==");

    for &(n_vec, cb_bits) in &[(4096usize, 10u32), (4096, 14), (1024, 15)] {
        let n_cb = 1usize << cb_bits;
        let vectors = unit_rows(n_vec, 8, 1);
        let cb = unit_rows(n_cb, 8, 2);
        let mut out = vec![0u32; n_vec];
        bench.run_elems(
            &format!("cosine k=8 {n_vec}vec x 2^{cb_bits}cb"),
            (n_vec * n_cb) as u64,
            || {
                pcdvq::quant::assign::assign_into(
                    black_box(&vectors),
                    black_box(&cb),
                    &[],
                    &mut out,
                );
            },
        );
    }

    // Euclidean variant (coupled-VQ baselines)
    let vectors = unit_rows(4096, 8, 3);
    let cb = unit_rows(4096, 8, 4);
    let bias = euclidean_bias(&cb);
    bench.run_elems("euclidean k=8 4096vec x 4096cb", 4096u64 * 4096, || {
        black_box(assign_batch(black_box(&vectors), black_box(&cb), &bias));
    });

    // non-specialized dims (generic path)
    for k in [4usize, 16] {
        let v = unit_rows(2048, k, 5);
        let c = unit_rows(2048, k, 6);
        bench.run_elems(&format!("cosine generic k={k} 2048x2048"), 2048 * 2048, || {
            black_box(assign_euclidean(black_box(&v), black_box(&c)));
        });
    }
}
