//! Bench: DACC codebook construction — the offline stage (paper: "performed
//! only once for all circumstances"), plus the E8 substrate.

use pcdvq::bench::{black_box, Bench};
use pcdvq::codebook::{DirectionCodebook, DirectionMethod, MagnitudeCodebook, MagnitudeMethod};
use pcdvq::lattice::e8::E8Points;
use pcdvq::quant::quip::nearest_e8;
use pcdvq::rng::Rng;

fn main() {
    let mut bench = Bench::new();
    println!("== codebook construction (offline stage) ==");

    bench.run("e8 enumerate shells<=6 (9120 pts)", || {
        black_box(E8Points::enumerate(6));
    });

    for bits in [8u32, 10, 12] {
        bench.run(&format!("greedy-e8 direction 2^{bits}"), || {
            black_box(DirectionCodebook::build(DirectionMethod::GreedyE8, bits, 8, 0));
        });
    }

    bench.run("lloyd-max magnitude 2^2 (chi-8 analytic)", || {
        black_box(MagnitudeCodebook::build(
            MagnitudeMethod::LloydMax,
            2,
            8,
            1.0 - 1e-4,
            0,
        ));
    });

    // the algebraic E8 decoder (QuIP#-like hot inner loop)
    let mut rng = Rng::new(3);
    let probes: Vec<[f32; 8]> = (0..4096)
        .map(|_| {
            let mut v = [0.0f32; 8];
            for x in v.iter_mut() {
                *x = rng.normal() as f32 * 2.0;
            }
            v
        })
        .collect();
    bench.run_elems("nearest_e8 algebraic decode x4096", 4096, || {
        for p in &probes {
            black_box(nearest_e8(black_box(p)));
        }
    });
}
