//! Bench: end-to-end serving throughput (the §4.4 table) — fp32 weights vs
//! PCDVQ in-graph dequant, decode steps/s and tokens/s through the real
//! batched server. Skips cleanly if `make artifacts` has not run.

use std::sync::mpsc::channel;
use std::time::Instant;

use pcdvq::bench::Bench;
use pcdvq::codebook::{DirectionMethod, MagnitudeMethod};
use pcdvq::config::{build_pcdvq_with, Paths};
use pcdvq::coordinator::{Batcher, BatcherConfig, GenRequest, Server, ServingWeights};
use pcdvq::model::QuantizedGpt;
use pcdvq::runtime::Engine;

fn drive(server: &mut Server, prompts: &[Vec<u8>], max_new: usize) -> f64 {
    let (tx, rx) = channel::<GenRequest>();
    let batcher = Batcher::new(rx, BatcherConfig::default());
    let mut keep = Vec::new();
    for p in prompts {
        let (rtx, rrx) = channel();
        tx.send(GenRequest {
            prompt: p.clone(),
            max_new,
            temperature: 0.0,
            resp: rtx,
            enqueued: Instant::now(),
        })
        .unwrap();
        keep.push(rrx);
    }
    drop(tx);
    let t = Instant::now();
    server.serve(&batcher).unwrap();
    let tokens = prompts.len() * max_new;
    tokens as f64 / t.elapsed().as_secs_f64()
}

fn main() {
    let paths = Paths::detect();
    let Ok(model) = paths.load_model("gpt-m") else {
        println!("serving bench skipped: no gpt-m.pct (run `make artifacts` first)");
        return;
    };

    // --- host codes-resident serving (no XLA artifacts needed) ---
    {
        println!("== host codes-resident serving (gpt-m, batch 8, greedy decode) ==");
        let pcdvq = build_pcdvq_with(
            &paths,
            DirectionMethod::GreedyE8,
            MagnitudeMethod::LloydMax,
            14,
            2,
            7,
        )
        .unwrap();
        let q = QuantizedGpt::quantize(&model, &pcdvq);
        let resident_kib = q.resident_bits() as f64 / 8.0 / 1024.0;
        let mut host = Server::new_host(ServingWeights::CodesResident(Box::new(q))).unwrap();
        let eval = paths.eval_tokens().unwrap();
        let prompts: Vec<Vec<u8>> = (0..8)
            .map(|i| {
                let s = (i * 4099) % (eval.len() - 64);
                eval[s..s + 48].iter().map(|&t| t as u8).collect()
            })
            .collect();
        let host_tps = drive(&mut host, &prompts, 8);
        println!(
            "codes-resident host:    {host_tps:>8.1} tok/s   ({resident_kib:.1} KiB resident)"
        );
    }

    if !paths.artifacts.join("fwd_q_gpt-m.hlo.txt").exists() {
        println!("XLA serving bench skipped: run `make artifacts` first");
        return;
    }
    let _bench = Bench::new(); // uniform output style
    println!("== serving throughput (gpt-m, batch 8, greedy decode) ==");
    let engine = Engine::new().unwrap();
    let eval = paths.eval_tokens().unwrap();
    let prompts: Vec<Vec<u8>> = (0..16)
        .map(|i| {
            let s = (i * 4099) % (eval.len() - 64);
            eval[s..s + 48].iter().map(|&t| t as u8).collect()
        })
        .collect();

    let mut fp = Server::new(&engine, &paths.artifacts, ServingWeights::Fp(model.clone())).unwrap();
    // warm + measure twice, report the better (compile amortized)
    let _ = drive(&mut fp, &prompts, 8);
    let fp_tps = drive(&mut fp, &prompts, 24);
    println!("fp32 weights:           {fp_tps:>8.1} tok/s");

    let pcdvq = build_pcdvq_with(&paths, DirectionMethod::GreedyE8, MagnitudeMethod::LloydMax, 14, 2, 7).unwrap();
    let q = QuantizedGpt::quantize(&model, &pcdvq);
    let ratio = q.dense_bits() as f64 / q.payload_bits() as f64;
    let mut qs = Server::new(
        &engine,
        &paths.artifacts,
        ServingWeights::Quantized(Box::new(q), (*pcdvq.dir).clone(), (*pcdvq.mag).clone()),
    )
    .unwrap();
    let _ = drive(&mut qs, &prompts, 8);
    let q_tps = drive(&mut qs, &prompts, 24);
    println!("pcdvq in-graph dequant: {q_tps:>8.1} tok/s   (weights {ratio:.1}x smaller resident)");
    println!("note: CPU testbed is compute-bound; see EXPERIMENTS.md §4.4 for discussion");
}
