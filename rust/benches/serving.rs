//! Bench: end-to-end serving throughput — KV-cached incremental decode vs
//! windowed re-forward on the host codes-resident backend, continuous vs
//! static batching, paged-KV prefix sharing (hot vs cold TTFT, paged vs
//! dense), the layer-sharded pipeline vs a single node, plus the §4.4 XLA
//! comparison when `make artifacts` has run.
//!
//! Needs **no** artifacts: without `gpt-m.pct` it builds a synthetic tinygpt
//! (the same shape the coordinator integration tests use), so CI gets real
//! numbers. Writes `BENCH_serving.json` for the perf trajectory — the
//! `bench-regression` CI job gates on it against `baselines/`.
//!
//! Bench hygiene: every scenario runs one explicitly **discarded warm-up
//! iteration** before measurement, so first-touch allocation (slot caches,
//! decode LUTs, pipeline channels) lands outside the timed region and
//! thread-scaling comparisons aren't skewed by whichever scenario ran
//! first.

use std::sync::mpsc::channel;
use std::time::Instant;

use pcdvq::bench::{black_box, Bench};
use pcdvq::codebook::{DirectionMethod, MagnitudeMethod};
use pcdvq::config::Paths;
use pcdvq::coordinator::ingress::{parse_sse, post_generate, sse_tokens};
use pcdvq::coordinator::{
    Batcher, BatcherConfig, DecodePolicy, GenRequest, Ingress, IngressConfig, Server,
    ServingWeights,
};
use pcdvq::model::{GptModel, KvCache, QuantizedGpt};
use pcdvq::proptest::{synthetic_tinygpt, tiny_pcdvq};
use pcdvq::rng::Rng;
use pcdvq::runtime::Engine;

/// Synthetic tinygpt (d=64, 2 layers, ctx=64) — the shared library fixture,
/// so the bench runs on a bare CI runner without `make artifacts`.
fn synthetic_model() -> GptModel {
    synthetic_tinygpt("pcdvq_bench_serving", "bench-nano", 41)
}

/// Push `prompts` through the server once (greedy) and wait for completion.
fn drive(server: &mut Server, prompts: &[Vec<u8>], max_new: usize) {
    let reqs: Vec<(Vec<u8>, usize)> =
        prompts.iter().map(|p| (p.clone(), max_new)).collect();
    drive_mixed(server, &reqs, BatcherConfig::default(), false);
}

/// Push a mixed-length workload through the server once (greedy) and wait.
/// `continuous` selects the slot-pool loop; otherwise static batches of
/// `cfg.max_batch`.
fn drive_mixed(
    server: &mut Server,
    reqs: &[(Vec<u8>, usize)],
    cfg: BatcherConfig,
    continuous: bool,
) {
    let (tx, rx) = channel::<GenRequest>();
    let mut batcher = Batcher::new(rx, cfg);
    let mut keep = Vec::new();
    for (p, max_new) in reqs {
        let (rtx, rrx) = channel();
        tx.send(GenRequest::builder(p.clone()).max_new(*max_new).build(rtx)).unwrap();
        keep.push(rrx);
    }
    drop(tx);
    if continuous {
        server.serve_continuous(&mut batcher).unwrap();
    } else {
        server.serve(&mut batcher).unwrap();
    }
    for rrx in keep {
        let _ = black_box(rrx.recv().unwrap().generated.len());
    }
}

fn main() {
    let paths = Paths::detect();
    let (model, model_label) = match paths.load_model("gpt-m") {
        Ok(m) => (m, "gpt-m"),
        Err(_) => (synthetic_model(), "synthetic-nano"),
    };
    let ctx = model.config.ctx;

    // deterministic synthetic prompts (the eval corpus is absent on CI)
    let mut prng = Rng::new(99);
    let prompts: Vec<Vec<u8>> = (0..4)
        .map(|_| (0..24).map(|_| prng.below(256) as u8).collect())
        .collect();
    let max_new = 8usize;
    let toks_per_drive = (prompts.len() * max_new) as u64;

    let mut bench = Bench::new();
    println!("== host codes-resident decode ({model_label}, ctx {ctx}, greedy) ==");
    let pcdvq_q = tiny_pcdvq();
    let q = QuantizedGpt::quantize(&model, &pcdvq_q);
    let resident_kib = q.resident_bits() as f64 / 8.0 / 1024.0;
    let kv_kib = model.config.kv_cache_bits() as f64 / 8.0 / 1024.0;
    println!("resident weights {resident_kib:.1} KiB, KV cache {kv_kib:.1} KiB/slot");

    let mut server =
        Server::builder(ServingWeights::CodesResident(Box::new(q.clone()))).build().unwrap();

    server.decode = DecodePolicy::KvCached;
    drive(&mut server, &prompts, max_new); // discarded warm-up iteration
    let cached = bench
        .run_elems("serve_host_kv_cached_tok", toks_per_drive, || {
            drive(&mut server, &prompts, max_new)
        })
        .clone();

    server.decode = DecodePolicy::Reforward;
    drive(&mut server, &prompts, max_new); // discarded warm-up iteration
    let reforward = bench
        .run_elems("serve_host_reforward_tok", toks_per_drive, || {
            drive(&mut server, &prompts, max_new)
        })
        .clone();

    // steady-state single-step latency: decode into a nearly full cache,
    // sliding (and rebuilding) as it overflows — the amortized serving cost
    let hf = pcdvq::model::HostForward::from_quantized(q.clone()).unwrap();
    let mut cache = KvCache::new(&model.config);
    hf.prefill(&vec![7i32; ctx - 1], &mut cache).unwrap();
    let _ = black_box(hf.decode_step(11, &mut cache).unwrap()); // warm-up
    let step = bench
        .run("decode_step_steady_state", || {
            let _ = black_box(hf.decode_step(11, &mut cache).unwrap());
        })
        .clone();

    let tok_s = |ns: f64, toks: f64| toks / (ns * 1e-9);
    let cached_tps = tok_s(cached.median_ns, toks_per_drive as f64);
    let reforward_tps = tok_s(reforward.median_ns, toks_per_drive as f64);
    println!("kv-cached decode:   {cached_tps:>10.1} tok/s");
    println!(
        "windowed re-forward:{reforward_tps:>10.1} tok/s   ({:.1}x slower)",
        cached_tps / reforward_tps.max(1e-9)
    );
    println!(
        "steady-state decode_step: {:.1} µs/token ({} evictions amortized in)",
        step.median_ns / 1e3,
        cache.evictions()
    );

    // --- continuous batching + block prefill vs static batches ---
    // Mixed-length traffic through 2 slots: static batching holds a
    // finished request's slot until its batchmate completes and prefills
    // token-at-a-time; the continuous loop admits the next request into the
    // freed slot immediately and absorbs prompts in chunks (amortizing the
    // per-token code-decode of `matmul_from_codes` across each block).
    println!("== continuous vs static batching (2 slots, mixed lengths) ==");
    let mixed: Vec<(Vec<u8>, usize)> = (0..8)
        .map(|i| {
            let plen = if i % 2 == 0 { 48 } else { 24 };
            let p: Vec<u8> = (0..plen).map(|_| prng.below(256) as u8).collect();
            (p, if i % 2 == 0 { 2 } else { 10 })
        })
        .collect();
    let mixed_toks: u64 = mixed.iter().map(|(_, m)| *m as u64).sum();
    let mk_host = |q: &QuantizedGpt| {
        Server::builder(ServingWeights::CodesResident(Box::new(q.clone())))
            .max_slots(2)
            .prefill_chunk(16)
            .build()
            .unwrap()
    };
    let mut cont_server = mk_host(&q);
    drive_mixed(&mut cont_server, &mixed, BatcherConfig::default(), true); // warm-up
    let continuous = bench
        .run_elems("continuous_vs_static/continuous_tok", mixed_toks, || {
            drive_mixed(&mut cont_server, &mixed, BatcherConfig::default(), true)
        })
        .clone();
    let mut stat_server = mk_host(&q);
    let static_cfg = BatcherConfig {
        max_batch: 2,
        max_wait: std::time::Duration::from_millis(1),
        ..Default::default()
    };
    drive_mixed(&mut stat_server, &mixed, static_cfg, false); // warm-up
    let static_m = bench
        .run_elems("continuous_vs_static/static_tok", mixed_toks, || {
            drive_mixed(&mut stat_server, &mixed, static_cfg, false)
        })
        .clone();
    let cont_tps = tok_s(continuous.median_ns, mixed_toks as f64);
    let stat_tps = tok_s(static_m.median_ns, mixed_toks as f64);
    println!(
        "continuous batching:{cont_tps:>10.1} tok/s   (occupancy {:.0}%, ttft p50 {:.2} ms)",
        cont_server.metrics.slot_occupancy() * 100.0,
        cont_server.metrics.ttft_ms(50.0)
    );
    println!(
        "static batches:     {stat_tps:>10.1} tok/s   ({:.2}x continuous/static)",
        cont_tps / stat_tps.max(1e-9)
    );

    // --- paged KV pool + cross-request prefix sharing ---
    // 8 requests over a common 3/4-length prompt prefix (36 of 48 bytes):
    // the dense layout re-prefills the prefix for every request, the paged
    // pool with sharing attaches the resident prefix pages at admission and
    // prefills only the cold suffix — hot-prefix TTFT is the headline win.
    println!("== paged prefix sharing (8 reqs, 36/48-byte shared prefix, 2 slots) ==");
    let shared_prefix: Vec<u8> = (0..36).map(|_| prng.below(256) as u8).collect();
    let shared_reqs: Vec<(Vec<u8>, usize)> = (0..8)
        .map(|_| {
            let mut p = shared_prefix.clone();
            p.extend((0..12).map(|_| prng.below(256) as u8));
            (p, 6usize)
        })
        .collect();
    let shared_toks: u64 = shared_reqs.iter().map(|(_, m)| *m as u64).sum();
    let mk_paged = |q: &QuantizedGpt, kv_page: Option<usize>, share: bool| {
        Server::builder(ServingWeights::CodesResident(Box::new(q.clone())))
            .max_slots(2)
            .prefill_chunk(16)
            .kv_page(kv_page.unwrap_or(0)) // 0 selects the dense layout
            .prefix_share(share)
            .build()
            .unwrap()
    };
    let mut dense_server = mk_paged(&q, None, false);
    drive_mixed(&mut dense_server, &shared_reqs, BatcherConfig::default(), true); // warm-up
    let dense_m = bench
        .run_elems("paged_prefix_sharing/dense_tok", shared_toks, || {
            drive_mixed(&mut dense_server, &shared_reqs, BatcherConfig::default(), true)
        })
        .clone();
    let mut noshare_server = mk_paged(&q, Some(8), false);
    drive_mixed(&mut noshare_server, &shared_reqs, BatcherConfig::default(), true); // warm-up
    let noshare_m = bench
        .run_elems("paged_prefix_sharing/paged_noshare_tok", shared_toks, || {
            drive_mixed(&mut noshare_server, &shared_reqs, BatcherConfig::default(), true)
        })
        .clone();
    let mut shared_server = mk_paged(&q, Some(8), true);
    drive_mixed(&mut shared_server, &shared_reqs, BatcherConfig::default(), true); // warm-up
    let shared_m = bench
        .run_elems("paged_prefix_sharing/paged_shared_tok", shared_toks, || {
            drive_mixed(&mut shared_server, &shared_reqs, BatcherConfig::default(), true)
        })
        .clone();

    // hot vs cold TTFT from one fresh drive: the first admissions prefill
    // the whole prompt (cold), later requests ride the published prefix
    let mut ttft_server = mk_paged(&q, Some(8), true);
    drive_mixed(&mut ttft_server, &shared_reqs, BatcherConfig::default(), true);
    bench.record_ns(
        "paged_prefix_sharing/ttft_cold_p50",
        ttft_server.metrics.ttft_cold_ms(50.0) * 1e6,
    );
    bench.record_ns(
        "paged_prefix_sharing/ttft_hot_p50",
        ttft_server.metrics.ttft_hot_ms(50.0) * 1e6,
    );

    let dense_tps = tok_s(dense_m.median_ns, shared_toks as f64);
    let noshare_tps = tok_s(noshare_m.median_ns, shared_toks as f64);
    let shared_tps = tok_s(shared_m.median_ns, shared_toks as f64);
    println!("dense per-slot:      {dense_tps:>10.1} tok/s");
    println!("paged, no sharing:   {noshare_tps:>10.1} tok/s");
    println!(
        "paged + prefix share:{shared_tps:>10.1} tok/s   ({:.2}x vs dense; \
         hits {}/{}, reuse {} toks)",
        shared_tps / dense_tps.max(1e-9),
        ttft_server.metrics.prefix_hits,
        ttft_server.metrics.prefix_hits + ttft_server.metrics.prefix_misses,
        ttft_server.metrics.prefix_tokens_reused,
    );
    // effective slot density: the paged pool only materializes pages the
    // traffic touched, so short-prompt slots cost far less than a dense
    // full-ctx buffer
    let gib_bits = 8.0 * 1024.0 * 1024.0 * 1024.0;
    let dense_slot_bits = (dense_server.config.kv_cache_bits() as f64).max(1.0);
    let paged_slot_bits =
        (shared_server.kv_cache_bits() as f64 / shared_server.max_slots as f64).max(1.0);
    println!(
        "KV footprint: dense {:.1} KiB/slot ({:.0} slots/GiB) vs paged \
         {:.1} KiB/slot ({:.0} slots/GiB)",
        dense_slot_bits / 8.0 / 1024.0,
        gib_bits / dense_slot_bits,
        paged_slot_bits / 8.0 / 1024.0,
        gib_bits / paged_slot_bits,
    );

    // --- layer-sharded pipeline vs single node ---
    // Independent block-forward jobs stream through a 2-node shard chain
    // (node 1 runs job j while node 2 finishes j-1) vs the same jobs
    // sequentially on one HostForward. Outputs are bit-identical; the
    // pipeline's win is wall-clock overlap across cores.
    println!("== sharded vs single-node block forwards (2 nodes, pipelined) ==");
    let sharded = pcdvq::coordinator::ShardedForward::new(&q, 2).unwrap();
    for (i, nb) in sharded.node_bits().iter().enumerate() {
        println!(
            "node {i} (layers {:?}): payload {:.1} KiB + codebooks {:.1} KiB",
            nb.layers,
            nb.payload_bits as f64 / 8.0 / 1024.0,
            nb.codebook_bits as f64 / 8.0 / 1024.0
        );
    }
    let job_t = (ctx / 2).max(1);
    let jobs: Vec<(Vec<i32>, usize, usize)> = (0..6)
        .map(|j| {
            let toks: Vec<i32> =
                (0..job_t).map(|i| ((i * 7 + j * 31 + 1) % 251) as i32).collect();
            (toks, 1usize, job_t)
        })
        .collect();
    let job_toks = (jobs.len() * job_t) as u64;
    black_box(sharded.forward_pipelined(&jobs).unwrap()); // warm-up
    let piped = bench
        .run_elems("sharded_vs_single/sharded_pipelined_2n_tok", job_toks, || {
            black_box(sharded.forward_pipelined(&jobs).unwrap());
        })
        .clone();
    for (toks, b, t) in &jobs {
        black_box(hf.forward(toks, *b, *t).unwrap()); // warm-up
    }
    let single = bench
        .run_elems("sharded_vs_single/single_node_tok", job_toks, || {
            for (toks, b, t) in &jobs {
                black_box(hf.forward(toks, *b, *t).unwrap());
            }
        })
        .clone();
    let piped_tps = tok_s(piped.median_ns, job_toks as f64);
    let single_tps = tok_s(single.median_ns, job_toks as f64);
    println!(
        "sharded pipeline:   {piped_tps:>10.1} tok/s\nsingle node:        \
         {single_tps:>10.1} tok/s   ({:.2}x sharded/single)",
        piped_tps / single_tps.max(1e-9)
    );

    // --- kv-cached sharded continuous decode (DESIGN.md §16) ---
    // serve_continuous through node-owned slot caches at requested shard
    // counts 1/2/4 (the 2-layer fixture caps the chain at 2 nodes, so the
    // 4-shard run measures the degraded plan), plus the sharded re-forward
    // oracle on the same traffic. Sanity ordering (asserted against
    // baselines/): kv-cached ≥ re-forward at every shard count.
    println!("== sharded kv-cached continuous decode (requested shards 1/2/4) ==");
    let shard_reqs: Vec<(Vec<u8>, usize)> = (0..6)
        .map(|j| ((0..24).map(|i| ((i * 7 + j * 31 + 1) % 251) as u8).collect(), 8usize))
        .collect();
    let shard_toks: u64 = shard_reqs.iter().map(|(_, m)| *m as u64).sum();
    for n in [1usize, 2, 4] {
        let mut srv = Server::builder(ServingWeights::CodesResident(Box::new(q.clone())))
            .shards(n)
            .max_slots(2)
            .prefill_chunk(16)
            .build()
            .unwrap();
        drive_mixed(&mut srv, &shard_reqs, BatcherConfig::default(), true); // warm-up
        let m = bench
            .run_elems(&format!("sharded_vs_single/kv_cached_s{n}_tok"), shard_toks, || {
                drive_mixed(&mut srv, &shard_reqs, BatcherConfig::default(), true)
            })
            .clone();
        let cached_tps = tok_s(m.median_ns, shard_toks as f64);

        let mut re_srv = Server::builder(ServingWeights::CodesResident(Box::new(q.clone())))
            .shards(n)
            .decode(DecodePolicy::Reforward)
            .build()
            .unwrap();
        drive_mixed(&mut re_srv, &shard_reqs, BatcherConfig::default(), false); // warm-up
        let re = bench
            .run_elems(&format!("sharded_vs_single/reforward_s{n}_tok"), shard_toks, || {
                drive_mixed(&mut re_srv, &shard_reqs, BatcherConfig::default(), false)
            })
            .clone();
        let re_tps = tok_s(re.median_ns, shard_toks as f64);

        // per-node resident bits: node's share of KV pages + its cache
        // grids, on top of the codebook-once-per-node weight bits above
        // (recorded as raw bit counts, not durations)
        match (srv.kv_cache_bits_per_node(), srv.kv_codebook_bits_per_node()) {
            (Some(cache), Some(grids)) => {
                for (i, (cb, gb)) in cache.iter().zip(&grids).enumerate() {
                    bench.record_ns(
                        &format!("sharded_vs_single/kv_cached_s{n}_node{i}_resident_bits"),
                        (cb + gb) as f64,
                    );
                }
            }
            _ => {
                bench.record_ns(
                    &format!("sharded_vs_single/kv_cached_s{n}_node0_resident_bits"),
                    (srv.kv_cache_bits() + srv.kv_codebook_bits()) as f64,
                );
            }
        }
        println!(
            "kv-cached, shards {n}: {cached_tps:>10.1} tok/s   (re-forward \
             {re_tps:>10.1} tok/s, {:.2}x cached/reforward)",
            cached_tps / re_tps.max(1e-9)
        );
    }

    // --- ingress_load: closed-loop HTTP traffic through the front end ---
    // Client threads drive POST /v1/generate over a real socket with mixed
    // prompt/output lengths and bursty arrivals (a think-time gap every 4th
    // request). Two runs: 1x offered load (clients == slots, generous gate)
    // and 2x overload (double the clients, tight gate) — under overload the
    // admission gate must shed the excess with 429 *early* so goodput for
    // the admitted population stays close to the 1x run.
    println!("== ingress_load: closed-loop HTTP traffic (2 slots, 1x vs 2x) ==");
    let reqs_per_client = 8usize;
    let mut run_load = |label: &str, clients: usize, icfg: IngressConfig| -> (f64, u64, u64) {
        let server = Server::builder(ServingWeights::CodesResident(Box::new(q.clone())))
            .max_slots(2)
            .prefill_chunk(16)
            .build()
            .unwrap();
        let ingress =
            Ingress::spawn(server, BatcherConfig::default(), icfg, "127.0.0.1:0").unwrap();
        let addr = ingress.addr();
        let t0 = Instant::now();
        let workers: Vec<_> = (0..clients)
            .map(|c| {
                std::thread::spawn(move || {
                    let mut rng = Rng::new(1000 + c as u64);
                    let mut lat_ms = Vec::new();
                    let (mut toks, mut shed, mut errors) = (0u64, 0u64, 0u64);
                    for i in 0..reqs_per_client {
                        let plen = [12usize, 24, 48][rng.below(3)];
                        let max_new = [2usize, 6, 10][rng.below(3)];
                        let prompt: String =
                            (0..plen).map(|_| (b'a' + rng.below(26) as u8) as char).collect();
                        let t = Instant::now();
                        // generous deadline: with the gate shedding early,
                        // no admitted request should ever hit it
                        match post_generate(addr, &prompt, max_new, 0.0, "", 10_000) {
                            Ok(r) if r.status == 200 => {
                                lat_ms.push(t.elapsed().as_secs_f64() * 1e3);
                                toks += sse_tokens(&parse_sse(&r.body)).len() as u64;
                            }
                            Ok(r) if r.status == 429 => shed += 1,
                            _ => errors += 1,
                        }
                        if i % 4 == 3 {
                            std::thread::sleep(std::time::Duration::from_millis(2));
                        }
                    }
                    (lat_ms, toks, shed, errors)
                })
            })
            .collect();
        let mut lat_ms = Vec::new();
        let (mut toks, mut shed, mut errors) = (0u64, 0u64, 0u64);
        for w in workers {
            let (l, t, s, e) = w.join().unwrap();
            lat_ms.extend(l);
            toks += t;
            shed += s;
            errors += e;
        }
        let wall_s = t0.elapsed().as_secs_f64();
        let server = ingress.shutdown().unwrap();
        lat_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |p: f64| -> f64 {
            if lat_ms.is_empty() {
                return 0.0;
            }
            lat_ms[((p / 100.0) * (lat_ms.len() - 1) as f64).round() as usize]
        };
        let goodput = toks as f64 / wall_s;
        bench.record_ns(&format!("ingress_load/p50_ms_{label}"), pct(50.0) * 1e6);
        bench.record_ns(&format!("ingress_load/p99_ms_{label}"), pct(99.0) * 1e6);
        // ns per goodput token: lower is better, comparable across runs
        bench.record_ns(
            &format!("ingress_load/goodput_tok_{label}"),
            wall_s * 1e9 / (toks as f64).max(1.0),
        );
        let offered = (clients * reqs_per_client) as f64;
        println!(
            "{label}: {clients} clients  p50 {:.1} ms  p99 {:.1} ms  goodput {goodput:.1} tok/s  \
             shed {shed}/{} ({:.0}%)  errors {errors}  timeouts {}",
            pct(50.0),
            pct(99.0),
            offered,
            100.0 * shed as f64 / offered,
            server.metrics.timeouts,
        );
        (goodput, shed, server.metrics.timeouts)
    };
    let (good_1x, _, _) = run_load("1x", 2, IngressConfig::default());
    let overload_gate = IngressConfig { max_in_flight: 3, ..IngressConfig::default() };
    let (good_2x, shed_2x, timeouts_2x) = run_load("2x", 4, overload_gate);
    bench.record_ns(
        "ingress_load/shed_rate_2x_pct",
        100.0 * shed_2x as f64 / (4 * reqs_per_client) as f64,
    );
    println!(
        "overload goodput {:.0}% of 1x (shed {shed_2x} early, {timeouts_2x} late timeouts)",
        100.0 * good_2x / good_1x.max(1e-9)
    );

    // --- kv_quant: exact vs quantized KV cache (DESIGN.md §15) ---
    // Same shared-prefix traffic as the paging scenario, three cache
    // codecs: exact f32 rows, 8-bit and 4-bit polar-decoupled codes. The
    // quantized cache trades a per-row encode (direction scan + magnitude
    // search + LUT decode into the tile) for resident bits — the headline
    // is slot density: sequences resident per fixed pool budget.
    println!("== kv_quant: exact vs 8/4-bit polar-decoupled cache (2 slots) ==");
    let kvq_budget_bits = 64.0 * 1024.0 * 8.0; // fixed 64-KiB pool budget
    let full_seq_values = (2 * model.config.n_layer * ctx * model.config.d_model) as f64;
    for bits in [0u32, 8, 4] {
        let mut srv = Server::builder(ServingWeights::CodesResident(Box::new(q.clone())))
            .max_slots(2)
            .prefill_chunk(16)
            .kv_quant(bits)
            .build()
            .unwrap();
        drive_mixed(&mut srv, &shared_reqs, BatcherConfig::default(), true); // warm-up
        let label = if bits == 0 { "exact".to_string() } else { format!("{bits}bit") };
        let m = bench
            .run_elems(&format!("kv_quant/{label}_tok"), shared_toks, || {
                drive_mixed(&mut srv, &shared_reqs, BatcherConfig::default(), true)
            })
            .clone();
        let resident_bits = srv.kv_cache_bits() + srv.kv_codebook_bits();
        bench.record_ns(&format!("kv_quant/{label}_resident_kv_bits"), resident_bits as f64);
        let per_seq_bits = srv.kv_cache_bpw() * full_seq_values;
        let seqs_per_budget = (kvq_budget_bits / per_seq_bits).floor();
        bench.record_ns(&format!("kv_quant/{label}_seqs_per_64kib"), seqs_per_budget);
        println!(
            "{label:>6}: {:>10.1} tok/s  cache {:>4.1} bpw  resident {:>7.1} KiB \
             (+ codebooks {:.2} KiB)  {seqs_per_budget:.0} seqs/64KiB",
            tok_s(m.median_ns, shared_toks as f64),
            srv.kv_cache_bpw(),
            srv.kv_cache_bits() as f64 / 8.0 / 1024.0,
            srv.kv_codebook_bits() as f64 / 8.0 / 1024.0,
        );
    }

    bench.write_json("BENCH_serving.json").unwrap();
    println!("wrote BENCH_serving.json");

    // --- §4.4 XLA comparison (needs `make artifacts`) ---
    if model_label != "gpt-m" || !paths.artifacts.join("fwd_q_gpt-m.hlo.txt").exists() {
        println!("XLA serving bench skipped: run `make artifacts` first");
        return;
    }
    println!("== XLA serving throughput (gpt-m, batch 8, greedy decode) ==");
    let engine = Engine::new().unwrap();
    let eval = paths.eval_tokens().unwrap();
    let xla_prompts: Vec<Vec<u8>> = (0..16)
        .map(|i| {
            let s = (i * 4099) % (eval.len() - 64);
            eval[s..s + 48].iter().map(|&t| t as u8).collect()
        })
        .collect();

    let mut fp = Server::new(&engine, &paths.artifacts, ServingWeights::Fp(model.clone())).unwrap();
    // warm + measure (compile amortized)
    drive(&mut fp, &xla_prompts, 8);
    let t = Instant::now();
    drive(&mut fp, &xla_prompts, 24);
    let fp_tps = (xla_prompts.len() * 24) as f64 / t.elapsed().as_secs_f64();
    println!("fp32 weights:           {fp_tps:>8.1} tok/s");

    let q14 = pcdvq::config::build_pcdvq_with(
        &paths,
        DirectionMethod::GreedyE8,
        MagnitudeMethod::LloydMax,
        14,
        2,
        7,
    )
    .unwrap();
    let qq = QuantizedGpt::quantize(&model, &q14);
    let ratio = qq.dense_bits() as f64 / qq.payload_bits() as f64;
    let mut qs = Server::new(
        &engine,
        &paths.artifacts,
        ServingWeights::Quantized(Box::new(qq), (*q14.dir).clone(), (*q14.mag).clone()),
    )
    .unwrap();
    drive(&mut qs, &xla_prompts, 8);
    let t = Instant::now();
    drive(&mut qs, &xla_prompts, 24);
    let q_tps = (xla_prompts.len() * 24) as f64 / t.elapsed().as_secs_f64();
    println!("pcdvq in-graph dequant: {q_tps:>8.1} tok/s   (weights {ratio:.1}x smaller resident)");
    println!("note: CPU testbed is compute-bound; see EXPERIMENTS.md §4.4 for discussion");
}
