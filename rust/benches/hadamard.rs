//! Bench: FWHT / randomized Hadamard transform (regularization stage).
//!
//! Gelem/s counts matrix elements transformed per second.

use pcdvq::bench::{black_box, Bench};
use pcdvq::hadamard::{fwht_normalized, regularize, RandomizedHadamard};
use pcdvq::rng::Rng;
use pcdvq::tensor::Matrix;

fn main() {
    let mut bench = Bench::new();
    println!("== hadamard (FWHT + RHT regularization) ==");

    for n in [128usize, 512, 2048, 8192] {
        let mut rng = Rng::new(1);
        let mut x = rng.normal_vec(n);
        bench.run_elems(&format!("fwht_normalized n={n}"), n as u64, || {
            fwht_normalized(black_box(&mut x));
        });
    }

    for (rows, cols) in [(128usize, 512usize), (512, 512), (1024, 256)] {
        let mut rng = Rng::new(2);
        let w = Matrix::from_vec(rng.normal_vec(rows * cols), rows, cols);
        let rht = RandomizedHadamard::new(rows, 7);
        bench.run_elems(
            &format!("regularize {rows}x{cols} (fwd+scales)"),
            (rows * cols) as u64,
            || {
                black_box(regularize(black_box(&w), &rht));
            },
        );
    }
}
