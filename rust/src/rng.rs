//! Deterministic pseudo-random number generation.
//!
//! The vendored crate set has no `rand` (only `rand_core` without any RNGs),
//! so we carry our own: a [SplitMix64](https://prng.di.unimi.it/splitmix64.c)
//! stream generator plus xoshiro256++ for bulk use, with helpers for the
//! distributions the quantizers need (uniform, standard normal, permutations,
//! Rademacher signs for the randomized Hadamard transform).
//!
//! Everything in the repository that consumes randomness takes an explicit
//! seed so that artifacts (codebooks, sign vectors, synthetic tasks) are
//! bit-reproducible across runs — the paper's codebooks are likewise "offline
//! and performed only once for all circumstances" (§3.2.3).

/// SplitMix64: used to seed xoshiro and for cheap one-off draws.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG. Fast, 256-bit state, passes BigCrush.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box-Muller output.
    spare_normal: Option<f64>,
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[lo, hi)`.
    #[inline]
    pub fn uniform_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in `[0, n)` (Lemire's method, unbiased).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // Avoid u == 0 so ln is finite.
        let u = loop {
            let u = self.uniform();
            if u > 1e-300 {
                break u;
            }
        };
        let v = self.uniform();
        let r = (-2.0 * u.ln()).sqrt();
        let (sin, cos) = (2.0 * std::f64::consts::PI * v).sin_cos();
        self.spare_normal = Some(r * sin);
        r * cos
    }

    /// Fill a slice with i.i.d. standard normals (f32).
    pub fn fill_normal(&mut self, out: &mut [f32]) {
        for x in out.iter_mut() {
            *x = self.normal() as f32;
        }
    }

    /// Vector of `n` i.i.d. standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; n];
        self.fill_normal(&mut v);
        v
    }

    /// Rademacher signs (+1/-1) — the diagonal of the randomized Hadamard
    /// transform. One bit per entry, drawn from the raw stream.
    pub fn signs(&mut self, n: usize) -> Vec<f32> {
        let mut out = Vec::with_capacity(n);
        let mut bits = 0u64;
        for i in 0..n {
            if i % 64 == 0 {
                bits = self.next_u64();
            }
            out.push(if bits & 1 == 1 { 1.0 } else { -1.0 });
            bits >>= 1;
        }
        out
    }

    /// In-place Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `m` distinct indices from `[0, n)` (floyd's algorithm for small
    /// m, shuffle for large).
    pub fn sample_indices(&mut self, n: usize, m: usize) -> Vec<usize> {
        assert!(m <= n);
        if m * 4 > n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(m);
            all
        } else {
            let mut chosen = std::collections::HashSet::with_capacity(m);
            let mut out = Vec::with_capacity(m);
            for j in (n - m)..n {
                let t = self.below(j + 1);
                let pick = if chosen.contains(&t) { j } else { t };
                chosen.insert(pick);
                out.push(pick);
            }
            out
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn below_unbiased_smoke() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut s1, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let z = r.normal();
            s1 += z;
            s2 += z * z;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn signs_are_pm_one_and_balanced() {
        let mut r = Rng::new(13);
        let s = r.signs(100_000);
        assert!(s.iter().all(|&x| x == 1.0 || x == -1.0));
        let pos = s.iter().filter(|&&x| x > 0.0).count();
        assert!((48_000..52_000).contains(&pos));
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(17);
        for &(n, m) in &[(10, 3), (100, 90), (1000, 10)] {
            let idx = r.sample_indices(n, m);
            assert_eq!(idx.len(), m);
            let set: std::collections::HashSet<_> = idx.iter().collect();
            assert_eq!(set.len(), m);
            assert!(idx.iter().all(|&i| i < n));
        }
    }
}
