//! Layer-parallel quantization scheduler.
//!
//! Quantizing a model is embarrassingly parallel across weight matrices; the
//! scheduler fans the quantizable layers out to a worker pool over an
//! `mpsc` work queue. Codebooks are shared read-only (`Arc` inside the
//! quantizer), workers own per-layer scratch, and results merge back in
//! deterministic name order regardless of completion order — quantizing the
//! same model twice yields bit-identical outputs.
//!
//! Workers hand back **compressed artifacts** ([`QuantizedWeight`]), so the
//! merge step assembles a [`QuantizedGpt`] (codes + shared codebooks) and
//! every statistic is *measured* from the artifacts — payload bits from the
//! packed streams, codebook bits deduplicated by decoder spec — never
//! estimated from nominal bpw. [`quantize_model_parallel`] additionally
//! materializes the dense fake-quant model for eval paths that need one.

use std::sync::mpsc;
use std::sync::Mutex;
use std::time::Instant;

use crate::model::{GptModel, QuantizedGpt};
use crate::quant::{QuantizedWeight, Quantizer};

/// Per-run statistics (all measured from the merged artifacts).
#[derive(Clone, Debug, Default)]
pub struct QuantStats {
    /// (layer name, seconds, payload bits) per quantized matrix.
    pub layers: Vec<(String, f64, u64)>,
    /// Total wall-clock seconds.
    pub wall_s: f64,
    /// Total payload bits (packed codes + per-layer metadata).
    pub payload_bits: u64,
    /// Bits of the distinct shared codebooks referenced by the artifacts.
    pub codebook_bits: u64,
    /// Achieved bits per weight over the quantizable parameters.
    pub achieved_bpw: f64,
}

/// Quantize every quantizable matrix of `model` into compressed artifacts,
/// fanning out across `n_workers` threads. Returns the codes-resident model
/// + stats; no dense weight is materialized.
///
/// The quantizer must be `Sync` (shared immutably across workers) — all
/// quantizers in this crate are, their per-call state is stack-local.
pub fn quantize_model_compressed<Q: Quantizer + Sync + ?Sized>(
    model: &GptModel,
    quantizer: &Q,
    n_workers: usize,
) -> (QuantizedGpt, QuantStats) {
    let names = model.config.quantizable_names();
    let t0 = Instant::now();

    // With several layer workers, pin each worker's *inner* assignment
    // parallelism to one thread so the two levels don't oversubscribe the
    // machine; a single worker keeps the full within-layer split.
    let inner_threads = if n_workers > 1 { Some(1) } else { None };

    // Work queue: indices into `names`; results: (index, artifact, secs).
    let (result_tx, result_rx) = mpsc::channel::<(usize, QuantizedWeight, f64)>();
    let next = Mutex::new(0usize);

    std::thread::scope(|scope| {
        for _ in 0..n_workers.max(1) {
            let result_tx = result_tx.clone();
            let next = &next;
            let names = &names;
            scope.spawn(move || {
                let work = || loop {
                    let i = {
                        let mut guard = next.lock().unwrap();
                        let i = *guard;
                        if i >= names.len() {
                            return;
                        }
                        *guard += 1;
                        i
                    };
                    let w = &model.tensors[&names[i]];
                    let t = Instant::now();
                    let qw = quantizer.quantize(w);
                    let secs = t.elapsed().as_secs_f64();
                    result_tx.send((i, qw, secs)).ok();
                };
                match inner_threads {
                    Some(t) => crate::quant::assign::with_assign_threads(t, work),
                    None => work(),
                }
            });
        }
        drop(result_tx);
    });

    let mut stats = QuantStats::default();
    let mut results: Vec<Option<(QuantizedWeight, f64)>> =
        (0..names.len()).map(|_| None).collect();
    while let Ok((i, qw, secs)) = result_rx.recv() {
        results[i] = Some((qw, secs));
    }
    let mut weights = std::collections::BTreeMap::new();
    for (i, r) in results.into_iter().enumerate() {
        let (qw, secs) = r.expect("worker dropped a layer");
        let bits = qw.payload_bits();
        stats.layers.push((names[i].clone(), secs, bits));
        stats.payload_bits += bits;
        weights.insert(names[i].clone(), qw);
    }
    let q = QuantizedGpt::from_artifacts(model, weights);
    stats.codebook_bits = q.codebook_bits();
    stats.wall_s = t0.elapsed().as_secs_f64();
    stats.achieved_bpw =
        stats.payload_bits as f64 / model.config.quantizable_params() as f64;
    (q, stats)
}

/// Compression accounting for a **layer-sharded** deployment of the merged
/// artifacts: per node, the dedup of the shared codebooks that node's layer
/// range references (the codebook-once-per-node rule — a codebook used by
/// layers on two nodes is resident on both). The single-node
/// [`QuantStats::codebook_bits`] is the `n_shards = 1` case; the sum over
/// nodes is what a [`crate::coordinator::ShardedForward`] deployment
/// actually keeps resident, and `paper::verify_codes_resident` asserts the
/// two accountings agree on every quantized model it checks.
pub fn sharded_codebook_bits(q: &QuantizedGpt, n_shards: usize) -> Vec<u64> {
    super::shard::codebook_bits_per_node(q, n_shards)
}

/// [`quantize_model_compressed`] + explicit dense materialization: returns
/// the fake-quant [`GptModel`] for consumers (eval ablations, the `fwd_fp`
/// executable) that need dense weights.
pub fn quantize_model_parallel<Q: Quantizer + Sync + ?Sized>(
    model: &GptModel,
    quantizer: &Q,
    n_workers: usize,
) -> (GptModel, QuantStats) {
    let (q, stats) = quantize_model_compressed(model, quantizer, n_workers);
    (q.to_dense(), stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::{Entry, Pct};
    use crate::quant::sq::Rtn;
    use crate::rng::Rng;

    fn tiny_model() -> GptModel {
        // build a synthetic container in-memory via the pct round-trip
        let dir = std::env::temp_dir().join("pcdvq_sched_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.pct");
        let mut rng = Rng::new(3);
        let mut pct = Pct::new();
        let d = 32u64;
        pct.insert("embed.tok", Entry::f32(&[256, d], rng.normal_vec(256 * d as usize)));
        pct.insert("embed.pos", Entry::f32(&[128, d], rng.normal_vec(128 * d as usize)));
        for i in 0..2 {
            for nm in ["wq", "wk", "wv", "wo"] {
                pct.insert(
                    &format!("layer{i}.attn.{nm}"),
                    Entry::f32(&[d, d], rng.normal_vec((d * d) as usize)),
                );
            }
            pct.insert(
                &format!("layer{i}.mlp.w1"),
                Entry::f32(&[d, d * 4], rng.normal_vec((d * d * 4) as usize)),
            );
            pct.insert(
                &format!("layer{i}.mlp.w2"),
                Entry::f32(&[d * 4, d], rng.normal_vec((d * d * 4) as usize)),
            );
            for nm in ["ln1.g", "ln1.b", "ln2.g", "ln2.b"] {
                pct.insert(&format!("layer{i}.{nm}"), Entry::f32(&[d], vec![1.0; d as usize]));
            }
        }
        pct.insert("final_ln.g", Entry::f32(&[d], vec![1.0; d as usize]));
        pct.insert("final_ln.b", Entry::f32(&[d], vec![0.0; d as usize]));
        pct.insert("head.w", Entry::f32(&[d, 256], rng.normal_vec(d as usize * 256)));
        for (k, v) in
            [("vocab", 256u64), ("d_model", d), ("n_layer", 2), ("n_head", 4), ("d_ff", d * 4), ("ctx", 128)]
        {
            pct.insert(&format!("meta.{k}"), Entry::u64(&[1], vec![v]));
        }
        pct.save(&path).unwrap();
        GptModel::load(&path).unwrap()
    }

    #[test]
    fn parallel_matches_serial() {
        let model = tiny_model();
        let rtn = Rtn::new(4);
        let (serial, _) = model.fake_quantize(&rtn);
        let (par1, s1) = quantize_model_parallel(&model, &rtn, 1);
        let (par4, s4) = quantize_model_parallel(&model, &rtn, 4);
        for name in model.config.quantizable_names() {
            assert_eq!(serial.tensors[&name].as_slice(), par1.tensors[&name].as_slice());
            assert_eq!(serial.tensors[&name].as_slice(), par4.tensors[&name].as_slice());
        }
        assert_eq!(s1.payload_bits, s4.payload_bits);
        assert_eq!(s1.layers.len(), model.config.quantizable_names().len());
    }

    #[test]
    fn stats_account_bpw() {
        let model = tiny_model();
        let (_, stats) = quantize_model_parallel(&model, &Rtn::new(2), 2);
        // 2-bit indices + per-column scale overhead
        assert!(stats.achieved_bpw >= 2.0 && stats.achieved_bpw < 3.5, "{}", stats.achieved_bpw);
        assert!(stats.wall_s >= 0.0);
    }

    #[test]
    fn sharded_accounting_extends_single_node_stats() {
        let model = tiny_model();
        let (q, stats) = quantize_model_compressed(&model, &Rtn::new(3), 2);
        // one node == the classic accounting
        assert_eq!(sharded_codebook_bits(&q, 1), vec![stats.codebook_bits]);
        // more nodes: each node dedups independently; totals bracket
        let per_node = sharded_codebook_bits(&q, 2);
        assert_eq!(per_node.len(), 2);
        let total: u64 = per_node.iter().sum();
        assert!(total >= stats.codebook_bits);
        assert!(total <= stats.codebook_bits * 2);
    }

    #[test]
    fn compressed_merge_holds_codes_only() {
        let model = tiny_model();
        let (q, stats) = quantize_model_compressed(&model, &Rtn::new(2), 3);
        // every quantizable layer merged, in deterministic name order
        let names = model.config.quantizable_names();
        assert_eq!(q.weights.len(), names.len());
        assert_eq!(
            stats.layers.iter().map(|(n, ..)| n.clone()).collect::<Vec<_>>(),
            names
        );
        // measured payload = sum of per-artifact payloads
        assert_eq!(stats.payload_bits, q.payload_bits());
        assert_eq!(stats.codebook_bits, q.codebook_bits());
        // the artifact collection is ~16x smaller than dense fp32
        assert!(q.resident_bits() * 8 < q.dense_bits());
        // fp tensors (embeddings, norms) pass through
        assert!(q.fp_tensors.contains_key("embed.tok"));
        assert!(!q.fp_tensors.contains_key("head.w"));
    }
}
