//! Layer-parallel quantization scheduler.
//!
//! Quantizing a model is embarrassingly parallel across weight matrices; the
//! scheduler fans the quantizable layers out to a worker pool over an
//! `mpsc` work queue. Codebooks are shared read-only (`Arc` inside the
//! quantizer), workers own per-layer scratch, and results merge back in
//! deterministic name order regardless of completion order — quantizing the
//! same model twice yields bit-identical outputs.

use std::sync::mpsc;
use std::sync::Mutex;
use std::time::Instant;

use crate::model::GptModel;
use crate::quant::Quantizer;
use crate::tensor::Matrix;

/// Per-run statistics.
#[derive(Clone, Debug, Default)]
pub struct QuantStats {
    /// (layer name, seconds, payload bits) per quantized matrix.
    pub layers: Vec<(String, f64, u64)>,
    /// Total wall-clock seconds.
    pub wall_s: f64,
    /// Total payload bits.
    pub payload_bits: u64,
    /// Achieved bits per weight over the quantizable parameters.
    pub achieved_bpw: f64,
}

/// Quantize every quantizable matrix of `model` using `quantizer`, fanning
/// out across `n_workers` threads. Returns the fake-quant model + stats.
///
/// The quantizer must be `Sync` (shared immutably across workers) — all
/// quantizers in this crate are, their per-call state is stack-local.
pub fn quantize_model_parallel<Q: Quantizer + Sync + ?Sized>(
    model: &GptModel,
    quantizer: &Q,
    n_workers: usize,
) -> (GptModel, QuantStats) {
    let names = model.config.quantizable_names();
    let t0 = Instant::now();

    // Work queue: indices into `names`; results: (index, matrix, bits, secs).
    let (result_tx, result_rx) = mpsc::channel::<(usize, Matrix, u64, f64)>();
    let next = Mutex::new(0usize);

    std::thread::scope(|scope| {
        for _ in 0..n_workers.max(1) {
            let result_tx = result_tx.clone();
            let next = &next;
            let names = &names;
            scope.spawn(move || loop {
                let i = {
                    let mut guard = next.lock().unwrap();
                    let i = *guard;
                    if i >= names.len() {
                        return;
                    }
                    *guard += 1;
                    i
                };
                let w = &model.tensors[&names[i]];
                let t = Instant::now();
                let qw = quantizer.quantize(w);
                let secs = t.elapsed().as_secs_f64();
                let bits = qw.payload_bits();
                result_tx.send((i, qw.into_dequantized(), bits, secs)).ok();
            });
        }
        drop(result_tx);
    });

    let mut out = model.clone();
    let mut stats = QuantStats::default();
    let mut results: Vec<Option<(Matrix, u64, f64)>> = (0..names.len()).map(|_| None).collect();
    while let Ok((i, m, bits, secs)) = result_rx.recv() {
        results[i] = Some((m, bits, secs));
    }
    for (i, r) in results.into_iter().enumerate() {
        let (m, bits, secs) = r.expect("worker dropped a layer");
        stats.layers.push((names[i].clone(), secs, bits));
        stats.payload_bits += bits;
        out.tensors.insert(names[i].clone(), m);
    }
    stats.wall_s = t0.elapsed().as_secs_f64();
    stats.achieved_bpw =
        stats.payload_bits as f64 / model.config.quantizable_params() as f64;
    (out, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::{Entry, Pct};
    use crate::quant::sq::Rtn;
    use crate::rng::Rng;

    fn tiny_model() -> GptModel {
        // build a synthetic container in-memory via the pct round-trip
        let dir = std::env::temp_dir().join("pcdvq_sched_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.pct");
        let mut rng = Rng::new(3);
        let mut pct = Pct::new();
        let d = 32u64;
        pct.insert("embed.tok", Entry::f32(&[256, d], rng.normal_vec(256 * d as usize)));
        pct.insert("embed.pos", Entry::f32(&[128, d], rng.normal_vec(128 * d as usize)));
        for i in 0..2 {
            for nm in ["wq", "wk", "wv", "wo"] {
                pct.insert(
                    &format!("layer{i}.attn.{nm}"),
                    Entry::f32(&[d, d], rng.normal_vec((d * d) as usize)),
                );
            }
            pct.insert(
                &format!("layer{i}.mlp.w1"),
                Entry::f32(&[d, d * 4], rng.normal_vec((d * d * 4) as usize)),
            );
            pct.insert(
                &format!("layer{i}.mlp.w2"),
                Entry::f32(&[d * 4, d], rng.normal_vec((d * d * 4) as usize)),
            );
            for nm in ["ln1.g", "ln1.b", "ln2.g", "ln2.b"] {
                pct.insert(&format!("layer{i}.{nm}"), Entry::f32(&[d], vec![1.0; d as usize]));
            }
        }
        pct.insert("final_ln.g", Entry::f32(&[d], vec![1.0; d as usize]));
        pct.insert("final_ln.b", Entry::f32(&[d], vec![0.0; d as usize]));
        pct.insert("head.w", Entry::f32(&[d, 256], rng.normal_vec(d as usize * 256)));
        for (k, v) in
            [("vocab", 256u64), ("d_model", d), ("n_layer", 2), ("n_head", 4), ("d_ff", d * 4), ("ctx", 128)]
        {
            pct.insert(&format!("meta.{k}"), Entry::u64(&[1], vec![v]));
        }
        pct.save(&path).unwrap();
        GptModel::load(&path).unwrap()
    }

    #[test]
    fn parallel_matches_serial() {
        let model = tiny_model();
        let rtn = Rtn::new(4);
        let (serial, _) = model.fake_quantize(&rtn);
        let (par1, s1) = quantize_model_parallel(&model, &rtn, 1);
        let (par4, s4) = quantize_model_parallel(&model, &rtn, 4);
        for name in model.config.quantizable_names() {
            assert_eq!(serial.tensors[&name].as_slice(), par1.tensors[&name].as_slice());
            assert_eq!(serial.tensors[&name].as_slice(), par4.tensors[&name].as_slice());
        }
        assert_eq!(s1.payload_bits, s4.payload_bits);
        assert_eq!(s1.layers.len(), model.config.quantizable_names().len());
    }

    #[test]
    fn stats_account_bpw() {
        let model = tiny_model();
        let (_, stats) = quantize_model_parallel(&model, &Rtn::new(2), 2);
        // 2-bit indices + per-column scale overhead
        assert!(stats.achieved_bpw >= 2.0 && stats.achieved_bpw < 3.5, "{}", stats.achieved_bpw);
        assert!(stats.wall_s >= 0.0);
    }
}
