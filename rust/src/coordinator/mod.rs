//! L3 coordinator — the system side of the reproduction.
//!
//! PCDVQ's contribution is the quantization algorithm; the coordinator turns
//! it into a deployable system (the role vLLM's router plays for serving
//! papers):
//!
//! * [`scheduler`] — layer-parallel quantization: weight matrices fan out to
//!   worker threads, codebooks are shared read-only, results are merged in
//!   deterministic order.
//! * [`batcher`] — dynamic request batching for the serving loop (collect up
//!   to `max_batch` requests or `max_wait`, whichever first).
//! * [`server`] — the generation service: batched iterative decoding against
//!   the AOT forward executable (fp *or* in-graph-dequant quantized) or the
//!   host **codes-resident** backend (packed codes + shared codebooks only),
//!   with throughput/latency metrics (§4.4). The host backend decodes
//!   incrementally against per-slot KV caches
//!   ([`server::DecodePolicy::KvCached`]); the windowed re-forward remains
//!   as the parity oracle.

pub mod batcher;
pub mod metrics;
pub mod scheduler;
pub mod server;

pub use batcher::{Batcher, BatcherConfig, GenRequest, GenResponse};
pub use metrics::Metrics;
pub use scheduler::{quantize_model_compressed, quantize_model_parallel, QuantStats};
pub use server::{DecodePolicy, Server, ServingWeights};
