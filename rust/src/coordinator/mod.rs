//! L3 coordinator — the system side of the reproduction.
//!
//! PCDVQ's contribution is the quantization algorithm; the coordinator turns
//! it into a deployable system (the role vLLM's router plays for serving
//! papers):
//!
//! * [`scheduler`] — layer-parallel quantization: weight matrices fan out to
//!   worker threads, codebooks are shared read-only, results are merged in
//!   deterministic order.
//! * [`batcher`] — request admission for the serving loop: static batch
//!   coalescing (collect up to `max_batch` requests or `max_wait`,
//!   whichever first) for the fixed-geometry XLA path, and a drain-first
//!   FIFO admission queue (deterministic, deadline-aware) feeding the
//!   continuous loop.
//! * [`server`] — the generation service: batched iterative decoding
//!   against the AOT forward executable (fp *or* in-graph-dequant
//!   quantized) or the host **codes-resident** backend (packed codes +
//!   shared codebooks only), with throughput/latency metrics (§4.4). The
//!   host backend decodes incrementally against per-slot KV caches
//!   ([`server::DecodePolicy::KvCached`]) and serves with **continuous
//!   batching + block prefill** ([`server::Server::serve_continuous`]):
//!   slots admit new requests the moment a sequence finishes, prompts
//!   enter the cache in chunks. The windowed re-forward remains as the
//!   parity oracle.
//! * [`prefix`] — the cross-request prefix-sharing trie ([`PrefixCache`]):
//!   maps prompt prefixes to shared KV page chains
//!   ([`crate::model::kv_pool`]) at admission time, so a hot prefix's
//!   prefill is paid once per server (DESIGN.md §13).
//! * [`shard`] — the layer-sharded multi-worker topology: the artifact
//!   collection partitions by layer across N worker nodes
//!   ([`ShardedForward`]), activations pipeline through the shard chain,
//!   and compression accounting extends to **codebook-once-per-node** bits
//!   ([`sharded_codebook_bits`]). Each node also owns per-slot KV state
//!   for its layer range, so `serve_continuous` decodes KV-cached through
//!   the chain ([`ShardedForward::step_slots`], DESIGN.md §16).
//!   Bit-identical to the single-node host forward at any shard count
//!   (DESIGN.md §12).
//! * [`ingress`] — the network front end: a threaded HTTP/1.1 listener
//!   (`POST /v1/generate` streamed as SSE, `GET /metrics` in Prometheus
//!   text, `GET /healthz` liveness + `GET /readyz` readiness) with an
//!   admission gate that sheds overload early with 429 instead of timing
//!   out late, in front of the batcher's per-tenant weighted-round-robin
//!   queues (DESIGN.md §14). Request bodies are validated at the boundary
//!   (structured 400s) and slow clients are cut off with 408 after a
//!   configurable read timeout.
//! * [`fault`] — the fault-tolerance layer (DESIGN.md §17): supervised
//!   slot stepping converts a per-slot panic/error into a typed
//!   [`Fault`], failing only the affected request
//!   ([`FinishReason::Faulted`]) while its slot is quarantined and
//!   rebuilt; [`FaultPlan`] (`PALLAS_FAULT`) injects deterministic faults
//!   at an exact (node, slot, step) coordinate for the chaos suite.

pub mod batcher;
pub mod fault;
pub mod ingress;
pub mod metrics;
pub mod prefix;
pub mod scheduler;
pub mod server;
pub mod shard;

pub use batcher::{
    Admitted, Batcher, BatcherConfig, FinishReason, GenRequest, GenRequestBuilder, GenResponse,
    Priority,
};
pub use fault::{Fault, FaultKind, FaultMode, FaultPlan};
pub use ingress::{Ingress, IngressConfig};
pub use metrics::Metrics;
pub use prefix::{PrefixCache, PrefixStats};
pub use scheduler::{
    quantize_model_compressed, quantize_model_parallel, sharded_codebook_bits, QuantStats,
};
pub use server::{
    validate_kv_page, validate_kv_quant, DecodePolicy, KvPageAudit, Server, ServerBuilder,
    ServingWeights,
};
pub use shard::{shard_layers, ShardBits, ShardStepJob, ShardedForward, SlotStepOutcome};
