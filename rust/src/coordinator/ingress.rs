//! HTTP ingress: the network front end of the continuous-batching server.
//!
//! [`Ingress::spawn`] binds a std [`TcpListener`] and puts three endpoints
//! in front of [`Server::serve_continuous`] — no async runtime, no
//! dependencies beyond the standard library:
//!
//! * `POST /v1/generate` — JSON body (`prompt`, `max_new`, `temperature`,
//!   optional `deadline_ms` / `tenant` / `priority`), answered as a
//!   Server-Sent-Events stream: one `data: {"token":N}` event per generated
//!   token as the scheduler produces it, terminated by an `event: usage`
//!   record (token/step counts, queue wait, TTFT, admission seq, finish
//!   reason) — or by an `event: error` record when the request's
//!   supervised step faulted ([`FinishReason::Faulted`], DESIGN.md §17),
//!   so a fault is always a structured stream terminator, never a hung
//!   connection. Bodies are validated at this boundary: a shape error
//!   (empty prompt, `max_new == 0`, prompt longer than the model context)
//!   is a structured 400 naming the offending field.
//! * `GET /metrics` — Prometheus text format: the serving loop's counters
//!   and latency quantiles ([`Metrics::prometheus_text`] via
//!   [`Server::metrics_mirror`]) plus the gate's per-tenant admitted/shed
//!   counters and live queue-pressure gauges.
//! * `GET /healthz` — liveness probe: 200 whenever the process can answer.
//! * `GET /readyz` — readiness probe: 503 before the serving loop's first
//!   scheduler iteration and while draining ([`Ingress::begin_drain`] /
//!   [`Ingress::shutdown`]), 200 otherwise — the signal a load balancer
//!   uses to route traffic away without killing in-flight requests.
//!
//! Slow clients are bounded too: every socket read runs under
//! [`IngressConfig::read_timeout`]; a client that dribbles its request
//! (slowloris) gets `408 Request Timeout` and its connection closed instead
//! of wedging a handler thread.
//!
//! # Admission control and load shedding
//!
//! An [`AdmissionGate`] sits between the socket and the [`Batcher`]: every
//! `POST /v1/generate` is checked *synchronously, before any response byte
//! is written*, against three budgets ([`IngressConfig`]) — total
//! in-flight requests, per-tenant in-flight requests, and the estimated
//! queue wait (requests beyond slot capacity × an EWMA of observed service
//! time ÷ slots). A request over budget is rejected **early** with
//! `429 Too Many Requests` + a `Retry-After` hint, instead of timing out
//! late after queueing — the admitted population is therefore one the
//! server can actually serve, which is what keeps goodput flat under
//! overload (the `ingress_load` bench scenario pins this). Requests that
//! pass the gate enter the batcher's per-`(priority, tenant)` queues and
//! get weighted-round-robin fairness from there (see
//! [`crate::coordinator::batcher`]'s module docs).
//!
//! # Threading
//!
//! One **serving thread** owns the [`Server`] and runs
//! `serve_continuous` (whose slot fan-out keeps using the shared
//! [`crate::exec::Pool`] — all model compute stays there). One **accept
//! thread** takes connections and hands each to a short-lived handler
//! thread (handlers are I/O-bound: parse, gate check, relay channel
//! messages to the socket; they never touch model state). Connections are
//! `Connection: close` — one request per connection — and capped at
//! [`IngressConfig::max_connections`] (503 beyond). [`Ingress::shutdown`]
//! stops accepting, drains in-flight requests, and hands the [`Server`]
//! back for inspection; [`Ingress::wait`] parks forever (the CLI
//! `serve --listen` path).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::batcher::{Batcher, BatcherConfig, FinishReason, GenRequest, GenResponse, Priority};
use super::metrics::Metrics;
use super::server::Server;

/// Admission budgets and connection limits for [`Ingress::spawn`].
#[derive(Clone, Debug)]
pub struct IngressConfig {
    /// Admitted requests in flight (queued + running) beyond which new
    /// arrivals shed with 429. `0` = unbounded.
    pub max_in_flight: usize,
    /// Per-tenant in-flight bound; the fairness backstop against one
    /// tenant monopolizing the queue. `0` = unbounded.
    pub tenant_in_flight_cap: usize,
    /// Estimated queue wait beyond which arrivals shed with 429. The
    /// estimate is `(in_flight - slots) × EWMA(service time) / slots`.
    /// Zero = disabled.
    pub queue_wait_budget: Duration,
    /// Concurrent connections; excess get an immediate 503.
    pub max_connections: usize,
    /// Weighted-round-robin weights handed to
    /// [`Batcher::set_tenant_weight`] at spawn (default weight is 1).
    pub tenant_weights: Vec<(String, usize)>,
    /// Socket read budget per connection (request line, headers, body):
    /// a client that dribbles past it gets `408 Request Timeout` and the
    /// connection closed (`serve --read-timeout-ms`). Zero keeps the
    /// default (30 s).
    pub read_timeout: Duration,
}

impl Default for IngressConfig {
    fn default() -> Self {
        IngressConfig {
            max_in_flight: 64,
            tenant_in_flight_cap: 0,
            queue_wait_budget: Duration::ZERO,
            max_connections: 256,
            tenant_weights: Vec::new(),
            read_timeout: Duration::from_secs(30),
        }
    }
}

/// Per-tenant admission bookkeeping inside the gate.
#[derive(Clone, Debug, Default)]
struct TenantStat {
    admitted: u64,
    shed: u64,
    in_flight: usize,
}

#[derive(Debug, Default)]
struct GateState {
    in_flight: usize,
    /// EWMA of per-request *service* time (latency minus queue wait),
    /// seconds; 0 until the first completion.
    ema_service_s: f64,
    /// First-seen order, so `/metrics` output is stable across scrapes.
    tenants: Vec<(String, TenantStat)>,
}

/// The synchronous admission gate in front of the request queue: bounded
/// in-flight counts (total and per tenant) plus an estimated-queue-wait
/// budget. Shared by every handler thread; one short [`Mutex`] hold per
/// decision.
pub struct AdmissionGate {
    max_in_flight: usize,
    tenant_cap: usize,
    wait_budget: Duration,
    slots: usize,
    state: Mutex<GateState>,
}

impl AdmissionGate {
    fn new(cfg: &IngressConfig, slots: usize) -> Self {
        AdmissionGate {
            max_in_flight: cfg.max_in_flight,
            tenant_cap: cfg.tenant_in_flight_cap,
            wait_budget: cfg.queue_wait_budget,
            slots: slots.max(1),
            state: Mutex::new(GateState::default()),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, GateState> {
        // a poisoned gate mutex means a handler panicked mid-update; the
        // counters are still sane (single writes), so keep serving
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Estimated wait for a request arriving now, given `in_flight`
    /// admitted requests ahead of it.
    fn estimate_wait_s(&self, in_flight: usize, ema_service_s: f64) -> f64 {
        let excess = in_flight.saturating_sub(self.slots) as f64;
        excess * ema_service_s / self.slots as f64
    }

    /// Admit or shed. `Err(retry_after_s)` means shed: the caller answers
    /// 429 with that `Retry-After` hint and MUST NOT call
    /// [`Self::complete`]. `Ok(())` increments the in-flight counts; the
    /// caller MUST pair it with exactly one `complete`.
    fn try_admit(&self, tenant: &str) -> std::result::Result<(), u64> {
        let mut st = self.lock();
        let est_wait = self.estimate_wait_s(st.in_flight, st.ema_service_s);
        let idx = match st.tenants.iter().position(|(t, _)| t == tenant) {
            Some(i) => i,
            None => {
                st.tenants.push((tenant.to_string(), TenantStat::default()));
                st.tenants.len() - 1
            }
        };
        let over_total = self.max_in_flight > 0 && st.in_flight >= self.max_in_flight;
        let over_tenant =
            self.tenant_cap > 0 && st.tenants[idx].1.in_flight >= self.tenant_cap;
        let over_wait = self.wait_budget > Duration::ZERO
            && est_wait > self.wait_budget.as_secs_f64();
        if over_total || over_tenant || over_wait {
            st.tenants[idx].1.shed += 1;
            return Err((est_wait.ceil() as u64).max(1));
        }
        st.in_flight += 1;
        st.tenants[idx].1.admitted += 1;
        st.tenants[idx].1.in_flight += 1;
        Ok(())
    }

    /// Mark one admitted request resolved. `service` (latency minus queue
    /// wait) feeds the wait estimator; pass `None` for requests that did
    /// no work (timed out, shed in-queue, server shutting down).
    fn complete(&self, tenant: &str, service: Option<Duration>) {
        let mut st = self.lock();
        st.in_flight = st.in_flight.saturating_sub(1);
        if let Some(entry) = st.tenants.iter_mut().find(|(t, _)| t == tenant) {
            entry.1.in_flight = entry.1.in_flight.saturating_sub(1);
        }
        if let Some(s) = service {
            let s = s.as_secs_f64();
            st.ema_service_s = if st.ema_service_s == 0.0 {
                s
            } else {
                0.7 * st.ema_service_s + 0.3 * s
            };
        }
    }

    /// `(admitted, shed)` counters for one tenant (0, 0 if never seen).
    pub fn tenant_counters(&self, tenant: &str) -> (u64, u64) {
        let st = self.lock();
        st.tenants
            .iter()
            .find(|(t, _)| t == tenant)
            .map(|(_, s)| (s.admitted, s.shed))
            .unwrap_or((0, 0))
    }

    /// Total requests shed at the gate across all tenants.
    pub fn shed_total(&self) -> u64 {
        self.lock().tenants.iter().map(|(_, s)| s.shed).sum()
    }

    /// The gate's Prometheus lines, appended after the server metrics by
    /// `GET /metrics`.
    fn prometheus_text(&self) -> String {
        let st = self.lock();
        let mut out = String::new();
        out.push_str(
            "# HELP pallas_tenant_admitted_total Requests admitted through the ingress gate\n\
             # TYPE pallas_tenant_admitted_total counter\n",
        );
        for (t, s) in &st.tenants {
            out.push_str(&format!(
                "pallas_tenant_admitted_total{{tenant=\"{}\"}} {}\n",
                escape_label(t),
                s.admitted
            ));
        }
        out.push_str(
            "# HELP pallas_tenant_shed_total Requests shed at the ingress gate\n\
             # TYPE pallas_tenant_shed_total counter\n",
        );
        for (t, s) in &st.tenants {
            out.push_str(&format!(
                "pallas_tenant_shed_total{{tenant=\"{}\"}} {}\n",
                escape_label(t),
                s.shed
            ));
        }
        out.push_str(&format!(
            "# HELP pallas_ingress_in_flight Admitted requests currently queued or running\n\
             # TYPE pallas_ingress_in_flight gauge\n\
             pallas_ingress_in_flight {}\n",
            st.in_flight
        ));
        out.push_str(&format!(
            "# HELP pallas_ingress_est_queue_wait_seconds Estimated wait for a request arriving now\n\
             # TYPE pallas_ingress_est_queue_wait_seconds gauge\n\
             pallas_ingress_est_queue_wait_seconds {}\n",
            self.estimate_wait_s(st.in_flight, st.ema_service_s)
        ));
        out
    }
}

/// Escape a Prometheus label value (backslash, quote, newline).
fn escape_label(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// State shared by the accept loop and every handler thread. The request
/// sender lives here and nowhere else: when the accept thread and the last
/// in-flight handler drop their `Arc`, the channel closes, the batcher
/// drains, and the serving thread returns the server.
struct Ctx {
    req_tx: Mutex<Sender<GenRequest>>,
    gate: Arc<AdmissionGate>,
    mirror: Arc<Mutex<Metrics>>,
    stop: Arc<AtomicBool>,
    live_conns: AtomicUsize,
    max_conns: usize,
    /// Serving-loop readiness latch ([`Server::ready_signal`]) — `/readyz`
    /// answers 503 until it flips.
    ready: Arc<AtomicBool>,
    /// Graceful-shutdown flag ([`Ingress::begin_drain`]) — `/readyz`
    /// answers 503 while set, in-flight requests keep streaming.
    draining: Arc<AtomicBool>,
    /// Per-connection socket read budget (see [`IngressConfig`]).
    read_timeout: Duration,
    /// Model context length, for boundary validation of prompt sizes.
    model_ctx: usize,
}

/// A running HTTP front end — see the [module docs](self).
pub struct Ingress {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    draining: Arc<AtomicBool>,
    gate: Arc<AdmissionGate>,
    accept: Option<JoinHandle<()>>,
    serve: Option<JoinHandle<Result<Server>>>,
}

impl Ingress {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral test port) and
    /// start serving: the server moves onto its own thread running
    /// [`Server::serve_continuous`]; requests flow socket → gate →
    /// [`Batcher`] → slots → SSE.
    pub fn spawn(
        mut server: Server,
        batcher_cfg: BatcherConfig,
        cfg: IngressConfig,
        addr: &str,
    ) -> Result<Ingress> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding ingress on {addr}"))?;
        let addr = listener.local_addr().context("resolving bound address")?;
        let mirror = server.metrics_mirror();
        let ready = server.ready_signal();
        let model_ctx = server.config.ctx;
        let gate = Arc::new(AdmissionGate::new(&cfg, server.max_slots));
        let stop = Arc::new(AtomicBool::new(false));
        let draining = Arc::new(AtomicBool::new(false));

        let (req_tx, req_rx) = channel();
        let mut batcher = Batcher::new(req_rx, batcher_cfg);
        for (tenant, weight) in &cfg.tenant_weights {
            batcher.set_tenant_weight(tenant.clone(), *weight);
        }
        let serve = std::thread::Builder::new()
            .name("pallas-serve".into())
            .spawn(move || -> Result<Server> {
                server.serve_continuous(&mut batcher)?;
                Ok(server)
            })
            .context("spawning serving thread")?;

        let ctx = Arc::new(Ctx {
            req_tx: Mutex::new(req_tx),
            gate: gate.clone(),
            mirror,
            stop: stop.clone(),
            live_conns: AtomicUsize::new(0),
            max_conns: cfg.max_connections.max(1),
            ready,
            draining: draining.clone(),
            read_timeout: if cfg.read_timeout.is_zero() {
                Duration::from_secs(30)
            } else {
                cfg.read_timeout
            },
            model_ctx,
        });
        let accept = std::thread::Builder::new()
            .name("pallas-ingress".into())
            .spawn(move || accept_loop(listener, ctx))
            .context("spawning accept thread")?;

        Ok(Ingress { addr, stop, draining, gate, accept: Some(accept), serve: Some(serve) })
    }

    /// The bound socket address (resolves `:0` test binds).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// `(admitted, shed)` gate counters for one tenant — test hook; the
    /// same numbers flow out of `GET /metrics`.
    pub fn tenant_counters(&self, tenant: &str) -> (u64, u64) {
        self.gate.tenant_counters(tenant)
    }

    /// Total requests shed at the gate.
    pub fn shed_total(&self) -> u64 {
        self.gate.shed_total()
    }

    /// Flip `/readyz` to 503 while the listener keeps accepting — the
    /// graceful-degradation window (DESIGN.md §17) where a load balancer
    /// routes new traffic away while in-flight requests finish streaming.
    /// [`Self::shutdown`] enters this state first; tests call it directly
    /// to observe draining readiness.
    pub fn begin_drain(&self) {
        self.draining.store(true, Ordering::SeqCst);
    }

    /// Stop accepting, drain every in-flight request, and hand the
    /// [`Server`] back (its [`Server::metrics`] hold the final counters).
    /// Readiness flips first ([`Self::begin_drain`]), then the listener
    /// closes.
    pub fn shutdown(mut self) -> Result<Server> {
        self.begin_drain();
        self.stop.store(true, Ordering::SeqCst);
        // the accept loop is parked in accept(): poke it awake so it can
        // observe the flag and exit
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            h.join().map_err(|_| anyhow::anyhow!("ingress accept thread panicked"))?;
        }
        // with the accept thread gone, the request channel closes as soon
        // as the last in-flight handler finishes; the serving loop then
        // drains and returns the server
        match self.serve.take() {
            Some(h) => h.join().map_err(|_| anyhow::anyhow!("serving thread panicked"))?,
            None => anyhow::bail!("serving thread already taken"),
        }
    }

    /// Park until the process dies (the CLI `serve --listen` path): joins
    /// the accept thread, which only exits on [`Self::shutdown`].
    pub fn wait(mut self) -> Result<()> {
        if let Some(h) = self.accept.take() {
            h.join().map_err(|_| anyhow::anyhow!("ingress accept thread panicked"))?;
        }
        if let Some(h) = self.serve.take() {
            h.join().map_err(|_| anyhow::anyhow!("serving thread panicked"))??;
        }
        Ok(())
    }
}

fn accept_loop(listener: TcpListener, ctx: Arc<Ctx>) {
    for stream in listener.incoming() {
        if ctx.stop.load(Ordering::SeqCst) {
            break;
        }
        let Ok(mut stream) = stream else { continue };
        if ctx.live_conns.fetch_add(1, Ordering::SeqCst) >= ctx.max_conns {
            ctx.live_conns.fetch_sub(1, Ordering::SeqCst);
            let _ = write_simple(
                &mut stream,
                503,
                "Service Unavailable",
                "application/json",
                &[("Retry-After", "1".to_string())],
                "{\"error\":\"too many connections\"}\n",
            );
            continue;
        }
        let hctx = ctx.clone();
        let spawned = std::thread::Builder::new().name("pallas-conn".into()).spawn(move || {
            let _ = handle_connection(stream, &hctx);
            hctx.live_conns.fetch_sub(1, Ordering::SeqCst);
        });
        if spawned.is_err() {
            ctx.live_conns.fetch_sub(1, Ordering::SeqCst);
        }
    }
}

/// Guard one socket read against a dribbling client: a timed-out read
/// answers `408 Request Timeout` and yields `None` so the handler returns
/// (closing the connection) instead of wedging its thread; any other I/O
/// error propagates as before.
fn guard_read_timeout<T>(
    r: std::io::Result<T>,
    stream: &mut TcpStream,
    what: &str,
) -> Result<Option<T>> {
    use std::io::ErrorKind;
    match r {
        Ok(v) => Ok(Some(v)),
        // both kinds, because platforms disagree on which one a timed-out
        // blocking read reports
        Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
            write_simple(
                stream,
                408,
                "Request Timeout",
                "application/json",
                &[],
                "{\"error\":\"read timed out\"}\n",
            )?;
            Ok(None)
        }
        Err(e) => Err(e).with_context(|| format!("reading {what}")),
    }
}

fn handle_connection(mut stream: TcpStream, ctx: &Ctx) -> Result<()> {
    stream.set_read_timeout(Some(ctx.read_timeout)).ok();
    let mut reader = BufReader::new(stream.try_clone().context("cloning connection")?);
    let mut line = String::new();
    if guard_read_timeout(reader.read_line(&mut line), &mut stream, "request line")?.is_none() {
        return Ok(());
    }
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();
    let mut content_len = 0usize;
    loop {
        let mut header = String::new();
        if guard_read_timeout(reader.read_line(&mut header), &mut stream, "header")?.is_none() {
            return Ok(());
        }
        let header = header.trim_end();
        if header.is_empty() {
            break;
        }
        if let Some((name, value)) = header.split_once(':') {
            if name.eq_ignore_ascii_case("content-length") {
                content_len = value.trim().parse().unwrap_or(0);
            }
        }
    }
    const MAX_BODY: usize = 1 << 20;
    if content_len > MAX_BODY {
        return write_simple(
            &mut stream,
            413,
            "Payload Too Large",
            "application/json",
            &[],
            "{\"error\":\"body too large\"}\n",
        );
    }
    let mut body = vec![0u8; content_len];
    if guard_read_timeout(reader.read_exact(&mut body), &mut stream, "body")?.is_none() {
        return Ok(());
    }

    match (method.as_str(), path.as_str()) {
        ("POST", "/v1/generate") => handle_generate(&mut stream, &body, ctx),
        ("GET", "/metrics") => {
            let mut text = {
                let m = ctx.mirror.lock().unwrap_or_else(|e| e.into_inner());
                m.prometheus_text()
            };
            text.push_str(&ctx.gate.prometheus_text());
            write_simple(
                &mut stream,
                200,
                "OK",
                "text/plain; version=0.0.4; charset=utf-8",
                &[],
                &text,
            )
        }
        // liveness: 200 whenever the process can answer at all
        ("GET", "/healthz") => {
            write_simple(&mut stream, 200, "OK", "text/plain; charset=utf-8", &[], "ok\n")
        }
        // readiness: 503 while draining / shutting down, or before the
        // serving loop's first scheduler iteration
        ("GET", "/readyz") => {
            let (status, reason, body) = if ctx.draining.load(Ordering::SeqCst)
                || ctx.stop.load(Ordering::SeqCst)
            {
                (503, "Service Unavailable", "draining\n")
            } else if !ctx.ready.load(Ordering::SeqCst) {
                (503, "Service Unavailable", "starting\n")
            } else {
                (200, "OK", "ready\n")
            };
            write_simple(&mut stream, status, reason, "text/plain; charset=utf-8", &[], body)
        }
        _ => write_simple(
            &mut stream,
            404,
            "Not Found",
            "application/json",
            &[],
            "{\"error\":\"not found\"}\n",
        ),
    }
}

fn handle_generate(stream: &mut TcpStream, body: &[u8], ctx: &Ctx) -> Result<()> {
    let spec = match parse_generate(body) {
        Ok(s) => s,
        Err(e) => {
            return write_simple(
                stream,
                400,
                "Bad Request",
                "application/json",
                &[],
                &format!("{{\"error\":{}}}\n", json_quote(&format!("{e:#}"))),
            )
        }
    };
    // shape validation at the boundary (DESIGN.md §14): a degenerate
    // request is a structured 400 naming the field, not a zero-token
    // generation downstream
    if let Err(e) = validate_generate(&spec, ctx.model_ctx) {
        return write_simple(
            stream,
            400,
            "Bad Request",
            "application/json",
            &[],
            &format!("{{\"error\":{}}}\n", json_quote(&format!("{e:#}"))),
        );
    }
    // The shed decision happens here, synchronously, before any response
    // byte: a rejected request costs the server nothing downstream.
    if let Err(retry_after) = ctx.gate.try_admit(&spec.tenant) {
        return write_simple(
            stream,
            429,
            "Too Many Requests",
            "application/json",
            &[("Retry-After", retry_after.to_string())],
            &format!("{{\"error\":\"shed\",\"retry_after_s\":{retry_after}}}\n"),
        );
    }
    // gate admitted: exactly one `complete` below, on every path
    let (resp_tx, resp_rx) = channel();
    let (tok_tx, tok_rx) = channel();
    let mut builder = GenRequest::builder(spec.prompt)
        .max_new(spec.max_new)
        .temperature(spec.temperature)
        .tenant(spec.tenant.clone())
        .priority(spec.priority)
        .stream(tok_tx);
    if let Some(ms) = spec.deadline_ms {
        builder = builder.deadline_in(Duration::from_millis(ms));
    }
    let req = builder.build(resp_tx);
    let sent = {
        let tx = ctx.req_tx.lock().unwrap_or_else(|e| e.into_inner()).clone();
        tx.send(req)
    };
    if sent.is_err() {
        ctx.gate.complete(&spec.tenant, None);
        return write_simple(
            stream,
            503,
            "Service Unavailable",
            "application/json",
            &[],
            "{\"error\":\"shutting down\"}\n",
        );
    }
    let result = stream_sse(stream, tok_rx, resp_rx);
    // only cleanly-completed requests feed the wait estimator: a faulted
    // or expired request's latency says nothing about healthy service time
    let service = result
        .as_ref()
        .ok()
        .filter(|r| !r.generated.is_empty() && r.finish == FinishReason::Done)
        .map(|r| r.latency.saturating_sub(r.queue_wait));
    ctx.gate.complete(&spec.tenant, service);
    result.map(|_| ())
}

/// Relay the token stream and the final response onto the socket as SSE.
/// A client that disconnects mid-stream stops receiving but never stops
/// the generation — the channels just drain into dropped receivers.
fn stream_sse(
    stream: &mut TcpStream,
    tok_rx: Receiver<u8>,
    resp_rx: Receiver<GenResponse>,
) -> Result<GenResponse> {
    write_head(stream, 200, "OK", "text/event-stream", &[("Cache-Control", "no-cache".into())])?;
    let mut client_gone = false;
    for tok in tok_rx.iter() {
        if client_gone {
            continue; // keep draining so the serving loop never blocks on us
        }
        let event = format!("data: {{\"token\":{tok}}}\n\n");
        if stream.write_all(event.as_bytes()).and_then(|_| stream.flush()).is_err() {
            client_gone = true;
        }
    }
    // the token sender dropping means the request resolved: its response
    // is already in (or about to enter) the channel
    let resp = resp_rx.recv().context("serving thread dropped the request")?;
    // a supervised fault terminates the stream with a structured error
    // event (DESIGN.md §17) — the client always sees an explicit
    // terminator, never a silently-truncated stream or a hung connection
    if resp.finish == FinishReason::Faulted {
        let event = format!(
            "event: error\ndata: {{\"error\":\"faulted\",\"seq\":{},\"tokens\":{}}}\n\n",
            resp.seq,
            resp.generated.len(),
        );
        if !client_gone {
            let _ = stream.write_all(event.as_bytes()).and_then(|_| stream.flush());
        }
        return Ok(resp);
    }
    let ttft_ms = match resp.ttft {
        Some(d) => format!("{:.3}", d.as_secs_f64() * 1e3),
        None => "null".to_string(),
    };
    let usage = format!(
        "event: usage\ndata: {{\"tokens\":{},\"steps\":{},\"seq\":{},\"queue_wait_ms\":{:.3},\"ttft_ms\":{},\"latency_ms\":{:.3},\"finish\":\"{}\"}}\n\n",
        resp.generated.len(),
        resp.steps,
        resp.seq,
        resp.queue_wait.as_secs_f64() * 1e3,
        ttft_ms,
        resp.latency.as_secs_f64() * 1e3,
        resp.finish.as_str(),
    );
    if !client_gone {
        let _ = stream.write_all(usage.as_bytes()).and_then(|_| stream.flush());
    }
    Ok(resp)
}

// ---------------------------------------------------------------------------
// HTTP plumbing (hand-rolled: the offline crate set has no HTTP stack)
// ---------------------------------------------------------------------------

fn write_head(
    stream: &mut TcpStream,
    status: u16,
    reason: &str,
    content_type: &str,
    extra: &[(&str, String)],
) -> Result<()> {
    let mut head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\nConnection: close\r\n"
    );
    for (name, value) in extra {
        head.push_str(&format!("{name}: {value}\r\n"));
    }
    head.push_str("\r\n");
    stream.write_all(head.as_bytes()).context("writing response head")?;
    stream.flush().context("flushing response head")
}

fn write_simple(
    stream: &mut TcpStream,
    status: u16,
    reason: &str,
    content_type: &str,
    extra: &[(&str, String)],
    body: &str,
) -> Result<()> {
    let mut head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n",
        body.len()
    );
    for (name, value) in extra {
        head.push_str(&format!("{name}: {value}\r\n"));
    }
    head.push_str("\r\n");
    stream.write_all(head.as_bytes()).context("writing response")?;
    stream.write_all(body.as_bytes()).context("writing body")?;
    stream.flush().context("flushing response")
}

/// A parsed HTTP response from [`http_request`] — the minimal blocking
/// client the ingress tests and the `ingress_load` bench drive traffic
/// with.
#[derive(Clone, Debug)]
pub struct HttpResponse {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    /// Entire response body (the server closes after each response, so
    /// SSE bodies arrive complete; decode them with [`parse_sse`]).
    pub body: String,
}

impl HttpResponse {
    /// Case-insensitive header lookup.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(n, _)| n.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }
}

/// Issue one blocking HTTP/1.1 request (`Connection: close`) and read the
/// response to EOF, with the default 120 s client-side read timeout.
pub fn http_request(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: Option<&str>,
) -> Result<HttpResponse> {
    http_request_with_timeout(addr, method, path, body, Duration::from_secs(120))
}

/// As [`http_request`], with an explicit client-side socket read timeout
/// (the slowloris test uses a short budget so a stalled server read
/// surfaces quickly instead of after two minutes).
pub fn http_request_with_timeout(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: Option<&str>,
    read_timeout: Duration,
) -> Result<HttpResponse> {
    let mut stream =
        TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
    stream.set_read_timeout(Some(read_timeout)).ok();
    let body = body.unwrap_or("");
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(req.as_bytes()).context("writing request")?;
    stream.flush().ok();
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw).context("reading response")?;
    let raw = String::from_utf8(raw).context("response is not UTF-8")?;
    let (head, body) = raw
        .split_once("\r\n\r\n")
        .context("response has no header/body separator")?;
    let mut lines = head.lines();
    let status_line = lines.next().context("empty response")?;
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .with_context(|| format!("bad status line: {status_line}"))?;
    let headers = lines
        .filter_map(|l| l.split_once(':'))
        .map(|(n, v)| (n.trim().to_string(), v.trim().to_string()))
        .collect();
    Ok(HttpResponse { status, headers, body: body.to_string() })
}

/// Convenience: `POST /v1/generate` with a JSON body assembled from parts.
/// `deadline_ms` 0 means no deadline; empty `tenant` is the anonymous
/// default.
pub fn post_generate(
    addr: SocketAddr,
    prompt: &str,
    max_new: usize,
    temperature: f32,
    tenant: &str,
    deadline_ms: u64,
) -> Result<HttpResponse> {
    let mut body = format!(
        "{{\"prompt\":{},\"max_new\":{max_new},\"temperature\":{temperature}",
        json_quote(prompt)
    );
    if !tenant.is_empty() {
        body.push_str(&format!(",\"tenant\":{}", json_quote(tenant)));
    }
    if deadline_ms > 0 {
        body.push_str(&format!(",\"deadline_ms\":{deadline_ms}"));
    }
    body.push('}');
    http_request(addr, "POST", "/v1/generate", Some(&body))
}

/// One Server-Sent Event from a response body.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SseEvent {
    /// The `event:` field; `"message"` when absent (plain `data:` events).
    pub event: String,
    pub data: String,
}

/// Split an SSE body into events (blank-line-delimited, `data:` payloads
/// concatenated per the SSE spec).
pub fn parse_sse(body: &str) -> Vec<SseEvent> {
    let mut events = Vec::new();
    for chunk in body.split("\n\n") {
        let mut event = String::from("message");
        let mut data = String::new();
        for line in chunk.lines() {
            if let Some(v) = line.strip_prefix("event: ") {
                event = v.to_string();
            } else if let Some(v) = line.strip_prefix("data: ") {
                if !data.is_empty() {
                    data.push('\n');
                }
                data.push_str(v);
            }
        }
        if !data.is_empty() {
            events.push(SseEvent { event, data });
        }
    }
    events
}

/// Decode the generated token bytes out of a parsed SSE stream (the
/// `data: {"token":N}` events, in order).
pub fn sse_tokens(events: &[SseEvent]) -> Vec<u8> {
    events
        .iter()
        .filter(|e| e.event == "message")
        .filter_map(|e| {
            e.data
                .strip_prefix("{\"token\":")
                .and_then(|r| r.strip_suffix('}'))
                .and_then(|n| n.trim().parse::<u8>().ok())
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Minimal JSON (flat objects of strings/numbers — the request body schema)
// ---------------------------------------------------------------------------

#[derive(Debug)]
enum JsonVal {
    Str(String),
    Num(f64),
    Bool(bool),
    Null,
}

type Chars<'a> = std::iter::Peekable<std::str::Chars<'a>>;

fn skip_ws(p: &mut Chars) {
    while matches!(p.peek(), Some(' ' | '\t' | '\r' | '\n')) {
        p.next();
    }
}

fn parse_json_string(p: &mut Chars) -> Result<String> {
    anyhow::ensure!(p.next() == Some('"'), "expected a string");
    let mut out = String::new();
    loop {
        match p.next() {
            None => anyhow::bail!("unterminated string"),
            Some('"') => return Ok(out),
            Some('\\') => match p.next() {
                Some('"') => out.push('"'),
                Some('\\') => out.push('\\'),
                Some('/') => out.push('/'),
                Some('n') => out.push('\n'),
                Some('r') => out.push('\r'),
                Some('t') => out.push('\t'),
                Some('b') => out.push('\u{8}'),
                Some('f') => out.push('\u{c}'),
                Some('u') => {
                    let mut code = 0u32;
                    for _ in 0..4 {
                        let d = p
                            .next()
                            .and_then(|c| c.to_digit(16))
                            .context("bad \\u escape")?;
                        code = code * 16 + d;
                    }
                    out.push(char::from_u32(code).context("bad \\u code point")?);
                }
                _ => anyhow::bail!("bad escape"),
            },
            Some(c) => out.push(c),
        }
    }
}

fn parse_json_number(p: &mut Chars) -> Result<f64> {
    let mut s = String::new();
    while let Some(&c) = p.peek() {
        if c.is_ascii_digit() || matches!(c, '-' | '+' | '.' | 'e' | 'E') {
            s.push(c);
            p.next();
        } else {
            break;
        }
    }
    s.parse::<f64>().with_context(|| format!("bad number '{s}'"))
}

fn expect_word(p: &mut Chars, word: &str) -> Result<()> {
    for c in word.chars() {
        anyhow::ensure!(p.next() == Some(c), "malformed literal (expected '{word}')");
    }
    Ok(())
}

/// Parse a flat JSON object of string/number/bool/null values — the whole
/// grammar `POST /v1/generate` accepts (nested values are a 400).
fn parse_flat_object(s: &str) -> Result<Vec<(String, JsonVal)>> {
    let mut p = s.chars().peekable();
    skip_ws(&mut p);
    anyhow::ensure!(p.next() == Some('{'), "body must be a JSON object");
    let mut out = Vec::new();
    skip_ws(&mut p);
    if p.peek().copied() == Some('}') {
        p.next();
        return Ok(out);
    }
    loop {
        skip_ws(&mut p);
        let key = parse_json_string(&mut p).context("object key")?;
        skip_ws(&mut p);
        anyhow::ensure!(p.next() == Some(':'), "expected ':' after \"{key}\"");
        skip_ws(&mut p);
        let val = match p.peek().copied() {
            Some('"') => JsonVal::Str(parse_json_string(&mut p)?),
            Some('t') => {
                expect_word(&mut p, "true")?;
                JsonVal::Bool(true)
            }
            Some('f') => {
                expect_word(&mut p, "false")?;
                JsonVal::Bool(false)
            }
            Some('n') => {
                expect_word(&mut p, "null")?;
                JsonVal::Null
            }
            Some(c) if c.is_ascii_digit() || c == '-' => JsonVal::Num(parse_json_number(&mut p)?),
            _ => anyhow::bail!("unsupported value for \"{key}\" (flat strings/numbers only)"),
        };
        out.push((key, val));
        skip_ws(&mut p);
        match p.next() {
            Some(',') => continue,
            Some('}') => return Ok(out),
            _ => anyhow::bail!("expected ',' or '}}'"),
        }
    }
}

/// Quote a string as a JSON value (for response bodies and the client
/// helper).
fn json_quote(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// A validated `POST /v1/generate` body.
#[derive(Debug)]
struct GenSpec {
    prompt: Vec<u8>,
    max_new: usize,
    temperature: f32,
    deadline_ms: Option<u64>,
    tenant: String,
    priority: Priority,
}

fn parse_generate(body: &[u8]) -> Result<GenSpec> {
    let text = std::str::from_utf8(body).context("body is not UTF-8")?;
    let fields = parse_flat_object(text)?;
    let mut spec = GenSpec {
        prompt: Vec::new(),
        max_new: 16,
        temperature: 0.0,
        deadline_ms: None,
        tenant: String::new(),
        priority: Priority::Normal,
    };
    let mut have_prompt = false;
    let usize_field = |key: &str, n: f64, cap: f64| -> Result<usize> {
        anyhow::ensure!(
            n.is_finite() && n >= 0.0 && n.fract() == 0.0 && n <= cap,
            "'{key}' must be an integer in 0..={cap}"
        );
        Ok(n as usize)
    };
    for (key, val) in fields {
        match (key.as_str(), val) {
            ("prompt", JsonVal::Str(s)) => {
                spec.prompt = s.into_bytes();
                have_prompt = true;
            }
            ("max_new", JsonVal::Num(n)) => spec.max_new = usize_field("max_new", n, 65536.0)?,
            ("temperature", JsonVal::Num(n)) => {
                anyhow::ensure!(n.is_finite() && n >= 0.0, "'temperature' must be >= 0");
                spec.temperature = n as f32;
            }
            ("deadline_ms", JsonVal::Num(n)) => {
                spec.deadline_ms = Some(usize_field("deadline_ms", n, 86_400_000.0)? as u64);
            }
            ("tenant", JsonVal::Str(s)) => spec.tenant = s,
            ("priority", JsonVal::Str(s)) => {
                spec.priority = Priority::parse(&s)
                    .with_context(|| format!("'priority' must be \"high\" or \"normal\", got \"{s}\""))?;
            }
            (k, _) => anyhow::bail!("unknown or mistyped field '{k}'"),
        }
    }
    anyhow::ensure!(have_prompt, "missing required field 'prompt'");
    Ok(spec)
}

/// Boundary validation of a parsed request against the serving model
/// (DESIGN.md §17): every rejection names the offending field, so the 400
/// body tells the caller exactly what to fix. Shapes rejected here would
/// otherwise resolve as degenerate zero-token generations (empty prompt,
/// `max_new == 0`) or be silently truncated (prompt at or beyond the
/// context, which leaves no room to generate).
fn validate_generate(spec: &GenSpec, model_ctx: usize) -> Result<()> {
    anyhow::ensure!(!spec.prompt.is_empty(), "invalid field 'prompt': must be non-empty");
    anyhow::ensure!(spec.max_new > 0, "invalid field 'max_new': must be at least 1");
    anyhow::ensure!(
        spec.prompt.len() < model_ctx,
        "invalid field 'prompt': {} tokens do not fit the model context \
         ({model_ctx} positions, one reserved for generation)",
        spec.prompt.len(),
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_a_full_generate_body() {
        let body = br#"{"prompt": "say \"hi\"", "max_new": 8, "temperature": 0.5,
                        "deadline_ms": 250, "tenant": "acme", "priority": "high"}"#;
        let spec = parse_generate(body).unwrap();
        assert_eq!(spec.prompt, b"say \"hi\"");
        assert_eq!(spec.max_new, 8);
        assert!((spec.temperature - 0.5).abs() < 1e-6);
        assert_eq!(spec.deadline_ms, Some(250));
        assert_eq!(spec.tenant, "acme");
        assert_eq!(spec.priority, Priority::High);
    }

    #[test]
    fn generate_body_defaults_and_rejections() {
        let spec = parse_generate(br#"{"prompt":"x"}"#).unwrap();
        assert_eq!(spec.max_new, 16);
        assert_eq!(spec.temperature, 0.0);
        assert_eq!(spec.tenant, "");
        assert_eq!(spec.priority, Priority::Normal);
        assert!(spec.deadline_ms.is_none());
        assert!(parse_generate(b"{}").is_err(), "prompt is required");
        assert!(parse_generate(br#"{"prompt":"x","max_new":-1}"#).is_err());
        assert!(parse_generate(br#"{"prompt":"x","max_new":1.5}"#).is_err());
        assert!(parse_generate(br#"{"prompt":"x","priority":"urgent"}"#).is_err());
        assert!(parse_generate(br#"{"prompt":"x","bogus":1}"#).is_err());
        assert!(parse_generate(br#"{"prompt":["x"]}"#).is_err(), "no nested values");
        assert!(parse_generate(b"not json").is_err());
    }

    #[test]
    fn boundary_validation_names_the_offending_field() {
        let ok = |body: &[u8]| parse_generate(body).unwrap();
        assert!(validate_generate(&ok(br#"{"prompt":"hello"}"#), 64).is_ok());

        let e = validate_generate(&ok(br#"{"prompt":""}"#), 64).unwrap_err();
        assert!(e.to_string().contains("'prompt'"), "{e}");
        assert!(e.to_string().contains("non-empty"), "{e}");

        let e = validate_generate(&ok(br#"{"prompt":"x","max_new":0}"#), 64).unwrap_err();
        assert!(e.to_string().contains("'max_new'"), "{e}");

        let long = format!("{{\"prompt\":{}}}", json_quote(&"p".repeat(64)));
        let e = validate_generate(&ok(long.as_bytes()), 64).unwrap_err();
        assert!(e.to_string().contains("'prompt'"), "{e}");
        assert!(e.to_string().contains("context"), "{e}");
        // one position is reserved for generation: ctx - 1 still fits
        let fits = format!("{{\"prompt\":{}}}", json_quote(&"p".repeat(63)));
        assert!(validate_generate(&ok(fits.as_bytes()), 64).is_ok());
    }

    #[test]
    fn json_quote_roundtrips_through_the_parser() {
        let nasty = "a\"b\\c\nd\te\u{1}f";
        let body = format!("{{\"prompt\":{}}}", json_quote(nasty));
        let spec = parse_generate(body.as_bytes()).unwrap();
        assert_eq!(spec.prompt, nasty.as_bytes());
    }

    #[test]
    fn sse_roundtrip_decodes_tokens_in_order() {
        let body = "data: {\"token\":7}\n\ndata: {\"token\":255}\n\n\
                    event: usage\ndata: {\"tokens\":2,\"finish\":\"done\"}\n\n";
        let events = parse_sse(body);
        assert_eq!(events.len(), 3);
        assert_eq!(sse_tokens(&events), vec![7, 255]);
        assert_eq!(events[2].event, "usage");
        assert!(events[2].data.contains("\"finish\":\"done\""));
    }

    #[test]
    fn gate_sheds_over_total_and_tenant_budgets() {
        let cfg = IngressConfig {
            max_in_flight: 3,
            tenant_in_flight_cap: 2,
            ..IngressConfig::default()
        };
        let gate = AdmissionGate::new(&cfg, 1);
        assert!(gate.try_admit("a").is_ok());
        assert!(gate.try_admit("a").is_ok());
        // tenant cap hits before the total cap
        assert!(gate.try_admit("a").is_err());
        assert!(gate.try_admit("b").is_ok());
        // now the total cap bites for everyone
        assert!(gate.try_admit("b").is_err());
        assert_eq!(gate.tenant_counters("a"), (2, 1));
        assert_eq!(gate.tenant_counters("b"), (1, 1));
        assert_eq!(gate.shed_total(), 2);
        // completions reopen the gate
        gate.complete("a", Some(Duration::from_millis(10)));
        assert!(gate.try_admit("b").is_ok());
    }

    #[test]
    fn gate_sheds_on_estimated_wait_and_recovers() {
        let cfg = IngressConfig {
            max_in_flight: 0,
            queue_wait_budget: Duration::from_millis(50),
            ..IngressConfig::default()
        };
        let gate = AdmissionGate::new(&cfg, 1);
        // no service-time samples yet: estimate is 0, everything admits
        for _ in 0..4 {
            assert!(gate.try_admit("t").is_ok());
        }
        // a slow completion teaches the estimator; 3 still in flight over
        // 1 slot → est wait = 2 × 100ms > 50ms budget
        gate.complete("t", Some(Duration::from_millis(100)));
        assert!(gate.try_admit("t").is_err());
        // drain the queue: estimate falls back under budget
        gate.complete("t", None);
        gate.complete("t", None);
        assert!(gate.try_admit("t").is_ok());
    }

    #[test]
    fn gate_prometheus_lines_are_labelled_and_escaped() {
        let gate = AdmissionGate::new(&IngressConfig::default(), 2);
        gate.try_admit("plain").unwrap();
        gate.try_admit("we\"ird\\t").unwrap();
        let text = gate.prometheus_text();
        assert!(text.contains("pallas_tenant_admitted_total{tenant=\"plain\"} 1"));
        assert!(text.contains("tenant=\"we\\\"ird\\\\t\""));
        assert!(text.contains("pallas_ingress_in_flight 2"));
        assert!(text.contains("# TYPE pallas_tenant_shed_total counter"));
    }
}
