//! Fault taxonomy and deterministic fault injection for the serving stack.
//!
//! DESIGN.md §17: a panic or error inside one slot's step must fail *that
//! request only* — every other in-flight request's tokens and per-request
//! metrics stay bit-identical to a fault-free run (§12 determinism extended
//! to the failure domain). This module supplies the two halves of that
//! contract:
//!
//! * **Supervision** — [`run_supervised`] wraps one slot's step in
//!   `catch_unwind` and converts a panic or `Err` into a typed [`Fault`]
//!   carrying its (node, slot) coordinate, so the scheduler can finish the
//!   affected request as `FinishReason::Faulted`, quarantine the slot's KV
//!   state, and keep serving.
//! * **Injection** — [`FaultPlan`] triggers exactly one synthetic fault at
//!   an exact (node, slot, step) coordinate, either as a real `panic!`
//!   (exercising the unwind path) or as an injected corruption error. The
//!   plan is deterministic: the same plan against the same request set
//!   fires at the same scheduler step every run, which is what lets the
//!   chaos suite (`tests/fault_tolerance.rs`) compare a faulted run
//!   token-for-token against a fault-free one.

use std::fmt;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};

use anyhow::{bail, Result};

/// What kind of failure a supervised step produced. The spelling of
/// [`FaultKind::as_str`] is the `kind` label on the
/// `pallas_faults_total{kind,node}` Prometheus counter.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// The step panicked and was caught by the supervisor.
    StepPanic,
    /// The step returned an error (including injected corruption).
    StepError,
}

impl FaultKind {
    /// Metric-label spelling.
    pub fn as_str(self) -> &'static str {
        match self {
            FaultKind::StepPanic => "panic",
            FaultKind::StepError => "error",
        }
    }
}

/// One supervised per-request failure: what happened, and at which
/// (node, slot) coordinate. Produced by [`run_supervised`]; consumed by the
/// serving loops, which finish the affected request as `Faulted`, bump
/// `pallas_faults_total{kind,node}`, and reset the slot's KV state on every
/// node before the slot is reused (the quarantine/rebuild step).
#[derive(Clone, Debug)]
pub struct Fault {
    /// Failure class (metric label).
    pub kind: FaultKind,
    /// Shard node the failure surfaced on (0 on the single-node backend).
    pub node: usize,
    /// Slot index of the affected request.
    pub slot: usize,
    /// Human-readable detail (panic payload or error chain).
    pub detail: String,
}

impl Fault {
    /// A caught panic at (node, slot).
    pub fn step_panic(node: usize, slot: usize, detail: impl Into<String>) -> Self {
        Fault { kind: FaultKind::StepPanic, node, slot, detail: detail.into() }
    }

    /// A step error at (node, slot).
    pub fn step_error(node: usize, slot: usize, err: &anyhow::Error) -> Self {
        Fault { kind: FaultKind::StepError, node, slot, detail: format!("{err:#}") }
    }
}

impl fmt::Display for Fault {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "step {} on node {} slot {}: {}",
            self.kind.as_str(),
            self.node,
            self.slot,
            self.detail
        )
    }
}

/// How an armed [`FaultPlan`] manifests when its coordinate is hit.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultMode {
    /// `panic!` inside the supervised step — exercises the full
    /// catch_unwind path (caught as [`FaultKind::StepPanic`]).
    Panic,
    /// Return an injected-corruption error from the supervised step
    /// (caught as [`FaultKind::StepError`]).
    Corrupt,
}

impl FaultMode {
    fn as_str(self) -> &'static str {
        match self {
            FaultMode::Panic => "panic",
            FaultMode::Corrupt => "corrupt",
        }
    }
}

/// A deterministic one-shot fault-injection plan: fire `mode` the first
/// time step `step` of the request occupying slot `slot` runs on shard
/// node `node`.
///
/// * `step` counts *completed scheduler steps* of the occupying request
///   when the faulty step begins — i.e. `step = 0` is the request's first
///   prefill chunk, and a request with `p` prefill chunks decodes at steps
///   `p, p+1, …`. For the parity guarantee of the chaos suite, pick a step
///   at which the KV codecs are already frozen (any `step >= 1` single
///   node, `step >= 2` sharded): while codecs are still seeding, the loops
///   step sequentially and the supervisor attributes the whole chain to
///   the armed node.
/// * The plan fires **once per server lifetime** (an internal latch flips
///   on the first coordinate match), so a quarantined-and-reused slot is
///   not re-faulted.
///
/// Wire format (the `PALLAS_FAULT` environment variable and
/// [`FaultPlan::parse`]): `<mode>@node=<N>,slot=<S>,step=<K>` with mode
/// `panic` or `corrupt`, e.g. `PALLAS_FAULT=panic@node=1,slot=0,step=3`.
/// Threaded through `ServerBuilder::fault`; the env var is the default
/// when the builder knob is unset.
#[derive(Debug)]
pub struct FaultPlan {
    /// How the fault manifests.
    pub mode: FaultMode,
    /// Target shard node (0 on the single-node backend).
    pub node: usize,
    /// Target slot index.
    pub slot: usize,
    /// Target scheduler step of the occupying request (see type docs).
    pub step: u64,
    fired: AtomicBool,
}

impl FaultPlan {
    /// A plan that fires `mode` at (node, slot, step).
    pub fn new(mode: FaultMode, node: usize, slot: usize, step: u64) -> Self {
        FaultPlan { mode, node, slot, step, fired: AtomicBool::new(false) }
    }

    /// Parse the `PALLAS_FAULT` wire form
    /// (`panic@node=N,slot=S,step=K` / `corrupt@...`).
    pub fn parse(s: &str) -> Result<FaultPlan> {
        let (mode, rest) = s
            .split_once('@')
            .ok_or_else(|| anyhow::anyhow!("fault plan '{s}': expected '<mode>@<coords>'"))?;
        let mode = match mode {
            "panic" => FaultMode::Panic,
            "corrupt" => FaultMode::Corrupt,
            other => bail!("fault plan '{s}': unknown mode '{other}' (panic|corrupt)"),
        };
        let (mut node, mut slot, mut step) = (None, None, None);
        for part in rest.split(',') {
            let (key, val) = part
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("fault plan '{s}': bad coordinate '{part}'"))?;
            let n: u64 = val
                .parse()
                .map_err(|_| anyhow::anyhow!("fault plan '{s}': '{key}' is not an integer"))?;
            match key {
                "node" => node = Some(n as usize),
                "slot" => slot = Some(n as usize),
                "step" => step = Some(n),
                other => bail!("fault plan '{s}': unknown coordinate '{other}'"),
            }
        }
        match (node, slot, step) {
            (Some(node), Some(slot), Some(step)) => Ok(FaultPlan::new(mode, node, slot, step)),
            _ => bail!("fault plan '{s}': needs node=, slot= and step="),
        }
    }

    /// Atomically consume the plan if `(node, slot, step)` is its target
    /// coordinate. Returns the mode to inject exactly once; `None` on a
    /// coordinate miss or if the plan already fired.
    pub fn fire(&self, node: usize, slot: usize, step: u64) -> Option<FaultMode> {
        if node != self.node || slot != self.slot || step != self.step {
            return None;
        }
        self.fired
            .compare_exchange(false, true, Ordering::SeqCst, Ordering::SeqCst)
            .is_ok()
            .then_some(self.mode)
    }

    /// Whether the plan has fired.
    pub fn has_fired(&self) -> bool {
        self.fired.load(Ordering::SeqCst)
    }
}

impl Clone for FaultPlan {
    fn clone(&self) -> Self {
        FaultPlan {
            mode: self.mode,
            node: self.node,
            slot: self.slot,
            step: self.step,
            fired: AtomicBool::new(self.fired.load(Ordering::SeqCst)),
        }
    }
}

impl fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}@node={},slot={},step={}", self.mode.as_str(), self.node, self.slot, self.step)
    }
}

/// Render a `catch_unwind` payload as text (`&str` / `String` payloads,
/// which is what `panic!` produces; anything else is opaque).
pub fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".to_string()
    }
}

/// Supervise one slot step attributed to `(node, slot)`: optionally inject
/// `injected` first (so the injection exercises the same catch path a real
/// failure would), then run `f` under `catch_unwind`, converting a panic
/// into [`FaultKind::StepPanic`] and an `Err` into [`FaultKind::StepError`].
///
/// Note: the process-global panic hook still prints the payload of a caught
/// panic to stderr before unwinding reaches us — cosmetic under injection,
/// and genuinely useful signal for real faults — so it is left installed.
pub fn run_supervised<T>(
    node: usize,
    slot: usize,
    injected: Option<FaultMode>,
    f: impl FnOnce() -> Result<T>,
) -> std::result::Result<T, Fault> {
    let out = catch_unwind(AssertUnwindSafe(|| -> Result<T> {
        if let Some(mode) = injected {
            match mode {
                FaultMode::Panic => panic!("injected fault: node {node} slot {slot}"),
                FaultMode::Corrupt => bail!("injected corruption: node {node} slot {slot}"),
            }
        }
        f()
    }));
    match out {
        Ok(Ok(v)) => Ok(v),
        Ok(Err(e)) => Err(Fault::step_error(node, slot, &e)),
        Err(payload) => Err(Fault::step_panic(node, slot, panic_message(payload.as_ref()))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_parses_both_modes_and_round_trips() {
        let p = FaultPlan::parse("panic@node=1,slot=0,step=3").unwrap();
        assert_eq!((p.mode, p.node, p.slot, p.step), (FaultMode::Panic, 1, 0, 3));
        assert_eq!(p.to_string(), "panic@node=1,slot=0,step=3");
        let c = FaultPlan::parse("corrupt@node=0,slot=2,step=7").unwrap();
        assert_eq!((c.mode, c.node, c.slot, c.step), (FaultMode::Corrupt, 0, 2, 7));
        assert_eq!(FaultPlan::parse(&c.to_string()).unwrap().slot, 2);
    }

    #[test]
    fn plan_rejects_malformed_specs() {
        for bad in [
            "panic",
            "explode@node=0,slot=0,step=0",
            "panic@node=0,slot=0",
            "panic@node=x,slot=0,step=0",
            "panic@node=0,slot=0,step=0,extra=1",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "{bad:?} should be rejected");
        }
    }

    #[test]
    fn plan_fires_exactly_once_at_its_coordinate() {
        let p = FaultPlan::new(FaultMode::Panic, 1, 2, 5);
        assert_eq!(p.fire(0, 2, 5), None, "node miss");
        assert_eq!(p.fire(1, 0, 5), None, "slot miss");
        assert_eq!(p.fire(1, 2, 4), None, "step miss");
        assert!(!p.has_fired());
        assert_eq!(p.fire(1, 2, 5), Some(FaultMode::Panic));
        assert!(p.has_fired());
        assert_eq!(p.fire(1, 2, 5), None, "one-shot");
    }

    #[test]
    fn supervision_converts_panics_and_errors_into_faults() {
        let ok = run_supervised(0, 0, None, || Ok(41));
        assert_eq!(ok.unwrap(), 41);

        let err = run_supervised(2, 1, None, || -> Result<()> { bail!("bad block") });
        let f = err.unwrap_err();
        assert_eq!((f.kind, f.node, f.slot), (FaultKind::StepError, 2, 1));
        assert!(f.detail.contains("bad block"));

        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| {})); // silence the expected panic
        let caught = run_supervised(1, 3, None, || -> Result<()> { panic!("kaboom") });
        let injected = run_supervised(0, 4, Some(FaultMode::Panic), || Ok(()));
        std::panic::set_hook(prev);

        let f = caught.unwrap_err();
        assert_eq!((f.kind, f.node, f.slot), (FaultKind::StepPanic, 1, 3));
        assert!(f.detail.contains("kaboom"));
        let f = injected.unwrap_err();
        assert_eq!(f.kind, FaultKind::StepPanic);
        assert!(f.detail.contains("injected fault"));

        let f = run_supervised(0, 5, Some(FaultMode::Corrupt), || Ok(())).unwrap_err();
        assert_eq!(f.kind, FaultKind::StepError);
        assert!(f.detail.contains("injected corruption"));
    }
}
