//! Token-id-keyed prefix trie mapping prompt prefixes to shared KV page
//! chains — the admission-time half of cross-request prefix sharing
//! (DESIGN.md §13).
//!
//! Each trie edge is one **full page** of token ids (`page_size` tokens);
//! a node owns the `Arc<`[`KvPage`]`>` holding that block's K/V rows. At
//! admission [`PrefixCache::lookup`] walks the prompt's page-aligned blocks
//! as deep as the trie matches and hands back the chain of shared pages, so
//! the serving loop attaches them ([`crate::model::PagedKvCache::attach`])
//! and prefills only the cold suffix. After a prompt's prefill completes,
//! [`PrefixCache::publish`] inserts its full pages so later requests over
//! the same prefix find them.
//!
//! ```text
//!   admission(prompt) ── split into page-sized token blocks ──┐
//!                                                             ▼
//!        roots ──[b0]──▶ node(page₀) ──[b1]──▶ node(page₁) ──[b2]─▶ ∅
//!                        │ match           │ match           miss
//!                        ▼                 ▼
//!                attach page₀        attach page₁        prefill b2.. cold
//! ```
//!
//! Correctness guardrails:
//!
//! * **Whole pages only** — a partially-filled tail page could still be
//!   written by its owner, so only completely full pages are published or
//!   attached (and at most `(prompt_len − 1) / page_size` pages are looked
//!   up: at least one prompt token must run through the model to produce
//!   the first-token logits).
//! * **Publication is idempotent-first** — re-publishing a block keeps the
//!   existing node, so every earlier request that attached it keeps sharing
//!   the same allocation.
//! * **Coordinator-thread only** — lookup, publish and eviction run between
//!   the serving loop's parallel sections, which is what keeps pool
//!   counters and refcount transitions deterministic at every thread count
//!   (DESIGN.md §12/§13).
//! * **Eviction skips pinned pages** — a page some live chain still holds
//!   (`Arc` refcount > 1) is never dropped from the trie; the LRU victim is
//!   always a leaf, so chains evict deepest-first.
//! * **Codec-agnostic** — the trie shares `Arc<KvPage>`s, not row layouts:
//!   under a quantized pool (DESIGN.md §15) a published page carries its
//!   packed code words alongside the decoded tile, so every request that
//!   attaches a hot prefix shares the *quantized* page — same codes, same
//!   decoded rows, same accounting — with no re-quantization on attach.

use std::collections::HashMap;
use std::sync::Arc;

use crate::model::{KvPage, KvPool};

struct Node {
    page: Arc<KvPage>,
    /// Logical clock of the last lookup/publish that touched this node.
    last_used: u64,
    /// Insertion tiebreak — makes LRU victim selection a unique minimum
    /// (HashMap iteration order never leaks into eviction decisions).
    seq: u64,
    children: HashMap<Box<[i32]>, Node>,
}

/// Snapshot of a [`PrefixCache`]'s counters — the serving loop folds deltas
/// of these into [`crate::coordinator::Metrics`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PrefixStats {
    pub hits: u64,
    pub misses: u64,
    pub tokens_reused: u64,
    pub pages_published: u64,
    pub pages_evicted: u64,
}

/// Prompt-prefix → shared-page-chain trie, one per paged server. See the
/// module docs for the admission diagram and guardrails.
pub struct PrefixCache {
    page_size: usize,
    /// Resident-page cap; eviction trims LRU leaves down to this.
    max_pages: usize,
    roots: HashMap<Box<[i32]>, Node>,
    resident: usize,
    tick: u64,
    next_seq: u64,
    /// Lookups that attached at least one page.
    pub hits: u64,
    /// Lookups that attached nothing.
    pub misses: u64,
    /// Prompt tokens served from shared pages instead of prefill.
    pub tokens_reused: u64,
    /// Pages inserted by [`Self::publish`].
    pub pages_published: u64,
    /// Pages dropped by the LRU cap (unpinned leaves only).
    pub pages_evicted: u64,
}

impl PrefixCache {
    /// An empty trie for pages of `page_size` tokens, capped at `max_pages`
    /// resident pages (clamped to at least 1).
    pub fn new(page_size: usize, max_pages: usize) -> Self {
        PrefixCache {
            page_size: page_size.max(1),
            max_pages: max_pages.max(1),
            roots: HashMap::new(),
            resident: 0,
            tick: 0,
            next_seq: 0,
            hits: 0,
            misses: 0,
            tokens_reused: 0,
            pages_published: 0,
            pages_evicted: 0,
        }
    }

    /// Pages currently held by the trie.
    pub fn resident_pages(&self) -> usize {
        self.resident
    }

    /// Snapshot of the counters.
    pub fn stats(&self) -> PrefixStats {
        PrefixStats {
            hits: self.hits,
            misses: self.misses,
            tokens_reused: self.tokens_reused,
            pages_published: self.pages_published,
            pages_evicted: self.pages_evicted,
        }
    }

    /// Walk `prompt`'s page-aligned blocks down the trie and return the
    /// matched chain plus the number of prompt tokens it covers (a multiple
    /// of the page size, at most `prompt.len() - 1` rounded down to whole
    /// pages — the cold suffix is never empty).
    pub fn lookup(&mut self, prompt: &[i32]) -> (Vec<Arc<KvPage>>, usize) {
        self.tick += 1;
        let ps = self.page_size;
        let max_pages = prompt.len().saturating_sub(1) / ps;
        let mut chain = Vec::new();
        let mut map = &mut self.roots;
        for block in 0..max_pages {
            match map.get_mut(&prompt[block * ps..(block + 1) * ps]) {
                Some(node) => {
                    node.last_used = self.tick;
                    chain.push(node.page.clone());
                    map = &mut node.children;
                }
                None => break,
            }
        }
        let covered = chain.len() * ps;
        if covered > 0 {
            self.hits += 1;
            self.tokens_reused += covered as u64;
        } else {
            self.misses += 1;
        }
        (chain, covered)
    }

    /// Insert the full pages of a freshly prefilled prompt. `chain` is the
    /// owning cache's page chain (`chain[i]` holds prompt tokens
    /// `i·ps .. (i+1)·ps`); only `prompt.len() / ps` whole pages are
    /// published. Existing nodes are kept (their page is already shared),
    /// then the LRU cap is enforced via `pool` accounting.
    pub fn publish(&mut self, prompt: &[i32], chain: &[Arc<KvPage>], pool: &KvPool) {
        self.tick += 1;
        let ps = self.page_size;
        let full = (prompt.len() / ps).min(chain.len());
        let tick = self.tick;
        let mut inserted = 0usize;
        let mut map = &mut self.roots;
        for block in 0..full {
            let key = &prompt[block * ps..(block + 1) * ps];
            if !map.contains_key(key) {
                self.next_seq += 1;
                map.insert(
                    key.into(),
                    Node {
                        page: chain[block].clone(),
                        last_used: tick,
                        seq: self.next_seq,
                        children: HashMap::new(),
                    },
                );
                inserted += 1;
            }
            let node = map.get_mut(key).expect("present or just inserted");
            node.last_used = tick;
            map = &mut node.children;
        }
        self.resident += inserted;
        self.pages_published += inserted as u64;
        self.enforce_cap(pool);
    }

    /// Drop LRU leaf pages until at most `max_pages` remain, skipping pages
    /// some chain still holds. Deterministic: the victim is the unique
    /// minimum of `(last_used, seq)` over unpinned leaves.
    fn enforce_cap(&mut self, pool: &KvPool) {
        while self.resident > self.max_pages {
            let mut path = Vec::new();
            let mut best: Option<(u64, u64, Vec<Box<[i32]>>)> = None;
            find_lru_leaf(&self.roots, &mut path, &mut best);
            let Some((_, _, victim)) = best else {
                break; // every leaf is pinned by a live chain
            };
            let node = remove_at(&mut self.roots, &victim);
            pool.drop_external(node.page);
            self.resident -= 1;
            self.pages_evicted += 1;
        }
    }

    /// Drop every page (pinned pages just lose the trie's ref; last-ref
    /// drops are counted by the pool). Counters survive.
    pub fn clear(&mut self, pool: &KvPool) {
        fn drop_all(map: &mut HashMap<Box<[i32]>, Node>, pool: &KvPool) {
            for (_, mut node) in map.drain() {
                drop_all(&mut node.children, pool);
                pool.drop_external(node.page);
            }
        }
        drop_all(&mut self.roots, pool);
        self.resident = 0;
    }
}

/// Depth-first scan for the least-recently-used **unpinned leaf**
/// (refcount 1 = only the trie holds it). `best` carries the minimum
/// `(last_used, seq)` and the key path to it.
fn find_lru_leaf(
    map: &HashMap<Box<[i32]>, Node>,
    path: &mut Vec<Box<[i32]>>,
    best: &mut Option<(u64, u64, Vec<Box<[i32]>>)>,
) {
    for (key, node) in map {
        path.push(key.clone());
        if node.children.is_empty() {
            if Arc::strong_count(&node.page) == 1 {
                let better = match best {
                    Some((lu, sq, _)) => (node.last_used, node.seq) < (*lu, *sq),
                    None => true,
                };
                if better {
                    *best = Some((node.last_used, node.seq, path.clone()));
                }
            }
        } else {
            find_lru_leaf(&node.children, path, best);
        }
        path.pop();
    }
}

/// Remove and return the node at `path` (must exist; must be a leaf).
fn remove_at(map: &mut HashMap<Box<[i32]>, Node>, path: &[Box<[i32]>]) -> Node {
    let (last, rest) = path.split_last().expect("non-empty victim path");
    let mut map = map;
    for key in rest {
        map = &mut map.get_mut(key).expect("victim path valid").children;
    }
    map.remove(last).expect("victim leaf present")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{GptConfig, KvPool, PagedKvCache};

    fn cfg() -> GptConfig {
        GptConfig { vocab: 256, d_model: 16, n_layer: 2, n_head: 2, d_ff: 32, ctx: 32 }
    }

    /// Prefill `cache` with `toks` via raw writes (no model needed here).
    fn feed(cache: &mut PagedKvCache, toks: &[i32]) {
        let base = cache.len();
        for (j, &t) in toks.iter().enumerate() {
            for l in 0..2 {
                cache.write_kv_at(l, base + j, &vec![t as f32; 16], &vec![-t as f32; 16]);
            }
        }
        cache.commit_block(toks);
    }

    #[test]
    fn lookup_miss_then_publish_then_hit() {
        let pool = KvPool::new(&cfg(), 4).unwrap();
        let mut trie = PrefixCache::new(4, 64);
        let prompt: Vec<i32> = (0..10).collect();

        let (chain, covered) = trie.lookup(&prompt);
        assert!(chain.is_empty());
        assert_eq!(covered, 0);
        assert_eq!(trie.misses, 1);

        let mut cache = PagedKvCache::new(&cfg(), &pool);
        feed(&mut cache, &prompt);
        trie.publish(&prompt, cache.pages(), &pool);
        assert_eq!(trie.resident_pages(), 2, "10 tokens / page 4 → 2 full pages");
        assert_eq!(trie.pages_published, 2);

        let (chain, covered) = trie.lookup(&prompt);
        assert_eq!(chain.len(), 2);
        assert_eq!(covered, 8);
        assert_eq!(trie.hits, 1);
        assert_eq!(trie.tokens_reused, 8);
        // the shared rows are the owner's rows
        assert_eq!(chain[1].k_row(0, 3), cache.k_row(0, 7));

        // a prompt that diverges in the second block shares only the first
        let mut other = prompt.clone();
        other[5] = 99;
        let (chain, covered) = trie.lookup(&other);
        assert_eq!((chain.len(), covered), (1, 4));
    }

    #[test]
    fn lookup_never_covers_the_whole_prompt() {
        let pool = KvPool::new(&cfg(), 4).unwrap();
        let mut trie = PrefixCache::new(4, 64);
        let prompt: Vec<i32> = (0..8).collect();
        let mut cache = PagedKvCache::new(&cfg(), &pool);
        feed(&mut cache, &prompt);
        trie.publish(&prompt, cache.pages(), &pool);
        assert_eq!(trie.resident_pages(), 2);
        // page-aligned prompt: both pages resident, but lookup caps at
        // (8-1)/4 = 1 page so one token still runs through the model
        let (chain, covered) = trie.lookup(&prompt);
        assert_eq!((chain.len(), covered), (1, 4));
    }

    #[test]
    fn publish_is_idempotent_and_keeps_existing_pages() {
        let pool = KvPool::new(&cfg(), 4).unwrap();
        let mut trie = PrefixCache::new(4, 64);
        let prompt: Vec<i32> = (0..8).collect();
        let mut a = PagedKvCache::new(&cfg(), &pool);
        feed(&mut a, &prompt);
        trie.publish(&prompt, a.pages(), &pool);
        let (first, _) = trie.lookup(&prompt);

        let mut b = PagedKvCache::new(&cfg(), &pool);
        feed(&mut b, &prompt);
        trie.publish(&prompt, b.pages(), &pool);
        assert_eq!(trie.resident_pages(), 2, "re-publish inserts nothing");
        let (second, _) = trie.lookup(&prompt);
        assert!(Arc::ptr_eq(&first[0], &second[0]), "existing page kept");
    }

    #[test]
    fn cap_evicts_lru_leaves_but_never_pinned_pages() {
        let pool = KvPool::new(&cfg(), 2).unwrap();
        let mut trie = PrefixCache::new(2, 2);
        let pa: Vec<i32> = vec![1, 2, 3, 4];
        let pb: Vec<i32> = vec![9, 8, 7, 6];
        let mut a = PagedKvCache::new(&cfg(), &pool);
        feed(&mut a, &pa);
        trie.publish(&pa, a.pages(), &pool);
        assert_eq!(trie.resident_pages(), 2);

        // `a` still holds its pages → both of pa's pages are pinned; pb's
        // publish overflows the cap but can only evict unpinned leaves
        let mut b = PagedKvCache::new(&cfg(), &pool);
        feed(&mut b, &pb);
        trie.publish(&pb, b.pages(), &pool);
        assert_eq!(trie.resident_pages(), 4, "all pages pinned → nothing evicted");
        assert_eq!(trie.pages_evicted, 0);

        // release the chains: now eviction can trim down to the cap, oldest
        // (pa's deepest leaf first) going first
        a.reset();
        b.reset();
        let pc: Vec<i32> = vec![5, 5, 5, 5];
        let mut c = PagedKvCache::new(&cfg(), &pool);
        feed(&mut c, &pc);
        trie.publish(&pc, c.pages(), &pool);
        c.reset();
        assert_eq!(trie.resident_pages(), 2);
        assert!(trie.pages_evicted >= 4, "trimmed to cap once unpinned");
        // evicted unshared pages return to the allocator, counted
        assert_eq!(pool.counters().dropped, trie.pages_evicted);
    }

    #[test]
    fn clear_releases_everything() {
        let pool = KvPool::new(&cfg(), 4).unwrap();
        let mut trie = PrefixCache::new(4, 64);
        let prompt: Vec<i32> = (0..12).collect();
        let mut cache = PagedKvCache::new(&cfg(), &pool);
        feed(&mut cache, &prompt);
        trie.publish(&prompt, cache.pages(), &pool);
        cache.reset();
        assert_eq!(trie.resident_pages(), 3);
        trie.clear(&pool);
        assert_eq!(trie.resident_pages(), 0);
        assert_eq!(pool.counters().dropped, 3);
        let (chain, covered) = trie.lookup(&prompt);
        assert!(chain.is_empty() && covered == 0);
    }
}
