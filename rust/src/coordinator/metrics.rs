//! Serving metrics: counters + latency percentiles (no external deps).

use std::time::Duration;

/// Accumulates request/token counters and latency samples.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub requests: u64,
    pub tokens_generated: u64,
    pub batches: u64,
    pub decode_steps: u64,
    latencies_us: Vec<u64>,
    pub wall_s: f64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_batch(&mut self, n_requests: usize, tokens: usize, steps: usize) {
        self.requests += n_requests as u64;
        self.tokens_generated += tokens as u64;
        self.batches += 1;
        self.decode_steps += steps as u64;
    }

    pub fn record_latency(&mut self, d: Duration) {
        self.latencies_us.push(d.as_micros() as u64);
    }

    /// Latency percentile in milliseconds (p in [0,100]).
    pub fn latency_ms(&self, p: f64) -> f64 {
        if self.latencies_us.is_empty() {
            return 0.0;
        }
        let mut v = self.latencies_us.clone();
        v.sort_unstable();
        let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
        v[idx.min(v.len() - 1)] as f64 / 1000.0
    }

    /// Tokens generated per wall-clock second.
    pub fn tokens_per_s(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.tokens_generated as f64 / self.wall_s
        } else {
            0.0
        }
    }

    /// Mean requests per batch (batching efficiency).
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches > 0 {
            self.requests as f64 / self.batches as f64
        } else {
            0.0
        }
    }

    pub fn summary(&self) -> String {
        format!(
            "requests={} tokens={} tok/s={:.1} batches={} mean_bs={:.2} p50={:.1}ms p95={:.1}ms",
            self.requests,
            self.tokens_generated,
            self.tokens_per_s(),
            self.batches,
            self.mean_batch_size(),
            self.latency_ms(50.0),
            self.latency_ms(95.0),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let mut m = Metrics::new();
        for i in 1..=100u64 {
            m.record_latency(Duration::from_micros(i * 1000));
        }
        assert!(m.latency_ms(50.0) <= m.latency_ms(95.0));
        assert!((m.latency_ms(100.0) - 100.0).abs() < 1.0);
    }

    #[test]
    fn counters_accumulate() {
        let mut m = Metrics::new();
        m.record_batch(3, 24, 8);
        m.record_batch(5, 40, 8);
        m.wall_s = 2.0;
        assert_eq!(m.requests, 8);
        assert_eq!(m.tokens_generated, 64);
        assert!((m.tokens_per_s() - 32.0).abs() < 1e-9);
        assert!((m.mean_batch_size() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn empty_metrics_are_safe() {
        let m = Metrics::new();
        assert_eq!(m.latency_ms(50.0), 0.0);
        assert_eq!(m.tokens_per_s(), 0.0);
        assert_eq!(m.mean_batch_size(), 0.0);
    }
}
