//! Serving metrics: counters + latency percentiles (no external deps).
//!
//! Besides end-to-end latency, the continuous-batching server records the
//! scheduler-level signals that matter for a slot-pool loop: **TTFT** (time
//! from enqueue to the first generated token — what block prefill cuts),
//! **queue wait** (enqueue → slot admission, recorded in admission order, so
//! fairness tests can pin its monotonicity), and **slot occupancy** (busy
//! slot-steps over offered slot-steps — what continuous admission raises
//! over static batches).

use std::time::Duration;

/// Latency percentile in milliseconds over µs samples (p in [0,100]).
fn percentile_ms(samples: &[u64], p: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut v = samples.to_vec();
    v.sort_unstable();
    let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
    v[idx.min(v.len() - 1)] as f64 / 1000.0
}

/// Accumulates request/token counters and latency samples.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub requests: u64,
    pub tokens_generated: u64,
    pub batches: u64,
    pub decode_steps: u64,
    latencies_us: Vec<u64>,
    pub wall_s: f64,
    ttft_us: Vec<u64>,
    queue_wait_us: Vec<u64>,
    /// Requests resolved as timed-out at admission (deadline expired).
    pub timeouts: u64,
    /// Requests rejected by admission control before reaching a slot
    /// (tenant queue over cap, or the ingress gate's 429 path).
    pub shed: u64,
    /// Scheduler steps × slots that held an active request.
    pub slot_steps_busy: u64,
    /// Scheduler steps × slots offered (busy or idle).
    pub slot_steps_total: u64,
    /// Fresh KV page buffers allocated by the paged pool
    /// ([`crate::model::KvPool`]; 0 under the dense layout).
    pub kv_pages_allocated: u64,
    /// KV page acquisitions served from a free list instead of a fresh
    /// allocation.
    pub kv_pages_reused: u64,
    /// KV page buffers returned to a free list (reset / eviction churn).
    pub kv_pages_released: u64,
    /// KV page buffers freed back to the allocator (prefix-trie eviction).
    pub kv_pages_dropped: u64,
    /// Copy-on-write page copies (0 in the serving loop by construction —
    /// writes never target attached prefix pages).
    pub kv_cow_copies: u64,
    /// Admissions that attached at least one shared prefix page.
    pub prefix_hits: u64,
    /// Admissions that found no shared prefix (paged + sharing only).
    pub prefix_misses: u64,
    /// Prompt tokens served from shared pages instead of prefill work.
    pub prefix_tokens_reused: u64,
    /// Pages inserted into the prefix trie after prompt prefill.
    pub prefix_pages_published: u64,
    /// Pages the prefix trie's LRU cap dropped.
    pub prefix_pages_evicted: u64,
    /// KV subvectors decoded through the cache codec's LUT (write-path
    /// tile decodes + explicit re-decodes; 0 with an exact cache).
    pub kv_decoded_subvecs: u64,
    /// Resident KV payload bits (packed code words under `--kv-quant`,
    /// f32 rows otherwise) — gauge, refreshed at each metrics sync.
    pub kv_cache_resident_bits: u64,
    /// Bits of the frozen per-layer cache codebooks (0 with an exact
    /// cache) — gauge.
    pub kv_cache_codebook_bits: u64,
    /// Declared cache bits per value (32.0 exact) — gauge.
    pub kv_cache_bpw: f64,
    /// TTFT samples of requests that attached shared prefix pages.
    ttft_hot_us: Vec<u64>,
    /// TTFT samples of requests that prefilled from scratch.
    ttft_cold_us: Vec<u64>,
    /// Supervised per-slot faults by `(kind, node)` — kind is the
    /// [`crate::coordinator::fault::FaultKind`] label ("panic" / "error"),
    /// node the shard node the fault surfaced on. Kept sorted by (kind,
    /// node) so exposition order is deterministic; exported as
    /// `pallas_faults_total{kind,node}` (DESIGN.md §17).
    faults: Vec<(String, usize, u64)>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_batch(&mut self, n_requests: usize, tokens: usize, steps: usize) {
        self.requests += n_requests as u64;
        self.tokens_generated += tokens as u64;
        self.batches += 1;
        self.decode_steps += steps as u64;
    }

    pub fn record_latency(&mut self, d: Duration) {
        self.latencies_us.push(d.as_micros() as u64);
    }

    /// Record one request's time-to-first-token.
    pub fn record_ttft(&mut self, d: Duration) {
        self.ttft_us.push(d.as_micros() as u64);
    }

    /// Record one request's enqueue→admission wait. Call in admission order:
    /// the sample sequence doubles as the fairness audit trail.
    pub fn record_queue_wait(&mut self, d: Duration) {
        self.queue_wait_us.push(d.as_micros() as u64);
    }

    /// Record one scheduler step of a slot pool: `busy` active slots out of
    /// `total` offered.
    pub fn record_occupancy(&mut self, busy: usize, total: usize) {
        self.slot_steps_busy += busy as u64;
        self.slot_steps_total += total as u64;
    }

    /// TTFT of a request that attached shared prefix pages (also record the
    /// sample via [`Self::record_ttft`] — the hot/cold split is an extra
    /// breakdown, not a replacement).
    pub fn record_ttft_hot(&mut self, d: Duration) {
        self.ttft_hot_us.push(d.as_micros() as u64);
    }

    /// TTFT of a request that prefilled its whole prompt from scratch.
    pub fn record_ttft_cold(&mut self, d: Duration) {
        self.ttft_cold_us.push(d.as_micros() as u64);
    }

    /// Count one supervised fault of `kind` ("panic" / "error") on shard
    /// `node`. Called from the coordinator's fold (never from workers), so
    /// the counter is thread-count-invariant like every other metric.
    pub fn record_fault(&mut self, kind: &str, node: usize) {
        match self.faults.iter_mut().find(|(k, n, _)| k == kind && *n == node) {
            Some(entry) => entry.2 += 1,
            None => {
                self.faults.push((kind.to_string(), node, 1));
                self.faults.sort_by(|a, b| (a.0.as_str(), a.1).cmp(&(b.0.as_str(), b.1)));
            }
        }
    }

    /// Per-(kind, node) fault counts, sorted by (kind, node).
    pub fn faults(&self) -> &[(String, usize, u64)] {
        &self.faults
    }

    /// Total supervised faults across kinds and nodes.
    pub fn faults_total(&self) -> u64 {
        self.faults.iter().map(|(_, _, n)| n).sum()
    }

    /// Latency percentile in milliseconds (p in [0,100]).
    pub fn latency_ms(&self, p: f64) -> f64 {
        percentile_ms(&self.latencies_us, p)
    }

    /// Time-to-first-token percentile in milliseconds.
    pub fn ttft_ms(&self, p: f64) -> f64 {
        percentile_ms(&self.ttft_us, p)
    }

    /// Queue-wait percentile in milliseconds.
    pub fn queue_wait_ms(&self, p: f64) -> f64 {
        percentile_ms(&self.queue_wait_us, p)
    }

    /// Hot-prefix (shared pages attached) TTFT percentile in milliseconds.
    pub fn ttft_hot_ms(&self, p: f64) -> f64 {
        percentile_ms(&self.ttft_hot_us, p)
    }

    /// Cold-prefix (full prefill) TTFT percentile in milliseconds.
    pub fn ttft_cold_ms(&self, p: f64) -> f64 {
        percentile_ms(&self.ttft_cold_us, p)
    }

    /// Number of hot-prefix TTFT samples recorded.
    pub fn ttft_hot_count(&self) -> usize {
        self.ttft_hot_us.len()
    }

    /// Number of cold-prefix TTFT samples recorded.
    pub fn ttft_cold_count(&self) -> usize {
        self.ttft_cold_us.len()
    }

    /// Queue-wait samples (µs) in admission order — the fairness tests
    /// assert monotonicity over this sequence.
    pub fn queue_waits_us(&self) -> &[u64] {
        &self.queue_wait_us
    }

    /// Fraction of offered slot-steps that held an active request (0 until
    /// the first continuous-serving step).
    pub fn slot_occupancy(&self) -> f64 {
        if self.slot_steps_total > 0 {
            self.slot_steps_busy as f64 / self.slot_steps_total as f64
        } else {
            0.0
        }
    }

    /// Tokens generated per wall-clock second.
    pub fn tokens_per_s(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.tokens_generated as f64 / self.wall_s
        } else {
            0.0
        }
    }

    /// Mean requests per batch (batching efficiency, static path).
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches > 0 {
            self.requests as f64 / self.batches as f64
        } else {
            0.0
        }
    }

    pub fn summary(&self) -> String {
        let mut s = format!(
            "requests={} tokens={} tok/s={:.1} batches={} mean_bs={:.2} p50={:.1}ms p95={:.1}ms",
            self.requests,
            self.tokens_generated,
            self.tokens_per_s(),
            self.batches,
            self.mean_batch_size(),
            self.latency_ms(50.0),
            self.latency_ms(95.0),
        );
        if self.slot_steps_total > 0 {
            s.push_str(&format!(
                " ttft_p50={:.1}ms qwait_p50={:.1}ms occupancy={:.0}%",
                self.ttft_ms(50.0),
                self.queue_wait_ms(50.0),
                self.slot_occupancy() * 100.0,
            ));
        }
        if self.kv_pages_allocated > 0 {
            s.push_str(&format!(
                " kv_pages={} (reused={} released={} dropped={})",
                self.kv_pages_allocated,
                self.kv_pages_reused,
                self.kv_pages_released,
                self.kv_pages_dropped,
            ));
        }
        if self.prefix_hits + self.prefix_misses > 0 {
            s.push_str(&format!(
                " prefix_hits={}/{} reuse_toks={} ttft_hot_p50={:.1}ms ttft_cold_p50={:.1}ms",
                self.prefix_hits,
                self.prefix_hits + self.prefix_misses,
                self.prefix_tokens_reused,
                self.ttft_hot_ms(50.0),
                self.ttft_cold_ms(50.0),
            ));
        }
        if self.kv_cache_bpw > 0.0 && self.kv_cache_bpw < 32.0 {
            s.push_str(&format!(
                " kv_bpw={:.1} kv_bits={} kv_cb_bits={} kv_decoded={}",
                self.kv_cache_bpw,
                self.kv_cache_resident_bits,
                self.kv_cache_codebook_bits,
                self.kv_decoded_subvecs,
            ));
        }
        if self.timeouts > 0 {
            s.push_str(&format!(" timeouts={}", self.timeouts));
        }
        if self.shed > 0 {
            s.push_str(&format!(" shed={}", self.shed));
        }
        if self.faults_total() > 0 {
            s.push_str(&format!(" faults={}", self.faults_total()));
        }
        s
    }

    /// Render the metrics in the Prometheus text exposition format
    /// (version 0.0.4): monotone `*_total` counters for every event
    /// counter, gauges for rates/ratios, and `{quantile="…"}`-labelled
    /// gauges for the latency distributions. Scraped by `GET /metrics` on
    /// [`crate::coordinator::ingress`], which appends its own per-tenant
    /// admission counters after this block.
    pub fn prometheus_text(&self) -> String {
        let mut out = String::new();
        let counters: [(&str, &str, u64); 17] = [
            ("pallas_requests_total", "Requests resolved (all finish reasons)", self.requests),
            ("pallas_tokens_generated_total", "Tokens generated", self.tokens_generated),
            ("pallas_batches_total", "Static-path batches executed", self.batches),
            ("pallas_decode_steps_total", "Scheduler decode steps", self.decode_steps),
            ("pallas_timeouts_total", "Requests expired before admission", self.timeouts),
            ("pallas_shed_total", "Requests rejected by admission control", self.shed),
            ("pallas_slot_steps_busy_total", "Slot-steps holding an active request", self.slot_steps_busy),
            ("pallas_slot_steps_offered_total", "Slot-steps offered (busy or idle)", self.slot_steps_total),
            ("pallas_kv_pages_allocated_total", "Fresh KV pages allocated", self.kv_pages_allocated),
            ("pallas_kv_pages_reused_total", "KV pages served from a free list", self.kv_pages_reused),
            ("pallas_kv_pages_released_total", "KV pages returned to a free list", self.kv_pages_released),
            ("pallas_kv_pages_dropped_total", "KV pages freed to the allocator", self.kv_pages_dropped),
            ("pallas_kv_cow_copies_total", "Copy-on-write KV page copies", self.kv_cow_copies),
            ("pallas_prefix_hits_total", "Admissions that attached shared prefix pages", self.prefix_hits),
            ("pallas_prefix_misses_total", "Admissions with no shared prefix", self.prefix_misses),
            ("pallas_prefix_tokens_reused_total", "Prompt tokens served from shared pages", self.prefix_tokens_reused),
            ("pallas_kv_decoded_subvecs_total", "KV subvectors decoded through the cache LUT", self.kv_decoded_subvecs),
        ];
        for (name, help, v) in counters {
            out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} counter\n{name} {v}\n"));
        }
        out.push_str(
            "# HELP pallas_faults_total Supervised per-slot step faults by kind and shard node\n\
             # TYPE pallas_faults_total counter\n",
        );
        for (kind, node, count) in &self.faults {
            out.push_str(&format!(
                "pallas_faults_total{{kind=\"{kind}\",node=\"{node}\"}} {count}\n"
            ));
        }
        let gauges: [(&str, &str, f64); 5] = [
            ("pallas_slot_occupancy", "Busy fraction of offered slot-steps", self.slot_occupancy()),
            ("pallas_tokens_per_second", "Generated tokens per wall-clock second", self.tokens_per_s()),
            ("pallas_kv_cache_resident_bits", "Resident KV payload bits", self.kv_cache_resident_bits as f64),
            ("pallas_kv_cache_codebook_bits", "Frozen cache codebook bits", self.kv_cache_codebook_bits as f64),
            ("pallas_kv_cache_bpw", "Declared cache bits per value (32 = exact)", self.kv_cache_bpw),
        ];
        for (name, help, v) in gauges {
            out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} gauge\n{name} {v}\n"));
        }
        let quantiles: [(&str, &str, &dyn Fn(f64) -> f64); 5] = [
            ("pallas_latency_ms", "End-to-end request latency (ms)", &|p| self.latency_ms(p)),
            ("pallas_ttft_ms", "Time to first token (ms)", &|p| self.ttft_ms(p)),
            ("pallas_ttft_hot_ms", "TTFT with shared prefix pages attached (ms)", &|p| {
                self.ttft_hot_ms(p)
            }),
            ("pallas_ttft_cold_ms", "TTFT with full prompt prefill (ms)", &|p| {
                self.ttft_cold_ms(p)
            }),
            ("pallas_queue_wait_ms", "Enqueue-to-admission wait (ms)", &|p| {
                self.queue_wait_ms(p)
            }),
        ];
        for (name, help, f) in quantiles {
            out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} gauge\n"));
            for (label, p) in [("0.5", 50.0), ("0.99", 99.0)] {
                out.push_str(&format!("{name}{{quantile=\"{label}\"}} {}\n", f(p)));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let mut m = Metrics::new();
        for i in 1..=100u64 {
            m.record_latency(Duration::from_micros(i * 1000));
        }
        assert!(m.latency_ms(50.0) <= m.latency_ms(95.0));
        assert!((m.latency_ms(100.0) - 100.0).abs() < 1.0);
    }

    #[test]
    fn counters_accumulate() {
        let mut m = Metrics::new();
        m.record_batch(3, 24, 8);
        m.record_batch(5, 40, 8);
        m.wall_s = 2.0;
        assert_eq!(m.requests, 8);
        assert_eq!(m.tokens_generated, 64);
        assert!((m.tokens_per_s() - 32.0).abs() < 1e-9);
        assert!((m.mean_batch_size() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn empty_metrics_are_safe() {
        let m = Metrics::new();
        assert_eq!(m.latency_ms(50.0), 0.0);
        assert_eq!(m.tokens_per_s(), 0.0);
        assert_eq!(m.mean_batch_size(), 0.0);
        assert_eq!(m.ttft_ms(50.0), 0.0);
        assert_eq!(m.queue_wait_ms(50.0), 0.0);
        assert_eq!(m.slot_occupancy(), 0.0);
    }

    /// Slots advancing from multiple pool workers must leave TTFT /
    /// queue-wait / occupancy counters race-free and monotone. The serving
    /// loop's rule is: workers only *produce* per-slot outcomes (they never
    /// touch `Metrics`), and the coordinator folds outcomes in in slot
    /// order after the join — so the counters are identical at every thread
    /// count by construction. This test forces true overlap with a
    /// [`Barrier`] across the pool's strips (loom-free) and asserts the
    /// invariants hold and the serialized metrics match the 1-thread run.
    #[test]
    fn counters_deterministic_under_parallel_slot_workers() {
        use std::sync::Barrier;

        let n_slots = 8usize;
        let steps = 4u64;
        let run = |threads: usize| -> Metrics {
            let mut m = Metrics::new();
            let pool = crate::exec::Pool::new(threads);
            for step in 0..steps {
                // every worker strip parks at the barrier before producing
                // its outcomes: all workers are live simultaneously
                let strips = crate::exec::partition(n_slots, threads.clamp(1, n_slots));
                let barrier = Barrier::new(strips.len());
                let outcomes: Vec<Vec<(u64, u64)>> =
                    pool.run_strips(n_slots, 1, |_, range| {
                        barrier.wait();
                        range
                            .map(|s| {
                                let s = s as u64;
                                // (queue-wait µs, ttft µs) for slot s: waits
                                // grow with slot index = admission order
                                (s * 100 + step, s * 1000 + step * 10)
                            })
                            .collect()
                    });
                // fold on the coordinator thread, in slot order
                let mut busy = 0usize;
                for (wait_us, ttft_us) in outcomes.into_iter().flatten() {
                    m.record_queue_wait(Duration::from_micros(wait_us));
                    m.record_ttft(Duration::from_micros(ttft_us));
                    busy += 1;
                }
                m.record_occupancy(busy, n_slots);
            }
            m
        };

        let serial = run(1);
        for threads in [2usize, 4, 7] {
            let par = run(threads);
            assert_eq!(
                par.queue_waits_us(),
                serial.queue_waits_us(),
                "threads={threads}: queue-wait sequence diverged"
            );
            assert_eq!(par.ttft_ms(50.0), serial.ttft_ms(50.0), "threads={threads}");
            assert_eq!(par.ttft_ms(95.0), serial.ttft_ms(95.0), "threads={threads}");
            assert_eq!(par.slot_steps_busy, serial.slot_steps_busy);
            assert_eq!(par.slot_steps_total, serial.slot_steps_total);
            assert_eq!(par.summary(), serial.summary(), "threads={threads}");
            // within each step the waits are monotone in slot (= admission)
            // order — the fairness audit trail survives the fan-out
            for chunk in par.queue_waits_us().chunks(n_slots) {
                for w in chunk.windows(2) {
                    assert!(w[1] >= w[0], "waits not monotone: {chunk:?}");
                }
            }
            assert_eq!(par.slot_occupancy(), 1.0);
        }
    }

    #[test]
    fn paged_kv_signals() {
        let mut m = Metrics::new();
        // dense serving: paged sections stay out of the summary entirely
        assert!(!m.summary().contains("kv_pages"));
        assert!(!m.summary().contains("prefix_hits"));
        m.kv_pages_allocated = 6;
        m.kv_pages_reused = 10;
        m.prefix_hits = 3;
        m.prefix_misses = 1;
        m.prefix_tokens_reused = 96;
        m.record_ttft(Duration::from_millis(2));
        m.record_ttft_hot(Duration::from_millis(2));
        m.record_ttft(Duration::from_millis(9));
        m.record_ttft_cold(Duration::from_millis(9));
        assert_eq!(m.ttft_hot_count(), 1);
        assert_eq!(m.ttft_cold_count(), 1);
        assert!(m.ttft_hot_ms(50.0) < m.ttft_cold_ms(50.0));
        let s = m.summary();
        assert!(s.contains("kv_pages=6"), "summary was: {s}");
        assert!(s.contains("prefix_hits=3/4"), "summary was: {s}");
        assert!(s.contains("reuse_toks=96"), "summary was: {s}");
    }

    #[test]
    fn prometheus_text_is_well_formed() {
        let mut m = Metrics::new();
        m.record_batch(2, 16, 8);
        m.shed = 3;
        m.timeouts = 1;
        m.record_ttft(Duration::from_millis(4));
        m.record_queue_wait(Duration::from_millis(1));
        m.record_occupancy(3, 4);
        m.wall_s = 0.5;
        m.record_fault("panic", 1);
        m.record_fault("panic", 1);
        m.record_fault("error", 0);
        let text = m.prometheus_text();
        assert!(text.contains("# TYPE pallas_requests_total counter"));
        assert!(text.contains("pallas_requests_total 2\n"));
        assert!(text.contains("pallas_shed_total 3\n"));
        assert!(text.contains("pallas_timeouts_total 1\n"));
        assert!(text.contains("pallas_faults_total{kind=\"error\",node=\"0\"} 1\n"));
        assert!(text.contains("pallas_faults_total{kind=\"panic\",node=\"1\"} 2\n"));
        assert!(text.contains("pallas_slot_occupancy 0.75\n"));
        assert!(text.contains("pallas_ttft_ms{quantile=\"0.5\"}"));
        // every exposition line is either a comment or `name[{labels}] value`
        for line in text.lines() {
            if line.starts_with('#') {
                continue;
            }
            let (name, value) = line.rsplit_once(' ').expect("name value");
            assert!(!name.is_empty());
            let bare = name.split('{').next().unwrap();
            assert!(
                bare.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':'),
                "bad metric name: {line}"
            );
            value.parse::<f64>().unwrap_or_else(|_| panic!("bad value in: {line}"));
        }
    }

    #[test]
    fn continuous_serving_signals() {
        let mut m = Metrics::new();
        m.record_ttft(Duration::from_millis(4));
        m.record_ttft(Duration::from_millis(8));
        m.record_queue_wait(Duration::from_millis(1));
        m.record_queue_wait(Duration::from_millis(3));
        m.record_occupancy(2, 4);
        m.record_occupancy(4, 4);
        assert!((m.ttft_ms(100.0) - 8.0).abs() < 0.5);
        assert_eq!(m.queue_waits_us(), &[1000, 3000]);
        assert!((m.slot_occupancy() - 0.75).abs() < 1e-9);
        let s = m.summary();
        assert!(s.contains("occupancy=75%"), "summary was: {s}");
    }
}
