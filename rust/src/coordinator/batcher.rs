//! Dynamic request batching.
//!
//! The serving executable has a fixed batch geometry (B=8 compiled in), so
//! the batcher's job is the classic one: coalesce the request stream into
//! batches, trading latency (`max_wait`) against utilization (`max_batch`),
//! exactly the mechanism the paper's §4.4 throughput numbers rely on.

use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::time::{Duration, Instant};

/// A generation request.
#[derive(Debug)]
pub struct GenRequest {
    /// Prompt bytes (byte-level vocab).
    pub prompt: Vec<u8>,
    /// Number of tokens to generate.
    pub max_new: usize,
    /// Sampling temperature; 0 = greedy.
    pub temperature: f32,
    /// Where the response goes.
    pub resp: Sender<GenResponse>,
    /// Enqueue timestamp (for latency accounting).
    pub enqueued: Instant,
}

/// A finished generation.
#[derive(Clone, Debug)]
pub struct GenResponse {
    pub generated: Vec<u8>,
    /// Queue + compute latency.
    pub latency: Duration,
    /// Decode steps executed for this request's batch.
    pub steps: usize,
}

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Maximum requests per batch (the executable's compiled B).
    pub max_batch: usize,
    /// Maximum time the first request of a batch waits for company.
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(10) }
    }
}

/// Pulls requests off a channel and groups them into batches.
pub struct Batcher {
    rx: Receiver<GenRequest>,
    pub cfg: BatcherConfig,
}

impl Batcher {
    pub fn new(rx: Receiver<GenRequest>, cfg: BatcherConfig) -> Self {
        Batcher { rx, cfg }
    }

    /// Block for the next batch. Returns `None` when the request channel has
    /// been closed and drained (shutdown).
    pub fn next_batch(&self) -> Option<Vec<GenRequest>> {
        // Block indefinitely for the first request…
        let first = self.rx.recv().ok()?;
        let mut batch = vec![first];
        let deadline = Instant::now() + self.cfg.max_wait;
        // …then fill the batch until the deadline or capacity.
        while batch.len() < self.cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match self.rx.recv_timeout(deadline - now) {
                Ok(req) => batch.push(req),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn req(prompt: &[u8]) -> (GenRequest, Receiver<GenResponse>) {
        let (tx, rx) = channel();
        (
            GenRequest {
                prompt: prompt.to_vec(),
                max_new: 4,
                temperature: 0.0,
                resp: tx,
                enqueued: Instant::now(),
            },
            rx,
        )
    }

    #[test]
    fn batches_up_to_capacity() {
        let (tx, rx) = channel();
        let batcher = Batcher::new(
            rx,
            BatcherConfig { max_batch: 3, max_wait: Duration::from_millis(50) },
        );
        let mut keep = Vec::new();
        for _ in 0..5 {
            let (r, rx) = req(b"hi");
            tx.send(r).unwrap();
            keep.push(rx);
        }
        let b1 = batcher.next_batch().unwrap();
        assert_eq!(b1.len(), 3);
        let b2 = batcher.next_batch().unwrap();
        assert_eq!(b2.len(), 2);
    }

    #[test]
    fn respects_deadline_with_sparse_traffic() {
        let (tx, rx) = channel();
        let batcher = Batcher::new(
            rx,
            BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(5) },
        );
        let (r, _keep) = req(b"solo");
        tx.send(r).unwrap();
        let t = Instant::now();
        let b = batcher.next_batch().unwrap();
        assert_eq!(b.len(), 1);
        assert!(t.elapsed() < Duration::from_millis(200));
    }

    #[test]
    fn shutdown_returns_none() {
        let (tx, rx) = channel::<GenRequest>();
        drop(tx);
        let batcher = Batcher::new(rx, BatcherConfig::default());
        assert!(batcher.next_batch().is_none());
    }
}
