//! Request admission: the queue between clients and the serving loop.
//!
//! Two consumers share this queue. The **static** path
//! ([`crate::coordinator::Server::process_batch`], the only mode the
//! fixed-geometry XLA executables support) coalesces requests into batches
//! via [`Batcher::next_batch`], trading latency (`max_wait`) against
//! utilization (`max_batch`). The **continuous** path
//! ([`crate::coordinator::Server::serve_continuous`]) treats the batcher as
//! an admission queue: [`Batcher::poll_admit`] hands over whatever has
//! arrived — never blocking, never losing buffered arrivals — the moment a
//! slot frees, and [`Batcher::wait_any`] parks the server only when every
//! slot is idle.
//!
//! Admission is strictly FIFO in arrival order and stamps each request with
//! a monotone sequence number ([`Admitted::seq`]) — the ordering the
//! fairness tests pin. Requests carry an optional [`GenRequest::deadline`];
//! a request whose deadline passed before admission is resolved immediately
//! with [`GenResponse::timed_out`] instead of occupying a slot.
//!
//! Determinism under test: arrivals are drained into an internal buffer
//! before every poll, so whether a request is visible to a poll depends
//! only on whether it was sent before the poll — never on channel timing —
//! and [`Batcher::push`] injects requests directly, so tests drive
//! admission without sleeping. (The raw mpsc channel already never loses
//! buffered sends; the buffer is about making admission *observable and
//! injectable*, and about letting a timed-out poll hand over everything
//! that arrived during its wait window in one batch.)

use std::collections::VecDeque;
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::time::{Duration, Instant};

/// A generation request.
#[derive(Debug)]
pub struct GenRequest {
    /// Prompt bytes (byte-level vocab).
    pub prompt: Vec<u8>,
    /// Number of tokens to generate.
    pub max_new: usize,
    /// Sampling temperature; 0 = greedy.
    pub temperature: f32,
    /// Where the response goes.
    pub resp: Sender<GenResponse>,
    /// Enqueue timestamp (for latency accounting).
    pub enqueued: Instant,
    /// Admission deadline: if no slot picked the request up by this instant,
    /// it resolves immediately as [`GenResponse::timed_out`]. `None` waits
    /// forever.
    pub deadline: Option<Instant>,
}

impl GenRequest {
    /// A request enqueued now, with no admission deadline.
    pub fn new(
        prompt: Vec<u8>,
        max_new: usize,
        temperature: f32,
        resp: Sender<GenResponse>,
    ) -> Self {
        GenRequest {
            prompt,
            max_new,
            temperature,
            resp,
            enqueued: Instant::now(),
            deadline: None,
        }
    }
}

/// A finished generation.
#[derive(Clone, Debug)]
pub struct GenResponse {
    pub generated: Vec<u8>,
    /// Queue + compute latency.
    pub latency: Duration,
    /// Scheduler steps this request consumed: per-request prefill-chunk +
    /// decode steps under continuous batching; the batch's shared decode
    /// steps on the static path.
    pub steps: usize,
    /// Request placement marker. Under continuous batching (and for every
    /// timed-out response) this is the queue's monotone admission sequence
    /// number. Successful *static*-path responses instead carry their batch
    /// slot index (those requests may bypass the queue entirely via
    /// `process_batch`), so seq values are only globally orderable on the
    /// continuous path.
    pub seq: u64,
    /// Time spent queued before a slot picked the request up.
    pub queue_wait: Duration,
    /// Time from enqueue to the first generated token (continuous path
    /// only; `None` when no token was produced or on the static path).
    pub ttft: Option<Duration>,
    /// Per-step logits, oldest first — populated only when
    /// [`crate::coordinator::Server::capture_logits`] is set (parity
    /// harnesses); empty in normal serving.
    pub logits: Vec<Vec<f32>>,
    /// The request's [`GenRequest::deadline`] expired before admission; no
    /// tokens were generated.
    pub timed_out: bool,
}

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Maximum requests per batch (the executable's compiled B).
    pub max_batch: usize,
    /// Maximum time the first request of a batch waits for company
    /// (static path only — continuous admission never waits).
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(10) }
    }
}

/// A request the queue has handed to the serving loop.
#[derive(Debug)]
pub struct Admitted {
    pub req: GenRequest,
    /// Monotone admission sequence number — FIFO in arrival order.
    pub seq: u64,
    /// When the queue handed the request over (queue wait =
    /// `admitted - req.enqueued`).
    pub admitted: Instant,
}

/// The admission queue: drains a request channel into an internal FIFO
/// buffer and hands requests to the serving loop — batched
/// ([`Self::next_batch`]) or continuously ([`Self::poll_admit`]).
pub struct Batcher {
    rx: Receiver<GenRequest>,
    pub cfg: BatcherConfig,
    /// Arrivals drained from the channel (or injected) but not yet admitted.
    buf: VecDeque<GenRequest>,
    /// The channel's sender side is gone; once `buf` drains too, the stream
    /// is over.
    closed: bool,
    next_seq: u64,
    timed_out: u64,
}

impl Batcher {
    pub fn new(rx: Receiver<GenRequest>, cfg: BatcherConfig) -> Self {
        Batcher { rx, cfg, buf: VecDeque::new(), closed: false, next_seq: 0, timed_out: 0 }
    }

    /// Move everything currently sitting in the channel into the buffer.
    /// Never blocks; records channel disconnection.
    fn drain_channel(&mut self) {
        loop {
            match self.rx.try_recv() {
                Ok(r) => self.buf.push_back(r),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    self.closed = true;
                    break;
                }
            }
        }
    }

    /// Inject a request directly, bypassing the channel — deterministic
    /// admission for tests and benches: the request is visible to the very
    /// next poll, no channel timing involved. FIFO order with already
    /// buffered requests is preserved.
    pub fn push(&mut self, req: GenRequest) {
        self.buf.push_back(req);
    }

    /// Requests buffered right now (drains the channel first).
    pub fn poll_pending(&mut self) -> usize {
        self.drain_channel();
        self.buf.len()
    }

    /// True once the sender side is gone *and* the buffer has drained —
    /// reflects the state as of the last poll.
    pub fn is_closed(&self) -> bool {
        self.closed && self.buf.is_empty()
    }

    /// Requests resolved as timed-out at admission so far.
    pub fn timed_out(&self) -> u64 {
        self.timed_out
    }

    /// Block until at least one request is buffered or the stream closes.
    /// Returns `false` only when the channel is disconnected and fully
    /// drained (shutdown). Never spins: parks on the channel when idle.
    pub fn wait_any(&mut self) -> bool {
        self.drain_channel();
        while self.buf.is_empty() && !self.closed {
            match self.rx.recv() {
                Ok(r) => self.buf.push_back(r),
                Err(_) => self.closed = true,
            }
        }
        !self.buf.is_empty()
    }

    /// Consume an admission seq for `req`; if its deadline has passed as of
    /// `now`, resolve it with [`GenResponse::timed_out`] and return `None`,
    /// else hand the request back for a slot. Shared by both serving paths
    /// so the deadline contract is admission-wide.
    fn admit_or_expire(&mut self, req: GenRequest, now: Instant) -> Option<GenRequest> {
        let seq = self.next_seq;
        self.next_seq += 1;
        if req.deadline.is_some_and(|d| now >= d) {
            self.timed_out += 1;
            let wait = req.enqueued.elapsed();
            req.resp
                .send(GenResponse {
                    generated: Vec::new(),
                    latency: wait,
                    steps: 0,
                    seq,
                    queue_wait: wait,
                    ttft: None,
                    logits: Vec::new(),
                    timed_out: true,
                })
                .ok();
            return None;
        }
        Some(req)
    }

    /// Admit up to `max` buffered requests, FIFO, without blocking.
    /// Requests whose [`GenRequest::deadline`] has passed are resolved
    /// immediately with [`GenResponse::timed_out`] (they still consume a
    /// sequence number — admission order is arrival order, always).
    pub fn poll_admit(&mut self, max: usize) -> Vec<Admitted> {
        self.drain_channel();
        let now = Instant::now();
        let mut out = Vec::new();
        while out.len() < max {
            let Some(req) = self.buf.pop_front() else { break };
            let seq = self.next_seq; // admit_or_expire consumes it
            if let Some(req) = self.admit_or_expire(req, now) {
                out.push(Admitted { req, seq, admitted: now });
            }
        }
        out
    }

    /// Block for the next batch (static path). Returns `None` when the
    /// request channel has been closed and drained (shutdown). Buffered
    /// arrivals are never lost: a poll that times out still returns
    /// whatever arrived during the wait window. Expired-deadline requests
    /// resolve as [`GenResponse::timed_out`] here too, never reaching a
    /// batch slot.
    pub fn next_batch(&mut self) -> Option<Vec<GenRequest>> {
        loop {
            // Block indefinitely for the first request…
            if !self.wait_any() {
                return None;
            }
            // …then fill the batch until the deadline or capacity.
            let deadline = Instant::now() + self.cfg.max_wait;
            loop {
                self.drain_channel();
                if self.buf.len() >= self.cfg.max_batch || self.closed {
                    break;
                }
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match self.rx.recv_timeout(deadline - now) {
                    Ok(req) => self.buf.push_back(req),
                    Err(RecvTimeoutError::Timeout) => break,
                    Err(RecvTimeoutError::Disconnected) => {
                        self.closed = true;
                        break;
                    }
                }
            }
            let now = Instant::now();
            let mut batch = Vec::new();
            while batch.len() < self.cfg.max_batch {
                let Some(req) = self.buf.pop_front() else { break };
                if let Some(req) = self.admit_or_expire(req, now) {
                    batch.push(req);
                }
            }
            if !batch.is_empty() {
                return Some(batch);
            }
            // every buffered request had already expired — park again
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn req(prompt: &[u8]) -> (GenRequest, Receiver<GenResponse>) {
        let (tx, rx) = channel();
        (GenRequest::new(prompt.to_vec(), 4, 0.0, tx), rx)
    }

    #[test]
    fn batches_up_to_capacity() {
        let (tx, rx) = channel();
        let mut batcher = Batcher::new(
            rx,
            BatcherConfig { max_batch: 3, max_wait: Duration::from_millis(50) },
        );
        let mut keep = Vec::new();
        for _ in 0..5 {
            let (r, rx) = req(b"hi");
            tx.send(r).unwrap();
            keep.push(rx);
        }
        let b1 = batcher.next_batch().unwrap();
        assert_eq!(b1.len(), 3);
        let b2 = batcher.next_batch().unwrap();
        assert_eq!(b2.len(), 2);
    }

    #[test]
    fn respects_deadline_with_sparse_traffic() {
        let (tx, rx) = channel();
        let mut batcher = Batcher::new(
            rx,
            BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(5) },
        );
        let (r, _keep) = req(b"solo");
        tx.send(r).unwrap();
        let t = Instant::now();
        let b = batcher.next_batch().unwrap();
        assert_eq!(b.len(), 1);
        assert!(t.elapsed() < Duration::from_millis(200));
    }

    #[test]
    fn shutdown_returns_none() {
        let (tx, rx) = channel::<GenRequest>();
        drop(tx);
        let mut batcher = Batcher::new(rx, BatcherConfig::default());
        assert!(batcher.next_batch().is_none());
    }

    #[test]
    fn buffered_arrivals_survive_sender_disconnect() {
        // requests sitting in the channel when the sender goes away are
        // admitted, not dropped as `None` — pins the drain-first contract
        // (mpsc itself guarantees this; the buffer must preserve it)
        let (tx, rx) = channel();
        let mut batcher = Batcher::new(rx, BatcherConfig::default());
        let mut keep = Vec::new();
        for _ in 0..3 {
            let (r, rrx) = req(b"late");
            tx.send(r).unwrap();
            keep.push(rrx);
        }
        drop(tx);
        let b = batcher.next_batch().expect("buffered requests must be admitted");
        assert_eq!(b.len(), 3);
        assert!(batcher.next_batch().is_none(), "then shutdown");
    }

    #[test]
    fn poll_admit_is_fifo_and_never_blocks() {
        let (tx, rx) = channel::<GenRequest>();
        let mut batcher = Batcher::new(rx, BatcherConfig::default());
        assert!(batcher.poll_admit(4).is_empty(), "empty poll returns nothing");
        let mut keep = Vec::new();
        for p in [b"a" as &[u8], b"b", b"c"] {
            let (r, rrx) = req(p);
            tx.send(r).unwrap();
            keep.push(rrx);
        }
        // injected requests join the same FIFO
        let (r, rrx) = req(b"d");
        batcher.push(r);
        keep.push(rrx);
        assert_eq!(batcher.poll_pending(), 4);
        let first = batcher.poll_admit(2);
        assert_eq!(first.len(), 2);
        assert_eq!(first[0].req.prompt, b"a");
        assert_eq!(first[1].req.prompt, b"b");
        assert_eq!(first[0].seq, 0);
        assert_eq!(first[1].seq, 1);
        let rest = batcher.poll_admit(10);
        assert_eq!(rest.len(), 2);
        assert_eq!(rest[0].req.prompt, b"c");
        assert_eq!(rest[1].req.prompt, b"d");
        assert_eq!(rest[1].seq, 3);
        drop(tx);
        assert_eq!(batcher.poll_pending(), 0);
        assert!(batcher.is_closed());
    }

    #[test]
    fn next_batch_filters_expired_deadlines() {
        // the deadline contract is admission-wide: the static path resolves
        // expired requests as timed_out instead of decoding them
        let (tx, rx) = channel::<GenRequest>();
        let mut batcher = Batcher::new(
            rx,
            BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(1) },
        );
        let (mut dead, dead_rx) = req(b"late");
        dead.deadline = Some(dead.enqueued); // already expired
        batcher.push(dead);
        let (live, _live_rx) = req(b"ok");
        batcher.push(live);
        let b = batcher.next_batch().unwrap();
        assert_eq!(b.len(), 1, "expired request never reaches a batch slot");
        assert_eq!(b[0].prompt, b"ok");
        assert_eq!(batcher.timed_out(), 1);
        let resp = dead_rx.recv().unwrap();
        assert!(resp.timed_out && resp.generated.is_empty());
        drop(tx);
    }

    #[test]
    fn expired_deadline_resolves_as_timed_out() {
        let (_tx, rx) = channel::<GenRequest>();
        let mut batcher = Batcher::new(rx, BatcherConfig::default());
        let (mut r, rrx) = req(b"too late");
        r.deadline = Some(r.enqueued); // already expired
        batcher.push(r);
        let (live, live_rx) = req(b"fresh");
        batcher.push(live);
        let admitted = batcher.poll_admit(8);
        assert_eq!(admitted.len(), 1, "expired request never reaches a slot");
        assert_eq!(admitted[0].req.prompt, b"fresh");
        assert_eq!(admitted[0].seq, 1, "expiry still consumes its seq");
        assert_eq!(batcher.timed_out(), 1);
        let resp = rrx.recv().unwrap();
        assert!(resp.timed_out);
        assert!(resp.generated.is_empty());
        drop(live_rx);
    }
}
