//! Request admission: the queue between clients and the serving loop.
//!
//! Two consumers share this queue. The **static** path
//! ([`crate::coordinator::Server::process_batch`], the only mode the
//! fixed-geometry XLA executables support) coalesces requests into batches
//! via [`Batcher::next_batch`], trading latency (`max_wait`) against
//! utilization (`max_batch`). The **continuous** path
//! ([`crate::coordinator::Server::serve_continuous`]) treats the batcher as
//! an admission queue: [`Batcher::poll_admit`] hands over whatever has
//! arrived — never blocking, never losing buffered arrivals — the moment a
//! slot frees, and [`Batcher::wait_any`] parks the server only when every
//! slot is idle.
//!
//! # Admission order
//!
//! Arrivals are routed into per-`(priority, tenant)` FIFO queues
//! ([`GenRequest::tenant`], [`GenRequest::priority`]). Admission drains
//! [`Priority::High`] queues strictly before [`Priority::Normal`] ones;
//! within a class, tenants are served weighted-round-robin in first-seen
//! order (default weight 1, [`Batcher::set_tenant_weight`]); within a
//! tenant, order is FIFO. Traffic from a single tenant at a single priority
//! therefore degenerates to the original strict-FIFO contract the
//! fairness tests pin. Each admitted request is stamped with a monotone
//! sequence number ([`Admitted::seq`]) in admission order.
//!
//! Requests carry an optional [`GenRequest::deadline`]; a request whose
//! deadline passed before admission is resolved immediately with
//! [`FinishReason::TimedOut`] instead of occupying a slot (it still
//! consumes a sequence number). When [`BatcherConfig::tenant_queue_cap`]
//! is non-zero, an arrival that would overflow its tenant queue is
//! resolved immediately with [`FinishReason::Shed`] at routing time — the
//! in-process twin of the HTTP 429 path in
//! [`crate::coordinator::ingress`].
//!
//! Determinism under test: arrivals are drained into the internal queues
//! before every poll, so whether a request is visible to a poll depends
//! only on whether it was sent before the poll — never on channel timing —
//! and [`Batcher::push`] injects requests directly, so tests drive
//! admission without sleeping. (The raw mpsc channel already never loses
//! buffered sends; the buffering is about making admission *observable and
//! injectable*, and about letting a timed-out poll hand over everything
//! that arrived during its wait window in one batch.)

use std::collections::VecDeque;
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::time::{Duration, Instant};

/// Scheduling class of a request. [`Priority::High`] queues drain strictly
/// before [`Priority::Normal`] ones; within a class tenants share capacity
/// weighted-round-robin (see the [module docs](self)).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Priority {
    /// Admitted before any `Normal` request, regardless of arrival order.
    High,
    /// The default class.
    #[default]
    Normal,
}

impl Priority {
    /// Strict drain order: smaller classes drain first.
    fn class(self) -> usize {
        match self {
            Priority::High => 0,
            Priority::Normal => 1,
        }
    }

    /// Parse the wire spelling used by the HTTP ingress (`"high"` /
    /// `"normal"`, case-sensitive). Unknown spellings are `None` so the
    /// caller can reject rather than silently downgrade.
    pub fn parse(s: &str) -> Option<Priority> {
        match s {
            "high" => Some(Priority::High),
            "normal" => Some(Priority::Normal),
            _ => None,
        }
    }
}

/// How a request left the system. Replaces the old bare `timed_out` flag
/// with the three terminal states the serving stack distinguishes.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum FinishReason {
    /// Ran to completion (generated `max_new` tokens or hit a stop).
    #[default]
    Done,
    /// [`GenRequest::deadline`] expired before a slot picked the request
    /// up; no tokens were generated.
    TimedOut,
    /// Rejected by admission control (tenant queue over
    /// [`BatcherConfig::tenant_queue_cap`], or the ingress gate) before
    /// entering a queue; no tokens were generated.
    Shed,
    /// A supervised step panicked or errored while this request held the
    /// slot (see [`crate::coordinator::fault`]): the request fails with the
    /// tokens generated so far, its slot's KV state is quarantined and
    /// rebuilt, and every other in-flight request is unaffected
    /// (DESIGN.md §17). Surfaces as SSE `event: error` on the ingress.
    Faulted,
}

impl FinishReason {
    /// Wire spelling for usage records and logs.
    pub fn as_str(self) -> &'static str {
        match self {
            FinishReason::Done => "done",
            FinishReason::TimedOut => "timed_out",
            FinishReason::Shed => "shed",
            FinishReason::Faulted => "faulted",
        }
    }
}

/// A generation request.
///
/// Construct via [`GenRequest::builder`] — the same builder serves the
/// in-process path and the HTTP ingress
/// ([`crate::coordinator::ingress`]), so tenant / priority / deadline
/// semantics are identical no matter where a request enters.
#[derive(Debug)]
pub struct GenRequest {
    /// Prompt bytes (byte-level vocab).
    pub prompt: Vec<u8>,
    /// Number of tokens to generate.
    pub max_new: usize,
    /// Sampling temperature; 0 = greedy.
    pub temperature: f32,
    /// Where the response goes.
    pub resp: Sender<GenResponse>,
    /// Enqueue timestamp (for latency accounting).
    pub enqueued: Instant,
    /// Admission deadline: if no slot picked the request up by this instant,
    /// it resolves immediately as [`FinishReason::TimedOut`]. `None` waits
    /// forever.
    pub deadline: Option<Instant>,
    /// Fairness bucket. Requests from the same tenant are FIFO; distinct
    /// tenants share capacity weighted-round-robin. Empty = the anonymous
    /// default tenant.
    pub tenant: String,
    /// Scheduling class (see [`Priority`]).
    pub priority: Priority,
    /// Optional token stream: each generated token byte is sent here by the
    /// coordinator thread as soon as the scheduler step that produced it
    /// completes (slot order, so the stream is deterministic). The sender is
    /// dropped with the request once the final [`GenResponse`] has been
    /// delivered, which is the receiver's end-of-stream signal. Powers SSE
    /// in [`crate::coordinator::ingress`]; `None` for plain
    /// request/response use.
    pub stream: Option<Sender<u8>>,
}

impl GenRequest {
    /// Start building a request for `prompt`. Defaults: `max_new` 16,
    /// greedy temperature, anonymous tenant, [`Priority::Normal`], no
    /// deadline, no token stream.
    pub fn builder(prompt: Vec<u8>) -> GenRequestBuilder {
        GenRequestBuilder {
            prompt,
            max_new: 16,
            temperature: 0.0,
            deadline: None,
            tenant: String::new(),
            priority: Priority::Normal,
            stream: None,
        }
    }

    /// A request enqueued now, with no admission deadline.
    #[deprecated(
        since = "0.2.0",
        note = "use `GenRequest::builder(prompt).max_new(n).temperature(t).build(resp)`"
    )]
    pub fn new(
        prompt: Vec<u8>,
        max_new: usize,
        temperature: f32,
        resp: Sender<GenResponse>,
    ) -> Self {
        GenRequest::builder(prompt).max_new(max_new).temperature(temperature).build(resp)
    }
}

/// Builder for [`GenRequest`] — see [`GenRequest::builder`].
#[derive(Debug)]
pub struct GenRequestBuilder {
    prompt: Vec<u8>,
    max_new: usize,
    temperature: f32,
    deadline: Option<Instant>,
    tenant: String,
    priority: Priority,
    stream: Option<Sender<u8>>,
}

impl GenRequestBuilder {
    /// Number of tokens to generate (default 16).
    pub fn max_new(mut self, n: usize) -> Self {
        self.max_new = n;
        self
    }

    /// Sampling temperature; 0 = greedy (the default).
    pub fn temperature(mut self, t: f32) -> Self {
        self.temperature = t;
        self
    }

    /// Absolute admission deadline (see [`GenRequest::deadline`]).
    pub fn deadline(mut self, d: Instant) -> Self {
        self.deadline = Some(d);
        self
    }

    /// Admission deadline `d` from now.
    pub fn deadline_in(mut self, d: Duration) -> Self {
        self.deadline = Some(Instant::now() + d);
        self
    }

    /// Fairness bucket (see [`GenRequest::tenant`]).
    pub fn tenant(mut self, t: impl Into<String>) -> Self {
        self.tenant = t.into();
        self
    }

    /// Scheduling class (see [`Priority`]).
    pub fn priority(mut self, p: Priority) -> Self {
        self.priority = p;
        self
    }

    /// Attach a per-token stream (see [`GenRequest::stream`]).
    pub fn stream(mut self, tx: Sender<u8>) -> Self {
        self.stream = Some(tx);
        self
    }

    /// Finish the build; the request is stamped as enqueued now.
    pub fn build(self, resp: Sender<GenResponse>) -> GenRequest {
        GenRequest {
            prompt: self.prompt,
            max_new: self.max_new,
            temperature: self.temperature,
            resp,
            enqueued: Instant::now(),
            deadline: self.deadline,
            tenant: self.tenant,
            priority: self.priority,
            stream: self.stream,
        }
    }
}

/// A finished generation.
#[derive(Clone, Debug)]
pub struct GenResponse {
    pub generated: Vec<u8>,
    /// Queue + compute latency.
    pub latency: Duration,
    /// Scheduler steps this request consumed: per-request prefill-chunk +
    /// decode steps under continuous batching; the batch's shared decode
    /// steps on the static path.
    pub steps: usize,
    /// Request placement marker. Under continuous batching (and for every
    /// timed-out or shed response) this is the queue's monotone admission
    /// sequence number. Successful *static*-path responses instead carry
    /// their batch slot index (those requests may bypass the queue entirely
    /// via `process_batch`), so seq values are only globally orderable on
    /// the continuous path.
    pub seq: u64,
    /// Time spent queued before a slot picked the request up.
    pub queue_wait: Duration,
    /// Time from enqueue to the first generated token (continuous path
    /// only; `None` when no token was produced or on the static path).
    pub ttft: Option<Duration>,
    /// Per-step logits, oldest first — populated only when
    /// [`crate::coordinator::Server::capture_logits`] is set (parity
    /// harnesses); empty in normal serving.
    pub logits: Vec<Vec<f32>>,
    /// How the request left the system (see [`FinishReason`]).
    pub finish: FinishReason,
}

impl GenResponse {
    /// A terminal response carrying no tokens (timed out or shed), with
    /// latency == queue wait == time since enqueue.
    fn rejected(enqueued: Instant, seq: u64, finish: FinishReason) -> Self {
        let wait = enqueued.elapsed();
        GenResponse {
            generated: Vec::new(),
            latency: wait,
            steps: 0,
            seq,
            queue_wait: wait,
            ttft: None,
            logits: Vec::new(),
            finish,
        }
    }
}

/// Batching and admission policy.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Maximum requests per batch (the executable's compiled B).
    pub max_batch: usize,
    /// Maximum time the first request of a batch waits for company
    /// (static path only — continuous admission never waits).
    pub max_wait: Duration,
    /// Per-`(priority, tenant)` queue bound: an arrival that would make its
    /// queue exceed this depth is resolved immediately with
    /// [`FinishReason::Shed`]. `0` (the default) disables in-queue shedding
    /// — the HTTP ingress layers its own gate in front regardless.
    pub tenant_queue_cap: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(10),
            tenant_queue_cap: 0,
        }
    }
}

/// A request the queue has handed to the serving loop.
#[derive(Debug)]
pub struct Admitted {
    pub req: GenRequest,
    /// Monotone admission sequence number, stamped in admission order (see
    /// the [module docs](self) for the order contract).
    pub seq: u64,
    /// When the queue handed the request over (queue wait =
    /// `admitted - req.enqueued`).
    pub admitted: Instant,
}

/// One tenant's FIFO within a priority class.
#[derive(Debug)]
struct TenantQueue {
    tenant: String,
    buf: VecDeque<GenRequest>,
}

/// The admission queue: drains a request channel into per-tenant FIFO
/// queues and hands requests to the serving loop — batched
/// ([`Self::next_batch`]) or continuously ([`Self::poll_admit`]).
pub struct Batcher {
    rx: Receiver<GenRequest>,
    pub cfg: BatcherConfig,
    /// Per-priority-class tenant queues, first-seen tenant order.
    /// `classes[Priority::High.class()]` drains strictly first.
    classes: [Vec<TenantQueue>; 2],
    /// Weighted-round-robin position per class: index of the tenant queue
    /// currently being served.
    cursor: [usize; 2],
    /// Requests the current tenant may still take in this WRR visit.
    credit: [usize; 2],
    /// Per-tenant WRR weights (default 1); applies to both classes.
    weights: Vec<(String, usize)>,
    /// The channel's sender side is gone; once the queues drain too, the
    /// stream is over.
    closed: bool,
    next_seq: u64,
    timed_out: u64,
    shed: u64,
}

impl Batcher {
    pub fn new(rx: Receiver<GenRequest>, cfg: BatcherConfig) -> Self {
        Batcher {
            rx,
            cfg,
            classes: [Vec::new(), Vec::new()],
            cursor: [0, 0],
            credit: [0, 0],
            weights: Vec::new(),
            closed: false,
            next_seq: 0,
            timed_out: 0,
            shed: 0,
        }
    }

    /// Set a tenant's weighted-round-robin weight (default 1; clamped to
    /// ≥ 1). A tenant with weight `w` may take up to `w` consecutive
    /// requests per round-robin visit within its priority class.
    pub fn set_tenant_weight(&mut self, tenant: impl Into<String>, weight: usize) {
        let tenant = tenant.into();
        let weight = weight.max(1);
        match self.weights.iter_mut().find(|(t, _)| *t == tenant) {
            Some(entry) => entry.1 = weight,
            None => self.weights.push((tenant, weight)),
        }
    }

    fn weight_of(&self, tenant: &str) -> usize {
        self.weights
            .iter()
            .find(|(t, _)| t == tenant)
            .map(|(_, w)| *w)
            .unwrap_or(1)
    }

    /// Route an arrival into its `(priority, tenant)` queue, shedding at
    /// the tenant-queue cap. Routing happens in arrival order.
    fn route(&mut self, req: GenRequest) {
        let class = req.priority.class();
        let idx = match self.classes[class].iter().position(|q| q.tenant == req.tenant) {
            Some(i) => i,
            None => {
                let w = self.weight_of(&req.tenant);
                self.classes[class]
                    .push(TenantQueue { tenant: req.tenant.clone(), buf: VecDeque::new() });
                let i = self.classes[class].len() - 1;
                if i == 0 {
                    // First tenant in this class: start the WRR scan here
                    // with a full credit so single-tenant traffic is pure
                    // FIFO from the first admission.
                    self.cursor[class] = 0;
                    self.credit[class] = w;
                }
                i
            }
        };
        let cap = self.cfg.tenant_queue_cap;
        if cap > 0 && self.classes[class][idx].buf.len() >= cap {
            self.shed += 1;
            let seq = self.next_seq;
            self.next_seq += 1;
            req.resp.send(GenResponse::rejected(req.enqueued, seq, FinishReason::Shed)).ok();
            return;
        }
        self.classes[class][idx].buf.push_back(req);
    }

    /// Move everything currently sitting in the channel into the queues.
    /// Never blocks; records channel disconnection.
    fn drain_channel(&mut self) {
        loop {
            match self.rx.try_recv() {
                Ok(r) => self.route(r),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    self.closed = true;
                    break;
                }
            }
        }
    }

    /// Inject a request directly, bypassing the channel — deterministic
    /// admission for tests and benches: the request is visible to the very
    /// next poll, no channel timing involved. Queue order with already
    /// buffered requests is preserved.
    pub fn push(&mut self, req: GenRequest) {
        self.route(req);
    }

    fn total_buffered(&self) -> usize {
        self.classes.iter().flatten().map(|q| q.buf.len()).sum()
    }

    /// Requests buffered right now (drains the channel first).
    pub fn poll_pending(&mut self) -> usize {
        self.drain_channel();
        self.total_buffered()
    }

    /// True once the sender side is gone *and* the queues have drained —
    /// reflects the state as of the last poll.
    pub fn is_closed(&self) -> bool {
        self.closed && self.total_buffered() == 0
    }

    /// Requests resolved as timed-out at admission so far.
    pub fn timed_out(&self) -> u64 {
        self.timed_out
    }

    /// Requests resolved as shed (tenant queue over cap) so far.
    pub fn shed(&self) -> u64 {
        self.shed
    }

    /// Block until at least one request is buffered or the stream closes.
    /// Returns `false` only when the channel is disconnected and fully
    /// drained (shutdown). Never spins: parks on the channel when idle.
    pub fn wait_any(&mut self) -> bool {
        self.drain_channel();
        while self.total_buffered() == 0 && !self.closed {
            match self.rx.recv() {
                Ok(r) => self.route(r),
                Err(_) => self.closed = true,
            }
        }
        self.total_buffered() > 0
    }

    /// Pop the next request in admission order: strict priority, then
    /// weighted round-robin across tenants, then FIFO within a tenant.
    fn pop_next(&mut self) -> Option<GenRequest> {
        (0..self.classes.len()).find_map(|class| self.pop_class(class))
    }

    fn pop_class(&mut self, class: usize) -> Option<GenRequest> {
        let n = self.classes[class].len();
        if n == 0 || self.classes[class].iter().all(|q| q.buf.is_empty()) {
            return None;
        }
        loop {
            let i = self.cursor[class] % n;
            if self.classes[class][i].buf.is_empty() || self.credit[class] == 0 {
                // This tenant's visit is over (queue empty or credit
                // spent): move on and grant the next tenant a full visit.
                let next = (i + 1) % n;
                let w = self.weight_of(&self.classes[class][next].tenant);
                self.cursor[class] = next;
                self.credit[class] = w;
                continue;
            }
            self.credit[class] -= 1;
            return self.classes[class][i].buf.pop_front();
        }
    }

    /// Consume an admission seq for `req`; if its deadline has passed as of
    /// `now`, resolve it with [`FinishReason::TimedOut`] and return `None`,
    /// else hand the request back for a slot. Shared by both serving paths
    /// so the deadline contract is admission-wide.
    fn admit_or_expire(&mut self, req: GenRequest, now: Instant) -> Option<GenRequest> {
        let seq = self.next_seq;
        self.next_seq += 1;
        if req.deadline.is_some_and(|d| now >= d) {
            self.timed_out += 1;
            req.resp.send(GenResponse::rejected(req.enqueued, seq, FinishReason::TimedOut)).ok();
            return None;
        }
        Some(req)
    }

    /// Admit up to `max` buffered requests in admission order (see the
    /// [module docs](self)), without blocking. Requests whose
    /// [`GenRequest::deadline`] has passed are resolved immediately with
    /// [`FinishReason::TimedOut`] (they still consume a sequence number).
    pub fn poll_admit(&mut self, max: usize) -> Vec<Admitted> {
        self.drain_channel();
        let now = Instant::now();
        let mut out = Vec::new();
        while out.len() < max {
            let Some(req) = self.pop_next() else { break };
            let seq = self.next_seq; // admit_or_expire consumes it
            if let Some(req) = self.admit_or_expire(req, now) {
                out.push(Admitted { req, seq, admitted: now });
            }
        }
        out
    }

    /// Block for the next batch (static path). Returns `None` when the
    /// request channel has been closed and drained (shutdown). Buffered
    /// arrivals are never lost: a poll that times out still returns
    /// whatever arrived during the wait window. Expired-deadline requests
    /// resolve as [`FinishReason::TimedOut`] here too, never reaching a
    /// batch slot.
    pub fn next_batch(&mut self) -> Option<Vec<GenRequest>> {
        loop {
            // Block indefinitely for the first request…
            if !self.wait_any() {
                return None;
            }
            // …then fill the batch until the deadline or capacity.
            let deadline = Instant::now() + self.cfg.max_wait;
            loop {
                self.drain_channel();
                if self.total_buffered() >= self.cfg.max_batch || self.closed {
                    break;
                }
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match self.rx.recv_timeout(deadline - now) {
                    Ok(req) => self.route(req),
                    Err(RecvTimeoutError::Timeout) => break,
                    Err(RecvTimeoutError::Disconnected) => {
                        self.closed = true;
                        break;
                    }
                }
            }
            let now = Instant::now();
            let mut batch = Vec::new();
            while batch.len() < self.cfg.max_batch {
                let Some(req) = self.pop_next() else { break };
                if let Some(req) = self.admit_or_expire(req, now) {
                    batch.push(req);
                }
            }
            if !batch.is_empty() {
                return Some(batch);
            }
            // every buffered request had already expired — park again
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn req(prompt: &[u8]) -> (GenRequest, Receiver<GenResponse>) {
        let (tx, rx) = channel();
        (GenRequest::builder(prompt.to_vec()).max_new(4).build(tx), rx)
    }

    fn tenant_req(prompt: &[u8], tenant: &str) -> (GenRequest, Receiver<GenResponse>) {
        let (tx, rx) = channel();
        (GenRequest::builder(prompt.to_vec()).max_new(4).tenant(tenant).build(tx), rx)
    }

    #[test]
    fn batches_up_to_capacity() {
        let (tx, rx) = channel();
        let mut batcher = Batcher::new(
            rx,
            BatcherConfig {
                max_batch: 3,
                max_wait: Duration::from_millis(50),
                ..BatcherConfig::default()
            },
        );
        let mut keep = Vec::new();
        for _ in 0..5 {
            let (r, rx) = req(b"hi");
            tx.send(r).unwrap();
            keep.push(rx);
        }
        let b1 = batcher.next_batch().unwrap();
        assert_eq!(b1.len(), 3);
        let b2 = batcher.next_batch().unwrap();
        assert_eq!(b2.len(), 2);
    }

    #[test]
    fn respects_deadline_with_sparse_traffic() {
        let (tx, rx) = channel();
        let mut batcher = Batcher::new(
            rx,
            BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(5),
                ..BatcherConfig::default()
            },
        );
        let (r, _keep) = req(b"solo");
        tx.send(r).unwrap();
        let t = Instant::now();
        let b = batcher.next_batch().unwrap();
        assert_eq!(b.len(), 1);
        assert!(t.elapsed() < Duration::from_millis(200));
    }

    #[test]
    fn shutdown_returns_none() {
        let (tx, rx) = channel::<GenRequest>();
        drop(tx);
        let mut batcher = Batcher::new(rx, BatcherConfig::default());
        assert!(batcher.next_batch().is_none());
    }

    #[test]
    fn buffered_arrivals_survive_sender_disconnect() {
        // requests sitting in the channel when the sender goes away are
        // admitted, not dropped as `None` — pins the drain-first contract
        // (mpsc itself guarantees this; the buffer must preserve it)
        let (tx, rx) = channel();
        let mut batcher = Batcher::new(rx, BatcherConfig::default());
        let mut keep = Vec::new();
        for _ in 0..3 {
            let (r, rrx) = req(b"late");
            tx.send(r).unwrap();
            keep.push(rrx);
        }
        drop(tx);
        let b = batcher.next_batch().expect("buffered requests must be admitted");
        assert_eq!(b.len(), 3);
        assert!(batcher.next_batch().is_none(), "then shutdown");
    }

    #[test]
    fn poll_admit_is_fifo_and_never_blocks() {
        let (tx, rx) = channel::<GenRequest>();
        let mut batcher = Batcher::new(rx, BatcherConfig::default());
        assert!(batcher.poll_admit(4).is_empty(), "empty poll returns nothing");
        let mut keep = Vec::new();
        for p in [b"a" as &[u8], b"b", b"c"] {
            let (r, rrx) = req(p);
            tx.send(r).unwrap();
            keep.push(rrx);
        }
        // injected requests join the same FIFO
        let (r, rrx) = req(b"d");
        batcher.push(r);
        keep.push(rrx);
        assert_eq!(batcher.poll_pending(), 4);
        let first = batcher.poll_admit(2);
        assert_eq!(first.len(), 2);
        assert_eq!(first[0].req.prompt, b"a");
        assert_eq!(first[1].req.prompt, b"b");
        assert_eq!(first[0].seq, 0);
        assert_eq!(first[1].seq, 1);
        let rest = batcher.poll_admit(10);
        assert_eq!(rest.len(), 2);
        assert_eq!(rest[0].req.prompt, b"c");
        assert_eq!(rest[1].req.prompt, b"d");
        assert_eq!(rest[1].seq, 3);
        drop(tx);
        assert_eq!(batcher.poll_pending(), 0);
        assert!(batcher.is_closed());
    }

    #[test]
    fn next_batch_filters_expired_deadlines() {
        // the deadline contract is admission-wide: the static path resolves
        // expired requests as timed_out instead of decoding them
        let (tx, rx) = channel::<GenRequest>();
        let mut batcher = Batcher::new(
            rx,
            BatcherConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
                ..BatcherConfig::default()
            },
        );
        let (mut dead, dead_rx) = req(b"late");
        dead.deadline = Some(dead.enqueued); // already expired
        batcher.push(dead);
        let (live, _live_rx) = req(b"ok");
        batcher.push(live);
        let b = batcher.next_batch().unwrap();
        assert_eq!(b.len(), 1, "expired request never reaches a batch slot");
        assert_eq!(b[0].prompt, b"ok");
        assert_eq!(batcher.timed_out(), 1);
        let resp = dead_rx.recv().unwrap();
        assert_eq!(resp.finish, FinishReason::TimedOut);
        assert!(resp.generated.is_empty());
        drop(tx);
    }

    #[test]
    fn expired_deadline_resolves_as_timed_out() {
        let (_tx, rx) = channel::<GenRequest>();
        let mut batcher = Batcher::new(rx, BatcherConfig::default());
        let (mut r, rrx) = req(b"too late");
        r.deadline = Some(r.enqueued); // already expired
        batcher.push(r);
        let (live, live_rx) = req(b"fresh");
        batcher.push(live);
        let admitted = batcher.poll_admit(8);
        assert_eq!(admitted.len(), 1, "expired request never reaches a slot");
        assert_eq!(admitted[0].req.prompt, b"fresh");
        assert_eq!(admitted[0].seq, 1, "expiry still consumes its seq");
        assert_eq!(batcher.timed_out(), 1);
        let resp = rrx.recv().unwrap();
        assert_eq!(resp.finish, FinishReason::TimedOut);
        assert!(resp.generated.is_empty());
        drop(live_rx);
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_new_shim_builds_a_default_request() {
        // shim coverage for one release: positional construction still
        // yields the builder's defaults for the new fields
        let (tx, _rx) = channel();
        let r = GenRequest::new(b"compat".to_vec(), 7, 0.5, tx);
        assert_eq!(r.prompt, b"compat");
        assert_eq!(r.max_new, 7);
        assert_eq!(r.temperature, 0.5);
        assert_eq!(r.tenant, "");
        assert_eq!(r.priority, Priority::Normal);
        assert!(r.deadline.is_none() && r.stream.is_none());
    }

    #[test]
    fn tenants_interleave_round_robin_within_a_class() {
        // tenant a floods 4 requests, then tenant b sends 2; admission
        // alternates a,b while b has work, FIFO within each tenant
        let (_tx, rx) = channel::<GenRequest>();
        let mut batcher = Batcher::new(rx, BatcherConfig::default());
        let mut keep = Vec::new();
        for p in [b"a0" as &[u8], b"a1", b"a2", b"a3"] {
            let (r, rrx) = tenant_req(p, "a");
            batcher.push(r);
            keep.push(rrx);
        }
        for p in [b"b0" as &[u8], b"b1"] {
            let (r, rrx) = tenant_req(p, "b");
            batcher.push(r);
            keep.push(rrx);
        }
        let order: Vec<Vec<u8>> =
            batcher.poll_admit(16).into_iter().map(|a| a.req.prompt).collect();
        let want: Vec<Vec<u8>> =
            [b"a0" as &[u8], b"b0", b"a1", b"b1", b"a2", b"a3"]
                .iter()
                .map(|p| p.to_vec())
                .collect();
        assert_eq!(order, want);
    }

    #[test]
    fn tenant_weights_scale_the_round_robin_share() {
        // weight 2 lets tenant a take two requests per visit
        let (_tx, rx) = channel::<GenRequest>();
        let mut batcher = Batcher::new(rx, BatcherConfig::default());
        batcher.set_tenant_weight("a", 2);
        let mut keep = Vec::new();
        for p in [b"a0" as &[u8], b"a1", b"a2", b"a3"] {
            let (r, rrx) = tenant_req(p, "a");
            batcher.push(r);
            keep.push(rrx);
        }
        for p in [b"b0" as &[u8], b"b1"] {
            let (r, rrx) = tenant_req(p, "b");
            batcher.push(r);
            keep.push(rrx);
        }
        let order: Vec<Vec<u8>> =
            batcher.poll_admit(16).into_iter().map(|a| a.req.prompt).collect();
        let want: Vec<Vec<u8>> =
            [b"a0" as &[u8], b"a1", b"b0", b"a2", b"a3", b"b1"]
                .iter()
                .map(|p| p.to_vec())
                .collect();
        assert_eq!(order, want);
    }

    #[test]
    fn high_priority_drains_strictly_first() {
        let (_tx, rx) = channel::<GenRequest>();
        let mut batcher = Batcher::new(rx, BatcherConfig::default());
        let mut keep = Vec::new();
        let (r, rrx) = req(b"normal0");
        batcher.push(r);
        keep.push(rrx);
        let (tx_h, rrx) = channel();
        batcher.push(
            GenRequest::builder(b"vip".to_vec())
                .max_new(4)
                .priority(Priority::High)
                .build(tx_h),
        );
        keep.push(rrx);
        let (r, rrx) = req(b"normal1");
        batcher.push(r);
        keep.push(rrx);
        let order: Vec<Vec<u8>> =
            batcher.poll_admit(16).into_iter().map(|a| a.req.prompt).collect();
        assert_eq!(order[0], b"vip", "High admits before earlier-arrived Normal");
        assert_eq!(order[1], b"normal0");
        assert_eq!(order[2], b"normal1");
    }

    #[test]
    fn tenant_queue_cap_sheds_at_routing_time() {
        let (_tx, rx) = channel::<GenRequest>();
        let mut batcher = Batcher::new(
            rx,
            BatcherConfig { tenant_queue_cap: 2, ..BatcherConfig::default() },
        );
        let mut keep = Vec::new();
        let mut shed_rx = Vec::new();
        for i in 0..4u8 {
            let (r, rrx) = tenant_req(&[b'a', i], "a");
            batcher.push(r);
            if i < 2 {
                keep.push(rrx);
            } else {
                shed_rx.push(rrx);
            }
        }
        // other tenants are unaffected by a's full queue
        let (r, rrx) = tenant_req(b"b0", "b");
        batcher.push(r);
        keep.push(rrx);
        assert_eq!(batcher.shed(), 2);
        for rrx in &shed_rx {
            let resp = rrx.recv().unwrap();
            assert_eq!(resp.finish, FinishReason::Shed);
            assert!(resp.generated.is_empty() && resp.steps == 0);
        }
        let admitted = batcher.poll_admit(16);
        assert_eq!(admitted.len(), 3, "capped overflow never reaches a slot");
    }
}
