//! The generation server: batched iterative decoding.
//!
//! Three serving modes share every line of the decode loop:
//!
//! * **Fp** — dense weights bound to `fwd_fp_<model>_b8` (fp baseline, or
//!   any fake-quant model for ablations);
//! * **Quantized** — PCDVQ codes + codebooks bound to `fwd_q_<model>`, where
//!   dequantization happens *inside* the executable (gather + scale +
//!   inverse RHT fused by XLA): the dense weights never exist on the host,
//!   which is what shrinks the per-request weight traffic 8-16x (§4.4);
//! * **CodesResident** — the host backend ([`HostForward`]): every
//!   quantizable linear is served straight from its packed code streams via
//!   [`crate::quant::QuantizedWeight::matmul_from_codes`]. No XLA artifact
//!   (and no dense weight) is involved at any point; resident weight state
//!   is exactly codes + shared codebooks, which
//!   [`crate::paper::verify_codes_resident`] checks against the §4.4 claim.
//!
//! The host backend decodes **incrementally** with one [`KvCache`] per batch
//! slot (reset at every request boundary — per-request state is explicit);
//! the windowed re-forward survives as [`DecodePolicy::Reforward`], both as
//! the parity oracle and as the only option for the fixed-geometry XLA
//! executables (DESIGN.md §9).

use std::time::Instant;

use anyhow::{Context, Result};

use super::batcher::{Batcher, GenRequest, GenResponse};
use super::metrics::Metrics;
use crate::codebook::{DirectionCodebook, MagnitudeCodebook};
use crate::eval::weight_inputs;
use crate::model::{GptModel, HostForward, KvCache, QuantizedGpt};
use crate::rng::Rng;
use crate::runtime::{BoundExecutable, Engine, Input};

/// What the server serves.
pub enum ServingWeights {
    /// Dense weights (original or fake-quant) through the XLA `fwd_fp`
    /// executable — or the host backend via [`Server::new_host`].
    Fp(GptModel),
    /// PCDVQ codes + the shared DACC codebooks through the XLA `fwd_q`
    /// executable (in-graph dequantization).
    Quantized(Box<QuantizedGpt>, DirectionCodebook, MagnitudeCodebook),
    /// Compressed artifacts served on the host: packed codes + shared
    /// codebooks are the only resident weight state.
    CodesResident(Box<QuantizedGpt>),
}

impl ServingWeights {
    fn model_name(&self) -> &str {
        match self {
            ServingWeights::Fp(m) => &m.name,
            ServingWeights::Quantized(q, _, _) => &q.name,
            ServingWeights::CodesResident(q) => &q.name,
        }
    }

    fn config(&self) -> crate::model::GptConfig {
        match self {
            ServingWeights::Fp(m) => m.config,
            ServingWeights::Quantized(q, _, _) => q.config,
            ServingWeights::CodesResident(q) => q.config,
        }
    }
}

/// The decode backend: a bound XLA executable or the host forward.
enum Backend {
    Xla(BoundExecutable),
    Host(HostForward),
}

/// How the server advances a decode step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DecodePolicy {
    /// Incremental decode against per-slot [`KvCache`]s — O(1) weight work
    /// per token. Host backend only (and its default).
    KvCached,
    /// Re-forward the whole window every token — O(window) per token. The
    /// parity oracle for the cached path, and the only policy the
    /// fixed-geometry XLA executables support.
    Reforward,
}

/// A ready-to-serve model: backend + decode state.
pub struct Server {
    backend: Backend,
    pub config: crate::model::GptConfig,
    pub batch: usize,
    pub metrics: Metrics,
    /// Decode strategy. [`Self::new_host`] defaults to
    /// [`DecodePolicy::KvCached`]; an XLA server ignores `KvCached` and
    /// re-forwards regardless (its executable geometry is fixed).
    pub decode: DecodePolicy,
    /// Seed for the per-request sampling streams: every request draws from a
    /// fresh `Rng` derived from this seed and its batch slot, so requests
    /// never inherit sampler state from earlier traffic — a request replayed
    /// in the same batch slot on a fresh server reproduces its output
    /// exactly. (The stream does depend on slot placement, so co-batched
    /// traffic can shift which stream a sampled request gets.)
    pub sampler_seed: u64,
    /// One KV cache per batch slot, built lazily on the host backend and
    /// **reset at every request boundary** — a new request always starts
    /// from an empty cache.
    slot_caches: Vec<KvCache>,
    /// Weight bits actually resident for the quantizable matrices (fp32 vs
    /// packed codes) — reported by the efficiency harness.
    pub resident_weight_bits: u64,
    /// Bits of the distinct shared codebooks resident beside the payloads
    /// (0 for dense serving; amortized across all layers otherwise).
    pub resident_codebook_bits: u64,
}

impl Server {
    /// Bind a serving model against its AOT artifact (XLA backend).
    pub fn new(engine: &Engine, artifacts_dir: &std::path::Path, weights: ServingWeights) -> Result<Self> {
        let config = weights.config();
        let batch = 8usize;
        let (bound, resident_weight_bits, resident_codebook_bits) = match &weights {
            ServingWeights::Fp(model) => {
                let base =
                    artifacts_dir.join(format!("fwd_fp_{}_b{batch}", weights.model_name()));
                let exe = engine.load(&base)?;
                let fixed = weight_inputs(model, &exe.manifest)?;
                let bits = model.config.quantizable_params() as u64 * 32;
                (exe.bind(&fixed, 1)?, bits, 0)
            }
            ServingWeights::Quantized(q, dir_cb, mag_cb) => {
                let base = artifacts_dir.join(format!("fwd_q_{}", weights.model_name()));
                let exe = engine.load(&base)?;
                let fixed = quantized_inputs(q, dir_cb, mag_cb, &exe.manifest)?;
                let cb_bits = q.codebook_bits();
                (exe.bind(&fixed, 1)?, q.payload_bits(), cb_bits)
            }
            ServingWeights::CodesResident(_) => anyhow::bail!(
                "codes-resident serving runs on the host — use Server::new_host"
            ),
        };
        Ok(Server {
            backend: Backend::Xla(bound),
            config,
            batch,
            metrics: Metrics::new(),
            decode: DecodePolicy::Reforward,
            sampler_seed: 0x5E84,
            slot_caches: Vec::new(),
            resident_weight_bits,
            resident_codebook_bits,
        })
    }

    /// Build a host-backed server (no XLA artifacts required). `Fp` serves
    /// dense weights; `CodesResident` serves packed codes directly.
    pub fn new_host(weights: ServingWeights) -> Result<Self> {
        let config = weights.config();
        let (hf, resident_weight_bits, resident_codebook_bits) = match weights {
            ServingWeights::Fp(model) => {
                let bits = model.config.quantizable_params() as u64 * 32;
                (HostForward::from_dense(model)?, bits, 0)
            }
            ServingWeights::CodesResident(q) => {
                let payload = q.payload_bits();
                let cb_bits = q.codebook_bits();
                (HostForward::from_quantized(*q)?, payload, cb_bits)
            }
            ServingWeights::Quantized(..) => anyhow::bail!(
                "the in-graph-dequant mode needs the fwd_q XLA artifact — \
                 use ServingWeights::CodesResident for host serving"
            ),
        };
        Ok(Server {
            backend: Backend::Host(hf),
            config,
            batch: 8,
            metrics: Metrics::new(),
            decode: DecodePolicy::KvCached,
            sampler_seed: 0x5E84,
            slot_caches: Vec::new(),
            resident_weight_bits,
            resident_codebook_bits,
        })
    }

    /// One forward of a `(b, t)` token block through whichever backend.
    fn run_block(&self, block: Vec<i32>, b: usize, t: usize) -> Result<Vec<f32>> {
        match &self.backend {
            Backend::Xla(bound) => bound.run_f32(&[Input::I32(block, vec![b, t])]),
            Backend::Host(hf) => hf.forward(&block, b, t),
        }
    }

    /// True when the backend never materializes dense quantizable weights.
    pub fn is_codes_resident(&self) -> bool {
        match &self.backend {
            Backend::Host(hf) => hf.is_codes_resident(),
            Backend::Xla(_) => false,
        }
    }

    /// f32 bits of KV-cache state currently allocated across batch slots
    /// (0 until the first cached batch; grows to
    /// `batch · config.kv_cache_bits()`).
    pub fn kv_cache_bits(&self) -> u64 {
        self.slot_caches.iter().map(|c| c.memory_bits()).sum()
    }

    /// Decode one batch of requests to completion; sends responses on each
    /// request's channel and updates metrics.
    pub fn process_batch(&mut self, batch: Vec<GenRequest>) -> Result<()> {
        anyhow::ensure!(
            batch.len() <= self.batch,
            "batch larger than executable geometry"
        );
        let cached = matches!(&self.backend, Backend::Host(_))
            && self.decode == DecodePolicy::KvCached;
        if cached {
            self.process_batch_cached(batch)
        } else {
            self.process_batch_reforward(batch)
        }
    }

    /// Incremental decode: per-slot KV caches, one token of model work per
    /// step. Each request starts from an explicitly reset cache and a fresh
    /// sampling stream — no state crosses request boundaries.
    fn process_batch_cached(&mut self, batch: Vec<GenRequest>) -> Result<()> {
        let t0 = Instant::now();
        let ctx = self.config.ctx;
        let v = self.config.vocab;
        let Backend::Host(hf) = &self.backend else {
            anyhow::bail!("cached decode needs the host backend")
        };
        while self.slot_caches.len() < batch.len() {
            self.slot_caches.push(KvCache::new(&self.config));
        }

        let mut generated: Vec<Vec<u8>> = vec![Vec::new(); batch.len()];
        for (s, req) in batch.iter().enumerate() {
            let cache = &mut self.slot_caches[s];
            cache.reset(); // new request → fresh cache
            let mut rng = request_rng(self.sampler_seed, s);
            let prompt: Vec<i32> = req
                .prompt
                .iter()
                .rev()
                .take(ctx - 1) // leave room to generate
                .rev()
                .map(|&x| x as i32)
                .collect();
            if prompt.is_empty() {
                // degenerate request: resolve with zero tokens rather than
                // failing the whole batch (finish_batch still responds)
                continue;
            }
            let mut logits = hf.prefill(&prompt, cache).context("prefill")?;
            for step in 0..req.max_new {
                debug_assert_eq!(logits.len(), v);
                let next = if req.temperature <= 0.0 {
                    crate::tensor::argmax(&logits) as u8
                } else {
                    sample(&logits, req.temperature, &mut rng)
                };
                generated[s].push(next);
                if step + 1 < req.max_new {
                    logits = hf.decode_step(next as i32, cache).context("decode step")?;
                }
            }
        }

        let steps = batch.iter().map(|r| r.max_new).max().unwrap_or(0);
        self.finish_batch(t0, &batch, &generated, steps);
        Ok(())
    }

    /// Windowed re-forward: the whole prefix through the backend every step.
    /// The parity oracle for [`DecodePolicy::KvCached`], and the decode loop
    /// of the fixed-geometry XLA executables.
    fn process_batch_reforward(&mut self, batch: Vec<GenRequest>) -> Result<()> {
        let t0 = Instant::now();
        let ctx = self.config.ctx;
        let b = self.batch;

        // Per-slot state: token buffer + generated bytes.
        let mut bufs: Vec<Vec<i32>> = Vec::with_capacity(b);
        let mut lens: Vec<usize> = Vec::with_capacity(b);
        for req in &batch {
            let p: Vec<i32> = req
                .prompt
                .iter()
                .rev()
                .take(ctx - 1) // leave room to generate
                .rev()
                .map(|&x| x as i32)
                .collect();
            lens.push(p.len());
            bufs.push(p);
        }
        let max_new = batch.iter().map(|r| r.max_new).max().unwrap_or(0);
        let mut generated: Vec<Vec<u8>> = vec![Vec::new(); batch.len()];
        let mut rngs: Vec<Rng> = (0..batch.len())
            .map(|s| request_rng(self.sampler_seed, s))
            .collect();

        let mut steps = 0usize;
        for _ in 0..max_new {
            // assemble the (B, ctx) token block
            let mut block = vec![0i32; b * ctx];
            for (s, buf) in bufs.iter().enumerate() {
                let start = buf.len().saturating_sub(ctx);
                for (j, &t) in buf[start..].iter().enumerate() {
                    block[s * ctx + j] = t;
                }
            }
            let logits = self.run_block(block, b, ctx).context("decode step")?;
            steps += 1;
            let v = self.config.vocab;
            for (s, req) in batch.iter().enumerate() {
                // empty-prompt slots resolve with zero tokens (no position
                // to predict from), mirroring the cached path
                if generated[s].len() >= req.max_new || lens[s] == 0 {
                    continue;
                }
                let pos = (lens[s].min(ctx)) - 1;
                let row = &logits[(s * ctx + pos) * v..(s * ctx + pos + 1) * v];
                let next = if req.temperature <= 0.0 {
                    crate::tensor::argmax(row) as u8
                } else {
                    sample(row, req.temperature, &mut rngs[s])
                };
                generated[s].push(next);
                bufs[s].push(next as i32);
                if bufs[s].len() > ctx {
                    // sliding window: len stays ctx, predict from the end
                    lens[s] = ctx;
                } else {
                    lens[s] = bufs[s].len();
                }
            }
        }

        self.finish_batch(t0, &batch, &generated, steps);
        Ok(())
    }

    /// Shared batch epilogue: responses + metrics.
    fn finish_batch(
        &mut self,
        t0: Instant,
        batch: &[GenRequest],
        generated: &[Vec<u8>],
        steps: usize,
    ) {
        let mut tokens = 0usize;
        for (req, gen) in batch.iter().zip(generated.iter()) {
            tokens += gen.len();
            let resp = GenResponse {
                generated: gen.clone(),
                latency: req.enqueued.elapsed(),
                steps,
            };
            self.metrics.record_latency(resp.latency);
            req.resp.send(resp).ok();
        }
        self.metrics.record_batch(batch.len(), tokens, steps);
        self.metrics.wall_s += t0.elapsed().as_secs_f64();
    }

    /// Serve until the request channel closes.
    pub fn serve(&mut self, batcher: &Batcher) -> Result<()> {
        while let Some(batch) = batcher.next_batch() {
            self.process_batch(batch)?;
        }
        Ok(())
    }
}

/// Per-request sampling stream, deterministic in (server seed, batch slot):
/// a request's samples never depend on traffic served *before* it, so a
/// request replayed in the same batch slot on a fresh server reproduces its
/// output exactly. Slot placement itself still depends on how the batcher
/// grouped concurrent traffic.
fn request_rng(seed: u64, slot: usize) -> Rng {
    Rng::new(seed ^ (slot as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

/// Temperature sampling over a logit row.
fn sample(logits: &[f32], temperature: f32, rng: &mut Rng) -> u8 {
    let maxv = logits.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
    let mut probs: Vec<f64> = logits
        .iter()
        .map(|&x| (((x - maxv) / temperature) as f64).exp())
        .collect();
    let total: f64 = probs.iter().sum();
    let mut u = rng.uniform() * total;
    for (i, p) in probs.iter_mut().enumerate() {
        u -= *p;
        if u <= 0.0 {
            return i as u8;
        }
    }
    (logits.len() - 1) as u8
}

/// Build the fixed inputs of a `fwd_q` executable from a quantized model +
/// codebooks, following the manifest order. The artifacts must be DACC
/// (two-stream: direction + magnitude) with an RHT seed — i.e. PCDVQ.
pub fn quantized_inputs(
    q: &QuantizedGpt,
    dir_cb: &DirectionCodebook,
    mag_cb: &MagnitudeCodebook,
    manifest: &crate::runtime::Manifest,
) -> Result<Vec<Input>> {
    let weight = |base: &str| -> Result<&crate::quant::QuantizedWeight> {
        let w = q
            .weights
            .get(base)
            .with_context(|| format!("missing codes for {base}"))?;
        anyhow::ensure!(
            w.codes().n_streams() == 2,
            "'{base}' is not a two-stream (DACC) artifact"
        );
        Ok(w)
    };
    let mut out = Vec::with_capacity(manifest.len() - 1);
    for e in &manifest.entries {
        if e.name == "tokens" {
            continue;
        }
        let input = if e.name == "codebook.dir" {
            Input::F32(dir_cb.vectors.as_slice().to_vec(), e.dims.clone())
        } else if e.name == "codebook.mag" {
            Input::F32(mag_cb.levels.clone(), e.dims.clone())
        } else if let Some(base) = e.name.strip_suffix(".dir_idx") {
            let w = weight(base)?;
            let s = w.codes().stream(0);
            let idx: Vec<i32> = (0..s.len).map(|i| s.get(i) as i32).collect();
            Input::I32(idx, e.dims.clone())
        } else if let Some(base) = e.name.strip_suffix(".mag_idx") {
            let w = weight(base)?;
            let s = w.codes().stream(1);
            let idx: Vec<i32> = (0..s.len).map(|i| s.get(i) as i32).collect();
            Input::I32(idx, e.dims.clone())
        } else if let Some(base) = e.name.strip_suffix(".scales") {
            let w = weight(base)?;
            Input::F32(w.scales().to_vec(), e.dims.clone())
        } else if let Some(base) = e.name.strip_suffix(".signs") {
            let w = weight(base)?;
            let seed = w
                .rht_seed()
                .with_context(|| format!("'{base}' has no RHT seed"))?;
            let rht = crate::hadamard::RandomizedHadamard::new(w.rows(), seed);
            Input::F32(rht.signs().to_vec(), e.dims.clone())
        } else {
            // fp tensor (embeddings, norms)
            let t = q
                .fp_tensors
                .get(&e.name)
                .with_context(|| format!("missing fp tensor '{}'", e.name))?;
            Input::F32(t.as_slice().to_vec(), e.dims.clone())
        };
        anyhow::ensure!(
            input.dims() == e.dims.as_slice(),
            "input '{}' shape mismatch",
            e.name
        );
        out.push(input);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_greedy_limit() {
        // at tiny temperature sampling must match argmax
        let mut rng = Rng::new(1);
        let mut logits = vec![0.0f32; 32];
        logits[17] = 9.0;
        for _ in 0..20 {
            assert_eq!(sample(&logits, 0.05, &mut rng), 17);
        }
    }

    #[test]
    fn request_rng_is_slot_stable_and_slot_distinct() {
        // same (seed, slot) → identical stream; different slots → different
        let mut a = request_rng(7, 3);
        let mut b = request_rng(7, 3);
        let mut c = request_rng(7, 4);
        let same: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        assert!(same.iter().all(|&x| x == b.next_u64()));
        let other: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_ne!(same, other);
    }

    #[test]
    fn sample_respects_distribution() {
        let mut rng = Rng::new(2);
        let mut logits = vec![f32::NEG_INFINITY; 8];
        logits[2] = 0.0;
        logits[5] = 0.0;
        let mut counts = [0usize; 8];
        for _ in 0..2000 {
            counts[sample(&logits, 1.0, &mut rng) as usize] += 1;
        }
        assert_eq!(counts[0] + counts[1] + counts[3] + counts[4] + counts[6] + counts[7], 0);
        assert!(counts[2] > 800 && counts[5] > 800);
    }
}
