//! The generation server: batched and continuous iterative decoding.
//!
//! Three serving modes share the decode machinery:
//!
//! * **Fp** — dense weights bound to `fwd_fp_<model>_b8` (fp baseline, or
//!   any fake-quant model for ablations);
//! * **Quantized** — PCDVQ codes + codebooks bound to `fwd_q_<model>`, where
//!   dequantization happens *inside* the executable (gather + scale +
//!   inverse RHT fused by XLA): the dense weights never exist on the host,
//!   which is what shrinks the per-request weight traffic 8-16x (§4.4);
//! * **CodesResident** — the host backend ([`HostForward`]): every
//!   quantizable linear is served straight from its packed code streams via
//!   the blocked, LUT-driven kernel
//!   [`crate::quant::QuantizedWeight::matmul_from_codes`] (both decode/
//!   prefill paths — [`HostForward::decode_step`] matvecs and the
//!   `(chunk, d)` block-prefill matmuls — run the same kernel; DESIGN.md
//!   §11). No XLA artifact (and no dense weight) is involved at any point;
//!   resident weight state is exactly codes + shared codebooks plus their
//!   rebuildable decode LUTs, which
//!   [`crate::paper::verify_codes_resident`] checks against the §4.4 claim
//!   (LUTs counted as derived state, zero artifact bits).
//!
//! Two serving loops run on top:
//!
//! * [`Server::serve`] — **static batches**: [`Batcher::next_batch`]
//!   coalesces requests, [`Server::process_batch`] decodes the whole batch
//!   to completion. The only loop the fixed-geometry XLA executables
//!   support, and the baseline the `continuous_vs_static` bench compares
//!   against.
//! * [`Server::serve_continuous`] — **continuous batching with block
//!   prefill** (host backend): a persistent pool of [`Server::max_slots`]
//!   slots, each tracking its own phase
//!   (`Prefill { remaining } → Decode → Done`). Slots admit new requests
//!   the moment a sequence finishes — no batch barrier — and prompts enter
//!   the per-slot [`KvCache`] in [`Server::prefill_chunk`]-sized blocks
//!   ([`HostForward::prefill_extend`]), paying a single lazy head
//!   projection at the final chunk boundary. Per-request outputs are
//!   pinned token-for-token to single-request [`DecodePolicy::Reforward`]
//!   oracle runs by `tests/continuous_batching.rs` (DESIGN.md §9).
//!
//! The host backend decodes **incrementally** with one KV cache per slot
//! (reset at every request boundary — per-request state is explicit). By
//! default the slot caches are views onto a **block-paged pool**
//! ([`crate::model::kv_pool`]) and admissions attach shared pages for
//! prompt prefixes already resident in the [`PrefixCache`] trie, so a hot
//! prefix's prefill is paid once per server (DESIGN.md §13); the dense
//! per-slot layout stays reachable as the parity oracle
//! (`--kv-page-size 0`, [`validate_kv_page`]). The windowed re-forward
//! survives as [`DecodePolicy::Reforward`], both as the cross-layout
//! parity oracle and as the only option for the fixed-geometry XLA
//! executables.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use super::batcher::{Admitted, Batcher, FinishReason, GenRequest, GenResponse};
use super::fault::{run_supervised, Fault, FaultPlan};
use super::metrics::Metrics;
use super::prefix::{PrefixCache, PrefixStats};
use crate::codebook::{DirectionCodebook, MagnitudeCodebook};
use crate::eval::weight_inputs;
use crate::model::{
    GptModel, HostForward, KvCache, KvPool, KvPoolCounters, KvStore, PagedKvCache, QuantizedGpt,
};
use crate::quant::kv::{KvQuantCodec, KvQuantSpec};
use crate::rng::Rng;
use crate::runtime::{BoundExecutable, Engine, Input};

/// What the server serves.
pub enum ServingWeights {
    /// Dense weights (original or fake-quant) through the XLA `fwd_fp`
    /// executable — or the host backend via [`Server::builder`].
    Fp(GptModel),
    /// PCDVQ codes + the shared DACC codebooks through the XLA `fwd_q`
    /// executable (in-graph dequantization).
    Quantized(Box<QuantizedGpt>, DirectionCodebook, MagnitudeCodebook),
    /// Compressed artifacts served on the host: packed codes + shared
    /// codebooks are the only resident weight state.
    CodesResident(Box<QuantizedGpt>),
}

impl ServingWeights {
    fn model_name(&self) -> &str {
        match self {
            ServingWeights::Fp(m) => &m.name,
            ServingWeights::Quantized(q, _, _) => &q.name,
            ServingWeights::CodesResident(q) => &q.name,
        }
    }

    fn config(&self) -> crate::model::GptConfig {
        match self {
            ServingWeights::Fp(m) => m.config,
            ServingWeights::Quantized(q, _, _) => q.config,
            ServingWeights::CodesResident(q) => q.config,
        }
    }
}

/// The decode backend: a bound XLA executable, the host forward, or the
/// layer-sharded host chain.
enum Backend {
    Xla(BoundExecutable),
    Host(HostForward),
    Sharded(super::shard::ShardedForward),
}

/// How the server advances a decode step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DecodePolicy {
    /// Incremental decode against per-slot [`KvCache`]s — O(1) weight work
    /// per token. Host backend only (and its default).
    KvCached,
    /// Re-forward the whole window every token — O(window) per token. The
    /// parity oracle for the cached path, and the only policy the
    /// fixed-geometry XLA executables support.
    Reforward,
}

/// Lifecycle of one serving slot in the continuous loop. A slot is born in
/// `Prefill` (unless the request is degenerate), emits its first token at
/// the final prompt-chunk boundary, decodes one token per scheduler step,
/// and frees the slot for the next admission the step after `Done`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum SlotPhase {
    /// `remaining` prompt tokens still to enter the KV cache.
    Prefill { remaining: usize },
    /// Prompt absorbed; one generated token per step.
    Decode,
    /// Response ready to send; the slot frees this step.
    Done,
}

/// One active request in the slot pool.
struct Slot {
    req: GenRequest,
    seq: u64,
    queue_wait: std::time::Duration,
    /// Prompt truncated to the last `ctx - 1` tokens (same truncation as
    /// the static path).
    prompt: Vec<i32>,
    phase: SlotPhase,
    rng: Rng,
    generated: Vec<u8>,
    /// Logits of the position about to be sampled (valid while `Decode`).
    logits: Vec<f32>,
    /// Per-step logits when [`Server::capture_logits`] is set.
    captured: Vec<Vec<f32>>,
    ttft: Option<std::time::Duration>,
    /// Scheduler steps this request consumed (prefill chunks + decode).
    steps: usize,
    /// Prompt tokens attached from shared prefix pages at admission (0 on a
    /// cold prefix or under the dense layout).
    reused: usize,
    /// Whether this prompt's pages have been offered to the prefix trie.
    published: bool,
    /// Tokens already flushed to [`GenRequest::stream`] — the coordinator
    /// flushes `generated[streamed..]` after every scheduler step's join,
    /// in slot order, so streams are as deterministic as the outputs.
    streamed: usize,
    /// How this request will resolve. `Done` unless a supervised step
    /// faulted ([`FinishReason::Faulted`]) or the deadline expired
    /// mid-flight ([`FinishReason::TimedOut`]).
    finish: FinishReason,
}

impl Slot {
    /// Sample the next token from `self.logits`, record it, and flip to
    /// `Done` once `max_new` tokens exist.
    fn emit_token(&mut self, capture: bool) {
        let next = next_token(&self.logits, self.req.temperature, &mut self.rng);
        if capture {
            self.captured.push(self.logits.clone());
        }
        if self.generated.is_empty() {
            self.ttft = Some(self.req.enqueued.elapsed());
        }
        self.generated.push(next);
        if self.generated.len() >= self.req.max_new {
            self.phase = SlotPhase::Done;
        }
    }
}

/// What kind of model work one scheduler step ran on a slot (folded into
/// metrics on the coordinator thread after the parallel fan-out joins).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum StepKind {
    Prefill,
    Decode,
}

/// Per-slot KV storage: the block-paged pool layout
/// ([`crate::model::PagedKvCache`], the default) or the dense per-slot
/// buffers kept reachable as the parity oracle (`--kv-page-size 0`).
enum SlotCache {
    Dense(KvCache),
    Paged(PagedKvCache),
}

impl SlotCache {
    fn reset(&mut self) {
        match self {
            SlotCache::Dense(c) => c.reset(),
            SlotCache::Paged(c) => c.reset(),
        }
    }

    fn memory_bits(&self) -> u64 {
        match self {
            SlotCache::Dense(c) => c.memory_bits(),
            SlotCache::Paged(c) => c.memory_bits(),
        }
    }
}

/// One slot + its KV cache, owned exclusively by one pool worker for the
/// duration of a scheduler step. Under prefix sharing the worker's
/// exclusivity covers the *mutable tail* of the chain; attached prefix
/// pages are immutable and shared read-only (writes during a step always
/// target positions past them — see `model::kv_pool`'s COW rule for why
/// even a violation of that would stay correct).
struct SlotWork<'a> {
    /// Slot index — the coordinate faults are attributed to, and the key
    /// the coordinator folds outcomes back by.
    idx: usize,
    slot: &'a mut Slot,
    cache: &'a mut SlotCache,
}

/// Advance one active slot by one unit of work — one prompt chunk
/// ([`HostForward::prefill_extend`]; the final chunk pays the lazy head
/// projection and emits the first token) or one cached decode step. This is
/// the per-worker body of the continuous loop's slot fan-out: it touches
/// nothing but its own slot and cache, so any number of slots can step
/// concurrently with outputs identical to the serial walk. Generic over the
/// KV layout ([`KvStore`]): dense and paged caches step byte-identically.
fn step_slot<C: KvStore>(
    hf: &HostForward,
    slot: &mut Slot,
    cache: &mut C,
    chunk: usize,
    capture: bool,
) -> Result<StepKind> {
    match slot.phase {
        SlotPhase::Prefill { remaining } => {
            slot.steps += 1;
            let fed = slot.prompt.len() - remaining;
            let take = chunk.min(remaining);
            let block = &slot.prompt[fed..fed + take];
            if take == remaining {
                // final chunk: the one lazy head projection, which
                // immediately yields the first token
                slot.logits = hf.prefill_block(block, cache, chunk).context("prefill block")?;
                slot.phase = SlotPhase::Decode;
                slot.emit_token(capture);
            } else {
                hf.prefill_extend(block, cache, chunk).context("prefill extend")?;
                slot.phase = SlotPhase::Prefill { remaining: remaining - take };
            }
            Ok(StepKind::Prefill)
        }
        SlotPhase::Decode => {
            slot.steps += 1;
            let last = *slot.generated.last().expect("decode implies a token") as i32;
            slot.logits = hf.decode_step(last, cache).context("decode step")?;
            slot.emit_token(capture);
            Ok(StepKind::Decode)
        }
        SlotPhase::Done => unreachable!("Done slots are filtered before stepping"),
    }
}

/// [`step_slot`] under fault supervision (single-node continuous loop):
/// checks the injection plan for a (node 0, slot idx, step) coordinate
/// match, then runs the step inside `catch_unwind` so a panic or error
/// fails only this slot's request ([`super::fault`], DESIGN.md §17). Used
/// both by the inline codec-seeding step and inside the pool fan-out —
/// without it, a panic in a worker closure would unwind through
/// `exec::Pool::map_mut`'s join and kill the whole serving loop.
fn supervised_step(
    hf: &HostForward,
    w: &mut SlotWork<'_>,
    chunk: usize,
    capture: bool,
    plan: Option<&FaultPlan>,
) -> std::result::Result<StepKind, Fault> {
    let injected = plan.and_then(|p| p.fire(0, w.idx, w.slot.steps as u64));
    let idx = w.idx;
    run_supervised(0, idx, injected, || match w.cache {
        SlotCache::Dense(c) => step_slot(hf, w.slot, c, chunk, capture),
        SlotCache::Paged(c) => step_slot(hf, w.slot, c, chunk, capture),
    })
}

/// Decode one static-path request to completion against its own cache:
/// reset, fresh placement-derived sampling stream, full-prompt prefill,
/// then `max_new` cached decode steps. The per-worker body of
/// [`Server::process_batch`]'s slot fan-out, generic over the KV layout
/// ([`KvStore`]) so the dense and paged paths share one copy and cannot
/// drift.
#[allow(clippy::too_many_arguments)]
fn decode_one<C: KvStore>(
    hf: &HostForward,
    cache: &mut C,
    slot: u64,
    prompt_bytes: &[u8],
    max_new: usize,
    temperature: f32,
    seed: u64,
    ctx: usize,
    v: usize,
) -> Result<Vec<u8>> {
    cache.reset(); // new request → fresh cache
    let mut rng = request_rng(seed, slot);
    let prompt = truncate_prompt(prompt_bytes, ctx);
    let mut gen = Vec::new();
    if prompt.is_empty() {
        // degenerate request: resolve with zero tokens rather than
        // failing the whole batch (finish_batch responds)
        return Ok(gen);
    }
    let mut logits = hf.prefill(&prompt, cache).context("prefill")?;
    for step in 0..max_new {
        debug_assert_eq!(logits.len(), v);
        let next = next_token(&logits, temperature, &mut rng);
        gen.push(next);
        if step + 1 < max_new {
            logits = hf.decode_step(next as i32, cache).context("decode step")?;
        }
    }
    Ok(gen)
}

/// Snapshot of where every page the KV pool ever created currently lives
/// ([`Server::kv_page_audit`]). With every slot idle, `created ==
/// slot_free_pages + prefix_pages + dropped` and `slot_chain_pages == 0`
/// — the no-leak invariant the paged proptests assert.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KvPageAudit {
    /// Page buffers the pool ever materialized.
    pub created: u64,
    /// Buffers dropped out of circulation (trie evictions / clears).
    pub dropped: u64,
    /// Pages currently held by live slot chains.
    pub slot_chain_pages: u64,
    /// Recycled buffers parked on slot free lists.
    pub slot_free_pages: u64,
    /// Pages resident in the prefix trie.
    pub prefix_pages: u64,
}

/// A ready-to-serve model: backend + decode state.
pub struct Server {
    backend: Backend,
    pub config: crate::model::GptConfig,
    pub batch: usize,
    pub metrics: Metrics,
    /// Decode strategy. Host servers ([`Server::builder`]) default to
    /// [`DecodePolicy::KvCached`]; an XLA server ignores `KvCached` and
    /// re-forwards regardless (its executable geometry is fixed).
    pub decode: DecodePolicy,
    /// Seed for the per-request sampling streams: every request draws from a
    /// fresh `Rng` derived from this seed and its placement — the batch slot
    /// on the static path, the admission sequence number under continuous
    /// batching (so a sampled request's stream is independent of which slot
    /// happened to be free). Requests never inherit sampler state from
    /// earlier traffic.
    pub sampler_seed: u64,
    /// Slot-pool width for [`Self::serve_continuous`] (`serve --max-slots`).
    pub max_slots: usize,
    /// Prompt tokens per block-prefill step in the continuous loop
    /// (`serve --prefill-chunk`); defaults to `ctx / 4`.
    pub prefill_chunk: usize,
    /// Worker threads for the per-slot fan-out of the serving loops
    /// (`serve --threads`; defaults to [`crate::exec::default_threads`],
    /// i.e. `PALLAS_THREADS` or the available parallelism). When the slot
    /// pool runs more than one worker, each worker's *inner* kernels are
    /// pinned to one thread so the machine is not oversubscribed; at
    /// `threads = 1` the slots step serially and the fused matmul keeps its
    /// own column-strip parallelism. Outputs and metrics are identical at
    /// every setting (DESIGN.md §12).
    pub threads: usize,
    /// Capture per-step logits into [`GenResponse::logits`] (continuous
    /// loop only) — parity harnesses; off in normal serving.
    pub capture_logits: bool,
    /// KV layout: `Some(page_size)` → the block-paged pool
    /// ([`crate::model::kv_pool`], the default: `ctx / 8` pages, or
    /// `PALLAS_KV_PAGE`); `None` → dense per-slot buffers, kept reachable
    /// as the parity oracle (`serve --kv-page-size 0`). Validate CLI input
    /// with [`validate_kv_page`]. Changing this between serve calls
    /// rebuilds the slot caches on the next call.
    pub kv_page: Option<usize>,
    /// Cross-request prefix sharing (paged layout only): admissions attach
    /// shared pages for resident prompt prefixes and completed prompts
    /// publish their pages into the [`PrefixCache`] trie (DESIGN.md §13).
    /// `serve --no-prefix-share` turns it off.
    pub prefix_share: bool,
    /// Page budget of the prefix trie; LRU leaves evict past it.
    pub prefix_page_cap: usize,
    /// Cache quantization: `Some(bits)` stores K/V rows as polar-decoupled
    /// codes at `bits` bits per cached value (DESIGN.md §15,
    /// [`crate::quant::kv`]); `None` keeps exact f32 rows — the parity
    /// oracle (`serve --kv-quant 0`). Validate CLI input with
    /// [`validate_kv_quant`]; defaults to `PALLAS_KV_QUANT` (unset →
    /// exact). Changing this between serve calls rebuilds the slot caches
    /// (and the frozen codec) on the next call.
    pub kv_quant: Option<u32>,
    /// One KV cache per slot, built lazily on the host backend and
    /// **reset at every request boundary** — a new request always starts
    /// from an empty cache (possibly re-attaching shared prefix pages).
    slot_caches: Vec<SlotCache>,
    /// The shared page pool behind the paged slot caches (geometry +
    /// counters; pages themselves recycle through per-slot free lists).
    kv_pool: Option<KvPool>,
    /// The shared cache codec behind quantized slot caches: per-layer
    /// codebooks freeze on each layer's first write, `Arc`-shared with the
    /// pool so prefix pages published by one request decode identically
    /// for every attachment.
    kv_codec: Option<Arc<KvQuantCodec>>,
    /// High-water mark of the codec's decode-tile counter (for delta folds
    /// into [`Self::metrics`], mirroring `pool_seen`).
    kv_decoded_seen: u64,
    /// The prompt-prefix → page-chain trie (paged layout only).
    prefix: Option<PrefixCache>,
    /// High-water marks for folding pool/trie counter deltas into
    /// [`Self::metrics`] (counters accumulate across serve calls).
    pool_seen: KvPoolCounters,
    prefix_seen: PrefixStats,
    /// Live snapshot of [`Self::metrics`] for out-of-band scrapers
    /// ([`Self::metrics_mirror`]): the continuous loop copies its metrics
    /// in after every scheduler step, so `GET /metrics` on the ingress can
    /// read them while the serving thread owns the server.
    mirror: Option<Arc<Mutex<Metrics>>>,
    /// One-shot deterministic fault injection ([`FaultPlan`], DESIGN.md
    /// §17): set via `ServerBuilder::fault` or the `PALLAS_FAULT` env var;
    /// `None` in normal serving. `Arc` because pool workers check the plan
    /// concurrently during the slot fan-out.
    fault: Option<Arc<FaultPlan>>,
    /// Readiness latch for `/readyz` ([`Self::ready_signal`]): flips true
    /// at the first scheduler iteration of a continuous serve call.
    ready: Arc<AtomicBool>,
    /// Weight bits actually resident for the quantizable matrices (fp32 vs
    /// packed codes) — reported by the efficiency harness.
    pub resident_weight_bits: u64,
    /// Bits of the distinct shared codebooks resident beside the payloads
    /// (0 for dense serving; amortized across all layers otherwise).
    pub resident_codebook_bits: u64,
}

impl Server {
    /// Shared constructor core: backend + measured resident bits; every
    /// other serving default (batch/slot geometry, sampler seed, thread
    /// budget, prefill chunk) lives here once, so the XLA, host and
    /// sharded constructors can never drift apart.
    fn with_backend(
        backend: Backend,
        config: crate::model::GptConfig,
        decode: DecodePolicy,
        resident_weight_bits: u64,
        resident_codebook_bits: u64,
    ) -> Self {
        Server {
            backend,
            config,
            batch: 8,
            metrics: Metrics::new(),
            decode,
            sampler_seed: 0x5E84,
            max_slots: 8,
            prefill_chunk: (config.ctx / 4).max(1),
            threads: crate::exec::default_threads(),
            capture_logits: false,
            kv_page: default_kv_page(config.ctx),
            prefix_share: true,
            prefix_page_cap: 1024,
            kv_quant: default_kv_quant(),
            slot_caches: Vec::new(),
            kv_pool: None,
            kv_codec: None,
            kv_decoded_seen: 0,
            prefix: None,
            pool_seen: KvPoolCounters::default(),
            prefix_seen: PrefixStats::default(),
            mirror: None,
            fault: None,
            ready: Arc::new(AtomicBool::new(false)),
            resident_weight_bits,
            resident_codebook_bits,
        }
    }

    /// Start building a host-backed server (no XLA artifacts required) —
    /// the blessed construction path for everything but the XLA backend
    /// ([`Server::new`]). All knobs default exactly as documented on the
    /// corresponding [`Server`] fields:
    ///
    /// ```no_run
    /// # use pcdvq::coordinator::{Server, ServingWeights};
    /// # fn demo(weights: ServingWeights) -> anyhow::Result<()> {
    /// let server = Server::builder(weights)
    ///     .shards(1)
    ///     .threads(4)
    ///     .kv_page(8)
    ///     .prefix_share(true)
    ///     .build()?;
    /// # let _ = server; Ok(())
    /// # }
    /// ```
    pub fn builder(weights: ServingWeights) -> ServerBuilder {
        ServerBuilder {
            weights,
            shards: 1,
            threads: None,
            kv_page: None,
            kv_quant: None,
            prefix_share: None,
            prefix_page_cap: None,
            max_slots: None,
            prefill_chunk: None,
            decode: None,
            sampler_seed: None,
            capture_logits: false,
            batch: None,
            fault: None,
        }
    }

    /// Bind a serving model against its AOT artifact (XLA backend).
    pub fn new(engine: &Engine, artifacts_dir: &std::path::Path, weights: ServingWeights) -> Result<Self> {
        let config = weights.config();
        let batch = 8usize;
        let (bound, resident_weight_bits, resident_codebook_bits) = match &weights {
            ServingWeights::Fp(model) => {
                let base =
                    artifacts_dir.join(format!("fwd_fp_{}_b{batch}", weights.model_name()));
                let exe = engine.load(&base)?;
                let fixed = weight_inputs(model, &exe.manifest)?;
                let bits = model.config.quantizable_params() as u64 * 32;
                (exe.bind(&fixed, 1)?, bits, 0)
            }
            ServingWeights::Quantized(q, dir_cb, mag_cb) => {
                let base = artifacts_dir.join(format!("fwd_q_{}", weights.model_name()));
                let exe = engine.load(&base)?;
                let fixed = quantized_inputs(q, dir_cb, mag_cb, &exe.manifest)?;
                let cb_bits = q.codebook_bits();
                (exe.bind(&fixed, 1)?, q.payload_bits(), cb_bits)
            }
            ServingWeights::CodesResident(_) => anyhow::bail!(
                "codes-resident serving runs on the host — use Server::builder"
            ),
        };
        debug_assert_eq!(batch, 8, "XLA executables are lowered at batch 8");
        Ok(Server::with_backend(
            Backend::Xla(bound),
            config,
            DecodePolicy::Reforward,
            resident_weight_bits,
            resident_codebook_bits,
        ))
    }

    /// Build a host-backed server (no XLA artifacts required). `Fp` serves
    /// dense weights; `CodesResident` serves packed codes directly.
    #[deprecated(since = "0.2.0", note = "use `Server::builder(weights).build()`")]
    pub fn new_host(weights: ServingWeights) -> Result<Self> {
        Server::host_server(weights)
    }

    /// Constructor core of the single-node host backend (the
    /// [`Server::builder`] default).
    fn host_server(weights: ServingWeights) -> Result<Self> {
        let config = weights.config();
        let (hf, resident_weight_bits, resident_codebook_bits) = match weights {
            ServingWeights::Fp(model) => {
                let bits = model.config.quantizable_params() as u64 * 32;
                (HostForward::from_dense(model)?, bits, 0)
            }
            ServingWeights::CodesResident(q) => {
                let payload = q.payload_bits();
                let cb_bits = q.codebook_bits();
                (HostForward::from_quantized(*q)?, payload, cb_bits)
            }
            ServingWeights::Quantized(..) => anyhow::bail!(
                "the in-graph-dequant mode needs the fwd_q XLA artifact — \
                 use ServingWeights::CodesResident for host serving"
            ),
        };
        Ok(Server::with_backend(
            Backend::Host(hf),
            config,
            DecodePolicy::KvCached,
            resident_weight_bits,
            resident_codebook_bits,
        ))
    }

    /// Build a **layer-sharded** host server: the artifact collection is
    /// partitioned across `n_shards` worker nodes
    /// ([`super::shard::ShardedForward`]), each resident with only its layer
    /// range's packed codes plus one copy of every codebook those codes
    /// reference (codebook-once-per-node accounting — the reported
    /// `resident_codebook_bits` is the per-node dedup summed over nodes).
    /// Sharded servers decode incrementally against **node-owned** per-slot
    /// KV caches ([`DecodePolicy::KvCached`], DESIGN.md §16) and honor the
    /// same [`Server::kv_page`] / [`Server::kv_quant`] / prefix-sharing
    /// layout knobs as single-node serving; the windowed re-forward
    /// ([`DecodePolicy::Reforward`]) survives as the cross-topology parity
    /// oracle.
    #[deprecated(since = "0.2.0", note = "use `Server::builder(weights).shards(n).build()`")]
    pub fn new_host_sharded(weights: ServingWeights, n_shards: usize) -> Result<Self> {
        Server::sharded_server(weights, n_shards)
    }

    /// Constructor core of the layer-sharded host backend
    /// ([`ServerBuilder::shards`] > 1).
    fn sharded_server(weights: ServingWeights, n_shards: usize) -> Result<Self> {
        let config = weights.config();
        let ServingWeights::CodesResident(q) = weights else {
            anyhow::bail!(
                "layer-sharded serving partitions compressed artifacts — \
                 use ServingWeights::CodesResident"
            )
        };
        let sf = super::shard::ShardedForward::new(&q, n_shards)?;
        let payload = sf.payload_bits();
        let cb_bits = sf.codebook_bits();
        Ok(Server::with_backend(
            Backend::Sharded(sf),
            config,
            DecodePolicy::KvCached,
            payload,
            cb_bits,
        ))
    }

    /// One forward of a `(b, t)` token block through whichever backend.
    fn run_block(&self, block: Vec<i32>, b: usize, t: usize) -> Result<Vec<f32>> {
        match &self.backend {
            Backend::Xla(bound) => bound.run_f32(&[Input::I32(block, vec![b, t])]),
            Backend::Host(hf) => hf.forward(&block, b, t),
            Backend::Sharded(sf) => sf.forward(&block, b, t),
        }
    }

    /// True when the backend never materializes dense quantizable weights.
    pub fn is_codes_resident(&self) -> bool {
        match &self.backend {
            Backend::Host(hf) => hf.is_codes_resident(),
            Backend::Sharded(sf) => sf.is_codes_resident(),
            Backend::Xla(_) => false,
        }
    }

    /// Payload bits of KV-cache state currently allocated across slots
    /// (0 until the first cached batch). Dense: `slots ·
    /// cache.memory_bits()`. Paged: every page the pool ever created —
    /// whether currently in a chain, a free list, or the prefix trie —
    /// which is the honest footprint (pages are recycled, never freed).
    /// Under [`Server::kv_quant`] the payload is the word-aligned packed
    /// code words only; the frozen codebooks are a separate, shared
    /// account ([`Self::kv_codebook_bits`]) and the decoded f32 tiles are
    /// derived state counted by neither.
    pub fn kv_cache_bits(&self) -> u64 {
        if let Backend::Sharded(sf) = &self.backend {
            return sf.kv_cache_bits();
        }
        match &self.kv_pool {
            Some(pool) => pool.pages_created() * pool.page_bits(),
            None => self.slot_caches.iter().map(|c| c.memory_bits()).sum(),
        }
    }

    /// Resident K/V cache bits per shard node, in chain order (`None` on
    /// single-node backends — use [`Self::kv_cache_bits`]). Each node is
    /// charged only its own layer range's pages/windows.
    pub fn kv_cache_bits_per_node(&self) -> Option<Vec<u64>> {
        match &self.backend {
            Backend::Sharded(sf) => Some(sf.kv_cache_bits_per_node()),
            _ => None,
        }
    }

    /// Bits of the frozen per-layer cache codebooks (directions +
    /// magnitude levels, shared across every slot and page; 0 with an
    /// exact cache or before the first prefill freezes them). On the
    /// sharded backend this sums node codecs — K/V grids are per-layer, so
    /// they partition across nodes and the sum equals the single-node
    /// codec total bit-for-bit (unlike weight codebooks, which duplicate
    /// once per node).
    pub fn kv_codebook_bits(&self) -> u64 {
        if let Backend::Sharded(sf) = &self.backend {
            return sf.kv_codebook_bits();
        }
        self.kv_codec.as_ref().map_or(0, |c| c.codebook_bits())
    }

    /// Frozen cache-codebook bits per shard node, in chain order (`None`
    /// on single-node backends). Each node freezes only the grids of its
    /// own layer range.
    pub fn kv_codebook_bits_per_node(&self) -> Option<Vec<u64>> {
        match &self.backend {
            Backend::Sharded(sf) => Some(sf.kv_codebook_bits_per_node()),
            _ => None,
        }
    }

    /// The shared cache codec, once the slot caches have been built under
    /// [`Server::kv_quant`] (test/diagnostic hook). On the sharded backend
    /// this is node 0's codec — a layout probe (spec/geometry identical on
    /// every node), with only node 0's layer range frozen.
    pub fn kv_codec(&self) -> Option<&Arc<KvQuantCodec>> {
        if let Backend::Sharded(sf) = &self.backend {
            return sf.kv_codec();
        }
        self.kv_codec.as_ref()
    }

    /// Declared cache bits per value: `code_bits_per_row / d_model` under
    /// [`Server::kv_quant`] (word-alignment overhead included — the honest
    /// allocated rate), 32.0 for the exact f32 cache.
    pub fn kv_cache_bpw(&self) -> f64 {
        match self.kv_codec() {
            Some(c) => c.code_bits_per_row() as f64 / self.config.d_model as f64,
            None => 32.0,
        }
    }

    /// Pool counters since server construction (`None` under the dense
    /// layout). Test hook; the same deltas flow into [`Self::metrics`].
    /// Summed across node pools on the sharded backend.
    pub fn kv_pool_counters(&self) -> Option<KvPoolCounters> {
        if let Backend::Sharded(sf) = &self.backend {
            return sf.kv_pool_counters();
        }
        self.kv_pool.as_ref().map(|p| p.counters())
    }

    /// Pages currently resident in the prefix trie (0 when sharing is off
    /// or the layout is dense). Summed across node tries on the sharded
    /// backend.
    pub fn prefix_resident_pages(&self) -> usize {
        if let Backend::Sharded(sf) = &self.backend {
            return sf.prefix_resident_pages();
        }
        self.prefix.as_ref().map_or(0, |t| t.resident_pages())
    }

    /// Drop every published prefix page (their buffers leave the pool's
    /// accounting as `dropped`). The next request over any prefix is cold
    /// again — parity harnesses use this to compare hot vs cold runs.
    pub fn clear_prefix_cache(&mut self) {
        if let Backend::Sharded(sf) = &mut self.backend {
            sf.clear_prefix_caches();
        } else if let (Some(trie), Some(pool)) = (self.prefix.as_mut(), self.kv_pool.as_ref()) {
            trie.clear(pool);
        }
        self.sync_kv_metrics();
    }

    /// Where every page the pool ever created currently lives. With all
    /// slots idle (chains reset), `created == slot_free_pages +
    /// prefix_pages + dropped` and `slot_chain_pages == 0` — the no-leak
    /// invariant `tests/paged_kv.rs` asserts after every traffic pattern.
    /// On the sharded backend the snapshot sums node pools (the invariant
    /// holds per node — see [`Self::kv_page_audit_per_node`]).
    pub fn kv_page_audit(&self) -> Option<KvPageAudit> {
        if let Backend::Sharded(sf) = &self.backend {
            let audits = sf.kv_page_audit_per_node()?;
            return Some(audits.into_iter().fold(
                KvPageAudit {
                    created: 0,
                    dropped: 0,
                    slot_chain_pages: 0,
                    slot_free_pages: 0,
                    prefix_pages: 0,
                },
                |mut acc, a| {
                    acc.created += a.created;
                    acc.dropped += a.dropped;
                    acc.slot_chain_pages += a.slot_chain_pages;
                    acc.slot_free_pages += a.slot_free_pages;
                    acc.prefix_pages += a.prefix_pages;
                    acc
                },
            ));
        }
        let pool = self.kv_pool.as_ref()?;
        let mut chain = 0u64;
        let mut free = 0u64;
        for c in &self.slot_caches {
            if let SlotCache::Paged(p) = c {
                chain += p.pages().len() as u64;
                free += p.local_free_len() as u64;
            }
        }
        Some(KvPageAudit {
            created: pool.pages_created(),
            dropped: pool.counters().dropped,
            slot_chain_pages: chain,
            slot_free_pages: free,
            prefix_pages: self.prefix_resident_pages() as u64,
        })
    }

    /// Per-node page audit on the sharded backend (`None` on single-node
    /// backends or dense layouts): the no-leak invariant holds node by
    /// node, because pages never migrate between node pools.
    pub fn kv_page_audit_per_node(&self) -> Option<Vec<KvPageAudit>> {
        match &self.backend {
            Backend::Sharded(sf) => sf.kv_page_audit_per_node(),
            _ => None,
        }
    }

    /// Make at least `n` slot caches exist under the *current* layout
    /// ([`Self::kv_page`] × [`Self::kv_quant`]). A layout change (page size
    /// or cache bits toggled between serve calls) rebuilds from scratch:
    /// old caches, pool, trie and codec are dropped together so no page can
    /// outlive its pool's accounting and no code can outlive the codec that
    /// wrote it.
    fn ensure_slot_caches(&mut self, n: usize) -> Result<()> {
        let quant_stale = self.kv_codec.as_ref().map(|c| c.spec().bits()) != self.kv_quant;
        let stale = quant_stale
            || match (&self.kv_page, self.kv_pool.as_ref()) {
                (Some(ps), Some(pool)) => pool.page_size() != *ps,
                (Some(_), None) => !self.slot_caches.is_empty(),
                (None, Some(_)) => true,
                (None, None) => self
                    .slot_caches
                    .iter()
                    .any(|c| matches!(c, SlotCache::Paged(_))),
            };
        if stale {
            self.slot_caches.clear();
            if let (Some(trie), Some(pool)) = (self.prefix.as_mut(), self.kv_pool.as_ref()) {
                trie.clear(pool);
            }
            self.prefix = None;
            self.kv_pool = None;
            self.kv_codec = None;
            self.kv_decoded_seen = 0;
            self.pool_seen = KvPoolCounters::default();
            self.prefix_seen = PrefixStats::default();
        }
        if let Some(bits) = self.kv_quant {
            if self.kv_codec.is_none() {
                self.kv_codec = Some(Arc::new(KvQuantCodec::new(
                    KvQuantSpec::new(bits)?,
                    self.config.n_layer,
                    self.config.d_model,
                    self.sampler_seed ^ 0x6B76_7175_616E_7431,
                )));
            }
        }
        if let Some(ps) = self.kv_page {
            if self.kv_pool.is_none() {
                self.kv_pool =
                    Some(KvPool::with_codec(&self.config, ps, self.kv_codec.clone())?);
                self.prefix = Some(PrefixCache::new(ps, self.prefix_page_cap));
            }
        }
        while self.slot_caches.len() < n {
            self.slot_caches.push(match &self.kv_pool {
                Some(pool) => SlotCache::Paged(PagedKvCache::new(&self.config, pool)),
                None => {
                    SlotCache::Dense(KvCache::with_codec(&self.config, self.kv_codec.clone()))
                }
            });
        }
        Ok(())
    }

    /// Fold pool-counter and trie-stat deltas (since the last fold) into
    /// [`Self::metrics`]. Called at the end of each serving entry point so
    /// `Metrics::summary` and `BENCH_serving.json` see cumulative totals.
    /// On the sharded backend the sources are the node-owned pools / tries
    /// / codecs (summed — except prefix hit/miss/token stats, which are
    /// logical per-request counts and come from node 0 so the shard count
    /// doesn't multiply them); the delta registers work identically.
    fn sync_kv_metrics(&mut self) {
        let (pool_c, trie_s, decoded) = match &self.backend {
            Backend::Sharded(sf) => (
                sf.kv_pool_counters(),
                sf.prefix_stats(),
                sf.kv_codec().map(|_| sf.kv_decoded_subvecs()),
            ),
            _ => (
                self.kv_pool.as_ref().map(|p| p.counters()),
                self.prefix.as_ref().map(|t| t.stats()),
                self.kv_codec.as_ref().map(|c| c.decoded_subvecs()),
            ),
        };
        if let Some(c) = pool_c {
            self.metrics.kv_pages_allocated += c.allocated - self.pool_seen.allocated;
            self.metrics.kv_pages_reused += c.reused - self.pool_seen.reused;
            self.metrics.kv_pages_released += c.released - self.pool_seen.released;
            self.metrics.kv_pages_dropped += c.dropped - self.pool_seen.dropped;
            self.metrics.kv_cow_copies += c.cow_copies - self.pool_seen.cow_copies;
            self.pool_seen = c;
        }
        if let Some(s) = trie_s {
            self.metrics.prefix_hits += s.hits - self.prefix_seen.hits;
            self.metrics.prefix_misses += s.misses - self.prefix_seen.misses;
            self.metrics.prefix_tokens_reused += s.tokens_reused - self.prefix_seen.tokens_reused;
            self.metrics.prefix_pages_published +=
                s.pages_published - self.prefix_seen.pages_published;
            self.metrics.prefix_pages_evicted +=
                s.pages_evicted - self.prefix_seen.pages_evicted;
            self.prefix_seen = s;
        }
        if let Some(d) = decoded {
            self.metrics.kv_decoded_subvecs += d - self.kv_decoded_seen;
            self.kv_decoded_seen = d;
        }
        self.metrics.kv_cache_resident_bits = self.kv_cache_bits();
        self.metrics.kv_cache_codebook_bits = self.kv_codebook_bits();
        self.metrics.kv_cache_bpw = self.kv_cache_bpw();
    }

    /// Decode one batch of requests to completion; sends responses on each
    /// request's channel and updates metrics. The static path runs cached
    /// decode only on the single-node host backend; on the sharded backend
    /// it always decodes by windowed re-forward through the chain
    /// regardless of [`Self::decode`] — that is the cross-topology parity
    /// oracle (DESIGN.md §16). Sharded KV-cached decode lives in
    /// [`Self::serve_continuous`].
    pub fn process_batch(&mut self, batch: Vec<GenRequest>) -> Result<()> {
        anyhow::ensure!(
            batch.len() <= self.batch,
            "batch larger than executable geometry"
        );
        let cached = matches!(&self.backend, Backend::Host(_))
            && self.decode == DecodePolicy::KvCached;
        if cached {
            self.process_batch_cached(batch)
        } else {
            self.process_batch_reforward(batch)
        }
    }

    /// Incremental decode: per-slot KV caches, one token of model work per
    /// step. Each request starts from an explicitly reset cache and a fresh
    /// sampling stream — no state crosses request boundaries, so the slots
    /// fan out across [`Self::threads`] pool workers (each owning its slot's
    /// cache and sampler exclusively) with outputs bit-identical to the
    /// serial walk.
    fn process_batch_cached(&mut self, batch: Vec<GenRequest>) -> Result<()> {
        let t0 = Instant::now();
        let ctx = self.config.ctx;
        let v = self.config.vocab;
        let seed = self.sampler_seed;
        self.ensure_slot_caches(batch.len())?;
        let Backend::Host(hf) = &self.backend else {
            anyhow::bail!("cached decode needs the host backend")
        };

        /// One batch slot's work unit: shareable request fields + exclusive
        /// cache ownership (the response `Sender` stays on the coordinator).
        struct CachedWork<'a> {
            slot: usize,
            prompt: &'a [u8],
            max_new: usize,
            temperature: f32,
            cache: &'a mut SlotCache,
        }
        let mut work: Vec<CachedWork> = batch
            .iter()
            .enumerate()
            .zip(self.slot_caches.iter_mut())
            .map(|((slot, req), cache)| CachedWork {
                slot,
                prompt: &req.prompt,
                max_new: req.max_new,
                temperature: req.temperature,
                cache,
            })
            .collect();
        // codebook-freeze determinism (§15): per-layer cache codebooks
        // freeze on each layer's first-ever write, and under a multi-worker
        // fan-out "first" would be scheduling-dependent — so while any
        // layer is unfrozen, slot 0 decodes inline on the coordinator
        // thread before the fan-out, seeding every layer's codebook from
        // the same rows at every thread count.
        let mut head: Option<Result<Vec<u8>>> = None;
        if let Some(codec) = self.kv_codec.clone() {
            if !codec.frozen() && !work.is_empty() {
                let w = work.remove(0);
                head = Some(match w.cache {
                    SlotCache::Dense(c) => {
                        decode_one(hf, c, w.slot as u64, w.prompt, w.max_new, w.temperature, seed, ctx, v)
                    }
                    SlotCache::Paged(c) => {
                        decode_one(hf, c, w.slot as u64, w.prompt, w.max_new, w.temperature, seed, ctx, v)
                    }
                });
            }
        }
        let pool = crate::exec::Pool::new(self.threads.max(1));
        // the shared nesting policy: pin inner kernels only when the
        // request fan-out is real (exec::Pool::inner_threads)
        let inner = pool.inner_threads(work.len());
        let results = pool.map_mut(&mut work, |_, w| -> Result<Vec<u8>> {
            crate::exec::with_threads(inner, || match w.cache {
                SlotCache::Dense(c) => {
                    decode_one(hf, c, w.slot as u64, w.prompt, w.max_new, w.temperature, seed, ctx, v)
                }
                SlotCache::Paged(c) => {
                    decode_one(hf, c, w.slot as u64, w.prompt, w.max_new, w.temperature, seed, ctx, v)
                }
            })
        });
        let mut generated: Vec<Vec<u8>> = Vec::with_capacity(batch.len());
        for r in head.into_iter().chain(results) {
            generated.push(r?);
        }

        let steps = batch.iter().map(|r| r.max_new).max().unwrap_or(0);
        self.finish_batch(t0, &batch, &generated, steps);
        self.sync_kv_metrics();
        Ok(())
    }

    /// Windowed re-forward: the whole prefix through the backend every step.
    /// The parity oracle for [`DecodePolicy::KvCached`], and the decode loop
    /// of the fixed-geometry XLA executables.
    fn process_batch_reforward(&mut self, batch: Vec<GenRequest>) -> Result<()> {
        let t0 = Instant::now();
        let ctx = self.config.ctx;
        let b = self.batch;

        // Per-slot state: token buffer + generated bytes.
        let mut bufs: Vec<Vec<i32>> = Vec::with_capacity(b);
        let mut lens: Vec<usize> = Vec::with_capacity(b);
        for req in &batch {
            let p = truncate_prompt(&req.prompt, ctx);
            lens.push(p.len());
            bufs.push(p);
        }
        let max_new = batch.iter().map(|r| r.max_new).max().unwrap_or(0);
        let mut generated: Vec<Vec<u8>> = vec![Vec::new(); batch.len()];
        let mut rngs: Vec<Rng> = (0..batch.len())
            .map(|s| request_rng(self.sampler_seed, s as u64))
            .collect();

        let mut steps = 0usize;
        for _ in 0..max_new {
            // assemble the (B, ctx) token block
            let mut block = vec![0i32; b * ctx];
            for (s, buf) in bufs.iter().enumerate() {
                let start = buf.len().saturating_sub(ctx);
                for (j, &t) in buf[start..].iter().enumerate() {
                    block[s * ctx + j] = t;
                }
            }
            let logits = self.run_block(block, b, ctx).context("decode step")?;
            steps += 1;
            let v = self.config.vocab;
            for (s, req) in batch.iter().enumerate() {
                // empty-prompt slots resolve with zero tokens (no position
                // to predict from), mirroring the cached path
                if generated[s].len() >= req.max_new || lens[s] == 0 {
                    continue;
                }
                let pos = (lens[s].min(ctx)) - 1;
                let row = &logits[(s * ctx + pos) * v..(s * ctx + pos + 1) * v];
                let next = next_token(row, req.temperature, &mut rngs[s]);
                generated[s].push(next);
                bufs[s].push(next as i32);
                if bufs[s].len() > ctx {
                    // sliding window: len stays ctx, predict from the end
                    lens[s] = ctx;
                } else {
                    lens[s] = bufs[s].len();
                }
            }
        }

        self.finish_batch(t0, &batch, &generated, steps);
        Ok(())
    }

    /// Shared batch epilogue: responses + metrics.
    fn finish_batch(
        &mut self,
        t0: Instant,
        batch: &[GenRequest],
        generated: &[Vec<u8>],
        steps: usize,
    ) {
        let mut tokens = 0usize;
        for (s, (req, gen)) in batch.iter().zip(generated.iter()).enumerate() {
            tokens += gen.len();
            let resp = GenResponse {
                generated: gen.clone(),
                latency: req.enqueued.elapsed(),
                steps,
                seq: s as u64,
                queue_wait: t0.saturating_duration_since(req.enqueued),
                ttft: None,
                logits: Vec::new(),
                finish: FinishReason::Done,
            };
            self.metrics.record_latency(resp.latency);
            req.resp.send(resp).ok();
        }
        self.metrics.record_batch(batch.len(), tokens, steps);
        self.metrics.wall_s += t0.elapsed().as_secs_f64();
    }

    /// A live, lock-guarded snapshot of [`Self::metrics`] for scrapers on
    /// other threads (the ingress `GET /metrics` endpoint). The continuous
    /// loop refreshes the snapshot after every scheduler step; before the
    /// first serve call it reads as the current metrics.
    pub fn metrics_mirror(&mut self) -> Arc<Mutex<Metrics>> {
        if self.mirror.is_none() {
            self.mirror = Some(Arc::new(Mutex::new(self.metrics.clone())));
        }
        self.mirror.as_ref().expect("just installed").clone()
    }

    /// Readiness flag for the ingress `/readyz` probe: `false` until the
    /// continuous loop has completed its first scheduler iteration, `true`
    /// from then on. Cloned by [`super::Ingress::spawn`] before the server
    /// moves onto its serving thread.
    pub fn ready_signal(&self) -> Arc<AtomicBool> {
        self.ready.clone()
    }

    /// Refresh the out-of-band snapshot, if anyone asked for one.
    fn publish_mirror(&self) {
        if let Some(m) = &self.mirror {
            if let Ok(mut guard) = m.lock() {
                *guard = self.metrics.clone();
            }
        }
    }

    /// Fold the batcher's admission-side resolution counters (timeouts,
    /// sheds) into metrics past the `(timed_out, shed)` high-water marks in
    /// `seen`. (The counters accumulate across serve calls and batchers.)
    fn sync_admission_counters(&mut self, batcher: &Batcher, seen: &mut (u64, u64)) {
        let t = batcher.timed_out();
        self.metrics.timeouts += t - seen.0;
        seen.0 = t;
        let s = batcher.shed();
        self.metrics.shed += s - seen.1;
        seen.1 = s;
    }

    /// Serve static batches until the request channel closes.
    pub fn serve(&mut self, batcher: &mut Batcher) -> Result<()> {
        let mut seen = (batcher.timed_out(), batcher.shed());
        while let Some(batch) = batcher.next_batch() {
            self.sync_admission_counters(batcher, &mut seen);
            self.process_batch(batch)?;
        }
        self.sync_admission_counters(batcher, &mut seen);
        Ok(())
    }

    /// Serve with **continuous batching + block prefill** until the request
    /// channel closes (host or sharded backend, [`DecodePolicy::KvCached`]
    /// only).
    ///
    /// On the **sharded** backend ([`ServerBuilder::shards`] > 1) the same
    /// loop runs against node-owned per-slot caches: each shard node holds
    /// K/V state for its own layer range, the coordinator routes one
    /// activation block per slot per step through the chain
    /// ([`super::shard::ShardedForward::step_slots`] — pipelined, one
    /// worker thread per node), and admission / streaming / publication /
    /// completion all stay on the coordinator thread in slot order.
    /// Outputs are token-identical to the single-node cached path at every
    /// shard count × page size × cache width (DESIGN.md §16).
    ///
    /// The step loop: (1) admit queued requests into free slots — a slot
    /// frees the moment its sequence completes, with no batch barrier;
    /// (2) advance every active slot by one unit of work — one
    /// [`Self::prefill_chunk`]-sized prompt block
    /// ([`HostForward::prefill_extend`]; the final chunk pays the single
    /// lazy head projection and emits the first token), or one cached
    /// decode step; (3) complete finished slots (response + metrics) so
    /// the next admission can reuse them. When every slot is idle the loop
    /// parks on the queue instead of spinning.
    ///
    /// Step (2) fans the active slots out across [`Self::threads`] workers
    /// on the shared pool ([`crate::exec::Pool`]): each worker owns its
    /// slot + [`KvCache`] exclusively (no locks), inner kernels are pinned
    /// to one thread while the pool is wider than one, and every counter
    /// folds into [`Self::metrics`] on the coordinator thread in slot order
    /// after the join — batched decode across independent slots is where
    /// continuous batching earns multi-core throughput, and outputs stay
    /// bit-identical to the serial walk at every thread count (DESIGN.md
    /// §12).
    ///
    /// Per-request state is explicit, exactly as in the static cached path:
    /// a reset cache and a fresh sampling stream per request (derived
    /// from the admission `seq`, so streams are independent of slot
    /// placement). Greedy outputs are therefore token-identical to
    /// single-request oracle runs regardless of traffic interleaving.
    ///
    /// Under the paged layout ([`Self::kv_page`], the default) with
    /// [`Self::prefix_share`] on, admission additionally attaches shared
    /// pages covering the longest whole-page prompt prefix resident in the
    /// [`PrefixCache`], prefill runs only the cold suffix, and the step a
    /// prompt finishes prefilling its whole pages are published back to the
    /// trie. Attached pages hold exactly the K/V rows the model would have
    /// recomputed, so outputs stay token-identical to the dense layout and
    /// to the [`DecodePolicy::Reforward`] oracle (DESIGN.md §13).
    pub fn serve_continuous(&mut self, batcher: &mut Batcher) -> Result<()> {
        anyhow::ensure!(
            self.decode == DecodePolicy::KvCached,
            "continuous batching decodes incrementally — use \
             DecodePolicy::KvCached (Reforward is the static-path oracle)"
        );
        match &self.backend {
            Backend::Host(_) => self.serve_continuous_host(batcher),
            Backend::Sharded(_) => self.serve_continuous_sharded(batcher),
            Backend::Xla(_) => anyhow::bail!(
                "continuous batching requires the host or sharded backend \
                 (per-slot KV caches)"
            ),
        }
    }

    /// Single-node body of [`Self::serve_continuous`].
    fn serve_continuous_host(&mut self, batcher: &mut Batcher) -> Result<()> {
        let n = self.max_slots.max(1);
        let chunk = self.prefill_chunk.max(1);
        let ctx = self.config.ctx;
        self.ensure_slot_caches(n)?;
        let Backend::Host(hf) = &self.backend else { unreachable!() };
        let mut slots: Vec<Option<Slot>> = (0..n).map(|_| None).collect();
        let mut seen = (batcher.timed_out(), batcher.shed());
        self.ready.store(true, Ordering::SeqCst);

        loop {
            // ---- admission: fill free slots from the queue ----
            let mut active = slots.iter().filter(|s| s.is_some()).count();
            if active == 0 && !batcher.wait_any() {
                break; // stream closed and fully drained
            }
            if active < n {
                for Admitted { req, seq, admitted } in batcher.poll_admit(n - active) {
                    let queue_wait = admitted.saturating_duration_since(req.enqueued);
                    self.metrics.record_queue_wait(queue_wait);
                    let prompt = truncate_prompt(&req.prompt, ctx);
                    let rng = request_rng(self.sampler_seed, seq);
                    let idx = slots
                        .iter()
                        .position(|s| s.is_none())
                        .expect("admission capped at free slots");
                    self.slot_caches[idx].reset(); // new request → fresh cache
                    // prefix sharing: attach resident pages covering the
                    // longest whole-page prompt prefix, so prefill only
                    // runs the cold suffix through the model (§13)
                    let mut reused = 0usize;
                    if self.prefix_share && !prompt.is_empty() && req.max_new > 0 {
                        if let (SlotCache::Paged(cache), Some(trie)) =
                            (&mut self.slot_caches[idx], self.prefix.as_mut())
                        {
                            let (chain, covered) = trie.lookup(&prompt);
                            if covered > 0 {
                                cache.attach(&chain, &prompt[..covered]);
                            }
                            reused = covered;
                        }
                    }
                    // degenerate requests resolve with zero tokens without
                    // occupying a scheduler step's worth of model work
                    let phase = if prompt.is_empty() || req.max_new == 0 {
                        SlotPhase::Done
                    } else {
                        // lookup never covers the whole prompt, so at
                        // least one token always prefills through the
                        // model (the head needs fresh logits)
                        SlotPhase::Prefill { remaining: prompt.len() - reused }
                    };
                    slots[idx] = Some(Slot {
                        req,
                        seq,
                        queue_wait,
                        prompt,
                        phase,
                        rng,
                        generated: Vec::new(),
                        logits: Vec::new(),
                        captured: Vec::new(),
                        ttft: None,
                        steps: 0,
                        reused,
                        published: false,
                        streamed: 0,
                        finish: FinishReason::Done,
                    });
                    active += 1;
                }
            }
            self.sync_admission_counters(batcher, &mut seen);
            if active == 0 {
                self.publish_mirror();
                continue; // everything admitted had expired — park again
            }

            // ---- deadlines: expire in-flight requests before model work ----
            // A deadline that lapses mid-prefill (or mid-decode) finishes
            // the request as `TimedOut` with whatever tokens it has; the
            // completion pass below reclaims the slot and its pages, so the
            // next admission reuses them cleanly.
            let now = Instant::now();
            for entry in slots.iter_mut() {
                let Some(slot) = entry else { continue };
                if slot.phase != SlotPhase::Done
                    && slot.req.deadline.is_some_and(|d| now >= d)
                {
                    slot.phase = SlotPhase::Done;
                    slot.finish = FinishReason::TimedOut;
                    self.metrics.timeouts += 1;
                }
            }

            // ---- one unit of work per active slot, fanned out on the pool ----
            // Each worker owns its slot + KV cache exclusively; counters
            // fold into metrics on this thread, in slot order, after the
            // join — so outputs AND metrics are identical at every thread
            // count (the §12 determinism contract).
            let t0 = Instant::now();
            let capture = self.capture_logits;
            let pool = crate::exec::Pool::new(self.threads.max(1));
            let mut work: Vec<SlotWork> = slots
                .iter_mut()
                .zip(self.slot_caches.iter_mut())
                .enumerate()
                .filter_map(|(idx, (entry, cache))| match entry {
                    Some(slot) if slot.phase != SlotPhase::Done => {
                        Some(SlotWork { idx, slot, cache })
                    }
                    _ => None,
                })
                .collect();
            let worked = work.len(); // slots that ran model work this step
            // codebook-freeze determinism (§15): while any layer's cache
            // codebook is still unfrozen, the lowest-index busy slot steps
            // inline on the coordinator thread first — its chunk writes a
            // row to every layer, freezing all codebooks from the same
            // deterministic seed rows at every thread count. Slots are
            // independent within a round, so outputs are unchanged.
            let fault = self.fault.clone();
            let mut inline_outcome = None;
            if let Some(codec) = self.kv_codec.clone() {
                if !codec.frozen() && !work.is_empty() {
                    let mut w = work.remove(0);
                    let r = supervised_step(hf, &mut w, chunk, capture, fault.as_deref());
                    inline_outcome = Some((w.idx, r));
                }
            }
            // the shared nesting policy: pin inner kernels to one thread
            // only when the slot fan-out is real — a lone active slot (or
            // a 1-thread pool) keeps the matmul's column-strip /
            // attention-row parallelism (exec::Pool::inner_threads)
            let inner = pool.inner_threads(work.len());
            let outcomes = pool.map_mut(&mut work, |_, w| {
                let idx = w.idx;
                let r = crate::exec::with_threads(inner, || {
                    supervised_step(hf, w, chunk, capture, fault.as_deref())
                });
                (idx, r)
            });
            drop(work);
            // fold in slot order (inline outcome is always the lowest busy
            // slot): successful decode steps count; a fault fails only its
            // own request — `Faulted`, slot quarantined, cache rebuilt —
            // every other slot's outcome is untouched (DESIGN.md §17)
            for (idx, outcome) in inline_outcome.into_iter().chain(outcomes) {
                match outcome {
                    Ok(StepKind::Decode) => self.metrics.decode_steps += 1,
                    Ok(_) => {}
                    Err(f) => {
                        self.metrics.record_fault(f.kind.as_str(), f.node);
                        if let Some(slot) = slots[idx].as_mut() {
                            slot.phase = SlotPhase::Done;
                            slot.finish = FinishReason::Faulted;
                        }
                        // quarantine: drop the poisoned KV state so the
                        // next admission starts from a clean rebuild
                        self.slot_caches[idx].reset();
                    }
                }
            }
            // occupancy counts slots that actually ran model work — a
            // degenerate request parked in Done does not inflate it
            self.metrics.record_occupancy(worked, n);
            self.metrics.wall_s += t0.elapsed().as_secs_f64();

            // ---- streaming: flush freshly generated tokens ----
            // Coordinator thread only, slot order — workers never do I/O,
            // so the §12 determinism contract covers token streams too. A
            // dropped receiver just stops listening; generation proceeds
            // and the final response still carries the full output.
            for entry in slots.iter_mut() {
                let Some(slot) = entry else { continue };
                match &slot.req.stream {
                    Some(stream) => {
                        while slot.streamed < slot.generated.len() {
                            stream.send(slot.generated[slot.streamed]).ok();
                            slot.streamed += 1;
                        }
                    }
                    None => slot.streamed = slot.generated.len(),
                }
            }

            // ---- publication: offer freshly-prefilled prompts' pages ----
            // The step a slot leaves Prefill its cache holds exactly the
            // prompt (`len == prompt.len()` — the first decode write lands
            // next step), so its whole pages are immutable from here on and
            // safe to share. Runs on the coordinator thread only; `publish`
            // is idempotent-first, so racing admissions are impossible and
            // repeated prompts keep the already-resident pages (§13).
            if self.prefix_share {
                if let (Some(pool), Some(trie)) = (self.kv_pool.as_ref(), self.prefix.as_mut()) {
                    for (entry, cache) in slots.iter_mut().zip(self.slot_caches.iter()) {
                        let Some(slot) = entry else { continue };
                        if slot.published
                            || matches!(slot.phase, SlotPhase::Prefill { .. })
                            || slot.prompt.is_empty()
                            || slot.finish != FinishReason::Done
                        {
                            continue;
                        }
                        if let SlotCache::Paged(c) = cache {
                            if c.len() == slot.prompt.len() {
                                trie.publish(&slot.prompt, c.pages(), pool);
                            }
                        }
                        slot.published = true;
                    }
                }
            }

            // ---- completions: respond and free slots ----
            for (entry, cache) in slots.iter_mut().zip(self.slot_caches.iter_mut()) {
                let done = matches!(entry, Some(s) if s.phase == SlotPhase::Done);
                if !done {
                    continue;
                }
                let slot = entry.take().expect("checked above");
                self.metrics.requests += 1;
                self.metrics.tokens_generated += slot.generated.len() as u64;
                if let Some(t) = slot.ttft {
                    self.metrics.record_ttft(t);
                    // hot/cold TTFT breakdown: did this prompt ride shared
                    // prefix pages? (always cold under the dense layout)
                    if slot.reused > 0 {
                        self.metrics.record_ttft_hot(t);
                    } else {
                        self.metrics.record_ttft_cold(t);
                    }
                }
                let resp = GenResponse {
                    generated: slot.generated,
                    latency: slot.req.enqueued.elapsed(),
                    steps: slot.steps,
                    seq: slot.seq,
                    queue_wait: slot.queue_wait,
                    ttft: slot.ttft,
                    logits: slot.captured,
                    finish: slot.finish,
                };
                self.metrics.record_latency(resp.latency);
                slot.req.resp.send(resp).ok();
                // return the chain's pages promptly (published pages stay
                // resident through the trie's refs): idle slots hold no
                // pages, which keeps the no-leak audit exact
                cache.reset();
            }
            self.publish_mirror();
        }
        self.sync_kv_metrics();
        self.publish_mirror();
        Ok(())
    }

    /// Sharded body of [`Self::serve_continuous`] (DESIGN.md §16): the
    /// same admit → step → stream → publish → complete loop as the host
    /// path, with per-slot K/V state owned by the shard nodes. Each
    /// scheduler step builds one [`super::shard::ShardStepJob`] per active
    /// slot (in slot order) and hands the batch to
    /// [`super::shard::ShardedForward::step_slots`], which pipelines the
    /// blocks through one worker thread per node — node `i` advances slot
    /// `j`'s block while node `i+1` still runs slot `j−1`'s. Everything
    /// else (sampling, streaming, prefix publication, completions, metric
    /// folds) stays on the coordinator thread in slot order, so outputs
    /// AND metrics are bit-identical at every `PALLAS_THREADS` *and* every
    /// shard count (§12 extended to topology).
    fn serve_continuous_sharded(&mut self, batcher: &mut Batcher) -> Result<()> {
        let n = self.max_slots.max(1);
        let chunk = self.prefill_chunk.max(1);
        let ctx = self.config.ctx;
        let threads = self.threads.max(1);
        let capture = self.capture_logits;
        let (kv_page, kv_quant) = (self.kv_page, self.kv_quant);
        // same codec seed derivation as the single-node server, and node
        // codecs keep full-model geometry — that pair is what makes the
        // frozen grids (and thus logits) bit-identical across topologies
        let codec_seed = self.sampler_seed ^ 0x6B76_7175_616E_7431;
        let prefix_cap = self.prefix_page_cap;
        {
            let Backend::Sharded(sf) = &mut self.backend else { unreachable!() };
            if sf.ensure_slot_caches(n, kv_page, kv_quant, codec_seed, prefix_cap)? {
                // layout rebuilt from scratch: zero the delta registers so
                // the next fold doesn't subtract stale high-water marks
                self.kv_decoded_seen = 0;
                self.pool_seen = KvPoolCounters::default();
                self.prefix_seen = PrefixStats::default();
            }
        }
        let mut slots: Vec<Option<Slot>> = (0..n).map(|_| None).collect();
        let mut seen = (batcher.timed_out(), batcher.shed());
        self.ready.store(true, Ordering::SeqCst);

        loop {
            // ---- admission: fill free slots from the queue ----
            let mut active = slots.iter().filter(|s| s.is_some()).count();
            if active == 0 && !batcher.wait_any() {
                break; // stream closed and fully drained
            }
            if active < n {
                for Admitted { req, seq, admitted } in batcher.poll_admit(n - active) {
                    let queue_wait = admitted.saturating_duration_since(req.enqueued);
                    self.metrics.record_queue_wait(queue_wait);
                    let prompt = truncate_prompt(&req.prompt, ctx);
                    let rng = request_rng(self.sampler_seed, seq);
                    let idx = slots
                        .iter()
                        .position(|s| s.is_none())
                        .expect("admission capped at free slots");
                    let Backend::Sharded(sf) = &mut self.backend else { unreachable!() };
                    sf.reset_slot(idx); // new request → fresh windows on every node
                    let mut reused = 0usize;
                    if self.prefix_share && !prompt.is_empty() && req.max_new > 0 {
                        reused = sf.attach_prefix(idx, &prompt);
                    }
                    let phase = if prompt.is_empty() || req.max_new == 0 {
                        SlotPhase::Done
                    } else {
                        SlotPhase::Prefill { remaining: prompt.len() - reused }
                    };
                    slots[idx] = Some(Slot {
                        req,
                        seq,
                        queue_wait,
                        prompt,
                        phase,
                        rng,
                        generated: Vec::new(),
                        logits: Vec::new(),
                        captured: Vec::new(),
                        ttft: None,
                        steps: 0,
                        reused,
                        published: false,
                        streamed: 0,
                        finish: FinishReason::Done,
                    });
                    active += 1;
                }
            }
            self.sync_admission_counters(batcher, &mut seen);
            if active == 0 {
                self.publish_mirror();
                continue; // everything admitted had expired — park again
            }

            // ---- deadlines: expire in-flight requests before model work ----
            // Same contract as the host loop: a lapsed deadline finishes
            // the request as `TimedOut` with the tokens it has; completion
            // below reclaims the slot's windows on every node.
            let now = Instant::now();
            for entry in slots.iter_mut() {
                let Some(slot) = entry else { continue };
                if slot.phase != SlotPhase::Done
                    && slot.req.deadline.is_some_and(|d| now >= d)
                {
                    slot.phase = SlotPhase::Done;
                    slot.finish = FinishReason::TimedOut;
                    self.metrics.timeouts += 1;
                }
            }

            // ---- one unit of work per active slot, pipelined on the chain ----
            // Jobs are built in slot order; `step_slots` commits each
            // node's writes in that same order (and steps sequentially on
            // this thread while any node codec is still seeding its
            // grids), so the §15 freeze determinism carries over.
            let t0 = Instant::now();
            let mut jobs: Vec<super::shard::ShardStepJob> = Vec::new();
            for (idx, entry) in slots.iter().enumerate() {
                let Some(slot) = entry else { continue };
                match slot.phase {
                    SlotPhase::Done => {}
                    SlotPhase::Prefill { remaining } => {
                        let fed = slot.prompt.len() - remaining;
                        let take = chunk.min(remaining);
                        jobs.push(super::shard::ShardStepJob {
                            slot: idx,
                            tokens: slot.prompt[fed..fed + take].to_vec(),
                            // the final chunk pays the one lazy head
                            // projection and emits the first token
                            want_logits: take == remaining,
                        });
                    }
                    SlotPhase::Decode => {
                        let last =
                            *slot.generated.last().expect("decode implies a token") as i32;
                        jobs.push(super::shard::ShardStepJob {
                            slot: idx,
                            tokens: vec![last],
                            want_logits: true,
                        });
                    }
                }
            }
            let worked = jobs.len(); // slots that ran model work this step
            // fault injection (DESIGN.md §17): if the plan's (slot, step)
            // coordinate is stepping this round, arm the chain so the
            // plan's node trips inside that slot's supervised stage
            let mut armed = None;
            if let Some(plan) = self.fault.clone() {
                for job in &jobs {
                    let steps =
                        slots[job.slot].as_ref().expect("job slots are active").steps as u64;
                    if let Some(mode) = plan.fire(plan.node, job.slot, steps) {
                        armed = Some((plan.node, job.slot, mode));
                    }
                }
            }
            let results = {
                let Backend::Sharded(sf) = &mut self.backend else { unreachable!() };
                sf.arm_fault(armed);
                crate::exec::with_threads(threads, || sf.step_slots(&jobs))?
            };
            // fold outcomes on the coordinator, in slot (= job) order: a
            // faulted job fails only its own request (`Faulted`, windows
            // rebuilt on every node); every other outcome is exactly what
            // a fault-free run produces (the poisoned marker never touches
            // other jobs' activations or cache writes)
            for (job, outcome) in jobs.iter().zip(results) {
                let slot = slots[job.slot].as_mut().expect("job slots are active");
                let logits = match outcome {
                    super::shard::SlotStepOutcome::Logits(l) => l,
                    super::shard::SlotStepOutcome::Fault(f) => {
                        self.metrics.record_fault(f.kind.as_str(), f.node);
                        slot.phase = SlotPhase::Done;
                        slot.finish = FinishReason::Faulted;
                        // quarantine: drop the poisoned windows on every
                        // node so the next admission rebuilds from clean
                        let Backend::Sharded(sf) = &mut self.backend else { unreachable!() };
                        sf.reset_slot(job.slot);
                        continue;
                    }
                };
                slot.steps += 1;
                match slot.phase {
                    SlotPhase::Prefill { remaining } => {
                        if let Some(l) = logits {
                            slot.logits = l;
                            slot.phase = SlotPhase::Decode;
                            slot.emit_token(capture);
                        } else {
                            slot.phase = SlotPhase::Prefill {
                                remaining: remaining - job.tokens.len(),
                            };
                        }
                    }
                    SlotPhase::Decode => {
                        slot.logits = logits.expect("decode steps always want logits");
                        slot.emit_token(capture);
                        self.metrics.decode_steps += 1;
                    }
                    SlotPhase::Done => unreachable!("Done slots are filtered before stepping"),
                }
            }
            self.metrics.record_occupancy(worked, n);
            self.metrics.wall_s += t0.elapsed().as_secs_f64();

            // ---- streaming: flush freshly generated tokens (slot order) ----
            for entry in slots.iter_mut() {
                let Some(slot) = entry else { continue };
                match &slot.req.stream {
                    Some(stream) => {
                        while slot.streamed < slot.generated.len() {
                            stream.send(slot.generated[slot.streamed]).ok();
                            slot.streamed += 1;
                        }
                    }
                    None => slot.streamed = slot.generated.len(),
                }
            }

            // ---- publication: offer freshly-prefilled prompts' pages ----
            // Every node publishes its own pages for the same prompt, so
            // tries stay in lockstep across the chain (which is what makes
            // `attach_prefix` coverage topology-symmetric).
            if self.prefix_share {
                let Backend::Sharded(sf) = &mut self.backend else { unreachable!() };
                for (idx, entry) in slots.iter_mut().enumerate() {
                    let Some(slot) = entry else { continue };
                    if slot.published
                        || matches!(slot.phase, SlotPhase::Prefill { .. })
                        || slot.prompt.is_empty()
                        || slot.finish != FinishReason::Done
                    {
                        continue;
                    }
                    sf.publish_prefix(idx, &slot.prompt);
                    slot.published = true;
                }
            }

            // ---- completions: respond and free slots ----
            for (idx, entry) in slots.iter_mut().enumerate() {
                let done = matches!(entry, Some(s) if s.phase == SlotPhase::Done);
                if !done {
                    continue;
                }
                let slot = entry.take().expect("checked above");
                self.metrics.requests += 1;
                self.metrics.tokens_generated += slot.generated.len() as u64;
                if let Some(t) = slot.ttft {
                    self.metrics.record_ttft(t);
                    if slot.reused > 0 {
                        self.metrics.record_ttft_hot(t);
                    } else {
                        self.metrics.record_ttft_cold(t);
                    }
                }
                let resp = GenResponse {
                    generated: slot.generated,
                    latency: slot.req.enqueued.elapsed(),
                    steps: slot.steps,
                    seq: slot.seq,
                    queue_wait: slot.queue_wait,
                    ttft: slot.ttft,
                    logits: slot.captured,
                    finish: slot.finish,
                };
                self.metrics.record_latency(resp.latency);
                slot.req.resp.send(resp).ok();
                // drop the windows promptly on every node (published pages
                // stay resident through the tries' refs) — idle slots hold
                // no pages, keeping the per-node no-leak audit exact
                let Backend::Sharded(sf) = &mut self.backend else { unreachable!() };
                sf.reset_slot(idx);
            }
            self.publish_mirror();
        }
        self.sync_kv_metrics();
        self.publish_mirror();
        Ok(())
    }
}

/// Builder for host-backed [`Server`]s — see [`Server::builder`]. Replaces
/// the old `new_host` / `new_host_sharded` constructors plus post-hoc
/// field mutation; each setter documents its default. XLA-bound servers
/// keep their own constructor ([`Server::new`] — they need an engine and
/// an artifacts directory, which have no host equivalent).
#[must_use = "call .build() to construct the server"]
pub struct ServerBuilder {
    weights: ServingWeights,
    shards: usize,
    threads: Option<usize>,
    kv_page: Option<usize>,
    kv_quant: Option<u32>,
    prefix_share: Option<bool>,
    prefix_page_cap: Option<usize>,
    max_slots: Option<usize>,
    prefill_chunk: Option<usize>,
    decode: Option<DecodePolicy>,
    sampler_seed: Option<u64>,
    capture_logits: bool,
    batch: Option<usize>,
    fault: Option<FaultPlan>,
}

impl ServerBuilder {
    /// Partition the model's layers across `n` worker nodes
    /// ([`crate::coordinator::ShardedForward`]). `0` and `1` both mean
    /// single-node (the default). Sharded servers require
    /// [`ServingWeights::CodesResident`], decode incrementally against
    /// node-owned per-slot KV caches (DESIGN.md §16), and honor the same
    /// [`ServerBuilder::kv_page`] / [`ServerBuilder::kv_quant`] /
    /// [`ServerBuilder::prefix_share`] layout knobs as single-node
    /// serving; the static path and [`DecodePolicy::Reforward`] remain the
    /// cross-topology parity oracles.
    pub fn shards(mut self, n: usize) -> Self {
        self.shards = n;
        self
    }

    /// Worker threads for the slot fan-out (see [`Server::threads`]).
    /// `0` keeps the default ([`crate::exec::default_threads`]) — same
    /// contract as `serve --threads`.
    pub fn threads(mut self, t: usize) -> Self {
        self.threads = Some(t);
        self
    }

    /// KV layout: `0` selects the dense per-slot buffers (the parity
    /// oracle), `1..=ctx` the block-paged pool with that page size (see
    /// [`Server::kv_page`]). Values past the model context fail
    /// [`ServerBuilder::build`] with the [`validate_kv_page`] error. Unset
    /// keeps the environment-driven default (`PALLAS_KV_PAGE`, else
    /// `ctx / 8`).
    pub fn kv_page(mut self, page: usize) -> Self {
        self.kv_page = Some(page);
        self
    }

    /// Cache quantization: `0` keeps exact f32 K/V rows (the parity
    /// oracle), `2..=8` stores polar-decoupled codes at that many bits per
    /// cached value (see [`Server::kv_quant`]). Out-of-range bits fail
    /// [`ServerBuilder::build`] with the [`validate_kv_quant`] error.
    /// Unset keeps the environment-driven default (`PALLAS_KV_QUANT`,
    /// else exact).
    pub fn kv_quant(mut self, bits: u32) -> Self {
        self.kv_quant = Some(bits);
        self
    }

    /// Cross-request prefix sharing (see [`Server::prefix_share`];
    /// default on).
    pub fn prefix_share(mut self, share: bool) -> Self {
        self.prefix_share = Some(share);
        self
    }

    /// Page budget of the prefix trie (see [`Server::prefix_page_cap`];
    /// default 1024).
    pub fn prefix_page_cap(mut self, cap: usize) -> Self {
        self.prefix_page_cap = Some(cap);
        self
    }

    /// Slot-pool width for the continuous loop (see [`Server::max_slots`];
    /// default 8).
    pub fn max_slots(mut self, n: usize) -> Self {
        self.max_slots = Some(n);
        self
    }

    /// Prompt tokens per block-prefill step (see [`Server::prefill_chunk`];
    /// default `ctx / 4`). `0` keeps the default — same contract as
    /// `serve --prefill-chunk`.
    pub fn prefill_chunk(mut self, chunk: usize) -> Self {
        self.prefill_chunk = Some(chunk);
        self
    }

    /// Decode strategy (see [`DecodePolicy`]; defaults to `KvCached` on
    /// both the single-node and sharded backends).
    pub fn decode(mut self, policy: DecodePolicy) -> Self {
        self.decode = Some(policy);
        self
    }

    /// Seed of the per-request sampling streams (see
    /// [`Server::sampler_seed`]).
    pub fn sampler_seed(mut self, seed: u64) -> Self {
        self.sampler_seed = Some(seed);
        self
    }

    /// Capture per-step logits into [`GenResponse::logits`] (parity
    /// harnesses; default off).
    pub fn capture_logits(mut self, capture: bool) -> Self {
        self.capture_logits = capture;
        self
    }

    /// Static-path batch width (see [`Server::batch`]; default 8).
    pub fn batch(mut self, n: usize) -> Self {
        self.batch = Some(n);
        self
    }

    /// Arm a deterministic fault-injection plan ([`FaultPlan`], DESIGN.md
    /// §17): the continuous loop trips exactly one supervised fault at the
    /// plan's `(node, slot, step)` coordinate, finishing that request as
    /// [`FinishReason::Faulted`] while every other request is served
    /// bit-identically to a fault-free run. Unset keeps the
    /// environment-driven default (`PALLAS_FAULT`, else no injection).
    pub fn fault(mut self, plan: FaultPlan) -> Self {
        self.fault = Some(plan);
        self
    }

    /// Construct the server. Fails on an invalid weights/backend pairing
    /// (e.g. sharding non-codes-resident weights) or an out-of-range
    /// [`ServerBuilder::kv_page`].
    pub fn build(self) -> Result<Server> {
        let mut server = if self.shards > 1 {
            Server::sharded_server(self.weights, self.shards)?
        } else {
            Server::host_server(self.weights)?
        };
        if let Some(page) = self.kv_page {
            server.kv_page = validate_kv_page(page, server.config.ctx)?;
        }
        if let Some(bits) = self.kv_quant {
            server.kv_quant = validate_kv_quant(bits)?;
        }
        if let Some(t) = self.threads {
            if t > 0 {
                server.threads = t;
            }
        }
        if let Some(share) = self.prefix_share {
            server.prefix_share = share;
        }
        if let Some(cap) = self.prefix_page_cap {
            server.prefix_page_cap = cap;
        }
        if let Some(n) = self.max_slots {
            server.max_slots = n.max(1);
        }
        if let Some(chunk) = self.prefill_chunk {
            if chunk > 0 {
                server.prefill_chunk = chunk;
            }
        }
        if let Some(policy) = self.decode {
            server.decode = policy;
        }
        if let Some(seed) = self.sampler_seed {
            server.sampler_seed = seed;
        }
        if let Some(n) = self.batch {
            server.batch = n.max(1);
        }
        server.capture_logits = self.capture_logits;
        server.fault = self.fault.map(Arc::new).or_else(|| default_fault_plan().map(Arc::new));
        Ok(server)
    }
}

/// Default KV layout for a fresh server: the block-paged pool with
/// `ctx / 8`-token pages. `PALLAS_KV_PAGE` overrides it — `0` forces the
/// dense per-slot layout (the parity oracle), any other value is clamped
/// into `1..=ctx`; unset or unparseable falls back to the default.
fn default_kv_page(ctx: usize) -> Option<usize> {
    match std::env::var("PALLAS_KV_PAGE") {
        Ok(s) => match s.trim().parse::<usize>() {
            Ok(0) => None,
            Ok(p) => Some(p.clamp(1, ctx.max(1))),
            Err(_) => Some((ctx / 8).max(1)),
        },
        Err(_) => Some((ctx / 8).max(1)),
    }
}

/// Default fault-injection plan for a fresh server: none. `PALLAS_FAULT`
/// overrides it with a [`FaultPlan`] spec (e.g.
/// `panic@node=0,slot=1,step=2`); unset or unparseable means no injection
/// — the chaos suite sets the plan explicitly through
/// [`ServerBuilder::fault`].
fn default_fault_plan() -> Option<FaultPlan> {
    match std::env::var("PALLAS_FAULT") {
        Ok(s) => FaultPlan::parse(&s).ok(),
        Err(_) => None,
    }
}

/// Default cache quantization for a fresh server: exact f32 rows.
/// `PALLAS_KV_QUANT` overrides it — `0` (or unset/unparseable) keeps the
/// exact cache, any other value is clamped into the supported
/// `2..=8` bits-per-value range.
fn default_kv_quant() -> Option<u32> {
    match std::env::var("PALLAS_KV_QUANT") {
        Ok(s) => match s.trim().parse::<u32>() {
            Ok(0) | Err(_) => None,
            Ok(b) => Some(b.clamp(KvQuantSpec::MIN_BITS, KvQuantSpec::MAX_BITS)),
        },
        Err(_) => None,
    }
}

/// Validate a `serve --kv-quant` value and turn it into a
/// [`Server::kv_quant`] setting: `0` selects the exact f32 cache (the
/// parity oracle), `2..=8` the polar-decoupled codec at that many bits per
/// cached value, anything else is a flag error with a usable message.
pub fn validate_kv_quant(bits: u32) -> Result<Option<u32>> {
    if bits == 0 {
        return Ok(None); // exact f32 rows (the parity oracle)
    }
    KvQuantSpec::new(bits)?;
    Ok(Some(bits))
}

/// Validate a `serve --kv-page-size` value against the model context and
/// turn it into a [`Server::kv_page`] setting: `0` selects the dense
/// layout, `1..=ctx` the paged pool, anything larger is a flag error (not
/// a panic — degenerate page sizes must fail with a usable message).
pub fn validate_kv_page(page: usize, ctx: usize) -> Result<Option<usize>> {
    if page == 0 {
        return Ok(None); // dense per-slot buffers (the parity oracle)
    }
    anyhow::ensure!(
        page <= ctx,
        "--kv-page-size {page} exceeds the model context ({ctx}); \
         pass 0 for the dense layout or a page size in 1..={ctx}"
    );
    Ok(Some(page))
}

/// Truncate a byte prompt to the last `ctx - 1` positions (leaving room to
/// generate) as the token stream the model sees. Every serving path —
/// static cached, static re-forward, continuous — MUST use this one helper:
/// the decode-equivalence suites compare their outputs token-for-token.
fn truncate_prompt(prompt: &[u8], ctx: usize) -> Vec<i32> {
    prompt.iter().rev().take(ctx - 1).rev().map(|&x| x as i32).collect()
}

/// Pick the next token from a logit row: argmax at temperature 0 (greedy),
/// temperature sampling otherwise. Shared by every serving path — see
/// [`truncate_prompt`] for why there is exactly one copy.
fn next_token(logits: &[f32], temperature: f32, rng: &mut Rng) -> u8 {
    if temperature <= 0.0 {
        crate::tensor::argmax(logits) as u8
    } else {
        sample(logits, temperature, rng)
    }
}

/// Per-request sampling stream, deterministic in (server seed, placement):
/// a request's samples never depend on traffic served *before* it. On the
/// static path `placement` is the batch slot; under continuous batching it
/// is the admission sequence number, so the stream does not depend on which
/// slot happened to be free.
fn request_rng(seed: u64, placement: u64) -> Rng {
    Rng::new(seed ^ placement.wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

/// Temperature sampling over a logit row.
fn sample(logits: &[f32], temperature: f32, rng: &mut Rng) -> u8 {
    let maxv = logits.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
    let mut probs: Vec<f64> = logits
        .iter()
        .map(|&x| (((x - maxv) / temperature) as f64).exp())
        .collect();
    let total: f64 = probs.iter().sum();
    let mut u = rng.uniform() * total;
    for (i, p) in probs.iter_mut().enumerate() {
        u -= *p;
        if u <= 0.0 {
            return i as u8;
        }
    }
    (logits.len() - 1) as u8
}

/// Build the fixed inputs of a `fwd_q` executable from a quantized model +
/// codebooks, following the manifest order. The artifacts must be DACC
/// (two-stream: direction + magnitude) with an RHT seed — i.e. PCDVQ.
pub fn quantized_inputs(
    q: &QuantizedGpt,
    dir_cb: &DirectionCodebook,
    mag_cb: &MagnitudeCodebook,
    manifest: &crate::runtime::Manifest,
) -> Result<Vec<Input>> {
    let weight = |base: &str| -> Result<&crate::quant::QuantizedWeight> {
        let w = q
            .weights
            .get(base)
            .with_context(|| format!("missing codes for {base}"))?;
        anyhow::ensure!(
            w.codes().n_streams() == 2,
            "'{base}' is not a two-stream (DACC) artifact"
        );
        Ok(w)
    };
    let mut out = Vec::with_capacity(manifest.len() - 1);
    for e in &manifest.entries {
        if e.name == "tokens" {
            continue;
        }
        let input = if e.name == "codebook.dir" {
            Input::F32(dir_cb.vectors.as_slice().to_vec(), e.dims.clone())
        } else if e.name == "codebook.mag" {
            Input::F32(mag_cb.levels.clone(), e.dims.clone())
        } else if let Some(base) = e.name.strip_suffix(".dir_idx") {
            let w = weight(base)?;
            let s = w.codes().stream(0);
            let idx: Vec<i32> = (0..s.len).map(|i| s.get(i) as i32).collect();
            Input::I32(idx, e.dims.clone())
        } else if let Some(base) = e.name.strip_suffix(".mag_idx") {
            let w = weight(base)?;
            let s = w.codes().stream(1);
            let idx: Vec<i32> = (0..s.len).map(|i| s.get(i) as i32).collect();
            Input::I32(idx, e.dims.clone())
        } else if let Some(base) = e.name.strip_suffix(".scales") {
            let w = weight(base)?;
            Input::F32(w.scales().to_vec(), e.dims.clone())
        } else if let Some(base) = e.name.strip_suffix(".signs") {
            let w = weight(base)?;
            let seed = w
                .rht_seed()
                .with_context(|| format!("'{base}' has no RHT seed"))?;
            let rht = crate::hadamard::RandomizedHadamard::new(w.rows(), seed);
            Input::F32(rht.signs().to_vec(), e.dims.clone())
        } else {
            // fp tensor (embeddings, norms)
            let t = q
                .fp_tensors
                .get(&e.name)
                .with_context(|| format!("missing fp tensor '{}'", e.name))?;
            Input::F32(t.as_slice().to_vec(), e.dims.clone())
        };
        anyhow::ensure!(
            input.dims() == e.dims.as_slice(),
            "input '{}' shape mismatch",
            e.name
        );
        out.push(input);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_greedy_limit() {
        // at tiny temperature sampling must match argmax
        let mut rng = Rng::new(1);
        let mut logits = vec![0.0f32; 32];
        logits[17] = 9.0;
        for _ in 0..20 {
            assert_eq!(sample(&logits, 0.05, &mut rng), 17);
        }
    }

    #[test]
    fn request_rng_is_placement_stable_and_placement_distinct() {
        // same (seed, placement) → identical stream; different → different
        let mut a = request_rng(7, 3);
        let mut b = request_rng(7, 3);
        let mut c = request_rng(7, 4);
        let same: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        assert!(same.iter().all(|&x| x == b.next_u64()));
        let other: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_ne!(same, other);
    }

    #[test]
    fn validate_kv_page_accepts_range_and_rejects_oversize() {
        assert_eq!(validate_kv_page(0, 64).unwrap(), None); // dense oracle
        assert_eq!(validate_kv_page(1, 64).unwrap(), Some(1));
        assert_eq!(validate_kv_page(64, 64).unwrap(), Some(64));
        let err = validate_kv_page(65, 64).unwrap_err().to_string();
        assert!(err.contains("--kv-page-size 65"), "got: {err}");
        assert!(err.contains("1..=64"), "got: {err}");
    }

    #[test]
    fn validate_kv_quant_accepts_range_and_rejects_odd_widths() {
        assert_eq!(validate_kv_quant(0).unwrap(), None); // exact oracle
        assert_eq!(validate_kv_quant(2).unwrap(), Some(2));
        assert_eq!(validate_kv_quant(8).unwrap(), Some(8));
        let err = validate_kv_quant(9).unwrap_err().to_string();
        assert!(err.contains("--kv-quant 9"), "got: {err}");
        assert!(validate_kv_quant(1).is_err());
    }

    #[test]
    fn sample_respects_distribution() {
        let mut rng = Rng::new(2);
        let mut logits = vec![f32::NEG_INFINITY; 8];
        logits[2] = 0.0;
        logits[5] = 0.0;
        let mut counts = [0usize; 8];
        for _ in 0..2000 {
            counts[sample(&logits, 1.0, &mut rng) as usize] += 1;
        }
        assert_eq!(counts[0] + counts[1] + counts[3] + counts[4] + counts[6] + counts[7], 0);
        assert!(counts[2] > 800 && counts[5] > 800);
    }
}
