//! Layer-sharded multi-worker serving topology (DESIGN.md §12).
//!
//! The compressed-artifact collection is cheap to partition by layer: each
//! **shard node** owns the packed codes (+ referenced codebooks) of a
//! contiguous layer range — node 0 additionally owns the embeddings, the
//! last node the final norm and the head — and activations pipeline through
//! the shard chain. The per-layer math is the exact
//! [`block_layer_forward`] unit the single-node host forward runs, so a
//! sharded forward is **bit-identical** to [`crate::model::HostForward::forward`] for any
//! shard count; the pipeline ([`ShardedForward::forward_pipelined`]) runs
//! one worker thread per node with node `i` processing job `j` while node
//! `i+1` still works on job `j−1`, which is where the multi-core throughput
//! comes from on independent block-forward traffic.
//!
//! ## Codebook-once-per-node accounting
//!
//! A shared codebook referenced by layers on two nodes is resident on
//! **both** — sharding deduplicates codebooks per node, not globally.
//! [`ShardedForward::node_bits`] (and the scheduler-side
//! [`codebook_bits_per_node`]) report exactly that: per node, payload bits
//! of the owned artifacts plus the dedup of the codebooks those artifacts
//! reference. Summed over nodes this is ≥ the single-node dedup and ≤
//! `n_nodes ×` it; `paper::verify_codes_resident` asserts the identity on
//! every quantized model it checks.
//!
//! The layer partition itself is [`crate::exec::partition`] — the same
//! deterministic fixed-strip contract every pool fan-out in this crate
//! uses, so "which node owns which layers" is one formula
//! ([`shard_layers`]).
//!
//! ## Sharded KV-cached decode (DESIGN.md §16)
//!
//! Since PR 9 every node also owns **per-slot K/V state for its own layer
//! range**: a [`crate::model::KvCache`] (dense), a
//! [`crate::model::PagedKvCache`] over a node-local
//! [`crate::model::KvPool`] (paged), optionally quantized through a
//! node-local [`KvQuantCodec`] — plus a node-local [`PrefixCache`] trie.
//! The coordinator never holds K/V rows; it only routes per-step
//! activations between nodes ([`ShardedForward::step_slots`]) and drives
//! the slot lifecycle (`reset_slot` / `attach_prefix` /
//! `publish_prefix`).
//! The per-layer unit is the exact [`crate::model::HostForward`] cached
//! walk (`cached_layer_forward`), caches/pools index layers by their
//! *absolute* model position, and node codecs keep full-model geometry —
//! so sharded KV-cached decode is **bit-identical** to the single-node
//! cached path at every shard count, page size and cache width (the §12
//! determinism contract extended to topology).

use std::collections::BTreeMap;
use std::ops::Range;
use std::sync::mpsc;
use std::sync::Arc;

use anyhow::{Context, Result};

use super::fault::{panic_message, run_supervised, Fault, FaultMode};
use super::prefix::{PrefixCache, PrefixStats};
use super::server::KvPageAudit;
use crate::model::{
    block_layer_forward, cached_layer_forward, embed_block, embed_block_at, layer_names,
    layer_norm, GptConfig, KvCache, KvPool, KvPoolCounters, KvStore, LayerNames, LayerParams,
    LinearW, PagedKvCache, QuantizedGpt,
};
use crate::quant::kv::{KvQuantCodec, KvQuantSpec};
use crate::tensor::Matrix;

/// Deterministic layer partition: `n_layer` layers into (at most)
/// `n_shards` contiguous ranges via the [`crate::exec::partition`]
/// contract. Always at least one range (a zero-layer model still gets one
/// node for embeddings + head).
pub fn shard_layers(cfg: &GptConfig, n_shards: usize) -> Vec<Range<usize>> {
    if cfg.n_layer == 0 {
        return vec![0..0];
    }
    crate::exec::partition(cfg.n_layer, n_shards.max(1))
}

/// Layer index a quantizable-weight name belongs to (`layer{i}.…`), or
/// `None` for per-model weights (currently only `head.w`).
fn weight_layer(name: &str) -> Option<usize> {
    name.strip_prefix("layer")?.split('.').next()?.parse().ok()
}

/// Quantizable-weight names a node owns: filtered straight from
/// [`GptConfig::quantizable_names`] (the single naming source of truth, so
/// a new quantizable matrix automatically lands on the right node) — the
/// layers in `layers`, plus every per-model weight (`head.w`) on the last
/// node.
fn node_weight_names(cfg: &GptConfig, layers: &Range<usize>, last: bool) -> Vec<String> {
    cfg.quantizable_names()
        .into_iter()
        .filter(|name| match weight_layer(name) {
            Some(l) => layers.contains(&l),
            None => last,
        })
        .collect()
}

/// Codebook-once-per-node bits of a layer-sharded deployment of `q`:
/// partition the artifact collection with [`shard_layers`], then dedup each
/// node's shared codebooks independently (a codebook referenced from two
/// nodes is resident on both — that is what the topology actually
/// allocates). The scheduler-side accounting hook
/// ([`crate::coordinator::scheduler`]) and `paper::verify_codes_resident`
/// both go through here.
pub fn codebook_bits_per_node(q: &QuantizedGpt, n_shards: usize) -> Vec<u64> {
    let plan = shard_layers(&q.config, n_shards);
    let n_nodes = plan.len();
    plan.iter()
        .enumerate()
        .map(|(i, layers)| {
            let names = node_weight_names(&q.config, layers, i + 1 == n_nodes);
            crate::quant::dedup_codebook_bits(
                names.iter().filter_map(|n| q.weights.get(n)),
            )
        })
        .collect()
}

/// Per-node resident-bits accounting of a [`ShardedForward`].
#[derive(Clone, Debug)]
pub struct ShardBits {
    /// Layer range this node owns.
    pub layers: Range<usize>,
    /// Packed-code payload bits of the owned artifacts.
    pub payload_bits: u64,
    /// Shared-codebook bits resident on this node (deduplicated **per
    /// node** — the codebook-once-per-node rule).
    pub codebook_bits: u64,
}

/// One worker node of the shard chain: the compressed linears + fp tensors
/// of a contiguous layer range (plus embeddings on the first node, final
/// norm + head on the last).
struct ShardNode {
    layers: Range<usize>,
    linears: BTreeMap<String, LinearW>,
    fp: BTreeMap<String, Matrix>,
    /// Pre-resolved tensor names, indexed by **absolute** layer — built
    /// once so the per-block walk never `format!`s in the decode hot path
    /// (same hoist as `HostForward::names`).
    names: std::sync::Arc<Vec<LayerNames>>,
    first: bool,
    last: bool,
    /// Per-slot K/V state for this node's layer range (DESIGN.md §16).
    /// Empty until [`ShardedForward::ensure_slot_caches`] runs.
    slots: Vec<NodeSlotCache>,
    /// Node-local page pool backing paged slot caches (covers exactly
    /// `layers`).
    pool: Option<KvPool>,
    /// Node-local K/V codec. Full-model geometry with absolute layer
    /// indexing, but this node only ever observes/freezes its own range —
    /// summed over nodes the frozen grids partition, so Σ node
    /// `codebook_bits()` equals the single-node codec total.
    codec: Option<Arc<KvQuantCodec>>,
    /// Node-local prefix trie (paged layouts only); published/looked-up in
    /// lockstep across nodes so coverage is always topology-symmetric.
    prefix: Option<PrefixCache>,
}

/// One slot's K/V state on one node — the sharded mirror of the server's
/// `SlotCache`, restricted to the node's layer range.
enum NodeSlotCache {
    Dense(KvCache),
    Paged(PagedKvCache),
}

impl NodeSlotCache {
    fn len(&self) -> usize {
        match self {
            NodeSlotCache::Dense(c) => c.len(),
            NodeSlotCache::Paged(c) => c.len(),
        }
    }

    fn capacity(&self) -> usize {
        match self {
            NodeSlotCache::Dense(c) => c.capacity(),
            NodeSlotCache::Paged(c) => c.capacity(),
        }
    }

    fn reset(&mut self) {
        match self {
            NodeSlotCache::Dense(c) => c.reset(),
            NodeSlotCache::Paged(c) => c.reset(),
        }
    }

    fn begin_evict(&mut self) -> Vec<i32> {
        match self {
            NodeSlotCache::Dense(c) => c.begin_evict(),
            NodeSlotCache::Paged(c) => c.begin_evict(),
        }
    }

    fn memory_bits(&self) -> u64 {
        match self {
            NodeSlotCache::Dense(c) => c.memory_bits(),
            NodeSlotCache::Paged(c) => c.memory_bits(),
        }
    }
}

/// The cached walk over one node's layer range: the exact
/// [`cached_layer_forward`] unit `HostForward::advance_block` runs, with
/// absolute layer indices (the cache translates to its local range).
/// Free function so the `LayerParams` borrows of `fp`/`linears` can
/// coexist with the `&mut` slot cache.
#[allow(clippy::too_many_arguments)]
fn node_cached_walk<C: KvStore>(
    layers: Range<usize>,
    names: &[LayerNames],
    fp: &BTreeMap<String, Matrix>,
    linears: &BTreeMap<String, LinearW>,
    x: &mut Matrix,
    base: usize,
    cache: &mut C,
    n_head: usize,
    hd: usize,
) -> Result<()> {
    let g = |n: &str| {
        fp.get(n)
            .with_context(|| format!("shard node missing fp tensor '{n}'"))
    };
    let w = |n: &str| {
        linears
            .get(n)
            .with_context(|| format!("shard node missing linear '{n}'"))
    };
    for l in layers {
        let nm = &names[l];
        let p = LayerParams {
            ln1_g: g(&nm.ln1_g)?,
            ln1_b: g(&nm.ln1_b)?,
            wq: w(&nm.wq)?,
            wk: w(&nm.wk)?,
            wv: w(&nm.wv)?,
            wo: w(&nm.wo)?,
            ln2_g: g(&nm.ln2_g)?,
            ln2_b: g(&nm.ln2_b)?,
            w1: w(&nm.w1)?,
            w2: w(&nm.w2)?,
        };
        cached_layer_forward(x, &p, l, base, cache, n_head, hd);
    }
    Ok(())
}

impl ShardNode {
    fn fp(&self, name: &str) -> Result<&Matrix> {
        self.fp
            .get(name)
            .with_context(|| format!("shard node missing fp tensor '{name}'"))
    }

    fn linear(&self, name: &str) -> Result<&LinearW> {
        self.linears
            .get(name)
            .with_context(|| format!("shard node missing linear '{name}'"))
    }

    /// Token + position embeddings (first node only).
    fn embed(&self, tokens: &[i32], b: usize, t: usize, cfg: &GptConfig) -> Result<Matrix> {
        anyhow::ensure!(self.first, "only the first shard node embeds");
        embed_block(
            self.fp("embed.tok")?,
            self.fp("embed.pos")?,
            tokens,
            b,
            t,
            cfg.vocab,
        )
    }

    /// Run the owned layer range over a hidden block; the last node
    /// additionally applies the final norm + head, returning logits
    /// `(b·t, vocab)` instead of hidden states.
    fn process(&self, mut x: Matrix, b: usize, t: usize, cfg: &GptConfig) -> Result<Matrix> {
        for l in self.layers.clone() {
            let nm = &self.names[l];
            let p = LayerParams {
                ln1_g: self.fp(&nm.ln1_g)?,
                ln1_b: self.fp(&nm.ln1_b)?,
                wq: self.linear(&nm.wq)?,
                wk: self.linear(&nm.wk)?,
                wv: self.linear(&nm.wv)?,
                wo: self.linear(&nm.wo)?,
                ln2_g: self.fp(&nm.ln2_g)?,
                ln2_b: self.fp(&nm.ln2_b)?,
                w1: self.linear(&nm.w1)?,
                w2: self.linear(&nm.w2)?,
            };
            block_layer_forward(&mut x, &p, b, t, cfg.n_head, cfg.head_dim());
        }
        if self.last {
            let xf = layer_norm(&x, self.fp("final_ln.g")?.as_slice(), self.fp("final_ln.b")?.as_slice());
            return Ok(self.linear("head.w")?.matmul(&xf));
        }
        Ok(x)
    }

    /// Embeddings at absolute positions `base..base + tokens.len()` (first
    /// node only) — the cached-decode analogue of [`Self::embed`].
    fn embed_at(&self, tokens: &[i32], base: usize, cfg: &GptConfig) -> Result<Matrix> {
        anyhow::ensure!(self.first, "only the first shard node embeds");
        embed_block_at(
            self.fp("embed.tok")?,
            self.fp("embed.pos")?,
            tokens,
            base,
            cfg.vocab,
        )
    }

    /// Advance one slot's K/V window through this node's layer range and
    /// commit the block — the node-local slice of
    /// `HostForward::advance_block`.
    fn advance_cached(
        &mut self,
        x: &mut Matrix,
        slot: usize,
        tokens: &[i32],
        base: usize,
        cfg: &GptConfig,
    ) -> Result<()> {
        anyhow::ensure!(slot < self.slots.len(), "slot {slot} has no node cache");
        let ShardNode { layers, linears, fp, names, slots, .. } = self;
        match &mut slots[slot] {
            NodeSlotCache::Dense(c) => node_cached_walk(
                layers.clone(),
                names,
                fp,
                linears,
                x,
                base,
                c,
                cfg.n_head,
                cfg.head_dim(),
            )?,
            NodeSlotCache::Paged(c) => node_cached_walk(
                layers.clone(),
                names,
                fp,
                linears,
                x,
                base,
                c,
                cfg.n_head,
                cfg.head_dim(),
            )?,
        }
        match &mut slots[slot] {
            NodeSlotCache::Dense(c) => c.commit_block(tokens),
            NodeSlotCache::Paged(c) => c.commit_block(tokens),
        }
        Ok(())
    }

    /// Final norm + head over a hidden block (last node only).
    fn head_logits(&self, x: &Matrix) -> Result<Matrix> {
        anyhow::ensure!(self.last, "only the last shard node owns the head");
        let xf = layer_norm(x, self.fp("final_ln.g")?.as_slice(), self.fp("final_ln.b")?.as_slice());
        Ok(self.linear("head.w")?.matmul(&xf))
    }
}

/// A layer-sharded, codes-resident forward chain: `N` worker nodes, each
/// holding only its layer range's packed codes + referenced codebooks.
/// [`Self::forward`] runs the chain sequentially (the oracle);
/// [`Self::forward_pipelined`] streams a list of independent block-forward
/// jobs through one thread per node. Both are bit-identical to the
/// single-node [`crate::model::HostForward::forward`] — same [`block_layer_forward`]
/// units in the same order.
pub struct ShardedForward {
    pub config: GptConfig,
    pub name: String,
    nodes: Vec<ShardNode>,
    /// One-shot injection armed by the server for the *next*
    /// [`Self::step_slots`] call: `(node, slot, mode)` — see
    /// [`Self::arm_fault`] and [`super::fault::FaultPlan`].
    armed_fault: Option<(usize, usize, FaultMode)>,
}

impl ShardedForward {
    /// Partition `q` into (at most) `n_shards` layer-contiguous nodes.
    /// Artifacts are cloned per node (cheap: packed codes copy, codebooks
    /// stay `Arc`-shared in memory — the *accounting* still charges every
    /// node its own copy of each referenced codebook, because a real
    /// deployment ships one per machine).
    pub fn new(q: &QuantizedGpt, n_shards: usize) -> Result<Self> {
        let plan = shard_layers(&q.config, n_shards);
        let n_nodes = plan.len();
        let names = std::sync::Arc::new(layer_names(q.config.n_layer));
        let mut nodes = Vec::with_capacity(n_nodes);
        for (i, layers) in plan.into_iter().enumerate() {
            let (first, last) = (i == 0, i + 1 == n_nodes);
            let mut linears = BTreeMap::new();
            for name in node_weight_names(&q.config, &layers, last) {
                let w = q
                    .weights
                    .get(&name)
                    .with_context(|| format!("missing codes for '{name}'"))?;
                linears.insert(name, LinearW::Codes(w.clone()));
            }
            let mut fp = BTreeMap::new();
            let mut fp_needed: Vec<String> = Vec::new();
            if first {
                fp_needed.extend(["embed.tok".into(), "embed.pos".into()]);
            }
            if last {
                fp_needed.extend(["final_ln.g".into(), "final_ln.b".into()]);
            }
            for l in layers.clone() {
                for nm in ["ln1.g", "ln1.b", "ln2.g", "ln2.b"] {
                    fp_needed.push(format!("layer{l}.{nm}"));
                }
            }
            for name in fp_needed {
                let t = q
                    .fp_tensors
                    .get(&name)
                    .with_context(|| format!("missing fp tensor '{name}'"))?;
                fp.insert(name, t.clone());
            }
            nodes.push(ShardNode {
                layers,
                linears,
                fp,
                names: std::sync::Arc::clone(&names),
                first,
                last,
                slots: Vec::new(),
                pool: None,
                codec: None,
                prefix: None,
            });
        }
        Ok(ShardedForward { config: q.config, name: q.name.clone(), nodes, armed_fault: None })
    }

    /// Arm (or clear) a one-shot fault injection for the next
    /// [`Self::step_slots`] call: the supervised stage for `node` injects
    /// `mode` into the job targeting `slot`. The server translates a
    /// [`super::fault::FaultPlan`] coordinate match into this call; the
    /// armed value is consumed at the top of `step_slots` whether or not
    /// any job matches.
    pub(crate) fn arm_fault(&mut self, armed: Option<(usize, usize, FaultMode)>) {
        self.armed_fault = armed;
    }

    /// Number of worker nodes in the chain.
    pub fn n_shards(&self) -> usize {
        self.nodes.len()
    }

    /// Layer range of node `i`.
    pub fn node_layers(&self, i: usize) -> Range<usize> {
        self.nodes[i].layers.clone()
    }

    /// True when every linear on every node is served from packed codes
    /// (always the case for a chain built from a [`QuantizedGpt`]).
    pub fn is_codes_resident(&self) -> bool {
        self.nodes
            .iter()
            .all(|n| n.linears.values().all(|l| l.codes().is_some()))
    }

    /// Per-node resident bits: payload + codebook-once-per-node.
    pub fn node_bits(&self) -> Vec<ShardBits> {
        self.nodes
            .iter()
            .map(|n| ShardBits {
                layers: n.layers.clone(),
                payload_bits: n.linears.values().map(|l| l.resident_bits()).sum(),
                codebook_bits: crate::quant::dedup_codebook_bits(
                    n.linears.values().filter_map(|l| l.codes()),
                ),
            })
            .collect()
    }

    /// Payload bits summed over nodes (equals the unsharded payload — codes
    /// are partitioned, never duplicated).
    pub fn payload_bits(&self) -> u64 {
        self.node_bits().iter().map(|b| b.payload_bits).sum()
    }

    /// Codebook bits summed over nodes (≥ the single-node dedup: shared
    /// codebooks are resident once **per node** that references them).
    pub fn codebook_bits(&self) -> u64 {
        self.node_bits().iter().map(|b| b.codebook_bits).sum()
    }

    /// Total bits resident across the deployment.
    pub fn resident_bits(&self) -> u64 {
        self.payload_bits() + self.codebook_bits()
    }

    /// One `(b, t)` token block through the whole chain, sequentially on
    /// the calling thread — the parity oracle for the pipeline, and the
    /// `run_block` backend of a sharded [`super::Server`].
    pub fn forward(&self, tokens: &[i32], b: usize, t: usize) -> Result<Vec<f32>> {
        anyhow::ensure!(tokens.len() == b * t, "token block shape mismatch");
        anyhow::ensure!(t <= self.config.ctx, "sequence longer than ctx");
        let mut x = self.nodes[0].embed(tokens, b, t, &self.config)?;
        for node in &self.nodes {
            x = node.process(x, b, t, &self.config)?;
        }
        Ok(x.into_vec())
    }

    /// Stream independent block-forward jobs through the shard chain, one
    /// worker thread per node, activations flowing over channels: node `i`
    /// works on job `j` while node `i+1` still runs job `j−1` (pipeline
    /// parallelism — the `sharded_vs_single` bench scenario measures the
    /// resulting throughput multiple). Results return in job order and are
    /// bit-identical to [`Self::forward`] per job.
    pub fn forward_pipelined(
        &self,
        jobs: &[(Vec<i32>, usize, usize)],
    ) -> Result<Vec<Vec<f32>>> {
        let n_nodes = self.nodes.len();
        if n_nodes == 1 || jobs.len() <= 1 {
            return jobs.iter().map(|(toks, b, t)| self.forward(toks, *b, *t)).collect();
        }
        for (toks, b, t) in jobs {
            anyhow::ensure!(toks.len() == b * t, "token block shape mismatch");
            anyhow::ensure!(*t <= self.config.ctx, "sequence longer than ctx");
        }
        let cfg = &self.config;
        // split the caller's thread budget across the stage threads (the
        // exec nesting contract: coarse-grain sections cap their workers'
        // inner parallelism so N stages never contend for the same cores)
        let inner = (crate::exec::current_threads() / n_nodes).max(1);
        let collected = std::thread::scope(|scope| -> Result<Vec<(usize, Vec<f32>)>> {
            // one channel per chain hop; stage i sends on txs[i], receives
            // on the channel before it
            let mut txs = Vec::with_capacity(n_nodes - 1);
            let mut rxs = Vec::with_capacity(n_nodes - 1);
            for _ in 0..n_nodes - 1 {
                let (tx, rx) = mpsc::channel::<(usize, Matrix, usize, usize)>();
                txs.push(tx);
                rxs.push(rx);
            }
            let mut tx_iter = txs.into_iter();
            let mut rx_iter = rxs.into_iter();

            let first = &self.nodes[0];
            let tx0 = tx_iter.next().expect("n_nodes >= 2");
            let h0 = scope.spawn(move || -> Result<()> {
                crate::exec::with_threads(inner, || -> Result<()> {
                    for (idx, (toks, b, t)) in jobs.iter().enumerate() {
                        let x = first.embed(toks, *b, *t, cfg)?;
                        let x = first.process(x, *b, *t, cfg)?;
                        if tx0.send((idx, x, *b, *t)).is_err() {
                            break; // downstream failed; its error surfaces below
                        }
                    }
                    Ok(())
                })
            });
            let mut mids = Vec::new();
            for node in &self.nodes[1..n_nodes - 1] {
                let rx = rx_iter.next().expect("one rx per mid stage");
                let tx = tx_iter.next().expect("one tx per mid stage");
                mids.push(scope.spawn(move || -> Result<()> {
                    crate::exec::with_threads(inner, || -> Result<()> {
                        for (idx, x, b, t) in rx {
                            let x = node.process(x, b, t, cfg)?;
                            if tx.send((idx, x, b, t)).is_err() {
                                break;
                            }
                        }
                        Ok(())
                    })
                }));
            }
            let last = &self.nodes[n_nodes - 1];
            let rx_last = rx_iter.next().expect("final stage rx");
            let h_last = scope.spawn(move || -> Result<Vec<(usize, Vec<f32>)>> {
                crate::exec::with_threads(inner, || -> Result<Vec<(usize, Vec<f32>)>> {
                    let mut out = Vec::new();
                    for (idx, x, b, t) in rx_last {
                        let y = last.process(x, b, t, cfg)?;
                        out.push((idx, y.into_vec()));
                    }
                    Ok(out)
                })
            });
            join_stage(h0, 0)?;
            for (m, h) in mids.into_iter().enumerate() {
                join_stage(h, m + 1)?;
            }
            join_stage(h_last, n_nodes - 1)
        })?;
        let mut results: Vec<Vec<f32>> = vec![Vec::new(); jobs.len()];
        for (idx, r) in collected {
            results[idx] = r;
        }
        Ok(results)
    }

    // ------------------------------------------------------------------
    // Sharded KV-cached decode (DESIGN.md §16): node-owned slot state.
    // ------------------------------------------------------------------

    /// Make at least `n` per-slot caches exist **on every node** under the
    /// requested layout (`kv_page` × `kv_quant` — the same knobs as the
    /// single-node server). A layout change rebuilds every node from
    /// scratch (caches, pool, trie and codec drop together); returns `true`
    /// when that happened so the caller can zero its counter high-water
    /// marks.
    pub(crate) fn ensure_slot_caches(
        &mut self,
        n: usize,
        kv_page: Option<usize>,
        kv_quant: Option<u32>,
        codec_seed: u64,
        prefix_page_cap: usize,
    ) -> Result<bool> {
        let cfg = self.config;
        let probe = &self.nodes[0];
        let quant_stale = probe.codec.as_ref().map(|c| c.spec().bits()) != kv_quant;
        let stale = quant_stale
            || match (&kv_page, probe.pool.as_ref()) {
                (Some(ps), Some(pool)) => pool.page_size() != *ps,
                (Some(_), None) => !probe.slots.is_empty(),
                (None, Some(_)) => true,
                (None, None) => false,
            };
        if stale {
            for node in &mut self.nodes {
                node.slots.clear();
                if let (Some(trie), Some(pool)) = (node.prefix.as_mut(), node.pool.as_ref()) {
                    trie.clear(pool);
                }
                node.prefix = None;
                node.pool = None;
                node.codec = None;
            }
        }
        for node in &mut self.nodes {
            if let Some(bits) = kv_quant {
                if node.codec.is_none() {
                    // full-model geometry + absolute layer indexing: every
                    // node derives the same per-layer seed as the
                    // single-node codec, so frozen grids partition across
                    // nodes bit-identically
                    node.codec = Some(Arc::new(KvQuantCodec::new(
                        KvQuantSpec::new(bits)?,
                        cfg.n_layer,
                        cfg.d_model,
                        codec_seed,
                    )));
                }
            }
            if let Some(ps) = kv_page {
                if node.pool.is_none() {
                    node.pool = Some(KvPool::for_layers(
                        &cfg,
                        ps,
                        node.codec.clone(),
                        node.layers.clone(),
                    )?);
                    node.prefix = Some(PrefixCache::new(ps, prefix_page_cap));
                }
            }
            while node.slots.len() < n {
                node.slots.push(match &node.pool {
                    Some(pool) => NodeSlotCache::Paged(PagedKvCache::new(&cfg, pool)),
                    None => NodeSlotCache::Dense(KvCache::with_layers(
                        &cfg,
                        cfg.ctx,
                        (cfg.ctx / 4).max(1),
                        node.codec.clone(),
                        node.layers.clone(),
                    )),
                });
            }
        }
        Ok(stale)
    }

    /// Slot caches currently built per node.
    pub(crate) fn n_slots(&self) -> usize {
        self.nodes[0].slots.len()
    }

    /// Clear one slot's K/V window on every node (admission reset and
    /// post-completion eviction).
    pub(crate) fn reset_slot(&mut self, slot: usize) {
        for node in &mut self.nodes {
            node.slots[slot].reset();
        }
    }

    /// Cached window length of a slot (identical on every node by
    /// construction — the chain always commits in lockstep).
    pub(crate) fn slot_len(&self, slot: usize) -> usize {
        let len = self.nodes[0].slots[slot].len();
        debug_assert!(
            self.nodes.iter().all(|n| n.slots[slot].len() == len),
            "shard nodes' slot windows diverged"
        );
        len
    }

    /// Prefix-trie lookup + attach on every node; returns the covered
    /// token count (necessarily equal across nodes — tries are published
    /// in lockstep). `0` for dense layouts or on miss.
    pub(crate) fn attach_prefix(&mut self, slot: usize, prompt: &[i32]) -> usize {
        let mut covered_all: Option<usize> = None;
        for node in &mut self.nodes {
            let Some(trie) = node.prefix.as_mut() else { return 0 };
            let NodeSlotCache::Paged(cache) = &mut node.slots[slot] else { return 0 };
            let (chain, covered) = trie.lookup(prompt);
            if let Some(c0) = covered_all {
                assert_eq!(c0, covered, "prefix coverage diverged across shard nodes");
            }
            covered_all = Some(covered);
            if covered > 0 {
                cache.attach(&chain, &prompt[..covered]);
            }
        }
        covered_all.unwrap_or(0)
    }

    /// Publish a finished prompt's whole pages into every node's trie
    /// (no-op for dense layouts or when eviction already slid the window).
    pub(crate) fn publish_prefix(&mut self, slot: usize, prompt: &[i32]) {
        for node in &mut self.nodes {
            let (Some(pool), Some(trie)) = (node.pool.as_ref(), node.prefix.as_mut()) else {
                continue;
            };
            if let NodeSlotCache::Paged(c) = &node.slots[slot] {
                if c.len() == prompt.len() {
                    trie.publish(prompt, c.pages(), pool);
                }
            }
        }
    }

    /// One committed block through the whole chain: node 0 embeds at the
    /// window's absolute base, every node runs its cached layer walk and
    /// commits. Returns the hidden block out of the last node's layers
    /// (pre-head).
    fn chain_advance_block(&mut self, slot: usize, tokens: &[i32]) -> Result<Matrix> {
        anyhow::ensure!(!tokens.is_empty(), "advance needs at least one token");
        let cfg = self.config;
        let base = self.slot_len(slot);
        anyhow::ensure!(
            base + tokens.len() <= self.nodes[0].slots[slot].capacity(),
            "token block overruns the cache window"
        );
        let mut x = self.nodes[0].embed_at(tokens, base, &cfg)?;
        for node in &mut self.nodes {
            node.advance_cached(&mut x, slot, tokens, base, &cfg)?;
        }
        Ok(x)
    }

    /// Slide every node's window by the eviction stride and re-feed the
    /// survivors as one block — the sharded mirror of the single-node
    /// slide+rebuild eviction, so windows (and logits) stay identical.
    fn chain_evict(&mut self, slot: usize) -> Result<()> {
        let keep = self.nodes[0].slots[slot].begin_evict();
        for node in &mut self.nodes[1..] {
            let also = node.slots[slot].begin_evict();
            debug_assert_eq!(keep, also, "shard nodes slid different windows");
        }
        if !keep.is_empty() {
            self.chain_advance_block(slot, &keep)?;
        }
        Ok(())
    }

    /// Feed a token run through the chain in `chunk`-sized blocks with
    /// window slides exactly where `HostForward::feed_blocks` would put
    /// them. Returns the hidden block of the final chunk.
    fn chain_feed_blocks(&mut self, slot: usize, tokens: &[i32], chunk: usize) -> Result<Matrix> {
        anyhow::ensure!(!tokens.is_empty(), "prefill needs at least one token");
        let chunk = chunk.max(1);
        let mut rest = tokens;
        let mut last = None;
        while !rest.is_empty() {
            let (len, cap) = (self.slot_len(slot), self.nodes[0].slots[slot].capacity());
            if len == cap {
                self.chain_evict(slot)?;
                continue;
            }
            let take = chunk.min(rest.len()).min(cap - len);
            let (head, tail) = rest.split_at(take);
            last = Some(self.chain_advance_block(slot, head)?);
            rest = tail;
        }
        Ok(last.expect("non-empty token stream"))
    }

    /// Last-row logits out of the chain's final node.
    fn chain_head_logits(&self, x: &Matrix) -> Result<Vec<f32>> {
        let d = self.config.d_model;
        let row = Matrix::from_vec(x.row(x.rows() - 1).to_vec(), 1, d);
        let y = self.nodes.last().expect("at least one node").head_logits(&row)?;
        Ok(y.into_vec())
    }

    /// One generated token through the chain against slot `slot`'s cached
    /// window — the sharded [`crate::model::HostForward::decode_step`].
    /// O(t) per step: each node touches only the new row plus its own
    /// cached K/V.
    pub fn decode_step(&mut self, slot: usize, token: i32) -> Result<Vec<f32>> {
        let x = self.chain_feed_blocks(slot, &[token], 1)?;
        self.chain_head_logits(&x)
    }

    /// Chunked prompt prefill returning last-position logits — the sharded
    /// [`crate::model::HostForward::prefill_block`].
    pub fn prefill_block(&mut self, slot: usize, tokens: &[i32], chunk: usize) -> Result<Vec<f32>> {
        let x = self.chain_feed_blocks(slot, tokens, chunk)?;
        self.chain_head_logits(&x)
    }

    /// Chunked prompt prefill without the head projection — the sharded
    /// [`crate::model::HostForward::prefill_extend`].
    pub fn prefill_extend(&mut self, slot: usize, tokens: &[i32], chunk: usize) -> Result<()> {
        self.chain_feed_blocks(slot, tokens, chunk).map(|_| ())
    }

    /// True once every node's codec has frozen the grids of its **own**
    /// layer range (vacuously true for exact caches). Until then stepping
    /// must stay sequential on the coordinator thread so first-write order
    /// — which seeds the grids — is schedule-independent.
    fn kv_codecs_frozen(&self) -> bool {
        self.nodes.iter().all(|node| {
            node.codec
                .as_ref()
                .is_none_or(|c| c.frozen_range(node.layers.clone()))
        })
    }

    /// Step a batch of slots through the chain, pipelined one worker
    /// thread per node: node `i` advances job `j` while node `i+1` still
    /// runs job `j−1`. Jobs must target **distinct** slots. Returns, per
    /// job, a [`SlotStepOutcome`]: logits (`Some(last-row)` when
    /// `want_logits` was set, else `None`) — or the [`Fault`] that stopped
    /// it.
    ///
    /// Every per-job unit of stage work runs under
    /// [`run_supervised`], so a panic or error inside one job's
    /// `advance_cached` poisons *that job only*: downstream stages forward
    /// the poisoned marker untouched, every other job's activations and
    /// cache writes are exactly those of a fault-free run, and the
    /// pipeline keeps flowing (DESIGN.md §17). A `Err` from this function
    /// is reserved for systemic failures (coordinator-side eviction in
    /// Phase A, a stage thread dying outside supervision).
    ///
    /// Falls back to the sequential chain (job order, calling thread) when
    /// the chain is a single node, the batch has one job, or any node's
    /// K/V codec is still observing its own layers — the same
    /// inline-seeding rule as the single-node server, which is what makes
    /// node codebooks bit-identical to the single-node codec's.
    pub fn step_slots(&mut self, jobs: &[ShardStepJob]) -> Result<Vec<SlotStepOutcome>> {
        debug_assert!(
            {
                let mut slots: Vec<usize> = jobs.iter().map(|j| j.slot).collect();
                slots.sort_unstable();
                slots.windows(2).all(|w| w[0] != w[1])
            },
            "step_slots jobs must target distinct slots"
        );
        let n_nodes = self.nodes.len();
        let armed = self.armed_fault.take();
        if n_nodes == 1 || jobs.len() <= 1 || !self.kv_codecs_frozen() {
            let mut out = Vec::with_capacity(jobs.len());
            for j in jobs {
                // the whole chain runs inline here, so a slot match
                // injects regardless of the chain position the plan names;
                // the fault is still attributed to the armed node
                let (node, injected) = match armed {
                    Some((n, s, mode)) if s == j.slot => (n, Some(mode)),
                    _ => (0, None),
                };
                let r = run_supervised(node, j.slot, injected, || {
                    if j.want_logits {
                        self.prefill_block(j.slot, &j.tokens, j.tokens.len().max(1)).map(Some)
                    } else {
                        self.prefill_extend(j.slot, &j.tokens, j.tokens.len().max(1))
                            .map(|_| None)
                    }
                });
                out.push(match r {
                    Ok(l) => SlotStepOutcome::Logits(l),
                    Err(f) => SlotStepOutcome::Fault(f),
                });
            }
            return Ok(out);
        }
        // Phase A (coordinator thread, job order): run evictions and
        // capacity-overflow blocks sequentially until each job is one
        // in-window block — exactly the blocks the single-node
        // `feed_blocks` schedule would form, since job blocks are already
        // at most one chunk long.
        struct FinalBlock {
            idx: usize,
            slot: usize,
            base: usize,
            tokens: Vec<i32>,
        }
        let mut finals: Vec<FinalBlock> = Vec::with_capacity(jobs.len());
        for (idx, j) in jobs.iter().enumerate() {
            anyhow::ensure!(!j.tokens.is_empty(), "step job needs at least one token");
            let cap = self.nodes[0].slots[j.slot].capacity();
            let mut rest = j.tokens.as_slice();
            loop {
                if self.slot_len(j.slot) == cap {
                    self.chain_evict(j.slot)?;
                }
                let room = cap - self.slot_len(j.slot);
                if rest.len() <= room {
                    finals.push(FinalBlock {
                        idx,
                        slot: j.slot,
                        base: self.slot_len(j.slot),
                        tokens: rest.to_vec(),
                    });
                    break;
                }
                let (head, tail) = rest.split_at(room);
                self.chain_advance_block(j.slot, head)?;
                rest = tail;
            }
        }
        // Phase B: pipeline the final blocks, one stage thread per node.
        // Distinct slots ⇒ each node's thread is the only writer of the
        // caches it touches, and it processes jobs in arrival (= job)
        // order, so the commit order per node matches the sequential
        // chain. Each per-job unit is supervised: a fault replaces the
        // job's activations with a poisoned marker that downstream stages
        // relay as-is, so the other jobs never notice.
        let want: Vec<bool> = jobs.iter().map(|j| j.want_logits).collect();
        let cfg = self.config;
        let inner = (crate::exec::current_threads() / n_nodes).max(1);
        let (first_node, rest_nodes) = self.nodes.split_first_mut().expect("at least one node");
        let (last_node, mid_nodes) = rest_nodes.split_last_mut().expect("n_nodes >= 2");
        let collected = std::thread::scope(|scope| -> Result<Vec<JobOutcome>> {
            let mut txs = Vec::with_capacity(n_nodes - 1);
            let mut rxs = Vec::with_capacity(n_nodes - 1);
            for _ in 0..n_nodes - 1 {
                let (tx, rx) = mpsc::channel::<StageItem>();
                txs.push(tx);
                rxs.push(rx);
            }
            let mut tx_iter = txs.into_iter();
            let mut rx_iter = rxs.into_iter();

            let tx0 = tx_iter.next().expect("n_nodes >= 2");
            let cfg0 = cfg;
            let h0 = scope.spawn(move || -> Result<()> {
                crate::exec::with_threads(inner, || -> Result<()> {
                    for fb in finals {
                        let FinalBlock { idx, slot, base, tokens } = fb;
                        let payload =
                            run_supervised(0, slot, injected_mode(armed, 0, slot), || {
                                let mut x = first_node.embed_at(&tokens, base, &cfg0)?;
                                first_node.advance_cached(&mut x, slot, &tokens, base, &cfg0)?;
                                Ok((x, base, tokens))
                            });
                        if tx0.send((idx, slot, payload)).is_err() {
                            break; // downstream died; its error surfaces below
                        }
                    }
                    Ok(())
                })
            });
            let mut mids = Vec::new();
            for (m, node) in mid_nodes.iter_mut().enumerate() {
                let rx = rx_iter.next().expect("one rx per mid stage");
                let tx = tx_iter.next().expect("one tx per mid stage");
                let cfg_m = cfg;
                let node_idx = m + 1;
                mids.push(scope.spawn(move || -> Result<()> {
                    crate::exec::with_threads(inner, || -> Result<()> {
                        for (idx, slot, payload) in rx {
                            let fwd = match payload {
                                // poisoned upstream: relay untouched
                                Err(fault) => Err(fault),
                                Ok((mut x, base, toks)) => run_supervised(
                                    node_idx,
                                    slot,
                                    injected_mode(armed, node_idx, slot),
                                    || {
                                        node.advance_cached(&mut x, slot, &toks, base, &cfg_m)?;
                                        Ok((x, base, toks))
                                    },
                                ),
                            };
                            if tx.send((idx, slot, fwd)).is_err() {
                                break;
                            }
                        }
                        Ok(())
                    })
                }));
            }
            let rx_last = rx_iter.next().expect("final stage rx");
            let want = &want;
            let cfg_l = cfg;
            let last_idx = n_nodes - 1;
            let h_last = scope.spawn(move || -> Result<Vec<JobOutcome>> {
                crate::exec::with_threads(inner, || -> Result<Vec<JobOutcome>> {
                    let mut out = Vec::new();
                    for (idx, slot, payload) in rx_last {
                        let r = match payload {
                            Err(fault) => Err(fault),
                            Ok((mut x, base, toks)) => run_supervised(
                                last_idx,
                                slot,
                                injected_mode(armed, last_idx, slot),
                                || {
                                    last_node.advance_cached(&mut x, slot, &toks, base, &cfg_l)?;
                                    if want[idx] {
                                        let row = Matrix::from_vec(
                                            x.row(x.rows() - 1).to_vec(),
                                            1,
                                            cfg_l.d_model,
                                        );
                                        Ok(Some(last_node.head_logits(&row)?.into_vec()))
                                    } else {
                                        Ok(None)
                                    }
                                },
                            ),
                        };
                        out.push((idx, r));
                    }
                    Ok(out)
                })
            });
            join_stage(h0, 0)?;
            for (m, h) in mids.into_iter().enumerate() {
                join_stage(h, m + 1)?;
            }
            join_stage(h_last, last_idx)
        })?;
        let mut results: Vec<SlotStepOutcome> =
            jobs.iter().map(|_| SlotStepOutcome::Logits(None)).collect();
        for (idx, r) in collected {
            results[idx] = match r {
                Ok(l) => SlotStepOutcome::Logits(l),
                Err(f) => SlotStepOutcome::Fault(f),
            };
        }
        Ok(results)
    }

    // ------------------------------------------------------------------
    // Per-node KV residency accounting (codes + codebook-once-per-node).
    // ------------------------------------------------------------------

    /// Resident K/V cache bits per node: paged layouts charge every page
    /// the pool ever materialized (`pages_created · page_bits` — the
    /// high-water mark), dense layouts the full per-slot windows. Each
    /// node's `page_bits` covers only its own layer range.
    pub fn kv_cache_bits_per_node(&self) -> Vec<u64> {
        self.nodes
            .iter()
            .map(|node| match &node.pool {
                Some(pool) => pool.pages_created() * pool.page_bits(),
                None => node.slots.iter().map(|c| c.memory_bits()).sum(),
            })
            .collect()
    }

    /// Total resident K/V cache bits across the deployment.
    pub fn kv_cache_bits(&self) -> u64 {
        self.kv_cache_bits_per_node().iter().sum()
    }

    /// Frozen K/V codebook bits per node. Unlike weight codebooks (shared,
    /// duplicated per node), K/V grids are per-layer, so they **partition**
    /// across the chain: the sum over nodes equals the single-node codec's
    /// total bit-for-bit.
    pub fn kv_codebook_bits_per_node(&self) -> Vec<u64> {
        self.nodes
            .iter()
            .map(|n| n.codec.as_ref().map_or(0, |c| c.codebook_bits()))
            .collect()
    }

    /// K/V codebook bits summed over nodes.
    pub fn kv_codebook_bits(&self) -> u64 {
        self.kv_codebook_bits_per_node().iter().sum()
    }

    /// Node 0's K/V codec (layout probe: spec/bits are identical on every
    /// node), when caches quantize.
    pub fn kv_codec(&self) -> Option<&Arc<KvQuantCodec>> {
        self.nodes[0].codec.as_ref()
    }

    /// Pool telemetry summed over node pools (`None` for dense layouts).
    pub(crate) fn kv_pool_counters(&self) -> Option<KvPoolCounters> {
        let mut total: Option<KvPoolCounters> = None;
        for node in &self.nodes {
            if let Some(pool) = &node.pool {
                let c = pool.counters();
                let t = total.get_or_insert_with(KvPoolCounters::default);
                t.allocated += c.allocated;
                t.reused += c.reused;
                t.released += c.released;
                t.dropped += c.dropped;
                t.cow_copies += c.cow_copies;
            }
        }
        total
    }

    /// Prefix-trie stats: hit/miss/token counts come from node 0 (every
    /// node sees the same logical lookups — counting all nodes would
    /// multiply request-level stats by the shard count), while
    /// published/evicted **pages** sum over nodes (physical, per-node
    /// residency).
    pub(crate) fn prefix_stats(&self) -> Option<PrefixStats> {
        let s0 = self.nodes[0].prefix.as_ref()?.stats();
        let mut published = 0;
        let mut evicted = 0;
        for node in &self.nodes {
            if let Some(trie) = &node.prefix {
                let s = trie.stats();
                published += s.pages_published;
                evicted += s.pages_evicted;
            }
        }
        Some(PrefixStats {
            hits: s0.hits,
            misses: s0.misses,
            tokens_reused: s0.tokens_reused,
            pages_published: published,
            pages_evicted: evicted,
        })
    }

    /// Pages resident in prefix tries, summed over nodes.
    pub fn prefix_resident_pages(&self) -> usize {
        self.nodes
            .iter()
            .filter_map(|n| n.prefix.as_ref())
            .map(|t| t.resident_pages())
            .sum()
    }

    /// Drop every node's published prefix chains.
    pub(crate) fn clear_prefix_caches(&mut self) {
        for node in &mut self.nodes {
            if let (Some(trie), Some(pool)) = (node.prefix.as_mut(), node.pool.as_ref()) {
                trie.clear(pool);
            }
        }
    }

    /// Codec decode-counter summed over nodes.
    pub(crate) fn kv_decoded_subvecs(&self) -> u64 {
        self.nodes
            .iter()
            .filter_map(|n| n.codec.as_ref())
            .map(|c| c.decoded_subvecs())
            .sum()
    }

    /// Per-node page audit (`None` for dense layouts): every page each
    /// node's pool created is either live in a slot chain, parked on a
    /// slot free list, resident in the node's trie, or dropped.
    pub fn kv_page_audit_per_node(&self) -> Option<Vec<KvPageAudit>> {
        self.nodes[0].pool.as_ref()?;
        Some(
            self.nodes
                .iter()
                .map(|node| {
                    let pool = node.pool.as_ref().expect("pools are built in lockstep");
                    let mut chain = 0u64;
                    let mut free = 0u64;
                    for c in &node.slots {
                        if let NodeSlotCache::Paged(p) = c {
                            chain += p.pages().len() as u64;
                            free += p.local_free_len() as u64;
                        }
                    }
                    KvPageAudit {
                        created: pool.pages_created(),
                        dropped: pool.counters().dropped,
                        slot_chain_pages: chain,
                        slot_free_pages: free,
                        prefix_pages: node
                            .prefix
                            .as_ref()
                            .map_or(0, |t| t.resident_pages() as u64),
                    }
                })
                .collect(),
        )
    }
}

/// One slot's work item for [`ShardedForward::step_slots`]: a token block
/// (one prompt chunk, or a single generated token) to advance through the
/// chain against the slot's cached window.
pub struct ShardStepJob {
    /// Slot index (shared across all nodes).
    pub slot: usize,
    /// Tokens to commit this step — at most one prefill chunk.
    pub tokens: Vec<i32>,
    /// Compute last-row logits on the final node (final prefill chunk and
    /// every decode step).
    pub want_logits: bool,
}

/// Per-job result of [`ShardedForward::step_slots`]: the step's logits, or
/// the supervised [`Fault`] that stopped this job (and only this job — the
/// rest of the batch completed exactly as in a fault-free run).
#[derive(Debug)]
pub enum SlotStepOutcome {
    /// The job completed: `Some(last-row logits)` when `want_logits` was
    /// set, else `None`.
    Logits(Option<Vec<f32>>),
    /// The job's supervised stage work panicked or errored; the server
    /// finishes the occupying request as `Faulted` and quarantines the
    /// slot.
    Fault(Fault),
}

/// One job flowing between pipeline stages: `(job idx, slot, payload)`.
/// A poisoned payload (`Err(Fault)`) is relayed downstream untouched so
/// the pipeline keeps moving for every other job.
type StageItem =
    (usize, usize, std::result::Result<(Matrix, usize, Vec<i32>), Fault>);

/// What the final stage hands back per job before reassembly into
/// [`SlotStepOutcome`]s.
type JobOutcome = (usize, std::result::Result<Option<Vec<f32>>, Fault>);

/// The mode to inject for `(node, slot)` if the armed one-shot matches.
fn injected_mode(
    armed: Option<(usize, usize, FaultMode)>,
    node: usize,
    slot: usize,
) -> Option<FaultMode> {
    match armed {
        Some((n, s, mode)) if n == node && s == slot => Some(mode),
        _ => None,
    }
}

/// Join one pipeline stage thread, converting a panic that escaped per-job
/// supervision (a systemic bug, not a per-request fault) into a structured
/// error instead of unwinding the serving loop.
fn join_stage<T>(h: std::thread::ScopedJoinHandle<'_, Result<T>>, stage: usize) -> Result<T> {
    match h.join() {
        Ok(r) => r,
        Err(payload) => Err(anyhow::anyhow!(
            "shard stage {stage} panicked outside per-job supervision: {}",
            panic_message(payload.as_ref())
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::QuantizedGpt;
    use crate::proptest::{synthetic_tinygpt, tiny_pcdvq};

    fn fixture() -> (crate::model::GptModel, QuantizedGpt) {
        let model = synthetic_tinygpt("pcdvq_shard_tests", "shard", 17);
        let q = QuantizedGpt::quantize(&model, &tiny_pcdvq());
        (model, q)
    }

    #[test]
    fn shard_plan_is_deterministic_and_covers_layers() {
        let (model, _) = fixture();
        for n in [1usize, 2, 3, 8] {
            let plan = shard_layers(&model.config, n);
            assert!(!plan.is_empty());
            assert!(plan.len() <= n.max(1));
            assert_eq!(plan[0].start, 0);
            assert_eq!(plan.last().unwrap().end, model.config.n_layer);
            for w in plan.windows(2) {
                assert_eq!(w[0].end, w[1].start, "contiguous, in order");
            }
            assert_eq!(plan, shard_layers(&model.config, n), "pure function");
        }
    }

    #[test]
    fn sharded_forward_bit_identical_to_single_node() {
        let (model, q) = fixture();
        let hf = crate::model::HostForward::from_quantized(q.clone()).unwrap();
        let (b, t) = (2usize, 12usize);
        let tokens: Vec<i32> = (0..b * t).map(|i| ((i * 13 + 1) % 251) as i32).collect();
        let want = hf.forward(&tokens, b, t).unwrap();
        for n in [1usize, 2, 4] {
            let sf = ShardedForward::new(&q, n).unwrap();
            assert!(sf.is_codes_resident());
            let got = sf.forward(&tokens, b, t).unwrap();
            let wb: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
            let gb: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
            assert_eq!(wb, gb, "n_shards={n}: sharded chain diverged");
        }
    }

    #[test]
    fn pipeline_matches_sequential_chain() {
        let (_, q) = fixture();
        let sf = ShardedForward::new(&q, 2).unwrap();
        assert_eq!(sf.n_shards(), 2);
        let jobs: Vec<(Vec<i32>, usize, usize)> = (0..5)
            .map(|j| {
                let t = 8 + j;
                ((0..t).map(|i| ((i * 7 + j * 31 + 2) % 251) as i32).collect(), 1, t)
            })
            .collect();
        let piped = sf.forward_pipelined(&jobs).unwrap();
        assert_eq!(piped.len(), jobs.len());
        for (i, (toks, b, t)) in jobs.iter().enumerate() {
            let solo = sf.forward(toks, *b, *t).unwrap();
            assert_eq!(solo, piped[i], "job {i}: pipeline diverged");
        }
    }

    #[test]
    fn pipeline_surfaces_stage_errors() {
        let (_, q) = fixture();
        let sf = ShardedForward::new(&q, 2).unwrap();
        // an out-of-vocab token fails at the embed stage without hanging
        // the chain
        let jobs = vec![(vec![5i32, -1, 3, 2], 1usize, 4usize), (vec![1i32; 4], 1, 4)];
        assert!(sf.forward_pipelined(&jobs).is_err());
    }

    #[test]
    fn codebook_once_per_node_accounting() {
        let (_, q) = fixture();
        let global = q.codebook_bits();
        let payload = q.payload_bits();
        for n in [1usize, 2] {
            let sf = ShardedForward::new(&q, n).unwrap();
            let bits = sf.node_bits();
            assert_eq!(bits.len(), sf.n_shards());
            // codes partition exactly; codebooks duplicate per node
            assert_eq!(sf.payload_bits(), payload, "n={n}");
            let per_node = codebook_bits_per_node(&q, n);
            assert_eq!(
                per_node,
                bits.iter().map(|b| b.codebook_bits).collect::<Vec<_>>(),
                "standalone accounting must match the built chain"
            );
            let total = sf.codebook_bits();
            assert!(total >= global, "n={n}: a node lost its codebooks");
            assert!(
                total <= global * sf.n_shards() as u64,
                "n={n}: more than one codebook copy per node"
            );
            if n == 1 {
                assert_eq!(total, global);
            }
        }
        // PCDVQ shares one DACC pair across all layers: every node holds
        // one full copy, so 2 nodes hold exactly 2x the global dedup
        let two = codebook_bits_per_node(&q, 2);
        assert_eq!(two.iter().sum::<u64>(), 2 * global);
    }
}
