//! Layer-sharded multi-worker serving topology (DESIGN.md §12).
//!
//! The compressed-artifact collection is cheap to partition by layer: each
//! **shard node** owns the packed codes (+ referenced codebooks) of a
//! contiguous layer range — node 0 additionally owns the embeddings, the
//! last node the final norm and the head — and activations pipeline through
//! the shard chain. The per-layer math is the exact
//! [`block_layer_forward`] unit the single-node host forward runs, so a
//! sharded forward is **bit-identical** to [`crate::model::HostForward::forward`] for any
//! shard count; the pipeline ([`ShardedForward::forward_pipelined`]) runs
//! one worker thread per node with node `i` processing job `j` while node
//! `i+1` still works on job `j−1`, which is where the multi-core throughput
//! comes from on independent block-forward traffic.
//!
//! ## Codebook-once-per-node accounting
//!
//! A shared codebook referenced by layers on two nodes is resident on
//! **both** — sharding deduplicates codebooks per node, not globally.
//! [`ShardedForward::node_bits`] (and the scheduler-side
//! [`codebook_bits_per_node`]) report exactly that: per node, payload bits
//! of the owned artifacts plus the dedup of the codebooks those artifacts
//! reference. Summed over nodes this is ≥ the single-node dedup and ≤
//! `n_nodes ×` it; `paper::verify_codes_resident` asserts the identity on
//! every quantized model it checks.
//!
//! The layer partition itself is [`crate::exec::partition`] — the same
//! deterministic fixed-strip contract every pool fan-out in this crate
//! uses, so "which node owns which layers" is one formula
//! ([`shard_layers`]).

use std::collections::BTreeMap;
use std::ops::Range;
use std::sync::mpsc;

use anyhow::{Context, Result};

use crate::model::{
    block_layer_forward, embed_block, layer_names, layer_norm, GptConfig, LayerNames,
    LayerParams, LinearW, QuantizedGpt,
};
use crate::tensor::Matrix;

/// Deterministic layer partition: `n_layer` layers into (at most)
/// `n_shards` contiguous ranges via the [`crate::exec::partition`]
/// contract. Always at least one range (a zero-layer model still gets one
/// node for embeddings + head).
pub fn shard_layers(cfg: &GptConfig, n_shards: usize) -> Vec<Range<usize>> {
    if cfg.n_layer == 0 {
        return vec![0..0];
    }
    crate::exec::partition(cfg.n_layer, n_shards.max(1))
}

/// Layer index a quantizable-weight name belongs to (`layer{i}.…`), or
/// `None` for per-model weights (currently only `head.w`).
fn weight_layer(name: &str) -> Option<usize> {
    name.strip_prefix("layer")?.split('.').next()?.parse().ok()
}

/// Quantizable-weight names a node owns: filtered straight from
/// [`GptConfig::quantizable_names`] (the single naming source of truth, so
/// a new quantizable matrix automatically lands on the right node) — the
/// layers in `layers`, plus every per-model weight (`head.w`) on the last
/// node.
fn node_weight_names(cfg: &GptConfig, layers: &Range<usize>, last: bool) -> Vec<String> {
    cfg.quantizable_names()
        .into_iter()
        .filter(|name| match weight_layer(name) {
            Some(l) => layers.contains(&l),
            None => last,
        })
        .collect()
}

/// Codebook-once-per-node bits of a layer-sharded deployment of `q`:
/// partition the artifact collection with [`shard_layers`], then dedup each
/// node's shared codebooks independently (a codebook referenced from two
/// nodes is resident on both — that is what the topology actually
/// allocates). The scheduler-side accounting hook
/// ([`crate::coordinator::scheduler`]) and `paper::verify_codes_resident`
/// both go through here.
pub fn codebook_bits_per_node(q: &QuantizedGpt, n_shards: usize) -> Vec<u64> {
    let plan = shard_layers(&q.config, n_shards);
    let n_nodes = plan.len();
    plan.iter()
        .enumerate()
        .map(|(i, layers)| {
            let names = node_weight_names(&q.config, layers, i + 1 == n_nodes);
            crate::quant::dedup_codebook_bits(
                names.iter().filter_map(|n| q.weights.get(n)),
            )
        })
        .collect()
}

/// Per-node resident-bits accounting of a [`ShardedForward`].
#[derive(Clone, Debug)]
pub struct ShardBits {
    /// Layer range this node owns.
    pub layers: Range<usize>,
    /// Packed-code payload bits of the owned artifacts.
    pub payload_bits: u64,
    /// Shared-codebook bits resident on this node (deduplicated **per
    /// node** — the codebook-once-per-node rule).
    pub codebook_bits: u64,
}

/// One worker node of the shard chain: the compressed linears + fp tensors
/// of a contiguous layer range (plus embeddings on the first node, final
/// norm + head on the last).
struct ShardNode {
    layers: Range<usize>,
    linears: BTreeMap<String, LinearW>,
    fp: BTreeMap<String, Matrix>,
    /// Pre-resolved tensor names, indexed by **absolute** layer — built
    /// once so the per-block walk never `format!`s in the decode hot path
    /// (same hoist as `HostForward::names`).
    names: std::sync::Arc<Vec<LayerNames>>,
    first: bool,
    last: bool,
}

impl ShardNode {
    fn fp(&self, name: &str) -> Result<&Matrix> {
        self.fp
            .get(name)
            .with_context(|| format!("shard node missing fp tensor '{name}'"))
    }

    fn linear(&self, name: &str) -> Result<&LinearW> {
        self.linears
            .get(name)
            .with_context(|| format!("shard node missing linear '{name}'"))
    }

    /// Token + position embeddings (first node only).
    fn embed(&self, tokens: &[i32], b: usize, t: usize, cfg: &GptConfig) -> Result<Matrix> {
        anyhow::ensure!(self.first, "only the first shard node embeds");
        embed_block(
            self.fp("embed.tok")?,
            self.fp("embed.pos")?,
            tokens,
            b,
            t,
            cfg.vocab,
        )
    }

    /// Run the owned layer range over a hidden block; the last node
    /// additionally applies the final norm + head, returning logits
    /// `(b·t, vocab)` instead of hidden states.
    fn process(&self, mut x: Matrix, b: usize, t: usize, cfg: &GptConfig) -> Result<Matrix> {
        for l in self.layers.clone() {
            let nm = &self.names[l];
            let p = LayerParams {
                ln1_g: self.fp(&nm.ln1_g)?,
                ln1_b: self.fp(&nm.ln1_b)?,
                wq: self.linear(&nm.wq)?,
                wk: self.linear(&nm.wk)?,
                wv: self.linear(&nm.wv)?,
                wo: self.linear(&nm.wo)?,
                ln2_g: self.fp(&nm.ln2_g)?,
                ln2_b: self.fp(&nm.ln2_b)?,
                w1: self.linear(&nm.w1)?,
                w2: self.linear(&nm.w2)?,
            };
            block_layer_forward(&mut x, &p, b, t, cfg.n_head, cfg.head_dim());
        }
        if self.last {
            let xf = layer_norm(&x, self.fp("final_ln.g")?.as_slice(), self.fp("final_ln.b")?.as_slice());
            return Ok(self.linear("head.w")?.matmul(&xf));
        }
        Ok(x)
    }
}

/// A layer-sharded, codes-resident forward chain: `N` worker nodes, each
/// holding only its layer range's packed codes + referenced codebooks.
/// [`Self::forward`] runs the chain sequentially (the oracle);
/// [`Self::forward_pipelined`] streams a list of independent block-forward
/// jobs through one thread per node. Both are bit-identical to the
/// single-node [`crate::model::HostForward::forward`] — same [`block_layer_forward`]
/// units in the same order.
pub struct ShardedForward {
    pub config: GptConfig,
    pub name: String,
    nodes: Vec<ShardNode>,
}

impl ShardedForward {
    /// Partition `q` into (at most) `n_shards` layer-contiguous nodes.
    /// Artifacts are cloned per node (cheap: packed codes copy, codebooks
    /// stay `Arc`-shared in memory — the *accounting* still charges every
    /// node its own copy of each referenced codebook, because a real
    /// deployment ships one per machine).
    pub fn new(q: &QuantizedGpt, n_shards: usize) -> Result<Self> {
        let plan = shard_layers(&q.config, n_shards);
        let n_nodes = plan.len();
        let names = std::sync::Arc::new(layer_names(q.config.n_layer));
        let mut nodes = Vec::with_capacity(n_nodes);
        for (i, layers) in plan.into_iter().enumerate() {
            let (first, last) = (i == 0, i + 1 == n_nodes);
            let mut linears = BTreeMap::new();
            for name in node_weight_names(&q.config, &layers, last) {
                let w = q
                    .weights
                    .get(&name)
                    .with_context(|| format!("missing codes for '{name}'"))?;
                linears.insert(name, LinearW::Codes(w.clone()));
            }
            let mut fp = BTreeMap::new();
            let mut fp_needed: Vec<String> = Vec::new();
            if first {
                fp_needed.extend(["embed.tok".into(), "embed.pos".into()]);
            }
            if last {
                fp_needed.extend(["final_ln.g".into(), "final_ln.b".into()]);
            }
            for l in layers.clone() {
                for nm in ["ln1.g", "ln1.b", "ln2.g", "ln2.b"] {
                    fp_needed.push(format!("layer{l}.{nm}"));
                }
            }
            for name in fp_needed {
                let t = q
                    .fp_tensors
                    .get(&name)
                    .with_context(|| format!("missing fp tensor '{name}'"))?;
                fp.insert(name, t.clone());
            }
            nodes.push(ShardNode {
                layers,
                linears,
                fp,
                names: std::sync::Arc::clone(&names),
                first,
                last,
            });
        }
        Ok(ShardedForward { config: q.config, name: q.name.clone(), nodes })
    }

    /// Number of worker nodes in the chain.
    pub fn n_shards(&self) -> usize {
        self.nodes.len()
    }

    /// Layer range of node `i`.
    pub fn node_layers(&self, i: usize) -> Range<usize> {
        self.nodes[i].layers.clone()
    }

    /// True when every linear on every node is served from packed codes
    /// (always the case for a chain built from a [`QuantizedGpt`]).
    pub fn is_codes_resident(&self) -> bool {
        self.nodes
            .iter()
            .all(|n| n.linears.values().all(|l| l.codes().is_some()))
    }

    /// Per-node resident bits: payload + codebook-once-per-node.
    pub fn node_bits(&self) -> Vec<ShardBits> {
        self.nodes
            .iter()
            .map(|n| ShardBits {
                layers: n.layers.clone(),
                payload_bits: n.linears.values().map(|l| l.resident_bits()).sum(),
                codebook_bits: crate::quant::dedup_codebook_bits(
                    n.linears.values().filter_map(|l| l.codes()),
                ),
            })
            .collect()
    }

    /// Payload bits summed over nodes (equals the unsharded payload — codes
    /// are partitioned, never duplicated).
    pub fn payload_bits(&self) -> u64 {
        self.node_bits().iter().map(|b| b.payload_bits).sum()
    }

    /// Codebook bits summed over nodes (≥ the single-node dedup: shared
    /// codebooks are resident once **per node** that references them).
    pub fn codebook_bits(&self) -> u64 {
        self.node_bits().iter().map(|b| b.codebook_bits).sum()
    }

    /// Total bits resident across the deployment.
    pub fn resident_bits(&self) -> u64 {
        self.payload_bits() + self.codebook_bits()
    }

    /// One `(b, t)` token block through the whole chain, sequentially on
    /// the calling thread — the parity oracle for the pipeline, and the
    /// `run_block` backend of a sharded [`super::Server`].
    pub fn forward(&self, tokens: &[i32], b: usize, t: usize) -> Result<Vec<f32>> {
        anyhow::ensure!(tokens.len() == b * t, "token block shape mismatch");
        anyhow::ensure!(t <= self.config.ctx, "sequence longer than ctx");
        let mut x = self.nodes[0].embed(tokens, b, t, &self.config)?;
        for node in &self.nodes {
            x = node.process(x, b, t, &self.config)?;
        }
        Ok(x.into_vec())
    }

    /// Stream independent block-forward jobs through the shard chain, one
    /// worker thread per node, activations flowing over channels: node `i`
    /// works on job `j` while node `i+1` still runs job `j−1` (pipeline
    /// parallelism — the `sharded_vs_single` bench scenario measures the
    /// resulting throughput multiple). Results return in job order and are
    /// bit-identical to [`Self::forward`] per job.
    pub fn forward_pipelined(
        &self,
        jobs: &[(Vec<i32>, usize, usize)],
    ) -> Result<Vec<Vec<f32>>> {
        let n_nodes = self.nodes.len();
        if n_nodes == 1 || jobs.len() <= 1 {
            return jobs.iter().map(|(toks, b, t)| self.forward(toks, *b, *t)).collect();
        }
        for (toks, b, t) in jobs {
            anyhow::ensure!(toks.len() == b * t, "token block shape mismatch");
            anyhow::ensure!(*t <= self.config.ctx, "sequence longer than ctx");
        }
        let cfg = &self.config;
        // split the caller's thread budget across the stage threads (the
        // exec nesting contract: coarse-grain sections cap their workers'
        // inner parallelism so N stages never contend for the same cores)
        let inner = (crate::exec::current_threads() / n_nodes).max(1);
        let collected = std::thread::scope(|scope| -> Result<Vec<(usize, Vec<f32>)>> {
            // one channel per chain hop; stage i sends on txs[i], receives
            // on the channel before it
            let mut txs = Vec::with_capacity(n_nodes - 1);
            let mut rxs = Vec::with_capacity(n_nodes - 1);
            for _ in 0..n_nodes - 1 {
                let (tx, rx) = mpsc::channel::<(usize, Matrix, usize, usize)>();
                txs.push(tx);
                rxs.push(rx);
            }
            let mut tx_iter = txs.into_iter();
            let mut rx_iter = rxs.into_iter();

            let first = &self.nodes[0];
            let tx0 = tx_iter.next().expect("n_nodes >= 2");
            let h0 = scope.spawn(move || -> Result<()> {
                crate::exec::with_threads(inner, || -> Result<()> {
                    for (idx, (toks, b, t)) in jobs.iter().enumerate() {
                        let x = first.embed(toks, *b, *t, cfg)?;
                        let x = first.process(x, *b, *t, cfg)?;
                        if tx0.send((idx, x, *b, *t)).is_err() {
                            break; // downstream failed; its error surfaces below
                        }
                    }
                    Ok(())
                })
            });
            let mut mids = Vec::new();
            for node in &self.nodes[1..n_nodes - 1] {
                let rx = rx_iter.next().expect("one rx per mid stage");
                let tx = tx_iter.next().expect("one tx per mid stage");
                mids.push(scope.spawn(move || -> Result<()> {
                    crate::exec::with_threads(inner, || -> Result<()> {
                        for (idx, x, b, t) in rx {
                            let x = node.process(x, b, t, cfg)?;
                            if tx.send((idx, x, b, t)).is_err() {
                                break;
                            }
                        }
                        Ok(())
                    })
                }));
            }
            let last = &self.nodes[n_nodes - 1];
            let rx_last = rx_iter.next().expect("final stage rx");
            let h_last = scope.spawn(move || -> Result<Vec<(usize, Vec<f32>)>> {
                crate::exec::with_threads(inner, || -> Result<Vec<(usize, Vec<f32>)>> {
                    let mut out = Vec::new();
                    for (idx, x, b, t) in rx_last {
                        let y = last.process(x, b, t, cfg)?;
                        out.push((idx, y.into_vec()));
                    }
                    Ok(out)
                })
            });
            h0.join().expect("shard stage 0 panicked")?;
            for h in mids {
                h.join().expect("shard mid stage panicked")?;
            }
            h_last.join().expect("final shard stage panicked")
        })?;
        let mut results: Vec<Vec<f32>> = vec![Vec::new(); jobs.len()];
        for (idx, r) in collected {
            results[idx] = r;
        }
        Ok(results)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::QuantizedGpt;
    use crate::proptest::{synthetic_tinygpt, tiny_pcdvq};

    fn fixture() -> (crate::model::GptModel, QuantizedGpt) {
        let model = synthetic_tinygpt("pcdvq_shard_tests", "shard", 17);
        let q = QuantizedGpt::quantize(&model, &tiny_pcdvq());
        (model, q)
    }

    #[test]
    fn shard_plan_is_deterministic_and_covers_layers() {
        let (model, _) = fixture();
        for n in [1usize, 2, 3, 8] {
            let plan = shard_layers(&model.config, n);
            assert!(!plan.is_empty());
            assert!(plan.len() <= n.max(1));
            assert_eq!(plan[0].start, 0);
            assert_eq!(plan.last().unwrap().end, model.config.n_layer);
            for w in plan.windows(2) {
                assert_eq!(w[0].end, w[1].start, "contiguous, in order");
            }
            assert_eq!(plan, shard_layers(&model.config, n), "pure function");
        }
    }

    #[test]
    fn sharded_forward_bit_identical_to_single_node() {
        let (model, q) = fixture();
        let hf = crate::model::HostForward::from_quantized(q.clone()).unwrap();
        let (b, t) = (2usize, 12usize);
        let tokens: Vec<i32> = (0..b * t).map(|i| ((i * 13 + 1) % 251) as i32).collect();
        let want = hf.forward(&tokens, b, t).unwrap();
        for n in [1usize, 2, 4] {
            let sf = ShardedForward::new(&q, n).unwrap();
            assert!(sf.is_codes_resident());
            let got = sf.forward(&tokens, b, t).unwrap();
            let wb: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
            let gb: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
            assert_eq!(wb, gb, "n_shards={n}: sharded chain diverged");
        }
    }

    #[test]
    fn pipeline_matches_sequential_chain() {
        let (_, q) = fixture();
        let sf = ShardedForward::new(&q, 2).unwrap();
        assert_eq!(sf.n_shards(), 2);
        let jobs: Vec<(Vec<i32>, usize, usize)> = (0..5)
            .map(|j| {
                let t = 8 + j;
                ((0..t).map(|i| ((i * 7 + j * 31 + 2) % 251) as i32).collect(), 1, t)
            })
            .collect();
        let piped = sf.forward_pipelined(&jobs).unwrap();
        assert_eq!(piped.len(), jobs.len());
        for (i, (toks, b, t)) in jobs.iter().enumerate() {
            let solo = sf.forward(toks, *b, *t).unwrap();
            assert_eq!(solo, piped[i], "job {i}: pipeline diverged");
        }
    }

    #[test]
    fn pipeline_surfaces_stage_errors() {
        let (_, q) = fixture();
        let sf = ShardedForward::new(&q, 2).unwrap();
        // an out-of-vocab token fails at the embed stage without hanging
        // the chain
        let jobs = vec![(vec![5i32, -1, 3, 2], 1usize, 4usize), (vec![1i32; 4], 1, 4)];
        assert!(sf.forward_pipelined(&jobs).is_err());
    }

    #[test]
    fn codebook_once_per_node_accounting() {
        let (_, q) = fixture();
        let global = q.codebook_bits();
        let payload = q.payload_bits();
        for n in [1usize, 2] {
            let sf = ShardedForward::new(&q, n).unwrap();
            let bits = sf.node_bits();
            assert_eq!(bits.len(), sf.n_shards());
            // codes partition exactly; codebooks duplicate per node
            assert_eq!(sf.payload_bits(), payload, "n={n}");
            let per_node = codebook_bits_per_node(&q, n);
            assert_eq!(
                per_node,
                bits.iter().map(|b| b.codebook_bits).collect::<Vec<_>>(),
                "standalone accounting must match the built chain"
            );
            let total = sf.codebook_bits();
            assert!(total >= global, "n={n}: a node lost its codebooks");
            assert!(
                total <= global * sf.n_shards() as u64,
                "n={n}: more than one codebook copy per node"
            );
            if n == 1 {
                assert_eq!(total, global);
            }
        }
        // PCDVQ shares one DACC pair across all layers: every node holds
        // one full copy, so 2 nodes hold exactly 2x the global dedup
        let two = codebook_bits_per_node(&q, 2);
        assert_eq!(two.iter().sum::<u64>(), 2 * global);
    }
}
