//! Criterion-like micro-benchmark harness (criterion is not in the offline
//! crate set). Used by every target under `rust/benches/` (`harness = false`).
//!
//! Method: warm up, then collect `samples` timed runs of `iters` iterations
//! each and report min / median / mean / MAD — median-of-iterations is robust
//! to scheduler noise on the single-core testbed.

use std::time::Instant;

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    /// Nanoseconds per iteration: (min, median, mean, mad).
    pub min_ns: f64,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub mad_ns: f64,
    /// Optional throughput denominator (elements per iteration).
    pub elements: Option<u64>,
}

impl Measurement {
    /// Gigaelements (or whatever unit) per second at the median.
    pub fn throughput(&self) -> Option<f64> {
        self.elements.map(|e| e as f64 / self.median_ns)
    }

    pub fn report(&self) -> String {
        let tp = match self.throughput() {
            Some(t) => format!("  {:.3} Gelem/s", t),
            None => String::new(),
        };
        format!(
            "{:<44} {:>12} /iter  (min {:>10}, mad {:>8}){}",
            self.name,
            fmt_ns(self.median_ns),
            fmt_ns(self.min_ns),
            fmt_ns(self.mad_ns),
            tp
        )
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark runner. `samples` timed samples of adaptively-chosen `iters`.
pub struct Bench {
    pub samples: usize,
    /// Target wall time per sample (iters are chosen to hit this).
    pub target_sample_s: f64,
    pub warmup_s: f64,
    results: Vec<Measurement>,
}

impl Default for Bench {
    fn default() -> Self {
        // Modest defaults for the single-core box; CI smoke can lower them
        // via PCDVQ_BENCH_FAST=1.
        let fast = std::env::var_os("PCDVQ_BENCH_FAST").is_some();
        Bench {
            samples: if fast { 5 } else { 15 },
            target_sample_s: if fast { 0.05 } else { 0.2 },
            warmup_s: if fast { 0.05 } else { 0.3 },
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time `f`, which performs ONE iteration of the workload.
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) -> &Measurement {
        self.run_with_elements(name, None, &mut f)
    }

    /// Time `f` and attach a per-iteration element count for throughput.
    pub fn run_elems<F: FnMut()>(&mut self, name: &str, elements: u64, mut f: F) -> &Measurement {
        self.run_with_elements(name, Some(elements), &mut f)
    }

    fn run_with_elements(
        &mut self,
        name: &str,
        elements: Option<u64>,
        f: &mut dyn FnMut(),
    ) -> &Measurement {
        // warmup + calibration
        let t0 = Instant::now();
        let mut calib_iters = 0u64;
        while t0.elapsed().as_secs_f64() < self.warmup_s || calib_iters == 0 {
            f();
            calib_iters += 1;
        }
        let per_iter = t0.elapsed().as_secs_f64() / calib_iters as f64;
        let iters = ((self.target_sample_s / per_iter).ceil() as u64).max(1);

        let mut samples_ns = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..iters {
                f();
            }
            samples_ns.push(t.elapsed().as_nanos() as f64 / iters as f64);
        }
        samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = samples_ns[samples_ns.len() / 2];
        let mean = samples_ns.iter().sum::<f64>() / samples_ns.len() as f64;
        let mad = {
            let mut dev: Vec<f64> = samples_ns.iter().map(|x| (x - median).abs()).collect();
            dev.sort_by(|a, b| a.partial_cmp(b).unwrap());
            dev[dev.len() / 2]
        };
        let m = Measurement {
            name: name.to_string(),
            min_ns: samples_ns[0],
            median_ns: median,
            mean_ns: mean,
            mad_ns: mad,
            elements,
        };
        println!("{}", m.report());
        self.results.push(m);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }

    /// Write the collected measurements as machine-readable JSON (the perf
    /// trajectory files `BENCH_*.json`; serde is not in the offline crate
    /// set, so this is hand-rolled — names are plain ASCII identifiers).
    pub fn write_json(&self, path: impl AsRef<std::path::Path>) -> std::io::Result<()> {
        let mut out = String::from("[\n");
        for (i, m) in self.results.iter().enumerate() {
            let name = m.name.replace('\\', "\\\\").replace('"', "\\\"");
            out.push_str(&format!(
                "  {{\"name\": \"{}\", \"median_ns\": {:.1}, \"min_ns\": {:.1}, \
                 \"mean_ns\": {:.1}, \"mad_ns\": {:.1}, \"elements\": {}, \
                 \"gelem_per_s\": {}}}{}\n",
                name,
                m.median_ns,
                m.min_ns,
                m.mean_ns,
                m.mad_ns,
                m.elements.map_or("null".to_string(), |e| e.to_string()),
                m.throughput().map_or("null".to_string(), |t| format!("{t:.4}")),
                if i + 1 < self.results.len() { "," } else { "" },
            ));
        }
        out.push_str("]\n");
        std::fs::write(path, out)
    }
}

/// Prevent the optimizer from deleting a computed value (ptr read fence —
/// std::hint::black_box is stable but this keeps MSRV slack).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_sane() {
        let mut b = Bench::new();
        b.samples = 3;
        b.target_sample_s = 0.01;
        b.warmup_s = 0.005;
        let mut acc = 0u64;
        let m = b
            .run("noop-ish", || {
                acc = black_box(acc.wrapping_add(1));
            })
            .clone();
        assert!(m.median_ns > 0.0);
        assert!(m.min_ns <= m.median_ns);
    }

    #[test]
    fn write_json_is_parseable_shape() {
        // note: no set_var here — mutating the environment from a test racing
        // other threads' getenv is unsound; the fields are set directly.
        let mut b = Bench::new();
        b.samples = 2;
        b.target_sample_s = 0.005;
        b.warmup_s = 0.002;
        let mut acc = 0u64;
        b.run_elems("with \"quotes\"", 10, || {
            acc = black_box(acc.wrapping_add(1));
        });
        b.run("no-elems", || {
            acc = black_box(acc.wrapping_add(1));
        });
        let path = std::env::temp_dir().join("pcdvq_bench_test.json");
        b.write_json(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("[\n") && text.ends_with("]\n"));
        assert!(text.contains("\\\"quotes\\\""));
        assert!(text.contains("\"elements\": 10"));
        assert!(text.contains("\"elements\": null"));
        assert_eq!(text.matches("median_ns").count(), 2);
    }

    #[test]
    fn fmt_ns_ranges() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5_000.0).contains("µs"));
        assert!(fmt_ns(5_000_000.0).contains("ms"));
        assert!(fmt_ns(5e9).contains(" s"));
    }
}
