//! Criterion-like micro-benchmark harness (criterion is not in the offline
//! crate set). Used by every target under `rust/benches/` (`harness = false`).
//!
//! Method: warm up, then collect `samples` timed runs of `iters` iterations
//! each and report min / median / mean / MAD — median-of-iterations is robust
//! to scheduler noise on the single-core testbed.

use std::time::Instant;

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    /// Nanoseconds per iteration: (min, median, mean, mad).
    pub min_ns: f64,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub mad_ns: f64,
    /// Optional throughput denominator (elements per iteration).
    pub elements: Option<u64>,
}

impl Measurement {
    /// Gigaelements (or whatever unit) per second at the median.
    pub fn throughput(&self) -> Option<f64> {
        self.elements.map(|e| e as f64 / self.median_ns)
    }

    pub fn report(&self) -> String {
        let tp = match self.throughput() {
            Some(t) => format!("  {:.3} Gelem/s", t),
            None => String::new(),
        };
        format!(
            "{:<44} {:>12} /iter  (min {:>10}, mad {:>8}){}",
            self.name,
            fmt_ns(self.median_ns),
            fmt_ns(self.min_ns),
            fmt_ns(self.mad_ns),
            tp
        )
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark runner. `samples` timed samples of adaptively-chosen `iters`.
pub struct Bench {
    pub samples: usize,
    /// Target wall time per sample (iters are chosen to hit this).
    pub target_sample_s: f64,
    pub warmup_s: f64,
    results: Vec<Measurement>,
}

impl Default for Bench {
    fn default() -> Self {
        // Modest defaults for the single-core box; CI smoke can lower them
        // via PCDVQ_BENCH_FAST=1.
        let fast = std::env::var_os("PCDVQ_BENCH_FAST").is_some();
        Bench {
            samples: if fast { 5 } else { 15 },
            target_sample_s: if fast { 0.05 } else { 0.2 },
            warmup_s: if fast { 0.05 } else { 0.3 },
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time `f`, which performs ONE iteration of the workload.
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) -> &Measurement {
        self.run_with_elements(name, None, &mut f)
    }

    /// Time `f` and attach a per-iteration element count for throughput.
    pub fn run_elems<F: FnMut()>(&mut self, name: &str, elements: u64, mut f: F) -> &Measurement {
        self.run_with_elements(name, Some(elements), &mut f)
    }

    fn run_with_elements(
        &mut self,
        name: &str,
        elements: Option<u64>,
        f: &mut dyn FnMut(),
    ) -> &Measurement {
        // warmup + calibration
        let t0 = Instant::now();
        let mut calib_iters = 0u64;
        while t0.elapsed().as_secs_f64() < self.warmup_s || calib_iters == 0 {
            f();
            calib_iters += 1;
        }
        let per_iter = t0.elapsed().as_secs_f64() / calib_iters as f64;
        let iters = ((self.target_sample_s / per_iter).ceil() as u64).max(1);

        let mut samples_ns = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..iters {
                f();
            }
            samples_ns.push(t.elapsed().as_nanos() as f64 / iters as f64);
        }
        samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = samples_ns[samples_ns.len() / 2];
        let mean = samples_ns.iter().sum::<f64>() / samples_ns.len() as f64;
        let mad = {
            let mut dev: Vec<f64> = samples_ns.iter().map(|x| (x - median).abs()).collect();
            dev.sort_by(|a, b| a.partial_cmp(b).unwrap());
            dev[dev.len() / 2]
        };
        let m = Measurement {
            name: name.to_string(),
            min_ns: samples_ns[0],
            median_ns: median,
            mean_ns: mean,
            mad_ns: mad,
            elements,
        };
        println!("{}", m.report());
        self.results.push(m);
        self.results.last().unwrap()
    }

    /// Record an externally-measured duration as a single-sample
    /// measurement (min = median = mean, MAD 0). For scenario-level numbers
    /// a timed closure cannot express — e.g. percentile latencies pulled out
    /// of serving [`crate::coordinator::Metrics`] — so they still flow into
    /// [`Self::write_json`] and the `bench_gate` trend table.
    pub fn record_ns(&mut self, name: &str, ns: f64) -> &Measurement {
        let m = Measurement {
            name: name.to_string(),
            min_ns: ns,
            median_ns: ns,
            mean_ns: ns,
            mad_ns: 0.0,
            elements: None,
        };
        println!("{}", m.report());
        self.results.push(m);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }

    /// Write the collected measurements as machine-readable JSON (the perf
    /// trajectory files `BENCH_*.json`; serde is not in the offline crate
    /// set, so this is hand-rolled — names are plain ASCII identifiers).
    pub fn write_json(&self, path: impl AsRef<std::path::Path>) -> std::io::Result<()> {
        let mut out = String::from("[\n");
        for (i, m) in self.results.iter().enumerate() {
            let name = m.name.replace('\\', "\\\\").replace('"', "\\\"");
            out.push_str(&format!(
                "  {{\"name\": \"{}\", \"median_ns\": {:.1}, \"min_ns\": {:.1}, \
                 \"mean_ns\": {:.1}, \"mad_ns\": {:.1}, \"elements\": {}, \
                 \"gelem_per_s\": {}}}{}\n",
                name,
                m.median_ns,
                m.min_ns,
                m.mean_ns,
                m.mad_ns,
                m.elements.map_or("null".to_string(), |e| e.to_string()),
                m.throughput().map_or("null".to_string(), |t| format!("{t:.4}")),
                if i + 1 < self.results.len() { "," } else { "" },
            ));
        }
        out.push_str("]\n");
        std::fs::write(path, out)
    }
}

/// Prevent the optimizer from deleting a computed value (ptr read fence —
/// std::hint::black_box is stable but this keeps MSRV slack).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

// ---------------------------------------------------------------------------
// Perf-trajectory regression gate (`bench_gate` bin, CI `bench-regression`)
// ---------------------------------------------------------------------------

/// One entry of a `BENCH_*.json` trajectory file (the subset the regression
/// gate compares).
#[derive(Clone, Debug, PartialEq)]
pub struct BenchEntry {
    pub name: String,
    pub median_ns: f64,
}

/// Parse a `BENCH_*.json` file written by [`Bench::write_json`]. The format
/// is a flat array of flat objects, so this hand-rolled reader (serde is not
/// in the offline crate set) only needs top-level `{…}` spans plus the
/// `name` / `median_ns` fields; unknown fields are ignored.
pub fn parse_bench_json(text: &str) -> Result<Vec<BenchEntry>, String> {
    let body = text.trim();
    if !body.starts_with('[') || !body.ends_with(']') {
        return Err("not a JSON array".into());
    }
    let mut out = Vec::new();
    let mut rest = &body[1..body.len() - 1];
    while let Some(open) = rest.find('{') {
        let close = find_unquoted_close(&rest[open..])
            .ok_or_else(|| "unterminated object".to_string())?;
        let obj = &rest[open + 1..open + close];
        out.push(BenchEntry {
            name: json_string_field(obj, "name")
                .ok_or_else(|| format!("entry without name: {obj}"))?,
            median_ns: json_number_field(obj, "median_ns")
                .ok_or_else(|| format!("entry without median_ns: {obj}"))?,
        });
        rest = &rest[open + close + 1..];
    }
    Ok(out)
}

/// Byte offset of the first `}` that is not inside a JSON string — bench
/// names may legally contain braces, so a naive `find('}')` would split an
/// object mid-name.
fn find_unquoted_close(s: &str) -> Option<usize> {
    let mut in_str = false;
    let mut escaped = false;
    for (i, c) in s.char_indices() {
        if escaped {
            escaped = false;
            continue;
        }
        match c {
            '\\' if in_str => escaped = true,
            '"' => in_str = !in_str,
            '}' if !in_str => return Some(i),
            _ => {}
        }
    }
    None
}

/// Extract `"key": "…"` from a flat JSON object body, unescaping `\"`/`\\`.
fn json_string_field(obj: &str, key: &str) -> Option<String> {
    let pat = format!("\"{key}\":");
    let after = obj[obj.find(&pat)? + pat.len()..].trim_start();
    let inner = after.strip_prefix('"')?;
    let mut s = String::new();
    let mut chars = inner.chars();
    while let Some(c) = chars.next() {
        match c {
            '\\' => s.push(chars.next()?),
            '"' => return Some(s),
            _ => s.push(c),
        }
    }
    None
}

/// Extract `"key": <number>` from a flat JSON object body.
fn json_number_field(obj: &str, key: &str) -> Option<f64> {
    let pat = format!("\"{key}\":");
    let after = obj[obj.find(&pat)? + pat.len()..].trim_start();
    let end = after
        .find(|c: char| !(c.is_ascii_digit() || "+-.eE".contains(c)))
        .unwrap_or(after.len());
    after[..end].parse().ok()
}

/// Baseline-vs-current delta of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchDelta {
    pub name: String,
    pub base_ns: f64,
    pub cur_ns: f64,
    /// `cur / base` — above 1.0 is a slowdown.
    pub ratio: f64,
}

/// Result of comparing a current trajectory file against its baseline.
#[derive(Clone, Debug, Default)]
pub struct BenchComparison {
    /// Benchmarks present in both files.
    pub deltas: Vec<BenchDelta>,
    /// Present only in the current run (new benchmarks — informational).
    pub added: Vec<String>,
    /// Present only in the baseline (renamed/removed — informational).
    pub removed: Vec<String>,
}

impl BenchComparison {
    /// Deltas slower than `tolerance` (e.g. 1.3 = fail on >1.3x slowdown).
    pub fn regressions(&self, tolerance: f64) -> Vec<&BenchDelta> {
        self.deltas.iter().filter(|d| d.ratio > tolerance).collect()
    }

    /// Markdown trend table (the CI job-summary block): one row per shared
    /// benchmark, ✅/❌ against the tolerance, plus added/removed notes.
    pub fn markdown_table(&self, tolerance: f64) -> String {
        let mut out = String::from(
            "| benchmark | baseline | current | ratio | |\n|---|---:|---:|---:|---|\n",
        );
        for d in &self.deltas {
            let mark = if d.ratio > tolerance { "❌" } else { "✅" };
            out.push_str(&format!(
                "| {} | {} | {} | {:.2}x | {} |\n",
                d.name,
                fmt_ns(d.base_ns),
                fmt_ns(d.cur_ns),
                d.ratio,
                mark
            ));
        }
        for name in &self.added {
            out.push_str(&format!("| {name} | — | new | — | 🆕 |\n"));
        }
        for name in &self.removed {
            out.push_str(&format!("| {name} | gone | — | — | ⚠️ |\n"));
        }
        out
    }
}

/// Compare a current trajectory against its committed baseline, matching by
/// benchmark name (order-insensitive).
pub fn compare_benches(base: &[BenchEntry], cur: &[BenchEntry]) -> BenchComparison {
    let mut cmp = BenchComparison::default();
    for c in cur {
        match base.iter().find(|b| b.name == c.name) {
            Some(b) if b.median_ns > 0.0 => cmp.deltas.push(BenchDelta {
                name: c.name.clone(),
                base_ns: b.median_ns,
                cur_ns: c.median_ns,
                ratio: c.median_ns / b.median_ns,
            }),
            Some(_) | None => cmp.added.push(c.name.clone()),
        }
    }
    for b in base {
        if !cur.iter().any(|c| c.name == b.name) {
            cmp.removed.push(b.name.clone());
        }
    }
    cmp
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_sane() {
        let mut b = Bench::new();
        b.samples = 3;
        b.target_sample_s = 0.01;
        b.warmup_s = 0.005;
        let mut acc = 0u64;
        let m = b
            .run("noop-ish", || {
                acc = black_box(acc.wrapping_add(1));
            })
            .clone();
        assert!(m.median_ns > 0.0);
        assert!(m.min_ns <= m.median_ns);
    }

    #[test]
    fn write_json_is_parseable_shape() {
        // note: no set_var here — mutating the environment from a test racing
        // other threads' getenv is unsound; the fields are set directly.
        let mut b = Bench::new();
        b.samples = 2;
        b.target_sample_s = 0.005;
        b.warmup_s = 0.002;
        let mut acc = 0u64;
        b.run_elems("with \"quotes\"", 10, || {
            acc = black_box(acc.wrapping_add(1));
        });
        b.run("no-elems", || {
            acc = black_box(acc.wrapping_add(1));
        });
        let path = std::env::temp_dir().join("pcdvq_bench_test.json");
        b.write_json(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("[\n") && text.ends_with("]\n"));
        assert!(text.contains("\\\"quotes\\\""));
        assert!(text.contains("\"elements\": 10"));
        assert!(text.contains("\"elements\": null"));
        assert_eq!(text.matches("median_ns").count(), 2);
    }

    #[test]
    fn parse_round_trips_write_json() {
        let mut b = Bench::new();
        b.samples = 2;
        b.target_sample_s = 0.002;
        b.warmup_s = 0.001;
        let mut acc = 0u64;
        b.run_elems("alpha \"quoted\"", 4, || {
            acc = black_box(acc.wrapping_add(1));
        });
        b.run("beta", || {
            acc = black_box(acc.wrapping_add(1));
        });
        let path = std::env::temp_dir().join("pcdvq_bench_roundtrip.json");
        b.write_json(&path).unwrap();
        let parsed = parse_bench_json(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(parsed.len(), 2);
        assert_eq!(parsed[0].name, "alpha \"quoted\"");
        assert_eq!(parsed[1].name, "beta");
        for (p, m) in parsed.iter().zip(b.results()) {
            assert!((p.median_ns - m.median_ns).abs() < 0.1);
        }
    }

    #[test]
    fn parse_handles_empty_and_rejects_garbage() {
        assert_eq!(parse_bench_json("[]\n").unwrap(), vec![]);
        assert_eq!(parse_bench_json("[\n]\n").unwrap(), vec![]);
        assert!(parse_bench_json("not json").is_err());
        assert!(parse_bench_json("[{\"median_ns\": 1.0}]").is_err(), "missing name");
    }

    #[test]
    fn parse_survives_braces_and_escapes_in_names() {
        let text = "[\n  {\"name\": \"pack{w=8}\", \"median_ns\": 5.0},\n  \
                    {\"name\": \"quo\\\"te}\", \"median_ns\": 7.0}\n]\n";
        let parsed = parse_bench_json(text).unwrap();
        assert_eq!(parsed.len(), 2);
        assert_eq!(parsed[0].name, "pack{w=8}");
        assert_eq!(parsed[0].median_ns, 5.0);
        assert_eq!(parsed[1].name, "quo\"te}");
        assert_eq!(parsed[1].median_ns, 7.0);
    }

    fn entry(name: &str, ns: f64) -> BenchEntry {
        BenchEntry { name: name.into(), median_ns: ns }
    }

    #[test]
    fn compare_flags_only_regressions_beyond_tolerance() {
        let base = vec![entry("a", 100.0), entry("b", 100.0), entry("gone", 5.0)];
        let cur = vec![entry("a", 125.0), entry("b", 140.0), entry("fresh", 9.0)];
        let cmp = compare_benches(&base, &cur);
        assert_eq!(cmp.deltas.len(), 2);
        assert_eq!(cmp.added, vec!["fresh".to_string()]);
        assert_eq!(cmp.removed, vec!["gone".to_string()]);
        let regs = cmp.regressions(1.3);
        assert_eq!(regs.len(), 1);
        assert_eq!(regs[0].name, "b");
        assert!((regs[0].ratio - 1.4).abs() < 1e-9);
        // speedups never fail the gate
        assert!(cmp.regressions(2.0).is_empty());
    }

    #[test]
    fn empty_baseline_records_without_gating() {
        // the bootstrap state: committed baselines start as `[]` until a CI
        // run populates them — everything shows as added, nothing regresses
        let cmp = compare_benches(&[], &[entry("a", 10.0)]);
        assert!(cmp.deltas.is_empty());
        assert_eq!(cmp.added.len(), 1);
        assert!(cmp.regressions(1.3).is_empty());
    }

    #[test]
    fn markdown_table_shape() {
        let cmp = compare_benches(
            &[entry("fast", 100.0), entry("slow", 100.0)],
            &[entry("fast", 90.0), entry("slow", 200.0), entry("fresh", 1.0)],
        );
        let md = cmp.markdown_table(1.3);
        assert!(md.contains("| fast |"));
        assert!(md.contains("✅"));
        assert!(md.contains("❌"));
        assert!(md.contains("🆕"));
        assert!(md.lines().count() >= 5);
    }

    #[test]
    fn fmt_ns_ranges() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5_000.0).contains("µs"));
        assert!(fmt_ns(5_000_000.0).contains("ms"));
        assert!(fmt_ns(5e9).contains(" s"));
    }
}
