//! Magnitude codebooks: Algorithm 2 (Lloyd-Max on the chi(k) law) and the
//! Table-4 k-means ablation.

use crate::rng::Rng;
use crate::stats::ChiDistribution;

/// How to construct the magnitude codebook (Table 4 ablation axis).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MagnitudeMethod {
    /// Algorithm 2: Lloyd-Max against the analytic chi(k) PDF/CDF — optimal
    /// non-uniform scalar quantization. The paper's method.
    LloydMax,
    /// 1-D k-means on magnitudes sampled from chi(k).
    KMeans,
}

impl MagnitudeMethod {
    pub fn name(&self) -> &'static str {
        match self {
            MagnitudeMethod::LloydMax => "lloyd-max",
            MagnitudeMethod::KMeans => "kmeans",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "lloyd-max" => Some(MagnitudeMethod::LloydMax),
            "kmeans" => Some(MagnitudeMethod::KMeans),
            _ => None,
        }
    }
}

/// A `2^b`-entry scalar codebook for vector magnitudes.
#[derive(Clone, Debug)]
pub struct MagnitudeCodebook {
    /// Reconstruction levels, sorted ascending.
    pub levels: Vec<f32>,
    /// Index bits `b`.
    pub bits: u32,
    pub method: MagnitudeMethod,
}

impl MagnitudeCodebook {
    /// Build a codebook of `2^bits` levels for chi(`k`)-distributed
    /// magnitudes.
    ///
    /// * `tau` — CDF mass covered by the quantizer range (Algorithm 2's
    ///   "maximum threshold", default 1 − 1e-4).
    /// * `seed` — used only by the KMeans ablation.
    pub fn build(method: MagnitudeMethod, bits: u32, k: usize, tau: f64, seed: u64) -> Self {
        let n = 1usize << bits;
        let levels = match method {
            MagnitudeMethod::LloydMax => lloyd_max(n, k, tau),
            MagnitudeMethod::KMeans => kmeans_1d(n, k, seed),
        };
        debug_assert!(levels.windows(2).all(|w| w[0] <= w[1]));
        MagnitudeCodebook { levels, bits, method }
    }

    /// Convenience: the paper's configuration (Lloyd-Max, τ covering all but
    /// 1e-4 of the mass).
    pub fn paper_default(bits: u32, k: usize) -> Self {
        Self::build(MagnitudeMethod::LloydMax, bits, k, 1.0 - 1e-4, 0)
    }

    pub fn len(&self) -> usize {
        self.levels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.levels.is_empty()
    }

    /// Index of the nearest level — Eq. 7 `VQ_r`. Levels are sorted, so a
    /// binary search + neighbour check gives O(log n).
    #[inline]
    pub fn assign(&self, r: f32) -> u32 {
        let levels = &self.levels;
        let idx = match levels.binary_search_by(|l| l.partial_cmp(&r).unwrap()) {
            Ok(i) => i,
            Err(i) => {
                if i == 0 {
                    0
                } else if i >= levels.len() {
                    levels.len() - 1
                } else if (r - levels[i - 1]).abs() <= (levels[i] - r).abs() {
                    i - 1
                } else {
                    i
                }
            }
        };
        idx as u32
    }

    /// Reconstruction value for an index.
    #[inline]
    pub fn level(&self, idx: u32) -> f32 {
        self.levels[idx as usize]
    }

    /// Expected squared quantization error under chi(k), by fine Riemann sum
    /// (diagnostics / Table 4 harness).
    pub fn expected_sq_error(&self, k: usize) -> f64 {
        let chi = ChiDistribution::new(k);
        let hi = chi.quantile(1.0 - 1e-8);
        let n = 20_000;
        let dx = hi / n as f64;
        let mut acc = 0.0;
        for i in 0..n {
            let x = (i as f64 + 0.5) * dx;
            let q = self.level(self.assign(x as f32)) as f64;
            acc += (x - q) * (x - q) * chi.pdf(x) * dx;
        }
        acc
    }
}

/// Algorithm 2: Lloyd-Max with analytic centroids.
///
/// Alternates boundary updates `u_i = (r_i + r_{i+1})/2` with centroid
/// updates `r_i = E[R | u_{i-1} < R ≤ u_i]` until the max level shift is
/// below `tol`. Because the chi centroid has a closed form
/// ([`ChiDistribution::partial_mean`]), each iteration is exact.
fn lloyd_max(n: usize, k: usize, tau: f64) -> Vec<f32> {
    let chi = ChiDistribution::new(k);
    let max_r = chi.quantile(tau);
    // init: uniform levels over (0, max_r] — as in Algorithm 2 line 2
    let mut levels: Vec<f64> = (0..n)
        .map(|i| (i as f64 + 0.5) * max_r / n as f64)
        .collect();
    let tol = 1e-10;
    let max_iter = 500;
    for _ in 0..max_iter {
        // boundaries
        let mut bounds = Vec::with_capacity(n + 1);
        bounds.push(0.0);
        for i in 0..n - 1 {
            bounds.push(0.5 * (levels[i] + levels[i + 1]));
        }
        // The outermost cell is unbounded in truth; clamp to a high quantile
        // so the centroid stays finite (τ-threshold per Algorithm 2).
        bounds.push(chi.quantile(1.0 - 1e-12).max(max_r));
        // centroids
        let mut worst = 0.0f64;
        for i in 0..n {
            let c = chi.centroid(bounds[i], bounds[i + 1]);
            worst = worst.max((c - levels[i]).abs());
            levels[i] = c;
        }
        if worst < tol {
            break;
        }
    }
    levels.into_iter().map(|x| x as f32).collect()
}

/// Table-4 ablation: plain 1-D k-means on chi(k) samples.
fn kmeans_1d(n: usize, k: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed ^ 0xBEEF);
    let samples: Vec<f64> = (0..200_000)
        .map(|_| {
            let s: f64 = (0..k).map(|_| rng.normal().powi(2)).sum();
            s.sqrt()
        })
        .collect();
    // init: quantile-spread
    let mut sorted = samples.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mut centers: Vec<f64> = (0..n)
        .map(|i| sorted[(i * sorted.len() + sorted.len() / 2) / n.max(1)])
        .collect();
    for _ in 0..60 {
        let mut sums = vec![0.0f64; n];
        let mut counts = vec![0usize; n];
        for &s in &samples {
            // nearest center (centers stay sorted)
            let mut best = 0usize;
            let mut best_d = f64::INFINITY;
            for (c, &ctr) in centers.iter().enumerate() {
                let d = (s - ctr).abs();
                if d < best_d {
                    best_d = d;
                    best = c;
                }
            }
            sums[best] += s;
            counts[best] += 1;
        }
        let mut moved = 0.0f64;
        for c in 0..n {
            if counts[c] > 0 {
                let nc = sums[c] / counts[c] as f64;
                moved = moved.max((nc - centers[c]).abs());
                centers[c] = nc;
            }
        }
        centers.sort_by(|a, b| a.partial_cmp(b).unwrap());
        if moved < 1e-9 {
            break;
        }
    }
    centers.into_iter().map(|x| x as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lloyd_max_levels_sorted_positive() {
        let cb = MagnitudeCodebook::paper_default(2, 8);
        assert_eq!(cb.len(), 4);
        assert!(cb.levels.windows(2).all(|w| w[0] < w[1]));
        assert!(cb.levels[0] > 0.0);
    }

    #[test]
    fn lloyd_max_centers_bracket_chi_mean() {
        // chi(8) mean ≈ 2.7436; with 4 levels some must lie on each side.
        let cb = MagnitudeCodebook::paper_default(2, 8);
        let mean = ChiDistribution::new(8).mean() as f32;
        assert!(cb.levels[0] < mean && cb.levels[3] > mean, "{:?}", cb.levels);
    }

    #[test]
    fn lloyd_max_satisfies_optimality_conditions() {
        // Nearest-neighbour + centroid conditions: each level equals the
        // conditional mean of its own cell.
        let cb = MagnitudeCodebook::paper_default(3, 8);
        let chi = ChiDistribution::new(8);
        let n = cb.len();
        for i in 0..n {
            let lo = if i == 0 { 0.0 } else { 0.5 * (cb.levels[i - 1] + cb.levels[i]) as f64 };
            let hi = if i == n - 1 {
                chi.quantile(1.0 - 1e-12)
            } else {
                0.5 * (cb.levels[i] + cb.levels[i + 1]) as f64
            };
            let c = chi.centroid(lo, hi);
            assert!(
                (c - cb.levels[i] as f64).abs() < 1e-5,
                "level {i}: {} vs centroid {c}",
                cb.levels[i]
            );
        }
    }

    #[test]
    fn assign_is_true_nearest() {
        let cb = MagnitudeCodebook::paper_default(4, 8);
        for t in 0..1000 {
            let r = t as f32 * 0.01;
            let idx = cb.assign(r) as usize;
            for (j, &l) in cb.levels.iter().enumerate() {
                assert!(
                    (r - cb.levels[idx]).abs() <= (r - l).abs() + 1e-6,
                    "r={r}: assigned {idx} but {j} closer"
                );
            }
        }
    }

    #[test]
    fn lloyd_max_beats_kmeans_slightly_or_ties() {
        // Lloyd-Max on the analytic law is the optimum; sampled k-means can
        // only approach it.
        let lm = MagnitudeCodebook::build(MagnitudeMethod::LloydMax, 2, 8, 1.0 - 1e-4, 0);
        let km = MagnitudeCodebook::build(MagnitudeMethod::KMeans, 2, 8, 1.0 - 1e-4, 0);
        let e_lm = lm.expected_sq_error(8);
        let e_km = km.expected_sq_error(8);
        assert!(e_lm <= e_km * 1.02, "lloyd {e_lm} vs kmeans {e_km}");
    }

    #[test]
    fn more_bits_reduce_error() {
        let e2 = MagnitudeCodebook::paper_default(2, 8).expected_sq_error(8);
        let e4 = MagnitudeCodebook::paper_default(4, 8).expected_sq_error(8);
        let e6 = MagnitudeCodebook::paper_default(6, 8).expected_sq_error(8);
        assert!(e2 > e4 && e4 > e6, "e2={e2} e4={e4} e6={e6}");
    }

    #[test]
    fn method_parse_round_trip() {
        for m in [MagnitudeMethod::LloydMax, MagnitudeMethod::KMeans] {
            assert_eq!(MagnitudeMethod::parse(m.name()), Some(m));
        }
    }
}
