//! Codebook persistence, the on-disk artifact cache, and the in-memory
//! **shared-codebook registry**.
//!
//! Like the paper (§3.2.3: "this process is offline and performed only once
//! for all circumstances"), codebooks are built once and cached under
//! `artifacts/codebooks/`. The cache key encodes method, bits, k and seed so
//! ablation variants coexist.
//!
//! The registry is the in-process layer on top of that cache: compressed
//! weight artifacts ([`crate::quant::QuantizedWeight`]) reference their
//! codebooks through `Arc`s, and the registry guarantees that every request
//! for the same codebook key hands out the *same* `Arc` — so a model's
//! resident codebook state is physically shared and counted once, no matter
//! how many layers (or quantizer instances) reference it.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::Result;

use super::{DirectionCodebook, DirectionMethod, MagnitudeCodebook, MagnitudeMethod};
use crate::io::{Entry, Pct};
use crate::tensor::Matrix;

/// In-memory registry of shared codebooks, keyed by construction spec.
#[derive(Default)]
pub struct CodebookRegistry {
    dirs: HashMap<String, Arc<DirectionCodebook>>,
    mags: HashMap<String, Arc<MagnitudeCodebook>>,
}

impl CodebookRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    fn dir_key(method: DirectionMethod, bits: u32, k: usize, seed: u64) -> String {
        format!("dir:{}:a{bits}:k{k}:s{seed}", method.name())
    }

    fn mag_key(method: MagnitudeMethod, bits: u32, k: usize, seed: u64) -> String {
        format!("mag:{}:b{bits}:k{k}:s{seed}", method.name())
    }

    /// Shared direction codebook: built (through the on-disk cache when a
    /// cache dir is given) on first request, the same `Arc` afterwards.
    pub fn direction(
        &mut self,
        cache_dir: Option<&Path>,
        method: DirectionMethod,
        bits: u32,
        k: usize,
        seed: u64,
    ) -> Result<Arc<DirectionCodebook>> {
        let key = Self::dir_key(method, bits, k, seed);
        if let Some(cb) = self.dirs.get(&key) {
            return Ok(Arc::clone(cb));
        }
        let cb = match cache_dir {
            Some(dir) => cached_direction(dir, method, bits, k, seed)?,
            None => DirectionCodebook::build(method, bits, k, seed),
        };
        let cb = Arc::new(cb);
        self.dirs.insert(key, Arc::clone(&cb));
        Ok(cb)
    }

    /// Shared magnitude codebook (see [`Self::direction`]).
    pub fn magnitude(
        &mut self,
        cache_dir: Option<&Path>,
        method: MagnitudeMethod,
        bits: u32,
        k: usize,
        seed: u64,
    ) -> Result<Arc<MagnitudeCodebook>> {
        let key = Self::mag_key(method, bits, k, seed);
        if let Some(cb) = self.mags.get(&key) {
            return Ok(Arc::clone(cb));
        }
        let cb = match cache_dir {
            Some(dir) => cached_magnitude(dir, method, bits, k, seed)?,
            None => MagnitudeCodebook::build(method, bits, k, 1.0 - 1e-4, seed),
        };
        let cb = Arc::new(cb);
        self.mags.insert(key, Arc::clone(&cb));
        Ok(cb)
    }

    /// Intern an already-materialized direction codebook (the io load path)
    /// under an explicit key.
    pub fn intern_direction(
        &mut self,
        key: &str,
        cb: impl FnOnce() -> DirectionCodebook,
    ) -> Arc<DirectionCodebook> {
        if let Some(existing) = self.dirs.get(key) {
            return Arc::clone(existing);
        }
        let cb = Arc::new(cb());
        self.dirs.insert(key.to_string(), Arc::clone(&cb));
        cb
    }

    /// Intern an already-materialized magnitude codebook.
    pub fn intern_magnitude(
        &mut self,
        key: &str,
        cb: impl FnOnce() -> MagnitudeCodebook,
    ) -> Arc<MagnitudeCodebook> {
        if let Some(existing) = self.mags.get(key) {
            return Arc::clone(existing);
        }
        let cb = Arc::new(cb());
        self.mags.insert(key.to_string(), Arc::clone(&cb));
        cb
    }

    /// Number of distinct codebooks currently registered.
    pub fn len(&self) -> usize {
        self.dirs.len() + self.mags.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// The process-wide registry ([`crate::config::build_pcdvq_with`] routes
/// through it, so repeated quantizer builds share codebook memory).
pub fn global_registry() -> &'static Mutex<CodebookRegistry> {
    static REGISTRY: OnceLock<Mutex<CodebookRegistry>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(CodebookRegistry::new()))
}

/// Save a direction codebook as a `.pct` file.
pub fn save_direction(cb: &DirectionCodebook, path: impl AsRef<Path>) -> Result<()> {
    let mut p = Pct::new();
    p.insert(
        "vectors",
        Entry::f32(
            &[cb.len() as u64, cb.dim() as u64],
            cb.vectors.as_slice().to_vec(),
        ),
    );
    p.insert("bits", Entry::u64(&[1], vec![cb.bits as u64]));
    p.insert(
        "method",
        Entry::u32(&[1], vec![direction_method_tag(cb.method)]),
    );
    p.save(path)
}

/// Load a direction codebook.
pub fn load_direction(path: impl AsRef<Path>) -> Result<DirectionCodebook> {
    let p = Pct::load(path)?;
    let e = p.get("vectors")?;
    let (n, k) = (e.dims[0] as usize, e.dims[1] as usize);
    let vectors = Matrix::from_vec(e.as_f32()?.to_vec(), n, k);
    let bits = p.get("bits")?.scalar_u64()? as u32;
    let method = parse_direction_tag(p.get("method")?.as_u32()?[0]);
    Ok(DirectionCodebook { vectors, bits, method })
}

/// Save a magnitude codebook.
pub fn save_magnitude(cb: &MagnitudeCodebook, path: impl AsRef<Path>) -> Result<()> {
    let mut p = Pct::new();
    p.insert("levels", Entry::f32(&[cb.len() as u64], cb.levels.clone()));
    p.insert("bits", Entry::u64(&[1], vec![cb.bits as u64]));
    p.insert(
        "method",
        Entry::u32(&[1], vec![magnitude_method_tag(cb.method)]),
    );
    p.save(path)
}

/// Load a magnitude codebook.
pub fn load_magnitude(path: impl AsRef<Path>) -> Result<MagnitudeCodebook> {
    let p = Pct::load(path)?;
    let levels = p.get("levels")?.as_f32()?.to_vec();
    let bits = p.get("bits")?.scalar_u64()? as u32;
    let method = parse_magnitude_tag(p.get("method")?.as_u32()?[0]);
    Ok(MagnitudeCodebook { levels, bits, method })
}

/// Build-or-load a direction codebook through the on-disk cache.
pub fn cached_direction(
    cache_dir: impl AsRef<Path>,
    method: DirectionMethod,
    bits: u32,
    k: usize,
    seed: u64,
) -> Result<DirectionCodebook> {
    let dir = cache_dir.as_ref();
    std::fs::create_dir_all(dir)?;
    let path: PathBuf =
        dir.join(format!("dir_{}_a{}_k{}_s{}.pct", method.name(), bits, k, seed));
    if path.exists() {
        if let Ok(cb) = load_direction(&path) {
            if cb.bits == bits && cb.dim() == k && cb.method == method {
                return Ok(cb);
            }
        }
    }
    let cb = DirectionCodebook::build(method, bits, k, seed);
    save_direction(&cb, &path)?;
    Ok(cb)
}

/// Build-or-load a magnitude codebook through the on-disk cache.
pub fn cached_magnitude(
    cache_dir: impl AsRef<Path>,
    method: MagnitudeMethod,
    bits: u32,
    k: usize,
    seed: u64,
) -> Result<MagnitudeCodebook> {
    let dir = cache_dir.as_ref();
    std::fs::create_dir_all(dir)?;
    let path: PathBuf =
        dir.join(format!("mag_{}_b{}_k{}_s{}.pct", method.name(), bits, k, seed));
    if path.exists() {
        if let Ok(cb) = load_magnitude(&path) {
            if cb.bits == bits && cb.method == method {
                return Ok(cb);
            }
        }
    }
    let cb = MagnitudeCodebook::build(method, bits, k, 1.0 - 1e-4, seed);
    save_magnitude(&cb, &path)?;
    Ok(cb)
}

pub(crate) fn direction_method_tag(m: DirectionMethod) -> u32 {
    match m {
        DirectionMethod::GreedyE8 => 0,
        DirectionMethod::RandomGaussian => 1,
        DirectionMethod::SimulatedAnnealing => 2,
        DirectionMethod::KMeans => 3,
    }
}

pub(crate) fn parse_direction_tag(t: u32) -> DirectionMethod {
    match t {
        0 => DirectionMethod::GreedyE8,
        1 => DirectionMethod::RandomGaussian,
        2 => DirectionMethod::SimulatedAnnealing,
        _ => DirectionMethod::KMeans,
    }
}

pub(crate) fn magnitude_method_tag(m: MagnitudeMethod) -> u32 {
    match m {
        MagnitudeMethod::LloydMax => 0,
        MagnitudeMethod::KMeans => 1,
    }
}

pub(crate) fn parse_magnitude_tag(t: u32) -> MagnitudeMethod {
    match t {
        0 => MagnitudeMethod::LloydMax,
        _ => MagnitudeMethod::KMeans,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join("pcdvq_store_tests").join(name);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn direction_save_load_round_trip() {
        let cb = DirectionCodebook::build(DirectionMethod::GreedyE8, 5, 8, 1);
        let path = tmpdir("dir").join("cb.pct");
        save_direction(&cb, &path).unwrap();
        let cb2 = load_direction(&path).unwrap();
        assert_eq!(cb.vectors.as_slice(), cb2.vectors.as_slice());
        assert_eq!(cb.bits, cb2.bits);
        assert_eq!(cb.method, cb2.method);
    }

    #[test]
    fn magnitude_save_load_round_trip() {
        let cb = MagnitudeCodebook::paper_default(2, 8);
        let path = tmpdir("mag").join("cb.pct");
        save_magnitude(&cb, &path).unwrap();
        let cb2 = load_magnitude(&path).unwrap();
        assert_eq!(cb.levels, cb2.levels);
    }

    #[test]
    fn registry_shares_one_arc_per_key() {
        let mut reg = CodebookRegistry::new();
        let a = reg.direction(None, DirectionMethod::GreedyE8, 5, 8, 3).unwrap();
        let b = reg.direction(None, DirectionMethod::GreedyE8, 5, 8, 3).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "same key must share one codebook");
        let c = reg.direction(None, DirectionMethod::GreedyE8, 6, 8, 3).unwrap();
        assert!(!Arc::ptr_eq(&a, &c), "different bits must not share");
        let m1 = reg.magnitude(None, MagnitudeMethod::LloydMax, 2, 8, 0).unwrap();
        let m2 = reg.magnitude(None, MagnitudeMethod::LloydMax, 2, 8, 0).unwrap();
        assert!(Arc::ptr_eq(&m1, &m2));
        assert_eq!(reg.len(), 3);
    }

    #[test]
    fn registry_intern_reuses_existing() {
        let mut reg = CodebookRegistry::new();
        let a = reg.intern_direction("loaded:x", || {
            DirectionCodebook::build(DirectionMethod::RandomGaussian, 4, 8, 1)
        });
        let mut built_again = false;
        let b = reg.intern_direction("loaded:x", || {
            built_again = true;
            DirectionCodebook::build(DirectionMethod::RandomGaussian, 4, 8, 1)
        });
        assert!(Arc::ptr_eq(&a, &b));
        assert!(!built_again, "intern must not rebuild on a hit");
    }

    #[test]
    fn cache_hits_return_identical_codebook() {
        let dir = tmpdir("cache");
        let a = cached_direction(&dir, DirectionMethod::GreedyE8, 4, 8, 9).unwrap();
        let b = cached_direction(&dir, DirectionMethod::GreedyE8, 4, 8, 9).unwrap();
        assert_eq!(a.vectors.as_slice(), b.vectors.as_slice());
        let m1 = cached_magnitude(&dir, MagnitudeMethod::LloydMax, 2, 8, 0).unwrap();
        let m2 = cached_magnitude(&dir, MagnitudeMethod::LloydMax, 2, 8, 0).unwrap();
        assert_eq!(m1.levels, m2.levels);
    }
}
