//! Direction codebooks: Algorithm 1 (greedy E8) and the Table-4 ablations.

use crate::lattice::e8_directions;
use crate::rng::Rng;
use crate::tensor::{dot, Matrix};

/// How to construct the direction codebook (Table 4 ablation axis).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DirectionMethod {
    /// Algorithm 1: greedy max–min-cosine sampling of E8 lattice directions.
    /// The paper's method.
    GreedyE8,
    /// Random directions of standard Gaussian vectors.
    RandomGaussian,
    /// Simulated annealing maximizing the minimal pairwise angle.
    SimulatedAnnealing,
    /// K-means (spherical) on sampled Gaussian directions.
    KMeans,
}

impl DirectionMethod {
    pub fn name(&self) -> &'static str {
        match self {
            DirectionMethod::GreedyE8 => "greedy-e8",
            DirectionMethod::RandomGaussian => "random-gaussian",
            DirectionMethod::SimulatedAnnealing => "simulated-annealing",
            DirectionMethod::KMeans => "kmeans",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "greedy-e8" => Some(DirectionMethod::GreedyE8),
            "random-gaussian" => Some(DirectionMethod::RandomGaussian),
            "simulated-annealing" => Some(DirectionMethod::SimulatedAnnealing),
            "kmeans" => Some(DirectionMethod::KMeans),
        _ => None,
        }
    }
}

/// A codebook of `2^a` unit direction vectors in R^k.
#[derive(Clone, Debug)]
pub struct DirectionCodebook {
    /// Unit vectors as rows (`2^a x k`).
    pub vectors: Matrix,
    /// Index bits `a`.
    pub bits: u32,
    /// Construction method (recorded for artifact provenance).
    pub method: DirectionMethod,
}

impl DirectionCodebook {
    /// Build a codebook with `2^bits` entries of dimension `k`.
    ///
    /// `seed` feeds the ablation constructions and greedy tie-breaks;
    /// GreedyE8 is deterministic given (bits, k, seed).
    pub fn build(method: DirectionMethod, bits: u32, k: usize, seed: u64) -> Self {
        let n = 1usize << bits;
        let vectors = match method {
            DirectionMethod::GreedyE8 => greedy_e8(n, k, seed),
            DirectionMethod::RandomGaussian => random_gaussian(n, k, seed),
            DirectionMethod::SimulatedAnnealing => simulated_annealing(n, k, seed),
            DirectionMethod::KMeans => spherical_kmeans(n, k, seed),
        };
        DirectionCodebook { vectors, bits, method }
    }

    /// Number of entries (`2^bits`).
    pub fn len(&self) -> usize {
        self.vectors.rows()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dim(&self) -> usize {
        self.vectors.cols()
    }

    /// Index of the entry with maximal cosine similarity to the (not
    /// necessarily normalized) vector `v` — Eq. 7 `VQ_φ`.
    ///
    /// Because codebook rows are unit-norm, maximizing cosine is maximizing
    /// the dot product; `v`'s own norm only scales all scores equally.
    #[inline]
    pub fn assign(&self, v: &[f32]) -> u32 {
        debug_assert_eq!(v.len(), self.dim());
        let mut best = 0u32;
        let mut best_s = f32::NEG_INFINITY;
        for j in 0..self.len() {
            let s = dot(v, self.vectors.row(j));
            if s > best_s {
                best_s = s;
                best = j as u32;
            }
        }
        best
    }

    /// Minimum pairwise angle quality metric: the max over entries of the
    /// max cosine to any *other* entry (lower = better spread). Used by the
    /// Table-4 harness and tests.
    pub fn worst_coherence(&self) -> f32 {
        let n = self.len();
        let mut worst = f32::NEG_INFINITY;
        for i in 0..n {
            for j in (i + 1)..n {
                let c = dot(self.vectors.row(i), self.vectors.row(j));
                if c > worst {
                    worst = c;
                }
            }
        }
        worst
    }
}

/// Algorithm 1: greedily select `n` directions from the E8 candidate pool,
/// each time picking the candidate whose *maximum* cosine to the already
/// selected set is *minimal* (farthest-point sampling on the sphere).
///
/// Incremental bookkeeping makes this `O(N_candidates · n · k)`:
/// after adding a center we refresh each candidate's cached max-cos with one
/// dot product against the new center only.
fn greedy_e8(n: usize, k: usize, seed: u64) -> Matrix {
    assert_eq!(k, 8, "GreedyE8 requires k = 8 (E8 lattice), got k = {k}");
    // Grow the candidate pool shell by shell until it can cover n entries.
    let mut max_norm2 = 2;
    let mut cands = e8_directions(max_norm2);
    while cands.rows() < n {
        max_norm2 += 2;
        cands = e8_directions(max_norm2);
        assert!(max_norm2 <= 32, "E8 pool exhausted before {n} candidates");
    }
    greedy_from_candidates(&cands, n, seed)
}

/// Farthest-point (max–min-cosine) greedy selection from an arbitrary pool of
/// unit vectors. Exposed for tests and for building codebooks from custom
/// candidate sets.
pub fn greedy_from_candidates(cands: &Matrix, n: usize, seed: u64) -> Matrix {
    let ncand = cands.rows();
    let k = cands.cols();
    assert!(ncand >= n, "pool of {ncand} cannot yield {n} directions");
    let mut rng = Rng::new(seed);
    let first = rng.below(ncand);

    let mut selected: Vec<usize> = Vec::with_capacity(n);
    // max cosine of each candidate to the selected set so far
    let mut max_cos = vec![f32::NEG_INFINITY; ncand];
    let mut taken = vec![false; ncand];

    selected.push(first);
    taken[first] = true;
    update_max_cos(cands, first, &mut max_cos);

    for _ in 1..n {
        // candidate with minimal max-cos to the selected set
        let mut best = usize::MAX;
        let mut best_v = f32::INFINITY;
        for i in 0..ncand {
            if !taken[i] && max_cos[i] < best_v {
                best_v = max_cos[i];
                best = i;
            }
        }
        debug_assert!(best != usize::MAX);
        selected.push(best);
        taken[best] = true;
        update_max_cos(cands, best, &mut max_cos);
    }

    let mut out = Vec::with_capacity(n * k);
    for &i in &selected {
        out.extend_from_slice(cands.row(i));
    }
    Matrix::from_vec(out, n, k)
}

#[inline]
fn update_max_cos(cands: &Matrix, new_center: usize, max_cos: &mut [f32]) {
    let c = cands.row(new_center).to_vec();
    for i in 0..cands.rows() {
        let d = dot(cands.row(i), &c);
        if d > max_cos[i] {
            max_cos[i] = d;
        }
    }
}

/// Table-4 ablation: directions of i.i.d. standard Gaussian vectors.
fn random_gaussian(n: usize, k: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    let mut data = Vec::with_capacity(n * k);
    for _ in 0..n {
        let mut v = rng.normal_vec(k);
        normalize(&mut v);
        data.extend_from_slice(&v);
    }
    Matrix::from_vec(data, n, k)
}

/// Table-4 ablation: simulated annealing that *minimizes the maximal pairwise
/// cosine* (i.e. maximizes the minimal angle), starting from random Gaussian
/// directions and proposing single-entry jitter moves.
fn simulated_annealing(n: usize, k: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed ^ 0xA55A);
    let mut book = random_gaussian(n, k, seed);
    // Energy: sum of soft-max-ish pairwise penalties. Full O(n²) per sweep is
    // too slow for n = 2^14+, so anneal against a random mini-batch of rivals
    // per move — standard for large-n sphere packings.
    let iters = 20_000.min(n * 40);
    let rivals = 64.min(n - 1);
    let mut temp = 0.1f32;
    let cool = 0.9995f32;
    for _ in 0..iters {
        let i = rng.below(n);
        // propose: jitter entry i
        let mut prop: Vec<f32> = book.row(i).to_vec();
        for x in prop.iter_mut() {
            *x += 0.15 * rng.normal() as f32;
        }
        normalize(&mut prop);
        let (mut cur_e, mut prop_e) = (0.0f32, 0.0f32);
        for _ in 0..rivals {
            let j = {
                let mut j = rng.below(n);
                while j == i {
                    j = rng.below(n);
                }
                j
            };
            cur_e = cur_e.max(dot(book.row(i), book.row(j)));
            prop_e = prop_e.max(dot(&prop, book.row(j)));
        }
        let accept = prop_e < cur_e
            || rng.uniform() < (-(prop_e - cur_e) / temp).exp() as f64;
        if accept {
            book.row_mut(i).copy_from_slice(&prop);
        }
        temp *= cool;
    }
    book
}

/// Table-4 ablation: spherical k-means on Gaussian direction samples.
fn spherical_kmeans(n: usize, k: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed ^ 0x1234);
    // Sample a training pool of directions (4x the codebook size, capped) —
    // larger codebooks get fewer Lloyd iterations to keep the offline build
    // bounded (it is a one-time, cached artifact like the paper's).
    let pool_n = (4 * n).clamp(1024, 100_000);
    let iters = if n >= 16_384 { 5 } else { 25 };
    let pool = random_gaussian(pool_n, k, seed ^ 0x77);
    // init: random subset
    let init = rng.sample_indices(pool_n, n);
    let mut centers = Vec::with_capacity(n * k);
    for &i in &init {
        centers.extend_from_slice(pool.row(i));
    }
    let mut centers = Matrix::from_vec(centers, n, k);

    let mut assign = vec![0usize; pool_n];
    let mut assign_buf = vec![0u32; pool_n];
    for _iter in 0..iters {
        // assignment step (max cosine) via the blocked hot path
        crate::quant::assign::assign_into(&pool, &centers, &[], &mut assign_buf);
        let mut moved = 0usize;
        for (i, &best) in assign_buf.iter().enumerate() {
            let best = best as usize;
            if assign[i] != best {
                moved += 1;
                assign[i] = best;
            }
        }
        // update step: mean then re-normalize (spherical k-means)
        let mut sums = vec![0.0f32; n * k];
        let mut counts = vec![0usize; n];
        for i in 0..pool_n {
            let c = assign[i];
            counts[c] += 1;
            for (s, &x) in sums[c * k..(c + 1) * k].iter_mut().zip(pool.row(i)) {
                *s += x;
            }
        }
        for c in 0..n {
            if counts[c] == 0 {
                // re-seed empty cluster from a random pool vector
                let r = rng.below(pool_n);
                centers.row_mut(c).copy_from_slice(pool.row(r));
                continue;
            }
            let mut v = sums[c * k..(c + 1) * k].to_vec();
            normalize(&mut v);
            centers.row_mut(c).copy_from_slice(&v);
        }
        if moved == 0 {
            break;
        }
    }
    centers
}

fn normalize(v: &mut [f32]) {
    let n: f32 = v.iter().map(|x| x * x).sum::<f32>().sqrt();
    if n > 0.0 {
        for x in v.iter_mut() {
            *x /= n;
        }
    } else {
        v[0] = 1.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_unit_rows(m: &Matrix) {
        for i in 0..m.rows() {
            let n: f32 = m.row(i).iter().map(|x| x * x).sum::<f32>().sqrt();
            assert!((n - 1.0).abs() < 1e-4, "row {i} norm {n}");
        }
    }

    #[test]
    fn greedy_e8_small_codebook() {
        let cb = DirectionCodebook::build(DirectionMethod::GreedyE8, 6, 8, 0);
        assert_eq!(cb.len(), 64);
        assert_eq!(cb.dim(), 8);
        check_unit_rows(&cb.vectors);
        // spread: no two entries closer than ~25 degrees for 64-of-240
        assert!(cb.worst_coherence() < 0.95, "coherence={}", cb.worst_coherence());
    }

    #[test]
    fn greedy_e8_deterministic() {
        let a = DirectionCodebook::build(DirectionMethod::GreedyE8, 5, 8, 3);
        let b = DirectionCodebook::build(DirectionMethod::GreedyE8, 5, 8, 3);
        assert_eq!(a.vectors.as_slice(), b.vectors.as_slice());
    }

    #[test]
    fn greedy_beats_random_on_coherence() {
        // The paper's Table 4 claim in miniature: greedy E8 spreads directions
        // better than random Gaussian sampling.
        let g = DirectionCodebook::build(DirectionMethod::GreedyE8, 7, 8, 0);
        let r = DirectionCodebook::build(DirectionMethod::RandomGaussian, 7, 8, 0);
        assert!(
            g.worst_coherence() < r.worst_coherence(),
            "greedy {} vs random {}",
            g.worst_coherence(),
            r.worst_coherence()
        );
    }

    #[test]
    fn all_methods_produce_unit_rows() {
        for m in [
            DirectionMethod::RandomGaussian,
            DirectionMethod::SimulatedAnnealing,
            DirectionMethod::KMeans,
        ] {
            let cb = DirectionCodebook::build(m, 5, 8, 42);
            assert_eq!(cb.len(), 32);
            check_unit_rows(&cb.vectors);
        }
    }

    #[test]
    fn assign_picks_exact_match() {
        let cb = DirectionCodebook::build(DirectionMethod::GreedyE8, 6, 8, 0);
        for probe in [0usize, 17, 63] {
            let v: Vec<f32> = cb.vectors.row(probe).iter().map(|x| 3.5 * x).collect();
            assert_eq!(cb.assign(&v) as usize, probe);
        }
    }

    #[test]
    fn annealing_improves_over_its_random_init() {
        let n = 32;
        let sa = simulated_annealing(n, 8, 7);
        let rand = random_gaussian(n, 8, 7);
        let coh = |m: &Matrix| {
            let mut w = f32::NEG_INFINITY;
            for i in 0..n {
                for j in (i + 1)..n {
                    w = w.max(dot(m.row(i), m.row(j)));
                }
            }
            w
        };
        assert!(coh(&sa) <= coh(&rand) + 1e-6, "sa={} rand={}", coh(&sa), coh(&rand));
    }

    #[test]
    fn method_parse_round_trip() {
        for m in [
            DirectionMethod::GreedyE8,
            DirectionMethod::RandomGaussian,
            DirectionMethod::SimulatedAnnealing,
            DirectionMethod::KMeans,
        ] {
            assert_eq!(DirectionMethod::parse(m.name()), Some(m));
        }
        assert_eq!(DirectionMethod::parse("nope"), None);
    }
}
