//! DACC — Distribution Aligned Codebook Construction (paper §3.2.3).
//!
//! Two independent codebooks, both built **offline, once**, because after the
//! standard-Gaussian regularization every weight layer feeds the same two
//! distributions:
//!
//! * **Direction** — uniform on the sphere S^{k-1}: a `2^a`-entry codebook of
//!   unit vectors, greedily max–min-cosine sampled from E8 lattice directions
//!   (Algorithm 1). Ablation variants (Table 4): random Gaussian, simulated
//!   annealing, k-means.
//! * **Magnitude** — chi(k) distributed: a `2^b`-entry scalar codebook from
//!   Lloyd-Max against the analytic chi PDF (Algorithm 2). Ablation variant:
//!   k-means on sampled magnitudes.

pub mod direction;
pub mod magnitude;
pub mod store;

pub use direction::{DirectionCodebook, DirectionMethod};
pub use magnitude::{MagnitudeCodebook, MagnitudeMethod};
