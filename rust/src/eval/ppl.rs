//! Byte-level perplexity over the held-out corpus (WikiText2/C4 analog).

use anyhow::Result;

use super::ForwardPass;
use crate::model::GptConfig;

/// Perplexity evaluation result.
#[derive(Clone, Copy, Debug)]
pub struct PplResult {
    /// Mean negative log-likelihood per byte (nats).
    pub nll: f64,
    /// exp(nll) — byte-level perplexity.
    pub ppl: f64,
    /// nll / ln 2 — bits per byte.
    pub bits_per_byte: f64,
    /// Bytes scored.
    pub n_tokens: usize,
}

/// Log-softmax NLL of `target` under a logit row.
#[inline]
fn row_nll(logits: &[f32], target: usize) -> f64 {
    let mut maxv = f32::NEG_INFINITY;
    for &v in logits {
        if v > maxv {
            maxv = v;
        }
    }
    let mut sum = 0.0f64;
    for &v in logits {
        sum += ((v - maxv) as f64).exp();
    }
    (sum.ln() + maxv as f64) - logits[target] as f64
}

/// Score non-overlapping windows of the token stream with a forward backend
/// (batch geometry comes from the artifact: `(B, T)`).
///
/// `temperature` scales logits before the softmax (the Table-3 "e2e tuning"
/// analog); pass 1.0 for the plain metric. `max_windows` caps cost.
pub fn evaluate_ppl<F: ForwardPass + ?Sized>(
    bound: &F,
    cfg: &GptConfig,
    tokens: &[u32],
    batch: usize,
    max_windows: usize,
    temperature: f32,
) -> Result<PplResult> {
    let t = cfg.ctx;
    let v = cfg.vocab;
    let n_windows = ((tokens.len() - 1) / t).min(max_windows);
    anyhow::ensure!(n_windows >= 1, "token stream too short for one window");

    // Unbatched scoring through a stateful decode session when the backend
    // has one: same windows, same t-1 targets per window, but the session
    // never forwards the padded tail or the last (unscored) position. The
    // batched block path below is unchanged (and is the only path for the
    // fixed-geometry XLA executables).
    let session = if batch == 1 { bound.begin_session() } else { None };
    if let Some(mut sess) = session {
        let mut total_nll = 0.0f64;
        let mut total_count = 0usize;
        for w in 0..n_windows {
            sess.reset();
            let s = w * t;
            for pos in 0..t - 1 {
                let logits = sess.step(tokens[s + pos] as i32)?;
                debug_assert_eq!(logits.len(), v);
                let target = tokens[s + pos + 1] as usize;
                if temperature != 1.0 {
                    let scaled: Vec<f32> =
                        logits.iter().map(|x| x / temperature).collect();
                    total_nll += row_nll(&scaled, target);
                } else {
                    total_nll += row_nll(&logits, target);
                }
                total_count += 1;
            }
        }
        let nll = total_nll / total_count as f64;
        return Ok(PplResult {
            nll,
            ppl: nll.exp(),
            bits_per_byte: nll / std::f64::consts::LN_2,
            n_tokens: total_count,
        });
    }

    let mut total_nll = 0.0f64;
    let mut total_count = 0usize;
    let mut win = 0usize;
    while win < n_windows {
        let bsz = batch.min(n_windows - win);
        // assemble a full (batch, t) token block; ragged tails repeat the
        // last window (scored only for the real ones)
        let mut block = vec![0i32; batch * t];
        for b in 0..batch {
            let w = (win + b).min(n_windows - 1);
            let s = w * t;
            for j in 0..t {
                block[b * t + j] = tokens[s + j] as i32;
            }
        }
        let out = bound.forward_block(block, batch, t)?;
        debug_assert_eq!(out.len(), batch * t * v);
        for b in 0..bsz {
            let w = win + b;
            let s = w * t;
            for pos in 0..t - 1 {
                let target = tokens[s + pos + 1] as usize;
                let row = &out[(b * t + pos) * v..(b * t + pos + 1) * v];
                if temperature != 1.0 {
                    let scaled: Vec<f32> = row.iter().map(|x| x / temperature).collect();
                    total_nll += row_nll(&scaled, target);
                } else {
                    total_nll += row_nll(row, target);
                }
                total_count += 1;
            }
        }
        win += bsz;
    }
    let nll = total_nll / total_count as f64;
    Ok(PplResult {
        nll,
        ppl: nll.exp(),
        bits_per_byte: nll / std::f64::consts::LN_2,
        n_tokens: total_count,
    })
}

/// Fit a logit temperature on a calibration slice by golden-section search —
/// the closed-form "end-to-end tuning" analog of Table 3 (adjusting the
/// output distribution like norm-layer fine-tuning does, without gradients).
pub fn fit_temperature<F: ForwardPass + ?Sized>(
    bound: &F,
    cfg: &GptConfig,
    calib_tokens: &[u32],
    batch: usize,
    max_windows: usize,
) -> Result<f32> {
    let eval = |temp: f32| -> Result<f64> {
        Ok(evaluate_ppl(bound, cfg, calib_tokens, batch, max_windows, temp)?.nll)
    };
    // golden-section on [0.7, 1.6]
    let (mut lo, mut hi) = (0.7f32, 1.6f32);
    let phi = 0.618_034f32;
    let mut x1 = hi - phi * (hi - lo);
    let mut x2 = lo + phi * (hi - lo);
    let mut f1 = eval(x1)?;
    let mut f2 = eval(x2)?;
    for _ in 0..8 {
        if f1 < f2 {
            hi = x2;
            x2 = x1;
            f2 = f1;
            x1 = hi - phi * (hi - lo);
            f1 = eval(x1)?;
        } else {
            lo = x1;
            x1 = x2;
            f1 = f2;
            x2 = lo + phi * (hi - lo);
            f2 = eval(x2)?;
        }
    }
    Ok(0.5 * (lo + hi))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_nll_uniform_logits() {
        let logits = vec![0.0f32; 256];
        let nll = row_nll(&logits, 7);
        assert!((nll - (256f64).ln()).abs() < 1e-9);
    }

    #[test]
    fn row_nll_confident_prediction() {
        let mut logits = vec![0.0f32; 16];
        logits[3] = 20.0;
        assert!(row_nll(&logits, 3) < 1e-6);
        assert!(row_nll(&logits, 4) > 19.0);
    }

    #[test]
    fn row_nll_shift_invariant() {
        let a: Vec<f32> = (0..32).map(|i| (i as f32).sin()).collect();
        let b: Vec<f32> = a.iter().map(|x| x + 100.0).collect();
        assert!((row_nll(&a, 5) - row_nll(&b, 5)).abs() < 1e-4);
    }
}
