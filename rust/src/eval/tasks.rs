//! Zero-shot proxy tasks — the Arc-C/Arc-E/HellaSwag/PIQA/WinoGrande analog.
//!
//! lm-eval's zero-shot scoring ranks answer choices by (length-normalized)
//! model log-likelihood; we reproduce that code path on multiple-choice
//! *continuation* items built deterministically from the held-out corpus:
//! given a byte prefix, pick the true next-C-bytes among distractors. Five
//! variants of increasing difficulty play the role of the five QA datasets
//! (DESIGN.md §2).

use anyhow::Result;

use super::ForwardPass;
use crate::model::GptConfig;
use crate::rng::Rng;

/// The five proxy tasks.
pub const TASK_NAMES: [&str; 5] = ["cont-32", "cont-16", "cont-8", "nearby-16", "shift-16"];

/// Per-task accuracy plus the average (the paper's "QA Avg").
#[derive(Clone, Debug)]
pub struct TaskResult {
    pub accuracy: Vec<f64>,
    pub avg: f64,
    pub n_items: usize,
}

/// One multiple-choice item: token window per choice, continuation span.
struct Item {
    /// (n_choices, ctx) token windows: prefix + candidate continuation.
    windows: Vec<Vec<i32>>,
    /// continuation span `[start, end)` in window positions.
    span: (usize, usize),
}

const N_CHOICES: usize = 4;

fn build_items(
    task: &str,
    tokens: &[u32],
    ctx: usize,
    n_items: usize,
    seed: u64,
) -> Vec<Item> {
    let mut rng = Rng::new(seed ^ 0x7A5C);
    let cont_len = match task {
        "cont-32" => 32,
        "cont-16" | "nearby-16" | "shift-16" => 16,
        "cont-8" => 8,
        other => panic!("unknown task {other}"),
    };
    let prefix = ctx - cont_len;
    let max_start = tokens.len() - ctx - 64;
    let mut items = Vec::with_capacity(n_items);
    for _ in 0..n_items {
        let s = rng.below(max_start);
        let window: Vec<i32> = tokens[s..s + ctx].iter().map(|&t| t as i32).collect();
        let mut windows = vec![window.clone()];
        for _d in 0..N_CHOICES - 1 {
            let mut w = window.clone();
            match task {
                "shift-16" => {
                    // distractor: the true continuation shifted 1-4 bytes
                    let shift = 1 + rng.below(4);
                    for j in 0..cont_len {
                        w[prefix + j] = tokens[s + prefix + shift + j] as i32;
                    }
                }
                "nearby-16" => {
                    // distractor continuation from within ±2 KiB
                    let lo = s.saturating_sub(2048);
                    let hi = (s + 2048).min(max_start);
                    let d = lo + rng.below(hi - lo);
                    for j in 0..cont_len {
                        w[prefix + j] = tokens[d + prefix + j] as i32;
                    }
                }
                _ => {
                    // distractor continuation from a random position
                    let d = rng.below(max_start);
                    for j in 0..cont_len {
                        w[prefix + j] = tokens[d + prefix + j] as i32;
                    }
                }
            }
            windows.push(w);
        }
        items.push(Item { windows, span: (prefix, ctx) });
    }
    items
}

/// Mean per-byte log-likelihood of a window's continuation span, given the
/// logits block of the whole window.
fn span_logprob(logits: &[f32], window: &[i32], span: (usize, usize), vocab: usize) -> f64 {
    let (lo, hi) = span;
    let mut total = 0.0f64;
    for pos in lo..hi {
        // position pos is predicted by logits at pos-1
        let row = &logits[(pos - 1) * vocab..pos * vocab];
        let target = window[pos] as usize;
        let mut maxv = f32::NEG_INFINITY;
        for &v in row {
            if v > maxv {
                maxv = v;
            }
        }
        let mut sum = 0.0f64;
        for &v in row {
            sum += ((v - maxv) as f64).exp();
        }
        total += row[target] as f64 - (sum.ln() + maxv as f64);
    }
    total / (hi - lo) as f64
}

/// Evaluate the five proxy tasks; returns per-task accuracy + average.
pub fn evaluate_tasks<F: ForwardPass + ?Sized>(
    bound: &F,
    cfg: &GptConfig,
    eval_tokens: &[u32],
    batch: usize,
    n_items: usize,
    seed: u64,
) -> Result<TaskResult> {
    let v = cfg.vocab;
    let t = cfg.ctx;
    let mut accs = Vec::with_capacity(TASK_NAMES.len());
    for task in TASK_NAMES {
        let items = build_items(task, eval_tokens, t, n_items, seed);
        // flatten all windows, batch them through the executable
        let all_windows: Vec<&Vec<i32>> =
            items.iter().flat_map(|it| it.windows.iter()).collect();
        let mut scores = vec![0.0f64; all_windows.len()];
        let mut idx = 0usize;
        while idx < all_windows.len() {
            let bsz = batch.min(all_windows.len() - idx);
            let mut block = vec![0i32; batch * t];
            for b in 0..bsz {
                block[b * t..(b + 1) * t].copy_from_slice(all_windows[idx + b]);
            }
            let out = bound.forward_block(block, batch, t)?;
            for b in 0..bsz {
                let logits = &out[b * t * v..(b + 1) * t * v];
                let item = &items[(idx + b) / N_CHOICES];
                scores[idx + b] =
                    span_logprob(logits, all_windows[idx + b], item.span, v);
            }
            idx += bsz;
        }
        // accuracy: choice 0 (the true continuation) must score highest
        let mut correct = 0usize;
        for (i, _item) in items.iter().enumerate() {
            let s = &scores[i * N_CHOICES..(i + 1) * N_CHOICES];
            let best = s
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if best == 0 {
                correct += 1;
            }
        }
        accs.push(correct as f64 / items.len() as f64);
    }
    let avg = accs.iter().sum::<f64>() / accs.len() as f64;
    Ok(TaskResult { accuracy: accs, avg, n_items })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_items_shapes() {
        let tokens: Vec<u32> = (0..20_000u32).map(|i| i % 256).collect();
        for task in TASK_NAMES {
            let items = build_items(task, &tokens, 128, 10, 7);
            assert_eq!(items.len(), 10);
            for it in &items {
                assert_eq!(it.windows.len(), N_CHOICES);
                for w in &it.windows {
                    assert_eq!(w.len(), 128);
                }
                let (lo, hi) = it.span;
                assert!(lo > 0 && hi == 128);
            }
        }
    }

    #[test]
    fn distractors_differ_from_truth() {
        let tokens: Vec<u32> = (0..50_000u32).map(|i| (i * 17 + 3) % 256).collect();
        let items = build_items("cont-16", &tokens, 128, 20, 3);
        let mut diffs = 0;
        for it in &items {
            let (lo, hi) = it.span;
            for c in 1..N_CHOICES {
                if it.windows[c][lo..hi] != it.windows[0][lo..hi] {
                    diffs += 1;
                }
            }
        }
        assert!(diffs > 50, "only {diffs} distractors differ");
    }

    #[test]
    fn span_logprob_prefers_predicted_bytes() {
        // fabricate logits that put all mass on byte 42 everywhere
        let t = 16usize;
        let v = 64usize;
        let mut logits = vec![0.0f32; t * v];
        for pos in 0..t {
            logits[pos * v + 42] = 15.0;
        }
        let mut w_good = vec![42i32; t];
        let w_bad = vec![7i32; t];
        w_good[0] = 0; // first position unscored anyway
        let good = span_logprob(&logits, &w_good, (8, 16), v);
        let bad = span_logprob(&logits, &w_bad, (8, 16), v);
        assert!(good > bad + 10.0);
    }

    #[test]
    fn deterministic_items() {
        let tokens: Vec<u32> = (0..30_000u32).map(|i| i % 251).collect();
        let a = build_items("cont-8", &tokens, 128, 5, 9);
        let b = build_items("cont-8", &tokens, 128, 5, 9);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.windows, y.windows);
        }
    }
}
