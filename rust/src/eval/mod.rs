//! Evaluation harness: perplexity (WikiText2/C4 analog) and the five
//! zero-shot proxy tasks (Arc/HellaSwag/PIQA/WinoGrande analog).
//!
//! Everything here drives a [`ForwardPass`] — either the AOT
//! `fwd_fp_<model>_b8` executable with *bound* (device-resident) weights, so
//! per-batch work is one token upload + one execute + a host-side softmax
//! reduction, or the host backend ([`HostForward`]), which can evaluate a
//! **codes-resident** model without ever materializing its dense weights.
//! Serving uses the same two code paths.

mod ppl;
mod tasks;

pub use ppl::{evaluate_ppl, fit_temperature, PplResult};
pub use tasks::{evaluate_tasks, TaskResult, TASK_NAMES};

use crate::model::{GptModel, HostForward};
use crate::runtime::{BoundExecutable, Input};

/// A batched forward pass: `(b, t)` token block → logits `(b · t · vocab)`.
pub trait ForwardPass {
    fn forward_block(&self, tokens: Vec<i32>, b: usize, t: usize)
        -> anyhow::Result<Vec<f32>>;
}

impl ForwardPass for BoundExecutable {
    fn forward_block(
        &self,
        tokens: Vec<i32>,
        b: usize,
        t: usize,
    ) -> anyhow::Result<Vec<f32>> {
        self.run_f32(&[Input::I32(tokens, vec![b, t])])
    }
}

impl ForwardPass for HostForward {
    fn forward_block(
        &self,
        tokens: Vec<i32>,
        b: usize,
        t: usize,
    ) -> anyhow::Result<Vec<f32>> {
        self.forward(&tokens, b, t)
    }
}

/// Build the fixed (weight) inputs of a forward executable in manifest
/// order, from a (possibly fake-quant) model. The trailing `tokens` input is
/// the varying one.
pub fn weight_inputs(
    model: &GptModel,
    manifest: &crate::runtime::Manifest,
) -> anyhow::Result<Vec<Input>> {
    let mut out = Vec::with_capacity(manifest.len() - 1);
    for e in &manifest.entries {
        if e.name == "tokens" {
            continue;
        }
        let t = model.tensor(&e.name)?;
        let dims = model
            .dims
            .get(&e.name)
            .cloned()
            .unwrap_or_else(|| vec![t.rows(), t.cols()]);
        anyhow::ensure!(
            dims.iter().product::<usize>() == t.len(),
            "tensor '{}' dims mismatch",
            e.name
        );
        out.push(Input::F32(t.as_slice().to_vec(), dims));
    }
    Ok(out)
}
