//! Evaluation harness: perplexity (WikiText2/C4 analog) and the five
//! zero-shot proxy tasks (Arc/HellaSwag/PIQA/WinoGrande analog).
//!
//! Everything here drives a [`ForwardPass`] — either the AOT
//! `fwd_fp_<model>_b8` executable with *bound* (device-resident) weights, so
//! per-batch work is one token upload + one execute + a host-side softmax
//! reduction, or the host backend ([`HostForward`]), which can evaluate a
//! **codes-resident** model without ever materializing its dense weights.
//! Serving uses the same two code paths. Backends with a KV cache also
//! expose a stateful [`DecodeSession`] ([`ForwardPass::begin_session`]):
//! unbatched perplexity and [`greedy_decode`] ride it for O(1) model work
//! per token instead of per-window re-forwards.

mod ppl;
mod tasks;

pub use ppl::{evaluate_ppl, fit_temperature, PplResult};
pub use tasks::{evaluate_tasks, TaskResult, TASK_NAMES};

use crate::model::{GptConfig, GptModel, HostForward, KvCache};
use crate::quant::kv::KvQuantCodec;
use crate::runtime::{BoundExecutable, Input};
use std::sync::Arc;

/// A batched forward pass: `(b, t)` token block → logits `(b · t · vocab)`.
pub trait ForwardPass {
    fn forward_block(&self, tokens: Vec<i32>, b: usize, t: usize)
        -> anyhow::Result<Vec<f32>>;

    /// Begin a stateful incremental-decode session, if the backend supports
    /// one. `None` (the default) means block re-forward is the only mode —
    /// the fixed-geometry XLA executables, for instance. The host backend
    /// returns a KV-cached session.
    fn begin_session(&self) -> Option<Box<dyn DecodeSession + '_>> {
        None
    }
}

/// A stateful decode stream: feed tokens one at a time, get the logits at
/// each new position. Backed by a [`KvCache`] on the host backend, so N
/// steps cost O(N) model work instead of the O(N²) of re-forwarding.
pub trait DecodeSession {
    /// Feed one token; returns the logits (`vocab` floats) at its position.
    fn step(&mut self, token: i32) -> anyhow::Result<Vec<f32>>;

    /// Feed a whole prompt; returns the logits at the last position (the
    /// row that predicts the first generated token). The default steps
    /// token-at-a-time; backends with block prefill override this to fill
    /// their cache in bulk with a single head projection at the end —
    /// byte-identical results, lower time-to-first-token.
    fn prefill(&mut self, tokens: &[i32]) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(!tokens.is_empty(), "prefill needs at least one token");
        let mut logits = Vec::new();
        for &t in tokens {
            logits = self.step(t)?;
        }
        Ok(logits)
    }

    /// Tokens currently attended over (the window shrinks only when the
    /// backing cache slides past its capacity).
    fn window_len(&self) -> usize;

    /// Drop all decode state — the next [`Self::step`] starts a new stream.
    fn reset(&mut self);
}

impl ForwardPass for BoundExecutable {
    fn forward_block(
        &self,
        tokens: Vec<i32>,
        b: usize,
        t: usize,
    ) -> anyhow::Result<Vec<f32>> {
        self.run_f32(&[Input::I32(tokens, vec![b, t])])
    }
}

impl ForwardPass for HostForward {
    fn forward_block(
        &self,
        tokens: Vec<i32>,
        b: usize,
        t: usize,
    ) -> anyhow::Result<Vec<f32>> {
        self.forward(&tokens, b, t)
    }

    fn begin_session(&self) -> Option<Box<dyn DecodeSession + '_>> {
        Some(Box::new(HostSession {
            hf: self,
            cache: KvCache::new(&self.config),
        }))
    }
}

/// [`HostForward`] wrapper whose decode sessions run against a
/// **quantized** KV cache: every session it opens stores K/V rows as
/// polar-decoupled codes under the shared [`KvQuantCodec`] (DESIGN.md §15).
/// Block evaluation ([`ForwardPass::forward_block`]) is unchanged — only the
/// cached path quantizes — so `evaluate_ppl` in unbatched (session) mode
/// measures exactly the quantized-cache quality the serving loop ships.
pub struct KvQuantForward<'a> {
    hf: &'a HostForward,
    codec: Arc<KvQuantCodec>,
}

impl<'a> KvQuantForward<'a> {
    /// Wrap `hf` so sessions decode through `codec`'s cache layout. The
    /// codec geometry must match the model (asserted at cache build).
    pub fn new(hf: &'a HostForward, codec: Arc<KvQuantCodec>) -> Self {
        KvQuantForward { hf, codec }
    }

    /// The shared cache codec (e.g. to read accounting after an eval).
    pub fn codec(&self) -> &Arc<KvQuantCodec> {
        &self.codec
    }
}

impl ForwardPass for KvQuantForward<'_> {
    fn forward_block(
        &self,
        tokens: Vec<i32>,
        b: usize,
        t: usize,
    ) -> anyhow::Result<Vec<f32>> {
        self.hf.forward(&tokens, b, t)
    }

    fn begin_session(&self) -> Option<Box<dyn DecodeSession + '_>> {
        Some(Box::new(HostSession {
            hf: self.hf,
            cache: KvCache::with_codec(&self.hf.config, Some(self.codec.clone())),
        }))
    }
}

/// Host-backend decode session: a borrowed [`HostForward`] + its own cache.
struct HostSession<'a> {
    hf: &'a HostForward,
    cache: KvCache,
}

impl DecodeSession for HostSession<'_> {
    fn step(&mut self, token: i32) -> anyhow::Result<Vec<f32>> {
        self.hf.decode_step(token, &mut self.cache)
    }

    fn prefill(&mut self, tokens: &[i32]) -> anyhow::Result<Vec<f32>> {
        // block prefill: whole-window chunks, one head projection at the end
        let chunk = self.cache.capacity();
        self.hf.prefill_block(tokens, &mut self.cache, chunk)
    }

    fn window_len(&self) -> usize {
        self.cache.len()
    }

    fn reset(&mut self) {
        self.cache.reset();
    }
}

/// Greedy-decode `max_new` tokens after `prompt` (truncated to the last
/// `ctx - 1` bytes, mirroring the serving loop). Uses the backend's stateful
/// session when it has one — O(1) model work per token — and falls back to
/// windowed re-forward otherwise. The two paths match while
/// `prompt + generated` fits in `ctx`; past that the cached path slides by
/// its eviction stride rather than per-token.
pub fn greedy_decode<F: ForwardPass + ?Sized>(
    backend: &F,
    cfg: &GptConfig,
    prompt: &[u8],
    max_new: usize,
) -> anyhow::Result<Vec<u8>> {
    anyhow::ensure!(!prompt.is_empty(), "empty prompt");
    let mut buf: Vec<i32> = prompt
        .iter()
        .rev()
        .take(cfg.ctx - 1)
        .rev()
        .map(|&x| x as i32)
        .collect();
    let mut out = Vec::with_capacity(max_new);
    if let Some(mut sess) = backend.begin_session() {
        let mut logits = sess.prefill(&buf)?;
        for i in 0..max_new {
            let next = crate::tensor::argmax(&logits) as u8;
            out.push(next);
            if i + 1 < max_new {
                logits = sess.step(next as i32)?;
            }
        }
    } else {
        for _ in 0..max_new {
            let start = buf.len().saturating_sub(cfg.ctx);
            let window = buf[start..].to_vec();
            let t = window.len();
            let logits = backend.forward_block(window, 1, t)?;
            let row = &logits[(t - 1) * cfg.vocab..t * cfg.vocab];
            let next = crate::tensor::argmax(row) as u8;
            out.push(next);
            buf.push(next as i32);
        }
    }
    Ok(out)
}

/// Build the fixed (weight) inputs of a forward executable in manifest
/// order, from a (possibly fake-quant) model. The trailing `tokens` input is
/// the varying one.
pub fn weight_inputs(
    model: &GptModel,
    manifest: &crate::runtime::Manifest,
) -> anyhow::Result<Vec<Input>> {
    let mut out = Vec::with_capacity(manifest.len() - 1);
    for e in &manifest.entries {
        if e.name == "tokens" {
            continue;
        }
        let t = model.tensor(&e.name)?;
        let dims = model
            .dims
            .get(&e.name)
            .cloned()
            .unwrap_or_else(|| vec![t.rows(), t.cols()]);
        anyhow::ensure!(
            dims.iter().product::<usize>() == t.len(),
            "tensor '{}' dims mismatch",
            e.name
        );
        out.push(Input::F32(t.as_slice().to_vec(), dims));
    }
    Ok(out)
}
