//! Evaluation harness: perplexity (WikiText2/C4 analog) and the five
//! zero-shot proxy tasks (Arc/HellaSwag/PIQA/WinoGrande analog).
//!
//! Everything here drives the AOT `fwd_fp_<model>_b8` executable through the
//! runtime with *bound* (device-resident) weights, so per-batch work is one
//! token upload + one execute + a host-side softmax reduction — the same
//! code path serving uses.

mod ppl;
mod tasks;

pub use ppl::{evaluate_ppl, fit_temperature, PplResult};
pub use tasks::{evaluate_tasks, TaskResult, TASK_NAMES};

use crate::model::GptModel;
use crate::runtime::Input;

/// Build the fixed (weight) inputs of a forward executable in manifest
/// order, from a (possibly fake-quant) model. The trailing `tokens` input is
/// the varying one.
pub fn weight_inputs(
    model: &GptModel,
    manifest: &crate::runtime::Manifest,
) -> anyhow::Result<Vec<Input>> {
    let mut out = Vec::with_capacity(manifest.len() - 1);
    for e in &manifest.entries {
        if e.name == "tokens" {
            continue;
        }
        let t = model.tensor(&e.name)?;
        let dims = model
            .dims
            .get(&e.name)
            .cloned()
            .unwrap_or_else(|| vec![t.rows(), t.cols()]);
        anyhow::ensure!(
            dims.iter().product::<usize>() == t.len(),
            "tensor '{}' dims mismatch",
            e.name
        );
        out.push(Input::F32(t.as_slice().to_vec(), dims));
    }
    Ok(out)
}
