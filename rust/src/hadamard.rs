//! Fast Walsh–Hadamard transform and the randomized Hadamard transform (RHT).
//!
//! PCDVQ's first stage (§3.2.1 *Standard Gaussian Regularization*) multiplies
//! each weight column by a randomized Hadamard matrix `S = H·diag(signs)/√p`,
//! which makes the column approximately `N(0, ‖x‖²/p)`; dividing by the
//! per-column scale `s = ‖x‖/√p` then yields ~`N(0,1)` entries. The same
//! transform (it is orthogonal, so its inverse is its transpose) is re-applied
//! at dequantization time; `O(p log p)` per column, exactly as the paper's
//! §A.4 limitation analysis assumes.
//!
//! The sign diagonal is regenerated from a stored 64-bit seed rather than
//! materialized, so the per-layer metadata is 2 u64 + one f32 per column.

use crate::rng::Rng;
use crate::tensor::Matrix;

/// In-place fast Walsh–Hadamard transform of a power-of-two-length slice,
/// using the *orthonormal* convention (`H/√n`), so `fwht(fwht(x)) == x`.
pub fn fwht_normalized(x: &mut [f32]) {
    fwht_raw(x);
    let scale = 1.0 / (x.len() as f32).sqrt();
    for v in x.iter_mut() {
        *v *= scale;
    }
}

/// In-place unnormalized FWHT (`H` with entries ±1). `fwht_raw(fwht_raw(x))
/// == n·x`.
pub fn fwht_raw(x: &mut [f32]) {
    let n = x.len();
    assert!(n.is_power_of_two(), "FWHT length must be a power of two, got {n}");
    let mut h = 1;
    while h < n {
        for block in (0..n).step_by(h * 2) {
            for i in block..block + h {
                let (a, b) = (x[i], x[i + h]);
                x[i] = a + b;
                x[i + h] = a - b;
            }
        }
        h *= 2;
    }
}

/// Randomized Hadamard transform acting column-wise on a weight matrix.
///
/// Stores only the sign seed; `forward` computes `H·diag(signs)·x/√p` per
/// column, `inverse` computes `diag(signs)·H·y/√p` (orthogonality).
#[derive(Clone, Debug)]
pub struct RandomizedHadamard {
    /// Number of rows the transform acts on (must be a power of two).
    pub dim: usize,
    /// Seed from which the Rademacher diagonal is regenerated.
    pub seed: u64,
    signs: Vec<f32>,
}

impl RandomizedHadamard {
    /// Create the transform for `dim` rows (power of two) from a seed.
    pub fn new(dim: usize, seed: u64) -> Self {
        assert!(dim.is_power_of_two(), "RHT dim must be a power of two, got {dim}");
        let signs = Rng::new(seed).signs(dim);
        RandomizedHadamard { dim, seed, signs }
    }

    /// The Rademacher diagonal.
    pub fn signs(&self) -> &[f32] {
        &self.signs
    }

    /// Apply `(H/√p)·diag(signs)` to a single column vector in place.
    pub fn forward_col(&self, x: &mut [f32]) {
        assert_eq!(x.len(), self.dim);
        for (v, s) in x.iter_mut().zip(&self.signs) {
            *v *= s;
        }
        fwht_normalized(x);
    }

    /// Inverse of [`Self::forward_col`]: `diag(signs)·(H/√p)`.
    pub fn inverse_col(&self, x: &mut [f32]) {
        assert_eq!(x.len(), self.dim);
        fwht_normalized(x);
        for (v, s) in x.iter_mut().zip(&self.signs) {
            *v *= s;
        }
    }

    /// Apply the forward transform to every column of `w` (rows = `dim`).
    pub fn forward(&self, w: &Matrix) -> Matrix {
        self.map_cols(w, |col| self.forward_col(col))
    }

    /// Apply the inverse transform to every column of `w`.
    pub fn inverse(&self, w: &Matrix) -> Matrix {
        self.map_cols(w, |col| self.inverse_col(col))
    }

    fn map_cols<F: Fn(&mut [f32])>(&self, w: &Matrix, f: F) -> Matrix {
        assert_eq!(
            w.rows(),
            self.dim,
            "RHT dim {} does not match matrix rows {}",
            self.dim,
            w.rows()
        );
        // Work in the transposed layout so each column is contiguous, then
        // transpose back. (Profiled faster than strided access at p>=128.)
        let mut t = w.transposed();
        for j in 0..t.rows() {
            f(t.row_mut(j));
        }
        t.transposed()
    }
}

/// Per-column standard-Gaussian regularization (paper §3.2.1).
///
/// Returns the transformed matrix whose entries are ~N(0,1) together with the
/// per-column scales `s_j = ‖x_j‖/√p` needed to undo it.
pub fn regularize(w: &Matrix, rht: &RandomizedHadamard) -> (Matrix, Vec<f32>) {
    let mut h = rht.forward(w);
    let p = w.rows() as f32;
    let mut scales = Vec::with_capacity(w.cols());
    for j in 0..w.cols() {
        let col = w.col(j);
        let norm: f32 = col.iter().map(|x| x * x).sum::<f32>().sqrt();
        let s = if norm > 0.0 { norm / p.sqrt() } else { 1.0 };
        scales.push(s);
        let inv = 1.0 / s;
        for i in 0..h.rows() {
            h.set(i, j, h.get(i, j) * inv);
        }
    }
    (h, scales)
}

/// Undo [`regularize`]: rescale columns then apply the inverse RHT.
pub fn deregularize(h: &Matrix, scales: &[f32], rht: &RandomizedHadamard) -> Matrix {
    assert_eq!(h.cols(), scales.len());
    let mut scaled = h.clone();
    for j in 0..h.cols() {
        let s = scales[j];
        for i in 0..h.rows() {
            scaled.set(i, j, scaled.get(i, j) * s);
        }
    }
    rht.inverse(&scaled)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn fwht_normalized_is_involution() {
        let mut rng = Rng::new(3);
        let orig = rng.normal_vec(64);
        let mut x = orig.clone();
        fwht_normalized(&mut x);
        fwht_normalized(&mut x);
        for (a, b) in x.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn fwht_matches_naive_h4() {
        // H_4 rows: ++++, +-+-, ++--, +--+
        let mut x = vec![1.0, 2.0, 3.0, 4.0];
        fwht_raw(&mut x);
        assert_eq!(x, vec![10.0, -2.0, -4.0, 0.0]);
    }

    #[test]
    fn fwht_preserves_norm() {
        let mut rng = Rng::new(5);
        let mut x = rng.normal_vec(128);
        let n0: f32 = x.iter().map(|v| v * v).sum();
        fwht_normalized(&mut x);
        let n1: f32 = x.iter().map(|v| v * v).sum();
        assert!((n0 - n1).abs() / n0 < 1e-4);
    }

    #[test]
    #[should_panic]
    fn fwht_rejects_non_power_of_two() {
        let mut x = vec![0.0; 12];
        fwht_raw(&mut x);
    }

    #[test]
    fn rht_forward_inverse_round_trip() {
        let mut rng = Rng::new(17);
        let w = Matrix::from_vec(rng.normal_vec(64 * 5), 64, 5);
        let rht = RandomizedHadamard::new(64, 99);
        let back = rht.inverse(&rht.forward(&w));
        assert!(back.mse(&w) < 1e-10);
    }

    #[test]
    fn rht_deterministic_from_seed() {
        let a = RandomizedHadamard::new(32, 7);
        let b = RandomizedHadamard::new(32, 7);
        assert_eq!(a.signs(), b.signs());
    }

    #[test]
    fn regularize_round_trip_and_gaussianization() {
        let mut rng = Rng::new(23);
        // heavy-tailed input: a few outliers
        let mut data = rng.normal_vec(256 * 8);
        data[3] = 40.0;
        data[700] = -25.0;
        let w = Matrix::from_vec(data, 256, 8);
        let rht = RandomizedHadamard::new(256, 1);
        let (h, scales) = regularize(&w, &rht);
        // round trip
        let back = deregularize(&h, &scales, &rht);
        assert!(back.mse(&w) < 1e-8, "mse={}", back.mse(&w));
        // each column should now have ~unit variance
        for j in 0..h.cols() {
            let col = h.col(j);
            let var: f32 = col.iter().map(|x| x * x).sum::<f32>() / col.len() as f32;
            assert!((var - 1.0).abs() < 0.05, "col {j} var {var}");
        }
        // outlier suppressed: max |entry| far below 40/s
        let maxabs = h.as_slice().iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        assert!(maxabs < 8.0, "maxabs={maxabs}");
    }
}
