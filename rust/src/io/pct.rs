//! PCT1 reader/writer (see module docs in `io`).

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

/// Payload of one entry.
#[derive(Clone, Debug, PartialEq)]
pub enum PctData {
    F32(Vec<f32>),
    U32(Vec<u32>),
    U64(Vec<u64>),
    I32(Vec<i32>),
}

impl PctData {
    fn dtype_tag(&self) -> u8 {
        match self {
            PctData::F32(_) => 0,
            PctData::U32(_) => 1,
            PctData::U64(_) => 2,
            PctData::I32(_) => 3,
        }
    }

    fn len(&self) -> usize {
        match self {
            PctData::F32(v) => v.len(),
            PctData::U32(v) => v.len(),
            PctData::U64(v) => v.len(),
            PctData::I32(v) => v.len(),
        }
    }
}

/// One named tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Entry {
    pub dims: Vec<u64>,
    pub data: PctData,
}

impl Entry {
    pub fn f32(dims: &[u64], data: Vec<f32>) -> Self {
        Entry { dims: dims.to_vec(), data: PctData::F32(data) }
    }

    pub fn u32(dims: &[u64], data: Vec<u32>) -> Self {
        Entry { dims: dims.to_vec(), data: PctData::U32(data) }
    }

    pub fn u64(dims: &[u64], data: Vec<u64>) -> Self {
        Entry { dims: dims.to_vec(), data: PctData::U64(data) }
    }

    /// Borrow as f32, failing on dtype mismatch.
    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            PctData::F32(v) => Ok(v),
            other => bail!("expected f32 entry, found tag {}", other.dtype_tag()),
        }
    }

    pub fn as_u32(&self) -> Result<&[u32]> {
        match &self.data {
            PctData::U32(v) => Ok(v),
            other => bail!("expected u32 entry, found tag {}", other.dtype_tag()),
        }
    }

    pub fn as_u64(&self) -> Result<&[u64]> {
        match &self.data {
            PctData::U64(v) => Ok(v),
            other => bail!("expected u64 entry, found tag {}", other.dtype_tag()),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            PctData::I32(v) => Ok(v),
            other => bail!("expected i32 entry, found tag {}", other.dtype_tag()),
        }
    }

    /// Scalar helpers for metadata entries.
    pub fn scalar_u64(&self) -> Result<u64> {
        let v = self.as_u64()?;
        if v.len() != 1 {
            bail!("expected scalar, got {} elements", v.len());
        }
        Ok(v[0])
    }

    pub fn scalar_f32(&self) -> Result<f32> {
        let v = self.as_f32()?;
        if v.len() != 1 {
            bail!("expected scalar, got {} elements", v.len());
        }
        Ok(v[0])
    }
}

/// An ordered map of named tensors — the in-memory form of a `.pct` file.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Pct {
    entries: BTreeMap<String, Entry>,
}

const MAGIC: &[u8; 4] = b"PCT1";

impl Pct {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, name: &str, entry: Entry) {
        let expected: u64 = entry.dims.iter().product();
        assert_eq!(
            expected as usize,
            entry.data.len(),
            "entry '{name}': dims {:?} disagree with data length {}",
            entry.dims,
            entry.data.len()
        );
        self.entries.insert(name.to_string(), entry);
    }

    pub fn get(&self, name: &str) -> Result<&Entry> {
        self.entries
            .get(name)
            .with_context(|| format!("missing entry '{name}'"))
    }

    pub fn contains(&self, name: &str) -> bool {
        self.entries.contains_key(name)
    }

    /// Remove an entry by name, returning it if present.
    pub fn remove(&mut self, name: &str) -> Option<Entry> {
        self.entries.remove(name)
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(|s| s.as_str())
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Serialize to bytes.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&(self.entries.len() as u32).to_le_bytes());
        for (name, e) in &self.entries {
            let nb = name.as_bytes();
            out.extend_from_slice(&(nb.len() as u16).to_le_bytes());
            out.extend_from_slice(nb);
            out.push(e.data.dtype_tag());
            out.push(e.dims.len() as u8);
            for &d in &e.dims {
                out.extend_from_slice(&d.to_le_bytes());
            }
            match &e.data {
                PctData::F32(v) => {
                    for x in v {
                        out.extend_from_slice(&x.to_le_bytes());
                    }
                }
                PctData::U32(v) => {
                    for x in v {
                        out.extend_from_slice(&x.to_le_bytes());
                    }
                }
                PctData::U64(v) => {
                    for x in v {
                        out.extend_from_slice(&x.to_le_bytes());
                    }
                }
                PctData::I32(v) => {
                    for x in v {
                        out.extend_from_slice(&x.to_le_bytes());
                    }
                }
            }
        }
        out
    }

    /// Parse from bytes.
    pub fn from_bytes(buf: &[u8]) -> Result<Self> {
        let mut r = Cursor { buf, pos: 0 };
        let magic = r.take(4)?;
        if magic != MAGIC {
            bail!("bad magic: not a PCT1 file");
        }
        let count = r.u32()?;
        let mut pct = Pct::new();
        for _ in 0..count {
            let name_len = r.u16()? as usize;
            let name = std::str::from_utf8(r.take(name_len)?)
                .context("entry name is not UTF-8")?
                .to_string();
            let dtype = r.u8()?;
            let ndim = r.u8()? as usize;
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                dims.push(r.u64()?);
            }
            // corrupt dims must fail the parse — never overflow into a
            // panic or wrap into a bogus short read
            let n: u64 = dims
                .iter()
                .try_fold(1u64, |acc, &d| acc.checked_mul(d))
                .with_context(|| format!("entry '{name}': element count overflows"))?;
            let nbytes = |width: u64| -> Result<usize> {
                n.checked_mul(width)
                    .and_then(|b| usize::try_from(b).ok())
                    .with_context(|| format!("entry '{name}': byte length overflows"))
            };
            let data = match dtype {
                0 => {
                    let raw = r.take(nbytes(4)?)?;
                    PctData::F32(
                        raw.chunks_exact(4)
                            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                            .collect(),
                    )
                }
                1 => {
                    let raw = r.take(nbytes(4)?)?;
                    PctData::U32(
                        raw.chunks_exact(4)
                            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                            .collect(),
                    )
                }
                2 => {
                    let raw = r.take(nbytes(8)?)?;
                    PctData::U64(
                        raw.chunks_exact(8)
                            .map(|c| {
                                u64::from_le_bytes([
                                    c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7],
                                ])
                            })
                            .collect(),
                    )
                }
                3 => {
                    let raw = r.take(nbytes(4)?)?;
                    PctData::I32(
                        raw.chunks_exact(4)
                            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                            .collect(),
                    )
                }
                t => bail!("unknown dtype tag {t}"),
            };
            pct.insert(&name, Entry { dims, data });
        }
        Ok(pct)
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let bytes = self.to_bytes();
        let mut f = std::fs::File::create(path.as_ref())
            .with_context(|| format!("creating {}", path.as_ref().display()))?;
        f.write_all(&bytes)?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let mut buf = Vec::new();
        std::fs::File::open(path.as_ref())
            .with_context(|| format!("opening {}", path.as_ref().display()))?
            .read_to_end(&mut buf)?;
        Self::from_bytes(&buf)
    }
}

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!("truncated PCT1 file at offset {}", self.pos);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_all_dtypes() {
        let mut p = Pct::new();
        p.insert("w", Entry::f32(&[2, 3], vec![1.0, -2.5, 3.0, 0.0, 1e-7, 9.9]));
        p.insert("idx", Entry::u32(&[4], vec![0, 1, u32::MAX, 7]));
        p.insert("seed", Entry::u64(&[1], vec![0xDEADBEEF]));
        p.insert(
            "neg",
            Entry { dims: vec![2], data: PctData::I32(vec![-5, 12]) },
        );
        let q = Pct::from_bytes(&p.to_bytes()).unwrap();
        assert_eq!(p, q);
    }

    #[test]
    fn missing_entry_errors() {
        let p = Pct::new();
        assert!(p.get("nope").is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Pct::from_bytes(b"NOTAPCT123").is_err());
        assert!(Pct::from_bytes(b"PC").is_err());
    }

    #[test]
    fn rejects_truncation() {
        let mut p = Pct::new();
        p.insert("w", Entry::f32(&[8], vec![0.5; 8]));
        let bytes = p.to_bytes();
        for cut in [bytes.len() - 1, bytes.len() / 2, 6] {
            assert!(Pct::from_bytes(&bytes[..cut]).is_err(), "cut={cut}");
        }
    }

    #[test]
    fn rejects_overflowing_dims_without_panicking() {
        // hand-built header whose dims product overflows u64 — a shape a
        // flipped byte in a real file can produce
        let mut b = Vec::new();
        b.extend_from_slice(b"PCT1");
        b.extend_from_slice(&1u32.to_le_bytes());
        b.extend_from_slice(&1u16.to_le_bytes());
        b.push(b'w');
        b.push(0); // f32
        b.push(2); // ndim
        b.extend_from_slice(&(1u64 << 62).to_le_bytes());
        b.extend_from_slice(&16u64.to_le_bytes());
        assert!(Pct::from_bytes(&b).is_err(), "overflowing dims must be a parse error");
    }

    #[test]
    fn file_round_trip() {
        let dir = std::env::temp_dir().join("pcdvq_pct_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.pct");
        let mut p = Pct::new();
        p.insert("x", Entry::f32(&[3], vec![1.0, 2.0, 3.0]));
        p.save(&path).unwrap();
        assert_eq!(Pct::load(&path).unwrap(), p);
    }

    #[test]
    #[should_panic]
    fn insert_dims_mismatch_panics() {
        let mut p = Pct::new();
        p.insert("bad", Entry::f32(&[2, 2], vec![1.0; 5]));
    }
}
