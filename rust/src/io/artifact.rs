//! Persistence of quantized models in their **packed** form.
//!
//! The whole point of the compressed-artifact refactor is that the thing we
//! store, ship and serve is the codes — so the on-disk format mirrors the
//! in-memory [`QuantizedWeight`] exactly (DESIGN.md §6):
//!
//! ```text
//! meta.*                       model config (same keys as the fp container)
//! fp.<name>                    unquantized tensors (embeddings, norms)
//! q.<name>.shape               u64 [rows, cols]
//! q.<name>.decoder             u32 [tag, param]   0=dacc 1=table(param=id) 2=scalar(param=bits)
//! q.<name>.method              u32 byte-string (method label)
//! q.<name>.scales              f32 [cols]         (present iff non-empty)
//! q.<name>.rht                 u64 [seed]         (present iff RHT domain)
//! q.<name>.nstreams            u64 [n]
//! q.<name>.stream<s>.meta      u64 [width, record count]
//! q.<name>.stream<s>.words     u64 raw packed words
//! codebook.dacc.dir.vectors    f32 [2^a, k]   \  written once; every DACC
//! codebook.dacc.dir.meta       u64 [bits, method_tag]  artifact references it
//! codebook.dacc.mag.levels     f32 [2^b]      /
//! codebook.dacc.mag.meta       u64 [bits, method_tag]
//! codebook.table<i>.data       f32 [n, k]     shared reconstruction tables
//! codebook.table<i>.label      u32 byte-string
//! ```
//!
//! Shared codebooks are deduplicated by `Arc` identity at save time and
//! re-shared on load (every weight referencing table `i` gets the same
//! `Arc`; all DACC weights share one decoder), so a load-then-serve cycle
//! keeps the same resident-memory profile as the original quantization run.
//!
//! Containers are **sealed** with integrity entries before writing
//! ([`crate::io::integrity::seal`]: format version, per-section CRC32s,
//! entry count) and verified immediately after parsing on load — corruption
//! fails with an error naming the damaged section (DESIGN.md §17) before
//! any per-weight validation runs.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::codebook::{DirectionCodebook, MagnitudeCodebook};
use crate::io::{Entry, Pct};
use crate::model::{GptConfig, QuantizedGpt};
use crate::quant::packing::{PackedIndices, PackedStreams};
use crate::quant::pcdvq::DaccDecoder;
use crate::quant::sq::ScalarDecoder;
use crate::quant::{CodeDecoder, DecoderPersist, QuantizedWeight, TableDecoder};
use crate::tensor::Matrix;

const TAG_DACC: u32 = 0;
const TAG_TABLE: u32 = 1;
const TAG_SCALAR: u32 = 2;

fn str_entry(s: &str) -> Entry {
    let bytes: Vec<u32> = s.bytes().map(|b| b as u32).collect();
    Entry::u32(&[bytes.len() as u64], bytes)
}

fn entry_str(e: &Entry) -> Result<String> {
    let bytes: Vec<u8> = e.as_u32()?.iter().map(|&b| b as u8).collect();
    String::from_utf8(bytes).context("invalid string entry")
}

/// Validated rank-2 dims of an untrusted entry (its data length must match
/// — `Matrix::from_vec` would otherwise panic on a corrupt container).
fn entry_dims_2d(e: &Entry, what: &str) -> Result<(usize, usize)> {
    anyhow::ensure!(e.dims.len() == 2, "{what}: expected rank 2, got {:?}", e.dims);
    let (n, k) = (e.dims[0] as usize, e.dims[1] as usize);
    anyhow::ensure!(n >= 1 && k >= 1, "{what}: bad dims {n}x{k}");
    anyhow::ensure!(
        e.as_f32().map(|d| d.len() == n * k).unwrap_or(false),
        "{what}: data length disagrees with dims {n}x{k}"
    );
    Ok((n, k))
}

/// Save a quantized model in the packed format.
pub fn save_quantized(q: &QuantizedGpt, path: impl AsRef<Path>) -> Result<()> {
    let mut pct = Pct::new();
    for (k, v) in [
        ("vocab", q.config.vocab),
        ("d_model", q.config.d_model),
        ("n_layer", q.config.n_layer),
        ("n_head", q.config.n_head),
        ("d_ff", q.config.d_ff),
        ("ctx", q.config.ctx),
    ] {
        pct.insert(&format!("meta.{k}"), Entry::u64(&[1], vec![v as u64]));
    }

    for (name, t) in &q.fp_tensors {
        let dims: Vec<u64> = q
            .fp_dims
            .get(name)
            .map(|d| d.iter().map(|&x| x as u64).collect())
            .unwrap_or_else(|| vec![t.rows() as u64, t.cols() as u64]);
        pct.insert(&format!("fp.{name}"), Entry::f32(&dims, t.as_slice().to_vec()));
    }

    // shared codebooks, deduplicated by Arc identity
    let mut dacc_saved: Option<(*const DirectionCodebook, *const MagnitudeCodebook)> = None;
    let mut tables: Vec<*const Matrix> = Vec::new();

    for (name, w) in &q.weights {
        pct.insert(
            &format!("q.{name}.shape"),
            Entry::u64(&[2], vec![w.rows() as u64, w.cols() as u64]),
        );
        pct.insert(&format!("q.{name}.method"), str_entry(&w.method));
        if !w.scales().is_empty() {
            pct.insert(
                &format!("q.{name}.scales"),
                Entry::f32(&[w.scales().len() as u64], w.scales().to_vec()),
            );
        }
        if let Some(seed) = w.rht_seed() {
            pct.insert(&format!("q.{name}.rht"), Entry::u64(&[1], vec![seed]));
        }
        let codes = w.codes();
        pct.insert(
            &format!("q.{name}.nstreams"),
            Entry::u64(&[1], vec![codes.n_streams() as u64]),
        );
        for (s, stream) in codes.streams().iter().enumerate() {
            pct.insert(
                &format!("q.{name}.stream{s}.meta"),
                Entry::u64(&[2], vec![stream.width as u64, stream.len as u64]),
            );
            pct.insert(
                &format!("q.{name}.stream{s}.words"),
                Entry::u64(&[stream.words().len() as u64], stream.words().to_vec()),
            );
        }
        let decoder_entry = match w.decoder().persist() {
            DecoderPersist::Dacc { dir, mag } => {
                let ids = (Arc::as_ptr(dir), Arc::as_ptr(mag));
                match dacc_saved {
                    None => {
                        pct.insert(
                            "codebook.dacc.dir.vectors",
                            Entry::f32(
                                &[dir.len() as u64, dir.dim() as u64],
                                dir.vectors.as_slice().to_vec(),
                            ),
                        );
                        pct.insert(
                            "codebook.dacc.dir.meta",
                            Entry::u64(
                                &[2],
                                vec![
                                    dir.bits as u64,
                                    crate::codebook::store::direction_method_tag(dir.method)
                                        as u64,
                                ],
                            ),
                        );
                        pct.insert(
                            "codebook.dacc.mag.levels",
                            Entry::f32(&[mag.len() as u64], mag.levels.clone()),
                        );
                        pct.insert(
                            "codebook.dacc.mag.meta",
                            Entry::u64(
                                &[2],
                                vec![
                                    mag.bits as u64,
                                    crate::codebook::store::magnitude_method_tag(mag.method)
                                        as u64,
                                ],
                            ),
                        );
                        dacc_saved = Some(ids);
                    }
                    Some(saved) if saved == ids => {}
                    Some(_) => bail!(
                        "packed container supports one DACC codebook pair; \
                         '{name}' references a second one"
                    ),
                }
                Entry::u32(&[2], vec![TAG_DACC, 0])
            }
            DecoderPersist::Table { table, label } => {
                let ptr = Arc::as_ptr(table);
                let id = match tables.iter().position(|&p| p == ptr) {
                    Some(i) => i,
                    None => {
                        let i = tables.len();
                        pct.insert(
                            &format!("codebook.table{i}.data"),
                            Entry::f32(
                                &[table.rows() as u64, table.cols() as u64],
                                table.as_slice().to_vec(),
                            ),
                        );
                        pct.insert(&format!("codebook.table{i}.label"), str_entry(label));
                        tables.push(ptr);
                        i
                    }
                };
                Entry::u32(&[2], vec![TAG_TABLE, id as u32])
            }
            DecoderPersist::Scalar { bits } => Entry::u32(&[2], vec![TAG_SCALAR, bits]),
        };
        pct.insert(&format!("q.{name}.decoder"), decoder_entry);
    }
    crate::io::integrity::seal(&mut pct);
    pct.save(path)
}

/// Load a quantized model saved by [`save_quantized`]. Shared codebooks are
/// re-shared: all DACC artifacts reference one decoder, all artifacts of
/// table `i` reference one table.
pub fn load_quantized(path: impl AsRef<Path>, name: impl Into<String>) -> Result<QuantizedGpt> {
    let pct = Pct::load(path)?;
    // integrity first (DESIGN.md §17): a damaged container is rejected
    // naming its corrupted section before any per-weight validation runs
    crate::io::integrity::verify(&pct)?;
    let meta = |key: &str| -> Result<usize> {
        Ok(pct.get(&format!("meta.{key}"))?.scalar_u64()? as usize)
    };
    let config = GptConfig {
        vocab: meta("vocab")?,
        d_model: meta("d_model")?,
        n_layer: meta("n_layer")?,
        n_head: meta("n_head")?,
        d_ff: meta("d_ff")?,
        ctx: meta("ctx")?,
    };

    let mut fp_tensors = BTreeMap::new();
    let mut fp_dims = BTreeMap::new();
    let mut qnames = std::collections::BTreeSet::new();
    for full in pct.names() {
        if let Some(name) = full.strip_prefix("fp.") {
            let e = pct.get(full)?;
            let dims: Vec<usize> = e.dims.iter().map(|&d| d as usize).collect();
            let (rows, cols) = match dims.len() {
                1 => (dims[0], 1),
                2 => (dims[0], dims[1]),
                n => bail!("fp tensor '{name}' has unsupported rank {n}"),
            };
            fp_dims.insert(name.to_string(), dims);
            fp_tensors.insert(
                name.to_string(),
                Matrix::from_vec(e.as_f32()?.to_vec(), rows, cols),
            );
        } else if let Some(rest) = full.strip_prefix("q.") {
            if let Some(name) = rest.strip_suffix(".shape") {
                qnames.insert(name.to_string());
            }
        }
    }

    // lazily-shared decoders (one per distinct codebook, like at save time)
    let mut dacc: Option<Arc<DaccDecoder>> = None;
    let mut tables: BTreeMap<u32, Arc<TableDecoder>> = BTreeMap::new();
    let mut scalars: BTreeMap<u32, Arc<ScalarDecoder>> = BTreeMap::new();

    let mut weights = BTreeMap::new();
    for name in qnames {
        let shape = pct.get(&format!("q.{name}.shape"))?.as_u64()?.to_vec();
        anyhow::ensure!(shape.len() == 2, "bad shape entry for '{name}'");
        let (rows, cols) = (shape[0] as usize, shape[1] as usize);
        anyhow::ensure!(
            rows >= 1 && cols >= 1 && rows.checked_mul(cols).is_some(),
            "'{name}': bad shape {rows}x{cols}"
        );
        let method = entry_str(pct.get(&format!("q.{name}.method"))?)?;
        let scales = match pct.get(&format!("q.{name}.scales")) {
            Ok(e) => e.as_f32()?.to_vec(),
            Err(_) => Vec::new(),
        };
        let rht_seed = match pct.get(&format!("q.{name}.rht")) {
            Ok(e) => Some(e.scalar_u64()?),
            Err(_) => None,
        };
        let n_streams = pct.get(&format!("q.{name}.nstreams"))?.scalar_u64()?;
        // cap before allocating: a corrupt count must be Err, not an abort
        anyhow::ensure!(
            (1..=8).contains(&n_streams),
            "'{name}': implausible stream count {n_streams}"
        );
        let n_streams = n_streams as usize;
        let mut streams = Vec::with_capacity(n_streams);
        for s in 0..n_streams {
            let m = pct.get(&format!("q.{name}.stream{s}.meta"))?.as_u64()?.to_vec();
            anyhow::ensure!(m.len() == 2, "bad stream meta for '{name}'");
            let (width, len) = (m[0], m[1]);
            anyhow::ensure!(
                (1..=63).contains(&width),
                "'{name}' stream {s}: bad record width {width}"
            );
            anyhow::ensure!(
                len <= rows as u64 * cols as u64,
                "'{name}' stream {s}: record count {len} exceeds {rows}x{cols}"
            );
            let words = pct
                .get(&format!("q.{name}.stream{s}.words"))?
                .as_u64()?
                .to_vec();
            anyhow::ensure!(
                words.len() as u64 * 64 >= len * width,
                "'{name}' stream {s}: word array truncated"
            );
            streams.push(PackedIndices::from_words(words, width as u32, len as usize));
        }
        let dec = pct.get(&format!("q.{name}.decoder"))?.as_u32()?.to_vec();
        anyhow::ensure!(dec.len() == 2, "bad decoder entry for '{name}'");
        // record-range capacity per stream, checked below — this is a trust
        // boundary (the container may be truncated/corrupt), so malformed
        // data must come back as Err, not as a panic here or a
        // gather-out-of-bounds later in serving
        let stream_caps: Vec<u64>;
        let decoder: Arc<dyn CodeDecoder> = match dec[0] {
            TAG_DACC => {
                anyhow::ensure!(n_streams == 2, "'{name}': DACC needs 2 streams");
                if dacc.is_none() {
                    let dv = pct.get("codebook.dacc.dir.vectors")?;
                    let (n, k) = entry_dims_2d(dv, "codebook.dacc.dir.vectors")?;
                    let dm = pct.get("codebook.dacc.dir.meta")?.as_u64()?.to_vec();
                    anyhow::ensure!(dm.len() == 2, "bad dacc dir meta");
                    let dir = DirectionCodebook {
                        vectors: Matrix::from_vec(dv.as_f32()?.to_vec(), n, k),
                        bits: dm[0] as u32,
                        method: crate::codebook::store::parse_direction_tag(dm[1] as u32),
                    };
                    let mm = pct.get("codebook.dacc.mag.meta")?.as_u64()?.to_vec();
                    anyhow::ensure!(mm.len() == 2, "bad dacc mag meta");
                    let mag = MagnitudeCodebook {
                        levels: pct.get("codebook.dacc.mag.levels")?.as_f32()?.to_vec(),
                        bits: mm[0] as u32,
                        method: crate::codebook::store::parse_magnitude_tag(mm[1] as u32),
                    };
                    anyhow::ensure!(!mag.levels.is_empty(), "empty dacc magnitude levels");
                    dacc = Some(Arc::new(DaccDecoder::new(Arc::new(dir), Arc::new(mag))));
                }
                let d = dacc.clone().unwrap();
                stream_caps = vec![d.dir.len() as u64, d.mag.len() as u64];
                d
            }
            TAG_TABLE => {
                let id = dec[1];
                let d = match tables.get(&id) {
                    Some(d) => Arc::clone(d),
                    None => {
                        let e = pct.get(&format!("codebook.table{id}.data"))?;
                        let (n, k) = entry_dims_2d(e, "table codebook")?;
                        let table = Arc::new(Matrix::from_vec(e.as_f32()?.to_vec(), n, k));
                        let label =
                            entry_str(pct.get(&format!("codebook.table{id}.label"))?)?;
                        let d = Arc::new(TableDecoder::new(table, label));
                        tables.insert(id, Arc::clone(&d));
                        d
                    }
                };
                stream_caps = vec![d.table().rows() as u64];
                d
            }
            TAG_SCALAR => {
                let bits = dec[1];
                anyhow::ensure!((1..32).contains(&bits), "'{name}': bad scalar bits {bits}");
                stream_caps = vec![1u64 << bits];
                match scalars.get(&bits) {
                    Some(d) => Arc::clone(d) as Arc<dyn CodeDecoder>,
                    None => {
                        let d = Arc::new(ScalarDecoder::new(bits));
                        scalars.insert(bits, Arc::clone(&d));
                        d
                    }
                }
            }
            t => bail!("unknown decoder tag {t} for '{name}'"),
        };
        // shape + record-range validation (errors, not panics/late OOB)
        anyhow::ensure!(n_streams == stream_caps.len(), "'{name}': stream count mismatch");
        let n_vec = streams[0].len;
        anyhow::ensure!(
            streams.iter().all(|s| s.len == n_vec),
            "'{name}': stream record counts disagree"
        );
        anyhow::ensure!(
            n_vec * decoder.k() == rows * cols,
            "'{name}': {n_vec} records x k={} disagree with shape {rows}x{cols}",
            decoder.k()
        );
        anyhow::ensure!(
            scales.is_empty() || scales.len() == cols,
            "'{name}': scales length {} != cols {cols}",
            scales.len()
        );
        anyhow::ensure!(
            rht_seed.is_none() || rows.is_power_of_two(),
            "'{name}': RHT artifact with non-power-of-two rows {rows}"
        );
        for (s, (stream, &cap)) in streams.iter().zip(&stream_caps).enumerate() {
            for i in 0..stream.len {
                let rec = stream.get(i);
                anyhow::ensure!(
                    rec < cap,
                    "'{name}' stream {s} record {i} = {rec} out of codebook range {cap}"
                );
            }
        }
        weights.insert(
            name.clone(),
            QuantizedWeight::new(
                method,
                rows,
                cols,
                PackedStreams::new(streams),
                decoder,
                scales,
                rht_seed,
            ),
        );
    }

    Ok(QuantizedGpt {
        config,
        name: name.into(),
        weights,
        fp_tensors,
        fp_dims,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codebook::{DirectionMethod, MagnitudeMethod};
    use crate::model::GptModel;
    use crate::quant::pcdvq::{Pcdvq, PcdvqConfig};
    use crate::quant::sq::Rtn;
    use crate::quant::vq_kmeans::KMeansVq;

    fn tmp_model(name: &str) -> GptModel {
        let dir = std::env::temp_dir().join("pcdvq_artifact_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("{name}.pct"));
        crate::model::gpt::tests::synthetic_model_file(&path, 64, 2);
        GptModel::load(&path).unwrap()
    }

    fn tmp_path(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("pcdvq_artifact_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn pcdvq(a: u32, b: u32) -> Pcdvq {
        let dir = Arc::new(DirectionCodebook::build(DirectionMethod::GreedyE8, a, 8, 0));
        let mag = Arc::new(MagnitudeCodebook::build(
            MagnitudeMethod::LloydMax,
            b,
            8,
            1.0 - 1e-4,
            0,
        ));
        Pcdvq::new(PcdvqConfig { dir_bits: a, mag_bits: b, k: 8, seed: 7 }, dir, mag)
    }

    fn assert_models_equal(a: &QuantizedGpt, b: &QuantizedGpt) {
        assert_eq!(a.config, b.config);
        assert_eq!(a.payload_bits(), b.payload_bits());
        assert_eq!(a.codebook_bits(), b.codebook_bits());
        assert_eq!(
            a.weights.keys().collect::<Vec<_>>(),
            b.weights.keys().collect::<Vec<_>>()
        );
        for (name, wa) in &a.weights {
            let wb = &b.weights[name];
            assert_eq!(wa.codes(), wb.codes(), "{name} codes");
            assert_eq!(wa.scales(), wb.scales(), "{name} scales");
            assert_eq!(wa.rht_seed(), wb.rht_seed(), "{name} seed");
            // bit-identical reconstruction through the loaded codebooks
            assert_eq!(
                wa.dequantize().as_slice(),
                wb.dequantize().as_slice(),
                "{name} dequant"
            );
        }
        for (name, ta) in &a.fp_tensors {
            assert_eq!(ta.as_slice(), b.fp_tensors[name].as_slice(), "fp {name}");
        }
    }

    #[test]
    fn pcdvq_round_trip_bit_exact() {
        let m = tmp_model("rt_pcdvq");
        let q = QuantizedGpt::quantize(&m, &pcdvq(8, 2));
        let path = tmp_path("pcdvq_model.pctq");
        save_quantized(&q, &path).unwrap();
        let loaded = load_quantized(&path, q.name.clone()).unwrap();
        assert_models_equal(&q, &loaded);
        // the on-disk artifact is genuinely small: payload + codebooks +
        // fp tensors + bookkeeping, nowhere near the dense fp32 model
        let file_bits = std::fs::metadata(&path).unwrap().len() * 8;
        assert!(
            file_bits < q.dense_bits() / 2,
            "packed container {file_bits} bits vs dense {}",
            q.dense_bits()
        );
    }

    #[test]
    fn scalar_round_trip_bit_exact() {
        let m = tmp_model("rt_rtn");
        let q = QuantizedGpt::quantize(&m, &Rtn::with_clip_search(3));
        let path = tmp_path("rtn_model.pctq");
        save_quantized(&q, &path).unwrap();
        let loaded = load_quantized(&path, "rt_rtn").unwrap();
        assert_models_equal(&q, &loaded);
    }

    #[test]
    fn load_rejects_corrupt_containers_with_errors_not_panics() {
        let m = tmp_model("rt_corrupt");
        let q = QuantizedGpt::quantize(&m, &Rtn::new(2));
        let path = tmp_path("corrupt_base.pctq");
        save_quantized(&q, &path).unwrap();
        let name = q.weights.keys().next().unwrap().clone();

        // Each mutation is RE-SEALED before saving: the container passes
        // the integrity check with internally-consistent checksums, so the
        // deep per-weight validation below it stays genuinely exercised
        // (unsealed tampering is covered by tests/io_cross_language.rs and
        // the integrity module's own suite).

        // 1. truncated word array (width claims more bits than stored)
        let mut pct = Pct::load(&path).unwrap();
        let meta = pct
            .get(&format!("q.{name}.stream0.meta"))
            .unwrap()
            .as_u64()
            .unwrap()
            .to_vec();
        pct.insert(
            &format!("q.{name}.stream0.meta"),
            Entry::u64(&[2], vec![31, meta[1]]),
        );
        crate::io::integrity::seal(&mut pct);
        let p = tmp_path("corrupt_trunc.pctq");
        pct.save(&p).unwrap();
        assert!(load_quantized(&p, "x").is_err(), "truncated stream must be Err");

        // 2. records out of the decoder's codebook range (2-bit codes
        //    reinterpreted against a 1-bit grid)
        let mut pct = Pct::load(&path).unwrap();
        pct.insert(&format!("q.{name}.decoder"), Entry::u32(&[2], vec![2, 1]));
        crate::io::integrity::seal(&mut pct);
        let p = tmp_path("corrupt_range.pctq");
        pct.save(&p).unwrap();
        assert!(load_quantized(&p, "x").is_err(), "out-of-range records must be Err");

        // 3. shape that disagrees with the record count
        let mut pct = Pct::load(&path).unwrap();
        let shape = pct.get(&format!("q.{name}.shape")).unwrap().as_u64().unwrap().to_vec();
        pct.insert(
            &format!("q.{name}.shape"),
            Entry::u64(&[2], vec![shape[0], shape[1] * 2]),
        );
        crate::io::integrity::seal(&mut pct);
        let p = tmp_path("corrupt_shape.pctq");
        pct.save(&p).unwrap();
        assert!(load_quantized(&p, "x").is_err(), "bad shape must be Err");

        // 4. stale checksum (tamper WITHOUT re-sealing): rejected by the
        //    integrity layer, naming the damaged section
        let mut pct = Pct::load(&path).unwrap();
        pct.insert(&format!("q.{name}.decoder"), Entry::u32(&[2], vec![2, 1]));
        let p = tmp_path("corrupt_unsealed.pctq");
        pct.save(&p).unwrap();
        let err = load_quantized(&p, "x").unwrap_err().to_string();
        assert!(err.contains("section 'layout'"), "integrity should name the section: {err}");
    }

    #[test]
    fn table_round_trip_shares_one_table() {
        let m = tmp_model("rt_km");
        let mut km = KMeansVq::new(8, 6);
        km.fit(&m.quantizable_vectors(8));
        let q = QuantizedGpt::quantize(&m, &km);
        let path = tmp_path("km_model.pctq");
        save_quantized(&q, &path).unwrap();
        let loaded = load_quantized(&path, "rt_km").unwrap();
        assert_models_equal(&q, &loaded);
        // all layers reference the same loaded table Arc (counted once)
        let specs: std::collections::BTreeSet<String> = loaded
            .weights
            .values()
            .map(|w| w.decoder().spec())
            .collect();
        assert_eq!(specs.len(), 1);
        assert_eq!(loaded.codebook_bits(), (1 << 6) * 8 * 32);
    }
}
