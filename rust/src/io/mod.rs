//! PCT1 — the repository's named-tensor container format.
//!
//! `serde` is not in the offline crate set, so artifacts that cross the
//! python↔rust boundary (trained weights, corpora, codebooks, quantized
//! models) use a deliberately boring little-endian binary format both sides
//! implement in ~100 lines:
//!
//! ```text
//! magic  "PCT1"                      4 bytes
//! u32    entry count
//! per entry:
//!   u16  name length, then UTF-8 name bytes
//!   u8   dtype   (0 = f32, 1 = u32, 2 = u64, 3 = i32)
//!   u8   ndim
//!   u64  dims[ndim]
//!   raw  data (little-endian, row-major)
//! ```
//!
//! The python writer lives in `python/compile/pct.py`; the round-trip is
//! integration-tested from both sides.
//!
//! Quantized artifacts additionally carry **integrity entries**
//! ([`integrity`], DESIGN.md §17): a format version, per-section CRC32
//! checksums, and a total entry count, written by
//! [`artifact::save_quantized`] and verified by
//! [`artifact::load_quantized`] — a flipped byte fails the load with an
//! error naming the damaged section instead of serving wrong logits.
//! Plain tensor containers (and python-written files) carry no integrity
//! entries and verify trivially.

pub mod artifact;
pub mod integrity;
mod pct;

pub use artifact::{load_quantized, save_quantized};
pub use pct::{Entry, Pct, PctData};
