//! PCT1 — the repository's named-tensor container format.
//!
//! `serde` is not in the offline crate set, so artifacts that cross the
//! python↔rust boundary (trained weights, corpora, codebooks, quantized
//! models) use a deliberately boring little-endian binary format both sides
//! implement in ~100 lines:
//!
//! ```text
//! magic  "PCT1"                      4 bytes
//! u32    entry count
//! per entry:
//!   u16  name length, then UTF-8 name bytes
//!   u8   dtype   (0 = f32, 1 = u32, 2 = u64, 3 = i32)
//!   u8   ndim
//!   u64  dims[ndim]
//!   raw  data (little-endian, row-major)
//! ```
//!
//! The python writer lives in `python/compile/pct.py`; the round-trip is
//! integration-tested from both sides.

pub mod artifact;
mod pct;

pub use artifact::{load_quantized, save_quantized};
pub use pct::{Entry, Pct, PctData};
