//! Artifact integrity: versioned self-checksums inside the `.pct` container.
//!
//! A quantized artifact is the thing we ship and serve — a flipped bit in a
//! packed stream or a codebook must fail the *load* with a structured error
//! naming the damaged section, never surface later as silently-wrong logits
//! (DESIGN.md §17). [`seal`] adds three kinds of reserved entries before the
//! container is written:
//!
//! ```text
//! integrity.version          u64 [1]     format version (currently 1)
//! integrity.entries          u64 [1]     total entry count, this one included
//! integrity.<section>.crc32  u32 [1]     CRC32 over the section's entries
//! ```
//!
//! Sections partition every non-reserved entry by name ([`section_of`]):
//! `meta` (model config), `fp` (unquantized tensors), `codebooks` (shared
//! codebooks), `scales` (per-column scales), `streams` (packed code words),
//! and `layout` (shapes, decoder tags, stream counts — everything else).
//! Each CRC runs over the section's entries in container (BTreeMap) order,
//! feeding per entry: name bytes, a `0` separator, the dtype tag, the rank,
//! and the dims + payload as little-endian bytes — the same information the
//! wire format serializes, so any byte flip that survives parsing lands in
//! exactly one section's checksum. The `integrity.entries` count guards the
//! remaining gap: the container's entry *count* field, whose corruption
//! would otherwise silently drop trailing entries (the parser ignores
//! trailing bytes).
//!
//! [`verify`] recomputes everything on load. Containers without
//! `integrity.version` (pre-integrity artifacts, plain tensor files) verify
//! trivially — the checks are opt-in at save time.

use anyhow::{bail, Result};

use super::pct::{Entry, Pct, PctData};

/// Integrity format version written by [`seal`] / required by [`verify`].
pub const INTEGRITY_VERSION: u64 = 1;

/// Reserved-entry prefix; [`section_of`] excludes these from every section.
const PREFIX: &str = "integrity.";

/// The fixed section vocabulary, in the order CRC entries are emitted.
const SECTIONS: [&str; 6] = ["codebooks", "fp", "layout", "meta", "scales", "streams"];

/// CRC32 (IEEE 802.3, reflected, polynomial `0xEDB8_8320`) — the same
/// checksum gzip/zip/PNG use, implemented here because the offline crate
/// set has no checksum dependency.
pub fn crc32(data: &[u8]) -> u32 {
    const TABLE: [u32; 256] = {
        let mut table = [0u32; 256];
        let mut i = 0;
        while i < 256 {
            let mut c = i as u32;
            let mut k = 0;
            while k < 8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
                k += 1;
            }
            table[i] = c;
            i += 1;
        }
        table
    };
    let mut state = !0u32;
    for &b in data {
        state = TABLE[((state ^ b as u32) & 0xFF) as usize] ^ (state >> 8);
    }
    !state
}

/// Which integrity section a container entry belongs to; `None` for the
/// reserved `integrity.*` entries themselves.
pub fn section_of(name: &str) -> Option<&'static str> {
    if name.starts_with(PREFIX) {
        None
    } else if name.starts_with("meta.") {
        Some("meta")
    } else if name.starts_with("fp.") {
        Some("fp")
    } else if name.starts_with("codebook.") {
        Some("codebooks")
    } else if name.ends_with(".scales") {
        Some("scales")
    } else if name.contains(".stream") {
        Some("streams")
    } else {
        Some("layout")
    }
}

/// Feed one entry into a section's running byte stream: name, separator,
/// dtype tag, rank, dims, payload — all little-endian, mirroring what the
/// wire format serializes for the entry.
fn feed_entry(buf: &mut Vec<u8>, name: &str, e: &Entry) {
    buf.extend_from_slice(name.as_bytes());
    buf.push(0);
    let tag: u8 = match &e.data {
        PctData::F32(_) => 0,
        PctData::U32(_) => 1,
        PctData::U64(_) => 2,
        PctData::I32(_) => 3,
    };
    buf.push(tag);
    buf.push(e.dims.len() as u8);
    for &d in &e.dims {
        buf.extend_from_slice(&d.to_le_bytes());
    }
    match &e.data {
        PctData::F32(v) => v.iter().for_each(|x| buf.extend_from_slice(&x.to_le_bytes())),
        PctData::U32(v) => v.iter().for_each(|x| buf.extend_from_slice(&x.to_le_bytes())),
        PctData::U64(v) => v.iter().for_each(|x| buf.extend_from_slice(&x.to_le_bytes())),
        PctData::I32(v) => v.iter().for_each(|x| buf.extend_from_slice(&x.to_le_bytes())),
    }
}

/// Recompute every section checksum over the container's current entries
/// (container order, reserved entries excluded). Sections with no entries
/// are omitted.
fn section_crcs(pct: &Pct) -> Vec<(&'static str, u32)> {
    let mut bufs: Vec<(&'static str, Vec<u8>)> =
        SECTIONS.iter().map(|&s| (s, Vec::new())).collect();
    for name in pct.names() {
        let Some(section) = section_of(name) else { continue };
        let e = pct.get(name).expect("iterating existing names");
        let buf = &mut bufs
            .iter_mut()
            .find(|(s, _)| *s == section)
            .expect("section vocabulary is fixed")
            .1;
        feed_entry(buf, name, e);
    }
    bufs.into_iter()
        .filter(|(_, b)| !b.is_empty())
        .map(|(s, b)| (s, crc32(&b)))
        .collect()
}

/// Add the integrity entries to a container about to be written: format
/// version, per-section CRC32s, and the total entry count (itself
/// included). Idempotent — re-sealing recomputes everything.
pub fn seal(pct: &mut Pct) {
    // re-seal cleanly: stale reserved entries must not feed the new count
    let stale: Vec<String> =
        pct.names().filter(|n| n.starts_with(PREFIX)).map(String::from).collect();
    for name in &stale {
        pct.remove(name);
    }
    pct.insert("integrity.version", Entry::u64(&[1], vec![INTEGRITY_VERSION]));
    for (section, crc) in section_crcs(pct) {
        pct.insert(&format!("{PREFIX}{section}.crc32"), Entry::u32(&[1], vec![crc]));
    }
    let total = pct.len() as u64 + 1; // the count entry itself
    pct.insert("integrity.entries", Entry::u64(&[1], vec![total]));
}

/// Verify a loaded container against its integrity entries. Containers
/// without `integrity.version` pass trivially (pre-integrity artifacts);
/// sealed containers fail with an error naming the damaged section on any
/// CRC mismatch, a missing/extra entry, or an unsupported version.
pub fn verify(pct: &Pct) -> Result<()> {
    let version = match pct.get("integrity.version") {
        Ok(e) => e.scalar_u64()?,
        Err(_) => return Ok(()), // unsealed container: nothing to check
    };
    if version != INTEGRITY_VERSION {
        bail!(
            "artifact integrity check failed: unsupported integrity format \
             version {version} (this build reads version {INTEGRITY_VERSION})"
        );
    }
    let expected = pct.get("integrity.entries")?.scalar_u64()?;
    if expected != pct.len() as u64 {
        bail!(
            "artifact integrity check failed: section 'integrity' is corrupted \
             (container holds {} entries, seal recorded {expected} — \
             truncated or damaged entry table)",
            pct.len()
        );
    }
    for (section, computed) in section_crcs(pct) {
        let key = format!("{PREFIX}{section}.crc32");
        let stored = match pct.get(&key) {
            Ok(e) => {
                let v = e.as_u32()?;
                anyhow::ensure!(v.len() == 1, "artifact integrity check failed: bad '{key}'");
                v[0]
            }
            Err(_) => bail!(
                "artifact integrity check failed: section '{section}' has no \
                 stored checksum (damaged entry table)"
            ),
        };
        if stored != computed {
            bail!(
                "artifact integrity check failed: section '{section}' is \
                 corrupted (stored CRC32 {stored:08x}, computed {computed:08x})"
            );
        }
    }
    // a CRC entry whose own section vanished means entries were dropped in
    // a way the count above could miss only by collision — cheap to pin
    let live: Vec<&'static str> = section_crcs(pct).iter().map(|(s, _)| *s).collect();
    for name in pct.names() {
        if let Some(rest) = name.strip_prefix(PREFIX) {
            if let Some(section) = rest.strip_suffix(".crc32") {
                anyhow::ensure!(
                    live.iter().any(|s| *s == section),
                    "artifact integrity check failed: section '{section}' is \
                     corrupted (checksum present but section empty)"
                );
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_matches_the_ieee_check_value() {
        // the standard CRC-32/IEEE test vector
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_ne!(crc32(b"a"), crc32(b"b"));
    }

    #[test]
    fn sections_partition_the_artifact_namespace() {
        assert_eq!(section_of("meta.vocab"), Some("meta"));
        assert_eq!(section_of("fp.tok_emb"), Some("fp"));
        assert_eq!(section_of("codebook.dacc.dir.vectors"), Some("codebooks"));
        assert_eq!(section_of("q.layer0.attn_q.scales"), Some("scales"));
        assert_eq!(section_of("q.layer0.attn_q.stream0.words"), Some("streams"));
        assert_eq!(section_of("q.layer0.attn_q.stream1.meta"), Some("streams"));
        assert_eq!(section_of("q.layer0.attn_q.shape"), Some("layout"));
        assert_eq!(section_of("q.layer0.attn_q.decoder"), Some("layout"));
        assert_eq!(section_of("integrity.version"), None);
        assert_eq!(section_of("integrity.streams.crc32"), None);
    }

    fn sample() -> Pct {
        let mut pct = Pct::new();
        pct.insert("meta.vocab", Entry::u64(&[1], vec![256]));
        pct.insert("fp.emb", Entry::f32(&[2, 2], vec![0.0, 1.0, 2.0, 3.0]));
        pct.insert("q.w.shape", Entry::u64(&[2], vec![4, 4]));
        pct.insert("q.w.scales", Entry::f32(&[2], vec![0.5, 0.25]));
        pct.insert("q.w.stream0.words", Entry::u64(&[1], vec![0xDEAD_BEEF]));
        pct.insert("codebook.table0.data", Entry::f32(&[1, 2], vec![1.0, -1.0]));
        pct
    }

    #[test]
    fn seal_then_verify_round_trips_and_is_idempotent() {
        let mut pct = sample();
        seal(&mut pct);
        verify(&pct).unwrap();
        assert_eq!(
            pct.get("integrity.entries").unwrap().scalar_u64().unwrap(),
            pct.len() as u64
        );
        let once = pct.clone();
        seal(&mut pct); // re-seal: same entries, same checksums
        assert_eq!(once, pct);
        // bytes round-trip through the wire format too
        let loaded = Pct::from_bytes(&pct.to_bytes()).unwrap();
        verify(&loaded).unwrap();
    }

    #[test]
    fn unsealed_containers_verify_trivially() {
        verify(&sample()).unwrap();
        verify(&Pct::new()).unwrap();
    }

    #[test]
    fn tampering_names_the_damaged_section() {
        for (name, entry, want) in [
            ("fp.emb", Entry::f32(&[2, 2], vec![0.0, 1.0, 2.0, 3.5]), "'fp'"),
            ("q.w.shape", Entry::u64(&[2], vec![4, 8]), "'layout'"),
            ("q.w.scales", Entry::f32(&[2], vec![0.5, 0.125]), "'scales'"),
            ("q.w.stream0.words", Entry::u64(&[1], vec![0xDEAD_BEE0]), "'streams'"),
            ("codebook.table0.data", Entry::f32(&[1, 2], vec![1.0, -2.0]), "'codebooks'"),
            ("meta.vocab", Entry::u64(&[1], vec![512]), "'meta'"),
        ] {
            let mut pct = sample();
            seal(&mut pct);
            pct.insert(name, entry);
            let err = verify(&pct).unwrap_err().to_string();
            assert!(err.contains(want), "tampering {name}: {err}");
            assert!(err.contains("corrupted"), "tampering {name}: {err}");
        }
    }

    #[test]
    fn dropped_and_extra_entries_fail_the_count_check() {
        let mut pct = sample();
        seal(&mut pct);
        let mut dropped = pct.clone();
        dropped.remove("q.w.scales");
        let err = verify(&dropped).unwrap_err().to_string();
        assert!(err.contains("'integrity'"), "{err}");

        let mut extra = pct.clone();
        extra.insert("q.w.smuggled", Entry::u64(&[1], vec![7]));
        assert!(verify(&extra).is_err());
    }

    #[test]
    fn unsupported_versions_are_rejected() {
        let mut pct = sample();
        seal(&mut pct);
        pct.insert("integrity.version", Entry::u64(&[1], vec![99]));
        let err = verify(&pct).unwrap_err().to_string();
        assert!(err.contains("version 99"), "{err}");
    }
}
