//! §4.4 — efficiency analysis: memory footprint + serving throughput.
//!
//! Paper: PCDVQ-2bit cuts ~87.5% of weight memory, and tokens/s on an
//! RTX-4090 rises 33.1 → 95.7 because decoding is HBM-bandwidth-bound and
//! 2-bit weights shrink the traffic.
//!
//! On this CPU testbed the memory claim reproduces directly — and, since
//! the compressed-artifact refactor, it is *checked*, not just printed:
//! [`verify_codes_resident`] walks every layer of the quantized model,
//! confirms the serving path holds only packed codes + shared codebooks
//! (resident bytes ≈ payload bits / 8 per layer, ≤ 8 bytes of word-packing
//! slack per stream), asserts the fused [`matmul_from_codes`] kernel agrees
//! with explicit dequantize + dense matmul within 1e-5 and is bit-identical
//! to the scalar reference kernel, and checks the blocked kernel's decode
//! LUT stays *derived* state (rebuildable, zero artifact bits — never
//! double-counted against the codebooks it expands).
//!
//! The throughput claim does *not* transfer mechanically: CPU decode is
//! compute-bound, so the in-graph (or in-kernel) dequant costs more than
//! the saved DRAM traffic. We report both honestly — the resident-bytes
//! ratio is the mechanism the paper's GPU speedup rides on.
//!
//! [`matmul_from_codes`]: crate::quant::QuantizedWeight::matmul_from_codes

use std::sync::mpsc::channel;

use anyhow::Result;

use super::Ctx;
use crate::codebook::{DirectionMethod, MagnitudeMethod};
use crate::config::build_pcdvq_with;
use crate::coordinator::{Batcher, BatcherConfig, GenRequest, Server, ServingWeights};
use crate::model::QuantizedGpt;
use crate::rng::Rng;
use crate::tensor::{matmul, Matrix};

/// Verify the §4.4 resident-memory claim on a quantized model:
///
/// 1. per layer, the bytes the serving path keeps resident (packed stream
///    words + f32 scales + RHT seed) equal `payload_bits / 8` up to the
///    ≤ 8-byte tail slack of each stream's u64 word array;
/// 2. the fused code-domain matmul matches the explicit
///    dequantize-then-dense-matmul path within 1e-5 (relative) on a probe
///    batch, for every layer — i.e. nothing in serving needs the dense
///    weight;
/// 3. the blocked kernel serving actually runs is **bit-identical** to the
///    scalar reference kernel on the same probe batch, and its decode LUT
///    is pure *derived* state: building it changes neither the artifact's
///    payload bits nor the shared-codebook accounting (the LUT is
///    rebuildable from the codebooks — it must never be double-counted
///    against them, nor reported as stored artifact bits).
///
/// Returns the measured overall compression ratio vs dense fp32.
pub fn verify_codes_resident(q: &QuantizedGpt) -> Result<f64> {
    let mut rng = Rng::new(0x44EE);
    for (name, w) in &q.weights {
        let words_bytes: u64 = w
            .codes()
            .streams()
            .iter()
            .map(|s| s.words().len() as u64 * 8)
            .sum();
        let resident_bytes = words_bytes
            + w.scales().len() as u64 * 4
            + if w.rht_seed().is_some() { 8 } else { 0 };
        let payload_bytes = w.payload_bits().div_ceil(8);
        let slack = 8 * w.codes().n_streams() as u64;
        anyhow::ensure!(
            resident_bytes >= payload_bytes && resident_bytes - payload_bytes <= slack,
            "'{name}': resident {resident_bytes} B vs payload {payload_bytes} B \
             (> {slack} B slack) — the artifact holds more than its codes"
        );

        // LUT accounting: record the artifact's stored-state books, force
        // the derived LUT into existence, and check nothing moved
        let payload_before = w.payload_bits();
        let codebook_before = w.codebook_bits();
        let lut_bits = w.decoder().decode_lut().map_or(0, |l| l.bits());
        anyhow::ensure!(
            w.payload_bits() == payload_before && w.codebook_bits() == codebook_before,
            "'{name}': building the decode LUT ({lut_bits} bits of derived \
             state) leaked into payload/codebook accounting"
        );

        // fused-kernel parity: serving never needs the dense weight
        let x = Matrix::from_vec(rng.normal_vec(2 * w.rows()), 2, w.rows());
        let fused = w.matmul_from_codes(&x);
        let dense = matmul(&x, &w.dequantize());
        for (a, b) in dense.as_slice().iter().zip(fused.as_slice()) {
            anyhow::ensure!(
                (a - b).abs() <= 1e-5 * (1.0 + a.abs().max(b.abs())),
                "'{name}': matmul_from_codes diverges from dequantize path \
                 ({b} vs {a})"
            );
        }

        // blocked ≡ scalar: the serving kernel must be bit-identical to the
        // reference kernel (tests/kernel_equivalence.rs pins the full grid;
        // this re-checks on the real model's artifacts), including at an
        // explicit multi-thread strip count (the parallel pool, DESIGN.md
        // §12 — the default entry point may auto-select 1 strip on small
        // layers, so force the fan-out here)
        let scalar = w.matmul_from_codes_scalar(&x);
        for (a, b) in scalar.as_slice().iter().zip(fused.as_slice()) {
            anyhow::ensure!(
                a.to_bits() == b.to_bits(),
                "'{name}': blocked kernel not bit-identical to scalar \
                 reference ({b} vs {a})"
            );
        }
        let threaded = w.matmul_from_codes_threaded(&x, w.default_block_vecs(), true, 4);
        for (a, b) in scalar.as_slice().iter().zip(threaded.as_slice()) {
            anyhow::ensure!(
                a.to_bits() == b.to_bits(),
                "'{name}': parallel kernel (4 strips) not bit-identical to \
                 scalar reference ({b} vs {a})"
            );
        }
    }

    // codebook-once-per-node: the sharded topology keeps each shared
    // codebook resident on every node whose layers reference it. The
    // per-node dedup must collapse to the classic accounting at one node
    // and stay bracketed by [global, n_nodes · global] otherwise.
    let global = q.codebook_bits();
    for n_shards in [1usize, 2, 3] {
        let per_node = crate::coordinator::sharded_codebook_bits(q, n_shards);
        anyhow::ensure!(!per_node.is_empty(), "sharded accounting produced no nodes");
        let total: u64 = per_node.iter().sum();
        if n_shards == 1 {
            anyhow::ensure!(
                total == global,
                "1-node sharded accounting ({total}) != codebook dedup ({global})"
            );
        }
        anyhow::ensure!(
            total >= global && total <= global * per_node.len() as u64,
            "{n_shards}-shard codebook accounting out of bounds: \
             {total} vs global {global} x {} nodes",
            per_node.len()
        );
    }
    Ok(q.dense_bits() as f64 / q.resident_bits() as f64)
}

/// The cache-side companion of [`verify_codes_resident`]: verify that a
/// server running a quantized KV cache (DESIGN.md §15) accounts its cache
/// exactly like the weight path accounts the artifact —
///
/// 1. resident cache bits are **code** bits: `kv_cache_bpw` equals the
///    codec's word-aligned code bits per row over `d_model`, at least the
///    declared per-value width and within one u64 of word-packing slack
///    per row;
/// 2. the frozen per-layer codebooks are counted once, at the codec
///    ([`Server::kv_codebook_bits`] ≡ the codec's own accounting), never
///    folded into per-page payload bits;
/// 3. the decode LUT is *derived* state, exactly like the weight kernel's:
///    re-decoding resident codes moves neither payload nor codebook bits.
///
/// Returns the cache compression ratio vs the exact f32 layout (1.0 when
/// the server runs without a codec).
///
/// [`Server::kv_codebook_bits`]: crate::coordinator::Server::kv_codebook_bits
pub fn verify_kv_cache_resident(server: &Server) -> Result<f64> {
    let Some(codec) = server.kv_codec().cloned() else {
        anyhow::ensure!(
            server.kv_codebook_bits() == 0 && server.kv_cache_bpw() == 32.0,
            "exact cache reported quantized accounting ({} codebook bits, {} bpw)",
            server.kv_codebook_bits(),
            server.kv_cache_bpw(),
        );
        return Ok(1.0);
    };
    let spec = codec.spec();
    let bpw = server.kv_cache_bpw();
    let declared = spec.bits() as f64;
    anyhow::ensure!(
        bpw >= declared,
        "cache bpw {bpw:.3} below the declared {declared} bits/value — \
         accounting dropped code bits"
    );
    let code_bits = codec.n_sub() as u64 * spec.code_width() as u64;
    let row_bits = codec.code_bits_per_row();
    anyhow::ensure!(
        row_bits >= code_bits && row_bits - code_bits < 64,
        "per-row cache bits {row_bits} vs raw code bits {code_bits}: more \
         than one u64 of word-packing slack"
    );

    // codebooks once, at the codec — and the decode LUT stays derived
    // state. On the sharded backend the grids partition across node codecs
    // (each freezes only its own layer range), so the check becomes
    // "per-node bits sum to the server total" instead of equality with
    // node 0's codec, which under-counts by construction.
    match server.kv_codebook_bits_per_node() {
        Some(per_node) => anyhow::ensure!(
            per_node.iter().sum::<u64>() == server.kv_codebook_bits(),
            "per-node cache codebook bits {:?} do not sum to the server \
             total ({})",
            per_node,
            server.kv_codebook_bits(),
        ),
        None => anyhow::ensure!(
            server.kv_codebook_bits() == codec.codebook_bits(),
            "server cache codebook bits ({}) diverge from the codec's ({})",
            server.kv_codebook_bits(),
            codec.codebook_bits(),
        ),
    }
    let codebook_before = codec.codebook_bits();
    let cache_before = server.kv_cache_bits();
    let mut out = vec![0.0f32; codec.d_model()];
    for layer in 0..codec.n_layer() {
        if let Some(lc) = codec.layer(layer) {
            // code 0 (direction 0, magnitude 0) is valid in every frozen
            // layer, so an all-zero row exercises the LUT safely
            let words = vec![0u64; codec.words_per_row()];
            codec.decode_row(lc, &words, &mut out);
        }
    }
    anyhow::ensure!(
        codec.codebook_bits() == codebook_before && server.kv_cache_bits() == cache_before,
        "decoding resident cache codes moved the stored-state accounting \
         (codebooks {} -> {}, cache {} -> {})",
        codebook_before,
        codec.codebook_bits(),
        cache_before,
        server.kv_cache_bits(),
    );
    Ok(32.0 / bpw)
}

fn drive(server: &mut Server, ctx: &Ctx, n_requests: usize, max_new: usize) -> Result<f64> {
    let (tx, rx) = channel::<GenRequest>();
    let mut batcher = Batcher::new(rx, BatcherConfig::default());
    let mut rng = Rng::new(321);
    let mut keep = Vec::new();
    for _ in 0..n_requests {
        let s = rng.below(ctx.eval_tokens.len() - 64);
        let prompt: Vec<u8> = ctx.eval_tokens[s..s + 48].iter().map(|&t| t as u8).collect();
        let (rtx, rrx) = channel();
        let req = GenRequest::builder(prompt).max_new(max_new).build(rtx);
        tx.send(req).unwrap();
        keep.push(rrx);
    }
    drop(tx);
    server.serve(&mut batcher)?;
    Ok(server.metrics.tokens_per_s())
}

pub fn run_efficiency(ctx: &Ctx, model_name: &str, quick: bool) -> Result<()> {
    println!("=== §4.4: efficiency analysis ({model_name}) ===");
    println!("paper: 2-bit ≈ 87.5% weight-memory saved; RTX-4090 tokens/s 33.1 → 95.7.\n");

    let model = ctx.paths.load_model(model_name)?;
    let pcdvq = build_pcdvq_with(
        &ctx.paths,
        DirectionMethod::GreedyE8,
        MagnitudeMethod::LloydMax,
        14,
        2,
        7,
    )?;
    let q = QuantizedGpt::quantize(&model, &pcdvq);

    // --- memory accounting (the §A.3 / §4.4 claim), measured + verified ---
    let dense_fp16_bits = q.dense_bits() / 2; // paper baselines against fp16
    let payload = q.payload_bits();
    let codebook_bits = q.codebook_bits();
    // the blocked kernel's decode LUT is derived state: rebuilt from the
    // shared codebooks at serve time, deduplicated per decoder, and counted
    // against NEITHER payload nor codebook bits (verify_codes_resident
    // asserts it never leaks into either)
    let lut_bits = crate::quant::dedup_lut_bits(q.weights.values());
    let saved = 100.0 * (1.0 - payload as f64 / dense_fp16_bits as f64);
    println!("quantizable weights ({}):", model_name);
    println!("  fp16 baseline:        {:>9.1} KiB", dense_fp16_bits as f64 / 8.0 / 1024.0);
    println!("  PCDVQ payload:        {:>9.1} KiB (codes + scales + seeds)", payload as f64 / 8.0 / 1024.0);
    println!("  shared codebooks:     {:>9.1} KiB (amortized across the model)", codebook_bits as f64 / 8.0 / 1024.0);
    println!("  decode LUT (derived): {:>9.1} KiB (rebuilt from codebooks; 0 artifact bits)", lut_bits as f64 / 8.0 / 1024.0);
    println!("  memory saved:         {:>9.2}%  (paper: ~87.5% at 2.0 bpw)", saved);
    let ratio = verify_codes_resident(&q)?;
    println!(
        "  verified: serving holds codes + codebooks only \
         ({ratio:.1}x smaller than dense fp32; per-layer resident bytes \
         ≈ payload bits / 8; fused matmul ≡ dequant path)"
    );

    // layer-sharded deployment accounting (codebook-once-per-node): codes
    // partition exactly; each node keeps one copy of every codebook its
    // layers reference
    let sharded = crate::coordinator::ShardedForward::new(&q, 2)?;
    for (i, nb) in sharded.node_bits().iter().enumerate() {
        println!(
            "  shard node {i} (layers {:?}): payload {:>7.1} KiB + codebooks {:>7.1} KiB",
            nb.layers,
            nb.payload_bits as f64 / 8.0 / 1024.0,
            nb.codebook_bits as f64 / 8.0 / 1024.0,
        );
    }
    println!(
        "  2-node sharded resident total: {:.1} KiB (codebooks once per node)",
        sharded.resident_bits() as f64 / 8.0 / 1024.0
    );

    // --- host codes-resident serving (no XLA, no dense weights, ever) ---
    let (n_req, max_new) = if quick { (8, 12) } else { (32, 32) };
    let mut host_server =
        Server::builder(ServingWeights::CodesResident(Box::new(q.clone()))).build()?;
    let host_tps = drive(&mut host_server, ctx, n_req, max_new)?;
    println!(
        "\nhost codes-resident serving: {host_tps:.1} tok/s (resident weights \
         {:.1} KiB + codebooks {:.1} KiB)",
        host_server.resident_weight_bits as f64 / 8.0 / 1024.0,
        host_server.resident_codebook_bits as f64 / 8.0 / 1024.0,
    );

    // --- quantized KV cache (DESIGN.md §15): same weights, 4-bit cache ---
    let mut kvq_server = Server::builder(ServingWeights::CodesResident(Box::new(q.clone())))
        .kv_quant(4)
        .build()?;
    let kvq_tps = drive(&mut kvq_server, ctx, n_req, max_new)?;
    let cache_ratio = verify_kv_cache_resident(&kvq_server)?;
    println!(
        "4-bit polar-decoupled KV cache: {kvq_tps:.1} tok/s \
         (cache {:.1} bpw = {:.1}x smaller than f32 rows; \
         {:.1} KiB resident codes + {:.2} KiB frozen cache codebooks)",
        kvq_server.kv_cache_bpw(),
        cache_ratio,
        kvq_server.kv_cache_bits() as f64 / 8.0 / 1024.0,
        kvq_server.kv_codebook_bits() as f64 / 8.0 / 1024.0,
    );

    // --- XLA serving throughput (needs the AOT artifacts) ---
    let engine = &ctx.engine;
    let mut fp_server =
        Server::new(engine, &ctx.paths.artifacts, ServingWeights::Fp(model.clone()))?;
    let fp_tps = drive(&mut fp_server, ctx, n_req, max_new)?;
    let mut q_server = Server::new(
        engine,
        &ctx.paths.artifacts,
        ServingWeights::Quantized(Box::new(q), (*pcdvq.dir).clone(), (*pcdvq.mag).clone()),
    )?;
    let q_tps = drive(&mut q_server, ctx, n_req, max_new)?;

    println!("\nserving throughput ({n_req} requests x {max_new} new tokens, batch 8):");
    println!("  fp32 weights:         {fp_tps:>9.1} tok/s  (p50 {:.0} ms)", fp_server.metrics.latency_ms(50.0));
    println!("  PCDVQ in-graph deq:   {q_tps:>9.1} tok/s  (p50 {:.0} ms)", q_server.metrics.latency_ms(50.0));
    println!("  resident weight bits: fp {:.1} KiB vs quantized {:.1} KiB ({:.1}x smaller)",
        fp_server.resident_weight_bits as f64 / 8.0 / 1024.0,
        q_server.resident_weight_bits as f64 / 8.0 / 1024.0,
        fp_server.resident_weight_bits as f64 / q_server.resident_weight_bits as f64,
    );
    println!("\nnote: the paper's tok/s gain comes from GPU HBM bandwidth; on this");
    println!("compute-bound CPU testbed the dequant adds work instead, so we report");
    println!("the memory ratio (the mechanism) plus honest CPU throughput numbers.");
    Ok(())
}
