//! §4.4 — efficiency analysis: memory footprint + serving throughput.
//!
//! Paper: PCDVQ-2bit cuts ~87.5% of weight memory, and tokens/s on an
//! RTX-4090 rises 33.1 → 95.7 because decoding is HBM-bandwidth-bound and
//! 2-bit weights shrink the traffic.
//!
//! On this CPU testbed the memory claim reproduces directly (payload
//! accounting below); the throughput claim does *not* transfer mechanically:
//! CPU XLA decode is compute-bound, so the in-graph dequant costs more than
//! the saved DRAM traffic. We report both honestly — the resident-bytes
//! ratio is the mechanism the paper's GPU speedup rides on.

use std::sync::mpsc::channel;
use std::time::Instant;

use anyhow::Result;

use super::Ctx;
use crate::codebook::{DirectionMethod, MagnitudeMethod};
use crate::config::build_pcdvq_with;
use crate::coordinator::{Batcher, BatcherConfig, GenRequest, Server, ServingWeights};
use crate::model::QuantizedGpt;
use crate::rng::Rng;

fn drive(server: &mut Server, ctx: &Ctx, n_requests: usize, max_new: usize) -> Result<f64> {
    let (tx, rx) = channel::<GenRequest>();
    let batcher = Batcher::new(rx, BatcherConfig::default());
    let mut rng = Rng::new(321);
    let mut keep = Vec::new();
    for _ in 0..n_requests {
        let s = rng.below(ctx.eval_tokens.len() - 64);
        let prompt: Vec<u8> = ctx.eval_tokens[s..s + 48].iter().map(|&t| t as u8).collect();
        let (rtx, rrx) = channel();
        tx.send(GenRequest {
            prompt,
            max_new,
            temperature: 0.0,
            resp: rtx,
            enqueued: Instant::now(),
        })
        .unwrap();
        keep.push(rrx);
    }
    drop(tx);
    server.serve(&batcher)?;
    Ok(server.metrics.tokens_per_s())
}

pub fn run_efficiency(ctx: &Ctx, model_name: &str, quick: bool) -> Result<()> {
    println!("=== §4.4: efficiency analysis ({model_name}) ===");
    println!("paper: 2-bit ≈ 87.5% weight-memory saved; RTX-4090 tokens/s 33.1 → 95.7.\n");

    let model = ctx.paths.load_model(model_name)?;
    let pcdvq = build_pcdvq_with(
        &ctx.paths,
        DirectionMethod::GreedyE8,
        MagnitudeMethod::LloydMax,
        14,
        2,
        7,
    )?;
    let q = QuantizedGpt::quantize(&model, &pcdvq);

    // --- memory accounting (the §A.3 / §4.4 claim) ---
    let dense_fp16_bits = q.dense_bits() / 2; // paper baselines against fp16
    let payload = q.payload_bits();
    let codebook_bits =
        (pcdvq.dir.len() * pcdvq.dir.dim() * 32 + pcdvq.mag.len() * 32) as u64;
    let saved = 100.0 * (1.0 - payload as f64 / dense_fp16_bits as f64);
    println!("quantizable weights ({}):", model_name);
    println!("  fp16 baseline:        {:>9.1} KiB", dense_fp16_bits as f64 / 8.0 / 1024.0);
    println!("  PCDVQ payload:        {:>9.1} KiB (codes + scales + seeds)", payload as f64 / 8.0 / 1024.0);
    println!("  shared codebooks:     {:>9.1} KiB (amortized across the model)", codebook_bits as f64 / 8.0 / 1024.0);
    println!("  memory saved:         {:>9.2}%  (paper: ~87.5% at 2.0 bpw)", saved);

    // --- serving throughput ---
    let (n_req, max_new) = if quick { (8, 12) } else { (32, 32) };
    let engine = &ctx.engine;
    let mut fp_server =
        Server::new(engine, &ctx.paths.artifacts, ServingWeights::Fp(model.clone()))?;
    let fp_tps = drive(&mut fp_server, ctx, n_req, max_new)?;
    let mut q_server = Server::new(
        engine,
        &ctx.paths.artifacts,
        ServingWeights::Quantized(Box::new(q), (*pcdvq.dir).clone(), (*pcdvq.mag).clone()),
    )?;
    let q_tps = drive(&mut q_server, ctx, n_req, max_new)?;

    println!("\nserving throughput ({n_req} requests x {max_new} new tokens, batch 8):");
    println!("  fp32 weights:         {fp_tps:>9.1} tok/s  (p50 {:.0} ms)", fp_server.metrics.latency_ms(50.0));
    println!("  PCDVQ in-graph deq:   {q_tps:>9.1} tok/s  (p50 {:.0} ms)", q_server.metrics.latency_ms(50.0));
    println!("  resident weight bits: fp {:.1} KiB vs quantized {:.1} KiB ({:.1}x smaller)",
        fp_server.resident_weight_bits as f64 / 8.0 / 1024.0,
        q_server.resident_weight_bits as f64 / 8.0 / 1024.0,
        fp_server.resident_weight_bits as f64 / q_server.resident_weight_bits as f64,
    );
    println!("\nnote: the paper's tok/s gain comes from GPU HBM bandwidth; on this");
    println!("compute-bound CPU testbed the dequant adds work instead, so we report");
    println!("the memory ratio (the mechanism) plus honest CPU throughput numbers.");
    Ok(())
}
