//! Paper harness — one regenerator per table/figure of the PCDVQ paper.
//!
//! Every experiment prints the paper's reported numbers (its testbed:
//! LLaMA family + WikiText2/C4 + lm-eval) next to ours (tinygpt analogs +
//! byte-corpus + proxy tasks). Absolute values are not comparable across
//! testbeds — the claim being reproduced is the *shape*: orderings, gaps,
//! and trends. See DESIGN.md §2 and §5.
//!
//! Driven by the `paper` binary: `cargo run --release --bin paper -- <exp>`
//! with `<exp>` ∈ {fig1a, fig1b, table1, table2, table3, table4, fig3,
//! efficiency, all}. `--quick` shrinks eval sizes for smoke runs.

mod efficiency;
mod fig1;
mod fig3;
mod table1;
mod table3;
mod table4;

pub use efficiency::{run_efficiency, verify_codes_resident, verify_kv_cache_resident};
pub use fig1::{run_fig1a, run_fig1b};
pub use fig3::run_fig3;
pub use table1::{run_table1, run_table2};
pub use table3::run_table3;
pub use table4::run_table4;

use anyhow::Result;

use crate::config::Paths;
use crate::eval::{evaluate_ppl, evaluate_tasks};
use crate::model::GptModel;
use crate::runtime::Engine;

/// Shared state for all experiments.
pub struct Ctx {
    pub paths: Paths,
    pub engine: Engine,
    pub eval_tokens: Vec<u32>,
    pub train_tokens: Vec<u32>,
    /// Eval sizes: (ppl windows, task items).
    pub windows: usize,
    pub items: usize,
}

impl Ctx {
    pub fn new(quick: bool) -> Result<Self> {
        let paths = Paths::detect();
        let engine = Engine::new()?;
        let eval_tokens = paths.eval_tokens()?;
        let train_tokens = paths.train_tokens()?;
        let (windows, items) = if quick { (12, 16) } else { (96, 80) };
        Ok(Ctx { paths, engine, eval_tokens, train_tokens, windows, items })
    }

    /// PPL + QA-avg of a (possibly fake-quant) model through the AOT
    /// forward. `temperature` feeds the Table-3 e2e-tuning analog.
    pub fn eval_model(&self, model: &GptModel, temperature: f32) -> Result<(f64, f64)> {
        let exe = self
            .engine
            .load(self.paths.artifacts.join(format!("fwd_fp_{}_b8", model.name)))?;
        let fixed = crate::eval::weight_inputs(model, &exe.manifest)?;
        let bound = exe.bind(&fixed, 1)?;
        let ppl = evaluate_ppl(&bound, &model.config, &self.eval_tokens, 8, self.windows, temperature)?;
        let tasks = evaluate_tasks(&bound, &model.config, &self.eval_tokens, 8, self.items, 99)?;
        Ok((ppl.ppl, tasks.avg * 100.0))
    }
}

/// Render a measured-table row.
pub fn row(label: &str, bpw: f64, ppl: f64, qa: f64) -> String {
    format!("{label:<26} {bpw:>6.3}  {ppl:>8.3}  {qa:>7.2}%")
}

pub const RULE: &str = "--------------------------------------------------------";
