//! Figure 1 — the paper's motivating analysis.
//!
//! (a) Direction vs magnitude quantization sensitivity: quantize *only* one
//!     of the two polar components at increasing index bits and measure the
//!     zero-shot proxy average. The paper finds direction-only quantization
//!     costs up to ~46.5% accuracy while magnitude-only costs ~2.3%.
//! (b) Direction vs magnitude MSE of coupled k-means VQ as the vector
//!     dimension grows (Euclidean codebooks under-serve direction).

use anyhow::Result;

use super::Ctx;
use crate::codebook::{DirectionCodebook, DirectionMethod, MagnitudeCodebook, MagnitudeMethod};
use crate::hadamard::{deregularize, regularize, RandomizedHadamard};
use crate::quant::assign::assign_into;
use crate::quant::error::decompose;
use crate::quant::vq_kmeans::KMeansVq;
use crate::quant::Quantizer;
use crate::tensor::Matrix;

/// Quantize only one polar component of every quantizable weight.
fn quantize_one_component(
    model: &crate::model::GptModel,
    dir_cb: Option<&DirectionCodebook>,
    mag_cb: Option<&MagnitudeCodebook>,
) -> crate::model::GptModel {
    let mut out = model.clone();
    for name in model.config.quantizable_names() {
        let w = &model.tensors[&name];
        let rht = RandomizedHadamard::new(w.rows(), 0xF16A ^ w.cols() as u64);
        let (h, scales) = regularize(w, &rht);
        let vectors = h.reshape_vectors(8);
        let n = vectors.rows();
        let mut recon = Matrix::zeros(n, 8);
        // split
        let mut dirs = Matrix::zeros(n, 8);
        let mut mags = vec![0.0f32; n];
        for i in 0..n {
            let v = vectors.row(i);
            let r: f32 = v.iter().map(|x| x * x).sum::<f32>().sqrt();
            mags[i] = r;
            let d = dirs.row_mut(i);
            if r > 0.0 {
                for (dj, &vj) in d.iter_mut().zip(v) {
                    *dj = vj / r;
                }
            } else {
                d[0] = 1.0;
            }
        }
        // quantize the chosen component
        let dir_q: Vec<usize> = match dir_cb {
            Some(cb) => {
                let mut idx = vec![0u32; n];
                assign_into(&dirs, &cb.vectors, &[], &mut idx);
                idx.into_iter().map(|x| x as usize).collect()
            }
            None => Vec::new(),
        };
        for i in 0..n {
            let d: Vec<f32> = match dir_cb {
                Some(cb) => cb.vectors.row(dir_q[i]).to_vec(),
                None => dirs.row(i).to_vec(),
            };
            let r = match mag_cb {
                Some(cb) => cb.level(cb.assign(mags[i])),
                None => mags[i],
            };
            for (slot, dj) in recon.row_mut(i).iter_mut().zip(d) {
                *slot = r * dj;
            }
        }
        let hq = Matrix::from_vec(recon.into_vec(), w.rows(), w.cols());
        out.tensors.insert(name, deregularize(&hq, &scales, &rht));
    }
    out
}

/// Figure 1(a).
pub fn run_fig1a(ctx: &Ctx, model_name: &str) -> Result<()> {
    println!("=== Figure 1(a): direction vs magnitude quantization sensitivity ===");
    println!("paper (LLaMA-2-7B, K-Means VQ): direction-only quantization at low");
    println!("bits drops ~30-46% of zero-shot accuracy; magnitude-only ~2-3%.\n");
    let model = ctx.paths.load_model(model_name)?;
    let (fp_ppl, fp_qa) = ctx.eval_model(&model, 1.0)?;
    println!("{model_name} fp16 reference: ppl {fp_ppl:.3}, QA avg {fp_qa:.2}%\n");
    println!("{:<6} {:>18} {:>18}", "bits", "direction-only QA%", "magnitude-only QA%");
    for bits in [2u32, 4, 6, 8, 10, 12] {
        let dir_cb = DirectionCodebook::build(DirectionMethod::GreedyE8, bits, 8, 0);
        let mag_cb =
            MagnitudeCodebook::build(MagnitudeMethod::LloydMax, bits.min(10), 8, 1.0 - 1e-4, 0);
        let m_dir = quantize_one_component(&model, Some(&dir_cb), None);
        let m_mag = quantize_one_component(&model, None, Some(&mag_cb));
        let (_, qa_dir) = ctx.eval_model(&m_dir, 1.0)?;
        let (_, qa_mag) = ctx.eval_model(&m_mag, 1.0)?;
        println!("{bits:<6} {qa_dir:>17.2}% {qa_mag:>17.2}%");
    }
    println!("\nshape check: direction-only accuracy should climb steeply with bits");
    println!("while magnitude-only stays ≈ fp16 even at 2 bits.");
    Ok(())
}

/// Figure 1(b).
pub fn run_fig1b(ctx: &Ctx, model_name: &str) -> Result<()> {
    println!("=== Figure 1(b): direction vs magnitude MSE of coupled VQ vs dim ===");
    println!("paper: magnitude MSE stays small and flat; direction MSE is larger");
    println!("and grows with the vector dimension.\n");
    let model = ctx.paths.load_model(model_name)?;
    // pool of regularized weight values (the domain VQ actually sees) —
    // concatenate several matrices so even k=16 has a pool far larger than
    // the codebook
    let mut pooled = Vec::new();
    for name in model.config.quantizable_names() {
        let w = &model.tensors[&name];
        let rht = RandomizedHadamard::new(w.rows(), 0xF1B ^ w.cols() as u64);
        let (h, _) = regularize(w, &rht);
        pooled.extend_from_slice(h.as_slice());
        if pooled.len() > 400_000 {
            break;
        }
    }
    println!(
        "{:<6} {:>16} {:>16} {:>14}",
        "dim k", "direction MSE", "magnitude MSE", "total MSE"
    );
    for k in [2usize, 4, 8, 16] {
        let n = pooled.len() / k;
        let h = Matrix::from_vec(pooled[..n * k].to_vec(), n, k);
        let mut vq = KMeansVq::new(k, 12); // 4096-entry coupled codebook
        vq.fit_on_weight(&h);
        let deq = vq.quantize(&h).into_dequantized();
        let d = decompose(&h.reshape_vectors(k), &deq.reshape_vectors(k));
        println!(
            "{k:<6} {:>16.5} {:>16.5} {:>14.5}",
            d.direction_mse, d.magnitude_mse, d.total_mse
        );
    }
    let _ = ctx;
    println!("\nshape check: direction MSE > magnitude MSE at every dim, gap widens.");
    Ok(())
}
