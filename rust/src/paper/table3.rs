//! Table 3 — fine-tuning ablation (w/wo block tuning, w/wo e2e tuning).
//!
//! Substitution (DESIGN.md §2): "block tuning" → closed-form per-row scale
//! correction of each quantized matrix against its original; "e2e tuning" →
//! logit temperature fitted on a calibration slice of the training split.
//! Both are post-hoc corrections of the same *kind* as QuIP#'s two stages;
//! the cell structure (both > one > none, PCDVQ > QuIP#-like everywhere)
//! is the reproduced shape.

use anyhow::Result;

use super::{Ctx, RULE};
use crate::config::MethodSpec;
use crate::coordinator::quantize_model_parallel;
use crate::eval::fit_temperature;
use crate::model::GptModel;
use crate::quant::tune::row_scale_correction;

struct Cell {
    ppl: f64,
    qa: f64,
}

fn eval_with_tuning(
    ctx: &Ctx,
    original: &GptModel,
    quantized: &GptModel,
    block_tuning: bool,
    e2e_tuning: bool,
) -> Result<Cell> {
    // block tuning: per-row scale correction on every quantized matrix
    let model = if block_tuning {
        let mut m = quantized.clone();
        for name in original.config.quantizable_names() {
            let (corrected, _) =
                row_scale_correction(&original.tensors[&name], &quantized.tensors[&name]);
            m.tensors.insert(name, corrected);
        }
        m
    } else {
        quantized.clone()
    };
    // e2e tuning: temperature fitted on calibration (train-split tail)
    let temperature = if e2e_tuning {
        let exe = ctx
            .engine
            .load(ctx.paths.artifacts.join(format!("fwd_fp_{}_b8", model.name)))?;
        let fixed = crate::eval::weight_inputs(&model, &exe.manifest)?;
        let bound = exe.bind(&fixed, 1)?;
        let calib = &ctx.train_tokens[ctx.train_tokens.len().saturating_sub(40_000)..];
        fit_temperature(&bound, &model.config, calib, 8, 8)?
    } else {
        1.0
    };
    let (ppl, qa) = ctx.eval_model(&model, temperature)?;
    Ok(Cell { ppl, qa })
}

pub fn run_table3(ctx: &Ctx, model_name: &str) -> Result<()> {
    println!("=== Table 3: tuning ablation (2-bit, {model_name}) ===");
    println!("paper (LLaMA-2-7B, Wiki2 ppl / QA avg):");
    println!("  QuIP#: all 6.19/58.2 | wo-block 6.82/55.9 | wo-e2e 6.78/56.5 | none 9.05/52.3");
    println!("  PCDVQ: all 5.81/58.6 | wo-block 6.60/58.7 | wo-e2e 6.61/59.5 | none 8.47/55.9");
    println!("(substituted tuning analogs — see DESIGN.md §2)\n");

    let model = ctx.paths.load_model(model_name)?;
    println!(
        "{:<16} {:>14} {:>16} {:>15} {:>14}",
        "method", "w all tuning", "wo block tuning", "wo e2e tuning", "wo all tuning"
    );
    println!("{RULE}{RULE}");
    for spec_name in ["quip16", "pcdvq2"] {
        let spec = MethodSpec::parse(spec_name)?;
        let quantizer = spec.build(&ctx.paths, &model, 7)?;
        let (qm, _) = quantize_model_parallel(&model, quantizer.as_ref(), 1);
        let all = eval_with_tuning(ctx, &model, &qm, true, true)?;
        let wo_block = eval_with_tuning(ctx, &model, &qm, false, true)?;
        let wo_e2e = eval_with_tuning(ctx, &model, &qm, true, false)?;
        let none = eval_with_tuning(ctx, &model, &qm, false, false)?;
        println!(
            "{:<16} {:>7.3}/{:>5.1}% {:>9.3}/{:>5.1}% {:>8.3}/{:>5.1}% {:>7.3}/{:>5.1}%",
            spec.label(),
            all.ppl,
            all.qa,
            wo_block.ppl,
            wo_block.qa,
            wo_e2e.ppl,
            wo_e2e.qa,
            none.ppl,
            none.qa
        );
    }
    println!("\nshape check: PCDVQ beats QuIP#-like in every column (the paper's");
    println!("primary Table-3 claim). Honest caveat: the closed-form tuning");
    println!("analogs (row-scale fit + logit temperature) move ppl by <1% on this");
    println!("substrate, far less than the paper's gradient fine-tuning (which");
    println!("shifts ppl ~30%); the 'tuning helps monotonically' part of the");
    println!("shape does NOT reproduce under the substitution — see DESIGN.md §2.");
    Ok(())
}
