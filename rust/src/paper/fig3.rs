//! Figure 3 — per-decoder-block direction/magnitude MSE, QuIP#-like vs PCDVQ.
//!
//! The paper plots, block by block, the direction error (2‖v‖²(1−cosθ)) and
//! magnitude error ((‖v‖−‖c‖)²) of the quantized weights; PCD reduces the
//! direction error by ~0.3 on average while keeping magnitude error small.

use anyhow::Result;

use super::{Ctx, RULE};
use crate::codebook::{DirectionMethod, MagnitudeMethod};
use crate::config::build_pcdvq_with;
use crate::quant::error::decompose_weights;
use crate::quant::quip::QuipLike;
use crate::tensor::Matrix;

pub fn run_fig3(ctx: &Ctx, model_name: &str) -> Result<()> {
    println!("=== Figure 3: per-block error decomposition (2-bit, {model_name}) ===");
    println!("paper: PCDVQ's direction MSE sits ~0.3 below QuIP#'s on every");
    println!("decoder block of LLaMA-2-7B; magnitude MSE is small for both.");
    println!("(measured in the regularized domain, where VQ operates — the");
    println!("inverse RHT is a rotation and would isotropize the split)\n");

    let model = ctx.paths.load_model(model_name)?;
    let quip = QuipLike::build(16, 7);
    let pcdvq = build_pcdvq_with(
        &ctx.paths,
        DirectionMethod::GreedyE8,
        MagnitudeMethod::LloydMax,
        14,
        2,
        7,
    )?;

    let mut results: Vec<(String, Vec<(f64, f64)>)> = Vec::new();
    for which in ["quip", "pcdvq"] {
        let mut per_block = Vec::new();
        for layer in 0..model.config.n_layer {
            let mut dir = 0.0f64;
            let mut mag = 0.0f64;
            let mut n = 0usize;
            for name in model.config.quantizable_names() {
                if !name.starts_with(&format!("layer{layer}.")) {
                    continue;
                }
                let w: &Matrix = &model.tensors[&name];
                let (h, hq) = if which == "quip" {
                    quip.quantize_regularized(w)
                } else {
                    pcdvq.quantize_regularized(w)
                };
                let d = decompose_weights(&h, &hq, 8);
                dir += d.direction_mse * d.count as f64;
                mag += d.magnitude_mse * d.count as f64;
                n += d.count;
            }
            per_block.push((dir / n as f64, mag / n as f64));
        }
        let label = if which == "quip" {
            "QuIP#-like-16b".to_string()
        } else {
            "PCDVQ a=14 b=2".to_string()
        };
        results.push((label, per_block));
    }

    println!(
        "{:<8} {:>22} {:>22}",
        "block", results[0].0, results[1].0
    );
    println!("{:<8} {:>11} {:>10} {:>11} {:>10}", "", "dir MSE", "mag MSE", "dir MSE", "mag MSE");
    println!("{RULE}");
    let n_layer = results[0].1.len();
    let (mut q_dir, mut q_mag, mut p_dir, mut p_mag) = (0.0, 0.0, 0.0, 0.0);
    for i in 0..n_layer {
        let (qd, qm_) = results[0].1[i];
        let (pd, pm) = results[1].1[i];
        q_dir += qd;
        q_mag += qm_;
        p_dir += pd;
        p_mag += pm;
        println!("{i:<8} {qd:>11.4} {qm_:>10.4} {pd:>11.4} {pm:>10.4}");
    }
    let n = n_layer as f64;
    println!("{RULE}");
    println!(
        "means: {}  dir {:.4} mag {:.4} (total {:.4})",
        results[0].0,
        q_dir / n,
        q_mag / n,
        (q_dir + q_mag) / n
    );
    println!(
        "       {}  dir {:.4} mag {:.4} (total {:.4})",
        results[1].0,
        p_dir / n,
        p_mag / n,
        (p_dir + p_mag) / n
    );
    println!("\nshape check: PCDVQ's TOTAL decomposed error below the coupled");
    println!("baseline's on every block. Divergence from the paper, reported");
    println!("honestly: on this substrate PCDVQ's win flows through the magnitude");
    println!("channel (~4x lower, Lloyd-Max vs coupled radial granularity) while");
    println!("its direction MSE runs slightly above the 16-bit coupled E8 ball —");
    println!("the paper's Δ≈0.3 direction gap favoured PCDVQ on LLaMA-2-7B.");
    Ok(())
}
