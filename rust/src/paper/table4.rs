//! Table 4 — DACC ablation: codebook construction methods.
//!
//! Direction: Random-Gaussian vs Simulated-Annealing vs K-Means vs
//! Greedy-E8 (with Lloyd-Max magnitudes). Magnitude: K-Means vs Lloyd-Max
//! (with Greedy-E8 directions). Paper setting: 2.125 bpw on LLaMA-2-7B.

use anyhow::Result;

use super::{row, Ctx, RULE};
use crate::codebook::{DirectionMethod, MagnitudeMethod};
use crate::config::build_pcdvq_with;
use crate::coordinator::quantize_model_parallel;

pub fn run_table4(ctx: &Ctx, model_name: &str, quick: bool) -> Result<()> {
    println!("=== Table 4: DACC ablation at 2.125 bpw ({model_name}) ===");
    println!("paper (LLaMA-2-7B, Wiki2 ppl / QA avg):");
    println!("  direction: RandGauss 2637/34.8 | SimAnneal 7.08/58.5 | KMeans 6.59/59.1 | GreedyE8 5.68/60.4");
    println!("  magnitude: KMeans 6.44/60.1 | Lloyd-Max 5.68/60.4\n");

    let model = ctx.paths.load_model(model_name)?;
    // a=15,b=2 → (15+2)/8 = 2.125 exactly (the paper's stated a=16 is
    // inconsistent with its own bpw formula — DESIGN.md §6).
    let (a, b) = if quick { (11u32, 2u32) } else { (15, 2) };

    println!("direction codebook ablation (magnitude = Lloyd-Max):");
    println!("{:<26} {:>6}  {:>8}  {:>8}", "method", "bpw", "ppl↓", "QA Avg↑");
    println!("{RULE}");
    for dm in [
        DirectionMethod::RandomGaussian,
        DirectionMethod::SimulatedAnnealing,
        DirectionMethod::KMeans,
        DirectionMethod::GreedyE8,
    ] {
        let q = build_pcdvq_with(&ctx.paths, dm, MagnitudeMethod::LloydMax, a, b, 7)?;
        let (qm, stats) = quantize_model_parallel(&model, &q, 1);
        let (ppl, qa) = ctx.eval_model(&qm, 1.0)?;
        println!("{}", row(dm.name(), stats.achieved_bpw, ppl, qa));
    }

    println!("\nmagnitude codebook ablation (direction = Greedy-E8):");
    println!("{:<26} {:>6}  {:>8}  {:>8}", "method", "bpw", "ppl↓", "QA Avg↑");
    println!("{RULE}");
    for mm in [MagnitudeMethod::KMeans, MagnitudeMethod::LloydMax] {
        let q = build_pcdvq_with(&ctx.paths, DirectionMethod::GreedyE8, mm, a, b, 7)?;
        let (qm, stats) = quantize_model_parallel(&model, &q, 1);
        let (ppl, qa) = ctx.eval_model(&qm, 1.0)?;
        println!("{}", row(mm.name(), stats.achieved_bpw, ppl, qa));
    }
    println!("\nshape check: greedy-E8 best among directions (random Gaussian worst);");
    println!("Lloyd-Max ≥ K-Means for magnitudes.");
    Ok(())
}
