//! Tables 1 & 2 — main results: PCDVQ vs baselines at the 2-bit level.
//!
//! Model mapping (DESIGN.md §2): gpt-s/m/l play LLaMA-2-7B/13B/70B (Table 1);
//! gpt-alt and gpt-mini play LLaMA-3-8B and Mistral-7B (Table 2). Methods map
//! GPTQ→RTN/error-feedback SQ, GPTVQ/VPTQ→coupled k-means VQ,
//! QuIP#→RHT+E8-ball VQ, PCDVQ→this repo's implementation.

use anyhow::Result;

use super::{row, Ctx, RULE};
use crate::config::MethodSpec;
use crate::coordinator::quantize_model_parallel;

/// Paper numbers for the side-by-side header (Wiki2 ppl, QA avg).
const PAPER_T1_7B: &[(&str, f64, f64, f64)] = &[
    ("fp16", 16.0, 5.12, 62.24),
    ("GPTQ", 2.125, 50.75, 39.16),
    ("GPTVQ", 2.25, 6.71, 56.14),
    ("QuIP#", 2.02, 6.19, 58.23),
    ("VPTQ", 2.02, 6.13, 58.13),
    ("PCDVQ", 2.0, 5.81, 58.60),
    ("PCDVQ", 2.125, 5.68, 60.44),
];

fn methods(quick: bool) -> Vec<&'static str> {
    if quick {
        vec!["rtn2", "quip16", "pcdvq2"]
    } else {
        vec!["rtn2", "gptq2", "kmeans16", "quip16", "pcdvq2", "pcdvq2.125"]
    }
}

pub fn run_table_models(ctx: &Ctx, models: &[(&str, &str)], quick: bool) -> Result<()> {
    for (model_name, analog) in models {
        let model = ctx.paths.load_model(model_name)?;
        println!("\n--- {model_name} (plays {analog}) ---");
        println!("{:<26} {:>6}  {:>8}  {:>8}", "method", "bpw", "ppl↓", "QA Avg↑");
        println!("{RULE}");
        let (ppl, qa) = ctx.eval_model(&model, 1.0)?;
        println!("{}", row("fp16", 16.0, ppl, qa));
        for m in methods(quick) {
            let spec = MethodSpec::parse(m)?;
            let quantizer = spec.build(&ctx.paths, &model, 7)?;
            let (qm, stats) = quantize_model_parallel(&model, quantizer.as_ref(), 1);
            let (ppl, qa) = ctx.eval_model(&qm, 1.0)?;
            println!("{}", row(&spec.label(), stats.achieved_bpw, ppl, qa));
        }
    }
    Ok(())
}

/// Table 1 (LLaMA-2 series analogs).
pub fn run_table1(ctx: &Ctx, quick: bool) -> Result<()> {
    println!("=== Table 1: 2-bit quantization, LLaMA-2-series analogs ===");
    println!("paper (LLaMA-2-7B column: bpw, Wiki2 ppl↓, QA avg↑):");
    for (m, bpw, ppl, qa) in PAPER_T1_7B {
        println!("  {m:<8} {bpw:>6.3}  {ppl:>8.2}  {qa:>7.2}%");
    }
    println!("\nmeasured on the tinygpt analogs (byte ppl / proxy tasks — compare");
    println!("ORDER and GAPS, not absolute values):");
    let models: &[(&str, &str)] = if quick {
        &[("gpt-s", "LLaMA-2-7B")]
    } else {
        &[
            ("gpt-s", "LLaMA-2-7B"),
            ("gpt-m", "LLaMA-2-13B"),
            ("gpt-l", "LLaMA-2-70B"),
        ]
    };
    run_table_models(ctx, models, quick)?;
    println!("\nshape check: VQ ≫ SQ at 2 bits (RTN/GPTQ-like collapse hardest,");
    println!("like the paper's GPTQ row), PCDVQ at or near the top of the VQ");
    println!("group. Caveat: the per-model k-means baseline enjoys a memorization");
    println!("advantage at tiny scale (3-6 weight vectors per centroid vs ~10^4");
    println!("at LLaMA scale), so its rows are stronger here than VPTQ's are in");
    println!("the paper; PCDVQ's codebooks are model-independent.");
    Ok(())
}

/// Table 2 (LLaMA-3 / Mistral analogs).
pub fn run_table2(ctx: &Ctx, quick: bool) -> Result<()> {
    println!("=== Table 2: 2-bit quantization, LLaMA-3-8B / Mistral-7B analogs ===");
    println!("paper: PCDVQ 2-bit beats all sub-2.1-bit baselines on both models");
    println!("(e.g. LLaMA-3-8B: GPTQ 210 ppl vs VPTQ 9.29 vs PCDVQ 8.77).");
    let models: &[(&str, &str)] = if quick {
        &[("gpt-mini", "Mistral-7B")]
    } else {
        &[("gpt-alt", "LLaMA-3-8B"), ("gpt-mini", "Mistral-7B")]
    };
    run_table_models(ctx, models, quick)
}
