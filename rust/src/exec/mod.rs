//! Shared execution layer: a reusable scoped-thread worker pool with a
//! deterministic partitioning contract (DESIGN.md §12).
//!
//! Every multi-core path in the crate — the parallel fused matmul
//! ([`crate::quant::QuantizedWeight::matmul_from_codes`]), the per-position
//! attention fan-out in the host forward, the per-slot stepping of
//! [`crate::coordinator::Server::serve_continuous`], the nearest-codeword
//! scan ([`crate::quant::assign::assign_into`]) and the layer-shard chain
//! ([`crate::coordinator::ShardedForward`]) — runs through this module, so
//! there is exactly one thread-count default ([`default_threads`],
//! `PALLAS_THREADS`-overridable) and one partitioning rule ([`partition`]).
//!
//! ## The determinism contract
//!
//! Work is split into **fixed contiguous strips in index order**
//! ([`partition`]): strip boundaries depend only on `(n, parts)`, never on
//! scheduling. Each worker owns a disjoint strip of the input/output, and
//! results are combined on the calling thread in strip order after the
//! join. Consequently every parallel path in this crate is **bit-identical
//! to its serial execution at any thread count** — the kernel-equivalence
//! and continuous-batching suites pin this across a thread grid in CI
//! (`PALLAS_THREADS=1` and `=4` named steps).
//!
//! Pools are plain scoped-thread fan-outs (no persistent worker threads,
//! no channels, no dependencies): a [`Pool`] is just a thread-count, and
//! each call spawns its strips under [`std::thread::scope`] so borrowed
//! data flows in without `'static` bounds.
//!
//! ## Nesting
//!
//! Coarse-grain parallel sections (e.g. the slot pool) pin their workers'
//! *inner* parallelism to one thread via [`with_threads`] so the machine is
//! not oversubscribed — the same coordination hook the layer-parallel
//! quantization scheduler always used for the assignment scan.

use std::cell::Cell;
use std::ops::Range;
use std::sync::OnceLock;

std::thread_local! {
    /// Per-thread override of the worker count (see [`with_threads`]).
    static THREAD_OVERRIDE: Cell<Option<usize>> = const { Cell::new(None) };
}

/// Process-wide default worker count: `PALLAS_THREADS` if set (read once per
/// process — repeated `getenv` from concurrent threads is not safe on every
/// libc; `PCDVQ_ASSIGN_THREADS` is honored as the legacy alias), else the
/// available parallelism. This is the single thread-count default behind
/// every parallel path — set `PALLAS_THREADS=1` to make any run serial and
/// `PALLAS_THREADS=n` to make benches reproducible on any core count.
pub fn default_threads() -> usize {
    static DEFAULT: OnceLock<usize> = OnceLock::new();
    *DEFAULT.get_or_init(|| {
        for key in ["PALLAS_THREADS", "PCDVQ_ASSIGN_THREADS"] {
            if let Some(n) = std::env::var(key).ok().and_then(|s| s.parse::<usize>().ok()) {
                return n.max(1);
            }
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    })
}

/// Run `f` with [`current_threads`] capped at `threads` on this thread —
/// the coordination hook for callers that already parallelize at a coarser
/// grain (the slot pool pins its workers' inner kernels to 1 thread; the
/// layer-parallel scheduler does the same for within-layer assignment).
pub fn with_threads<R>(threads: usize, f: impl FnOnce() -> R) -> R {
    let prev = THREAD_OVERRIDE.with(|c| c.replace(Some(threads.max(1))));
    let out = f();
    THREAD_OVERRIDE.with(|c| c.set(prev));
    out
}

/// The worker count in effect on this thread: an enclosing [`with_threads`]
/// override, else [`default_threads`].
pub fn current_threads() -> usize {
    THREAD_OVERRIDE.with(|c| c.get()).unwrap_or_else(default_threads)
}

/// The deterministic partitioning contract: split `n` items into **at most**
/// `parts` contiguous strips of `ceil(n / parts')` items each (in index
/// order, where `parts' = parts.clamp(1, n)`). Strip boundaries are a pure
/// function of `(n, parts)` — never of scheduling — which is what makes
/// every pool fan-out in this crate bit-identical to its serial execution.
/// The layer-shard planner ([`crate::coordinator::shard_layers`]) uses the
/// same rule, so "which worker owns what" is one formula everywhere.
pub fn partition(n: usize, parts: usize) -> Vec<Range<usize>> {
    if n == 0 {
        return Vec::new();
    }
    let parts = parts.clamp(1, n);
    let chunk = n.div_ceil(parts);
    let mut out = Vec::with_capacity(parts);
    let mut start = 0usize;
    while start < n {
        let end = (start + chunk).min(n);
        out.push(start..end);
        start = end;
    }
    out
}

/// A scoped-thread worker pool: a thread-count plus the partitioning
/// contract. Construction is free — spawning happens per call, inside a
/// [`std::thread::scope`], so borrowed inputs and outputs need no `'static`
/// lifetime and panics propagate to the caller.
#[derive(Clone, Copy, Debug)]
pub struct Pool {
    threads: usize,
}

impl Pool {
    /// Pool with an explicit worker count (clamped to ≥ 1).
    pub fn new(threads: usize) -> Self {
        Pool { threads: threads.max(1) }
    }

    /// Pool at the thread count in effect on this thread
    /// ([`current_threads`]).
    pub fn current() -> Self {
        Pool::new(current_threads())
    }

    /// Worker count of this pool.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The §12 nesting policy in one place: the thread cap a worker's
    /// *inner* kernels should run under when this pool fans `work_len`
    /// items out. Pin to 1 only when the fan-out is real (≥ 2 items on a
    /// ≥ 2-thread pool — no oversubscription); otherwise keep the caller's
    /// current budget, so a lone work item still gets the kernels' own
    /// parallelism instead of idling the other cores. Callers apply it as
    /// `with_threads(pool.inner_threads(n), …)` inside the worker body.
    pub fn inner_threads(&self, work_len: usize) -> usize {
        if self.threads > 1 && work_len > 1 {
            1
        } else {
            current_threads()
        }
    }

    /// The strips [`Self::run_strips`] would use for `n` items: the
    /// [`partition`] of `n` into `threads` parts, capped so each strip
    /// keeps at least `min_per_strip` items (strips shorter than that are
    /// not worth a thread).
    pub fn strip_ranges(&self, n: usize, min_per_strip: usize) -> Vec<Range<usize>> {
        let parts = self.threads.clamp(1, (n / min_per_strip.max(1)).max(1));
        partition(n, parts)
    }

    /// Fan `n` items out as contiguous strips, one scoped worker per strip,
    /// and return each strip's result **in strip order** (deterministic
    /// regardless of which worker finished first). `f(strip_idx, range)`
    /// must be pure per strip; with one strip it runs inline on the caller.
    pub fn run_strips<R, F>(&self, n: usize, min_per_strip: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize, Range<usize>) -> R + Sync,
    {
        let ranges = self.strip_ranges(n, min_per_strip);
        if ranges.len() <= 1 {
            return ranges.into_iter().enumerate().map(|(i, r)| f(i, r)).collect();
        }
        std::thread::scope(|scope| {
            let handles: Vec<_> = ranges
                .into_iter()
                .enumerate()
                .map(|(i, r)| {
                    let f = &f;
                    scope.spawn(move || f(i, r))
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("exec worker panicked"))
                .collect()
        })
    }

    /// Split `data` (whose length must be a multiple of `group`) into
    /// contiguous strips on group boundaries and hand each worker exclusive
    /// ownership of its strip: `f(first_group_index, strip)`. Strips keep at
    /// least `min_groups` groups each. The split is [`partition`] over the
    /// group count, so writes land exactly where the serial loop would put
    /// them — used by the assignment scan (`group = 1`) and the attention
    /// fan-out (`group = d_model`, one group per activation row).
    pub fn scope_groups_mut<T, F>(&self, data: &mut [T], group: usize, min_groups: usize, f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        assert!(group > 0, "group size must be positive");
        assert_eq!(data.len() % group, 0, "data length must be a multiple of group");
        let n_groups = data.len() / group;
        if n_groups == 0 {
            return;
        }
        let parts = self.threads.clamp(1, (n_groups / min_groups.max(1)).max(1));
        if parts <= 1 {
            f(0, data);
            return;
        }
        let chunk_groups = n_groups.div_ceil(parts);
        std::thread::scope(|scope| {
            for (i, chunk) in data.chunks_mut(chunk_groups * group).enumerate() {
                let f = &f;
                scope.spawn(move || f(i * chunk_groups, chunk));
            }
        });
    }

    /// Run `f(index, &mut item)` over every item, fanning contiguous strips
    /// of items out to workers, and return the results **in item order**.
    /// Each worker owns its items exclusively (`&mut`), so per-item state
    /// (a serving slot + its KV cache) advances with no locks and no
    /// cross-item interference — the slot-pool step of
    /// [`crate::coordinator::Server::serve_continuous`] rides this.
    pub fn map_mut<T, R, F>(&self, items: &mut [T], f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(usize, &mut T) -> R + Sync,
    {
        let n = items.len();
        let parts = self.threads.clamp(1, n.max(1));
        if parts <= 1 {
            return items.iter_mut().enumerate().map(|(i, it)| f(i, it)).collect();
        }
        let chunk = n.div_ceil(parts);
        std::thread::scope(|scope| {
            let handles: Vec<_> = items
                .chunks_mut(chunk)
                .enumerate()
                .map(|(w, ch)| {
                    let f = &f;
                    scope.spawn(move || {
                        ch.iter_mut()
                            .enumerate()
                            .map(|(j, it)| f(w * chunk + j, it))
                            .collect::<Vec<R>>()
                    })
                })
                .collect();
            handles
                .into_iter()
                .flat_map(|h| h.join().expect("exec worker panicked"))
                .collect()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn partition_contract() {
        assert!(partition(0, 4).is_empty());
        assert_eq!(partition(10, 1), vec![0..10]);
        assert_eq!(partition(10, 4), vec![0..3, 3..6, 6..9, 9..10]);
        // parts > n clamps to n one-item strips
        assert_eq!(partition(3, 8), vec![0..1, 1..2, 2..3]);
        // boundaries are a pure function of (n, parts): re-evaluation agrees
        assert_eq!(partition(1000, 7), partition(1000, 7));
        // strips cover [0, n) exactly, in order, without overlap
        let ranges = partition(97, 5);
        let mut next = 0usize;
        for r in &ranges {
            assert_eq!(r.start, next);
            assert!(r.end > r.start);
            next = r.end;
        }
        assert_eq!(next, 97);
    }

    #[test]
    fn run_strips_returns_in_strip_order() {
        let pool = Pool::new(4);
        let out = pool.run_strips(10, 1, |i, r| (i, r.start, r.end));
        assert_eq!(out, vec![(0, 0, 3), (1, 3, 6), (2, 6, 9), (3, 9, 10)]);
        // single strip runs inline
        let calls = AtomicUsize::new(0);
        let out = Pool::new(1).run_strips(5, 1, |_, r| {
            calls.fetch_add(1, Ordering::Relaxed);
            r.len()
        });
        assert_eq!(out, vec![5]);
        assert_eq!(calls.load(Ordering::Relaxed), 1);
        // empty input: no strips, no calls
        assert!(pool.run_strips(0, 1, |_, _| 0usize).is_empty());
    }

    #[test]
    fn strip_ranges_respect_min_per_strip() {
        let pool = Pool::new(8);
        // 10 items at min 4 per strip: at most 2 strips
        assert_eq!(pool.strip_ranges(10, 4).len(), 2);
        assert_eq!(pool.strip_ranges(3, 4), vec![0..3]);
    }

    #[test]
    fn scope_groups_mut_writes_are_disjoint_and_deterministic() {
        let mut serial = vec![0u32; 24];
        Pool::new(1).scope_groups_mut(&mut serial, 3, 1, |g0, chunk| {
            for (j, v) in chunk.iter_mut().enumerate() {
                *v = (g0 * 3 + j) as u32 * 7;
            }
        });
        for threads in [2usize, 3, 5] {
            let mut par = vec![0u32; 24];
            Pool::new(threads).scope_groups_mut(&mut par, 3, 1, |g0, chunk| {
                for (j, v) in chunk.iter_mut().enumerate() {
                    *v = (g0 * 3 + j) as u32 * 7;
                }
            });
            assert_eq!(par, serial, "threads={threads}");
        }
    }

    #[test]
    fn map_mut_preserves_item_order() {
        let mut items: Vec<u64> = (0..13).collect();
        let out = Pool::new(4).map_mut(&mut items, |i, it| {
            *it += 100;
            (i as u64, *it)
        });
        let want: Vec<(u64, u64)> = (0..13u64).map(|i| (i, i + 100)).collect();
        assert_eq!(out, want);
        assert_eq!(items, (100..113u64).collect::<Vec<_>>());
    }

    #[test]
    fn with_threads_overrides_and_restores() {
        let base = current_threads();
        let inner = with_threads(1, || {
            let one = current_threads();
            let nested = with_threads(3, current_threads);
            (one, nested, current_threads())
        });
        assert_eq!(inner, (1, 3, 1));
        assert_eq!(current_threads(), base, "override must restore");
        assert!(default_threads() >= 1);
    }
}
