//! Minimal property-testing helper (proptest is not in the offline crate
//! set). Seeded generators + a `for_cases` driver that reports the failing
//! seed so any counterexample is reproducible with one integer — plus the
//! shared deterministic fixtures ([`synthetic_tinygpt`], [`tiny_pcdvq`])
//! that integration tests and benches build models from without
//! `make artifacts`.
//!
//! Used by `rust/tests/prop_invariants.rs` and `rust/tests/decode_parity.rs`;
//! the python side uses the real `hypothesis` package (available in the
//! image).

use std::sync::Arc;

use crate::codebook::{
    DirectionCodebook, DirectionMethod, MagnitudeCodebook, MagnitudeMethod,
};
use crate::io::{Entry, Pct};
use crate::model::GptModel;
use crate::quant::pcdvq::{Pcdvq, PcdvqConfig};
use crate::rng::Rng;
use crate::tensor::Matrix;

/// A reproducible case generator handed to each property iteration.
pub struct Gen {
    pub rng: Rng,
    pub case_seed: u64,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below(hi - lo + 1)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.uniform_range(lo, hi)
    }

    /// Power of two in `[lo, hi]` (both powers of two).
    pub fn pow2_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo.is_power_of_two() && hi.is_power_of_two());
        let lo_e = lo.trailing_zeros() as usize;
        let hi_e = hi.trailing_zeros() as usize;
        1 << self.usize_in(lo_e, hi_e)
    }

    /// Gaussian matrix with optional outliers (probability `p_outlier` per
    /// entry of a 10-50x spike) — models real weight tails.
    pub fn matrix(&mut self, rows: usize, cols: usize, p_outlier: f64) -> Matrix {
        let mut data = self.rng.normal_vec(rows * cols);
        if p_outlier > 0.0 {
            for x in data.iter_mut() {
                if self.rng.uniform() < p_outlier {
                    *x *= self.f32_in(10.0, 50.0);
                }
            }
        }
        Matrix::from_vec(data, rows, cols)
    }

    pub fn unit_vectors(&mut self, n: usize, k: usize) -> Matrix {
        let mut m = self.matrix(n, k, 0.0);
        for i in 0..n {
            let r = m.row_mut(i);
            let norm: f32 = r.iter().map(|x| x * x).sum::<f32>().sqrt();
            if norm > 0.0 {
                r.iter_mut().for_each(|x| *x /= norm);
            } else {
                r[0] = 1.0;
            }
        }
        m
    }
}

/// Synthetic tinygpt weight container (d=64, 2 layers, 4 heads, ctx=64,
/// byte vocab) written under `$TMP/<subdir>/<tag>.pct` and loaded back —
/// the shared fixture for integration tests and benches, usable without
/// `make artifacts`. Deterministic in `seed`.
pub fn synthetic_tinygpt(subdir: &str, tag: &str, seed: u64) -> GptModel {
    let dir = std::env::temp_dir().join(subdir);
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(format!("{tag}.pct"));
    let mut rng = Rng::new(seed);
    let mut pct = Pct::new();
    let d = 64u64;
    let ff = d * 4;
    let vocab = 256u64;
    let ctx = 64u64;
    let mut add = |name: &str, dims: &[u64], scale: f32| {
        let n: u64 = dims.iter().product();
        let data: Vec<f32> = rng.normal_vec(n as usize).iter().map(|x| x * scale).collect();
        pct.insert(name, Entry::f32(dims, data));
    };
    add("embed.tok", &[vocab, d], 0.05);
    add("embed.pos", &[ctx, d], 0.02);
    for i in 0..2 {
        for nm in ["wq", "wk", "wv", "wo"] {
            add(&format!("layer{i}.attn.{nm}"), &[d, d], 0.12);
        }
        add(&format!("layer{i}.mlp.w1"), &[d, ff], 0.12);
        add(&format!("layer{i}.mlp.w2"), &[ff, d], 0.08);
    }
    add("head.w", &[d, vocab], 0.1);
    // direct inserts only after `add`'s last call (its &mut borrows end);
    // Pct is a BTreeMap, so insertion order is irrelevant, and the norms
    // draw nothing from rng, so the random tensors are unaffected
    for i in 0..2 {
        for nm in ["ln1.g", "ln2.g"] {
            pct.insert(&format!("layer{i}.{nm}"), Entry::f32(&[d], vec![1.0; d as usize]));
        }
        for nm in ["ln1.b", "ln2.b"] {
            pct.insert(&format!("layer{i}.{nm}"), Entry::f32(&[d], vec![0.0; d as usize]));
        }
    }
    pct.insert("final_ln.g", Entry::f32(&[d], vec![1.0; d as usize]));
    pct.insert("final_ln.b", Entry::f32(&[d], vec![0.0; d as usize]));
    for (k, v) in [
        ("vocab", vocab),
        ("d_model", d),
        ("n_layer", 2),
        ("n_head", 4),
        ("d_ff", ff),
        ("ctx", ctx),
    ] {
        pct.insert(&format!("meta.{k}"), Entry::u64(&[1], vec![v]));
    }
    pct.save(&path).unwrap();
    GptModel::load(&path).unwrap()
}

/// A small PCDVQ (a=8, b=2, k=8) built in-process — no codebook disk cache,
/// so it runs on a bare machine. Pairs with [`synthetic_tinygpt`] as the
/// standard fast quantizer for tests and benches.
pub fn tiny_pcdvq() -> Pcdvq {
    let dir = Arc::new(DirectionCodebook::build(DirectionMethod::GreedyE8, 8, 8, 0));
    let mag = Arc::new(MagnitudeCodebook::build(
        MagnitudeMethod::LloydMax,
        2,
        8,
        1.0 - 1e-4,
        0,
    ));
    Pcdvq::new(PcdvqConfig { dir_bits: 8, mag_bits: 2, k: 8, seed: 7 }, dir, mag)
}

/// Run `prop` over `cases` generated cases. On failure, panics with the
/// case seed; re-run a single case via `PCDVQ_PROP_SEED=<seed>`.
pub fn for_cases(cases: usize, base_seed: u64, prop: impl Fn(&mut Gen)) {
    if let Some(seed) = std::env::var("PCDVQ_PROP_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
    {
        let mut g = Gen { rng: Rng::new(seed), case_seed: seed };
        prop(&mut g);
        return;
    }
    for i in 0..cases {
        let case_seed = base_seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(i as u64);
        let mut g = Gen { rng: Rng::new(case_seed), case_seed };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)));
        if let Err(e) = result {
            eprintln!(
                "property failed on case {i} — reproduce with PCDVQ_PROP_SEED={case_seed}"
            );
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generators_in_range() {
        for_cases(20, 42, |g| {
            let n = g.usize_in(1, 10);
            assert!((1..=10).contains(&n));
            let p = g.pow2_in(8, 64);
            assert!(p.is_power_of_two() && (8..=64).contains(&p));
            let m = g.matrix(4, 4, 0.0);
            assert!(m.as_slice().iter().all(|x| x.is_finite()));
            let u = g.unit_vectors(3, 8);
            for i in 0..3 {
                let nrm: f32 = u.row(i).iter().map(|x| x * x).sum::<f32>().sqrt();
                assert!((nrm - 1.0).abs() < 1e-4);
            }
        });
    }

    #[test]
    #[should_panic]
    fn failing_property_panics() {
        for_cases(5, 1, |g| {
            assert!(g.usize_in(0, 10) > 100, "always fails");
        });
    }
}
