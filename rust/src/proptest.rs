//! Minimal property-testing helper (proptest is not in the offline crate
//! set). Seeded generators + a `for_cases` driver that reports the failing
//! seed so any counterexample is reproducible with one integer.
//!
//! Used by `rust/tests/prop_invariants.rs`; the python side uses the real
//! `hypothesis` package (available in the image).

use crate::rng::Rng;
use crate::tensor::Matrix;

/// A reproducible case generator handed to each property iteration.
pub struct Gen {
    pub rng: Rng,
    pub case_seed: u64,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below(hi - lo + 1)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.uniform_range(lo, hi)
    }

    /// Power of two in `[lo, hi]` (both powers of two).
    pub fn pow2_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo.is_power_of_two() && hi.is_power_of_two());
        let lo_e = lo.trailing_zeros() as usize;
        let hi_e = hi.trailing_zeros() as usize;
        1 << self.usize_in(lo_e, hi_e)
    }

    /// Gaussian matrix with optional outliers (probability `p_outlier` per
    /// entry of a 10-50x spike) — models real weight tails.
    pub fn matrix(&mut self, rows: usize, cols: usize, p_outlier: f64) -> Matrix {
        let mut data = self.rng.normal_vec(rows * cols);
        if p_outlier > 0.0 {
            for x in data.iter_mut() {
                if self.rng.uniform() < p_outlier {
                    *x *= self.f32_in(10.0, 50.0);
                }
            }
        }
        Matrix::from_vec(data, rows, cols)
    }

    pub fn unit_vectors(&mut self, n: usize, k: usize) -> Matrix {
        let mut m = self.matrix(n, k, 0.0);
        for i in 0..n {
            let r = m.row_mut(i);
            let norm: f32 = r.iter().map(|x| x * x).sum::<f32>().sqrt();
            if norm > 0.0 {
                r.iter_mut().for_each(|x| *x /= norm);
            } else {
                r[0] = 1.0;
            }
        }
        m
    }
}

/// Run `prop` over `cases` generated cases. On failure, panics with the
/// case seed; re-run a single case via `PCDVQ_PROP_SEED=<seed>`.
pub fn for_cases(cases: usize, base_seed: u64, prop: impl Fn(&mut Gen)) {
    if let Some(seed) = std::env::var("PCDVQ_PROP_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
    {
        let mut g = Gen { rng: Rng::new(seed), case_seed: seed };
        prop(&mut g);
        return;
    }
    for i in 0..cases {
        let case_seed = base_seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(i as u64);
        let mut g = Gen { rng: Rng::new(case_seed), case_seed };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)));
        if let Err(e) = result {
            eprintln!(
                "property failed on case {i} — reproduce with PCDVQ_PROP_SEED={case_seed}"
            );
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generators_in_range() {
        for_cases(20, 42, |g| {
            let n = g.usize_in(1, 10);
            assert!((1..=10).contains(&n));
            let p = g.pow2_in(8, 64);
            assert!(p.is_power_of_two() && (8..=64).contains(&p));
            let m = g.matrix(4, 4, 0.0);
            assert!(m.as_slice().iter().all(|x| x.is_finite()));
            let u = g.unit_vectors(3, 8);
            for i in 0..3 {
                let nrm: f32 = u.row(i).iter().map(|x| x * x).sum::<f32>().sqrt();
                assert!((nrm - 1.0).abs() < 1e-4);
            }
        });
    }

    #[test]
    #[should_panic]
    fn failing_property_panics() {
        for_cases(5, 1, |g| {
            assert!(g.usize_in(0, 10) > 100, "always fails");
        });
    }
}
