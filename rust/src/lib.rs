//! # PCDVQ — Polar Coordinate Decoupled Vector Quantization
//!
//! Full-system reproduction of *“PCDVQ: Enhancing Vector Quantization for
//! Large Language Models via Polar Coordinate Decoupling”* (2025) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the coordinator: the PCDVQ quantizer and every
//!   baseline it is compared against, the DACC codebook constructors, a
//!   layer-parallel quantization scheduler, a batched serving loop, and the
//!   evaluation harness (perplexity + zero-shot proxy tasks).
//! * **L2 (python/compile/model.py)** — the tinygpt forward pass in JAX,
//!   AOT-lowered once to HLO text and executed from Rust via PJRT
//!   ([`runtime`]).
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the compute
//!   hot-spots (direction assignment, fused dequant-matmul, FWHT), lowered
//!   into the same HLO artifacts.
//!
//! Python never runs on the request path: `make artifacts` produces
//! `artifacts/*.hlo.txt` + the trained tinygpt weights, and everything after
//! that is Rust.
//!
//! See `DESIGN.md` for the system inventory and the per-experiment index
//! mapping every paper table/figure to a module and regenerator binary.

pub mod bench;
pub mod cli;
pub mod codebook;
pub mod config;
pub mod coordinator;
pub mod eval;
pub mod exec;
pub mod hadamard;
pub mod io;
pub mod lattice;
pub mod model;
pub mod paper;
pub mod proptest;
pub mod quant;
pub mod rng;
pub mod runtime;
pub mod stats;
pub mod tensor;

/// Vector dimension used throughout the paper (and this reproduction): the
/// weight matrix is reshaped into `k = 8`-dimensional vectors before VQ.
pub const VEC_DIM: usize = 8;
