//! Parser for the `.manifest` files `aot.py` writes next to every HLO
//! artifact: one line per executable input, `<index> <name> <dtype> <dims>`.

use anyhow::{bail, Context, Result};
use std::path::Path;

/// Element type of a manifest entry.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

/// One executable input.
#[derive(Clone, Debug)]
pub struct ManifestEntry {
    pub index: usize,
    pub name: String,
    pub dtype: Dtype,
    pub dims: Vec<usize>,
}

impl ManifestEntry {
    pub fn element_count(&self) -> usize {
        self.dims.iter().product()
    }
}

/// Ordered input list of one AOT executable.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub entries: Vec<ManifestEntry>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Self> {
        let mut entries = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let parts: Vec<&str> = line.split_whitespace().collect();
            if parts.len() != 4 {
                bail!("manifest line {}: expected 4 fields, got {line:?}", lineno + 1);
            }
            let index: usize = parts[0].parse().context("bad index")?;
            let dtype = match parts[2] {
                "float32" => Dtype::F32,
                "int32" => Dtype::I32,
                other => bail!("manifest line {}: unsupported dtype {other}", lineno + 1),
            };
            let dims: Vec<usize> = if parts[3] == "scalar" {
                vec![]
            } else {
                parts[3]
                    .split(',')
                    .map(|d| d.parse().context("bad dim"))
                    .collect::<Result<_>>()?
            };
            if index != entries.len() {
                bail!("manifest line {}: non-contiguous index {index}", lineno + 1);
            }
            entries.push(ManifestEntry { index, name: parts[1].to_string(), dtype, dims });
        }
        if entries.is_empty() {
            bail!("empty manifest");
        }
        Ok(Manifest { entries })
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::parse(&text)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Position of a named input.
    pub fn position(&self, name: &str) -> Result<usize> {
        self.entries
            .iter()
            .position(|e| e.name == name)
            .with_context(|| format!("manifest has no input named '{name}'"))
    }

    pub fn entry(&self, name: &str) -> Result<&ManifestEntry> {
        Ok(&self.entries[self.position(name)?])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
0 embed.tok float32 256,128
1 tokens int32 8,128
2 scale float32 scalar
";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.len(), 3);
        assert_eq!(m.entries[0].dims, vec![256, 128]);
        assert_eq!(m.entries[0].element_count(), 256 * 128);
        assert_eq!(m.entries[1].dtype, Dtype::I32);
        assert_eq!(m.entries[2].dims, Vec::<usize>::new());
        assert_eq!(m.position("tokens").unwrap(), 1);
    }

    #[test]
    fn rejects_gap_in_indices() {
        assert!(Manifest::parse("0 a float32 1\n2 b float32 1\n").is_err());
    }

    #[test]
    fn rejects_unknown_dtype() {
        assert!(Manifest::parse("0 a float64 1\n").is_err());
    }

    #[test]
    fn rejects_empty() {
        assert!(Manifest::parse("\n\n").is_err());
    }

    #[test]
    fn missing_name_errors() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.position("nope").is_err());
    }
}
