//! PJRT FFI seam — a compile-time stand-in for the `xla` crate.
//!
//! The real PJRT bindings (the `xla` crate wrapping `xla_extension`) are not
//! in the offline crate set, so this module provides the exact API surface
//! [`super::engine`] consumes. Every entry point fails at runtime with a
//! clear message; the type structure is identical, so swapping the real
//! bindings back in is a one-line change in `runtime/mod.rs` (replace
//! `pub mod xla;` + `use super::xla` with the external crate).
//!
//! Everything that does *not* need PJRT — quantization, packed-code
//! artifacts, the host codes-resident serving path, eval over
//! [`crate::model::HostForward`] — runs without this backend. Only the AOT
//! HLO executables (`fwd_fp_*`, `fwd_q_*`, Pallas kernel parity) require it,
//! and the integration tests skip cleanly when `artifacts/` is absent.

#![allow(dead_code)]

use anyhow::{bail, Result};

const UNAVAILABLE: &str =
    "PJRT backend not available in this build (the `xla` crate is not in the \
     offline crate set); host paths (codes-resident serving, quantization, \
     eval via HostForward) do not need it";

/// Stand-in for `xla::PjRtClient`.
#[derive(Clone, Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        bail!(UNAVAILABLE)
    }

    pub fn platform_name(&self) -> String {
        "unavailable".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        bail!(UNAVAILABLE)
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        bail!(UNAVAILABLE)
    }
}

/// Stand-in for `xla::HloModuleProto`.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        bail!(UNAVAILABLE)
    }
}

/// Stand-in for `xla::XlaComputation`.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// Stand-in for `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        bail!(UNAVAILABLE)
    }

    pub fn execute_b<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        bail!(UNAVAILABLE)
    }
}

/// Stand-in for `xla::PjRtBuffer`.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        bail!(UNAVAILABLE)
    }
}

/// Stand-in for `xla::Literal`.
pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        bail!(UNAVAILABLE)
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        bail!(UNAVAILABLE)
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        bail!(UNAVAILABLE)
    }
}
