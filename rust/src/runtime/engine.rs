//! PJRT engine: compile HLO-text artifacts, execute them with host data or
//! device-resident bound parameters.

use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

use super::manifest::{Dtype, Manifest};
use super::xla;

/// Host-side input value for an executable call.
#[derive(Clone, Debug)]
pub enum Input {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

impl Input {
    pub fn dims(&self) -> &[usize] {
        match self {
            Input::F32(_, d) | Input::I32(_, d) => d,
        }
    }

    fn dtype(&self) -> Dtype {
        match self {
            Input::F32(..) => Dtype::F32,
            Input::I32(..) => Dtype::I32,
        }
    }
}

/// Owns the PJRT client. One per process; executables borrow it via clones of
/// the underlying client handle (the xla crate's client is ref-counted).
pub struct Engine {
    client: xla::PjRtClient,
}

impl Engine {
    /// CPU PJRT client.
    pub fn new() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load `<base>.hlo.txt` + `<base>.manifest` and compile.
    pub fn load(&self, base: impl AsRef<Path>) -> Result<Executable> {
        let base = base.as_ref();
        let hlo_path: PathBuf = PathBuf::from(format!("{}.hlo.txt", base.display()));
        let man_path: PathBuf = PathBuf::from(format!("{}.manifest", base.display()));
        let manifest = Manifest::load(&man_path)?;
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {}", hlo_path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", hlo_path.display()))?;
        Ok(Executable {
            client: self.client.clone(),
            exe,
            manifest,
            name: base.file_name().map(|s| s.to_string_lossy().into_owned()).unwrap_or_default(),
        })
    }

    /// Upload a host input to the device.
    pub fn upload(&self, input: &Input) -> Result<xla::PjRtBuffer> {
        match input {
            Input::F32(data, dims) => self
                .client
                .buffer_from_host_buffer(data, dims, None)
                .context("uploading f32 buffer"),
            Input::I32(data, dims) => self
                .client
                .buffer_from_host_buffer(data, dims, None)
                .context("uploading i32 buffer"),
        }
    }
}

/// A compiled AOT artifact plus its ordered input manifest.
pub struct Executable {
    client: xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
    pub manifest: Manifest,
    pub name: String,
}

impl Executable {
    /// Validate inputs against the manifest (count, dtype, shape).
    fn check_inputs(&self, inputs: &[Input]) -> Result<()> {
        if inputs.len() != self.manifest.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.name,
                self.manifest.len(),
                inputs.len()
            );
        }
        for (e, inp) in self.manifest.entries.iter().zip(inputs) {
            if e.dtype != inp.dtype() {
                bail!("{}: input '{}' dtype mismatch", self.name, e.name);
            }
            if e.dims != inp.dims() {
                bail!(
                    "{}: input '{}' shape mismatch: manifest {:?} vs {:?}",
                    self.name,
                    e.name,
                    e.dims,
                    inp.dims()
                );
            }
        }
        Ok(())
    }

    /// Execute with host inputs; returns the output literals (the AOT graphs
    /// return 1-tuples — see gen path — which this unwraps).
    pub fn run(&self, inputs: &[Input]) -> Result<Vec<xla::Literal>> {
        self.check_inputs(inputs)?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|inp| -> Result<xla::Literal> {
                let dims: Vec<i64> = inp.dims().iter().map(|&d| d as i64).collect();
                match inp {
                    Input::F32(data, _) => {
                        Ok(xla::Literal::vec1(data).reshape(&dims)?)
                    }
                    Input::I32(data, _) => {
                        Ok(xla::Literal::vec1(data).reshape(&dims)?)
                    }
                }
            })
            .collect::<Result<_>>()?;
        let out = self.exe.execute::<xla::Literal>(&literals)?;
        let lit = out[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    /// Convenience: run and read the first output as f32.
    pub fn run_f32(&self, inputs: &[Input]) -> Result<Vec<f32>> {
        let outs = self.run(inputs)?;
        Ok(outs[0].to_vec::<f32>()?)
    }

    /// Convenience: run and read the first output as i32.
    pub fn run_i32(&self, inputs: &[Input]) -> Result<Vec<i32>> {
        let outs = self.run(inputs)?;
        Ok(outs[0].to_vec::<i32>()?)
    }

    /// Bind all inputs *except* the trailing `n_varying` ones as
    /// device-resident buffers (weights, codebooks). The per-request path
    /// then uploads only the varying inputs (tokens).
    pub fn bind(self, fixed: &[Input], n_varying: usize) -> Result<BoundExecutable> {
        if fixed.len() + n_varying != self.manifest.len() {
            bail!(
                "{}: bind expected {} fixed inputs, got {}",
                self.name,
                self.manifest.len() - n_varying,
                fixed.len()
            );
        }
        let mut buffers = Vec::with_capacity(fixed.len());
        for (e, inp) in self.manifest.entries.iter().zip(fixed) {
            if e.dims != inp.dims() || e.dtype != inp.dtype() {
                bail!("{}: bound input '{}' mismatch", self.name, e.name);
            }
            let buf = match inp {
                Input::F32(data, dims) => {
                    self.client.buffer_from_host_buffer(data, dims, None)?
                }
                Input::I32(data, dims) => {
                    self.client.buffer_from_host_buffer(data, dims, None)?
                }
            };
            buffers.push(buf);
        }
        Ok(BoundExecutable { inner: self, fixed: buffers })
    }
}

/// An executable with its leading parameters resident on device.
pub struct BoundExecutable {
    inner: Executable,
    fixed: Vec<xla::PjRtBuffer>,
}

impl BoundExecutable {
    pub fn name(&self) -> &str {
        &self.inner.name
    }

    /// Execute with the bound parameters + freshly-uploaded varying inputs.
    pub fn run(&self, varying: &[Input]) -> Result<Vec<xla::Literal>> {
        let mut args: Vec<&xla::PjRtBuffer> = self.fixed.iter().collect();
        let uploaded: Vec<xla::PjRtBuffer> = varying
            .iter()
            .map(|inp| match inp {
                Input::F32(data, dims) => {
                    self.inner.client.buffer_from_host_buffer(data, dims, None)
                }
                Input::I32(data, dims) => {
                    self.inner.client.buffer_from_host_buffer(data, dims, None)
                }
            })
            .collect::<std::result::Result<_, _>>()?;
        args.extend(uploaded.iter());
        let out = self.inner.exe.execute_b::<&xla::PjRtBuffer>(&args)?;
        let lit = out[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    /// Run and read the first output as f32.
    pub fn run_f32(&self, varying: &[Input]) -> Result<Vec<f32>> {
        let outs = self.run(varying)?;
        Ok(outs[0].to_vec::<f32>()?)
    }
}
