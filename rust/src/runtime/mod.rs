//! PJRT runtime — loads the AOT HLO-text artifacts and executes them.
//!
//! The bridge between L3 (this crate) and L2/L1 (the JAX/Pallas graphs):
//! `Engine` owns a CPU PJRT client; `Executable` pairs a compiled
//! `PjRtLoadedExecutable` with its input `Manifest` (the ordered input list
//! `aot.py` wrote next to the HLO). Device-resident parameter caching keeps
//! the weight upload off the per-request path ([`Executable::bind`]).
//!
//! Interchange is HLO **text** — see /opt/xla-example/README.md for why
//! serialized protos from jax ≥ 0.5 cannot be used with xla_extension 0.5.1.

mod engine;
mod manifest;
pub mod xla;

pub use engine::{BoundExecutable, Engine, Executable, Input};
pub use manifest::{Manifest, ManifestEntry};
