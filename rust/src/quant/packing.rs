//! Bit-packing of quantization indices.
//!
//! Paper §A.3: a quantized vector is "a index of direction and a index of
//! magnitude" — `a` bits and `b` bits spliced together (Eq. 8). We pack the
//! `(a+b)`-bit records contiguously into a `u64` stream, LSB-first, which is
//! also the layout the fused dequant kernels consume: the Pallas kernel (L1)
//! and the host blocked kernel
//! ([`crate::quant::QuantizedWeight::matmul_from_codes`]).
//!
//! ## Bit layout
//!
//! Record `i` of a `w`-bit stream occupies the bit range `[i·w, (i+1)·w)` of
//! the stream, counted LSB-first inside each `u64` word; records may
//! straddle a word boundary (low part in the high bits of `words[j]`, high
//! part in the low bits of `words[j+1]`):
//!
//! ```text
//! stream bit   0         w         2w        3w        ...        64 | 64+…
//!              ├─ rec 0 ─┼─ rec 1 ─┼─ rec 2 ─┼─   ...   ──┬─ rec j ─┼────
//! words[0]     [ lsb ──────────────────────────────────── │ lo bits ] msb
//! words[1]                                  msb … [ hi bits of rec j ] lsb
//! ```
//!
//! Supported widths are `1..=63`; the tail of the last word is zero padding
//! (at most 63 bits — the source of the ≤ 8-byte per-stream slack that
//! [`crate::paper::verify_codes_resident`] allows when it checks resident
//! bytes against [`PackedStreams::payload_bits`]).
//!
//! Access paths, fastest first:
//!
//! * [`PackedIndices::unpack_range_into`] — sequential bulk unpack of a
//!   record range (one running bit cursor); the blocked matmul kernel
//!   decodes a whole column block per call.
//! * [`PackedIndices::get`] / [`PackedStreams::records_into`] — random
//!   access to a single record (tuple); the scalar reference kernel and the
//!   persistence layer use these.

/// A packed stream of fixed-width bit records.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedIndices {
    words: Vec<u64>,
    /// Bits per record.
    pub width: u32,
    /// Number of records.
    pub len: usize,
}

impl PackedIndices {
    /// Pack `values` (each `< 2^width`) into the stream.
    pub fn pack(values: &[u64], width: u32) -> Self {
        assert!(width >= 1 && width <= 63, "width must be in 1..=63");
        let total_bits = values.len() as u64 * width as u64;
        let nwords = total_bits.div_ceil(64) as usize;
        let mut words = vec![0u64; nwords];
        let mut bitpos = 0u64;
        for &v in values {
            debug_assert!(
                width == 63 || v < (1u64 << width),
                "value {v} does not fit in {width} bits"
            );
            let word = (bitpos / 64) as usize;
            let off = (bitpos % 64) as u32;
            words[word] |= v << off;
            if off + width > 64 {
                words[word + 1] |= v >> (64 - off);
            }
            bitpos += width as u64;
        }
        PackedIndices { words, width, len: values.len() }
    }

    /// Read record `i`.
    #[inline]
    pub fn get(&self, i: usize) -> u64 {
        debug_assert!(i < self.len);
        let bitpos = i as u64 * self.width as u64;
        let word = (bitpos / 64) as usize;
        let off = (bitpos % 64) as u32;
        let mask = if self.width == 63 {
            (1u64 << 63) - 1
        } else {
            (1u64 << self.width) - 1
        };
        let mut v = self.words[word] >> off;
        if off + self.width > 64 {
            v |= self.words[word + 1] << (64 - off);
        }
        v & mask
    }

    /// Unpack records `start .. start + out.len()` into `out`.
    ///
    /// Equivalent to `out[j] = self.get(start + j)` but runs a single
    /// sequential bit cursor over the word array — the bulk-decode path of
    /// the blocked matmul kernel
    /// ([`crate::quant::QuantizedWeight::matmul_from_codes`]), which unpacks
    /// one column block of records per call instead of re-deriving the
    /// word/offset split per record.
    pub fn unpack_range_into(&self, start: usize, out: &mut [u64]) {
        assert!(
            start + out.len() <= self.len,
            "unpack_range_into: range {}..{} exceeds {} records",
            start,
            start + out.len(),
            self.len
        );
        let width = self.width;
        let mask = if width == 63 {
            (1u64 << 63) - 1
        } else {
            (1u64 << width) - 1
        };
        let mut bitpos = start as u64 * width as u64;
        for o in out.iter_mut() {
            let word = (bitpos >> 6) as usize;
            let off = (bitpos & 63) as u32;
            let mut v = self.words[word] >> off;
            if off + width > 64 {
                v |= self.words[word + 1] << (64 - off);
            }
            *o = v & mask;
            bitpos += width as u64;
        }
    }

    /// Unpack the whole stream.
    pub fn unpack(&self) -> Vec<u64> {
        (0..self.len).map(|i| self.get(i)).collect()
    }

    /// Exact payload size in bits (`len * width`).
    pub fn payload_bits(&self) -> u64 {
        self.len as u64 * self.width as u64
    }

    /// Raw words (for persistence / device upload).
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Rebuild from raw words.
    pub fn from_words(words: Vec<u64>, width: u32, len: usize) -> Self {
        assert!(words.len() as u64 * 64 >= len as u64 * width as u64);
        PackedIndices { words, width, len }
    }
}

/// Parallel packed streams over the same record axis — the multi-stream form
/// of a compressed weight. PCDVQ stores its direction indices (`a` bits) and
/// magnitude indices (`b` bits) as two parallel streams (record `i` of every
/// stream describes k-vector `i`); single-codebook methods use one stream.
/// Splitting by stream keeps each index kind contiguously packed, which is
/// what both the serving artifact (`fwd_q` wants separate `dir_idx`/`mag_idx`
/// gathers) and the host fused kernel consume.
///
/// ## Invariants
///
/// * at least one stream, and every stream has the **same record count**
///   (checked at construction — record `i` of every stream together forms
///   one decodable tuple for [`crate::quant::CodeDecoder::decode_into`]);
/// * stream widths are independent (each in `1..=63` per
///   [`PackedIndices::pack`]);
/// * record order is the row-major flattening of the weight into
///   `k`-vectors: record `i` covers flat elements `[i·k, (i+1)·k)` of the
///   `rows×cols` matrix — the layout contract the blocked kernel's
///   tile→segment mapping relies on (see `DESIGN.md` §11).
#[derive(Clone, Debug, PartialEq)]
pub struct PackedStreams {
    streams: Vec<PackedIndices>,
}

impl PackedStreams {
    /// Bundle parallel streams; all must have the same record count.
    pub fn new(streams: Vec<PackedIndices>) -> Self {
        assert!(!streams.is_empty(), "at least one stream required");
        let len = streams[0].len;
        assert!(
            streams.iter().all(|s| s.len == len),
            "stream record counts disagree"
        );
        PackedStreams { streams }
    }

    /// A single-stream bundle.
    pub fn single(codes: PackedIndices) -> Self {
        Self::new(vec![codes])
    }

    /// Records per stream.
    pub fn len(&self) -> usize {
        self.streams[0].len
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn n_streams(&self) -> usize {
        self.streams.len()
    }

    /// Borrow stream `s`.
    pub fn stream(&self, s: usize) -> &PackedIndices {
        &self.streams[s]
    }

    pub fn streams(&self) -> &[PackedIndices] {
        &self.streams
    }

    /// Read record `i` of every stream into `out` (len = `n_streams`).
    #[inline]
    pub fn records_into(&self, i: usize, out: &mut [u64]) {
        debug_assert_eq!(out.len(), self.streams.len());
        for (o, s) in out.iter_mut().zip(&self.streams) {
            *o = s.get(i);
        }
    }

    /// Exact payload bits across all streams.
    pub fn payload_bits(&self) -> u64 {
        self.streams.iter().map(|s| s.payload_bits()).sum()
    }

    /// Total bits per record across streams (the per-vector record width).
    pub fn record_bits(&self) -> u32 {
        self.streams.iter().map(|s| s.width).sum()
    }
}

/// Splice a (direction, magnitude) index pair into one record: direction in
/// the low `a` bits, magnitude above it (Eq. 8).
#[inline]
pub fn splice(dir_idx: u32, mag_idx: u32, a: u32) -> u64 {
    (dir_idx as u64) | ((mag_idx as u64) << a)
}

/// Inverse of [`splice`].
#[inline]
pub fn unsplice(record: u64, a: u32) -> (u32, u32) {
    let dir = (record & ((1u64 << a) - 1)) as u32;
    let mag = (record >> a) as u32;
    (dir, mag)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn pack_unpack_round_trip_various_widths() {
        let mut rng = Rng::new(5);
        for width in [1u32, 2, 3, 7, 8, 13, 16, 17, 31, 33, 63] {
            let mask = if width == 63 { (1u64 << 63) - 1 } else { (1u64 << width) - 1 };
            let values: Vec<u64> = (0..1000).map(|_| rng.next_u64() & mask).collect();
            let packed = PackedIndices::pack(&values, width);
            assert_eq!(packed.unpack(), values, "width={width}");
            assert_eq!(packed.payload_bits(), 1000 * width as u64);
        }
    }

    #[test]
    fn unpack_range_matches_random_access() {
        let mut rng = Rng::new(11);
        for width in [1u32, 3, 13, 17, 31, 63] {
            let mask = if width == 63 { (1u64 << 63) - 1 } else { (1u64 << width) - 1 };
            let values: Vec<u64> = (0..513).map(|_| rng.next_u64() & mask).collect();
            let packed = PackedIndices::pack(&values, width);
            // ranges that start mid-word, straddle words, and hit the tail
            for (start, len) in [(0usize, 513usize), (1, 64), (7, 100), (500, 13), (513, 0)] {
                let mut out = vec![0u64; len];
                packed.unpack_range_into(start, &mut out);
                assert_eq!(out, values[start..start + len], "width={width} start={start}");
            }
        }
    }

    #[test]
    #[should_panic]
    fn unpack_range_rejects_overrun() {
        let packed = PackedIndices::pack(&[1, 2, 3], 4);
        let mut out = vec![0u64; 2];
        packed.unpack_range_into(2, &mut out);
    }

    #[test]
    fn random_access_matches_unpack() {
        let mut rng = Rng::new(6);
        let values: Vec<u64> = (0..257).map(|_| rng.next_u64() & 0xFFFF).collect();
        let packed = PackedIndices::pack(&values, 16);
        for (i, &v) in values.iter().enumerate() {
            assert_eq!(packed.get(i), v);
        }
    }

    #[test]
    fn splice_unsplice_round_trip() {
        for a in [2u32, 8, 14, 16] {
            for dir in [0u32, 1, (1 << a) - 1] {
                for mag in [0u32, 1, 3] {
                    let rec = splice(dir, mag, a);
                    assert_eq!(unsplice(rec, a), (dir, mag));
                }
            }
        }
    }

    #[test]
    fn paper_bit_accounting() {
        // §A.3: k=8, a=14, b=2 → 2.0 bpw; a=16, b=2 → 2.125 bpw.
        let n_vectors = 1024usize;
        let k = 8;
        // NOTE: the paper's §A.3 states a=16,b=2 yet bpw=2.125; (16+2)/8 is
        // 2.25, so the consistent setting is a=15 (see DESIGN.md §6).
        for (a, b, expect) in [(14u32, 2u32, 2.0f64), (15, 2, 2.125)] {
            let values = vec![0u64; n_vectors];
            let packed = PackedIndices::pack(&values, a + b);
            let bpw = packed.payload_bits() as f64 / (n_vectors * k) as f64;
            assert!((bpw - expect).abs() < 1e-12);
        }
    }

    #[test]
    fn packed_streams_parallel_access() {
        let mut rng = Rng::new(9);
        let dir: Vec<u64> = (0..500).map(|_| rng.next_u64() & 0x3FFF).collect();
        let mag: Vec<u64> = (0..500).map(|_| rng.next_u64() & 0x3).collect();
        let s = PackedStreams::new(vec![
            PackedIndices::pack(&dir, 14),
            PackedIndices::pack(&mag, 2),
        ]);
        assert_eq!(s.len(), 500);
        assert_eq!(s.n_streams(), 2);
        assert_eq!(s.record_bits(), 16);
        assert_eq!(s.payload_bits(), 500 * 16);
        let mut rec = [0u64; 2];
        for i in 0..500 {
            s.records_into(i, &mut rec);
            assert_eq!(rec, [dir[i], mag[i]]);
        }
    }

    #[test]
    #[should_panic]
    fn packed_streams_reject_length_mismatch() {
        PackedStreams::new(vec![
            PackedIndices::pack(&[1, 2, 3], 4),
            PackedIndices::pack(&[1, 2], 4),
        ]);
    }

    #[test]
    fn from_words_round_trip() {
        let values: Vec<u64> = (0..100).map(|i| i % 16).collect();
        let p = PackedIndices::pack(&values, 4);
        let q = PackedIndices::from_words(p.words().to_vec(), 4, 100);
        assert_eq!(p, q);
    }
}
