//! GPTQ-like baseline: sequential error-compensated scalar quantization.
//!
//! True GPTQ minimizes `‖XW − XŴ‖²` using the Hessian `H = XᵀX` of real
//! calibration activations. Our substrate has no LLaMA calibration set, so —
//! per the DESIGN.md §3 substitution table — we run the *exact GPTQ update
//! equations* (quantize one input dim at a time, propagate the weighted
//! residual into the not-yet-quantized dims through `H^{-1}`) against a
//! synthetic AR(1)-correlated Hessian `H[i,j] = ρ^{|i-j|}`, which models the
//! smooth feature correlations GPTQ exploits. With ρ→0 this degenerates to
//! plain RTN, which is the identity the unit tests pin down.
//!
//! Like [`crate::quant::sq`], the artifact is a packed stream of offset
//! codes + per-column scales (the error feedback happens at quantization
//! time; the stored representation is plain uniform SQ) — so it serves
//! through the same [`ScalarDecoder`] grid LUT in the blocked host kernel
//! ([`crate::quant::QuantizedWeight::matmul_from_codes`]).

use std::sync::Arc;

use crate::quant::packing::{PackedIndices, PackedStreams};
use crate::quant::sq::ScalarDecoder;
use crate::quant::{QuantizedWeight, Quantizer};
use crate::tensor::Matrix;

/// GPTQ-like quantizer.
#[derive(Clone, Debug)]
pub struct GptqLike {
    pub bits: u32,
    /// AR(1) correlation of the synthetic Hessian.
    pub rho: f64,
}

impl GptqLike {
    pub fn new(bits: u32) -> Self {
        GptqLike { bits, rho: 0.3 }
    }
}

impl Quantizer for GptqLike {
    fn name(&self) -> String {
        format!("gptq-like{}", self.bits)
    }

    /// Quantize `w` (p×q). GPTQ walks the *input* dimension; our convention
    /// stores weights as (in, out) = (p rows, q cols), so we walk rows.
    fn quantize(&self, w: &Matrix) -> QuantizedWeight {
        let p = w.rows();
        let q = w.cols();
        let qmax = ((1i64 << (self.bits - 1)) - 1) as f32;
        let qmin = -(1i64 << (self.bits - 1));

        // Per-column symmetric scale from max|w| (as in GPTQ's grid init).
        let scales: Vec<f32> = (0..q)
            .map(|j| {
                let maxabs = w.col(j).iter().fold(0.0f32, |m, &x| m.max(x.abs()));
                if maxabs > 0.0 {
                    maxabs / qmax
                } else {
                    1.0
                }
            })
            .collect();

        // For the AR(1) Hessian, the Cholesky of H^{-1} has a closed-form
        // bidiagonal structure; the GPTQ update "err / L[i][i] times row of
        // L" reduces to propagating the scaled error to the *next* row only:
        //   w[i+1, :] += err[i, :] * rho
        // (derivable from H^{-1} being tridiagonal for AR(1)).
        let rho = self.rho as f32;
        let mut work = w.clone();
        let mut records = vec![0u64; p * q];
        for i in 0..p {
            // quantize row i
            for j in 0..q {
                let s = scales[j];
                let x = work.get(i, j);
                let qv = (x / s).round().clamp(-(qmax + 1.0), qmax);
                records[i * q + j] = (qv as i64 - qmin) as u64;
                let err = x - qv * s;
                // error feedback into the next (not yet quantized) row
                if i + 1 < p {
                    let nxt = work.get(i + 1, j) + rho * err;
                    work.set(i + 1, j, nxt);
                }
            }
        }
        let codes = PackedStreams::single(PackedIndices::pack(&records, self.bits));
        QuantizedWeight::new(
            self.name(),
            p,
            q,
            codes,
            Arc::new(ScalarDecoder::new(self.bits)),
            scales,
            None,
        )
    }

    fn bits_per_weight(&self) -> f64 {
        self.bits as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::sq::Rtn;
    use crate::rng::Rng;

    fn gaussian(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::from_vec(rng.normal_vec(rows * cols), rows, cols)
    }

    /// Weight whose rows are AR(1)-correlated — the structure the synthetic
    /// Hessian models.
    fn correlated(rows: usize, cols: usize, rho: f32, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let mut m = Matrix::zeros(rows, cols);
        for j in 0..cols {
            let mut prev = rng.normal() as f32;
            for i in 0..rows {
                let e = rng.normal() as f32;
                let x = rho * prev + (1.0 - rho * rho).sqrt() * e;
                m.set(i, j, x);
                prev = x;
            }
        }
        m
    }

    #[test]
    fn rho_zero_equals_rtn() {
        let w = gaussian(32, 8, 1);
        let g = GptqLike { bits: 3, rho: 0.0 }.quantize(&w);
        let r = Rtn::new(3).quantize(&w);
        assert_eq!(g.dequantize().as_slice(), r.dequantize().as_slice());
    }

    #[test]
    fn helps_on_correlated_weights_in_hessian_metric() {
        // On AR(1)-structured weights, error feedback should reduce the
        // *correlated-input* loss ‖X(w−ŵ)‖² (X with AR(1) rows), which is
        // what GPTQ optimizes — measure with a sampled X.
        let rho = 0.6f32;
        let w = correlated(128, 16, rho, 2);
        let g = GptqLike { bits: 2, rho: rho as f64 }.quantize(&w);
        let r = Rtn::new(2).quantize(&w);
        let g_deq = g.dequantize();
        let r_deq = r.dequantize();
        let mut rng = Rng::new(3);
        // sample AR(1)-correlated activations
        let nx = 200;
        let mut x = Matrix::zeros(nx, 128);
        for i in 0..nx {
            let mut prev = rng.normal() as f32;
            for t in 0..128 {
                let e = rng.normal() as f32;
                let v = rho * prev + (1.0 - rho * rho).sqrt() * e;
                x.set(i, t, v);
                prev = v;
            }
        }
        let act_err = |deq: &Matrix| {
            let mut s = 0.0f64;
            for i in 0..nx {
                for j in 0..16 {
                    let mut d = 0.0f32;
                    for t in 0..128 {
                        d += x.get(i, t) * (w.get(t, j) - deq.get(t, j));
                    }
                    s += (d as f64) * (d as f64);
                }
            }
            s
        };
        let eg = act_err(&g_deq);
        let er = act_err(&r_deq);
        assert!(eg < er * 1.05, "gptq-like {eg} should not lose to rtn {er}");
    }

    #[test]
    fn output_finite_and_bounded() {
        let w = gaussian(64, 8, 4);
        let g = GptqLike::new(2).quantize(&w);
        let maxabs = w.as_slice().iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        for &v in g.dequantize().as_slice() {
            assert!(v.is_finite());
            assert!(v.abs() <= maxabs * 2.0);
        }
    }
}
