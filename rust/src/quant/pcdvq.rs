//! PCDVQ — the paper's quantizer (§3.2).
//!
//! Pipeline per weight matrix `W ∈ R^{p×q}`:
//!
//! 1. **Standard Gaussian regularization** (§3.2.1): randomized Hadamard
//!    transform per column + per-column scale `s_j = ‖x_j‖/√p`, making
//!    entries ~N(0,1).
//! 2. **Polar coordinate decoupling** (§3.2.2): reshape to `k=8`-vectors;
//!    each vector `v` splits into direction `v/‖v‖` and magnitude `‖v‖`.
//! 3. **DACC assignment** (§3.2.3): direction → max-cosine index into the
//!    greedy-E8 codebook (`a` bits), magnitude → nearest Lloyd-Max level
//!    (`b` bits).
//! 4. **Packing** (§A.3 / Eq. 8): direction and magnitude indices pack into
//!    two parallel bit streams of an `(a+b)`-bit-per-vector artifact;
//!    bpw = `(a+b)/k`.
//!
//! The emitted [`QuantizedWeight`] is the real compressed representation
//! (packed code streams + per-column scales + RHT seed + `Arc` references to
//! the two shared DACC codebooks) — storage accounting and the serving
//! artifact are honest, and dequantization is an explicit, lazy operation.

use std::sync::{Arc, OnceLock};

use crate::codebook::{DirectionCodebook, MagnitudeCodebook};
use crate::hadamard::{regularize, RandomizedHadamard};
use crate::quant::assign::assign_into;
use crate::quant::packing::{PackedIndices, PackedStreams};
use crate::quant::{CodeDecoder, DecodeLut, QuantizedWeight, Quantizer};
use crate::tensor::Matrix;

/// Joint-index cap for the pre-expanded decode LUT: past this many
/// `(direction, magnitude)` entries the expansion (`entries · k` f32) stops
/// being cache-friendly and the [`DaccDecoder`]'s
/// [`CodeDecoder::decode_lut`] declines, sending the blocked kernel to its
/// per-record fallback. `2^18` covers the paper's largest setting (a = 15,
/// b = 2 → `2^17` joint entries, a 4-MiB table shared model-wide) with
/// headroom.
const MAX_LUT_ENTRIES: usize = 1 << 18;

/// Configuration of the PCDVQ quantizer.
#[derive(Clone, Debug)]
pub struct PcdvqConfig {
    /// Direction index bits `a` (paper: 14 for 2.0 bpw, 16 for 2.125 bpw).
    pub dir_bits: u32,
    /// Magnitude index bits `b` (paper: fixed to 2).
    pub mag_bits: u32,
    /// Vector dimension `k` (paper: 8).
    pub k: usize,
    /// Seed for the per-layer RHT sign diagonals.
    pub seed: u64,
}

impl PcdvqConfig {
    /// The paper's 2.0-bpw configuration (a=14, b=2, k=8).
    pub fn bpw2() -> Self {
        PcdvqConfig { dir_bits: 14, mag_bits: 2, k: 8, seed: 0x9CD_0E8 }
    }

    /// The paper's 2.125-bpw configuration.
    ///
    /// §A.3 says `a = 16, b = 2` *and* `bpw = (a+b)/k = 2.125`, which is
    /// arithmetically inconsistent ((16+2)/8 = 2.25). We take the stated
    /// bpw as ground truth and use `a = 15` so (15+2)/8 = 2.125 exactly;
    /// see DESIGN.md §6.
    pub fn bpw2_125() -> Self {
        PcdvqConfig { dir_bits: 15, mag_bits: 2, k: 8, seed: 0x9CD_0E8 }
    }

    pub fn bits_per_weight(&self) -> f64 {
        (self.dir_bits + self.mag_bits) as f64 / self.k as f64
    }
}

/// The DACC decoder: stream 0 gathers a unit direction, stream 1 a
/// Lloyd-Max magnitude level; the decoded vector is their product. One
/// decoder instance (and its two codebooks) serves the entire model — and
/// so does its lazily expanded direction×magnitude decode LUT
/// ([`CodeDecoder::decode_lut`]), which the blocked host kernel
/// ([`QuantizedWeight::matmul_from_codes`]) gathers from instead of
/// multiplying per record.
pub struct DaccDecoder {
    pub dir: Arc<DirectionCodebook>,
    pub mag: Arc<MagnitudeCodebook>,
    /// FNV-1a fingerprint of both codebooks' contents — part of
    /// [`CodeDecoder::spec`], so differently-built codebook pairs (e.g.
    /// different seeds) never dedup as one in the measured accounting.
    fingerprint: u64,
    /// Lazily pre-expanded direction×magnitude product table for the
    /// blocked kernel — derived state, built at most once per decoder (and
    /// the decoder is shared model-wide, so once per model). See
    /// [`CodeDecoder::decode_lut`].
    lut: OnceLock<Arc<DecodeLut>>,
}

impl DaccDecoder {
    pub fn new(dir: Arc<DirectionCodebook>, mag: Arc<MagnitudeCodebook>) -> Self {
        let h = crate::quant::fnv1a_f32(crate::quant::FNV_OFFSET, dir.vectors.as_slice());
        let h = crate::quant::fnv1a_f32(h, &mag.levels);
        DaccDecoder { dir, mag, fingerprint: h, lut: OnceLock::new() }
    }
}

impl CodeDecoder for DaccDecoder {
    fn k(&self) -> usize {
        self.dir.dim()
    }

    #[inline]
    fn decode_into(&self, records: &[u64], out: &mut [f32]) {
        let d = records[0] as usize;
        let r = self.mag.level(records[1] as u32);
        for (o, &dj) in out.iter_mut().zip(self.dir.vectors.row(d)) {
            *o = r * dj;
        }
    }

    /// The direction×magnitude product expanded once, magnitude scale
    /// folded in: `lut[m · 2^a + d] = level_m · dir_d`, so the blocked
    /// kernel's per-record decode is one contiguous k-float gather instead
    /// of a dispatch + scalar multiply. Each entry uses the same
    /// `level * dir_j` f32 multiply as [`CodeDecoder::decode_into`], so LUT
    /// rows are bit-identical to the scalar decode.
    fn decode_lut(&self) -> Option<Arc<DecodeLut>> {
        let nd = self.dir.len();
        let nm = self.mag.len();
        match nd.checked_mul(nm) {
            Some(n) if n <= MAX_LUT_ENTRIES => {}
            _ => return None,
        }
        Some(Arc::clone(self.lut.get_or_init(|| {
            let k = self.dir.dim();
            let mut data = vec![0.0f32; nd * nm * k];
            for m in 0..nm {
                let level = self.mag.level(m as u32);
                for d in 0..nd {
                    let dst = &mut data[(m * nd + d) * k..(m * nd + d + 1) * k];
                    for (o, &dj) in dst.iter_mut().zip(self.dir.vectors.row(d)) {
                        *o = level * dj;
                    }
                }
            }
            Arc::new(DecodeLut::new(
                Arc::new(Matrix::from_vec(data, nd * nm, k)),
                vec![1, nd],
            ))
        })))
    }

    fn codebook_bits(&self) -> u64 {
        (self.dir.len() * self.dir.dim() * 32 + self.mag.len() * 32) as u64
    }

    fn spec(&self) -> String {
        format!(
            "dacc:{}-a{}:{}-b{}:k{}:{:016x}",
            self.dir.method.name(),
            self.dir.bits,
            self.mag.method.name(),
            self.mag.bits,
            self.dir.dim(),
            self.fingerprint
        )
    }

    fn persist(&self) -> crate::quant::DecoderPersist<'_> {
        crate::quant::DecoderPersist::Dacc { dir: &self.dir, mag: &self.mag }
    }
}

/// The PCDVQ quantizer: shared codebooks + config.
///
/// Codebooks are `Arc`-shared: like the paper, one direction codebook and one
/// magnitude codebook serve the entire model (they are aligned to N(0,1), not
/// to any particular layer). Every artifact emitted by this instance
/// references the same [`DaccDecoder`].
pub struct Pcdvq {
    pub cfg: PcdvqConfig,
    pub dir: Arc<DirectionCodebook>,
    pub mag: Arc<MagnitudeCodebook>,
    decoder: Arc<DaccDecoder>,
}

impl Pcdvq {
    pub fn new(cfg: PcdvqConfig, dir: Arc<DirectionCodebook>, mag: Arc<MagnitudeCodebook>) -> Self {
        assert_eq!(dir.bits, cfg.dir_bits, "direction codebook bits mismatch");
        assert_eq!(mag.bits, cfg.mag_bits, "magnitude codebook bits mismatch");
        assert_eq!(dir.dim(), cfg.k, "direction codebook dim mismatch");
        let decoder = Arc::new(DaccDecoder::new(Arc::clone(&dir), Arc::clone(&mag)));
        Pcdvq { cfg, dir, mag, decoder }
    }

    /// The shared decoder referenced by every artifact this instance emits.
    pub fn decoder(&self) -> Arc<DaccDecoder> {
        Arc::clone(&self.decoder)
    }

    /// Quantize a weight matrix into the full compressed representation.
    pub fn quantize_full(&self, w: &Matrix) -> QuantizedWeight {
        let k = self.cfg.k;
        assert_eq!(
            w.len() % k,
            0,
            "weight size {}x{} not divisible by k={k}",
            w.rows(),
            w.cols()
        );
        assert!(
            w.rows().is_power_of_two(),
            "RHT requires power-of-two rows, got {} (pad upstream)",
            w.rows()
        );
        // Per-layer seed: mix the global seed with the shape so layers get
        // independent sign diagonals but remain reproducible.
        let seed = self
            .cfg
            .seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add((w.rows() as u64) << 32 ^ w.cols() as u64);
        let rht = RandomizedHadamard::new(w.rows(), seed);

        // 1. regularize to ~N(0,1)
        let (h, scales) = regularize(w, &rht);

        // 2. polar decoupling
        let vectors = h.reshape_vectors(k);
        let n_vec = vectors.rows();

        // magnitudes + normalized directions
        let mut mags = Vec::with_capacity(n_vec);
        let mut dirs = Matrix::zeros(n_vec, k);
        for i in 0..n_vec {
            let v = vectors.row(i);
            let r: f32 = v.iter().map(|x| x * x).sum::<f32>().sqrt();
            mags.push(r);
            let d = dirs.row_mut(i);
            if r > 0.0 {
                for (dj, &vj) in d.iter_mut().zip(v) {
                    *dj = vj / r;
                }
            } else {
                d[0] = 1.0; // degenerate zero vector: arbitrary direction
            }
        }

        // 3. DACC assignment — direction via the blocked argmax hot path,
        //    magnitude via binary search over the sorted levels.
        let mut dir_idx = vec![0u32; n_vec];
        assign_into(&dirs, &self.dir.vectors, &[], &mut dir_idx);
        let mag_idx: Vec<u64> =
            mags.iter().map(|&r| self.mag.assign(r) as u64).collect();
        let dir_idx: Vec<u64> = dir_idx.into_iter().map(|d| d as u64).collect();

        // 4. pack into the two parallel streams (a-bit + b-bit records)
        let codes = PackedStreams::new(vec![
            PackedIndices::pack(&dir_idx, self.cfg.dir_bits),
            PackedIndices::pack(&mag_idx, self.cfg.mag_bits),
        ]);

        QuantizedWeight::new(
            self.name(),
            w.rows(),
            w.cols(),
            codes,
            self.decoder(),
            scales,
            Some(seed),
        )
    }

    /// Quantize and return the pre/post pair **in the regularized domain**
    /// (the space where assignment actually happens) — used by the Fig-3
    /// error-decomposition harness. The inverse RHT is an isotropic
    /// rotation, so decomposing after it would wash out the
    /// direction/magnitude split.
    pub fn quantize_regularized(&self, w: &Matrix) -> (Matrix, Matrix) {
        let qw = self.quantize_full(w);
        let rht = RandomizedHadamard::new(w.rows(), qw.rht_seed().expect("PCDVQ uses the RHT"));
        let (h, _) = regularize(w, &rht);
        (h, qw.decode_codes())
    }

    /// Explicitly materialize a compressed weight back to a dense matrix
    /// (convenience over [`QuantizedWeight::dequantize`]).
    pub fn dequantize_full(&self, qw: &QuantizedWeight) -> Matrix {
        qw.dequantize()
    }
}

impl Quantizer for Pcdvq {
    fn name(&self) -> String {
        format!("pcdvq-{:.3}bpw", self.cfg.bits_per_weight())
    }

    fn quantize(&self, w: &Matrix) -> QuantizedWeight {
        self.quantize_full(w)
    }

    fn bits_per_weight(&self) -> f64 {
        self.cfg.bits_per_weight()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codebook::{DirectionMethod, MagnitudeMethod};
    use crate::rng::Rng;

    fn small_pcdvq(a: u32, b: u32) -> Pcdvq {
        let dir = Arc::new(DirectionCodebook::build(DirectionMethod::GreedyE8, a, 8, 0));
        let mag = Arc::new(MagnitudeCodebook::build(
            MagnitudeMethod::LloydMax,
            b,
            8,
            1.0 - 1e-4,
            0,
        ));
        Pcdvq::new(
            PcdvqConfig { dir_bits: a, mag_bits: b, k: 8, seed: 7 },
            dir,
            mag,
        )
    }

    fn gaussian_weight(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::from_vec(rng.normal_vec(rows * cols), rows, cols)
    }

    #[test]
    fn quantize_dequantize_reduces_with_bits() {
        let w = gaussian_weight(64, 32, 3);
        let e_small = {
            let q = small_pcdvq(6, 2);
            q.quantize(&w).dequantize().mse(&w)
        };
        let e_big = {
            let q = small_pcdvq(10, 2);
            q.quantize(&w).dequantize().mse(&w)
        };
        assert!(e_big < e_small, "a=10 ({e_big}) should beat a=6 ({e_small})");
        // and both should be far below the trivial all-zero error (≈ var = 1)
        assert!(e_big < 0.5);
    }

    #[test]
    fn payload_bits_match_a3_accounting() {
        let w = gaussian_weight(64, 64, 4);
        let q = small_pcdvq(14, 2);
        let qw = q.quantize_full(&w);
        let index_bits = (64 * 64 / 8) as u64 * 16; // (a+b) per vector
        assert_eq!(qw.codes().payload_bits(), index_bits);
        // achieved bpw of the index stream alone = 2.0
        let bpw = qw.codes().payload_bits() as f64 / w.len() as f64;
        assert!((bpw - 2.0).abs() < 1e-12);
        // and the two streams carry a / b bit records respectively
        assert_eq!(qw.codes().n_streams(), 2);
        assert_eq!(qw.codes().stream(0).width, 14);
        assert_eq!(qw.codes().stream(1).width, 2);
    }

    #[test]
    fn deterministic_given_seed() {
        let w = gaussian_weight(32, 16, 5);
        let q = small_pcdvq(8, 2);
        let a = q.quantize_full(&w);
        let b = q.quantize_full(&w);
        assert_eq!(a.codes(), b.codes());
        assert_eq!(a.scales(), b.scales());
        assert_eq!(a.rht_seed(), b.rht_seed());
    }

    #[test]
    fn round_trip_preserves_shape_and_scale_structure() {
        let w = gaussian_weight(128, 24, 6);
        let q = small_pcdvq(10, 3);
        let qw = q.quantize_full(&w);
        let deq = qw.dequantize();
        assert_eq!((deq.rows(), deq.cols()), (w.rows(), w.cols()));
        // column norms approximately preserved (magnitude codebook centers
        // the chi distribution)
        for j in 0..w.cols() {
            let n0: f32 = w.col(j).iter().map(|x| x * x).sum::<f32>().sqrt();
            let n1: f32 = deq.col(j).iter().map(|x| x * x).sum::<f32>().sqrt();
            assert!((n1 / n0 - 1.0).abs() < 0.25, "col {j}: {n0} vs {n1}");
        }
    }

    #[test]
    fn indices_in_range() {
        let w = gaussian_weight(64, 16, 8);
        let q = small_pcdvq(9, 2);
        let qw = q.quantize_full(&w);
        for i in 0..qw.n_vectors() {
            let d = qw.codes().stream(0).get(i);
            let m = qw.codes().stream(1).get(i);
            assert!(d < 1 << 9);
            assert!(m < 1 << 2);
        }
    }

    #[test]
    fn handles_zero_vectors() {
        let mut w = gaussian_weight(32, 8, 9);
        // zero out one full k-group
        for x in &mut w.as_mut_slice()[0..8] {
            *x = 0.0;
        }
        let q = small_pcdvq(6, 2);
        let deq = q.quantize(&w).into_dequantized();
        assert!(deq.as_slice().iter().all(|x| x.is_finite()));
    }

    #[test]
    fn artifacts_share_one_decoder() {
        // every layer references the same DACC codebooks — the Arc is
        // literally shared, so resident codebook state is counted once
        let q = small_pcdvq(7, 2);
        let a = q.quantize_full(&gaussian_weight(32, 16, 10));
        let b = q.quantize_full(&gaussian_weight(64, 8, 11));
        assert!(Arc::ptr_eq(a.decoder(), b.decoder()));
        assert_eq!(a.decoder().spec(), b.decoder().spec());
    }

    #[test]
    fn fused_matmul_matches_explicit_dequant() {
        let w = gaussian_weight(64, 32, 12);
        let q = small_pcdvq(8, 2);
        let qw = q.quantize_full(&w);
        let mut rng = Rng::new(13);
        let x = Matrix::from_vec(rng.normal_vec(3 * 64), 3, 64);
        let dense = crate::tensor::matmul(&x, &qw.dequantize());
        let fused = qw.matmul_from_codes(&x);
        for (a, b) in dense.as_slice().iter().zip(fused.as_slice()) {
            assert!(
                (a - b).abs() <= 1e-5 * (1.0 + a.abs().max(b.abs())),
                "fused {b} vs dense {a}"
            );
        }
    }

    #[test]
    fn dacc_lut_rows_bit_identical_to_decode_into() {
        let q = small_pcdvq(6, 2);
        let dec = q.decoder();
        let lut = dec.decode_lut().expect("small DACC books expand");
        let (nd, nm, k) = (q.dir.len(), q.mag.len(), q.cfg.k);
        assert_eq!(lut.n_entries(), nd * nm);
        assert_eq!((lut.k(), lut.n_strides()), (k, 2));
        assert_eq!((lut.stride(0), lut.stride(1)), (1, nd));
        let mut out = vec![0.0f32; k];
        for m in 0..nm as u64 {
            for d in 0..nd as u64 {
                let rec = [d, m];
                dec.decode_into(&rec, &mut out);
                let row: Vec<u32> = lut.row(lut.index(&rec)).iter().map(|v| v.to_bits()).collect();
                let exp: Vec<u32> = out.iter().map(|v| v.to_bits()).collect();
                assert_eq!(row, exp, "d={d} m={m}");
            }
        }
        // the LUT is derived: shared-codebook accounting is unchanged by it
        assert_eq!(
            dec.codebook_bits(),
            (nd * k * 32 + nm * 32) as u64,
            "codebook bits must not absorb the LUT"
        );
    }

    #[test]
    fn dacc_lut_declines_oversized_joint_space() {
        // a=14, b=2 expands (2^16 entries); only past MAX_LUT_ENTRIES does
        // the decoder decline — pin the boundary arithmetic
        let (nd, nm) = (1usize << 14, 1usize << 2);
        assert!(nd * nm <= MAX_LUT_ENTRIES, "the paper's a=14 setting must expand");
        let nd_big = 1usize << 17;
        assert!(nd_big * nm > MAX_LUT_ENTRIES, "past the cap the decoder declines");

        // ...and execute the decline branch itself: an (untrained) 2^17-row
        // direction codebook crosses the cap with b=2, so decode_lut must
        // return None without attempting the multi-entry expansion
        let dir = Arc::new(DirectionCodebook {
            vectors: crate::tensor::Matrix::zeros(nd_big, 8),
            bits: 17,
            method: DirectionMethod::RandomGaussian,
        });
        let mag = Arc::new(MagnitudeCodebook {
            levels: vec![0.5, 1.0, 1.5, 2.0],
            bits: 2,
            method: MagnitudeMethod::LloydMax,
        });
        let dec = DaccDecoder::new(dir, mag);
        assert!(dec.decode_lut().is_none(), "oversized joint space must decline");
        // a within-cap pair through the same constructor still expands
        let dir_ok = Arc::new(DirectionCodebook {
            vectors: crate::tensor::Matrix::zeros(1 << 6, 8),
            bits: 6,
            method: DirectionMethod::RandomGaussian,
        });
        let mag_ok = Arc::new(MagnitudeCodebook {
            levels: vec![0.5, 1.0, 1.5, 2.0],
            bits: 2,
            method: MagnitudeMethod::LloydMax,
        });
        let dec_ok = DaccDecoder::new(dir_ok, mag_ok);
        assert!(dec_ok.decode_lut().is_some(), "within-cap pair must expand");
    }

    #[test]
    fn blocked_kernel_bit_identical_on_rht_path() {
        // PCDVQ artifacts carry an RHT seed: both kernels share the same
        // activation transform, so outputs stay bit-identical
        let w = gaussian_weight(64, 32, 14);
        let q = small_pcdvq(7, 2);
        let qw = q.quantize_full(&w);
        assert!(qw.rht_seed().is_some());
        let mut rng = Rng::new(15);
        for n in [1usize, 3] {
            let x = Matrix::from_vec(rng.normal_vec(n * 64), n, 64);
            let scalar = qw.matmul_from_codes_scalar(&x);
            for block in [1usize, 7, qw.default_block_vecs(), qw.n_vectors()] {
                for lut in [false, true] {
                    let blocked = qw.matmul_from_codes_blocked(&x, block, lut);
                    let a: Vec<u32> = scalar.as_slice().iter().map(|v| v.to_bits()).collect();
                    let b: Vec<u32> = blocked.as_slice().iter().map(|v| v.to_bits()).collect();
                    assert_eq!(a, b, "n={n} block={block} lut={lut}");
                }
            }
        }
    }
}
