//! Direction/magnitude error decomposition (paper Fig 1b, Fig 3, Eq. 5).
//!
//! For a vector `v` and its quantized version `c`, the squared Euclidean
//! error splits exactly as
//!
//! ```text
//! ‖v − c‖² = (‖v‖ − ‖c‖)²  +  2·‖v‖·‖c‖·(1 − cos θ)
//!             └ magnitude ┘     └────── direction ──────┘
//! ```
//!
//! The paper's Fig 1b normalizes the direction term as `2‖v‖²(1−cosθ)`
//! (same-unit comparison); we expose both.

use crate::tensor::{dot, norm2, Matrix};

/// Decomposed quantization error statistics over a set of vectors.
#[derive(Clone, Copy, Debug, Default)]
pub struct ErrorDecomposition {
    /// Mean `(‖v‖−‖c‖)²`.
    pub magnitude_mse: f64,
    /// Mean `2‖v‖²(1−cosθ)` — Fig 1b's same-unit direction error.
    pub direction_mse: f64,
    /// Mean exact cross term `2‖v‖‖c‖(1−cosθ)`.
    pub direction_cross_mse: f64,
    /// Mean total `‖v−c‖²`.
    pub total_mse: f64,
    /// Mean `1 − cosθ`.
    pub mean_one_minus_cos: f64,
    /// Number of vectors measured.
    pub count: usize,
}

/// Decompose the error between original vectors and their quantized
/// counterparts (same shape, rows are k-vectors).
pub fn decompose(original: &Matrix, quantized: &Matrix) -> ErrorDecomposition {
    assert_eq!(original.rows(), quantized.rows());
    assert_eq!(original.cols(), quantized.cols());
    let n = original.rows();
    let mut out = ErrorDecomposition { count: n, ..Default::default() };
    for i in 0..n {
        let v = original.row(i);
        let c = quantized.row(i);
        let nv = norm2(v) as f64;
        let nc = norm2(c) as f64;
        let cos = if nv > 0.0 && nc > 0.0 {
            (dot(v, c) as f64 / (nv * nc)).clamp(-1.0, 1.0)
        } else {
            1.0
        };
        let dmag = (nv - nc) * (nv - nc);
        let ddir = 2.0 * nv * nv * (1.0 - cos);
        let dcross = 2.0 * nv * nc * (1.0 - cos);
        let total: f64 = v
            .iter()
            .zip(c)
            .map(|(a, b)| ((a - b) as f64) * ((a - b) as f64))
            .sum();
        out.magnitude_mse += dmag;
        out.direction_mse += ddir;
        out.direction_cross_mse += dcross;
        out.total_mse += total;
        out.mean_one_minus_cos += 1.0 - cos;
    }
    let inv = 1.0 / n.max(1) as f64;
    out.magnitude_mse *= inv;
    out.direction_mse *= inv;
    out.direction_cross_mse *= inv;
    out.total_mse *= inv;
    out.mean_one_minus_cos *= inv;
    out
}

/// Decompose between two weight matrices after the VQ reshape.
pub fn decompose_weights(w: &Matrix, deq: &Matrix, k: usize) -> ErrorDecomposition {
    decompose(&w.reshape_vectors(k), &deq.reshape_vectors(k))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn identity_decomposes_to_zero() {
        let mut rng = Rng::new(1);
        let v = Matrix::from_vec(rng.normal_vec(80), 10, 8);
        let d = decompose(&v, &v);
        // f32 dot products leave ~1e-7 cosine noise; thresholds reflect that
        assert!(d.magnitude_mse < 1e-10);
        assert!(d.direction_mse < 1e-5);
        assert!(d.total_mse < 1e-10);
    }

    #[test]
    fn pure_scaling_is_pure_magnitude_error() {
        let mut rng = Rng::new(2);
        let v = Matrix::from_vec(rng.normal_vec(80), 10, 8);
        let scaled = Matrix::from_vec(v.as_slice().iter().map(|x| 1.5 * x).collect(), 10, 8);
        let d = decompose(&v, &scaled);
        assert!(d.direction_mse < 1e-4, "direction {d:?}");
        assert!(d.magnitude_mse > 0.0);
    }

    #[test]
    fn pure_rotation_is_pure_direction_error() {
        // rotate each vector in its first two coordinates by 30°
        let mut rng = Rng::new(3);
        let v = Matrix::from_vec(rng.normal_vec(80), 10, 8);
        let mut r = v.clone();
        let (s, c) = (30.0f32.to_radians().sin(), 30.0f32.to_radians().cos());
        for i in 0..10 {
            let row = r.row_mut(i);
            let (x, y) = (row[0], row[1]);
            row[0] = c * x - s * y;
            row[1] = s * x + c * y;
        }
        let d = decompose(&v, &r);
        assert!(d.magnitude_mse < 1e-9, "magnitude {d:?}");
        assert!(d.direction_mse > 0.0);
    }

    #[test]
    fn eq5_identity_holds() {
        // ‖v−c‖² == Δr² + 2‖v‖‖c‖(1−cosθ), exactly (Eq. 5)
        let mut rng = Rng::new(4);
        let v = Matrix::from_vec(rng.normal_vec(400), 50, 8);
        let mut c = v.clone();
        for x in c.as_mut_slice().iter_mut() {
            *x += 0.1 * rng.normal() as f32;
        }
        let d = decompose(&v, &c);
        let recon = d.magnitude_mse + d.direction_cross_mse;
        assert!(
            (recon - d.total_mse).abs() / d.total_mse < 1e-6,
            "recon {recon} vs total {}",
            d.total_mse
        );
    }
}
