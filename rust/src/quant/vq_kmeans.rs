//! Coupled k-means vector quantization — the VPTQ/GPTVQ-style baseline.
//!
//! Clusters the raw k-dimensional weight vectors with Euclidean k-means
//! (the paper's Figure 1 uses exactly this to demonstrate the
//! direction/magnitude sensitivity gap) and replaces each vector by its
//! centroid index. Direction and magnitude stay *coupled* — the codebook
//! spends capacity on both at once, which is the inefficiency PCDVQ removes.
//!
//! Centroids are trained per-quantizer on a subsample of the model's vectors
//! (mini-batch Lloyd iterations), then shared across all matrices quantized
//! by this instance — mirroring VPTQ's per-model codebooks while staying
//! tractable on one core. Serving gathers straight from the shared centroid
//! table: it doubles as the decode LUT of the blocked host kernel
//! ([`crate::quant::QuantizedWeight::matmul_from_codes`], via
//! [`crate::quant::CodeDecoder::decode_lut`]).

use std::sync::Arc;

use crate::quant::assign::{assign_euclidean, euclidean_bias, assign_batch};
use crate::quant::packing::{PackedIndices, PackedStreams};
use crate::quant::{QuantizedWeight, Quantizer, TableDecoder};
use crate::rng::Rng;
use crate::tensor::Matrix;

/// Coupled k-means VQ.
#[derive(Clone, Debug)]
pub struct KMeansVq {
    /// Vector dimension.
    pub k: usize,
    /// Codebook bits (2^bits centroids).
    pub bits: u32,
    /// Trained centroids (None until [`Self::fit`]); `Arc`-shared with every
    /// artifact this quantizer emits (the per-model codebook the compressed
    /// weights reference).
    centroids: Option<Arc<Matrix>>,
    /// Lloyd iterations.
    pub iters: usize,
    pub seed: u64,
}

impl KMeansVq {
    pub fn new(k: usize, bits: u32) -> Self {
        KMeansVq { k, bits, centroids: None, iters: 4, seed: 0xC0DE }
    }

    /// Total index bits per vector.
    pub fn index_bits(&self) -> u32 {
        self.bits
    }

    pub fn centroids(&self) -> Option<&Matrix> {
        self.centroids.as_deref()
    }

    /// Train the codebook on sample vectors (rows of `samples`, dim k).
    ///
    /// Initialization follows the *distribution-aware* trick the paper's own
    /// Fig 1 baseline uses (plain k-means on the data): random distinct data
    /// vectors as seeds, then `iters` Lloyd steps over a capped sample.
    pub fn fit(&mut self, samples: &Matrix) {
        assert_eq!(samples.cols(), self.k);
        let mut n_centers = 1usize << self.bits;
        // A codebook larger than half the training pool would memorize the
        // data (and a tiny model simply has fewer vectors than 2^16); shrink
        // to the largest power of two ≤ pool/2 and keep the *nominal* bpw
        // accounting — matching how VPTQ-style codebooks saturate on small
        // layers.
        if n_centers > samples.rows() / 2 {
            n_centers = (samples.rows() / 2).next_power_of_two() / 2;
            assert!(n_centers >= 2, "pool of {} too small for k-means", samples.rows());
            eprintln!(
                "[kmeans-vq] pool {} < 2x codebook; shrinking to {} centers",
                samples.rows(),
                n_centers
            );
        }
        let cap = 120_000.min(samples.rows());
        let mut rng = Rng::new(self.seed);

        // subsample the training pool
        let pool = if samples.rows() > cap {
            let idx = rng.sample_indices(samples.rows(), cap);
            let mut data = Vec::with_capacity(cap * self.k);
            for &i in &idx {
                data.extend_from_slice(samples.row(i));
            }
            Matrix::from_vec(data, cap, self.k)
        } else {
            samples.clone()
        };
        assert!(
            pool.rows() >= n_centers,
            "need at least {n_centers} sample vectors, got {}",
            pool.rows()
        );

        // init: distinct random data vectors
        let init = rng.sample_indices(pool.rows(), n_centers);
        let mut data = Vec::with_capacity(n_centers * self.k);
        for &i in &init {
            data.extend_from_slice(pool.row(i));
        }
        let mut centers = Matrix::from_vec(data, n_centers, self.k);

        for _ in 0..self.iters {
            let assign = assign_euclidean(&pool, &centers);
            let mut sums = vec![0.0f64; n_centers * self.k];
            let mut counts = vec![0usize; n_centers];
            for (i, &c) in assign.iter().enumerate() {
                let c = c as usize;
                counts[c] += 1;
                for (s, &x) in sums[c * self.k..(c + 1) * self.k]
                    .iter_mut()
                    .zip(pool.row(i))
                {
                    *s += x as f64;
                }
            }
            for c in 0..n_centers {
                if counts[c] == 0 {
                    // dead center: re-seed from a random pool vector
                    let r = rng.below(pool.rows());
                    centers.row_mut(c).copy_from_slice(pool.row(r));
                } else {
                    let inv = 1.0 / counts[c] as f64;
                    for (dst, &s) in centers
                        .row_mut(c)
                        .iter_mut()
                        .zip(&sums[c * self.k..(c + 1) * self.k])
                    {
                        *dst = (s * inv) as f32;
                    }
                }
            }
        }
        self.centroids = Some(Arc::new(centers));
    }

    /// Fit directly on the vectors of a weight matrix (convenience used by
    /// single-layer experiments like Fig 1b).
    pub fn fit_on_weight(&mut self, w: &Matrix) {
        let vectors = w.reshape_vectors(self.k);
        self.fit(&vectors);
    }
}

impl Quantizer for KMeansVq {
    fn name(&self) -> String {
        format!("kmeans-vq-k{}-{}b", self.k, self.bits)
    }

    fn quantize(&self, w: &Matrix) -> QuantizedWeight {
        let centers = self
            .centroids
            .as_ref()
            .expect("KMeansVq::fit must be called before quantize");
        let vectors = w.reshape_vectors(self.k);
        let bias = euclidean_bias(centers);
        let idx = assign_batch(&vectors, centers, &bias);
        let records: Vec<u64> = idx.iter().map(|&c| c as u64).collect();
        // width stays the *nominal* bits even when the codebook saturated to
        // fewer centers (§A.3 nominal accounting, matching VPTQ's reporting)
        let codes = PackedStreams::single(PackedIndices::pack(&records, self.bits));
        let decoder = TableDecoder::new(
            Arc::clone(centers),
            format!("kmeans-k{}-{}b-s{}", self.k, self.bits, self.seed),
        );
        QuantizedWeight::new(
            self.name(),
            w.rows(),
            w.cols(),
            codes,
            Arc::new(decoder),
            Vec::new(),
            None,
        )
    }

    fn bits_per_weight(&self) -> f64 {
        self.bits as f64 / self.k as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gaussian(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::from_vec(rng.normal_vec(rows * cols), rows, cols)
    }

    #[test]
    fn fit_then_quantize_reduces_error_vs_random_book() {
        let w = gaussian(128, 64, 1);
        let mut q = KMeansVq::new(8, 8);
        q.fit_on_weight(&w);
        let fitted_err = q.quantize(&w).dequantize().mse(&w);

        // random (unfitted) codebook of the same size
        let mut rnd = KMeansVq::new(8, 8);
        rnd.centroids = Some(Arc::new(gaussian(256, 8, 99)));
        let rnd_err = rnd.quantize(&w).dequantize().mse(&w);
        assert!(fitted_err < rnd_err, "fitted {fitted_err} vs random {rnd_err}");
    }

    #[test]
    fn error_decreases_with_codebook_bits() {
        // large enough that no bits setting triggers the pool/2 shrink
        let w = gaussian(256, 256, 2);
        let err = |bits: u32| {
            let mut q = KMeansVq::new(8, bits);
            q.fit_on_weight(&w);
            q.quantize(&w).dequantize().mse(&w)
        };
        let (e4, e8, e10) = (err(4), err(8), err(10));
        assert!(e4 > e8 && e8 > e10, "e4={e4} e8={e8} e10={e10}");
    }

    #[test]
    fn bpw_accounting() {
        let q = KMeansVq::new(8, 16);
        assert!((q.bits_per_weight() - 2.0).abs() < 1e-12);
        let q = KMeansVq::new(8, 17);
        assert!((q.bits_per_weight() - 2.125).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn quantize_before_fit_panics() {
        let w = gaussian(16, 8, 3);
        let q = KMeansVq::new(8, 4);
        let _ = q.quantize(&w);
    }

    #[test]
    fn works_at_non_paper_dims() {
        for k in [2usize, 4, 16] {
            let w = gaussian(64, 32, 4);
            let mut q = KMeansVq::new(k, 6);
            q.fit_on_weight(&w);
            let deq = q.quantize(&w).into_dequantized();
            assert_eq!((deq.rows(), deq.cols()), (64, 32));
        }
    }
}
