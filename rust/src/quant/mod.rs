//! Weight quantizers: PCDVQ (the paper's method) and every baseline it is
//! compared against in Tables 1–4, all operating on the same [`Matrix`]
//! weight substrate and returning a [`QuantizedWeight`] — a first-class
//! **compressed artifact** (packed index streams + shared-codebook
//! references + per-column metadata) that can be lazily dequantized,
//! multiplied against directly ([`QuantizedWeight::matmul_from_codes`]),
//! measured ([`error`]) and persisted ([`crate::io::artifact`]).
//!
//! | module | paper row | idea |
//! |---|---|---|
//! | [`pcdvq`] | PCDVQ | RHT → polar decouple → greedy-E8 direction + Lloyd-Max magnitude |
//! | [`sq`] | GPTQ (RTN core) | symmetric uniform scalar quantization |
//! | [`gptq`] | GPTQ | error-compensated sequential SQ (synthetic Hessian — see DESIGN.md §3) |
//! | [`vq_kmeans`] | VPTQ / GPTVQ | coupled k-means vector quantization |
//! | [`quip`] | QuIP# | RHT + coupled E8-lattice codebook, algebraic decode |
//! | [`error`] | Fig 1b / Fig 3 | direction/magnitude error decomposition |
//! | [`tune`] | Table 3 | post-quantization correction analogs |
//!
//! ## The compressed representation
//!
//! Every quantizer emits the same artifact shape (DESIGN.md §6):
//!
//! * one or more parallel [`PackedStreams`] of fixed-width index records,
//!   one record tuple per `k`-vector of the row-major-flattened weight;
//! * an `Arc<dyn CodeDecoder>` referencing the **shared** codebooks (one
//!   direction + one magnitude codebook per model for PCDVQ; one centroid /
//!   lattice table per quantizer instance for the coupled baselines; none
//!   for scalar methods) — codebooks amortize across layers per §A.3;
//! * per-column scales applied in the code domain (empty ⇒ all 1.0);
//! * an optional RHT sign seed: when present, the codes live in the
//!   regularized domain and materialization applies the inverse transform.
//!
//! Dense weights exist only when a caller explicitly asks
//! ([`QuantizedWeight::dequantize_into`]); serving and eval can instead run
//! the fused kernel ([`QuantizedWeight::matmul_from_codes`]) so only codes +
//! codebooks stay resident. Since PR 4 the fused kernel is a **blocked,
//! LUT-driven GEMM** (DESIGN.md §11): code blocks bulk-unpack
//! ([`packing::PackedIndices::unpack_range_into`]) and decode once into an
//! L1-resident tile via a pre-expanded [`DecodeLut`], then FMA against every
//! activation row as contiguous autovectorized segments. Since PR 5 the
//! kernel additionally fans out across disjoint output-column strips on the
//! shared worker pool ([`crate::exec`], DESIGN.md §12) — with the original
//! scalar kernel kept as the bit-identical reference **at every thread
//! count** ([`QuantizedWeight::matmul_from_codes_scalar`],
//! `tests/kernel_equivalence.rs`).

pub mod assign;
pub mod error;
pub mod gptq;
pub mod kv;
pub mod packing;
pub mod pcdvq;
pub mod quip;
pub mod sq;
pub mod tune;
pub mod vq_kmeans;

use std::sync::{Arc, OnceLock};

use crate::hadamard::RandomizedHadamard;
use crate::quant::packing::PackedStreams;
use crate::tensor::Matrix;

/// Anything that can turn a weight matrix into a compressed representation.
pub trait Quantizer {
    /// Human-readable method name (used in tables and CLI).
    fn name(&self) -> String;

    /// Quantize a weight matrix into a compressed artifact.
    fn quantize(&self, w: &Matrix) -> QuantizedWeight;

    /// Nominal bits per weight of the index stream (excluding shared
    /// codebooks and per-column metadata, following the paper's §A.3
    /// accounting).
    fn bits_per_weight(&self) -> f64;
}

/// Decodes one `k`-vector from its per-stream index records by gathering
/// from the shared codebook(s) it references. Implementations are cheap,
/// immutable and shared (`Arc`) across every layer quantized with the same
/// codebooks.
pub trait CodeDecoder: Send + Sync {
    /// Vector dimension produced per record tuple.
    fn k(&self) -> usize;

    /// Decode one record tuple (`records[s]` = record of stream `s`) into
    /// `out` (length [`Self::k`]), in the code domain (pre-scale, pre-RHT).
    fn decode_into(&self, records: &[u64], out: &mut [f32]);

    /// Pre-expanded decode table for the blocked kernel
    /// ([`QuantizedWeight::matmul_from_codes`]), or `None` when the joint
    /// index space is too large to expand (the kernel then falls back to
    /// per-record [`Self::decode_into`] calls).
    ///
    /// Contract: for every record tuple this decoder accepts,
    /// `lut.row(lut.index(records))` must be **bit-identical** to what
    /// [`Self::decode_into`] writes for the same tuple — the kernel
    /// equivalence proptest (`tests/kernel_equivalence.rs`) relies on it.
    /// The LUT is *derived* state: rebuildable from the shared codebooks,
    /// never persisted, and counted by neither [`Self::codebook_bits`] nor
    /// any artifact's payload (see [`dedup_lut_bits`]).
    fn decode_lut(&self) -> Option<Arc<DecodeLut>> {
        None
    }

    /// Bits of the shared codebook state behind this decoder (amortized
    /// across all artifacts that reference it).
    fn codebook_bits(&self) -> u64;

    /// Stable identifier: artifacts referencing decoders with equal specs
    /// share one codebook (registry key + accounting dedup key).
    fn spec(&self) -> String;

    /// The decoder's persistable state ([`crate::io::artifact`] writes it
    /// once per distinct codebook and re-links artifacts on load).
    fn persist(&self) -> DecoderPersist<'_>;
}

/// Persistable view of a decoder's shared state (see
/// [`CodeDecoder::persist`]).
pub enum DecoderPersist<'a> {
    /// PCDVQ's decoupled pair: direction + magnitude codebooks.
    Dacc {
        dir: &'a Arc<crate::codebook::DirectionCodebook>,
        mag: &'a Arc<crate::codebook::MagnitudeCodebook>,
    },
    /// A dense reconstruction table (coupled-VQ baselines).
    Table { table: &'a Arc<Matrix>, label: &'a str },
    /// The stateless uniform integer grid.
    Scalar { bits: u32 },
}

/// A pre-expanded decode lookup table: one `k`-wide row per joint codebook
/// entry, addressed by `index(records) = Σ_s records[s] · stride(s)`. The
/// blocked matmul kernel ([`QuantizedWeight::matmul_from_codes`]) gathers
/// LUT rows instead of dispatching [`CodeDecoder::decode_into`] per record
/// — for PCDVQ this folds the magnitude scale into the direction rows once
/// (`lut[m·2^a + d] = level_m · dir_d`), so the per-record decode is a
/// single contiguous `k`-float copy.
///
/// A `DecodeLut` is **derived state**: it is rebuilt from the shared
/// codebooks on demand, is never persisted, and contributes zero bits to
/// both payload and codebook accounting ([`dedup_lut_bits`] reports it
/// separately; `paper::efficiency` asserts it never leaks into either).
pub struct DecodeLut {
    /// `n_entries × k`, row-major — for single-stream table decoders this is
    /// literally the shared reconstruction table (`Arc`-shared, zero copy).
    table: Arc<Matrix>,
    /// Per-stream index multipliers (`index = Σ records[s] · strides[s]`).
    strides: Vec<usize>,
}

impl DecodeLut {
    pub fn new(table: Arc<Matrix>, strides: Vec<usize>) -> Self {
        assert!(!strides.is_empty(), "decode LUT needs at least one stream stride");
        DecodeLut { table, strides }
    }

    /// Vector dimension per row (= the decoder's [`CodeDecoder::k`]).
    pub fn k(&self) -> usize {
        self.table.cols()
    }

    /// Rows in the expanded table (the joint index space).
    pub fn n_entries(&self) -> usize {
        self.table.rows()
    }

    /// Streams this LUT indexes over (= the artifact's stream count).
    pub fn n_strides(&self) -> usize {
        self.strides.len()
    }

    /// Index multiplier of stream `s`.
    #[inline]
    pub fn stride(&self, s: usize) -> usize {
        self.strides[s]
    }

    /// Joint row index of one record tuple.
    #[inline]
    pub fn index(&self, records: &[u64]) -> usize {
        debug_assert_eq!(records.len(), self.strides.len());
        records
            .iter()
            .zip(&self.strides)
            .map(|(&r, &st)| r as usize * st)
            .sum()
    }

    /// The decoded `k`-vector of joint entry `idx` — bit-identical to what
    /// [`CodeDecoder::decode_into`] produces for the corresponding records.
    #[inline]
    pub fn row(&self, idx: usize) -> &[f32] {
        self.table.row(idx)
    }

    /// Bits of this derived table. Reported separately from artifact payload
    /// and shared-codebook bits — rebuilding the LUT costs compute, not
    /// stored state.
    pub fn bits(&self) -> u64 {
        self.table.len() as u64 * 32
    }
}

/// Decoder over a dense reconstruction table: record → table row. Used by
/// the coupled-VQ baselines (k-means centroids, scaled E8-ball points).
pub struct TableDecoder {
    table: Arc<Matrix>,
    label: String,
    /// FNV-1a fingerprint of the table contents — part of [`Self::spec`], so
    /// two *differently fitted* tables never dedup as one in the measured
    /// codebook accounting even when their label/shape coincide.
    fingerprint: u64,
    /// Lazily built decode LUT (here just an `Arc` re-share of `table` —
    /// the table already is its own expansion).
    lut: OnceLock<Arc<DecodeLut>>,
}

impl TableDecoder {
    pub fn new(table: Arc<Matrix>, label: impl Into<String>) -> Self {
        let fingerprint = fnv1a_f32(FNV_OFFSET, table.as_slice());
        TableDecoder { table, label: label.into(), fingerprint, lut: OnceLock::new() }
    }

    pub fn table(&self) -> &Arc<Matrix> {
        &self.table
    }
}

/// FNV-1a offset basis — the shared fingerprint seed for codebook specs.
pub(crate) const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

/// Fold `xs` into an FNV-1a hash (bit-exact f32 identity) — the one
/// fingerprint rule behind every decoder's [`CodeDecoder::spec`] dedup key.
pub(crate) fn fnv1a_f32(mut h: u64, xs: &[f32]) -> u64 {
    for &x in xs {
        h = (h ^ x.to_bits() as u64).wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

impl CodeDecoder for TableDecoder {
    fn k(&self) -> usize {
        self.table.cols()
    }

    #[inline]
    fn decode_into(&self, records: &[u64], out: &mut [f32]) {
        out.copy_from_slice(self.table.row(records[0] as usize));
    }

    fn decode_lut(&self) -> Option<Arc<DecodeLut>> {
        Some(Arc::clone(self.lut.get_or_init(|| {
            Arc::new(DecodeLut::new(Arc::clone(&self.table), vec![1]))
        })))
    }

    fn codebook_bits(&self) -> u64 {
        self.table.len() as u64 * 32
    }

    fn spec(&self) -> String {
        format!(
            "table:{}:{}x{}:{:016x}",
            self.label,
            self.table.rows(),
            self.table.cols(),
            self.fingerprint
        )
    }

    fn persist(&self) -> DecoderPersist<'_> {
        DecoderPersist::Table { table: &self.table, label: &self.label }
    }
}

/// A quantized weight as a compressed artifact: packed index streams, a
/// reference to the shared codebooks (via the decoder), per-column scales
/// and the RHT seed. Enough to reconstruct the approximation — and to run
/// matmuls without ever reconstructing it. Cloning copies the packed codes
/// (cheap, ≈ payload bytes) and shares the codebooks.
#[derive(Clone)]
pub struct QuantizedWeight {
    /// Method label.
    pub method: String,
    rows: usize,
    cols: usize,
    codes: PackedStreams,
    decoder: Arc<dyn CodeDecoder>,
    /// Per-column scales applied in the code domain; empty = all 1.0.
    scales: Vec<f32>,
    /// `Some(seed)` ⇒ codes live in the RHT-regularized domain.
    rht_seed: Option<u64>,
}

impl QuantizedWeight {
    pub fn new(
        method: impl Into<String>,
        rows: usize,
        cols: usize,
        codes: PackedStreams,
        decoder: Arc<dyn CodeDecoder>,
        scales: Vec<f32>,
        rht_seed: Option<u64>,
    ) -> Self {
        let k = decoder.k();
        assert_eq!(
            codes.len() * k,
            rows * cols,
            "codes ({} records x k={k}) disagree with shape {rows}x{cols}",
            codes.len()
        );
        assert!(
            scales.is_empty() || scales.len() == cols,
            "scales length {} != cols {cols}",
            scales.len()
        );
        if rht_seed.is_some() {
            assert!(rows.is_power_of_two(), "RHT artifacts need power-of-two rows");
        }
        QuantizedWeight {
            method: method.into(),
            rows,
            cols,
            codes,
            decoder,
            scales,
            rht_seed,
        }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Element count of the (virtual) dense weight.
    pub fn len(&self) -> usize {
        self.rows * self.cols
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Number of k-vectors (= records per stream).
    pub fn n_vectors(&self) -> usize {
        self.codes.len()
    }

    /// The packed index streams.
    pub fn codes(&self) -> &PackedStreams {
        &self.codes
    }

    /// The shared-codebook decoder this artifact references.
    pub fn decoder(&self) -> &Arc<dyn CodeDecoder> {
        &self.decoder
    }

    /// Per-column code-domain scales (empty = all 1.0).
    pub fn scales(&self) -> &[f32] {
        &self.scales
    }

    /// RHT sign seed, if the codes live in the regularized domain.
    pub fn rht_seed(&self) -> Option<u64> {
        self.rht_seed
    }

    /// Per-layer payload bits: packed indices + f32 scales + RHT seed
    /// (paper §A.3 counts the index stream; we also count per-layer
    /// metadata for honesty). Shared codebooks amortize to ~0 per layer and
    /// are accounted separately via [`Self::codebook_bits`].
    pub fn payload_bits(&self) -> u64 {
        self.codes.payload_bits()
            + self.scales.len() as u64 * 32
            + if self.rht_seed.is_some() { 64 } else { 0 }
    }

    /// Bits of the shared codebooks behind this artifact (amortized).
    pub fn codebook_bits(&self) -> u64 {
        self.decoder.codebook_bits()
    }

    /// Achieved bits per weight for this layer (payload only).
    pub fn achieved_bpw(&self) -> f64 {
        self.payload_bits() as f64 / self.len() as f64
    }

    /// Decode the raw codes into the code-domain matrix (no scales, no
    /// inverse RHT) — the regularized-domain reconstruction the Fig-3
    /// error-decomposition harness measures.
    pub fn decode_codes(&self) -> Matrix {
        let k = self.decoder.k();
        let mut flat = vec![0.0f32; self.len()];
        let mut rec = vec![0u64; self.codes.n_streams()];
        for i in 0..self.codes.len() {
            self.codes.records_into(i, &mut rec);
            self.decoder.decode_into(&rec, &mut flat[i * k..(i + 1) * k]);
        }
        Matrix::from_vec(flat, self.rows, self.cols)
    }

    /// Explicitly materialize the dense approximation into `out`
    /// (gather → per-column scale → inverse RHT). The only place a dense
    /// copy of a quantized weight is ever created.
    pub fn dequantize_into(&self, out: &mut Matrix) {
        assert_eq!(
            (out.rows(), out.cols()),
            (self.rows, self.cols),
            "dequantize_into shape mismatch"
        );
        let k = self.decoder.k();
        let mut rec = vec![0u64; self.codes.n_streams()];
        {
            let flat = out.as_mut_slice();
            for i in 0..self.codes.len() {
                self.codes.records_into(i, &mut rec);
                self.decoder.decode_into(&rec, &mut flat[i * k..(i + 1) * k]);
            }
        }
        if !self.scales.is_empty() {
            for i in 0..self.rows {
                for (x, &s) in out.row_mut(i).iter_mut().zip(&self.scales) {
                    *x *= s;
                }
            }
        }
        if let Some(seed) = self.rht_seed {
            let rht = RandomizedHadamard::new(self.rows, seed);
            let dense = rht.inverse(out);
            *out = dense;
        }
    }

    /// Allocate-and-materialize convenience over [`Self::dequantize_into`].
    pub fn dequantize(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols);
        self.dequantize_into(&mut out);
        out
    }

    /// Consume into the dense approximation.
    pub fn into_dequantized(self) -> Matrix {
        self.dequantize()
    }

    /// Fused `y = x · Ŵ` straight from the codes (`x`: `(n, rows)`,
    /// returns `(n, cols)`) — the host serving kernel. The dense weight is
    /// never materialized: for RHT artifacts the input is transformed once
    /// per row (`t = (H/√p)·D·x`, one FWHT), then the packed records are
    /// decoded and accumulated, and per-column scales fold in at the end.
    ///
    /// ## Numerical contract
    ///
    /// This is the blocked, LUT-driven kernel
    /// ([`Self::matmul_from_codes_blocked`] at [`Self::default_block_vecs`],
    /// LUT on), fanned out across disjoint **output-column strips** on the
    /// shared worker pool ([`crate::exec`], [`Self::auto_strips`] workers at
    /// [`crate::exec::current_threads`]; `PALLAS_THREADS` overrides the
    /// process default). Its output is **bit-identical** to the scalar
    /// reference kernel ([`Self::matmul_from_codes_scalar`]) for every block
    /// size, LUT mode **and thread count** — each worker owns its slice of
    /// `y`, within a column the contributions still arrive in increasing
    /// weight-row order (the same flat row-major order the scalar kernel
    /// walks) with the same unfused mul-then-add sequence, and every
    /// [`CodeDecoder::decode_lut`] row is bit-identical to
    /// [`CodeDecoder::decode_into`]. `tests/kernel_equivalence.rs` pins this
    /// across the block-size grid {1, 7, default, default+1, n_vectors} and
    /// the thread grid {1, 2, 4, n+1}. Relative to `x · dequantize()` the
    /// result agrees to f32 rounding (≤ 1e-5 relative — the dense path sums
    /// in a different association).
    pub fn matmul_from_codes(&self, x: &Matrix) -> Matrix {
        let threads = crate::exec::current_threads();
        self.matmul_from_codes_threaded(
            x,
            self.default_block_vecs(),
            true,
            self.auto_strips(x.rows(), threads),
        )
    }

    /// Column strips the default entry point fans out to at `threads`
    /// workers: capped so each strip keeps ≥ 2¹⁵ flat mul-adds (below that
    /// the spawn cost beats the win — single-token decode matvecs on small
    /// layers stay serial) and ≥ 8 output columns (shorter axpy runs defeat
    /// the vectorized inner loop). DESIGN.md §12 records the tuning
    /// contract; the strip *boundaries* for a given count come from
    /// [`crate::exec::partition`].
    pub fn auto_strips(&self, batch_rows: usize, threads: usize) -> usize {
        const MIN_FLAT_PER_STRIP: usize = 1 << 15;
        const MIN_COLS_PER_STRIP: usize = 8;
        let work = self.len().saturating_mul(batch_rows.max(1));
        threads
            .clamp(1, (work / MIN_FLAT_PER_STRIP).max(1))
            .min((self.cols / MIN_COLS_PER_STRIP).max(1))
    }

    /// The parallel fused kernel: [`Self::matmul_from_codes_blocked`]
    /// fanned out across `threads` disjoint output-column strips
    /// ([`crate::exec::partition`] of the column range — fixed boundaries,
    /// never scheduling-dependent). Each worker decodes only the records
    /// covering its strip (records straddling a strip edge are decoded by
    /// both neighbours) and accumulates into its own `(n, strip)` buffer;
    /// the caller stitches strips back in column order and applies the
    /// scale epilogue, so the result is **bit-identical** to the scalar
    /// reference for any `threads ≥ 1` (see [`Self::matmul_from_codes`]).
    pub fn matmul_from_codes_threaded(
        &self,
        x: &Matrix,
        block_vecs: usize,
        use_lut: bool,
        threads: usize,
    ) -> Matrix {
        let strips = threads.clamp(1, self.cols.max(1));
        if strips <= 1 {
            return self.matmul_from_codes_blocked(x, block_vecs, use_lut);
        }
        let n = x.rows();
        let (transformed, lut) = self.kernel_prelude(x, use_lut);
        let t: &Matrix = transformed.as_ref().unwrap_or(x);
        let block = block_vecs.clamp(1, self.codes.len().max(1));
        let pool = crate::exec::Pool::new(strips);
        // each worker reports its range back with its buffer, so the
        // stitch-back can never drift from the layout the pool actually ran
        let parts = pool.run_strips(self.cols, 1, |_, range| {
            let mut strip = Matrix::zeros(n, range.len());
            self.accumulate_columns(t, &mut strip, range.clone(), block, lut.as_ref());
            (range, strip)
        });
        let mut y = Matrix::zeros(n, self.cols);
        for (range, strip) in &parts {
            for b in 0..n {
                y.row_mut(b)[range.start..range.end].copy_from_slice(strip.row(b));
            }
        }
        self.apply_col_scales(&mut y);
        y
    }

    /// Accumulate the fused product into one output-column strip
    /// `y[:, c0..c1)` — the per-worker body of
    /// [`Self::matmul_from_codes_threaded`]. Walks the packed stream row by
    /// row: for weight row `r` only the records covering flat elements
    /// `[r·cols + c0, r·cols + c1)` are unpacked and decoded (in
    /// `block`-sized tiles, exactly like the single-thread kernel). Within
    /// a column the contributions arrive in increasing weight-row order
    /// with the same single mul-then-add per element, which is what keeps
    /// the parallel kernel bit-identical to the scalar reference.
    fn accumulate_columns(
        &self,
        t: &Matrix,
        y: &mut Matrix,
        cols_range: std::ops::Range<usize>,
        block: usize,
        lut: Option<&Arc<DecodeLut>>,
    ) {
        let (c0, c1) = (cols_range.start, cols_range.end);
        debug_assert!(c0 < c1 && c1 <= self.cols);
        let k = self.decoder.k();
        let cols = self.cols;
        let n = t.rows();
        let n_streams = self.codes.n_streams();
        let mut tile = vec![0.0f32; block * k];
        let mut unpacked = vec![vec![0u64; block]; n_streams];
        let mut rec = vec![0u64; n_streams];
        for r in 0..self.rows {
            let f_lo = r * cols + c0;
            let f_hi = r * cols + c1;
            let rec_end = (f_hi - 1) / k + 1;
            let mut i0 = f_lo / k;
            while i0 < rec_end {
                let i1 = (i0 + block).min(rec_end);
                let bn = i1 - i0;
                self.decode_block(i0, bn, &mut unpacked, &mut rec, &mut tile, lut);
                // overlap of the decoded tile's flat range with this row's
                // strip — one contiguous column run at fixed weight row r
                let lo = f_lo.max(i0 * k);
                let hi = f_hi.min(i1 * k);
                for b in 0..n {
                    axpy(
                        &mut y.row_mut(b)[lo - f_lo..hi - f_lo],
                        &tile[lo - i0 * k..hi - i0 * k],
                        t.row(b)[r],
                    );
                }
                i0 = i1;
            }
        }
    }

    /// Default column-block size (in k-vector records) for the blocked
    /// kernel: chosen so one decoded tile (`block · k` f32) fits in half a
    /// conventional 32-KiB L1d, leaving the other half for the activation
    /// row and output segment streaming through it (DESIGN.md §11 records
    /// the tuning contract).
    pub fn default_block_vecs(&self) -> usize {
        const TILE_F32: usize = 4096; // 16 KiB decoded tile
        (TILE_F32 / self.decoder.k().max(1)).max(1)
    }

    /// The scalar reference kernel: per-record random access
    /// ([`PackedStreams::records_into`]) → [`CodeDecoder::decode_into`] →
    /// element-at-a-time FMA. Kept as the equivalence oracle for
    /// [`Self::matmul_from_codes_blocked`] (and as the before-side of the
    /// `matmul_kernels` bench scenario); serving uses the blocked kernel.
    pub fn matmul_from_codes_scalar(&self, x: &Matrix) -> Matrix {
        assert_eq!(
            x.cols(),
            self.rows,
            "matmul_from_codes: x has {} cols, weight has {} rows",
            x.cols(),
            self.rows
        );
        let n = x.rows();
        let transformed = self.rht_transformed(x);
        let t: &Matrix = transformed.as_ref().unwrap_or(x);
        let k = self.decoder.k();
        let cols = self.cols;
        let mut y = Matrix::zeros(n, cols);
        let mut rec = vec![0u64; self.codes.n_streams()];
        let mut v = vec![0.0f32; k];
        let mut rc = vec![(0usize, 0usize); k];
        for i in 0..self.codes.len() {
            self.codes.records_into(i, &mut rec);
            self.decoder.decode_into(&rec, &mut v);
            // (row, col) targets of this vector's k elements, computed once
            let base = i * k;
            for (d, slot) in rc.iter_mut().enumerate() {
                let flat = base + d;
                *slot = (flat / cols, flat % cols);
            }
            for b in 0..n {
                let trow = t.row(b);
                let yrow = y.row_mut(b);
                for (&(r, c), &hval) in rc.iter().zip(&v) {
                    yrow[c] += trow[r] * hval;
                }
            }
        }
        self.apply_col_scales(&mut y);
        y
    }

    /// The blocked kernel core: decode `block_vecs` records at a time into
    /// an L1-resident tile, then FMA the tile against every activation row
    /// as contiguous per-weight-row segments.
    ///
    /// Per block of records `[i0, i1)`:
    ///
    /// 1. **bulk-unpack** each stream's records with one sequential bit
    ///    cursor ([`packing::PackedIndices::unpack_range_into`]);
    /// 2. **decode once per block** — gather LUT rows
    ///    ([`CodeDecoder::decode_lut`], `use_lut = true`) or fall back to
    ///    per-record [`CodeDecoder::decode_into`] — into a `block·k` tile;
    ///    a batch of `n` activation rows reuses the tile `n` times, so a
    ///    block-prefill `(chunk, d)` matmul decodes each code block once
    ///    per chunk, not once per row;
    /// 3. **FMA by segments**: the tile covers flat elements
    ///    `[i0·k, i1·k)` of the row-major weight, i.e. runs of contiguous
    ///    columns at fixed weight row `r` — each run is one
    ///    `y[c0..c1] += t[r] · tile[..]` axpy over chunked slices that LLVM
    ///    autovectorizes (same shape as `assign`'s k = 8 distance kernel;
    ///    no `unsafe`).
    ///
    /// Output is bit-identical to [`Self::matmul_from_codes_scalar`] for
    /// any `block_vecs ≥ 1` and either LUT mode (see the contract on
    /// [`Self::matmul_from_codes`]).
    pub fn matmul_from_codes_blocked(
        &self,
        x: &Matrix,
        block_vecs: usize,
        use_lut: bool,
    ) -> Matrix {
        let n = x.rows();
        let (transformed, lut) = self.kernel_prelude(x, use_lut);
        let t: &Matrix = transformed.as_ref().unwrap_or(x);
        let k = self.decoder.k();
        let cols = self.cols;
        let n_vec = self.codes.len();
        let n_streams = self.codes.n_streams();
        let mut y = Matrix::zeros(n, cols);
        let block = block_vecs.clamp(1, n_vec.max(1));
        let mut tile = vec![0.0f32; block * k];
        let mut unpacked = vec![vec![0u64; block]; n_streams];
        let mut rec = vec![0u64; n_streams];
        let mut i0 = 0usize;
        while i0 < n_vec {
            let i1 = (i0 + block).min(n_vec);
            let bn = i1 - i0;
            self.decode_block(i0, bn, &mut unpacked, &mut rec, &mut tile, lut.as_ref());
            // FMA the tile: flat range [i0·k, i1·k) splits into contiguous
            // column segments at fixed weight row r
            let f0 = i0 * k;
            let f1 = i1 * k;
            for b in 0..n {
                let trow = t.row(b);
                let yrow = y.row_mut(b);
                let mut f = f0;
                while f < f1 {
                    let (r, c) = (f / cols, f % cols);
                    let seg = (cols - c).min(f1 - f);
                    axpy(&mut yrow[c..c + seg], &tile[f - f0..f - f0 + seg], trow[r]);
                    f += seg;
                }
            }
            i0 = i1;
        }
        self.apply_col_scales(&mut y);
        y
    }

    /// Shared kernel prelude — shape check, the one-time RHT activation
    /// transform, and the (consistency-checked) decode LUT. One copy for
    /// the single-thread blocked kernel and the column-strip workers, so
    /// the two entry points can never drift in what they validate.
    fn kernel_prelude(
        &self,
        x: &Matrix,
        use_lut: bool,
    ) -> (Option<Matrix>, Option<Arc<DecodeLut>>) {
        assert_eq!(
            x.cols(),
            self.rows,
            "matmul_from_codes: x has {} cols, weight has {} rows",
            x.cols(),
            self.rows
        );
        let transformed = self.rht_transformed(x);
        let lut = if use_lut { self.decoder.decode_lut() } else { None };
        if let Some(l) = &lut {
            assert_eq!(
                l.k(),
                self.decoder.k(),
                "decode LUT width disagrees with decoder k"
            );
            assert_eq!(
                l.n_strides(),
                self.codes.n_streams(),
                "decode LUT stride count disagrees with stream count"
            );
        }
        (transformed, lut)
    }

    /// Decode records `[i0, i0 + bn)` into the first `bn · k` floats of
    /// `tile` — the per-block decode shared by the single-thread blocked
    /// kernel and the per-strip workers: bulk-unpack each stream with one
    /// sequential bit cursor, then gather LUT rows (or fall back to
    /// per-record [`CodeDecoder::decode_into`]).
    fn decode_block(
        &self,
        i0: usize,
        bn: usize,
        unpacked: &mut [Vec<u64>],
        rec: &mut [u64],
        tile: &mut [f32],
        lut: Option<&Arc<DecodeLut>>,
    ) {
        let k = self.decoder.k();
        for (s, buf) in unpacked.iter_mut().enumerate() {
            self.codes.stream(s).unpack_range_into(i0, &mut buf[..bn]);
        }
        match lut {
            Some(l) => {
                for j in 0..bn {
                    let mut idx = 0usize;
                    for (s, buf) in unpacked.iter().enumerate() {
                        idx += buf[j] as usize * l.stride(s);
                    }
                    tile[j * k..(j + 1) * k].copy_from_slice(l.row(idx));
                }
            }
            None => {
                for j in 0..bn {
                    for (r, buf) in rec.iter_mut().zip(unpacked.iter()) {
                        *r = buf[j];
                    }
                    self.decoder.decode_into(rec, &mut tile[j * k..(j + 1) * k]);
                }
            }
        }
    }

    /// RHT prelude shared by both kernels: transform the activations once
    /// (the transpose trick — `x·D·(H/√p)` per row equals applying the
    /// forward RHT to each row vector). `None` for non-RHT artifacts, whose
    /// input is used in place with no copy.
    fn rht_transformed(&self, x: &Matrix) -> Option<Matrix> {
        self.rht_seed.map(|seed| {
            let rht = RandomizedHadamard::new(self.rows, seed);
            let mut t = x.clone();
            for i in 0..t.rows() {
                rht.forward_col(t.row_mut(i));
            }
            t
        })
    }

    /// Shared epilogue: fold the per-column code-domain scales into `y`.
    fn apply_col_scales(&self, y: &mut Matrix) {
        if self.scales.is_empty() {
            return;
        }
        for b in 0..y.rows() {
            for (yv, &s) in y.row_mut(b).iter_mut().zip(&self.scales) {
                *yv *= s;
            }
        }
    }

    /// Fused matvec: `y = xᵀ · Ŵ` for a single activation vector.
    pub fn matvec_from_codes(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.rows);
        let xm = Matrix::from_vec(x.to_vec(), 1, self.rows);
        self.matmul_from_codes(&xm).into_vec()
    }
}

/// `y += a · x` over equal-length slices, in 8-wide chunks with a scalar
/// tail — the blocked kernel's inner gather-FMA, shaped like
/// [`assign`]'s k = 8 distance loop so LLVM lowers the chunk body to packed
/// FMAs without explicit SIMD or `unsafe`. Per element this is exactly one
/// `mul` then one `add` (no reassociation), which is what keeps the blocked
/// kernel bit-identical to the scalar reference.
#[inline]
fn axpy(y: &mut [f32], x: &[f32], a: f32) {
    debug_assert_eq!(y.len(), x.len());
    let mut yc = y.chunks_exact_mut(8);
    let mut xc = x.chunks_exact(8);
    for (yy, xx) in (&mut yc).zip(&mut xc) {
        for i in 0..8 {
            yy[i] += a * xx[i];
        }
    }
    for (yy, &xv) in yc.into_remainder().iter_mut().zip(xc.remainder()) {
        *yy += a * xv;
    }
}

/// Sum the shared-codebook bits behind a set of artifacts, deduplicated by
/// decoder spec — `Arc`-shared codebooks count once, however many layers
/// reference them. The single accounting rule behind
/// `QuantizedGpt::codebook_bits` and `HostForward::codebook_bits`.
pub fn dedup_codebook_bits<'a, I>(weights: I) -> u64
where
    I: IntoIterator<Item = &'a QuantizedWeight>,
{
    let mut seen = std::collections::BTreeSet::new();
    let mut bits = 0u64;
    for w in weights {
        if seen.insert(w.decoder().spec()) {
            bits += w.codebook_bits();
        }
    }
    bits
}

/// Sum the **derived** decode-LUT bits behind a set of artifacts,
/// deduplicated by decoder spec — the mirror of [`dedup_codebook_bits`] for
/// rebuildable LUT state. Reported separately in the §4.4 accounting
/// (`paper::efficiency`): a LUT is reconstructed from the shared codebooks
/// at serve time, so it contributes zero artifact bits and must never be
/// folded into payload or codebook totals.
pub fn dedup_lut_bits<'a, I>(weights: I) -> u64
where
    I: IntoIterator<Item = &'a QuantizedWeight>,
{
    let mut seen = std::collections::BTreeSet::new();
    let mut bits = 0u64;
    for w in weights {
        if seen.insert(w.decoder().spec()) {
            bits += w.decoder().decode_lut().map_or(0, |l| l.bits());
        }
    }
    bits
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::packing::PackedIndices;
    use crate::rng::Rng;
    use crate::tensor::matmul;

    /// Identity-ish table decoder over a random reconstruction table.
    fn table_artifact(rows: usize, cols: usize, bits: u32, seed: u64) -> QuantizedWeight {
        let k = 4usize;
        let n_entries = 1usize << bits;
        let mut rng = Rng::new(seed);
        let table = Arc::new(Matrix::from_vec(rng.normal_vec(n_entries * k), n_entries, k));
        let n_vec = rows * cols / k;
        let records: Vec<u64> =
            (0..n_vec).map(|_| rng.below(n_entries) as u64).collect();
        let codes = PackedStreams::single(PackedIndices::pack(&records, bits));
        QuantizedWeight::new(
            "test-table",
            rows,
            cols,
            codes,
            Arc::new(TableDecoder::new(table, "test")),
            Vec::new(),
            None,
        )
    }

    #[test]
    fn payload_and_shape_accounting() {
        let qw = table_artifact(16, 8, 6, 1);
        assert_eq!((qw.rows(), qw.cols(), qw.len()), (16, 8, 128));
        assert_eq!(qw.n_vectors(), 32);
        assert_eq!(qw.payload_bits(), 32 * 6);
        assert!((qw.achieved_bpw() - 6.0 / 4.0).abs() < 1e-12);
        assert!(qw.codebook_bits() > 0);
    }

    #[test]
    fn dequantize_matches_decode_for_plain_tables() {
        // no scales, no RHT: dequantize == decode_codes
        let qw = table_artifact(16, 8, 5, 2);
        let a = qw.decode_codes();
        let b = qw.dequantize();
        assert_eq!(a.as_slice(), b.as_slice());
    }

    #[test]
    fn matmul_from_codes_matches_dense_matmul() {
        let qw = table_artifact(32, 16, 7, 3);
        let mut rng = Rng::new(4);
        let x = Matrix::from_vec(rng.normal_vec(5 * 32), 5, 32);
        let dense = matmul(&x, &qw.dequantize());
        let fused = qw.matmul_from_codes(&x);
        assert_eq!((fused.rows(), fused.cols()), (5, 16));
        for (a, b) in dense.as_slice().iter().zip(fused.as_slice()) {
            assert!(
                (a - b).abs() <= 1e-5 * (1.0 + a.abs().max(b.abs())),
                "fused {b} vs dense {a}"
            );
        }
    }

    /// Bit-pattern view for bit-identity assertions (NaN-safe, unlike f32 ==).
    fn bits(m: &Matrix) -> Vec<u32> {
        m.as_slice().iter().map(|v| v.to_bits()).collect()
    }

    #[test]
    fn blocked_kernel_bit_identical_to_scalar() {
        let qw = table_artifact(32, 16, 7, 21);
        let mut rng = Rng::new(22);
        let x = Matrix::from_vec(rng.normal_vec(5 * 32), 5, 32);
        let scalar = qw.matmul_from_codes_scalar(&x);
        let n_vec = qw.n_vectors();
        for block in [1usize, 7, qw.default_block_vecs(), n_vec] {
            for lut in [false, true] {
                let blocked = qw.matmul_from_codes_blocked(&x, block, lut);
                assert_eq!(bits(&scalar), bits(&blocked), "block={block} lut={lut}");
            }
        }
        // the default entry point is the blocked+LUT kernel
        assert_eq!(bits(&scalar), bits(&qw.matmul_from_codes(&x)));
    }

    #[test]
    fn threaded_kernel_bit_identical_across_thread_grid() {
        let qw = table_artifact(32, 16, 7, 31);
        let mut rng = Rng::new(32);
        let x = Matrix::from_vec(rng.normal_vec(5 * 32), 5, 32);
        let scalar = qw.matmul_from_codes_scalar(&x);
        let block = qw.default_block_vecs();
        for threads in [1usize, 2, 3, 4, 16, qw.cols() + 5] {
            for lut in [false, true] {
                let par = qw.matmul_from_codes_threaded(&x, block, lut, threads);
                assert_eq!(bits(&scalar), bits(&par), "threads={threads} lut={lut}");
            }
            // odd block sizes through the strip walk too
            let par = qw.matmul_from_codes_threaded(&x, 3, true, threads);
            assert_eq!(bits(&scalar), bits(&par), "threads={threads} block=3");
        }
    }

    #[test]
    fn threaded_kernel_handles_straddling_and_scales() {
        // cols=6, k=4 with per-column scales: strip edges fall inside
        // decoded vectors and the scale epilogue runs after assembly
        let k = 4usize;
        let n_entries = 32usize;
        let mut rng = Rng::new(33);
        let table = Arc::new(Matrix::from_vec(rng.normal_vec(n_entries * k), n_entries, k));
        let records: Vec<u64> = (0..12).map(|_| rng.below(n_entries) as u64).collect();
        let qw = QuantizedWeight::new(
            "strad",
            8,
            6,
            PackedStreams::single(PackedIndices::pack(&records, 5)),
            Arc::new(TableDecoder::new(table, "strad")),
            vec![0.5, -1.0, 2.0, 0.25, 3.0, -0.125],
            None,
        );
        let x = Matrix::from_vec(rng.normal_vec(3 * 8), 3, 8);
        let scalar = qw.matmul_from_codes_scalar(&x);
        for threads in [2usize, 3, 5, 6, 9] {
            let par = qw.matmul_from_codes_threaded(&x, 2, true, threads);
            assert_eq!(bits(&scalar), bits(&par), "threads={threads}");
        }
    }

    #[test]
    fn auto_strips_keeps_small_work_serial() {
        let qw = table_artifact(32, 16, 7, 34);
        // 32x16 · 1 row = 512 flat mul-adds — far below the strip floor
        assert_eq!(qw.auto_strips(1, 8), 1);
        // cols cap: never more strips than cols/8
        assert!(qw.auto_strips(usize::MAX / qw.len(), 64) <= 2);
    }

    #[test]
    fn blocked_kernel_handles_vectors_straddling_rows() {
        // cols=6, k=4: every second k-vector crosses a weight-row boundary,
        // so the tile→segment walk must split mid-vector
        let qw = table_artifact(8, 6, 5, 23);
        assert_ne!(qw.cols() % qw.decoder().k(), 0);
        let mut rng = Rng::new(24);
        let x = Matrix::from_vec(rng.normal_vec(3 * 8), 3, 8);
        let scalar = qw.matmul_from_codes_scalar(&x);
        for block in [1usize, 2, 3, 12] {
            for lut in [false, true] {
                let blocked = qw.matmul_from_codes_blocked(&x, block, lut);
                assert_eq!(bits(&scalar), bits(&blocked), "block={block} lut={lut}");
            }
        }
    }

    #[test]
    fn table_decoder_lut_is_the_shared_table() {
        // the reconstruction table is its own expansion: zero-copy Arc
        // re-share, rows bit-identical to decode_into, one stride
        let qw = table_artifact(16, 8, 6, 25);
        let lut = qw.decoder().decode_lut().expect("table decoders always have a LUT");
        assert_eq!(lut.n_strides(), 1);
        assert_eq!(lut.stride(0), 1);
        assert_eq!(lut.k(), qw.decoder().k());
        let mut out = vec![0.0f32; lut.k()];
        for i in 0..lut.n_entries() {
            qw.decoder().decode_into(&[i as u64], &mut out);
            assert_eq!(lut.index(&[i as u64]), i);
            let row: Vec<u32> = lut.row(i).iter().map(|v| v.to_bits()).collect();
            let exp: Vec<u32> = out.iter().map(|v| v.to_bits()).collect();
            assert_eq!(row, exp, "entry {i}");
        }
        // derived state: building the LUT changes no artifact accounting
        assert_eq!(lut.bits(), qw.codebook_bits(), "table LUT re-shares the codebook");
        assert_eq!(qw.payload_bits(), qw.codes().payload_bits());
    }

    #[test]
    fn one_entry_codebook_degenerate_lut() {
        // 1-row table: every record decodes to the same vector, LUT has a
        // single entry, kernels stay bit-identical
        let k = 4usize;
        let table = Arc::new(Matrix::from_vec(vec![0.5, -1.0, 2.0, 0.25], 1, k));
        let codes = PackedStreams::single(PackedIndices::pack(&[0u64; 8], 1));
        let qw = QuantizedWeight::new(
            "one-entry",
            4,
            8,
            codes,
            Arc::new(TableDecoder::new(table, "degenerate")),
            Vec::new(),
            None,
        );
        let lut = qw.decoder().decode_lut().unwrap();
        assert_eq!(lut.n_entries(), 1);
        let mut rng = Rng::new(26);
        let x = Matrix::from_vec(rng.normal_vec(2 * 4), 2, 4);
        let scalar = qw.matmul_from_codes_scalar(&x);
        for block in [1usize, 3, 8, 100] {
            let blocked = qw.matmul_from_codes_blocked(&x, block, true);
            assert_eq!(bits(&scalar), bits(&blocked), "block={block}");
        }
    }

    #[test]
    fn dedup_lut_bits_counts_shared_decoders_once() {
        let table = Arc::new(Matrix::from_vec(vec![1.0; 4 * 4], 4, 4));
        let dec: Arc<dyn CodeDecoder> = Arc::new(TableDecoder::new(table, "shared"));
        let mk = |seed: u64| {
            let mut rng = Rng::new(seed);
            let records: Vec<u64> = (0..8).map(|_| rng.below(4) as u64).collect();
            QuantizedWeight::new(
                "t",
                4,
                8,
                PackedStreams::single(PackedIndices::pack(&records, 2)),
                Arc::clone(&dec),
                Vec::new(),
                None,
            )
        };
        let (a, b) = (mk(1), mk(2));
        let solo = dedup_lut_bits([&a]);
        assert_eq!(solo, 4 * 4 * 32);
        assert_eq!(dedup_lut_bits([&a, &b]), solo, "shared decoder counts once");
    }

    #[test]
    fn matvec_agrees_with_matmul_row() {
        let qw = table_artifact(32, 8, 6, 5);
        let mut rng = Rng::new(6);
        let x = rng.normal_vec(32);
        let y = qw.matvec_from_codes(&x);
        let ym = qw.matmul_from_codes(&Matrix::from_vec(x.clone(), 1, 32));
        assert_eq!(y, ym.as_slice().to_vec());
    }

    #[test]
    fn scales_apply_per_column() {
        let k = 4usize;
        let table = Arc::new(Matrix::from_vec(vec![1.0; k], 1, k));
        let codes = PackedStreams::single(PackedIndices::pack(&[0u64; 2], 1));
        let qw = QuantizedWeight::new(
            "t",
            2,
            4,
            codes,
            Arc::new(TableDecoder::new(table, "ones")),
            vec![1.0, 2.0, 3.0, 4.0],
            None,
        );
        let d = qw.dequantize();
        assert_eq!(d.row(0), &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(d.row(1), &[1.0, 2.0, 3.0, 4.0]);
        // payload counts the scales
        assert_eq!(qw.payload_bits(), 2 * 1 + 4 * 32);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_rejected() {
        let table = Arc::new(Matrix::from_vec(vec![0.0; 4], 1, 4));
        let codes = PackedStreams::single(PackedIndices::pack(&[0u64; 3], 1));
        // 3 records x k=4 = 12 elements != 2x4
        let _ = QuantizedWeight::new(
            "bad",
            2,
            4,
            codes,
            Arc::new(TableDecoder::new(table, "x")),
            Vec::new(),
            None,
        );
    }
}
