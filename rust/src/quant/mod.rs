//! Weight quantizers: PCDVQ (the paper's method) and every baseline it is
//! compared against in Tables 1–4, all operating on the same [`Matrix`]
//! weight substrate and returning a [`QuantizedWeight`] that can be
//! dequantized, measured ([`error`]) and persisted.
//!
//! | module | paper row | idea |
//! |---|---|---|
//! | [`pcdvq`] | PCDVQ | RHT → polar decouple → greedy-E8 direction + Lloyd-Max magnitude |
//! | [`sq`] | GPTQ (RTN core) | symmetric uniform scalar quantization |
//! | [`gptq`] | GPTQ | error-compensated sequential SQ (synthetic Hessian — see DESIGN.md) |
//! | [`vq_kmeans`] | VPTQ / GPTVQ | coupled k-means vector quantization |
//! | [`quip`] | QuIP# | RHT + coupled E8-lattice codebook, algebraic decode |
//! | [`error`] | Fig 1b / Fig 3 | direction/magnitude error decomposition |
//! | [`tune`] | Table 3 | post-quantization correction analogs |

pub mod assign;
pub mod error;
pub mod gptq;
pub mod packing;
pub mod pcdvq;
pub mod quip;
pub mod sq;
pub mod tune;
pub mod vq_kmeans;

use crate::tensor::Matrix;

/// Anything that can turn a weight matrix into a compressed representation.
pub trait Quantizer {
    /// Human-readable method name (used in tables and CLI).
    fn name(&self) -> String;

    /// Quantize a weight matrix.
    fn quantize(&self, w: &Matrix) -> QuantizedWeight;

    /// Nominal bits per weight of the index stream (excluding shared
    /// codebooks and per-column metadata, following the paper's §A.3
    /// accounting).
    fn bits_per_weight(&self) -> f64;
}

/// A quantized weight: enough information to reconstruct an approximation of
/// the original matrix plus exact storage accounting.
pub struct QuantizedWeight {
    /// Reconstructed ("fake-quant") weight.
    dequant: Matrix,
    /// Bits of per-layer payload (indices + scales + seeds), excluding
    /// codebooks shared across the whole model.
    payload_bits: u64,
    /// Method label.
    pub method: String,
}

impl QuantizedWeight {
    pub fn new(dequant: Matrix, payload_bits: u64, method: impl Into<String>) -> Self {
        QuantizedWeight { dequant, payload_bits, method: method.into() }
    }

    /// The reconstructed weight matrix.
    pub fn dequantize(&self) -> &Matrix {
        &self.dequant
    }

    pub fn into_dequantized(self) -> Matrix {
        self.dequant
    }

    /// Per-layer payload bits (§A.3 accounting: codebooks amortize to ~0).
    pub fn payload_bits(&self) -> u64 {
        self.payload_bits
    }

    /// Achieved bits per weight for this layer.
    pub fn achieved_bpw(&self) -> f64 {
        self.payload_bits as f64 / self.dequant.len() as f64
    }
}
