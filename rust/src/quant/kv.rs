//! Polar-decoupled KV-cache quantization (DESIGN.md §15).
//!
//! The paper quantizes weights; at serving batch sizes the KV cache, not the
//! weights, dominates resident bytes. This module applies the same polar
//! decoupling to the cache: every K/V row splits into `d_model / 2`
//! two-dimensional subvectors, each decomposed into a **direction** (unit
//! vector, quantized against a small per-layer direction codebook) and a
//! **magnitude** (scalar, quantized against a per-layer empirical grid) —
//! exactly the DACC shape of [`crate::quant::pcdvq::DaccDecoder`], scaled
//! down from weight matrices to cache rows.
//!
//! ## Codebook lifecycle: build during prefill, freeze per layer
//!
//! Unlike weights, cache rows do not exist at quantization time — they are
//! produced online by the forward pass. Each layer's codebook pair is
//! therefore built from the **first K/V row the layer ever writes**
//! ([`KvQuantCodec::observe`]): the row's subvectors (and their antipodes)
//! seed a greedy max–min-cosine direction codebook
//! ([`crate::codebook::direction::greedy_from_candidates`], Algorithm 1 on
//! online candidates), and the empirical quantiles of its subvector radii
//! form the magnitude grid. The pair is **frozen** from then on: every later
//! write — including the slide+rebuild eviction re-feed — re-quantizes
//! against the same frozen codec, so one cache's codes mean the same thing
//! for the lifetime of the server, shared prefix pages decode identically
//! for every reader, and decode is bit-reproducible from codes alone.
//!
//! ## Decode-tile data flow: the weight kernel's LUT machinery
//!
//! Codes decode through the same pre-expanded [`DecodeLut`] the blocked
//! weight kernel gathers from ([`crate::quant::CodeDecoder::decode_lut`],
//! DESIGN.md §11): `lut[m · nd + d] = level_m · dir_d`, one contiguous
//! 2-float gather per subvector, with every LUT row **bit-identical** to the
//! scalar `level · dir` decode. On write, the packed codes land in the page
//! *and* are immediately decoded into the page's f32 matrices (the "decoded
//! tile"), so attention reads stay borrowed `&[f32]` slices at full speed.
//! The tile is derived state in the same sense as the weight LUTs: zero
//! payload bits, re-buildable bit-identically from the codes
//! ([`KvQuantCodec::decode_row`]), and counted by neither
//! [`KvQuantCodec::codebook_bits`] nor any page's payload.

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};

use anyhow::{ensure, Result};

use crate::codebook::direction::greedy_from_candidates;
use crate::codebook::{
    DirectionCodebook, DirectionMethod, MagnitudeCodebook, MagnitudeMethod,
};
use crate::quant::DecodeLut;
use crate::tensor::Matrix;

/// Cache bit budget: `--kv-quant BITS` bits per cached value. Each `k = 2`
/// subvector stores one `2·BITS`-bit joint code, split `mag = BITS/2`,
/// `dir = 2·BITS − mag` — direction gets the lion's share, the paper's
/// central sensitivity result (Fig. 1) applied to activations.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KvQuantSpec {
    bits: u32,
}

impl KvQuantSpec {
    /// Subvector dimension of the cache codec. Cache rows are short
    /// (`d_model`, not a weight matrix), so the codec uses `k = 2` — enough
    /// rows per layer to build an online codebook from a single seed row.
    pub const K: usize = 2;
    /// Smallest supported cache bit width (1 magnitude + 3 direction bits).
    pub const MIN_BITS: u32 = 2;
    /// Largest supported width; past 8 bits the exact cache is the answer.
    pub const MAX_BITS: u32 = 8;

    /// Validate a `--kv-quant` bit width (0 = exact is the caller's case).
    pub fn new(bits: u32) -> Result<Self> {
        ensure!(
            (Self::MIN_BITS..=Self::MAX_BITS).contains(&bits),
            "--kv-quant {bits}: cache bits must be 0 (exact) or {}..={}",
            Self::MIN_BITS,
            Self::MAX_BITS
        );
        Ok(KvQuantSpec { bits })
    }

    /// Bits per cached value.
    pub fn bits(&self) -> u32 {
        self.bits
    }

    /// Magnitude index bits per subvector (`BITS / 2`).
    pub fn mag_bits(&self) -> u32 {
        self.bits / 2
    }

    /// Direction index bits per subvector (the remainder of the budget).
    pub fn dir_bits(&self) -> u32 {
        2 * self.bits - self.mag_bits()
    }

    /// Joint code width per subvector (`dir + mag = 2·BITS`).
    pub fn code_width(&self) -> u32 {
        self.dir_bits() + self.mag_bits()
    }
}

/// `u64` words per packed code row: `n_sub` codes of `width` bits, each row
/// padded up to a word boundary so rewriting one position in place never
/// touches a neighbouring row's words.
pub fn words_per_row(n_sub: usize, width: u32) -> usize {
    (n_sub * width as usize).div_ceil(64)
}

/// One frozen layer codec: direction codebook + magnitude grid + the
/// pre-expanded decode LUT (derived state, zero payload bits — the same
/// contract as [`crate::quant::CodeDecoder::decode_lut`]).
pub struct KvLayerCodec {
    /// Unit directions, greedily max–min-cosine selected from the seed
    /// row's subvectors and their antipodes.
    pub dir: DirectionCodebook,
    /// Empirical-quantile magnitude levels of the seed row's radii
    /// (sorted ascending; *not* the chi(k) grid — cache rows are not
    /// Gaussian-regularized, so the grid must follow the observed radii).
    pub mag: MagnitudeCodebook,
    lut: Arc<DecodeLut>,
}

impl KvLayerCodec {
    /// Build a layer codec from the first K/V row pair the layer writes.
    fn build(spec: KvQuantSpec, k_row: &[f32], v_row: &[f32], seed: u64) -> KvLayerCodec {
        let k = KvQuantSpec::K;
        let n_sub = k_row.len() / k;
        debug_assert_eq!(k_row.len(), v_row.len());
        // Candidate directions: every subvector of the seed K and V rows
        // plus its antipode (the sphere is symmetric; negations double the
        // pool for free and cover sign flips of later rows).
        let mut cands = Matrix::zeros(4 * n_sub, k);
        let mut radii = Vec::with_capacity(2 * n_sub);
        for (which, row) in [k_row, v_row].into_iter().enumerate() {
            for (i, sub) in row.chunks_exact(k).enumerate() {
                let r: f32 = sub.iter().map(|x| x * x).sum::<f32>().sqrt();
                radii.push(r);
                let base = 2 * (which * n_sub + i);
                if r > 0.0 {
                    for (j, &x) in sub.iter().enumerate() {
                        cands.row_mut(base)[j] = x / r;
                        cands.row_mut(base + 1)[j] = -x / r;
                    }
                } else {
                    // degenerate zero subvector: arbitrary axis pair
                    cands.row_mut(base)[0] = 1.0;
                    cands.row_mut(base + 1)[0] = -1.0;
                }
            }
        }
        let n_dir = (1usize << spec.dir_bits()).min(cands.rows());
        let vectors = greedy_from_candidates(&cands, n_dir, seed);
        let dir = DirectionCodebook {
            vectors,
            bits: spec.dir_bits(),
            method: DirectionMethod::GreedyE8,
        };

        // Magnitude grid: empirical quantiles of the seed radii (sorted →
        // levels sorted, as MagnitudeCodebook::assign requires).
        radii.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n_mag = 1usize << spec.mag_bits();
        let hi = radii.len() - 1;
        let levels: Vec<f32> = (0..n_mag)
            .map(|i| radii[i * hi / (n_mag - 1).max(1)])
            .collect();
        let mag = MagnitudeCodebook {
            levels,
            bits: spec.mag_bits(),
            // descriptive only: the closest named method for an
            // empirically-fitted grid
            method: MagnitudeMethod::KMeans,
        };

        // The decode LUT, exactly as DaccDecoder expands it:
        // lut[m · nd + d] = level_m · dir_d, each entry the same f32
        // multiply as the scalar decode — LUT rows are bit-identical.
        let (nd, nm) = (dir.len(), mag.len());
        let mut data = vec![0.0f32; nd * nm * k];
        for m in 0..nm {
            let level = mag.level(m as u32);
            for d in 0..nd {
                let dst = &mut data[(m * nd + d) * k..(m * nd + d + 1) * k];
                for (o, &dj) in dst.iter_mut().zip(dir.vectors.row(d)) {
                    *o = level * dj;
                }
            }
        }
        let lut = Arc::new(DecodeLut::new(
            Arc::new(Matrix::from_vec(data, nd * nm, k)),
            vec![1, nd],
        ));
        KvLayerCodec { dir, mag, lut }
    }

    /// Quantize one subvector to its joint code: low bits = direction
    /// index, high bits = magnitude index.
    #[inline]
    pub fn encode_sub(&self, sub: &[f32]) -> u64 {
        let r: f32 = sub.iter().map(|x| x * x).sum::<f32>().sqrt();
        let mut unit = [0.0f32; KvQuantSpec::K];
        if r > 0.0 {
            for (o, &x) in unit.iter_mut().zip(sub) {
                *o = x / r;
            }
        } else {
            unit[0] = 1.0; // degenerate zero vector: arbitrary direction
        }
        let d = self.dir.assign(&unit) as u64;
        let m = self.mag.assign(r) as u64;
        (m << self.dir.bits) | d
    }

    /// The decoded 2-float subvector of one joint code — a single
    /// contiguous [`DecodeLut`] row gather, bit-identical on every call.
    #[inline]
    pub fn decode_code(&self, code: u64) -> &[f32] {
        let d = (code & ((1u64 << self.dir.bits) - 1)) as usize;
        let m = (code >> self.dir.bits) as usize;
        self.lut.row(m * self.dir.len() + d)
    }

    /// Bits of this layer's stored codebook state (directions + levels).
    /// The decode LUT is derived and contributes nothing, mirroring
    /// [`crate::quant::CodeDecoder::codebook_bits`].
    pub fn codebook_bits(&self) -> u64 {
        (self.dir.len() * self.dir.dim() * 32 + self.mag.len() * 32) as u64
    }

    /// The pre-expanded decode table (for diagnostics/tests).
    pub fn lut(&self) -> &Arc<DecodeLut> {
        &self.lut
    }
}

/// The shared per-server cache codec: one frozen [`KvLayerCodec`] per
/// layer, built on each layer's first write and immutable afterwards.
/// `Arc`-shared by every slot cache and the paged pool, so shared prefix
/// pages carry codes every reader decodes identically.
pub struct KvQuantCodec {
    spec: KvQuantSpec,
    d_model: usize,
    seed: u64,
    layers: Vec<OnceLock<KvLayerCodec>>,
    /// Decode-tile traffic: LUT row gathers performed (write-path decode +
    /// explicit re-decodes), folded into `Metrics::kv_decoded_tiles`.
    decoded_subvecs: AtomicU64,
}

impl KvQuantCodec {
    /// A fresh, unfrozen codec for `n_layer` layers of `d_model`-wide rows.
    pub fn new(spec: KvQuantSpec, n_layer: usize, d_model: usize, seed: u64) -> Self {
        assert_eq!(
            d_model % KvQuantSpec::K,
            0,
            "d_model {d_model} not divisible by the cache subvector dim {}",
            KvQuantSpec::K
        );
        KvQuantCodec {
            spec,
            d_model,
            seed,
            layers: (0..n_layer).map(|_| OnceLock::new()).collect(),
            decoded_subvecs: AtomicU64::new(0),
        }
    }

    pub fn spec(&self) -> KvQuantSpec {
        self.spec
    }

    pub fn n_layer(&self) -> usize {
        self.layers.len()
    }

    pub fn d_model(&self) -> usize {
        self.d_model
    }

    /// Subvectors per cache row.
    pub fn n_sub(&self) -> usize {
        self.d_model / KvQuantSpec::K
    }

    /// `u64` words per packed code row (rows are word-aligned).
    pub fn words_per_row(&self) -> usize {
        words_per_row(self.n_sub(), self.spec.code_width())
    }

    /// Resident payload bits of one packed code row, counting the allocated
    /// word-aligned storage (honest allocation accounting, ≥ the raw
    /// `n_sub · code_width` index bits by < 64).
    pub fn code_bits_per_row(&self) -> u64 {
        self.words_per_row() as u64 * 64
    }

    /// The frozen codec of `layer`, if its first write has happened.
    pub fn layer(&self, layer: usize) -> Option<&KvLayerCodec> {
        self.layers[layer].get()
    }

    /// True once every layer's codebook pair is frozen.
    pub fn frozen(&self) -> bool {
        self.frozen_range(0..self.layers.len())
    }

    /// True once every layer in `range` is frozen — the shard-node form
    /// (DESIGN.md §16): a node's codec keeps full-model geometry but the
    /// node only ever writes (and therefore freezes) its own layer range,
    /// so [`Self::frozen`] would never fire for it.
    pub fn frozen_range(&self, range: std::ops::Range<usize>) -> bool {
        self.layers[range].iter().all(|l| l.get().is_some())
    }

    /// The freeze-on-first-write gate: returns `layer`'s codec, building it
    /// from `(k_row, v_row)` if and only if this is the layer's first
    /// observation. Later calls ignore the rows entirely — the codebooks are
    /// frozen, which is what keeps eviction's slide+rebuild re-feed
    /// re-quantizing against the *same* grid it wrote with.
    ///
    /// Callers that fan writes out across threads must route the first
    /// write deterministically (the server steps the seeding slot inline
    /// before the slot fan-out); `OnceLock` makes a race safe but not
    /// schedule-independent.
    pub fn observe(&self, layer: usize, k_row: &[f32], v_row: &[f32]) -> &KvLayerCodec {
        self.layers[layer].get_or_init(|| {
            let seed = self.seed ^ (layer as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
            KvLayerCodec::build(self.spec, k_row, v_row, seed)
        })
    }

    /// Quantize `row` against the frozen `lc`: pack one joint code per
    /// subvector into `words` (word-aligned row layout) and write the
    /// LUT-decoded tile into `out`. `out` afterwards equals what
    /// [`Self::decode_row`] reproduces from `words` — bit-identical.
    pub fn encode_row(&self, lc: &KvLayerCodec, row: &[f32], words: &mut [u64], out: &mut [f32]) {
        let k = KvQuantSpec::K;
        let width = self.spec.code_width() as usize;
        debug_assert_eq!(row.len(), self.d_model);
        debug_assert_eq!(words.len(), self.words_per_row());
        words.fill(0);
        let mut bit = 0usize;
        for (sub, dst) in row.chunks_exact(k).zip(out.chunks_exact_mut(k)) {
            let code = lc.encode_sub(sub);
            let (wi, off) = (bit / 64, bit % 64);
            words[wi] |= code << off;
            if width > 64 - off {
                words[wi + 1] |= code >> (64 - off);
            }
            bit += width;
            dst.copy_from_slice(lc.decode_code(code));
        }
        self.decoded_subvecs.fetch_add(self.n_sub() as u64, Ordering::Relaxed);
    }

    /// Re-decode a packed code row into `out` through the LUT —
    /// bit-identical to the tile [`Self::encode_row`] wrote, proving the
    /// f32 tile is derived state (like the weight kernel's LUTs).
    pub fn decode_row(&self, lc: &KvLayerCodec, words: &[u64], out: &mut [f32]) {
        let k = KvQuantSpec::K;
        let width = self.spec.code_width() as usize;
        let mask = (1u64 << width) - 1;
        let mut bit = 0usize;
        for dst in out.chunks_exact_mut(k) {
            let (wi, off) = (bit / 64, bit % 64);
            let mut code = words[wi] >> off;
            if width > 64 - off {
                code |= words[wi + 1] << (64 - off);
            }
            dst.copy_from_slice(lc.decode_code(code & mask));
            bit += width;
        }
        self.decoded_subvecs.fetch_add(self.n_sub() as u64, Ordering::Relaxed);
    }

    /// Bits of the frozen per-layer codebooks (directions + magnitude
    /// levels, summed over frozen layers; decode LUTs and decoded tiles are
    /// derived state and contribute nothing).
    pub fn codebook_bits(&self) -> u64 {
        self.layers
            .iter()
            .filter_map(|l| l.get())
            .map(|lc| lc.codebook_bits())
            .sum()
    }

    /// Monotonic decode-tile counter: LUT subvector gathers so far.
    pub fn decoded_subvecs(&self) -> u64 {
        self.decoded_subvecs.load(Ordering::Relaxed)
    }
}

impl fmt::Debug for KvQuantCodec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "KvQuantCodec(bits={}, dir={}, mag={}, layers={}, frozen={})",
            self.spec.bits(),
            self.spec.dir_bits(),
            self.spec.mag_bits(),
            self.layers.len(),
            self.frozen()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn rows(d: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        (rng.normal_vec(d), rng.normal_vec(d))
    }

    #[test]
    fn spec_bit_budget_mapping() {
        // (bits, dir, mag): the per-value budget b splits mag = b/2,
        // dir = 2b - b/2 per 2-dim subvector
        for (b, dir, mag) in [(8u32, 12u32, 4u32), (6, 9, 3), (4, 6, 2), (2, 3, 1)] {
            let s = KvQuantSpec::new(b).unwrap();
            assert_eq!((s.dir_bits(), s.mag_bits()), (dir, mag), "bits={b}");
            assert_eq!(s.code_width(), 2 * b);
        }
        assert!(KvQuantSpec::new(0).is_err());
        assert!(KvQuantSpec::new(1).is_err());
        assert!(KvQuantSpec::new(9).is_err());
    }

    #[test]
    fn word_alignment_accounting() {
        // 32 subvectors at widths 4..16 bits: exact word multiples on the
        // d=64 testbed, and the general ceil for odd shapes
        assert_eq!(words_per_row(32, 16), 8);
        assert_eq!(words_per_row(32, 8), 4);
        assert_eq!(words_per_row(32, 4), 2);
        assert_eq!(words_per_row(5, 12), 1);
        assert_eq!(words_per_row(6, 12), 2);
    }

    #[test]
    fn freeze_on_first_observation() {
        let d = 64usize;
        let codec = KvQuantCodec::new(KvQuantSpec::new(4).unwrap(), 2, d, 7);
        assert!(!codec.frozen());
        let (k0, v0) = rows(d, 1);
        let lc = codec.observe(0, &k0, &v0);
        let first_dirs: Vec<u32> =
            lc.dir.vectors.as_slice().iter().map(|v| v.to_bits()).collect();
        let first_levels = lc.mag.levels.clone();
        // a second observation with different rows must NOT rebuild
        let (k1, v1) = rows(d, 2);
        let lc2 = codec.observe(0, &k1, &v1);
        let again: Vec<u32> =
            lc2.dir.vectors.as_slice().iter().map(|v| v.to_bits()).collect();
        assert_eq!(first_dirs, again, "layer codebook was rebuilt");
        assert_eq!(first_levels, lc2.mag.levels);
        assert!(!codec.frozen(), "layer 1 still unfrozen");
        codec.observe(1, &k1, &v1);
        assert!(codec.frozen());
        // and the build itself is deterministic in (rows, seed)
        let codec_b = KvQuantCodec::new(KvQuantSpec::new(4).unwrap(), 2, d, 7);
        let lc_b = codec_b.observe(0, &k0, &v0);
        let redo: Vec<u32> =
            lc_b.dir.vectors.as_slice().iter().map(|v| v.to_bits()).collect();
        assert_eq!(first_dirs, redo, "same seed row, different codebook");
    }

    #[test]
    fn encode_decode_roundtrip_is_bit_stable() {
        let d = 64usize;
        for bits in [2u32, 4, 6, 8] {
            let codec = KvQuantCodec::new(KvQuantSpec::new(bits).unwrap(), 1, d, 11);
            let (k0, v0) = rows(d, 3);
            let lc = codec.observe(0, &k0, &v0);
            let mut words = vec![0u64; codec.words_per_row()];
            let mut tile = vec![0.0f32; d];
            codec.encode_row(lc, &v0, &mut words, &mut tile);
            assert!(tile.iter().all(|x| x.is_finite()));
            // the tile is derived state: re-decoding the packed codes
            // reproduces it bit-for-bit
            let mut redecoded = vec![0.0f32; d];
            codec.decode_row(lc, &words, &mut redecoded);
            let a: Vec<u32> = tile.iter().map(|v| v.to_bits()).collect();
            let b: Vec<u32> = redecoded.iter().map(|v| v.to_bits()).collect();
            assert_eq!(a, b, "bits={bits}: redecode diverged from the write tile");
        }
    }

    #[test]
    fn higher_bits_reduce_row_error() {
        let d = 64usize;
        let (k0, v0) = rows(d, 5);
        let err_at = |bits: u32| -> f32 {
            let codec = KvQuantCodec::new(KvQuantSpec::new(bits).unwrap(), 1, d, 13);
            let lc = codec.observe(0, &k0, &v0);
            let mut words = vec![0u64; codec.words_per_row()];
            let mut tile = vec![0.0f32; d];
            // quantize a *different* row than the seed pair — the honest
            // generalization case
            let mut rng = Rng::new(17);
            let probe = rng.normal_vec(d);
            codec.encode_row(lc, &probe, &mut words, &mut tile);
            probe.iter().zip(&tile).map(|(a, b)| (a - b) * (a - b)).sum::<f32>() / d as f32
        };
        let (e2, e8) = (err_at(2), err_at(8));
        assert!(e8 < e2, "8-bit cache ({e8}) should beat 2-bit ({e2})");
    }

    #[test]
    fn degenerate_zero_row_stays_finite() {
        let d = 16usize;
        let codec = KvQuantCodec::new(KvQuantSpec::new(4).unwrap(), 1, d, 19);
        let zeros = vec![0.0f32; d];
        let lc = codec.observe(0, &zeros, &zeros);
        let mut words = vec![0u64; codec.words_per_row()];
        let mut tile = vec![1.0f32; d];
        codec.encode_row(lc, &zeros, &mut words, &mut tile);
        assert!(tile.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn accounting_counts_codebooks_once_and_tiles_never() {
        let d = 64usize;
        let codec = KvQuantCodec::new(KvQuantSpec::new(8).unwrap(), 2, d, 23);
        assert_eq!(codec.codebook_bits(), 0, "unfrozen layers hold no state");
        let (k0, v0) = rows(d, 7);
        let lc = codec.observe(0, &k0, &v0);
        let expect =
            (lc.dir.len() * KvQuantSpec::K * 32 + lc.mag.len() * 32) as u64;
        assert_eq!(codec.codebook_bits(), expect);
        // the direction pool is 4·n_sub candidates, so the stored codebook
        // is min(2^dir_bits, 128) entries — accounting follows the actual
        // stored vectors, never the nominal 2^12
        assert_eq!(lc.dir.len(), 4 * codec.n_sub());
        // decode-tile traffic is a counter, not a byte account
        let before = codec.decoded_subvecs();
        let mut words = vec![0u64; codec.words_per_row()];
        let mut tile = vec![0.0f32; d];
        codec.encode_row(lc, &k0, &mut words, &mut tile);
        assert_eq!(codec.decoded_subvecs(), before + codec.n_sub() as u64);
        assert_eq!(codec.codebook_bits(), expect, "tile decode changed accounting");
    }
}
