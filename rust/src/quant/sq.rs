//! RTN scalar quantization (the SQ baseline core, paper Eq. 1).
//!
//! Symmetric uniform round-to-nearest per column, optionally with a searched
//! clipping factor (minimizing the column MSE over a grid of clip ratios),
//! which is the standard "RTN+" trick most SQ papers start from. At 2 bits
//! this collapses badly — exactly the phenomenon motivating VQ (paper §1).
//!
//! The emitted artifact is the real compressed form: a single packed stream
//! of `bits`-wide offset codes (`code = q − qmin`, one per weight, k = 1)
//! plus one f32 scale per column; dequantization is `(code + qmin) · s_j`.

use std::sync::{Arc, OnceLock};

use crate::quant::packing::{PackedIndices, PackedStreams};
use crate::quant::{CodeDecoder, DecodeLut, QuantizedWeight, Quantizer};
use crate::tensor::Matrix;

/// Decoder for symmetric uniform scalar codes: record → signed level
/// `record + qmin` (per-column scales fold in via the artifact's scale
/// vector). Stateless — the "codebook" is the integer grid (so its decode
/// LUT for the blocked kernel
/// ([`crate::quant::QuantizedWeight::matmul_from_codes`]) is just the grid
/// materialized, `2^bits` f32 levels).
pub struct ScalarDecoder {
    bits: u32,
    qmin: i64,
    /// Lazily materialized integer grid for [`CodeDecoder::decode_lut`] —
    /// derived state, zero artifact bits.
    lut: OnceLock<Arc<DecodeLut>>,
}

impl ScalarDecoder {
    pub fn new(bits: u32) -> Self {
        assert!(bits >= 1 && bits < 32);
        ScalarDecoder { bits, qmin: -(1i64 << (bits - 1)), lut: OnceLock::new() }
    }

    pub fn bits(&self) -> u32 {
        self.bits
    }
}

/// Widest scalar grid worth materializing as a LUT (`2^16` f32 = 256 KiB);
/// wider grids fall back to per-record decode in the blocked kernel.
const MAX_LUT_BITS: u32 = 16;

impl CodeDecoder for ScalarDecoder {
    fn k(&self) -> usize {
        1
    }

    #[inline]
    fn decode_into(&self, records: &[u64], out: &mut [f32]) {
        out[0] = (records[0] as i64 + self.qmin) as f32;
    }

    fn decode_lut(&self) -> Option<Arc<DecodeLut>> {
        if self.bits > MAX_LUT_BITS {
            return None;
        }
        Some(Arc::clone(self.lut.get_or_init(|| {
            let n = 1usize << self.bits;
            // the same `record + qmin → f32` conversion as decode_into, so
            // LUT entries are bit-identical to the scalar decode
            let data: Vec<f32> = (0..n).map(|i| (i as i64 + self.qmin) as f32).collect();
            Arc::new(DecodeLut::new(Arc::new(Matrix::from_vec(data, n, 1)), vec![1]))
        })))
    }

    fn codebook_bits(&self) -> u64 {
        0
    }

    fn spec(&self) -> String {
        format!("uniform-scalar-{}b", self.bits)
    }

    fn persist(&self) -> crate::quant::DecoderPersist<'_> {
        crate::quant::DecoderPersist::Scalar { bits: self.bits }
    }
}

/// Round-to-nearest scalar quantizer.
#[derive(Clone, Debug)]
pub struct Rtn {
    /// Bit width b (levels span `[-2^{b-1}, 2^{b-1} - 1]`).
    pub bits: u32,
    /// If true, search the per-column clip ratio over a grid instead of
    /// using max(|w|).
    pub search_clip: bool,
}

impl Rtn {
    pub fn new(bits: u32) -> Self {
        Rtn { bits, search_clip: false }
    }

    pub fn with_clip_search(bits: u32) -> Self {
        Rtn { bits, search_clip: true }
    }

    /// Quantize one column into offset codes given a clip scale; returns the
    /// column MSE.
    fn quantize_col(col: &[f32], bits: u32, scale: f32, codes: &mut [u64]) -> f64 {
        let qmax = (1i64 << (bits - 1)) - 1;
        let qmin = -(1i64 << (bits - 1));
        let mut mse = 0.0f64;
        let s = if scale > 0.0 { scale } else { 1.0 };
        for (c, &x) in codes.iter_mut().zip(col) {
            let q = ((x / s).round() as i64).clamp(qmin, qmax);
            let d = (q as f32 * s - x) as f64;
            mse += d * d;
            *c = (q - qmin) as u64;
        }
        mse
    }
}

impl Quantizer for Rtn {
    fn name(&self) -> String {
        if self.search_clip {
            format!("rtn{}-clip", self.bits)
        } else {
            format!("rtn{}", self.bits)
        }
    }

    fn quantize(&self, w: &Matrix) -> QuantizedWeight {
        let qmax = ((1i64 << (self.bits - 1)) - 1) as f32;
        let cols = w.cols();
        let mut records = vec![0u64; w.len()];
        let mut scales = Vec::with_capacity(cols);
        let mut col_codes = vec![0u64; w.rows()];
        for j in 0..cols {
            let col = w.col(j);
            let maxabs = col.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
            let base_scale = maxabs / qmax;
            let best = if self.search_clip {
                // grid search clip ratio in [0.3, 1.0]
                let mut best_scale = base_scale;
                let mut best_mse = f64::INFINITY;
                for step in 0..15 {
                    let ratio = 0.3 + 0.05 * step as f32;
                    let s = base_scale * ratio;
                    let mse = Self::quantize_col(&col, self.bits, s, &mut col_codes);
                    if mse < best_mse {
                        best_mse = mse;
                        best_scale = s;
                    }
                }
                best_scale
            } else {
                base_scale
            };
            Self::quantize_col(&col, self.bits, best, &mut col_codes);
            // effective scale (0-scale columns quantize with s = 1.0)
            scales.push(if best > 0.0 { best } else { 1.0 });
            for (i, &c) in col_codes.iter().enumerate() {
                records[i * cols + j] = c;
            }
        }
        let codes = PackedStreams::single(PackedIndices::pack(&records, self.bits));
        QuantizedWeight::new(
            self.name(),
            w.rows(),
            cols,
            codes,
            Arc::new(ScalarDecoder::new(self.bits)),
            scales,
            None,
        )
    }

    fn bits_per_weight(&self) -> f64 {
        self.bits as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn gaussian(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::from_vec(rng.normal_vec(rows * cols), rows, cols)
    }

    #[test]
    fn high_bits_nearly_lossless() {
        let w = gaussian(64, 16, 1);
        let q = Rtn::new(8).quantize(&w);
        assert!(q.dequantize().mse(&w) < 1e-3);
    }

    #[test]
    fn error_decreases_with_bits() {
        let w = gaussian(64, 16, 2);
        let e2 = Rtn::new(2).quantize(&w).dequantize().mse(&w);
        let e4 = Rtn::new(4).quantize(&w).dequantize().mse(&w);
        let e8 = Rtn::new(8).quantize(&w).dequantize().mse(&w);
        assert!(e2 > e4 && e4 > e8, "e2={e2} e4={e4} e8={e8}");
    }

    #[test]
    fn clip_search_beats_plain_at_low_bits() {
        let w = gaussian(128, 32, 3);
        let plain = Rtn::new(2).quantize(&w).dequantize().mse(&w);
        let clip = Rtn::with_clip_search(2).quantize(&w).dequantize().mse(&w);
        assert!(clip <= plain, "clip {clip} vs plain {plain}");
    }

    #[test]
    fn output_values_on_grid() {
        let w = gaussian(32, 4, 4);
        let q = Rtn::new(2).quantize(&w);
        // 2-bit symmetric: at most 4 distinct values per column
        let deq = q.dequantize();
        for j in 0..4 {
            let mut vals: Vec<f32> = deq.col(j);
            vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
            vals.dedup();
            assert!(vals.len() <= 4, "col {j} has {} levels", vals.len());
        }
    }

    #[test]
    fn payload_accounting() {
        let w = gaussian(64, 8, 5);
        let q = Rtn::new(2).quantize(&w);
        assert_eq!(q.payload_bits(), 64 * 8 * 2 + 8 * 32);
        // scalar methods reference no shared codebook
        assert_eq!(q.codebook_bits(), 0);
    }

    #[test]
    fn scalar_lut_bit_identical_to_decode_into() {
        for bits in [1u32, 2, 3, 8] {
            let dec = ScalarDecoder::new(bits);
            let lut = dec.decode_lut().expect("narrow grids expand");
            assert_eq!(lut.n_entries(), 1 << bits);
            assert_eq!((lut.k(), lut.n_strides()), (1, 1));
            let mut out = [0.0f32];
            for r in 0..(1u64 << bits) {
                dec.decode_into(&[r], &mut out);
                assert_eq!(
                    lut.row(lut.index(&[r]))[0].to_bits(),
                    out[0].to_bits(),
                    "bits={bits} rec={r}"
                );
            }
            // the grid is stateless: LUT stays derived, codebook bits stay 0
            assert_eq!(dec.codebook_bits(), 0);
        }
        // past the cap the decoder declines and the kernel falls back
        assert!(ScalarDecoder::new(MAX_LUT_BITS + 1).decode_lut().is_none());
    }

    #[test]
    fn blocked_kernel_bit_identical_for_scalar_codes() {
        // k = 1: every "vector" is a single element, the hardest shape for
        // the tile→segment walk (segments of length cols)
        let w = gaussian(32, 12, 7);
        let qw = Rtn::new(3).quantize(&w);
        let mut rng = Rng::new(8);
        let x = Matrix::from_vec(rng.normal_vec(2 * 32), 2, 32);
        let scalar = qw.matmul_from_codes_scalar(&x);
        for block in [1usize, 7, qw.default_block_vecs(), qw.n_vectors()] {
            for lut in [false, true] {
                let blocked = qw.matmul_from_codes_blocked(&x, block, lut);
                let a: Vec<u32> = scalar.as_slice().iter().map(|v| v.to_bits()).collect();
                let b: Vec<u32> = blocked.as_slice().iter().map(|v| v.to_bits()).collect();
                assert_eq!(a, b, "block={block} lut={lut}");
            }
        }
    }

    #[test]
    fn codes_stay_resident_not_dense() {
        // the artifact itself holds only packed codes + scales
        let w = gaussian(64, 8, 6);
        let q = Rtn::new(3).quantize(&w);
        assert_eq!(q.codes().n_streams(), 1);
        assert_eq!(q.codes().len(), 64 * 8);
        assert_eq!(q.codes().record_bits(), 3);
    }
}
