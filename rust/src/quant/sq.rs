//! RTN scalar quantization (the SQ baseline core, paper Eq. 1).
//!
//! Symmetric uniform round-to-nearest per column, optionally with a searched
//! clipping factor (minimizing the column MSE over a grid of clip ratios),
//! which is the standard "RTN+" trick most SQ papers start from. At 2 bits
//! this collapses badly — exactly the phenomenon motivating VQ (paper §1).

use crate::quant::{QuantizedWeight, Quantizer};
use crate::tensor::Matrix;

/// Round-to-nearest scalar quantizer.
#[derive(Clone, Debug)]
pub struct Rtn {
    /// Bit width b (levels span `[-2^{b-1}, 2^{b-1} - 1]`).
    pub bits: u32,
    /// If true, search the per-column clip ratio over a grid instead of
    /// using max(|w|).
    pub search_clip: bool,
}

impl Rtn {
    pub fn new(bits: u32) -> Self {
        Rtn { bits, search_clip: false }
    }

    pub fn with_clip_search(bits: u32) -> Self {
        Rtn { bits, search_clip: true }
    }

    /// Quantize one column in place given a clip scale; returns the column
    /// MSE.
    fn quantize_col(col: &[f32], bits: u32, scale: f32, out: &mut [f32]) -> f64 {
        let qmax = (1i64 << (bits - 1)) - 1;
        let qmin = -(1i64 << (bits - 1));
        let mut mse = 0.0f64;
        let s = if scale > 0.0 { scale } else { 1.0 };
        for (o, &x) in out.iter_mut().zip(col) {
            let q = (x / s).round() as i64;
            let q = q.clamp(qmin, qmax);
            let deq = q as f32 * s;
            let d = (deq - x) as f64;
            mse += d * d;
            *o = deq;
        }
        mse
    }
}

impl Quantizer for Rtn {
    fn name(&self) -> String {
        if self.search_clip {
            format!("rtn{}-clip", self.bits)
        } else {
            format!("rtn{}", self.bits)
        }
    }

    fn quantize(&self, w: &Matrix) -> QuantizedWeight {
        let qmax = ((1i64 << (self.bits - 1)) - 1) as f32;
        let mut out = Matrix::zeros(w.rows(), w.cols());
        let mut scratch = vec![0.0f32; w.rows()];
        for j in 0..w.cols() {
            let col = w.col(j);
            let maxabs = col.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
            let base_scale = maxabs / qmax;
            let best = if self.search_clip {
                // grid search clip ratio in [0.3, 1.0]
                let mut best_scale = base_scale;
                let mut best_mse = f64::INFINITY;
                for step in 0..15 {
                    let ratio = 0.3 + 0.05 * step as f32;
                    let s = base_scale * ratio;
                    let mse = Self::quantize_col(&col, self.bits, s, &mut scratch);
                    if mse < best_mse {
                        best_mse = mse;
                        best_scale = s;
                    }
                }
                best_scale
            } else {
                base_scale
            };
            Self::quantize_col(&col, self.bits, best, &mut scratch);
            out.set_col(j, &scratch);
        }
        // payload: indices + per-column scale
        let bits = w.len() as u64 * self.bits as u64 + w.cols() as u64 * 32;
        QuantizedWeight::new(out, bits, self.name())
    }

    fn bits_per_weight(&self) -> f64 {
        self.bits as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn gaussian(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::from_vec(rng.normal_vec(rows * cols), rows, cols)
    }

    #[test]
    fn high_bits_nearly_lossless() {
        let w = gaussian(64, 16, 1);
        let q = Rtn::new(8).quantize(&w);
        assert!(q.dequantize().mse(&w) < 1e-3);
    }

    #[test]
    fn error_decreases_with_bits() {
        let w = gaussian(64, 16, 2);
        let e2 = Rtn::new(2).quantize(&w).dequantize().mse(&w);
        let e4 = Rtn::new(4).quantize(&w).dequantize().mse(&w);
        let e8 = Rtn::new(8).quantize(&w).dequantize().mse(&w);
        assert!(e2 > e4 && e4 > e8, "e2={e2} e4={e4} e8={e8}");
    }

    #[test]
    fn clip_search_beats_plain_at_low_bits() {
        let w = gaussian(128, 32, 3);
        let plain = Rtn::new(2).quantize(&w).dequantize().mse(&w);
        let clip = Rtn::with_clip_search(2).quantize(&w).dequantize().mse(&w);
        assert!(clip <= plain, "clip {clip} vs plain {plain}");
    }

    #[test]
    fn output_values_on_grid() {
        let w = gaussian(32, 4, 4);
        let q = Rtn::new(2).quantize(&w);
        // 2-bit symmetric: at most 4 distinct values per column
        for j in 0..4 {
            let mut vals: Vec<f32> = q.dequantize().col(j);
            vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
            vals.dedup();
            assert!(vals.len() <= 4, "col {j} has {} levels", vals.len());
        }
    }

    #[test]
    fn payload_accounting() {
        let w = gaussian(64, 8, 5);
        let q = Rtn::new(2).quantize(&w);
        assert_eq!(q.payload_bits(), 64 * 8 * 2 + 8 * 32);
    }
}
