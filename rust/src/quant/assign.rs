//! Batched nearest-codeword search — the L3 quantization hot path.
//!
//! Both PCDVQ (cosine / max dot product) and the coupled-VQ baselines
//! (Euclidean) reduce to `argmax_j (v·c_j + bias_j)` over codebook rows:
//! cosine uses `bias = 0` on unit rows; Euclidean uses `bias_j = -‖c_j‖²/2`
//! since `argmin ‖v-c‖² = argmax (v·c − ‖c‖²/2)`.
//!
//! The scan is blocked over codebook rows so a tile of the codebook stays in
//! L1/L2 cache while a strip of vectors streams through, with a specialized
//! `k = 8` inner kernel (the paper's vector dimension) that LLVM lowers to
//! packed-SIMD dot products. The same tiling scheme is what the Pallas
//! `assign` kernel (L1) expresses with BlockSpecs for VMEM.

use crate::tensor::Matrix;

/// Tunable strip sizes (chosen by the §Perf pass; see EXPERIMENTS.md).
const CB_TILE: usize = 512;

/// §Perf: the k = 8 fast path uses a *transposed* codebook tile
/// (k × CB_TILE, each component row contiguous over codebook indices) so the
/// inner loop is `score[j] += v_d * ct[d][j]` — a pure vertical SIMD FMA over
/// `j` with no horizontal reduction, which LLVM lowers to 8-lane AVX2. The
/// row-major variant (one dot per codebook row) measured 0.14 Gdot/s; this
/// layout reaches ~0.6 Gdot/s on the same core (see EXPERIMENTS.md §Perf).
struct TransposedTile {
    /// k rows × CB_TILE cols, row-major.
    data: Vec<f32>,
    width: usize,
}

impl TransposedTile {
    fn new(k: usize) -> Self {
        TransposedTile { data: vec![0.0; k * CB_TILE], width: 0 }
    }

    fn load(&mut self, codebook: &Matrix, tile_start: usize, tile_end: usize) {
        let k = codebook.cols();
        let w = tile_end - tile_start;
        self.width = w;
        for (jj, j) in (tile_start..tile_end).enumerate() {
            let row = codebook.row(j);
            for d in 0..k {
                self.data[d * CB_TILE + jj] = row[d];
            }
        }
    }

    #[inline]
    fn component(&self, d: usize) -> &[f32] {
        &self.data[d * CB_TILE..d * CB_TILE + self.width]
    }
}

/// §Perf: below this many vectors a strip is not worth a thread (tile loads
/// and thread spawn dominate); the parallel split keeps strips at least this
/// long.
const MIN_STRIP: usize = 2048;

/// Find, for every row of `vectors`, the index of the codebook row with the
/// highest score `v·c_j + bias_j`.
///
/// `bias` is either empty (cosine on unit rows) or one value per codebook
/// row (Euclidean).
pub fn assign_batch(vectors: &Matrix, codebook: &Matrix, bias: &[f32]) -> Vec<u32> {
    let mut out = vec![0u32; vectors.rows()];
    assign_into(vectors, codebook, bias, &mut out);
    out
}

/// Run `f` with [`assign_into`] capped at `threads` workers on this thread —
/// the coordination hook for callers that already parallelize at a coarser
/// grain (the layer-parallel scheduler pins its workers' inner parallelism
/// to 1 thread so the machine is not oversubscribed). Since PR 5 this is an
/// alias for [`crate::exec::with_threads`]: the cap applies to *every*
/// pool-driven kernel on this thread (assignment and the fused matmul
/// alike), which is exactly what a coarser-grain caller wants.
pub fn with_assign_threads<R>(threads: usize, f: impl FnOnce() -> R) -> R {
    crate::exec::with_threads(threads, f)
}

/// [`assign_batch`] into a caller-provided buffer (no allocation beyond the
/// per-call scratch — used by the scheduler's per-worker loops).
///
/// The vector strip is split across the shared worker pool
/// ([`crate::exec::Pool`]: each worker owns a disjoint `out` chunk with
/// fixed [`crate::exec::partition`] boundaries, so writes are deterministic
/// and the result is bit-identical to the serial scan regardless of thread
/// count). Thread count defaults to [`crate::exec::current_threads`]
/// (`PALLAS_THREADS` overrides the process default; an enclosing
/// [`with_assign_threads`]/[`crate::exec::with_threads`] overrides it per
/// thread), capped so each strip keeps at least [`MIN_STRIP`] vectors.
pub fn assign_into(vectors: &Matrix, codebook: &Matrix, bias: &[f32], out: &mut [u32]) {
    assign_into_with_threads(vectors, codebook, bias, out, crate::exec::current_threads())
}

/// [`assign_into`] with an explicit worker count (1 = the serial scan; the
/// benches use this to measure the before/after split).
pub fn assign_into_with_threads(
    vectors: &Matrix,
    codebook: &Matrix,
    bias: &[f32],
    out: &mut [u32],
    threads: usize,
) {
    assert_eq!(out.len(), vectors.rows());
    assert_eq!(vectors.cols(), codebook.cols(), "dimension mismatch");
    assert!(
        bias.is_empty() || bias.len() == codebook.rows(),
        "bias length must match codebook rows"
    );
    let n = vectors.rows();
    if n == 0 {
        return;
    }
    // Deterministic split through the shared pool contract: fixed-size
    // strips in row order, never shorter than MIN_STRIP; each worker writes
    // only its own chunk.
    crate::exec::Pool::new(threads).scope_groups_mut(out, 1, MIN_STRIP, |row_start, chunk| {
        assign_strip(vectors, row_start, row_start + chunk.len(), codebook, bias, chunk);
    });
}

/// Serial scan over the vector strip `[row_start, row_end)`; `out` has one
/// slot per strip row.
fn assign_strip(
    vectors: &Matrix,
    row_start: usize,
    row_end: usize,
    codebook: &Matrix,
    bias: &[f32],
    out: &mut [u32],
) {
    debug_assert_eq!(out.len(), row_end - row_start);
    let k = vectors.cols();
    let n_cb = codebook.rows();
    let mut best_score = vec![f32::NEG_INFINITY; row_end - row_start];
    let mut tile = TransposedTile::new(k);
    let mut scores = vec![0.0f32; CB_TILE];

    let mut tile_start = 0usize;
    while tile_start < n_cb {
        let tile_end = (tile_start + CB_TILE).min(n_cb);
        if k == 8 {
            tile.load(codebook, tile_start, tile_end);
            assign_tile_k8(
                vectors,
                row_start,
                &tile,
                bias,
                tile_start,
                tile_end,
                &mut scores,
                &mut best_score,
                out,
            );
        } else {
            assign_tile_generic(
                vectors,
                row_start,
                codebook,
                bias,
                tile_start,
                tile_end,
                &mut best_score,
                out,
            );
        }
        tile_start = tile_end;
    }
}

/// Specialized inner kernel for k = 8 over the transposed tile: phase 1
/// computes all CB_TILE scores for one vector with vertical SIMD FMAs
/// (no horizontal reductions); phase 2 folds the tile's argmax into the
/// running best. The tile (8×512 f32 = 16 KiB) stays L1-resident across all
/// vectors.
#[allow(clippy::too_many_arguments)]
fn assign_tile_k8(
    vectors: &Matrix,
    row_start: usize,
    tile: &TransposedTile,
    bias: &[f32],
    tile_start: usize,
    tile_end: usize,
    scores: &mut [f32],
    best_score: &mut [f32],
    out: &mut [u32],
) {
    let w = tile_end - tile_start;
    let (c0, c1, c2, c3, c4, c5, c6, c7) = (
        tile.component(0),
        tile.component(1),
        tile.component(2),
        tile.component(3),
        tile.component(4),
        tile.component(5),
        tile.component(6),
        tile.component(7),
    );
    for (i, (bs, o)) in best_score.iter_mut().zip(out.iter_mut()).enumerate() {
        let v = vectors.row(row_start + i);
        let (v0, v1, v2, v3, v4, v5, v6, v7) =
            (v[0], v[1], v[2], v[3], v[4], v[5], v[6], v[7]);
        let s = &mut scores[..w];
        // phase 1: vertical FMA over the tile — autovectorizes to 8-lane fma
        if bias.is_empty() {
            for j in 0..w {
                let a = v0 * c0[j] + v1 * c1[j] + v2 * c2[j] + v3 * c3[j];
                let b = v4 * c4[j] + v5 * c5[j] + v6 * c6[j] + v7 * c7[j];
                s[j] = a + b;
            }
        } else {
            let btile = &bias[tile_start..tile_end];
            for j in 0..w {
                let a = v0 * c0[j] + v1 * c1[j] + v2 * c2[j] + v3 * c3[j];
                let b = v4 * c4[j] + v5 * c5[j] + v6 * c6[j] + v7 * c7[j];
                s[j] = a + b + btile[j];
            }
        }
        // phase 2: argmax scan of the tile, folded into the running best
        let mut local_best = *bs;
        let mut local_idx = *o;
        for (j, &sc) in s.iter().enumerate() {
            if sc > local_best {
                local_best = sc;
                local_idx = (tile_start + j) as u32;
            }
        }
        *bs = local_best;
        *o = local_idx;
    }
}

#[allow(clippy::too_many_arguments)]
fn assign_tile_generic(
    vectors: &Matrix,
    row_start: usize,
    codebook: &Matrix,
    bias: &[f32],
    tile_start: usize,
    tile_end: usize,
    best_score: &mut [f32],
    out: &mut [u32],
) {
    for (i, (bs, o)) in best_score.iter_mut().zip(out.iter_mut()).enumerate() {
        let v = vectors.row(row_start + i);
        for j in tile_start..tile_end {
            let mut s = crate::tensor::dot(v, codebook.row(j));
            if !bias.is_empty() {
                s += bias[j];
            }
            if s > *bs {
                *bs = s;
                *o = j as u32;
            }
        }
    }
}

/// Euclidean bias vector: `-‖c_j‖²/2` per codebook row.
pub fn euclidean_bias(codebook: &Matrix) -> Vec<f32> {
    (0..codebook.rows())
        .map(|j| {
            let r = codebook.row(j);
            -0.5 * r.iter().map(|x| x * x).sum::<f32>()
        })
        .collect()
}

/// Convenience: Euclidean nearest-codeword assignment.
pub fn assign_euclidean(vectors: &Matrix, codebook: &Matrix) -> Vec<u32> {
    let bias = euclidean_bias(codebook);
    assign_batch(vectors, codebook, &bias)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::tensor::{dot, squared_distance};

    fn naive_cosine(vectors: &Matrix, cb: &Matrix) -> Vec<u32> {
        (0..vectors.rows())
            .map(|i| {
                let v = vectors.row(i);
                let mut best = 0u32;
                let mut best_s = f32::NEG_INFINITY;
                for j in 0..cb.rows() {
                    let s = dot(v, cb.row(j));
                    if s > best_s {
                        best_s = s;
                        best = j as u32;
                    }
                }
                best
            })
            .collect()
    }

    #[test]
    fn matches_naive_cosine_k8() {
        let mut rng = Rng::new(1);
        let vectors = Matrix::from_vec(rng.normal_vec(300 * 8), 300, 8);
        let mut cb = Matrix::from_vec(rng.normal_vec(1111 * 8), 1111, 8);
        for i in 0..cb.rows() {
            let r = cb.row_mut(i);
            let n: f32 = r.iter().map(|x| x * x).sum::<f32>().sqrt();
            r.iter_mut().for_each(|x| *x /= n);
        }
        assert_eq!(assign_batch(&vectors, &cb, &[]), naive_cosine(&vectors, &cb));
    }

    #[test]
    fn matches_naive_generic_k() {
        let mut rng = Rng::new(2);
        for k in [2usize, 4, 6, 16] {
            let vectors = Matrix::from_vec(rng.normal_vec(100 * k), 100, k);
            let cb = Matrix::from_vec(rng.normal_vec(70 * k), 70, k);
            assert_eq!(
                assign_batch(&vectors, &cb, &[]),
                naive_cosine(&vectors, &cb),
                "k={k}"
            );
        }
    }

    #[test]
    fn euclidean_assignment_is_true_nearest() {
        let mut rng = Rng::new(3);
        let vectors = Matrix::from_vec(rng.normal_vec(200 * 8), 200, 8);
        let cb = Matrix::from_vec(rng.normal_vec(600 * 8), 600, 8);
        let idx = assign_euclidean(&vectors, &cb);
        for i in 0..vectors.rows() {
            let v = vectors.row(i);
            let assigned_d = squared_distance(v, cb.row(idx[i] as usize));
            for j in 0..cb.rows() {
                assert!(
                    assigned_d <= squared_distance(v, cb.row(j)) + 1e-4,
                    "vector {i}: {j} closer than assigned {}",
                    idx[i]
                );
            }
        }
    }

    #[test]
    fn parallel_matches_serial_bit_exact() {
        // big enough that the parallel path actually splits (> MIN_STRIP)
        let mut rng = Rng::new(7);
        let n = 3 * MIN_STRIP + 137;
        let vectors = Matrix::from_vec(rng.normal_vec(n * 8), n, 8);
        let cb = Matrix::from_vec(rng.normal_vec(900 * 8), 900, 8);
        let mut serial = vec![0u32; n];
        assign_into_with_threads(&vectors, &cb, &[], &mut serial, 1);
        for threads in [2usize, 3, 7] {
            let mut par = vec![0u32; n];
            assign_into_with_threads(&vectors, &cb, &[], &mut par, threads);
            assert_eq!(par, serial, "threads={threads}");
        }
    }

    #[test]
    fn parallel_generic_k_matches_serial() {
        let mut rng = Rng::new(8);
        let n = 2 * MIN_STRIP + 11;
        let vectors = Matrix::from_vec(rng.normal_vec(n * 4), n, 4);
        let cb = Matrix::from_vec(rng.normal_vec(300 * 4), 300, 4);
        let bias = euclidean_bias(&cb);
        let mut serial = vec![0u32; n];
        assign_into_with_threads(&vectors, &cb, &bias, &mut serial, 1);
        let mut par = vec![0u32; n];
        assign_into_with_threads(&vectors, &cb, &bias, &mut par, 4);
        assert_eq!(par, serial);
    }

    #[test]
    fn tile_boundary_exactness() {
        // codebook larger than one tile (CB_TILE=512) exercises the running
        // max across tiles
        let mut rng = Rng::new(4);
        let vectors = Matrix::from_vec(rng.normal_vec(50 * 8), 50, 8);
        let cb = Matrix::from_vec(rng.normal_vec(1300 * 8), 1300, 8);
        assert_eq!(assign_batch(&vectors, &cb, &[]), naive_cosine(&vectors, &cb));
    }
}
