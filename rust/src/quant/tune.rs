//! Post-quantization correction analogs for the Table-3 fine-tuning ablation.
//!
//! The paper reuses QuIP#'s two-stage recipe: *block-wise* fine-tuning
//! (adjust unquantized weights inside each decoder block) and *end-to-end*
//! fine-tuning (adjust normalization parameters). Gradient training per
//! ablation cell is infeasible on this testbed, so — per DESIGN.md's
//! substitution table — we implement cheap closed-form corrections of the
//! same *kind*:
//!
//! * [`row_scale_correction`] — "block tuning" analog: per-output-row scale
//!   `s_i = ⟨w_i, ŵ_i⟩ / ⟨ŵ_i, ŵ_i⟩`, the least-squares optimal diagonal
//!   correction of the reconstructed weight (intra-layer, like block FT).
//! * e2e analog — a single logit temperature fitted on calibration NLL,
//!   implemented in `eval::ppl::fit_temperature` (end-to-end output
//!   correction, like norm-layer FT).

use crate::tensor::{dot, Matrix};

/// Least-squares optimal per-row scale correction.
///
/// Returns the corrected dequantized matrix and the scales applied. Storage
/// cost is one f32 per output row; callers add it to the payload accounting.
pub fn row_scale_correction(original: &Matrix, deq: &Matrix) -> (Matrix, Vec<f32>) {
    assert_eq!(original.rows(), deq.rows());
    assert_eq!(original.cols(), deq.cols());
    let mut out = deq.clone();
    let mut scales = Vec::with_capacity(original.rows());
    for i in 0..original.rows() {
        let w = original.row(i);
        let q = deq.row(i);
        let denom = dot(q, q);
        let s = if denom > 1e-12 { dot(w, q) / denom } else { 1.0 };
        scales.push(s);
        for x in out.row_mut(i) {
            *x *= s;
        }
    }
    (out, scales)
}

/// Frobenius error before/after a candidate correction — convenience used by
/// the Table-3 harness to report deltas.
pub fn correction_gain(original: &Matrix, deq: &Matrix, corrected: &Matrix) -> (f64, f64) {
    (original.mse(deq), original.mse(corrected))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn correction_never_hurts_mse() {
        let mut rng = Rng::new(1);
        let w = Matrix::from_vec(rng.normal_vec(64 * 32), 64, 32);
        // a biased reconstruction: rows shrunk by arbitrary factors
        let mut deq = w.clone();
        for i in 0..64 {
            let f = 0.5 + 0.01 * i as f32;
            for x in deq.row_mut(i) {
                *x *= f + 0.05 * rng.normal() as f32;
            }
        }
        let (corr, scales) = row_scale_correction(&w, &deq);
        let (before, after) = correction_gain(&w, &deq, &corr);
        assert!(after <= before + 1e-12, "after {after} vs before {before}");
        assert_eq!(scales.len(), 64);
    }

    #[test]
    fn exact_scale_recovered() {
        let mut rng = Rng::new(2);
        let w = Matrix::from_vec(rng.normal_vec(16 * 8), 16, 8);
        let mut deq = w.clone();
        for x in deq.as_mut_slice() {
            *x *= 0.25; // uniform shrink
        }
        let (corr, scales) = row_scale_correction(&w, &deq);
        for &s in &scales {
            assert!((s - 4.0).abs() < 1e-4);
        }
        assert!(w.mse(&corr) < 1e-10);
    }

    #[test]
    fn identity_input_gets_unit_scales() {
        let mut rng = Rng::new(3);
        let w = Matrix::from_vec(rng.normal_vec(8 * 8), 8, 8);
        let (_, scales) = row_scale_correction(&w, &w);
        for &s in &scales {
            assert!((s - 1.0).abs() < 1e-6);
        }
    }
}
