//! QuIP#-like baseline: RHT incoherence processing + a *coupled* E8-lattice
//! codebook with algebraic nearest-point decode.
//!
//! QuIP# (Tseng et al. 2024) = randomized Hadamard incoherence + the E8P
//! lattice codebook, assigning each k=8 vector to the nearest scaled E8
//! lattice point under the *Euclidean* metric. Direction and magnitude are
//! quantized together — the coupling (and Euclidean metric) PCDVQ's analysis
//! (§3.1) identifies as the accuracy bottleneck, which Fig 3 and Table 3
//! measure against this baseline.
//!
//! Implementation notes:
//! * Nearest E8 point uses the exact algebraic decoder (Conway & Sloane):
//!   `E8 = D8 ∪ (D8 + ½)`; nearest-D8 = round, fix parity by flipping the
//!   coordinate with the largest rounding error.
//! * The finite codebook is the `2^bits` lattice points most frequently hit
//!   by N(0,1)^8 samples at the chosen lattice scale (empirical typical set
//!   — QuIP#'s E8P ball construction plays the same role). Out-of-codebook
//!   decodes fall back to the most-probable in-codebook neighbour by local
//!   search over sign flips, then a linear scan (rare, tails only).
//! * Serving: artifacts decode through a [`TableDecoder`] over the
//!   materialized ball, so the blocked host kernel
//!   ([`crate::quant::QuantizedWeight::matmul_from_codes`]) gathers straight
//!   from the shared table as its decode LUT
//!   ([`crate::quant::CodeDecoder::decode_lut`]) — zero extra derived state.

use std::collections::HashMap;
use std::sync::Arc;

use crate::hadamard::{regularize, RandomizedHadamard};
use crate::quant::packing::{PackedIndices, PackedStreams};
use crate::quant::{QuantizedWeight, Quantizer, TableDecoder};
use crate::rng::Rng;
use crate::tensor::Matrix;

/// Doubled-coordinate E8 point (integers; actual point = `coords/2`).
type Point = [i16; 8];

/// Nearest point of `Z^8` with even coordinate sum (the D8 lattice), in
/// doubled coordinates, for input `x` (true coordinates).
fn nearest_d8(x: &[f32; 8], offset_half: bool) -> Point {
    // Work in true coordinates: round each (minus offset), fix parity.
    let mut rounded = [0i32; 8];
    let mut sum = 0i32;
    let mut worst = 0usize;
    let mut worst_gap = -1.0f32;
    for i in 0..8 {
        let t = if offset_half { x[i] - 0.5 } else { x[i] };
        let r = t.round();
        rounded[i] = r as i32;
        sum += r as i32;
        let gap = (t - r).abs();
        if gap > worst_gap {
            worst_gap = gap;
            worst = i;
        }
    }
    if sum.rem_euclid(2) != 0 {
        // flip the worst coordinate to the other side
        let t = if offset_half { x[worst] - 0.5 } else { x[worst] };
        let r = rounded[worst];
        rounded[worst] = if (t - r as f32) >= 0.0 { r + 1 } else { r - 1 };
    }
    let mut out = [0i16; 8];
    for i in 0..8 {
        let doubled = 2 * rounded[i] + if offset_half { 1 } else { 0 };
        out[i] = doubled as i16;
    }
    out
}

/// Exact nearest E8 lattice point (doubled coordinates).
pub fn nearest_e8(x: &[f32; 8]) -> Point {
    let a = nearest_d8(x, false);
    let b = nearest_d8(x, true);
    let d = |p: &Point| -> f32 {
        let mut s = 0.0;
        for i in 0..8 {
            let diff = x[i] - p[i] as f32 / 2.0;
            s += diff * diff;
        }
        s
    };
    if d(&a) <= d(&b) {
        a
    } else {
        b
    }
}

/// QuIP#-like quantizer.
pub struct QuipLike {
    /// Codebook bits per 8-vector (16 → 2.0 bpw, 17 → 2.125 bpw).
    pub bits: u32,
    /// Lattice scale: vectors are quantized as `s · nearest_e8(v / s)`.
    pub scale: f32,
    /// In-codebook lattice points and their index.
    book: HashMap<Point, u32>,
    /// Reverse map (index → point), reconstruction values.
    points: Vec<Point>,
    /// Materialized reconstruction table (`scale · point / 2` per row) — the
    /// shared codebook every emitted artifact references.
    recon: Arc<Matrix>,
    pub seed: u64,
}

impl QuipLike {
    /// Build the codebook as an E8 *ball* — the `2^bits` lattice points of
    /// smallest norm (QuIP#'s E8P is exactly a ball of E8+shift points) —
    /// and sweep the lattice scale for minimum MSE against N(0,1)^8 samples
    /// *with the finite book in the loop* (granular error vs overload
    /// clamping trade-off).
    pub fn build(bits: u32, seed: u64) -> Self {
        let n_book = 1usize << bits;
        // enumerate enough shells to fill the book
        let mut max_norm2 = 4i64;
        let mut pts = crate::lattice::e8::E8Points::enumerate(max_norm2);
        while pts.len() < n_book {
            max_norm2 += 2;
            assert!(max_norm2 <= 32, "E8 ball exhausted before {n_book} points");
            pts = crate::lattice::e8::E8Points::enumerate(max_norm2);
        }
        // the enumeration is already (norm, lex)-sorted; take the inner ball
        let points: Vec<Point> = pts
            .doubled
            .iter()
            .take(n_book)
            .map(|p| {
                let mut q = [0i16; 8];
                for i in 0..8 {
                    q[i] = p[i] as i16;
                }
                q
            })
            .collect();
        let book: HashMap<Point, u32> = points
            .iter()
            .enumerate()
            .map(|(i, &p)| (p, i as u32))
            .collect();

        // scale sweep with the finite book: minimize sample MSE
        let mut rng = Rng::new(seed);
        let sample: Vec<[f32; 8]> = (0..20_000)
            .map(|_| {
                let mut v = [0.0f32; 8];
                for x in v.iter_mut() {
                    *x = rng.normal() as f32;
                }
                v
            })
            .collect();
        let mut probe = QuipLike {
            bits,
            scale: 1.0,
            book,
            points,
            recon: Arc::new(Matrix::zeros(0, 0)),
            seed,
        };
        let mut best_scale = 1.0f32;
        let mut best_mse = f64::INFINITY;
        // the granular/overload optimum sits near chi_typical/ball_radius;
        // sweep a generous bracket around it
        let ball_r = ((max_norm2 as f32).sqrt()).max(1.0);
        let lo = 1.2 / ball_r;
        let hi = 6.5 / ball_r;
        for step in 0..28 {
            let s = lo + (hi - lo) * step as f32 / 27.0;
            probe.scale = s;
            let mut mse = 0.0f64;
            for v in &sample {
                let idx = probe.assign_vec(v);
                let rec = probe.decode(idx);
                for i in 0..8 {
                    let d = (v[i] - rec[i]) as f64;
                    mse += d * d;
                }
            }
            if mse < best_mse {
                best_mse = mse;
                best_scale = s;
            }
        }
        probe.scale = best_scale;
        // materialize the reconstruction table at the final scale
        let mut recon = Matrix::zeros(probe.points.len(), 8);
        for (i, p) in probe.points.iter().enumerate() {
            for (slot, &c) in recon.row_mut(i).iter_mut().zip(p.iter()) {
                *slot = probe.scale * c as f32 / 2.0;
            }
        }
        probe.recon = Arc::new(recon);
        probe
    }

    /// Expected per-element MSE on N(0,1) inputs (diagnostic).
    pub fn unit_gaussian_mse(&self, n_sample: usize, seed: u64) -> f64 {
        let mut rng = Rng::new(seed);
        let mut mse = 0.0f64;
        for _ in 0..n_sample {
            let mut v = [0.0f32; 8];
            for x in v.iter_mut() {
                *x = rng.normal() as f32;
            }
            let rec = self.decode(self.assign_vec(&v));
            for i in 0..8 {
                let d = (v[i] - rec[i]) as f64;
                mse += d * d;
            }
        }
        mse / (n_sample * 8) as f64
    }

    /// Codebook size actually realized.
    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Quantize one 8-vector (already RHT-regularized): index into the book.
    fn assign_vec(&self, v: &[f32; 8]) -> u32 {
        let mut scaled = [0.0f32; 8];
        for i in 0..8 {
            scaled[i] = v[i] / self.scale;
        }
        let p = nearest_e8(&scaled);
        if let Some(&idx) = self.book.get(&p) {
            return idx;
        }
        // Out-of-book (tail): shrink toward the origin until we land in the
        // book — preserves direction, pulls magnitude in, bounded iterations.
        let mut shrink = 0.9f32;
        for _ in 0..24 {
            let mut s2 = [0.0f32; 8];
            for i in 0..8 {
                s2[i] = scaled[i] * shrink;
            }
            let p = nearest_e8(&s2);
            if let Some(&idx) = self.book.get(&p) {
                return idx;
            }
            shrink *= 0.9;
        }
        // last resort: linear scan for nearest in-book point
        let mut best = 0u32;
        let mut best_d = f32::INFINITY;
        for (i, p) in self.points.iter().enumerate() {
            let mut d = 0.0f32;
            for j in 0..8 {
                let diff = scaled[j] - p[j] as f32 / 2.0;
                d += diff * diff;
            }
            if d < best_d {
                best_d = d;
                best = i as u32;
            }
        }
        best
    }

    /// Reconstruction for an index.
    fn decode(&self, idx: u32) -> [f32; 8] {
        let p = self.points[idx as usize];
        let mut v = [0.0f32; 8];
        for i in 0..8 {
            v[i] = self.scale * p[i] as f32 / 2.0;
        }
        v
    }
}

impl QuipLike {
    /// Pre/post pair **in the regularized domain** (Fig-3 harness; see
    /// `Pcdvq::quantize_regularized` for why decomposition must happen
    /// before the inverse RHT).
    pub fn quantize_regularized(&self, w: &Matrix) -> (Matrix, Matrix) {
        assert!(w.rows().is_power_of_two());
        let seed = self.seed ^ ((w.rows() as u64) << 32 ^ w.cols() as u64);
        let rht = RandomizedHadamard::new(w.rows(), seed);
        let (h, _) = regularize(w, &rht);
        let vectors = h.reshape_vectors(8);
        let mut flat = vec![0.0f32; w.len()];
        for i in 0..vectors.rows() {
            let mut v = [0.0f32; 8];
            v.copy_from_slice(vectors.row(i));
            let rec = self.decode(self.assign_vec(&v));
            flat[i * 8..(i + 1) * 8].copy_from_slice(&rec);
        }
        (h, Matrix::from_vec(flat, w.rows(), w.cols()))
    }
}

impl Quantizer for QuipLike {
    fn name(&self) -> String {
        format!("quip-like-{}b", self.bits)
    }

    fn quantize(&self, w: &Matrix) -> QuantizedWeight {
        assert!(w.rows().is_power_of_two(), "RHT requires power-of-two rows");
        assert_eq!(w.len() % 8, 0);
        let seed = self.seed ^ ((w.rows() as u64) << 32 ^ w.cols() as u64);
        let rht = RandomizedHadamard::new(w.rows(), seed);
        let (h, scales) = regularize(w, &rht);
        let vectors = h.reshape_vectors(8);
        let n_vec = vectors.rows();
        let mut records = Vec::with_capacity(n_vec);
        for i in 0..n_vec {
            let mut v = [0.0f32; 8];
            v.copy_from_slice(vectors.row(i));
            records.push(self.assign_vec(&v) as u64);
        }
        let codes = PackedStreams::single(PackedIndices::pack(&records, self.bits));
        let decoder = TableDecoder::new(
            Arc::clone(&self.recon),
            format!("quip-e8ball-{}b-s{}", self.bits, self.seed),
        );
        QuantizedWeight::new(
            self.name(),
            w.rows(),
            w.cols(),
            codes,
            Arc::new(decoder),
            scales,
            Some(seed),
        )
    }

    fn bits_per_weight(&self) -> f64 {
        self.bits as f64 / 8.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nearest_e8_on_lattice_points_is_identity() {
        // roots of E8: (1,1,0,...) and (½)^8
        let x = [1.0f32, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0];
        assert_eq!(nearest_e8(&x), [2, 2, 0, 0, 0, 0, 0, 0]);
        let h = [0.5f32; 8];
        assert_eq!(nearest_e8(&h), [1; 8]);
    }

    #[test]
    fn nearest_e8_is_truly_nearest_vs_enumeration() {
        use crate::lattice::e8::E8Points;
        use crate::rng::Rng;
        let pts = E8Points::enumerate(8);
        let mut rng = Rng::new(5);
        for _ in 0..200 {
            // stay within the enumerated ball so the brute force is valid
            let mut x = [0.0f32; 8];
            for v in x.iter_mut() {
                *v = (rng.normal() * 0.45) as f32;
            }
            let fast = nearest_e8(&x);
            // brute force over all enumerated points + origin
            let mut best_d = x.iter().map(|v| v * v).sum::<f32>(); // origin
            let mut best: Point = [0; 8];
            for p in &pts.doubled {
                let mut d = 0.0f32;
                for i in 0..8 {
                    let diff = x[i] - p[i] as f32 / 2.0;
                    d += diff * diff;
                }
                if d < best_d {
                    best_d = d;
                    for i in 0..8 {
                        best[i] = p[i] as i16;
                    }
                }
            }
            let mut fast_d = 0.0f32;
            for i in 0..8 {
                let diff = x[i] - fast[i] as f32 / 2.0;
                fast_d += diff * diff;
            }
            assert!(
                fast_d <= best_d + 1e-5,
                "decoder {fast:?} ({fast_d}) vs brute {best:?} ({best_d})"
            );
        }
    }

    #[test]
    fn build_produces_requested_size() {
        let q = QuipLike::build(10, 1);
        assert_eq!(q.len(), 1024);
        assert!(q.scale > 0.0);
    }

    #[test]
    fn quantize_error_reasonable() {
        use crate::rng::Rng;
        let mut rng = Rng::new(2);
        let w = Matrix::from_vec(rng.normal_vec(128 * 32), 128, 32);
        let q = QuipLike::build(12, 3);
        let mse = q.quantize(&w).dequantize().mse(&w);
        // 12 bits / 8 dims = 1.5 bpw — error should be below the unit variance
        assert!(mse < 0.9, "mse={mse}");
        // and more bits should help
        let q16 = QuipLike::build(14, 3);
        let mse16 = q16.quantize(&w).dequantize().mse(&w);
        assert!(mse16 < mse, "14b {mse16} vs 12b {mse}");
    }
}
