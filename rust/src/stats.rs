//! Special functions and the chi(k) magnitude distribution (paper Eq. 11).
//!
//! After Gaussian regularization the k-dimensional vector magnitudes follow
//! the *chi* distribution with k degrees of freedom (`r² ~ χ²(k)`), whose PDF
//! and CDF the paper derives in §A.1:
//!
//! ```text
//! f(r) = 2^{1-k/2} / Γ(k/2) · r^{k-1} · e^{-r²/2}
//! F(r) = P(k/2, r²/2)            (regularized lower incomplete gamma)
//! ```
//!
//! Lloyd-Max additionally needs cell centroids `∫ t f(t) dt / ΔF`, which
//! reduce analytically to incomplete-gamma differences (see
//! [`ChiDistribution::partial_mean`]), so no numerical integration is needed.

/// Natural log of the gamma function (Lanczos approximation, g=7, n=9).
/// |relative error| < 1e-13 over the positive reals.
pub fn ln_gamma(x: f64) -> f64 {
    const G: f64 = 7.0;
    const COEF: [f64; 9] = [
        0.999_999_999_999_809_93,
        676.520_368_121_885_1,
        -1259.139_216_722_402_8,
        771.323_428_777_653_13,
        -176.615_029_162_140_6,
        12.507_343_278_686_905,
        -0.138_571_095_265_720_12,
        9.984_369_578_019_571_6e-6,
        1.505_632_735_149_311_6e-7,
    ];
    if x < 0.5 {
        // reflection formula
        let pi = std::f64::consts::PI;
        return (pi / (pi * x).sin()).ln() - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = COEF[0];
    let t = x + G + 0.5;
    for (i, &c) in COEF.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

/// Regularized lower incomplete gamma `P(a, x) = γ(a,x)/Γ(a)`.
///
/// Series expansion for `x < a+1`, continued fraction otherwise — the
/// classic Numerical-Recipes split, accurate to ~1e-12.
pub fn gamma_p(a: f64, x: f64) -> f64 {
    assert!(a > 0.0, "gamma_p requires a > 0");
    if x <= 0.0 {
        return 0.0;
    }
    if x < a + 1.0 {
        gamma_p_series(a, x)
    } else {
        1.0 - gamma_q_cf(a, x)
    }
}

fn gamma_p_series(a: f64, x: f64) -> f64 {
    let mut ap = a;
    let mut sum = 1.0 / a;
    let mut del = sum;
    for _ in 0..500 {
        ap += 1.0;
        del *= x / ap;
        sum += del;
        if del.abs() < sum.abs() * 1e-15 {
            break;
        }
    }
    sum * (-x + a * x.ln() - ln_gamma(a)).exp()
}

fn gamma_q_cf(a: f64, x: f64) -> f64 {
    // Lentz's algorithm for the continued fraction of Q(a,x).
    let tiny = 1e-300;
    let mut b = x + 1.0 - a;
    let mut c = 1.0 / tiny;
    let mut d = 1.0 / b;
    let mut h = d;
    for i in 1..500 {
        let an = -(i as f64) * (i as f64 - a);
        b += 2.0;
        d = an * d + b;
        if d.abs() < tiny {
            d = tiny;
        }
        c = b + an / c;
        if c.abs() < tiny {
            c = tiny;
        }
        d = 1.0 / d;
        let del = d * c;
        h *= del;
        if (del - 1.0).abs() < 1e-15 {
            break;
        }
    }
    h * (-x + a * x.ln() - ln_gamma(a)).exp()
}

/// The chi distribution with `k` degrees of freedom — the law of the
/// magnitude `r = ‖v‖` of a k-vector of i.i.d. standard normals.
#[derive(Clone, Copy, Debug)]
pub struct ChiDistribution {
    /// Degrees of freedom (the VQ vector dimension, k = 8 in the paper).
    pub k: usize,
}

impl ChiDistribution {
    pub fn new(k: usize) -> Self {
        assert!(k >= 1);
        ChiDistribution { k }
    }

    /// PDF `f(r)` from Eq. 11 / Eq. 17.
    pub fn pdf(&self, r: f64) -> f64 {
        if r < 0.0 {
            return 0.0;
        }
        if r == 0.0 {
            return if self.k == 1 {
                (2.0 / std::f64::consts::PI).sqrt()
            } else {
                0.0
            };
        }
        let k = self.k as f64;
        let ln_f = (1.0 - k / 2.0) * std::f64::consts::LN_2 - ln_gamma(k / 2.0)
            + (k - 1.0) * r.ln()
            - r * r / 2.0;
        ln_f.exp()
    }

    /// CDF `F(r) = P(k/2, r²/2)` from Eq. 11 / Eq. 20.
    pub fn cdf(&self, r: f64) -> f64 {
        if r <= 0.0 {
            return 0.0;
        }
        gamma_p(self.k as f64 / 2.0, r * r / 2.0)
    }

    /// Inverse CDF by bisection + Newton polish. `p` in (0,1).
    pub fn quantile(&self, p: f64) -> f64 {
        assert!((0.0..1.0).contains(&p), "quantile needs p in [0,1), got {p}");
        if p == 0.0 {
            return 0.0;
        }
        let (mut lo, mut hi) = (0.0f64, (self.k as f64).sqrt() + 1.0);
        while self.cdf(hi) < p {
            hi *= 2.0;
        }
        for _ in 0..200 {
            let mid = 0.5 * (lo + hi);
            if self.cdf(mid) < p {
                lo = mid;
            } else {
                hi = mid;
            }
            if hi - lo < 1e-12 {
                break;
            }
        }
        0.5 * (lo + hi)
    }

    /// Mean `E[r] = √2 · Γ((k+1)/2) / Γ(k/2)`.
    pub fn mean(&self) -> f64 {
        let k = self.k as f64;
        std::f64::consts::SQRT_2
            * (ln_gamma((k + 1.0) / 2.0) - ln_gamma(k / 2.0)).exp()
    }

    /// Unnormalized partial first moment `∫_a^b t·f(t) dt`.
    ///
    /// With `y = t²/2`: `∫ t·f(t) dt = √2·Γ((k+1)/2)/Γ(k/2) · ΔP((k+1)/2, t²/2)`
    /// — i.e. the chi mean times the mass a chi(k+1)-shaped measure assigns to
    /// the cell. Exact, no quadrature.
    pub fn partial_mean(&self, a: f64, b: f64) -> f64 {
        assert!(b >= a && a >= 0.0);
        let k = self.k as f64;
        let coef = self.mean();
        let ap = (k + 1.0) / 2.0;
        coef * (gamma_p(ap, b * b / 2.0) - gamma_p(ap, a * a / 2.0))
    }

    /// Centroid (conditional mean) of the interval `[a, b]`.
    pub fn centroid(&self, a: f64, b: f64) -> f64 {
        let mass = self.cdf(b) - self.cdf(a);
        if mass <= 1e-300 {
            return 0.5 * (a + b);
        }
        self.partial_mean(a, b) / mass
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ln_gamma_known_values() {
        // Γ(1)=1, Γ(2)=1, Γ(3)=2, Γ(4)=6, Γ(0.5)=√π
        assert!(ln_gamma(1.0).abs() < 1e-12);
        assert!(ln_gamma(2.0).abs() < 1e-12);
        assert!((ln_gamma(3.0) - 2.0f64.ln()).abs() < 1e-12);
        assert!((ln_gamma(4.0) - 6.0f64.ln()).abs() < 1e-12);
        assert!((ln_gamma(0.5) - std::f64::consts::PI.sqrt().ln()).abs() < 1e-12);
    }

    #[test]
    fn gamma_p_known_values() {
        // P(1, x) = 1 - e^{-x}
        for &x in &[0.1, 0.5, 1.0, 2.0, 5.0, 10.0] {
            assert!((gamma_p(1.0, x) - (1.0 - (-x as f64).exp())).abs() < 1e-12);
        }
        // P(a, 0) = 0, P(a, inf) -> 1
        assert_eq!(gamma_p(3.0, 0.0), 0.0);
        assert!((gamma_p(3.0, 100.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn chi_cdf_matches_pdf_integral() {
        // trapezoidal integration of pdf should match cdf
        let chi = ChiDistribution::new(8);
        let n = 20_000;
        let hi = 6.0;
        let dx = hi / n as f64;
        let mut acc = 0.0;
        for i in 0..n {
            let x0 = i as f64 * dx;
            let x1 = x0 + dx;
            acc += 0.5 * (chi.pdf(x0) + chi.pdf(x1)) * dx;
            if (i + 1) % 5000 == 0 {
                let diff = (acc - chi.cdf(x1)).abs();
                assert!(diff < 1e-6, "x={x1} diff={diff}");
            }
        }
    }

    #[test]
    fn chi_mean_matches_montecarlo() {
        use crate::rng::Rng;
        let chi = ChiDistribution::new(8);
        let mut rng = Rng::new(31);
        let n = 50_000;
        let mut s = 0.0;
        for _ in 0..n {
            let v: f64 = (0..8).map(|_| rng.normal().powi(2)).sum();
            s += v.sqrt();
        }
        let mc = s / n as f64;
        assert!((chi.mean() - mc).abs() < 0.01, "analytic={} mc={}", chi.mean(), mc);
    }

    #[test]
    fn quantile_inverts_cdf() {
        let chi = ChiDistribution::new(8);
        for &p in &[0.01, 0.25, 0.5, 0.75, 0.99, 0.9999] {
            let r = chi.quantile(p);
            assert!((chi.cdf(r) - p).abs() < 1e-9, "p={p} r={r}");
        }
    }

    #[test]
    fn partial_mean_sums_to_mean() {
        let chi = ChiDistribution::new(8);
        let total = chi.partial_mean(0.0, 100.0);
        assert!((total - chi.mean()).abs() < 1e-9);
        // additivity
        let a = chi.partial_mean(0.0, 2.0) + chi.partial_mean(2.0, 100.0);
        assert!((a - total).abs() < 1e-12);
    }

    #[test]
    fn centroid_inside_cell() {
        let chi = ChiDistribution::new(8);
        let c = chi.centroid(2.0, 3.0);
        assert!((2.0..3.0).contains(&c));
    }
}
