//! E8 lattice point enumeration.
//!
//! `E8 = D8 ∪ (D8 + ½)`: all integer 8-vectors with even coordinate sum,
//! together with all half-odd-integer 8-vectors (every coordinate in `Z+½`)
//! with even coordinate sum. We enumerate every lattice point with
//! `‖x‖² ≤ max_norm2` by depth-first search with norm pruning, then collapse
//! collinear points into *directions* (unit vectors), keeping the
//! smallest-shell representative first.
//!
//! Shell sizes follow the E8 theta series
//! `1 + 240q + 2160q² + 6720q³ + 17520q⁴ + 30240q⁵ + 60480q⁶ + …`, which the
//! unit tests assert — a strong correctness check on the enumeration.

use crate::tensor::Matrix;

/// Dimension of the E8 lattice.
pub const DIM: usize = 8;

/// All E8 points up to a squared-norm bound, grouped by shell.
#[derive(Clone, Debug)]
pub struct E8Points {
    /// Points as rows, doubled coordinates (so they are integers): a point
    /// `x` is stored as `2x ∈ Z^8`.
    pub doubled: Vec<[i32; DIM]>,
    /// `‖x‖²·4 = ‖2x‖²` for each point (integer).
    pub norm2x4: Vec<i64>,
}

impl E8Points {
    /// Enumerate all nonzero E8 points with `‖x‖² ≤ max_norm2`.
    pub fn enumerate(max_norm2: i64) -> Self {
        let cap4 = max_norm2 * 4; // bound on ‖2x‖²
        let mut doubled = Vec::new();
        let mut norm2x4 = Vec::new();

        // Integer points: 2x even in every coordinate, Σx even.
        // Half-integer points: 2x odd in every coordinate, Σx even
        // (Σ(2x) ≡ 0 mod 4 since Σx ∈ 2Z).
        for &half in &[false, true] {
            let mut coords = [0i32; DIM];
            Self::dfs(0, 0, 0, half, cap4, &mut coords, &mut doubled, &mut norm2x4);
        }

        // Sort by shell (norm), then lexicographically — deterministic order.
        let mut idx: Vec<usize> = (0..doubled.len()).collect();
        idx.sort_by_key(|&i| (norm2x4[i], doubled[i]));
        let doubled = idx.iter().map(|&i| doubled[i]).collect();
        let norm2x4 = idx.iter().map(|&i| norm2x4[i]).collect();
        E8Points { doubled, norm2x4 }
    }

    #[allow(clippy::too_many_arguments)]
    fn dfs(
        pos: usize,
        sum2x: i32,
        norm: i64,
        half: bool,
        cap4: i64,
        coords: &mut [i32; DIM],
        out: &mut Vec<[i32; DIM]>,
        norms: &mut Vec<i64>,
    ) {
        if pos == DIM {
            if norm == 0 {
                return; // skip the origin: it has no direction
            }
            // Membership: Σx ∈ 2Z ⇔ Σ(2x) ≡ 0 (mod 4).
            if sum2x.rem_euclid(4) == 0 {
                out.push(*coords);
                norms.push(norm);
            }
            return;
        }
        // Doubled coordinate values: even (…,-2,0,2,…) or odd (…,-3,-1,1,3,…).
        let max_c = ((cap4 - norm) as f64).sqrt().floor() as i32;
        let mut c = if half {
            // largest odd ≤ max_c
            if max_c % 2 == 0 {
                max_c - 1
            } else {
                max_c
            }
        } else {
            // largest even ≤ max_c
            max_c - max_c % 2
        };
        while c >= -max_c {
            let n2 = norm + (c as i64) * (c as i64);
            if n2 <= cap4 {
                coords[pos] = c;
                Self::dfs(pos + 1, sum2x + c, n2, half, cap4, coords, out, norms);
            }
            c -= 2;
        }
    }

    /// Number of points in the shell of squared norm `norm2`.
    pub fn shell_count(&self, norm2: i64) -> usize {
        self.norm2x4.iter().filter(|&&n| n == norm2 * 4).count()
    }

    pub fn len(&self) -> usize {
        self.doubled.len()
    }

    pub fn is_empty(&self) -> bool {
        self.doubled.is_empty()
    }

    /// Collapse collinear points into unit-vector directions.
    ///
    /// Points on outer shells that are positive multiples of an inner-shell
    /// point (e.g. `(2,2,0,…)` vs `(1,1,0,…)`) contribute no new direction
    /// and are dropped; the enumeration order (shells inside-out) guarantees
    /// the canonical representative is the innermost one.
    pub fn directions(&self) -> Matrix {
        use std::collections::HashSet;
        let mut seen: HashSet<[i64; DIM]> = HashSet::with_capacity(self.len());
        let mut rows: Vec<f32> = Vec::new();
        let mut count = 0usize;
        for p in &self.doubled {
            // Canonical integer key: divide by gcd of the doubled coords.
            let mut g = 0i64;
            for &c in p.iter() {
                g = gcd(g, c.unsigned_abs() as i64);
            }
            debug_assert!(g > 0);
            let mut key = [0i64; DIM];
            for (k, &c) in key.iter_mut().zip(p.iter()) {
                *k = c as i64 / g;
            }
            if !seen.insert(key) {
                continue;
            }
            let norm = (p.iter().map(|&c| (c as f64) * (c as f64)).sum::<f64>()).sqrt();
            for &c in p.iter() {
                rows.push((c as f64 / norm) as f32);
            }
            count += 1;
        }
        Matrix::from_vec(rows, count, DIM)
    }
}

fn gcd(a: i64, b: i64) -> i64 {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}

/// Unit-vector directions of all E8 points with `‖x‖² ≤ max_norm2`
/// (deduplicated across shells), as rows of a matrix.
pub fn e8_directions(max_norm2: i64) -> Matrix {
    E8Points::enumerate(max_norm2).directions()
}

/// Points of a single shell (squared norm exactly `norm2`), as unit rows.
pub fn e8_shell(norm2: i64) -> Matrix {
    let pts = E8Points::enumerate(norm2);
    let mut rows = Vec::new();
    let mut count = 0;
    for (p, &n) in pts.doubled.iter().zip(&pts.norm2x4) {
        if n != norm2 * 4 {
            continue;
        }
        for &c in p.iter() {
            rows.push(c as f32);
        }
        count += 1;
    }
    // normalize: doubled coords / ‖2x‖ give the unit direction
    let mut m = Matrix::from_vec(rows, count, DIM);
    for i in 0..m.rows() {
        let r = m.row_mut(i);
        let n: f32 = r.iter().map(|x| x * x).sum::<f32>().sqrt();
        for x in r.iter_mut() {
            *x /= n;
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn theta_series_shell_counts() {
        let pts = E8Points::enumerate(6);
        assert_eq!(pts.shell_count(2), 240, "E8 kissing number");
        assert_eq!(pts.shell_count(4), 2160);
        assert_eq!(pts.shell_count(6), 6720);
        assert_eq!(pts.len(), 240 + 2160 + 6720);
    }

    #[test]
    fn no_odd_norm_shells() {
        // E8 is an even lattice: ‖x‖² is always an even integer.
        let pts = E8Points::enumerate(4);
        assert_eq!(pts.shell_count(1), 0);
        assert_eq!(pts.shell_count(3), 0);
    }

    #[test]
    fn roots_have_expected_shapes() {
        // The 240 roots: 112 of type (±1,±1,0^6) and 128 of type (±½)^8.
        let pts = E8Points::enumerate(2);
        let mut int_type = 0;
        let mut half_type = 0;
        for p in &pts.doubled {
            if p.iter().all(|&c| c % 2 == 0) {
                int_type += 1;
            } else {
                half_type += 1;
            }
        }
        assert_eq!(int_type, 112);
        assert_eq!(half_type, 128);
    }

    #[test]
    fn directions_are_unit_and_deduped() {
        let dirs = e8_directions(8);
        // All rows unit norm.
        for i in 0..dirs.rows().min(500) {
            let n: f32 = dirs.row(i).iter().map(|x| x * x).sum::<f32>().sqrt();
            assert!((n - 1.0).abs() < 1e-5);
        }
        // Fewer directions than points (collinear duplicates collapsed):
        let pts = E8Points::enumerate(8);
        assert!(dirs.rows() < pts.len());
        // but still plenty.
        assert!(dirs.rows() > 20_000, "got {}", dirs.rows());
        // No duplicate rows: check pairwise on a sample via exact equality.
        for i in 0..200 {
            for j in (i + 1)..200 {
                assert_ne!(dirs.row(i), dirs.row(j), "rows {i} and {j} equal");
            }
        }
    }

    #[test]
    fn enough_candidates_for_a16() {
        // a=16 needs 2^16 = 65536 candidate directions; shells ≤ 12 suffice.
        // (Enumeration of ~117k points — keep as an ignored-by-default slow
        // test? It runs in ~1s release; acceptable in debug too.)
        let dirs = e8_directions(12);
        assert!(dirs.rows() >= 65_536, "got {}", dirs.rows());
    }

    #[test]
    fn shell_helper_matches_enumeration() {
        let s = e8_shell(2);
        assert_eq!(s.rows(), 240);
        for i in 0..s.rows() {
            let n: f32 = s.row(i).iter().map(|x| x * x).sum::<f32>().sqrt();
            assert!((n - 1.0).abs() < 1e-5);
        }
    }
}
