//! Lattice substrates for codebook construction.
//!
//! The direction codebook of DACC (paper §3.2.3) samples from the directions
//! of the E8 lattice — the densest sphere packing in 8 dimensions
//! (Viazovska 2017) — because its points are "highly uniform and symmetric in
//! space".

pub mod e8;

pub use e8::{e8_directions, e8_shell, E8Points};
