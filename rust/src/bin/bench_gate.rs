//! `bench_gate` — the CI perf-regression gate.
//!
//! Compares freshly emitted `BENCH_*.json` trajectory files against the
//! committed baselines (`baselines/BENCH_*.json`), prints a markdown trend
//! table (optionally appended to a summary file, e.g. `$GITHUB_STEP_SUMMARY`)
//! and exits non-zero when any shared benchmark slowed down beyond the
//! tolerance.
//!
//! ```text
//! bench_gate --files BENCH_assign.json,BENCH_quant.json,BENCH_serving.json \
//!            [--baseline-dir ../baselines] [--current-dir .] \
//!            [--tolerance 1.3] [--summary out.md] [--capture]
//! ```
//!
//! Baseline files that are absent or empty (`[]`) record the trend without
//! gating — the bootstrap state until a toolchain-equipped runner populates
//! `baselines/` (procedure: `baselines/README.md`).
//!
//! `--capture` arms the gate instead of running it: every current
//! `BENCH_*.json` is validated (parseable, non-empty) and, only if all
//! pass, copied over its baseline — a bad file aborts before any baseline
//! is touched. A CI runner can thus rewrite `baselines/` from a fresh run
//! in one step and the diff lands in the PR that refreshes them.

use std::path::{Path, PathBuf};
use std::process::exit;

use pcdvq::bench::{compare_benches, parse_bench_json, BenchComparison};

struct Opts {
    files: Vec<String>,
    baseline_dir: PathBuf,
    current_dir: PathBuf,
    tolerance: f64,
    summary: Option<PathBuf>,
    capture: bool,
}

fn parse_opts() -> Result<Opts, String> {
    let mut opts = Opts {
        files: Vec::new(),
        baseline_dir: PathBuf::from("../baselines"),
        current_dir: PathBuf::from("."),
        tolerance: 1.3,
        summary: None,
        capture: false,
    };
    let mut it = std::env::args().skip(1);
    while let Some(arg) = it.next() {
        let mut val =
            |flag: &str| it.next().ok_or_else(|| format!("--{flag} needs a value"));
        match arg.as_str() {
            "--files" => {
                opts.files = val("files")?.split(',').map(|s| s.trim().to_string()).collect()
            }
            "--baseline-dir" => opts.baseline_dir = PathBuf::from(val("baseline-dir")?),
            "--current-dir" => opts.current_dir = PathBuf::from(val("current-dir")?),
            "--tolerance" => {
                opts.tolerance = val("tolerance")?
                    .parse()
                    .map_err(|e| format!("--tolerance: {e}"))?
            }
            "--summary" => opts.summary = Some(PathBuf::from(val("summary")?)),
            "--capture" => opts.capture = true,
            other => return Err(format!("unknown flag '{other}'")),
        }
    }
    if opts.files.is_empty() {
        return Err("--files is required (comma-separated BENCH_*.json names)".into());
    }
    Ok(opts)
}

/// `--capture`: validate **every** current trajectory first (parseable and
/// non-empty — an empty capture would silently disarm the gate it is meant
/// to arm), and only if all pass copy them over their baselines. Any bad
/// file aborts before a single baseline is touched, so a failed capture
/// never leaves `baselines/` half-refreshed.
fn capture(opts: &Opts) -> i32 {
    let mut validated = Vec::with_capacity(opts.files.len());
    let mut code = 0;
    for file in &opts.files {
        let src = opts.current_dir.join(file);
        match load(&src) {
            Ok(entries) if !entries.is_empty() => validated.push((file, src, entries.len())),
            Ok(_) => {
                eprintln!(
                    "bench_gate --capture: {} is empty — run the bench first",
                    src.display()
                );
                code = 1;
            }
            Err(e) => {
                eprintln!("bench_gate --capture: {e}");
                code = 1;
            }
        }
    }
    if code != 0 {
        eprintln!("bench_gate --capture: nothing captured (baselines unchanged)");
        return code;
    }
    for (file, src, n) in validated {
        let dst = opts.baseline_dir.join(file);
        match std::fs::copy(&src, &dst) {
            Ok(_) => println!("captured {file}: {n} benchmarks -> {}", dst.display()),
            Err(e) => {
                eprintln!("bench_gate --capture: copying {file}: {e}");
                code = 1;
            }
        }
    }
    code
}

fn load(path: &Path) -> Result<Vec<pcdvq::bench::BenchEntry>, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("{}: {e}", path.display()))?;
    parse_bench_json(&text).map_err(|e| format!("{}: {e}", path.display()))
}

fn main() {
    let opts = match parse_opts() {
        Ok(o) => o,
        Err(e) => {
            eprintln!("bench_gate: {e}");
            exit(2);
        }
    };
    if opts.capture {
        exit(capture(&opts));
    }

    let mut report = String::from("## Bench regression gate\n\n");
    let mut failed = false;
    let mut bench_ran = true;
    for file in &opts.files {
        report.push_str(&format!("### {file}\n\n"));
        let cur = match load(&opts.current_dir.join(file)) {
            Ok(c) => c,
            Err(e) => {
                // the bench did not emit its trajectory — that's a CI failure
                report.push_str(&format!("❌ current run missing: {e}\n\n"));
                bench_ran = false;
                continue;
            }
        };
        let base_path = opts.baseline_dir.join(file);
        let base = if base_path.exists() {
            match load(&base_path) {
                Ok(b) => b,
                Err(e) => {
                    // a committed baseline that no longer parses must fail
                    // loudly — treating it as "unpopulated" would silently
                    // disarm the gate
                    report.push_str(&format!("❌ baseline unreadable: {e}\n\n"));
                    failed = true;
                    continue;
                }
            }
        } else {
            Vec::new() // no committed baseline yet (bootstrap state)
        };
        if base.is_empty() {
            report.push_str(
                "baseline unpopulated — recording trend only \
                 (arm with `bench_gate --capture`; baselines/README.md)\n\n",
            );
        }
        let cmp: BenchComparison = compare_benches(&base, &cur);
        report.push_str(&cmp.markdown_table(opts.tolerance));
        report.push('\n');
        let regs = cmp.regressions(opts.tolerance);
        if !regs.is_empty() {
            failed = true;
            for r in regs {
                report.push_str(&format!(
                    "**regression**: `{}` {:.2}x slower than baseline (tolerance {:.2}x)\n",
                    r.name, r.ratio, opts.tolerance
                ));
            }
            report.push('\n');
        }
    }
    if failed {
        report.push_str("\n**gate: FAILED** — a benchmark regressed beyond tolerance\n");
    } else if !bench_ran {
        report.push_str("\n**gate: FAILED** — a bench run emitted no trajectory file\n");
    } else {
        report.push_str("\n**gate: passed**\n");
    }

    print!("{report}");
    if let Some(summary) = &opts.summary {
        use std::io::Write;
        if let Ok(mut f) = std::fs::OpenOptions::new().create(true).append(true).open(summary) {
            let _ = f.write_all(report.as_bytes());
        }
    }
    if failed || !bench_ran {
        exit(1);
    }
}
