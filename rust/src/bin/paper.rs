//! `paper` — regenerate every table and figure of the PCDVQ paper.
//!
//! USAGE: paper -- <experiment> [--quick] [--model NAME]
//!   experiments: fig1a fig1b table1 table2 table3 table4 fig3 efficiency all

use anyhow::Result;
use pcdvq::paper;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "--quick");
    let model = args
        .iter()
        .position(|a| a == "--model")
        .and_then(|i| args.get(i + 1))
        .map(|s| s.as_str())
        .unwrap_or("gpt-m")
        .to_string();
    let exp = args
        .iter()
        .find(|a| !a.starts_with("--") && *a != &model)
        .map(|s| s.as_str())
        .unwrap_or("all");

    let ctx = paper::Ctx::new(quick)?;
    let t0 = std::time::Instant::now();
    match exp {
        "fig1a" => paper::run_fig1a(&ctx, &model)?,
        "fig1b" => paper::run_fig1b(&ctx, &model)?,
        "table1" => paper::run_table1(&ctx, quick)?,
        "table2" => paper::run_table2(&ctx, quick)?,
        "table3" => paper::run_table3(&ctx, &model)?,
        "table4" => paper::run_table4(&ctx, &model, quick)?,
        "fig3" => paper::run_fig3(&ctx, &model)?,
        "efficiency" => paper::run_efficiency(&ctx, &model, quick)?,
        "all" => {
            paper::run_fig1a(&ctx, &model)?;
            println!();
            paper::run_fig1b(&ctx, &model)?;
            println!();
            paper::run_table1(&ctx, quick)?;
            println!();
            paper::run_table2(&ctx, quick)?;
            println!();
            paper::run_table3(&ctx, &model)?;
            println!();
            paper::run_table4(&ctx, &model, quick)?;
            println!();
            paper::run_fig3(&ctx, &model)?;
            println!();
            paper::run_efficiency(&ctx, &model, quick)?;
        }
        other => anyhow::bail!(
            "unknown experiment '{other}' (fig1a fig1b table1 table2 table3 table4 fig3 efficiency all)"
        ),
    }
    eprintln!("\n[paper] {exp} completed in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
