//! Per-layer K/V cache for incremental host decode.
//!
//! The windowed re-forward (DESIGN.md §9, pre-KV-cache) recomputed Q/K/V and
//! the MLP for the *entire* prefix on every generated token — O(t²) work per
//! sequence. A [`KvCache`] keeps the attention keys and values of every
//! position already processed, so [`crate::model::HostForward::decode_step`]
//! runs exactly one new token through the model and attends over the cached
//! rows: O(t) weight work per sequence, with attention's unavoidable
//! O(len·d) read per step.
//!
//! ## Layout
//!
//! One `(capacity, d_model)` append buffer per layer for K and another for V,
//! plus the token window those rows were computed from. Row `i` of every
//! buffer holds the K/V of window position `i` — positions are absolute
//! (position embedding `i` went into the row), which is what makes the cache
//! bit-consistent with a fresh forward over the same window.
//!
//! ## Eviction (prompts/generations longer than `capacity`)
//!
//! Absolute positions mean a full cache cannot just drop its oldest row: the
//! surviving rows would keep stale position embeddings while a re-forward of
//! the slid window would re-embed them at shifted positions. Instead the
//! cache slides by [`KvCache::evict_stride`] tokens and the caller
//! ([`crate::model::HostForward::decode_step`]) rebuilds the remaining
//! window's K/V at their new positions. Rebuild costs one prefill of
//! `capacity - stride` tokens every `stride` tokens — amortized
//! `(capacity/stride - 1)` extra token-forwards per generated token (the
//! default stride of `capacity/4` makes that 3), still far below the
//! `capacity` token-forwards per token the windowed re-forward pays.
//!
//! Memory: `2 · n_layer · capacity · d_model · 32` bits of f32 per cache
//! ([`crate::model::GptConfig::kv_cache_bits`]), one cache per active
//! session.
//!
//! ## Quantized rows
//!
//! A cache built with [`KvCache::with_codec`] stores polar-decoupled codes
//! (DESIGN.md §15, same contract as the paged pool's
//! [`crate::model::PageCodec::PcdVq`] layout): [`Self::write_kv_at`]
//! quantizes each incoming row against the layer's codec — frozen on the
//! layer's first-ever write — into packed code words, and the f32 buffers
//! hold the LUT-decoded tile (derived state, zero payload bits). The
//! slide+rebuild eviction re-feed flows through the same write path, so
//! rebuilt rows **re-quantize against the frozen codebook** rather than
//! re-building it: an evicted-then-rebuilt window decodes bit-identically
//! to a fresh quantized prefill of that window with the same codec.

use std::sync::Arc;

use crate::quant::kv::KvQuantCodec;
use crate::tensor::Matrix;

use super::GptConfig;

/// Per-layer K/V append buffer + the token window it was computed from.
///
/// Constructed per serving session ([`Self::new`]), reset on request
/// boundaries ([`Self::reset`]), advanced only through
/// [`crate::model::HostForward::decode_step`] /
/// [`crate::model::HostForward::prefill`].
#[derive(Clone, Debug)]
pub struct KvCache {
    /// First absolute layer index this cache owns (0 for a full-model
    /// cache; a shard node's cache owns only its layer range, DESIGN.md
    /// §16). All layer arguments to the accessors/write path are absolute.
    layer_base: usize,
    /// Number of owned layers (`cfg.n_layer` for a full-model cache).
    n_layer: usize,
    d_model: usize,
    capacity: usize,
    evict_stride: usize,
    /// The token window the cached rows were computed from (`len()` entries).
    tokens: Vec<i32>,
    /// Per layer: `(capacity, d_model)` keys; rows `0..len()` are valid.
    k: Vec<Matrix>,
    /// Per layer: `(capacity, d_model)` values; rows `0..len()` are valid.
    v: Vec<Matrix>,
    /// Present iff rows are stored as polar-decoupled codes; shared
    /// (`Arc`) so sibling caches quantize against the same frozen state.
    codec: Option<Arc<KvQuantCodec>>,
    /// Per layer: `capacity · words_per_row` packed K code words (empty
    /// without a codec).
    ck: Vec<Vec<u64>>,
    /// Per layer: packed V code words.
    cv: Vec<Vec<u64>>,
    /// Tokens ever fed through this cache (survives resets; telemetry).
    total_fed: u64,
    /// Window slides performed (telemetry; each one cost a rebuild).
    evictions: u64,
}

impl KvCache {
    /// Cache sized to the model's full context window, with the default
    /// eviction stride of `capacity / 4` (min 1).
    pub fn new(cfg: &GptConfig) -> Self {
        Self::with_capacity(cfg, cfg.ctx)
    }

    /// Cache over a sliding window of `capacity ≤ cfg.ctx` positions
    /// (clamped). Smaller capacities bound attention cost and memory at the
    /// price of a shorter effective context.
    pub fn with_capacity(cfg: &GptConfig, capacity: usize) -> Self {
        let capacity = capacity.clamp(1, cfg.ctx);
        let stride = (capacity / 4).max(1);
        Self::with_stride(cfg, capacity, stride)
    }

    /// Full control over window capacity and eviction stride (both clamped
    /// to valid ranges; `stride` to `1..=capacity`).
    pub fn with_stride(cfg: &GptConfig, capacity: usize, stride: usize) -> Self {
        Self::with_stride_codec(cfg, capacity, stride, None)
    }

    /// Full-context cache whose rows are stored as polar-decoupled codes
    /// quantized by `codec` (DESIGN.md §15); `None` is the exact layout.
    pub fn with_codec(cfg: &GptConfig, codec: Option<Arc<KvQuantCodec>>) -> Self {
        Self::with_stride_codec(cfg, cfg.ctx, (cfg.ctx / 4).max(1), codec)
    }

    /// The general full-model constructor: window geometry plus an optional
    /// cache codec shared with sibling caches.
    pub fn with_stride_codec(
        cfg: &GptConfig,
        capacity: usize,
        stride: usize,
        codec: Option<Arc<KvQuantCodec>>,
    ) -> Self {
        Self::with_layers(cfg, capacity, stride, codec, 0..cfg.n_layer)
    }

    /// Cache owning only the layers in `layers` — the shard-node form
    /// (DESIGN.md §16): a node allocates K/V rows for its own layer range,
    /// while the layer arguments of [`Self::write_kv_at`] / [`Self::layer`]
    /// stay *absolute* model indices, so the node-side write path is
    /// identical code to the single-node one. The codec (when present) keeps
    /// full-model geometry and is indexed by the same absolute layers, which
    /// is what makes per-node codebooks bit-identical to the single-node
    /// ones (same layer → same seed → same frozen grid).
    pub(crate) fn with_layers(
        cfg: &GptConfig,
        capacity: usize,
        stride: usize,
        codec: Option<Arc<KvQuantCodec>>,
        layers: std::ops::Range<usize>,
    ) -> Self {
        assert!(
            layers.start <= layers.end && layers.end <= cfg.n_layer,
            "kv cache layer range {layers:?} out of model range 0..{}",
            cfg.n_layer
        );
        if let Some(c) = &codec {
            assert!(
                c.n_layer() == cfg.n_layer && c.d_model() == cfg.d_model,
                "kv codec geometry ({} layers × {}) does not match model ({} × {})",
                c.n_layer(),
                c.d_model(),
                cfg.n_layer,
                cfg.d_model
            );
        }
        let owned = layers.len();
        let capacity = capacity.clamp(1, cfg.ctx);
        let evict_stride = stride.clamp(1, capacity);
        let words = codec.as_ref().map_or(0, |c| c.words_per_row());
        KvCache {
            layer_base: layers.start,
            n_layer: owned,
            d_model: cfg.d_model,
            capacity,
            evict_stride,
            tokens: Vec::with_capacity(capacity),
            k: (0..owned).map(|_| Matrix::zeros(capacity, cfg.d_model)).collect(),
            v: (0..owned).map(|_| Matrix::zeros(capacity, cfg.d_model)).collect(),
            codec,
            ck: (0..owned).map(|_| vec![0u64; capacity * words]).collect(),
            cv: (0..owned).map(|_| vec![0u64; capacity * words]).collect(),
            total_fed: 0,
            evictions: 0,
        }
    }

    /// Map an absolute model layer index onto this cache's local arrays.
    #[inline]
    fn local(&self, layer: usize) -> usize {
        debug_assert!(
            layer >= self.layer_base && layer < self.layer_base + self.n_layer,
            "layer {layer} outside owned range {}..{}",
            self.layer_base,
            self.layer_base + self.n_layer
        );
        layer - self.layer_base
    }

    /// Valid cached positions (= tokens in the current window).
    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// Maximum window length before eviction.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Tokens dropped per window slide.
    pub fn evict_stride(&self) -> usize {
        self.evict_stride
    }

    /// The token window the cached K/V rows correspond to — feeding exactly
    /// these tokens through a fresh full forward reproduces the cached state
    /// (the re-forward parity oracle's input).
    pub fn tokens(&self) -> &[i32] {
        &self.tokens
    }

    /// Tokens ever fed, across resets and evictions.
    pub fn total_fed(&self) -> u64 {
        self.total_fed
    }

    /// Window slides performed so far.
    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    /// K and V buffers of one (absolute) layer (rows `0..len()` valid).
    /// With a codec these hold the decoded tile — reads are layout-blind.
    pub fn layer(&self, layer: usize) -> (&Matrix, &Matrix) {
        let l = self.local(layer);
        (&self.k[l], &self.v[l])
    }

    /// The absolute layer range this cache owns (`0..cfg.n_layer` for the
    /// full-model constructors).
    pub fn layers(&self) -> std::ops::Range<usize> {
        self.layer_base..self.layer_base + self.n_layer
    }

    /// The cache codec, when rows are stored as codes.
    pub fn codec(&self) -> Option<&Arc<KvQuantCodec>> {
        self.codec.as_ref()
    }

    /// Packed K code words of one position (empty without a codec) — the
    /// row's actual resident payload.
    pub fn k_codes(&self, layer: usize, pos: usize) -> &[u64] {
        let w = self.codec.as_ref().map_or(0, |c| c.words_per_row());
        &self.ck[self.local(layer)][pos * w..(pos + 1) * w]
    }

    /// Packed V code words of one position (empty without a codec).
    pub fn v_codes(&self, layer: usize, pos: usize) -> &[u64] {
        let w = self.codec.as_ref().map_or(0, |c| c.words_per_row());
        &self.cv[self.local(layer)][pos * w..(pos + 1) * w]
    }

    /// Resident payload bits (allocation, not fill level): the f32 buffers
    /// exactly, or — with a codec — the word-aligned code words only (the
    /// decoded tile is derived state; the shared codebooks are counted once
    /// at the codec, [`KvQuantCodec::codebook_bits`]).
    pub fn memory_bits(&self) -> u64 {
        let rows = 2 * (self.n_layer * self.capacity) as u64;
        match &self.codec {
            None => rows * self.d_model as u64 * 32,
            Some(c) => rows * c.code_bits_per_row(),
        }
    }

    /// True when this is a *full-model* cache whose geometry matches `cfg`
    /// (a cache built for one model must not be fed through another; a
    /// shard-node layer-range cache is never compatible with the host
    /// forward, which writes every layer).
    pub fn compatible_with(&self, cfg: &GptConfig) -> bool {
        self.layer_base == 0
            && self.n_layer == cfg.n_layer
            && self.d_model == cfg.d_model
            && self.capacity <= cfg.ctx
    }

    /// Drop all cached state: the explicit new-request boundary. Telemetry
    /// counters (`total_fed`, `evictions`) survive; K/V rows and the token
    /// window do not.
    pub fn reset(&mut self) {
        self.tokens.clear();
    }

    /// Begin a window slide: drop the oldest `evict_stride` tokens and
    /// invalidate every cached row. Returns the surviving tokens, which the
    /// caller must re-feed (their K/V carry position embeddings that shifted
    /// with the slide). Used by `HostForward::decode_step`.
    pub(crate) fn begin_evict(&mut self) -> Vec<i32> {
        let stride = self.evict_stride.min(self.tokens.len());
        let keep = self.tokens[stride..].to_vec();
        self.tokens.clear();
        self.evictions += 1;
        keep
    }

    /// Write the K/V rows of one (still uncommitted) position for one layer
    /// — the block advance writes a whole chunk of positions
    /// (`len()..len()+chunk`) before a single [`Self::commit_block`]. With
    /// a codec the rows quantize against the layer's frozen codebook (built
    /// on the layer's first-ever write) and the buffers receive the
    /// LUT-decoded tile; the eviction re-feed flows through here too, so
    /// rebuilt rows re-quantize against the *same* frozen grid.
    pub(crate) fn write_kv_at(&mut self, layer: usize, pos: usize, k_row: &[f32], v_row: &[f32]) {
        debug_assert!(pos < self.capacity, "write_kv_at past capacity");
        let l = self.local(layer);
        match self.codec.clone() {
            None => {
                self.k[l].row_mut(pos).copy_from_slice(k_row);
                self.v[l].row_mut(pos).copy_from_slice(v_row);
            }
            Some(codec) => {
                // The codec is indexed by the *absolute* layer: a node-range
                // cache observes/encodes against the same per-layer grids a
                // full-model cache would.
                let lc = codec.observe(layer, k_row, v_row);
                let w = codec.words_per_row();
                let kw = &mut self.ck[l][pos * w..(pos + 1) * w];
                codec.encode_row(lc, k_row, kw, self.k[l].row_mut(pos));
                let vw = &mut self.cv[l][pos * w..(pos + 1) * w];
                codec.encode_row(lc, v_row, vw, self.v[l].row_mut(pos));
            }
        }
    }

    /// Finish a block step: record `tokens`, whose K/V rows were written at
    /// positions `len()..len()+tokens.len()` via [`Self::write_kv_at`].
    /// Telemetry counts every token exactly once, whatever the block size.
    pub(crate) fn commit_block(&mut self, tokens: &[i32]) {
        debug_assert!(
            self.tokens.len() + tokens.len() <= self.capacity,
            "commit_block past capacity"
        );
        self.tokens.extend_from_slice(tokens);
        self.total_fed += tokens.len() as u64;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> GptConfig {
        GptConfig { vocab: 256, d_model: 32, n_layer: 3, n_head: 4, d_ff: 64, ctx: 16 }
    }

    #[test]
    fn geometry_and_accounting() {
        let c = KvCache::new(&cfg());
        assert_eq!(c.capacity(), 16);
        assert_eq!(c.evict_stride(), 4);
        assert_eq!(c.len(), 0);
        assert!(c.is_empty());
        // 2 buffers · 3 layers · 16 positions · 32 dims · 32 bits
        assert_eq!(c.memory_bits(), 2 * 3 * 16 * 32 * 32);
        assert!(c.compatible_with(&cfg()));
        let other = GptConfig { d_model: 64, ..cfg() };
        assert!(!c.compatible_with(&other));
    }

    #[test]
    fn capacity_and_stride_clamped() {
        let c = KvCache::with_capacity(&cfg(), 1000);
        assert_eq!(c.capacity(), 16, "capacity clamps to ctx");
        let c = KvCache::with_stride(&cfg(), 8, 0);
        assert_eq!(c.evict_stride(), 1, "stride clamps up to 1");
        let c = KvCache::with_stride(&cfg(), 8, 99);
        assert_eq!(c.evict_stride(), 8, "stride clamps down to capacity");
    }

    #[test]
    fn write_commit_reset_cycle() {
        let mut c = KvCache::with_capacity(&cfg(), 4);
        let d = cfg().d_model;
        for t in 0..3i32 {
            for l in 0..cfg().n_layer {
                c.write_kv_at(l, t as usize, &vec![t as f32; d], &vec![-t as f32; d]);
            }
            c.commit_block(&[t]);
        }
        assert_eq!(c.len(), 3);
        assert_eq!(c.tokens(), &[0, 1, 2]);
        assert_eq!(c.total_fed(), 3);
        let (k, v) = c.layer(1);
        assert_eq!(k.row(2)[0], 2.0);
        assert_eq!(v.row(2)[0], -2.0);
        c.reset();
        assert!(c.is_empty());
        assert_eq!(c.total_fed(), 3, "telemetry survives reset");
    }

    #[test]
    fn block_commit_equals_per_token_commit() {
        // the block-prefill write path must leave the cache in exactly the
        // state the per-token path produces: same tokens, rows, telemetry
        let d = cfg().d_model;
        let mut per_tok = KvCache::with_capacity(&cfg(), 8);
        let mut block = KvCache::with_capacity(&cfg(), 8);
        let toks = [5i32, 9, 2];
        for (j, &t) in toks.iter().enumerate() {
            for l in 0..cfg().n_layer {
                let kr = vec![t as f32 + l as f32; d];
                let vr = vec![-(t as f32); d];
                per_tok.write_kv_at(l, j, &kr, &vr);
                block.write_kv_at(l, j, &kr, &vr);
            }
            per_tok.commit_block(&[t]);
        }
        block.commit_block(&toks);
        assert_eq!(per_tok.tokens(), block.tokens());
        assert_eq!(per_tok.total_fed(), block.total_fed());
        for l in 0..cfg().n_layer {
            let (ka, va) = per_tok.layer(l);
            let (kb, vb) = block.layer(l);
            for i in 0..toks.len() {
                assert_eq!(ka.row(i), kb.row(i));
                assert_eq!(va.row(i), vb.row(i));
            }
        }
    }

    #[test]
    fn quantized_rows_redecode_bit_identically() {
        use crate::quant::kv::KvQuantSpec;
        let cfg = cfg();
        let codec = Arc::new(KvQuantCodec::new(
            KvQuantSpec::new(6).unwrap(),
            cfg.n_layer,
            cfg.d_model,
            5,
        ));
        let mut c = KvCache::with_codec(&cfg, Some(codec.clone()));
        // payload accounting: word-aligned codes only, no tile bits
        assert_eq!(c.memory_bits(), 2 * 3 * 16 * codec.code_bits_per_row());
        assert!(c.memory_bits() < 2 * 3 * 16 * 32 * 32);
        let row = |pos: usize, l: usize, s: usize| -> Vec<f32> {
            (0..32).map(|i| ((pos * 29 + i * 7 + l * 11 + s) % 13) as f32 - 6.0).collect()
        };
        for pos in 0..3 {
            for l in 0..cfg.n_layer {
                c.write_kv_at(l, pos, &row(pos, l, 0), &row(pos, l, 5));
            }
        }
        c.commit_block(&[7, 8, 9]);
        assert!(codec.frozen());
        let mut out = vec![0.0f32; 32];
        for pos in 0..3 {
            for l in 0..cfg.n_layer {
                let lc = codec.layer(l).unwrap();
                codec.decode_row(lc, c.k_codes(l, pos), &mut out);
                let (k, v) = c.layer(l);
                assert_eq!(
                    out.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    k.row(pos).iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    "layer {l} pos {pos}: K tile is not decode(codes)"
                );
                codec.decode_row(lc, c.v_codes(l, pos), &mut out);
                assert_eq!(
                    out.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    v.row(pos).iter().map(|x| x.to_bits()).collect::<Vec<_>>()
                );
            }
        }
        // exact caches expose no code payload
        let exact = KvCache::new(&cfg);
        assert!(exact.k_codes(0, 0).is_empty());
    }

    #[test]
    fn layer_range_cache_uses_absolute_indices() {
        let cfg = cfg();
        let mut node = KvCache::with_layers(&cfg, 8, 2, None, 1..3);
        assert_eq!(node.layers(), 1..3);
        // owns 2 of the 3 layers → 2/3 of the full-model footprint
        assert_eq!(node.memory_bits(), 2 * 2 * 8 * 32 * 32);
        assert!(!node.compatible_with(&cfg), "range caches are node-only");
        let d = cfg.d_model;
        for l in 1..3 {
            node.write_kv_at(l, 0, &vec![l as f32; d], &vec![-(l as f32); d]);
        }
        node.commit_block(&[42]);
        let (k, v) = node.layer(2);
        assert_eq!(k.row(0)[0], 2.0);
        assert_eq!(v.row(0)[0], -2.0);
    }

    #[test]
    fn begin_evict_slides_window() {
        let mut c = KvCache::with_stride(&cfg(), 8, 3);
        for t in 0..8i32 {
            for l in 0..cfg().n_layer {
                c.write_kv_at(l, t as usize, &[0.0; 32], &[0.0; 32]);
            }
            c.commit_block(&[t]);
        }
        assert_eq!(c.len(), c.capacity());
        let keep = c.begin_evict();
        assert_eq!(keep, vec![3, 4, 5, 6, 7]);
        assert!(c.is_empty(), "rows invalidated until the caller re-feeds");
        assert_eq!(c.evictions(), 1);
    }
}
