//! tinygpt model container — the Rust view of the weights `train.py` saved.
//!
//! The coordinator loads a `.pct` weight container, quantizes the
//! quantizable matrices with any [`crate::quant::Quantizer`], and feeds the
//! (fake-quant or fp) weights to the AOT forward executables in manifest
//! order. For the PCDVQ serving path the *codes* (not dense weights) feed
//! `fwd_q_<model>` instead.

mod config;
mod forward;
pub(crate) mod gpt;
mod kv_cache;
pub mod kv_pool;

pub use config::GptConfig;
pub use forward::{HostForward, LinearW};
pub(crate) use forward::{
    block_layer_forward, cached_layer_forward, embed_block, embed_block_at, layer_names,
    layer_norm, LayerNames, LayerParams,
};
pub use gpt::{GptModel, QuantizedGpt};
pub use kv_cache::KvCache;
pub use kv_pool::{
    KvLayerView, KvPage, KvPool, KvPoolCounters, KvStore, PageCodec, PagedKvCache,
};
