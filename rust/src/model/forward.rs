//! Host forward pass for the tinygpt — the codes-resident serving backend.
//!
//! Mirrors `python/compile/model.py::forward_fp` (pre-norm GPT, causal
//! attention, tanh-GELU MLP, LN ε = 1e-5) so the host path and the AOT XLA
//! path compute the same function. The point of the host path is the weight
//! representation: every quantizable linear is either a dense matrix (fp
//! baseline) or a compressed [`QuantizedWeight`] whose matmul runs straight
//! off the packed codes ([`QuantizedWeight::matmul_from_codes`]) — the dense
//! weight is **never** materialized, so serving keeps only codes + shared
//! codebooks resident (DESIGN.md §7).

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use super::{GptConfig, GptModel, KvCache, QuantizedGpt};
use crate::quant::QuantizedWeight;
use crate::tensor::{matmul, Matrix};

/// One quantizable linear: dense (fp / fake-quant) or compressed codes.
pub enum LinearW {
    Dense(Matrix),
    Codes(QuantizedWeight),
}

impl LinearW {
    /// `y = x · W` (x: `(n, rows)` → `(n, cols)`).
    fn matmul(&self, x: &Matrix) -> Matrix {
        match self {
            LinearW::Dense(w) => matmul(x, w),
            LinearW::Codes(q) => q.matmul_from_codes(x),
        }
    }

    /// Bits resident on the host for this linear.
    fn resident_bits(&self) -> u64 {
        match self {
            LinearW::Dense(w) => w.len() as u64 * 32,
            LinearW::Codes(q) => q.payload_bits(),
        }
    }
}

/// Pre-resolved tensor names of one layer — the per-token decode path looks
/// these up every step, so they are built once instead of `format!`-ing ten
/// fresh strings per layer per token.
struct LayerNames {
    ln1_g: String,
    ln1_b: String,
    wq: String,
    wk: String,
    wv: String,
    wo: String,
    ln2_g: String,
    ln2_b: String,
    w1: String,
    w2: String,
}

fn layer_names(n_layer: usize) -> Vec<LayerNames> {
    (0..n_layer)
        .map(|i| LayerNames {
            ln1_g: format!("layer{i}.ln1.g"),
            ln1_b: format!("layer{i}.ln1.b"),
            wq: format!("layer{i}.attn.wq"),
            wk: format!("layer{i}.attn.wk"),
            wv: format!("layer{i}.attn.wv"),
            wo: format!("layer{i}.attn.wo"),
            ln2_g: format!("layer{i}.ln2.g"),
            ln2_b: format!("layer{i}.ln2.b"),
            w1: format!("layer{i}.mlp.w1"),
            w2: format!("layer{i}.mlp.w2"),
        })
        .collect()
}

/// A host-servable model: fp tensors + per-linear weight representation.
pub struct HostForward {
    pub config: GptConfig,
    pub name: String,
    fp: BTreeMap<String, Matrix>,
    linears: BTreeMap<String, LinearW>,
    names: Vec<LayerNames>,
}

impl HostForward {
    /// Serve dense weights (fp baseline or fake-quant ablations). Consumes
    /// the model — tensors move into the server, no copy.
    pub fn from_dense(model: GptModel) -> Result<Self> {
        let qnames: std::collections::BTreeSet<String> =
            model.config.quantizable_names().into_iter().collect();
        let mut linears = BTreeMap::new();
        let mut fp = BTreeMap::new();
        for (name, m) in model.tensors {
            if qnames.contains(&name) {
                linears.insert(name, LinearW::Dense(m));
            } else {
                fp.insert(name, m);
            }
        }
        let s = HostForward {
            names: layer_names(model.config.n_layer),
            config: model.config,
            name: model.name,
            fp,
            linears,
        };
        s.check_complete()?;
        Ok(s)
    }

    /// Serve compressed artifacts: every quantizable linear stays packed
    /// codes + shared codebooks for the lifetime of the server.
    pub fn from_quantized(q: QuantizedGpt) -> Result<Self> {
        let mut linears = BTreeMap::new();
        for (name, w) in q.weights {
            linears.insert(name, LinearW::Codes(w));
        }
        let s = HostForward {
            names: layer_names(q.config.n_layer),
            config: q.config,
            name: q.name,
            fp: q.fp_tensors,
            linears,
        };
        s.check_complete()?;
        Ok(s)
    }

    fn check_complete(&self) -> Result<()> {
        for name in self.config.quantizable_names() {
            anyhow::ensure!(self.linears.contains_key(&name), "missing linear '{name}'");
        }
        // every fp tensor forward() will index must exist up front, so a
        // truncated container fails at construction, not mid-serve
        let mut fp_needed: Vec<String> =
            ["embed.tok", "embed.pos", "final_ln.g", "final_ln.b"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        for layer in 0..self.config.n_layer {
            for nm in ["ln1.g", "ln1.b", "ln2.g", "ln2.b"] {
                fp_needed.push(format!("layer{layer}.{nm}"));
            }
        }
        for name in fp_needed {
            anyhow::ensure!(self.fp.contains_key(&name), "missing fp tensor '{name}'");
        }
        Ok(())
    }

    fn fp(&self, name: &str) -> &Matrix {
        &self.fp[name]
    }

    fn linear(&self, name: &str, x: &Matrix) -> Result<Matrix> {
        Ok(self
            .linears
            .get(name)
            .with_context(|| format!("missing linear '{name}'"))?
            .matmul(x))
    }

    /// Bits resident for the quantizable matrices (payload only — shared
    /// codebooks are reported separately by [`Self::codebook_bits`]).
    pub fn resident_weight_bits(&self) -> u64 {
        self.linears.values().map(|l| l.resident_bits()).sum()
    }

    /// Bits of the distinct shared codebooks referenced by the linears.
    pub fn codebook_bits(&self) -> u64 {
        crate::quant::dedup_codebook_bits(self.linears.values().filter_map(|l| match l {
            LinearW::Codes(q) => Some(q),
            LinearW::Dense(_) => None,
        }))
    }

    /// True when every quantizable linear is served from packed codes.
    pub fn is_codes_resident(&self) -> bool {
        self.linears.values().all(|l| matches!(l, LinearW::Codes(_)))
    }

    /// Forward a `(b, t)` token block to logits `(b · t · vocab)`,
    /// matching `forward_fp` in `python/compile/model.py`.
    pub fn forward(&self, tokens: &[i32], b: usize, t: usize) -> Result<Vec<f32>> {
        let cfg = &self.config;
        anyhow::ensure!(tokens.len() == b * t, "token block shape mismatch");
        anyhow::ensure!(t <= cfg.ctx, "sequence longer than ctx");
        let d = cfg.d_model;
        let n_head = cfg.n_head;
        let hd = d / n_head;

        // embeddings
        let tok = self.fp("embed.tok");
        let pos = self.fp("embed.pos");
        let mut x = Matrix::zeros(b * t, d);
        for bi in 0..b {
            for ti in 0..t {
                let id = tokens[bi * t + ti];
                anyhow::ensure!(
                    id >= 0 && (id as usize) < cfg.vocab,
                    "token {id} out of vocab"
                );
                let row = x.row_mut(bi * t + ti);
                for ((o, &e), &p) in
                    row.iter_mut().zip(tok.row(id as usize)).zip(pos.row(ti))
                {
                    *o = e + p;
                }
            }
        }

        for layer in 0..cfg.n_layer {
            let pfx = format!("layer{layer}");
            // attention block
            let ln1 = layer_norm(
                &x,
                self.fp(&format!("{pfx}.ln1.g")).as_slice(),
                self.fp(&format!("{pfx}.ln1.b")).as_slice(),
            );
            let q = self.linear(&format!("{pfx}.attn.wq"), &ln1)?;
            let k = self.linear(&format!("{pfx}.attn.wk"), &ln1)?;
            let v = self.linear(&format!("{pfx}.attn.wv"), &ln1)?;
            let mut y = Matrix::zeros(b * t, d);
            let scale = 1.0 / (hd as f32).sqrt();
            let mut scores = vec![0.0f32; t];
            for bi in 0..b {
                for h in 0..n_head {
                    let c0 = h * hd;
                    for ti in 0..t {
                        let qrow = &q.row(bi * t + ti)[c0..c0 + hd];
                        for (tj, s) in scores.iter_mut().enumerate() {
                            if tj > ti {
                                *s = -1e9;
                                continue;
                            }
                            let krow = &k.row(bi * t + tj)[c0..c0 + hd];
                            *s = crate::tensor::dot(qrow, krow) * scale;
                        }
                        softmax_inplace(&mut scores);
                        let yrow = &mut y.row_mut(bi * t + ti)[c0..c0 + hd];
                        for (tj, &a) in scores.iter().enumerate().take(ti + 1) {
                            if a == 0.0 {
                                continue;
                            }
                            let vrow = &v.row(bi * t + tj)[c0..c0 + hd];
                            for (o, &vv) in yrow.iter_mut().zip(vrow) {
                                *o += a * vv;
                            }
                        }
                    }
                }
            }
            let attn = self.linear(&format!("{pfx}.attn.wo"), &y)?;
            add_inplace(&mut x, &attn);

            // mlp block
            let ln2 = layer_norm(
                &x,
                self.fp(&format!("{pfx}.ln2.g")).as_slice(),
                self.fp(&format!("{pfx}.ln2.b")).as_slice(),
            );
            let mut h1 = self.linear(&format!("{pfx}.mlp.w1"), &ln2)?;
            for v in h1.as_mut_slice() {
                *v = gelu(*v);
            }
            let h2 = self.linear(&format!("{pfx}.mlp.w2"), &h1)?;
            add_inplace(&mut x, &h2);
        }

        let xf = layer_norm(
            &x,
            self.fp("final_ln.g").as_slice(),
            self.fp("final_ln.b").as_slice(),
        );
        let logits = self.linear("head.w", &xf)?;
        Ok(logits.into_vec())
    }

    /// Advance one token through the model with a [`KvCache`], returning the
    /// logits (`vocab` floats) at the new position.
    ///
    /// Each call runs exactly one token through every layer and attends over
    /// the cached K/V plus the new position — O(1) weight work per token
    /// instead of the windowed re-forward's O(window). The logits are
    /// bit-consistent (within f32 rounding, ≤1e-5) with the last row of
    /// [`Self::forward`] over `cache.tokens()` — that re-forward is kept as
    /// the parity oracle (DESIGN.md §9).
    ///
    /// When the cache is full, the window slides by `cache.evict_stride()`
    /// tokens and the surviving window's K/V are rebuilt at their shifted
    /// positions before the new token is processed (see [`KvCache`] for the
    /// amortized cost).
    pub fn decode_step(&self, token: i32, cache: &mut KvCache) -> Result<Vec<f32>> {
        let x = self.advance_token(token, cache)?;
        self.head_logits(&x)
    }

    /// Feed a prompt through the cache token by token, returning the logits
    /// at the last position (the row that predicts the first generated
    /// token). Only the final position pays the head projection — earlier
    /// tokens advance K/V state only. Prompts longer than the cache
    /// capacity slide the window as generation would.
    pub fn prefill(&self, tokens: &[i32], cache: &mut KvCache) -> Result<Vec<f32>> {
        anyhow::ensure!(!tokens.is_empty(), "prefill needs at least one token");
        let (last, head) = tokens.split_last().unwrap();
        for &t in head {
            self.advance_token(t, cache)?;
        }
        self.decode_step(*last, cache)
    }

    /// Evict if full, then advance one token (K/V appended, hidden state
    /// returned). The head projection is the caller's decision — prefill
    /// and eviction rebuilds never need logits, so they skip it.
    fn advance_token(&self, token: i32, cache: &mut KvCache) -> Result<Matrix> {
        anyhow::ensure!(
            cache.compatible_with(&self.config),
            "KvCache geometry does not match this model"
        );
        if cache.len() == cache.capacity() {
            // Slide + rebuild: surviving tokens re-embed at shifted
            // positions, so their K/V must be recomputed (kv_cache.rs).
            let keep = cache.begin_evict();
            for &t in &keep {
                self.advance_at_tail(t, cache)?;
            }
        }
        self.advance_at_tail(token, cache)
    }

    /// One token through every layer at the cache tail (`pos = cache.len()`,
    /// which must be below capacity — eviction is the caller's job).
    /// Returns the final hidden state `(1, d_model)` pre-head.
    fn advance_at_tail(&self, token: i32, cache: &mut KvCache) -> Result<Matrix> {
        let cfg = &self.config;
        anyhow::ensure!(
            token >= 0 && (token as usize) < cfg.vocab,
            "token {token} out of vocab"
        );
        let d = cfg.d_model;
        let n_head = cfg.n_head;
        let hd = d / n_head;
        let pos = cache.len();
        debug_assert!(pos < cache.capacity(), "step_at_tail on a full cache");

        // embedding of the single new position
        let tok_emb = self.fp("embed.tok");
        let pos_emb = self.fp("embed.pos");
        let mut x = Matrix::zeros(1, d);
        for ((o, &e), &p) in x
            .row_mut(0)
            .iter_mut()
            .zip(tok_emb.row(token as usize))
            .zip(pos_emb.row(pos))
        {
            *o = e + p;
        }

        let scale = 1.0 / (hd as f32).sqrt();
        let mut scores = vec![0.0f32; pos + 1];
        for layer in 0..cfg.n_layer {
            let nm = &self.names[layer];
            // attention block: project the new token, append its K/V, attend
            // over the whole cached window (causality is free — the cache
            // only holds past positions)
            let ln1 = layer_norm(
                &x,
                self.fp(&nm.ln1_g).as_slice(),
                self.fp(&nm.ln1_b).as_slice(),
            );
            let q = self.linear(&nm.wq, &ln1)?;
            let k = self.linear(&nm.wk, &ln1)?;
            let v = self.linear(&nm.wv, &ln1)?;
            cache.write_kv(layer, k.row(0), v.row(0));
            let (kc, vc) = cache.layer(layer);
            let mut y = Matrix::zeros(1, d);
            for h in 0..n_head {
                let c0 = h * hd;
                let qrow = &q.row(0)[c0..c0 + hd];
                for (tj, s) in scores.iter_mut().enumerate() {
                    *s = crate::tensor::dot(qrow, &kc.row(tj)[c0..c0 + hd]) * scale;
                }
                softmax_inplace(&mut scores);
                let yrow = &mut y.row_mut(0)[c0..c0 + hd];
                for (tj, &a) in scores.iter().enumerate() {
                    if a == 0.0 {
                        continue;
                    }
                    let vrow = &vc.row(tj)[c0..c0 + hd];
                    for (o, &vv) in yrow.iter_mut().zip(vrow) {
                        *o += a * vv;
                    }
                }
            }
            let attn = self.linear(&nm.wo, &y)?;
            add_inplace(&mut x, &attn);

            // mlp block
            let ln2 = layer_norm(
                &x,
                self.fp(&nm.ln2_g).as_slice(),
                self.fp(&nm.ln2_b).as_slice(),
            );
            let mut h1 = self.linear(&nm.w1, &ln2)?;
            for vv in h1.as_mut_slice() {
                *vv = gelu(*vv);
            }
            let h2 = self.linear(&nm.w2, &h1)?;
            add_inplace(&mut x, &h2);
        }
        cache.commit(token);
        Ok(x)
    }

    /// Final layer norm + head projection of one hidden row — the part of a
    /// decode step that only matters when the logits are actually read.
    fn head_logits(&self, x: &Matrix) -> Result<Vec<f32>> {
        let xf = layer_norm(
            x,
            self.fp("final_ln.g").as_slice(),
            self.fp("final_ln.b").as_slice(),
        );
        let logits = self.linear("head.w", &xf)?;
        Ok(logits.into_vec())
    }
}

/// Row-wise pre-norm layer norm (population variance, ε = 1e-5), matching
/// `model.py::_layer_norm`.
fn layer_norm(x: &Matrix, g: &[f32], b: &[f32]) -> Matrix {
    let d = x.cols();
    assert_eq!(g.len(), d);
    assert_eq!(b.len(), d);
    let mut out = Matrix::zeros(x.rows(), d);
    for i in 0..x.rows() {
        let row = x.row(i);
        let mu = row.iter().sum::<f32>() / d as f32;
        let var = row.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + 1e-5).sqrt();
        for (((o, &v), &gg), &bb) in
            out.row_mut(i).iter_mut().zip(row).zip(g).zip(b)
        {
            *o = (v - mu) * inv * gg + bb;
        }
    }
    out
}

/// tanh-approximate GELU (JAX's default `jax.nn.gelu(approximate=True)`).
#[inline]
fn gelu(x: f32) -> f32 {
    const C: f32 = 0.797_884_56; // sqrt(2/π)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

fn softmax_inplace(xs: &mut [f32]) {
    let maxv = xs.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
    let mut sum = 0.0f32;
    for v in xs.iter_mut() {
        *v = (*v - maxv).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in xs.iter_mut() {
        *v *= inv;
    }
}

fn add_inplace(x: &mut Matrix, y: &Matrix) {
    debug_assert_eq!((x.rows(), x.cols()), (y.rows(), y.cols()));
    for (a, &b) in x.as_mut_slice().iter_mut().zip(y.as_slice()) {
        *a += b;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_model(name: &str) -> GptModel {
        let dir = std::env::temp_dir().join("pcdvq_forward_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("{name}.pct"));
        crate::model::gpt::tests::synthetic_model_file(&path, 64, 2);
        GptModel::load(&path).unwrap()
    }

    #[test]
    fn forward_shapes_and_finiteness() {
        let m = tmp_model("fwd");
        let hf = HostForward::from_dense(m.clone()).unwrap();
        let (b, t) = (2usize, 16usize);
        let tokens: Vec<i32> = (0..b * t).map(|i| (i * 13 % 251) as i32).collect();
        let out = hf.forward(&tokens, b, t).unwrap();
        assert_eq!(out.len(), b * t * m.config.vocab);
        assert!(out.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn causality_prefix_invariance() {
        // changing a future token must not change logits at earlier
        // positions (causal mask)
        let m = tmp_model("causal");
        let hf = HostForward::from_dense(m.clone()).unwrap();
        let t = 12usize;
        let v = m.config.vocab;
        let mut tokens: Vec<i32> = (0..t).map(|i| (i * 7 % 200) as i32).collect();
        let a = hf.forward(&tokens, 1, t).unwrap();
        tokens[t - 1] = 3; // perturb the last token
        let b = hf.forward(&tokens, 1, t).unwrap();
        for pos in 0..t - 2 {
            for j in 0..v {
                let (x, y) = (a[pos * v + j], b[pos * v + j]);
                assert!(
                    (x - y).abs() < 1e-4,
                    "pos {pos} logit {j} changed: {x} vs {y}"
                );
            }
        }
    }

    #[test]
    fn codes_resident_forward_matches_fake_quant_dense() {
        // the strongest host-path consistency check: serving from packed
        // codes must equal serving the explicitly-dequantized dense weights
        let m = tmp_model("codesres");
        let rtn = crate::quant::sq::Rtn::new(4);
        let q = QuantizedGpt::quantize(&m, &rtn);
        let dense = q.to_dense();
        let hf_codes = HostForward::from_quantized(q).unwrap();
        assert!(hf_codes.is_codes_resident());
        let hf_dense = HostForward::from_dense(dense).unwrap();
        let (b, t) = (1usize, 10usize);
        let tokens: Vec<i32> = (0..b * t).map(|i| (i * 31 % 97) as i32).collect();
        let a = hf_codes.forward(&tokens, b, t).unwrap();
        let bb = hf_dense.forward(&tokens, b, t).unwrap();
        for (x, y) in a.iter().zip(&bb) {
            assert!(
                (x - y).abs() <= 2e-4 * (1.0 + x.abs().max(y.abs())),
                "codes {x} vs dense {y}"
            );
        }
        // and the codes path keeps far fewer bits resident
        assert!(hf_codes.resident_weight_bits() * 4 < hf_dense.resident_weight_bits());
    }

    #[test]
    fn decode_step_matches_block_forward() {
        // incremental KV-cached decode must reproduce the full forward's
        // last-position logits (the §9 parity contract, unit-sized)
        let m = tmp_model("kv_unit");
        let hf = HostForward::from_dense(m.clone()).unwrap();
        let t = 9usize;
        let tokens: Vec<i32> = (0..t).map(|i| (i * 17 % 230) as i32).collect();
        let mut cache = KvCache::new(&m.config);
        let inc = hf.prefill(&tokens, &mut cache).unwrap();
        assert_eq!(cache.len(), t);
        assert_eq!(cache.tokens(), &tokens[..]);
        let v = m.config.vocab;
        let full = hf.forward(&tokens, 1, t).unwrap();
        let last = &full[(t - 1) * v..t * v];
        for (a, b) in inc.iter().zip(last) {
            assert!((a - b).abs() <= 1e-5, "incremental {a} vs block {b}");
        }
    }

    #[test]
    fn decode_step_rejects_mismatched_cache() {
        let m = tmp_model("kv_guard");
        let hf = HostForward::from_dense(m.clone()).unwrap();
        let other = GptConfig { d_model: m.config.d_model * 2, ..m.config };
        let mut cache = KvCache::new(&other);
        assert!(hf.decode_step(1, &mut cache).is_err());
        let mut ok = KvCache::new(&m.config);
        assert!(hf.decode_step(-1, &mut ok).is_err(), "token out of vocab");
        assert!(ok.is_empty(), "failed step must not commit");
    }

    #[test]
    fn batch_slots_independent() {
        let m = tmp_model("batch");
        let hf = HostForward::from_dense(m.clone()).unwrap();
        let t = 8usize;
        let v = m.config.vocab;
        let one: Vec<i32> = (0..t).map(|i| (i * 5 % 100) as i32).collect();
        let solo = hf.forward(&one, 1, t).unwrap();
        let mut two = one.clone();
        two.extend((0..t).map(|i| (i * 11 % 100) as i32));
        let pair = hf.forward(&two, 2, t).unwrap();
        for i in 0..t * v {
            assert!((solo[i] - pair[i]).abs() < 1e-5);
        }
    }
}
