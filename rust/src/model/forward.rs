//! Host forward pass for the tinygpt — the codes-resident serving backend.
//!
//! Mirrors `python/compile/model.py::forward_fp` (pre-norm GPT, causal
//! attention, tanh-GELU MLP, LN ε = 1e-5) so the host path and the AOT XLA
//! path compute the same function. The point of the host path is the weight
//! representation: every quantizable linear is either a dense matrix (fp
//! baseline) or a compressed [`QuantizedWeight`] whose matmul runs straight
//! off the packed codes via the blocked, LUT-driven kernel
//! ([`QuantizedWeight::matmul_from_codes`], DESIGN.md §11) — the dense
//! weight is **never** materialized, so serving keeps only codes + shared
//! codebooks (plus their derived decode LUTs) resident (DESIGN.md §7).
//!
//! Since PR 5 the hot path is multi-core (DESIGN.md §12): the fused matmul
//! fans out over output-column strips inside [`QuantizedWeight`], and the
//! per-position attention of [`causal_self_attention`] / the
//! `advance_block` chunk walk fans out over disjoint activation-row strips
//! on the shared pool ([`crate::exec`]) — both bit-identical to their
//! serial walks at every thread count.
//!
//! The forward is **cache-layout-blind**: attention reads K/V rows as
//! `&[f32]` through [`KvStore`]'s layer views, so the quantized cache
//! (DESIGN.md §15) needs no kernel changes — the [`KvStore`] quantizes on
//! write and keeps a LUT-decoded f32 tile as derived state, and this module
//! attends over the decoded rows exactly as it does over exact ones.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use super::{GptConfig, GptModel, KvCache, KvStore, QuantizedGpt};
use crate::quant::QuantizedWeight;
use crate::tensor::{matmul, Matrix};

/// One quantizable linear: dense (fp / fake-quant) or compressed codes.
pub enum LinearW {
    Dense(Matrix),
    Codes(QuantizedWeight),
}

impl LinearW {
    /// `y = x · W` (x: `(n, rows)` → `(n, cols)`).
    pub(crate) fn matmul(&self, x: &Matrix) -> Matrix {
        match self {
            LinearW::Dense(w) => matmul(x, w),
            LinearW::Codes(q) => q.matmul_from_codes(x),
        }
    }

    /// Bits resident on the host for this linear.
    pub(crate) fn resident_bits(&self) -> u64 {
        match self {
            LinearW::Dense(w) => w.len() as u64 * 32,
            LinearW::Codes(q) => q.payload_bits(),
        }
    }

    /// The compressed artifact behind this linear, if codes-resident.
    pub(crate) fn codes(&self) -> Option<&QuantizedWeight> {
        match self {
            LinearW::Codes(q) => Some(q),
            LinearW::Dense(_) => None,
        }
    }
}

/// Pre-resolved tensor names of one layer — the per-token decode path (and
/// every shard node's per-block walk) looks these up every step, so they
/// are built once instead of `format!`-ing ten fresh strings per layer per
/// token.
pub(crate) struct LayerNames {
    pub(crate) ln1_g: String,
    pub(crate) ln1_b: String,
    pub(crate) wq: String,
    pub(crate) wk: String,
    pub(crate) wv: String,
    pub(crate) wo: String,
    pub(crate) ln2_g: String,
    pub(crate) ln2_b: String,
    pub(crate) w1: String,
    pub(crate) w2: String,
}

pub(crate) fn layer_names(n_layer: usize) -> Vec<LayerNames> {
    (0..n_layer)
        .map(|i| LayerNames {
            ln1_g: format!("layer{i}.ln1.g"),
            ln1_b: format!("layer{i}.ln1.b"),
            wq: format!("layer{i}.attn.wq"),
            wk: format!("layer{i}.attn.wk"),
            wv: format!("layer{i}.attn.wv"),
            wo: format!("layer{i}.attn.wo"),
            ln2_g: format!("layer{i}.ln2.g"),
            ln2_b: format!("layer{i}.ln2.b"),
            w1: format!("layer{i}.mlp.w1"),
            w2: format!("layer{i}.mlp.w2"),
        })
        .collect()
}

/// A host-servable model: fp tensors + per-linear weight representation.
pub struct HostForward {
    pub config: GptConfig,
    pub name: String,
    fp: BTreeMap<String, Matrix>,
    linears: BTreeMap<String, LinearW>,
    names: Vec<LayerNames>,
}

impl HostForward {
    /// Serve dense weights (fp baseline or fake-quant ablations). Consumes
    /// the model — tensors move into the server, no copy.
    pub fn from_dense(model: GptModel) -> Result<Self> {
        let qnames: std::collections::BTreeSet<String> =
            model.config.quantizable_names().into_iter().collect();
        let mut linears = BTreeMap::new();
        let mut fp = BTreeMap::new();
        for (name, m) in model.tensors {
            if qnames.contains(&name) {
                linears.insert(name, LinearW::Dense(m));
            } else {
                fp.insert(name, m);
            }
        }
        let s = HostForward {
            names: layer_names(model.config.n_layer),
            config: model.config,
            name: model.name,
            fp,
            linears,
        };
        s.check_complete()?;
        Ok(s)
    }

    /// Serve compressed artifacts: every quantizable linear stays packed
    /// codes + shared codebooks for the lifetime of the server.
    pub fn from_quantized(q: QuantizedGpt) -> Result<Self> {
        let mut linears = BTreeMap::new();
        for (name, w) in q.weights {
            linears.insert(name, LinearW::Codes(w));
        }
        let s = HostForward {
            names: layer_names(q.config.n_layer),
            config: q.config,
            name: q.name,
            fp: q.fp_tensors,
            linears,
        };
        s.check_complete()?;
        Ok(s)
    }

    fn check_complete(&self) -> Result<()> {
        for name in self.config.quantizable_names() {
            anyhow::ensure!(self.linears.contains_key(&name), "missing linear '{name}'");
        }
        // every fp tensor forward() will index must exist up front, so a
        // truncated container fails at construction, not mid-serve
        let mut fp_needed: Vec<String> =
            ["embed.tok", "embed.pos", "final_ln.g", "final_ln.b"]
                .iter()
                .map(|s| s.to_string())
                .collect();
        for layer in 0..self.config.n_layer {
            for nm in ["ln1.g", "ln1.b", "ln2.g", "ln2.b"] {
                fp_needed.push(format!("layer{layer}.{nm}"));
            }
        }
        for name in fp_needed {
            anyhow::ensure!(self.fp.contains_key(&name), "missing fp tensor '{name}'");
        }
        Ok(())
    }

    fn fp(&self, name: &str) -> &Matrix {
        &self.fp[name]
    }

    fn linear(&self, name: &str, x: &Matrix) -> Result<Matrix> {
        Ok(self.linear_ref(name)?.matmul(x))
    }

    fn linear_ref(&self, name: &str) -> Result<&LinearW> {
        self.linears
            .get(name)
            .with_context(|| format!("missing linear '{name}'"))
    }

    /// Bits resident for the quantizable matrices (payload only — shared
    /// codebooks are reported separately by [`Self::codebook_bits`]).
    pub fn resident_weight_bits(&self) -> u64 {
        self.linears.values().map(|l| l.resident_bits()).sum()
    }

    /// Bits of the distinct shared codebooks referenced by the linears.
    pub fn codebook_bits(&self) -> u64 {
        crate::quant::dedup_codebook_bits(self.linears.values().filter_map(|l| match l {
            LinearW::Codes(q) => Some(q),
            LinearW::Dense(_) => None,
        }))
    }

    /// True when every quantizable linear is served from packed codes.
    pub fn is_codes_resident(&self) -> bool {
        self.linears.values().all(|l| matches!(l, LinearW::Codes(_)))
    }

    /// Forward a `(b, t)` token block to logits `(b · t · vocab)`,
    /// matching `forward_fp` in `python/compile/model.py`.
    ///
    /// The per-layer math is the shared [`block_layer_forward`] unit (also
    /// the body of every node in the layer-sharded chain,
    /// [`crate::coordinator::ShardedForward`]), so a sharded forward is
    /// bit-identical to this single-node pass by construction.
    pub fn forward(&self, tokens: &[i32], b: usize, t: usize) -> Result<Vec<f32>> {
        let cfg = &self.config;
        anyhow::ensure!(tokens.len() == b * t, "token block shape mismatch");
        anyhow::ensure!(t <= cfg.ctx, "sequence longer than ctx");
        let mut x = embed_block(
            self.fp("embed.tok"),
            self.fp("embed.pos"),
            tokens,
            b,
            t,
            cfg.vocab,
        )?;
        for layer in 0..cfg.n_layer {
            let p = self.layer_params(layer)?;
            block_layer_forward(&mut x, &p, b, t, cfg.n_head, cfg.head_dim());
        }
        let xf = layer_norm(
            &x,
            self.fp("final_ln.g").as_slice(),
            self.fp("final_ln.b").as_slice(),
        );
        let logits = self.linear("head.w", &xf)?;
        Ok(logits.into_vec())
    }

    /// Borrowed parameter view of one layer (pre-resolved names).
    fn layer_params(&self, layer: usize) -> Result<LayerParams<'_>> {
        let nm = &self.names[layer];
        Ok(LayerParams {
            ln1_g: self.fp(&nm.ln1_g),
            ln1_b: self.fp(&nm.ln1_b),
            wq: self.linear_ref(&nm.wq)?,
            wk: self.linear_ref(&nm.wk)?,
            wv: self.linear_ref(&nm.wv)?,
            wo: self.linear_ref(&nm.wo)?,
            ln2_g: self.fp(&nm.ln2_g),
            ln2_b: self.fp(&nm.ln2_b),
            w1: self.linear_ref(&nm.w1)?,
            w2: self.linear_ref(&nm.w2)?,
        })
    }

    /// Advance one token through the model with a KV cache (dense
    /// [`KvCache`] or paged [`crate::model::PagedKvCache`] — any
    /// [`KvStore`]), returning the logits (`vocab` floats) at the new
    /// position. Both layouts produce byte-identical cache state and logits
    /// for the same token stream (DESIGN.md §13).
    ///
    /// Each call runs exactly one token through every layer and attends over
    /// the cached K/V plus the new position — O(1) weight work per token
    /// instead of the windowed re-forward's O(window). The logits are
    /// bit-consistent (within f32 rounding, ≤1e-5) with the last row of
    /// [`Self::forward`] over `cache.tokens()` — that re-forward is kept as
    /// the parity oracle (DESIGN.md §9).
    ///
    /// When the cache is full, the window slides by `cache.evict_stride()`
    /// tokens and the surviving window's K/V are rebuilt at their shifted
    /// positions before the new token is processed (see [`KvCache`] for the
    /// amortized cost).
    pub fn decode_step<C: KvStore>(&self, token: i32, cache: &mut C) -> Result<Vec<f32>> {
        let x = self.advance_token(token, cache)?;
        self.head_logits(&x)
    }

    /// Feed a prompt through the cache token by token, returning the logits
    /// at the last position (the row that predicts the first generated
    /// token). Only the final position pays the head projection — earlier
    /// tokens advance K/V state only. Prompts longer than the cache
    /// capacity slide the window as generation would.
    ///
    /// This is the chunk-size-1 reference for [`Self::prefill_block`]: the
    /// two leave the cache **byte-identical** for every chunk size (pinned
    /// by `tests/continuous_batching.rs`).
    pub fn prefill<C: KvStore>(&self, tokens: &[i32], cache: &mut C) -> Result<Vec<f32>> {
        anyhow::ensure!(!tokens.is_empty(), "prefill needs at least one token");
        let (last, head) = tokens.split_last().unwrap();
        for &t in head {
            self.advance_token(t, cache)?;
        }
        self.decode_step(*last, cache)
    }

    /// Block prefill: bulk-fill the cache with `tokens`, processing up to
    /// `chunk` tokens per pass — the linear projections run as one
    /// `(chunk, d)` matmul instead of `chunk` single-row matmuls, and only
    /// the final position pays the head projection. Returns the logits at
    /// the last position.
    ///
    /// Eviction follows the exact slide+rebuild schedule of the
    /// token-at-a-time path: every output row of every kernel depends only
    /// on its own input row, so the resulting [`KvCache`] (tokens, K/V rows,
    /// telemetry) and logits are **byte-identical** to [`Self::prefill`]
    /// for any `chunk ≥ 1`.
    pub fn prefill_block<C: KvStore>(
        &self,
        tokens: &[i32],
        cache: &mut C,
        chunk: usize,
    ) -> Result<Vec<f32>> {
        let x = self.feed_blocks(tokens, cache, chunk)?;
        let d = self.config.d_model;
        let last = Matrix::from_vec(x.row(x.rows() - 1).to_vec(), 1, d);
        self.head_logits(&last)
    }

    /// Block prefill without the head projection: advances K/V state only.
    /// The continuous-batching server feeds one prompt chunk per scheduler
    /// step through this, and pays the single lazy head projection via
    /// [`Self::prefill_block`] on the prompt's final chunk.
    pub fn prefill_extend<C: KvStore>(
        &self,
        tokens: &[i32],
        cache: &mut C,
        chunk: usize,
    ) -> Result<()> {
        self.feed_blocks(tokens, cache, chunk).map(|_| ())
    }

    /// Drive `tokens` through the cache in blocks of at most `chunk`,
    /// evicting on the same boundaries the token-at-a-time path would.
    /// Returns the hidden states of the final block.
    fn feed_blocks<C: KvStore>(
        &self,
        tokens: &[i32],
        cache: &mut C,
        chunk: usize,
    ) -> Result<Matrix> {
        anyhow::ensure!(!tokens.is_empty(), "prefill needs at least one token");
        let chunk = chunk.max(1);
        let mut rest = tokens;
        let mut last = None;
        while !rest.is_empty() {
            if cache.len() == cache.capacity() {
                // Slide + rebuild: surviving tokens re-embed at shifted
                // positions, so their K/V must be recomputed (kv_cache.rs).
                let keep = cache.begin_evict();
                if !keep.is_empty() {
                    self.advance_block(&keep, cache)?;
                }
            }
            // a block never overruns capacity: the eviction boundary must
            // fall exactly where the per-token schedule puts it
            let take = chunk.min(rest.len()).min(cache.capacity() - cache.len());
            let (head, tail) = rest.split_at(take);
            last = Some(self.advance_block(head, cache)?);
            rest = tail;
        }
        Ok(last.expect("non-empty token stream"))
    }

    /// Evict if full, then advance one token (K/V appended, hidden state
    /// returned). The head projection is the caller's decision — prefill
    /// and eviction rebuilds never need logits, so they skip it.
    fn advance_token<C: KvStore>(&self, token: i32, cache: &mut C) -> Result<Matrix> {
        if cache.len() == cache.capacity() {
            // Slide + rebuild: surviving tokens re-embed at shifted
            // positions, so their K/V must be recomputed (kv_cache.rs).
            let keep = cache.begin_evict();
            if !keep.is_empty() {
                self.advance_block(&keep, cache)?;
            }
        }
        self.advance_block(&[token], cache)
    }

    /// One block of tokens through every layer at the cache tail (positions
    /// `cache.len()..cache.len()+block`, which must fit below capacity —
    /// eviction is the caller's job). Returns the final hidden states
    /// `(block, d_model)` pre-head.
    ///
    /// This is the single kernel behind [`Self::decode_step`],
    /// [`Self::prefill`] and [`Self::prefill_block`]: every per-row
    /// computation (layer norm, linear projections, per-position attention,
    /// GELU) is independent of the other rows in the block, so a block of
    /// `n` tokens produces bit-for-bit the state of `n` single-token calls.
    ///
    /// For codes-resident linears each `(block, d)` projection is one
    /// [`QuantizedWeight::matmul_from_codes`] call, and the blocked kernel
    /// decodes each code block into its L1 tile **once per chunk** — every
    /// activation row of the chunk reuses the decoded tile, rather than
    /// paying a full code-stream decode per row (the dominant block-prefill
    /// saving; DESIGN.md §11).
    fn advance_block<C: KvStore>(&self, tokens: &[i32], cache: &mut C) -> Result<Matrix> {
        let cfg = &self.config;
        anyhow::ensure!(
            cache.compatible_with(cfg),
            "KV cache geometry does not match this model"
        );
        let m = tokens.len();
        anyhow::ensure!(m > 0, "advance_block needs at least one token");
        let base = cache.len();
        anyhow::ensure!(
            base + m <= cache.capacity(),
            "block of {m} tokens overruns cache capacity ({base}+{m} > {})",
            cache.capacity()
        );
        let d = cfg.d_model;
        let n_head = cfg.n_head;
        let hd = d / n_head;

        let mut x = embed_block_at(
            self.fp("embed.tok"),
            self.fp("embed.pos"),
            tokens,
            base,
            cfg.vocab,
        )?;
        for layer in 0..cfg.n_layer {
            let p = self.layer_params(layer)?;
            cached_layer_forward(&mut x, &p, layer, base, cache, n_head, hd);
        }
        cache.commit_block(tokens);
        Ok(x)
    }

    /// Final layer norm + head projection of one hidden row — the part of a
    /// decode step that only matters when the logits are actually read.
    fn head_logits(&self, x: &Matrix) -> Result<Vec<f32>> {
        let xf = layer_norm(
            x,
            self.fp("final_ln.g").as_slice(),
            self.fp("final_ln.b").as_slice(),
        );
        let logits = self.linear("head.w", &xf)?;
        Ok(logits.into_vec())
    }
}

/// Borrowed view of one transformer layer's parameters — the unit
/// [`HostForward::forward`] and every shard node of the layer-sharded
/// chain ([`crate::coordinator::ShardedForward`]) run per layer.
pub(crate) struct LayerParams<'a> {
    pub ln1_g: &'a Matrix,
    pub ln1_b: &'a Matrix,
    pub wq: &'a LinearW,
    pub wk: &'a LinearW,
    pub wv: &'a LinearW,
    pub wo: &'a LinearW,
    pub ln2_g: &'a Matrix,
    pub ln2_b: &'a Matrix,
    pub w1: &'a LinearW,
    pub w2: &'a LinearW,
}

/// One pre-norm transformer layer over a `(b·t, d)` hidden block with full
/// causal attention, in place. Exactly the math `forward_fp` runs per
/// layer; shared so the single-node forward and the shard chain are the
/// same function composed differently (bit-identical by construction).
pub(crate) fn block_layer_forward(
    x: &mut Matrix,
    p: &LayerParams<'_>,
    b: usize,
    t: usize,
    n_head: usize,
    hd: usize,
) {
    let ln1 = layer_norm(x, p.ln1_g.as_slice(), p.ln1_b.as_slice());
    let q = p.wq.matmul(&ln1);
    let k = p.wk.matmul(&ln1);
    let v = p.wv.matmul(&ln1);
    let y = causal_self_attention(&q, &k, &v, b, t, n_head, hd);
    let attn = p.wo.matmul(&y);
    add_inplace(x, &attn);
    let ln2 = layer_norm(x, p.ln2_g.as_slice(), p.ln2_b.as_slice());
    let mut h1 = p.w1.matmul(&ln2);
    for vv in h1.as_mut_slice() {
        *vv = gelu(*vv);
    }
    let h2 = p.w2.matmul(&h1);
    add_inplace(x, &h2);
}

/// Token + position embeddings of a chunk at absolute positions
/// `base..base+m` — the cache-tail companion of [`embed_block`], shared by
/// `HostForward::advance_block` and shard node 0's cached walk
/// ([`crate::coordinator::ShardedForward`], DESIGN.md §16).
pub(crate) fn embed_block_at(
    tok: &Matrix,
    pos: &Matrix,
    tokens: &[i32],
    base: usize,
    vocab: usize,
) -> Result<Matrix> {
    let d = tok.cols();
    let mut x = Matrix::zeros(tokens.len(), d);
    for (j, &t) in tokens.iter().enumerate() {
        anyhow::ensure!(t >= 0 && (t as usize) < vocab, "token {t} out of vocab");
        for ((o, &e), &p) in x
            .row_mut(j)
            .iter_mut()
            .zip(tok.row(t as usize))
            .zip(pos.row(base + j))
        {
            *o = e + p;
        }
    }
    Ok(x)
}

/// One pre-norm transformer layer over an `(m, d)` chunk at the KV-cache
/// tail (absolute positions `base..base+m`), in place: project the whole
/// chunk in one matmul, write its K/V rows at `base..base+m`, then attend
/// per position over the cached window plus the chunk's own prefix
/// (causality: position `base+j` sees rows `0..=base+j`, which are all
/// already written).
///
/// This is the cached counterpart of [`block_layer_forward`] and the single
/// per-layer unit behind `HostForward::advance_block` **and** every shard
/// node's cached walk ([`crate::coordinator::ShardedForward`]) — the
/// sharded KV-cached decode is bit-identical to the single-node one by
/// construction (DESIGN.md §16). Attention reads go through the
/// layout-agnostic [`KvStore`] view: a contiguous matrix for the dense
/// cache, a page walk for the paged one — same rows either way.
pub(crate) fn cached_layer_forward<C: KvStore>(
    x: &mut Matrix,
    p: &LayerParams<'_>,
    layer: usize,
    base: usize,
    cache: &mut C,
    n_head: usize,
    hd: usize,
) {
    let m = x.rows();
    let d = n_head * hd;
    let scale = 1.0 / (hd as f32).sqrt();
    let ln1 = layer_norm(x, p.ln1_g.as_slice(), p.ln1_b.as_slice());
    let q = p.wq.matmul(&ln1);
    let k = p.wk.matmul(&ln1);
    let v = p.wv.matmul(&ln1);
    for j in 0..m {
        cache.write_kv_at(layer, base + j, k.row(j), v.row(j));
    }
    let view = cache.attn_view(layer);
    let mut y = Matrix::zeros(m, d);
    // every position's attention depends only on its own query row plus
    // the already-written K/V, so the chunk fans out as disjoint y-row
    // strips on the shared pool — bit-identical to the serial walk at any
    // thread count (a 1-token decode step stays inline)
    crate::exec::Pool::current().scope_groups_mut(
        y.as_mut_slice(),
        d,
        MIN_ATTN_ROWS_PER_STRIP,
        |j0, chunk| {
            let mut scores = vec![0.0f32; base + m];
            for (jj, yfull) in chunk.chunks_mut(d).enumerate() {
                let j = j0 + jj;
                let srow = &mut scores[..base + j + 1];
                for h in 0..n_head {
                    let c0 = h * hd;
                    let qrow = &q.row(j)[c0..c0 + hd];
                    for (tj, s) in srow.iter_mut().enumerate() {
                        *s = crate::tensor::dot(qrow, &view.k_row(tj)[c0..c0 + hd]) * scale;
                    }
                    softmax_inplace(srow);
                    let yrow = &mut yfull[c0..c0 + hd];
                    for (tj, &a) in srow.iter().enumerate() {
                        if a == 0.0 {
                            continue;
                        }
                        let vrow = &view.v_row(tj)[c0..c0 + hd];
                        for (o, &vv) in yrow.iter_mut().zip(vrow) {
                            *o += a * vv;
                        }
                    }
                }
            }
        },
    );
    let attn = p.wo.matmul(&y);
    add_inplace(x, &attn);

    // mlp block
    let ln2 = layer_norm(x, p.ln2_g.as_slice(), p.ln2_b.as_slice());
    let mut h1 = p.w1.matmul(&ln2);
    for vv in h1.as_mut_slice() {
        *vv = gelu(*vv);
    }
    let h2 = p.w2.matmul(&h1);
    add_inplace(x, &h2);
}

/// Token + position embeddings of a `(b, t)` block (positions restart at 0
/// per sequence) — shared by [`HostForward::forward`] and shard node 0.
pub(crate) fn embed_block(
    tok: &Matrix,
    pos: &Matrix,
    tokens: &[i32],
    b: usize,
    t: usize,
    vocab: usize,
) -> Result<Matrix> {
    let d = tok.cols();
    let mut x = Matrix::zeros(b * t, d);
    for bi in 0..b {
        for ti in 0..t {
            let id = tokens[bi * t + ti];
            anyhow::ensure!(id >= 0 && (id as usize) < vocab, "token {id} out of vocab");
            let row = x.row_mut(bi * t + ti);
            for ((o, &e), &p) in row.iter_mut().zip(tok.row(id as usize)).zip(pos.row(ti)) {
                *o = e + p;
            }
        }
    }
    Ok(x)
}

/// Fewest activation rows one attention worker takes: below this the spawn
/// cost beats the per-row attention work (DESIGN.md §12).
const MIN_ATTN_ROWS_PER_STRIP: usize = 4;

/// Full causal self-attention over a `(b·t, d)` projection block: row
/// `bi·t + ti` attends over its sequence prefix `0..=ti` per head.
///
/// Each output row depends only on its own query row (plus the shared K/V),
/// so the rows fan out as disjoint strips on the shared worker pool
/// ([`crate::exec::Pool::current`]) — bit-identical to the serial loop at
/// any thread count. The prefix-truncated softmax equals the `-1e9`-masked
/// full softmax bit-for-bit (the masked terms underflow to exactly `0.0`
/// and are skipped), which is how this helper replaced the original masked
/// loop without moving a single logit.
pub(crate) fn causal_self_attention(
    q: &Matrix,
    k: &Matrix,
    v: &Matrix,
    b: usize,
    t: usize,
    n_head: usize,
    hd: usize,
) -> Matrix {
    let d = n_head * hd;
    debug_assert_eq!(q.rows(), b * t);
    let scale = 1.0 / (hd as f32).sqrt();
    let mut y = Matrix::zeros(b * t, d);
    crate::exec::Pool::current().scope_groups_mut(
        y.as_mut_slice(),
        d,
        MIN_ATTN_ROWS_PER_STRIP,
        |row0, chunk| {
            let mut scores = vec![0.0f32; t];
            for (jj, yrow) in chunk.chunks_mut(d).enumerate() {
                let row = row0 + jj;
                let (bi, ti) = (row / t, row % t);
                let srow = &mut scores[..ti + 1];
                for h in 0..n_head {
                    let c0 = h * hd;
                    let qrow = &q.row(row)[c0..c0 + hd];
                    for (tj, s) in srow.iter_mut().enumerate() {
                        let krow = &k.row(bi * t + tj)[c0..c0 + hd];
                        *s = crate::tensor::dot(qrow, krow) * scale;
                    }
                    softmax_inplace(srow);
                    let yslot = &mut yrow[c0..c0 + hd];
                    for (tj, &a) in srow.iter().enumerate() {
                        if a == 0.0 {
                            continue;
                        }
                        let vrow = &v.row(bi * t + tj)[c0..c0 + hd];
                        for (o, &vv) in yslot.iter_mut().zip(vrow) {
                            *o += a * vv;
                        }
                    }
                }
            }
        },
    );
    y
}

/// Row-wise pre-norm layer norm (population variance, ε = 1e-5), matching
/// `model.py::_layer_norm`.
pub(crate) fn layer_norm(x: &Matrix, g: &[f32], b: &[f32]) -> Matrix {
    let d = x.cols();
    assert_eq!(g.len(), d);
    assert_eq!(b.len(), d);
    let mut out = Matrix::zeros(x.rows(), d);
    for i in 0..x.rows() {
        let row = x.row(i);
        let mu = row.iter().sum::<f32>() / d as f32;
        let var = row.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + 1e-5).sqrt();
        for (((o, &v), &gg), &bb) in
            out.row_mut(i).iter_mut().zip(row).zip(g).zip(b)
        {
            *o = (v - mu) * inv * gg + bb;
        }
    }
    out
}

/// tanh-approximate GELU (JAX's default `jax.nn.gelu(approximate=True)`).
#[inline]
pub(crate) fn gelu(x: f32) -> f32 {
    const C: f32 = 0.797_884_56; // sqrt(2/π)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

pub(crate) fn softmax_inplace(xs: &mut [f32]) {
    let maxv = xs.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
    let mut sum = 0.0f32;
    for v in xs.iter_mut() {
        *v = (*v - maxv).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in xs.iter_mut() {
        *v *= inv;
    }
}

pub(crate) fn add_inplace(x: &mut Matrix, y: &Matrix) {
    debug_assert_eq!((x.rows(), x.cols()), (y.rows(), y.cols()));
    for (a, &b) in x.as_mut_slice().iter_mut().zip(y.as_slice()) {
        *a += b;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_model(name: &str) -> GptModel {
        let dir = std::env::temp_dir().join("pcdvq_forward_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("{name}.pct"));
        crate::model::gpt::tests::synthetic_model_file(&path, 64, 2);
        GptModel::load(&path).unwrap()
    }

    #[test]
    fn forward_shapes_and_finiteness() {
        let m = tmp_model("fwd");
        let hf = HostForward::from_dense(m.clone()).unwrap();
        let (b, t) = (2usize, 16usize);
        let tokens: Vec<i32> = (0..b * t).map(|i| (i * 13 % 251) as i32).collect();
        let out = hf.forward(&tokens, b, t).unwrap();
        assert_eq!(out.len(), b * t * m.config.vocab);
        assert!(out.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn causality_prefix_invariance() {
        // changing a future token must not change logits at earlier
        // positions (causal mask)
        let m = tmp_model("causal");
        let hf = HostForward::from_dense(m.clone()).unwrap();
        let t = 12usize;
        let v = m.config.vocab;
        let mut tokens: Vec<i32> = (0..t).map(|i| (i * 7 % 200) as i32).collect();
        let a = hf.forward(&tokens, 1, t).unwrap();
        tokens[t - 1] = 3; // perturb the last token
        let b = hf.forward(&tokens, 1, t).unwrap();
        for pos in 0..t - 2 {
            for j in 0..v {
                let (x, y) = (a[pos * v + j], b[pos * v + j]);
                assert!(
                    (x - y).abs() < 1e-4,
                    "pos {pos} logit {j} changed: {x} vs {y}"
                );
            }
        }
    }

    #[test]
    fn codes_resident_forward_matches_fake_quant_dense() {
        // the strongest host-path consistency check: serving from packed
        // codes must equal serving the explicitly-dequantized dense weights
        let m = tmp_model("codesres");
        let rtn = crate::quant::sq::Rtn::new(4);
        let q = QuantizedGpt::quantize(&m, &rtn);
        let dense = q.to_dense();
        let hf_codes = HostForward::from_quantized(q).unwrap();
        assert!(hf_codes.is_codes_resident());
        let hf_dense = HostForward::from_dense(dense).unwrap();
        let (b, t) = (1usize, 10usize);
        let tokens: Vec<i32> = (0..b * t).map(|i| (i * 31 % 97) as i32).collect();
        let a = hf_codes.forward(&tokens, b, t).unwrap();
        let bb = hf_dense.forward(&tokens, b, t).unwrap();
        for (x, y) in a.iter().zip(&bb) {
            assert!(
                (x - y).abs() <= 2e-4 * (1.0 + x.abs().max(y.abs())),
                "codes {x} vs dense {y}"
            );
        }
        // and the codes path keeps far fewer bits resident
        assert!(hf_codes.resident_weight_bits() * 4 < hf_dense.resident_weight_bits());
    }

    #[test]
    fn decode_step_matches_block_forward() {
        // incremental KV-cached decode must reproduce the full forward's
        // last-position logits (the §9 parity contract, unit-sized)
        let m = tmp_model("kv_unit");
        let hf = HostForward::from_dense(m.clone()).unwrap();
        let t = 9usize;
        let tokens: Vec<i32> = (0..t).map(|i| (i * 17 % 230) as i32).collect();
        let mut cache = KvCache::new(&m.config);
        let inc = hf.prefill(&tokens, &mut cache).unwrap();
        assert_eq!(cache.len(), t);
        assert_eq!(cache.tokens(), &tokens[..]);
        let v = m.config.vocab;
        let full = hf.forward(&tokens, 1, t).unwrap();
        let last = &full[(t - 1) * v..t * v];
        for (a, b) in inc.iter().zip(last) {
            assert!((a - b).abs() <= 1e-5, "incremental {a} vs block {b}");
        }
    }

    #[test]
    fn prefill_block_bitwise_matches_token_at_a_time() {
        // one advance_block kernel behind both paths → byte-identical cache
        // state and logits for every chunk size
        let m = tmp_model("block");
        let hf = HostForward::from_dense(m.clone()).unwrap();
        let t = 13usize;
        let tokens: Vec<i32> = (0..t).map(|i| (i * 29 % 240) as i32).collect();
        let mut c1 = KvCache::new(&m.config);
        let a = hf.prefill(&tokens, &mut c1).unwrap();
        for chunk in [1usize, 4, 16, 64] {
            let mut c2 = KvCache::new(&m.config);
            let b = hf.prefill_block(&tokens, &mut c2, chunk).unwrap();
            assert_eq!(a, b, "chunk {chunk}: logits diverged");
            assert_eq!(c1.tokens(), c2.tokens(), "chunk {chunk}: window diverged");
            // prefill_extend advances the same state, minus the head logits
            let mut c3 = KvCache::new(&m.config);
            hf.prefill_extend(&tokens, &mut c3, chunk).unwrap();
            assert_eq!(c1.tokens(), c3.tokens());
        }
    }

    #[test]
    fn decode_step_rejects_mismatched_cache() {
        let m = tmp_model("kv_guard");
        let hf = HostForward::from_dense(m.clone()).unwrap();
        let other = GptConfig { d_model: m.config.d_model * 2, ..m.config };
        let mut cache = KvCache::new(&other);
        assert!(hf.decode_step(1, &mut cache).is_err());
        let mut ok = KvCache::new(&m.config);
        assert!(hf.decode_step(-1, &mut ok).is_err(), "token out of vocab");
        assert!(ok.is_empty(), "failed step must not commit");
    }

    #[test]
    fn batch_slots_independent() {
        let m = tmp_model("batch");
        let hf = HostForward::from_dense(m.clone()).unwrap();
        let t = 8usize;
        let v = m.config.vocab;
        let one: Vec<i32> = (0..t).map(|i| (i * 5 % 100) as i32).collect();
        let solo = hf.forward(&one, 1, t).unwrap();
        let mut two = one.clone();
        two.extend((0..t).map(|i| (i * 11 % 100) as i32));
        let pair = hf.forward(&two, 2, t).unwrap();
        for i in 0..t * v {
            assert!((solo[i] - pair[i]).abs() < 1e-5);
        }
    }
}
